"""Quickstart: the TRA in 60 lines.

Builds distributed matrix multiply as a TRA expression (paper §2.1's
running example), compiles it to the IA (Table 1), lets the cost-based
optimizer pick among BMM / CPMM / RMM placements (§4.2.2), and executes
both on the reference and dense executors.

Run:  python examples/quickstart.py  (or PYTHONPATH=src)
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Placement, RelType, TraAgg, TraInput, TraJoin,
                        compile_tra, cost_plan, describe, evaluate_ia,
                        evaluate_tra, from_tensor, get_kernel, optimize,
                        to_tensor)


def main():
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (64, 96))
    B = jax.random.normal(jax.random.PRNGKey(1), (96, 48))

    # chunk into tensor relations: keys = block coordinates
    # (block grids divide the 4-site mesh so every partitioning is legal)
    RA = from_tensor(A, (16, 24))           # frontier (4, 4)
    RB = from_tensor(B, (24, 12))           # frontier (4, 4)

    # C = A @ B  ≙  Σ_(⟨0,2⟩, matAdd)( ⋈_(⟨1⟩,⟨0⟩, matMul)(R_A, R_B) )
    ta = TraInput("A", RA.rtype)
    tb = TraInput("B", RB.rtype)
    mm = TraAgg(TraJoin(ta, tb, (1,), (0,), get_kernel("matMul")),
                (0, 2), get_kernel("matAdd"))

    # logical evaluation
    out = evaluate_tra(mm, {"A": RA, "B": RB})
    np.testing.assert_allclose(np.asarray(to_tensor(out)),
                               np.asarray(A @ B), rtol=1e-4, atol=1e-4)
    print("TRA logical evaluation matches jnp matmul ✓")

    # Table-1 default physical plan (broadcast-based)
    default = compile_tra(mm, {"A": Placement.partitioned((0,), ("sites",)),
                               "B": Placement.partitioned((0,), ("sites",))})
    print("\nTable-1 default IA plan:")
    print(describe(default))
    print(cost_plan(default, {"sites": 4}))

    # cost-based optimization (the paper's §4 optimizer)
    res = optimize(mm,
                   {"A": Placement.partitioned((1,), ("sites",)),
                    "B": Placement.partitioned((0,), ("sites",))},
                   site_axes=("sites",), axis_sizes={"sites": 4})
    print(f"\noptimized plan (cost {res.cost:,} floats moved):")
    print(describe(res.plan))

    # the optimized physical plan computes the same thing
    out2 = evaluate_ia(res.plan, {"A": RA, "B": RB})
    np.testing.assert_allclose(np.asarray(to_tensor(out2)),
                               np.asarray(A @ B), rtol=1e-4, atol=1e-4)
    print("optimized IA plan matches ✓")


if __name__ == "__main__":
    main()
