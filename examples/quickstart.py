"""Quickstart: the TRA in 60 lines.

Builds distributed matrix multiply with the lazy ``Expr`` frontend
(paper §2.1's running example), runs it through the unified ``Engine`` —
which compiles via Table 1, lets the cost-based optimizer pick among
BMM / CPMM / RMM placements (§4.2.2), and selects the fused Σ∘⋈
contraction — and shows the same expression executing on the reference
and jit executors unchanged.

Run:  python examples/quickstart.py  (or PYTHONPATH=src)
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

import repro.core as tra
from repro.core import Engine, Placement, cost_plan, from_tensor, to_tensor


def main():
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (64, 96))
    B = jax.random.normal(jax.random.PRNGKey(1), (96, 48))

    # chunk into tensor relations: keys = block coordinates
    # (block grids divide the 4-site mesh so every partitioning is legal)
    RA = from_tensor(A, (16, 24))           # frontier (4, 4)
    RB = from_tensor(B, (24, 12))           # frontier (4, 4)

    # C = A @ B  ≙  Σ_(⟨0,2⟩, matAdd)( ⋈_(⟨1⟩,⟨0⟩, matMul)(R_A, R_B) )
    # — the Expr frontend builds the logical plan lazily, with shapes
    # checked at construction time
    a = tra.input("A", key_shape=(4, 4), bound=(16, 24))
    b = tra.input("B", key_shape=(4, 4), bound=(24, 12))
    mm = a @ b

    # one expression, any executor: the eager reference walk...
    ref = Engine(executor="reference", optimize=False)
    out = ref.run(mm, A=RA, B=RB)
    np.testing.assert_allclose(np.asarray(to_tensor(out)),
                               np.asarray(A @ B), rtol=1e-4, atol=1e-4)
    print("TRA reference evaluation matches jnp matmul ✓")

    # ...or the optimizing engine (the paper's §4 optimizer + fused Σ∘⋈),
    # staged into a single jit.  compile() is cached by structure.
    eng = Engine(executor="jit",
                 input_placements={
                     "A": Placement.partitioned((1,), ("sites",)),
                     "B": Placement.partitioned((0,), ("sites",))},
                 axis_sizes={"sites": 4})
    compiled = eng.compile(mm)
    print(f"\noptimized plan (cost {compiled.cost:,} floats moved):")
    print(compiled.describe())

    out2 = compiled.run(A=RA, B=RB)
    np.testing.assert_allclose(np.asarray(to_tensor(out2)),
                               np.asarray(A @ B), rtol=1e-4, atol=1e-4)
    assert eng.compile(mm) is compiled          # compile-cache hit
    print("optimized jit execution matches ✓ (compile cached)")

    # the Table-1 default physical plan (what optimize=False engines run)
    default = tra.compile_tra(mm, {
        "A": Placement.partitioned((0,), ("sites",)),
        "B": Placement.partitioned((0,), ("sites",))})
    print("\nTable-1 default IA plan:")
    print(tra.describe(default))
    print(cost_plan(default, {"sites": 4}))


if __name__ == "__main__":
    main()
