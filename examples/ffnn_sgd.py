"""Paper §5.3: distributed SGD for a two-layer FFNN, written in the TRA.

Runs the full forward + backward + update TRA program — a three-root Expr
DAG compiled once by the Engine, so the shared forward pass is evaluated
a single time per step — verifies it against a direct jnp implementation,
trains for a few steps to show the loss falling, and prices the paper's
TRA-DP vs TRA-MP physical plans with the exact cost model (Table 9's
decision).

Run:  PYTHONPATH=src python examples/ffnn_sgd.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Engine, from_tensor, optimize, to_tensor
from repro.core.programs import (ffnn_dp_placements, ffnn_mp_placements,
                                 ffnn_step_tra)


def main():
    nb, db, hb, lb = 4, 4, 4, 4      # block grids divide 4 sites
    bn, bd, bh, bl = 8, 4, 16, 2
    N, D, H, L = nb * bn, db * bd, hb * bh, lb * bl
    eta = 0.02
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (N, D))
    Wt = jax.random.normal(jax.random.PRNGKey(4), (D, L)) * 0.5
    Y = jax.nn.sigmoid(X @ Wt)                  # learnable targets
    W1 = jax.random.normal(jax.random.PRNGKey(2), (D, H)) * (D ** -0.5)
    W2 = jax.random.normal(jax.random.PRNGKey(3), (H, L)) * (H ** -0.5)

    prog = ffnn_step_tra(nb, db, hb, lb, bn, bd, bh, bl, eta=eta)
    # one jitted artifact for all three roots; the Expr DAG shares the
    # forward pass, and compile() is cached across the training loop
    engine = Engine(executor="jit", optimize=False)
    step = engine.compile((prog.w1_new, prog.w2_new, prog.a2))

    def tra_step(W1, W2):
        w1n, w2n, a2 = step.run(
            X=from_tensor(X, (bn, bd)), Y=from_tensor(Y, (bn, bl)),
            W1=from_tensor(W1, (bd, bh)), W2=from_tensor(W2, (bh, bl)))
        a2 = to_tensor(a2)
        return (to_tensor(w1n), to_tensor(w2n),
                float(jnp.mean((a2 - Y) ** 2)))

    # one step vs direct jnp
    a1 = jax.nn.relu(X @ W1)
    a2 = jax.nn.sigmoid(a1 @ W2)
    d2 = a2 - Y
    gw2 = a1.T @ d2
    gw1 = X.T @ ((a1 > 0) * (d2 @ W2.T))
    w1n, w2n, _ = tra_step(W1, W2)
    np.testing.assert_allclose(np.asarray(w1n),
                               np.asarray(W1 - eta * gw1), atol=1e-4)
    np.testing.assert_allclose(np.asarray(w2n),
                               np.asarray(W2 - eta * gw2), atol=1e-4)
    print("TRA backprop step == direct jnp backprop ✓")

    losses = []
    for i in range(12):
        W1, W2, loss = tra_step(W1, W2)
        losses.append(loss)
    print("MSE per TRA-SGD step:",
          " ".join(f"{l:.4f}" for l in losses))
    assert losses[-1] < losses[0]

    # the same training, TRA-native end to end: loss + autodiff backward
    # + AdamW update compiled as ONE named multi-root program that the
    # engine caches — steps >= 2 are pure dispatch
    from repro.core import AdamW, TraTrainer
    from repro.core.programs import ffnn_train_step_tra

    W1b = jax.random.normal(jax.random.PRNGKey(2), (D, H)) * (D ** -0.5)
    W2b = jax.random.normal(jax.random.PRNGKey(3), (H, L)) * (H ** -0.5)
    step_prog = ffnn_train_step_tra(
        nb, db, hb, lb, bn, bd, bh, bl,
        optimizer=AdamW(1e-2, weight_decay=0.01))
    trainer = TraTrainer(Engine(executor="jit"), step_prog,
                         params={"W1": from_tensor(W1b, (bd, bh)),
                                 "W2": from_tensor(W2b, (bh, bl))})
    losses = trainer.fit(12, X=from_tensor(X, (bn, bd)),
                         Y=from_tensor(Y, (bn, bl)))
    print("Σ-BCE per TRA-AdamW step:",
          " ".join(f"{l:.1f}" for l in losses))
    assert losses[-1] < losses[0]
    assert trainer.engine.cache_hits == 11     # steps 2+ are pure dispatch
    print("TRA-native AdamW train loop: compile once, dispatch forever ✓")

    # plan pricing: TRA-DP vs TRA-MP (per weight-update root)
    sites = 4
    for tag, places in [("TRA-DP", ffnn_dp_placements(nb, db, hb, lb)),
                        ("TRA-MP", ffnn_mp_placements(nb, db, hb, lb))]:
        cost = 0
        for root in (prog.w1_new, prog.w2_new):
            r = optimize(root, places, site_axes=("sites",),
                         axis_sizes={"sites": sites},
                         try_logical_rewrites=False, accounting="paper")
            cost += r.cost
        print(f"  {tag}: total update cost = {cost:,} floats "
              f"(paper accounting, {sites} sites)")
    print("(Table 9 reproduction across the paper's H grid: "
          "benchmarks/ffnn.py)")


if __name__ == "__main__":
    main()
