"""Paper §5.2: nearest-neighbour search in a Riemannian metric space.

    d_A(x_i, x_q) = (x_i − x_q) A (x_i − x_q)ᵀ,  argmin over rows i

Builds the TRA program with the Expr frontend, executes it through the
Engine, verifies against a direct jnp computation, and compares the
paper's two IA implementations (Opt4Horizontal vs Opt4Vertical) under the
exact cost model — showing the model picks the right one per data shape
(paper Tables 5–6).

Run:  PYTHONPATH=src python examples/nn_search.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Engine, from_tensor, optimize
from repro.core import tra as tra_ops
from repro.core.plan import Placement
from repro.core.programs import nn_search_tra


def build_env(Xs, xq, Am, rows, dcol):
    rxq = tra_ops.rekey(from_tensor(xq, (1, dcol)), lambda k: (k[1],))
    return {"xq": rxq,
            "X": from_tensor(Xs, (rows, dcol)),
            "A": from_tensor(Am, (dcol, dcol))}


def main():
    key = jax.random.PRNGKey(0)
    n_blocks, d_blocks, rows, dcol = 8, 4, 32, 16
    N, D = n_blocks * rows, d_blocks * dcol
    Xs = jax.random.normal(key, (N, D))
    xq = jax.random.normal(jax.random.PRNGKey(1), (1, D))
    Am = jnp.eye(D) + 0.05 * jax.random.normal(jax.random.PRNGKey(2),
                                               (D, D))

    prog = nn_search_tra(n_blocks, d_blocks, rows, dcol)
    env = build_env(Xs, xq, Am, rows, dcol)
    res = Engine(executor="jit", optimize=False).run(prog.result, **env)
    val, idx = (float(x) for x in np.asarray(res.data).reshape(-1))

    diff = Xs - xq
    dist = jnp.einsum("nd,de,ne->n", diff, Am, diff)
    assert int(idx) == int(jnp.argmin(dist)), (idx, jnp.argmin(dist))
    assert abs(val - float(dist.min())) < 1e-2
    print(f"TRA nearest neighbour: row {int(idx)} (d={val:.4f}) — "
          f"matches the direct computation ✓")

    # plan choice: Opt4Horizontal (X row-partitioned, xq/A broadcast) vs
    # Opt4Vertical (X col-partitioned, cross-product projection)
    sites = 4
    for name, places in [
        ("Opt4Horizontal", {"xq": Placement.replicated(),
                            "A": Placement.replicated(),
                            "X": Placement.partitioned((0,), ("sites",))}),
        ("Opt4Vertical", {"xq": Placement.replicated(),
                          "A": Placement.partitioned((0,), ("sites",)),
                          "X": Placement.partitioned((1,), ("sites",))}),
    ]:
        r = optimize(prog.dist, places, site_axes=("sites",),
                     axis_sizes={"sites": sites},
                     try_logical_rewrites=False)
        print(f"  {name:16s} best-plan cost = {r.cost:,} floats moved")
    print("(the cost model picks Horizontal for many-rows data and "
          "Vertical for wide data — see benchmarks/nn_search.py for the "
          "Table 5/6 shapes)")


if __name__ == "__main__":
    main()
