"""End-to-end driver: train a ~110M-parameter decoder LM for a few
hundred steps on the synthetic pipeline, with checkpointing and restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--seq 128]

The config is a qwen2-family dense decoder scaled to ~110M params
(12L, d=768, 12H/4KV, ff=2048, 32k vocab).  On a TPU pod the same driver
runs any ``--arch`` full config via repro.launch.train; this example keeps
everything CPU-runnable while exercising the full production stack:
TRA-planned sharding (when a mesh is given), AdamW + cosine schedule,
deterministic resumable data, async atomic checkpoints.
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig
from repro.data import DataConfig
from repro.models import count_params
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig

LM_110M = ModelConfig(
    name="repro-110m",
    family="dense",
    n_layers=12,
    d_model=768,
    vocab_size=32_000,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    d_ff=2_048,
    qkv_bias=True,
    remat="none",                 # small model: keep activations
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args(argv)

    cfg = LM_110M
    print(f"model: {cfg.name}  params={count_params(cfg):,}")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=0, grammar_frac=0.7)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=50,
                         ckpt_dir=args.ckpt_dir, warmup=20,
                         adamw=AdamWConfig(lr=args.lr))
    tr = Trainer(cfg, dcfg, tcfg)
    tr.init_or_restore()

    t0 = time.time()
    hist = tr.train()
    dt = time.time() - t0
    if hist:
        losses = [h["loss"] for h in hist]
        k = max(len(losses) // 10, 1)
        print(f"\ntrained {len(hist)} steps in {dt:.0f}s "
              f"({dt / max(len(hist), 1):.2f} s/step)")
        print(f"loss: first10={sum(losses[:k]) / k:.4f}  "
              f"last10={sum(losses[-k:]) / k:.4f}")
        print(f"accuracy last step: {hist[-1]['accuracy']:.3f}")
        if args.steps >= 50:
            assert min(losses[-10:]) < losses[0], "loss did not decrease"
    print("checkpoints:", tr.store.committed_steps())


if __name__ == "__main__":
    main()
