"""Serving example: batched prefill + greedy decode with a KV cache.

    PYTHONPATH=src python examples/serve_decode.py [--arch gemma2-2b]

Uses the reduced (smoke) config of the chosen architecture so it runs on
CPU; the identical code path serves full configs on a pod (see
repro.launch.serve, which adds TRA-planned cache sharding).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import decode_step, init_params, prefill


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    cache_len = args.prompt_len + args.gen
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = args.batch, args.prompt_len

    if cfg.input_mode == "tokens":
        batch = {"tokens": jax.random.randint(key, (B, S), 0,
                                              cfg.vocab_size)}
    else:
        batch = {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                             jnp.bfloat16)}

    pf = jax.jit(lambda p, b: prefill(cfg, p, b, cache_len))
    t0 = time.perf_counter()
    logits, cache = pf(params, batch)
    jax.block_until_ready(logits)
    print(f"[{cfg.name}] prefill {B}×{S}: "
          f"{(time.perf_counter() - t0) * 1e3:.1f} ms "
          f"(cache capacity {cache_len})")

    step = jax.jit(lambda p, c, b: decode_step(cfg, p, c, b),
                   donate_argnums=(1,))
    tok = logits.argmax(-1).astype(jnp.int32)
    seqs = [jax.device_get(tok)[:, 0]]
    t1 = time.perf_counter()
    for _ in range(args.gen - 1):
        if cfg.input_mode == "tokens":
            inp = {"token": tok}
        else:
            inp = {"embed": jax.random.normal(key, (B, 1, cfg.d_model),
                                              jnp.bfloat16)}
        logits, cache = step(params, cache, inp)
        tok = logits.argmax(-1).astype(jnp.int32)
        seqs.append(jax.device_get(tok)[:, 0])
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t1
    print(f"decode {args.gen - 1} steps: {B * (args.gen - 1) / dt:.1f} "
          f"tok/s aggregate")
    for b in range(min(B, 2)):
        print(f"  seq {b}: {[int(s[b]) for s in seqs]}")


if __name__ == "__main__":
    main()
