"""Serving example: continuous batching with TraServer.

    PYTHONPATH=src python examples/serve_decode.py [--requests 24]

Builds the smoke step-decode LM sized from the gemma2 smoke config,
serves a mixed stream of prompt/generation lengths through
:class:`~repro.serve.server.TraServer` (token-level continuous batching
over a fixed-capacity slot-keyed state relation), and checks a few
responses against the per-request dense oracle.  The dense-transformer
prefill/decode comparison loop lives in
``python -m repro.launch.serve --dense-oracle``.
"""
import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.core import Engine
from repro.serve import RecurrentLM, TraServer, lm_mix


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--executor", default="jit")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    lm = RecurrentLM.from_config(cfg, capacity=args.capacity)
    engine = Engine(executor=args.executor)
    server = TraServer(engine, lm)
    server.warmup()
    print(f"[{cfg.name}] serving {lm.name} (d={lm.d}, vocab={lm.vocab}) "
          f"with {lm.capacity} decode slots on executor={engine.executor}")

    rng = np.random.default_rng(0)
    reqs = lm_mix(lm, rng, args.requests, prompt_len=(1, 6),
                  new_tokens=(2, 12))
    t0 = time.perf_counter()
    results = server.serve(reqs)
    dt = time.perf_counter() - t0

    total = sum(len(r["tokens"]) for r in results)
    print(f"decoded {total} tokens for {len(reqs)} requests in "
          f"{dt * 1e3:.1f} ms ({total / dt:.1f} tok/s, continuous batching)")
    for i in (0, 1):
        oracle_tokens, _ = lm.oracle_decode(reqs[i].prompt,
                                            reqs[i].max_new_tokens)
        match = "matches" if results[i]["tokens"] == oracle_tokens \
            else "MISMATCHES"
        print(f"  req {i}: prompt {reqs[i].prompt} -> "
              f"{results[i]['tokens']} ({match} per-request oracle)")
    stats = server.stats()
    print(f"cache: {len(stats['artifacts'])} pinned artifact(s), "
          f"{stats['cache_misses_since_warmup']} misses after warmup")


if __name__ == "__main__":
    main()
