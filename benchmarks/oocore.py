"""Out-of-core streaming: store-backed execution vs in-memory dispatch.

Exercises the ISSUE-8 acceptance end to end and guards the numbers that
make the host relation store worth routing through:

* **over-budget contraction** — a fused Σ∘⋈ matmul whose operands are
  ≥4× the engine's ``memory_budget`` runs through
  ``Engine(memory_budget=...)``: key-range chunks stream from the host
  store with double-buffered prefetch and the oversized output writes
  back chunk-wise as a :class:`~repro.store.HostRelation`.  Guards: the
  result matches the in-memory oracle at 1e-5, the analytic peak device
  live-set stays under the budget, and the warm streamed run is within
  ``SLOWDOWN_MAX``× the warm in-memory run (bounded-slowdown claim);
* **copy/compute overlap** — the prefetch of chunk *i+1* must hide under
  chunk *i*'s compute: cumulative ``hidden_copy_s / copy_s`` from the
  cached artifact's :class:`~repro.launch.metering.StreamStats` must be
  ≥ ``OVERLAP_MIN`` (only the first load of each run is exposed);
* **chained plan** — a two-matmul chain ``(A@B)@C`` with A ≥4× budget
  streams end to end with the intermediate *never* materialized whole on
  device (peak stays under budget — zero rematerialization).

``--smoke`` swaps the timing sweep for a byte-accurate fault-injection
check: ``inject_oom(ok_bytes=B)`` makes the resident contraction OOM on
the plain engine while the SAME injected budget lets
``Engine(memory_budget=...)`` complete through the store.  Emits
``BENCH_oocore.json`` next to the repo root and raises on guard failure
— wired into ``benchmarks/run.py`` and the CI smoke step.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

# operands 8 MiB vs a 2 MiB budget → 4× over; compute-heavy bounds so
# the chunk loop's Python dispatch doesn't dominate the slowdown ratio
BUDGET = 2 * 1024 * 1024
KA, BA = (64, 8), (64, 64)
KB, BB = (8, 2), (64, 64)      # 2 MiB output → chunk-wise store write-back
REPS = 3
SLOWDOWN_MAX = 25.0             # warm streamed ≤ 25× warm in-memory
OVERLAP_MIN = 0.5               # hidden prefetch time / total copy time
SMOKE_OK_BYTES = 96 * 1024      # injected device capacity for --smoke


def _rel(seed, key_shape, bound):
    from repro.core import RelType, TensorRelation

    rng = np.random.default_rng(seed)
    data = np.asarray(rng.normal(size=tuple(key_shape) + tuple(bound)),
                      np.float32)
    return TensorRelation(data, RelType(tuple(key_shape), tuple(bound)))


def _np(res):
    return res.to_numpy() if hasattr(res, "to_numpy") \
        else np.asarray(res.data)


def _wall(fn) -> float:
    """Best-of-REPS wall clock in ms (noise only ever adds time)."""
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        fn()
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def _stream_stats(engine):
    for slot in engine.cache_info():
        if slot.stream_stats is not None:
            return slot.stream_stats
    raise AssertionError("no streamed artifact in the compile cache")


def bench_contraction() -> Dict:
    """≥4×-budget fused matmul: in-memory engine vs store streaming."""
    import jax

    import repro.core as tra
    from repro.core import Engine

    a = tra.input("A", key_shape=KA, bound=BA)
    b = tra.input("B", key_shape=KB, bound=BB)
    e = a @ b
    RA, RB = _rel(0, KA, BA), _rel(1, KB, BB)
    in_bytes = RA.data.nbytes + RB.data.nbytes
    want = _np(Engine(executor="reference", optimize=False,
                      fuse=False).run(e, A=RA, B=RB))

    mem = Engine(executor="jit")
    jax.block_until_ready(mem.run(e, A=RA, B=RB).data)   # pay the compile
    mem_ms = _wall(lambda: jax.block_until_ready(
        mem.run(e, A=RA, B=RB).data))

    ooc = Engine(executor="jit", memory_budget=BUDGET)
    got = ooc.run(e, A=RA, B=RB)          # compile + first streamed pass
    # fp32 accumulation-order noise at depth 512 sits just above 1e-5
    np.testing.assert_allclose(_np(got), want, atol=1e-4, rtol=1e-4)
    ooc_ms = _wall(lambda: ooc.run(e, A=RA, B=RB))

    st = _stream_stats(ooc)
    return {
        "operand_bytes": in_bytes,
        "budget_bytes": BUDGET,
        "over_budget_factor": round(in_bytes / BUDGET, 2),
        "mode": st.mode,
        "chunks_per_run": st.chunks // st.runs,
        "runs": st.runs,
        "memory_ms": round(mem_ms, 2),
        "streamed_ms": round(ooc_ms, 2),
        "slowdown": round(ooc_ms / max(mem_ms, 1e-9), 2),
        "peak_device_bytes": st.peak_device_bytes,
        "h2d_mb": round(st.h2d_bytes / 2 ** 20, 2),
        "d2h_mb": round(st.d2h_bytes / 2 ** 20, 2),
        "overlap_efficiency": round(st.overlap_efficiency, 3),
        "out_is_host_relation": hasattr(got, "to_numpy"),
    }


def bench_chained() -> Dict:
    """(A@B)@C with A ≥4× budget: the A@B intermediate streams through
    the chain without ever materializing whole on device."""
    import repro.core as tra
    from repro.core import Engine

    ka, ba = (64, 8), (64, 64)
    kb, bb = (8, 4), (64, 16)
    kc, bc = (4, 1), (16, 16)
    a = tra.input("A", key_shape=ka, bound=ba)
    b = tra.input("B", key_shape=kb, bound=bb)
    c = tra.input("C", key_shape=kc, bound=bc)
    e = (a @ b) @ c
    RA, RB, RC = _rel(2, ka, ba), _rel(3, kb, bb), _rel(4, kc, bc)
    want = _np(Engine(executor="reference", optimize=False,
                      fuse=False).run(e, A=RA, B=RB, C=RC))

    ooc = Engine(executor="jit", memory_budget=BUDGET)
    got = ooc.run(e, A=RA, B=RB, C=RC)
    # two chained fp32 contractions (depths 512 → 256) compound rounding
    np.testing.assert_allclose(_np(got), want, atol=1e-3, rtol=1e-3)
    st = _stream_stats(ooc)
    inter_bytes = RA.data.nbytes // ba[1] * bb[1]   # A@B materialized
    return {
        "operand_bytes": RA.data.nbytes,
        "intermediate_bytes": inter_bytes,
        "budget_bytes": BUDGET,
        "mode": st.mode,
        "chunks": st.chunks,
        "peak_device_bytes": st.peak_device_bytes,
    }


def smoke() -> List[str]:
    """Byte-accurate fault check: the injected device budget OOMs the
    in-memory engine but the store-streaming engine completes."""
    import repro.core as tra
    from repro.core import Engine
    from repro.core.faults import FaultInjector
    from repro.core.guards import is_oom_error

    ka, ba, kb, bb = (64, 4), (32, 16), (4, 1), (16, 16)
    a = tra.input("A", key_shape=ka, bound=ba)
    b = tra.input("B", key_shape=kb, bound=bb)
    e = a @ b
    RA, RB = _rel(5, ka, ba), _rel(6, kb, bb)
    want = _np(Engine(executor="reference", optimize=False,
                      fuse=False).run(e, A=RA, B=RB))

    mem = Engine(executor="jit", degrade=False,
                 fault_injector=FaultInjector().inject_oom(
                     ok_bytes=SMOKE_OK_BYTES))
    try:
        mem.run(e, A=RA, B=RB)
        raise AssertionError("in-memory engine survived the injected OOM")
    except Exception as err:  # noqa: BLE001
        if not is_oom_error(err):
            raise
    ooc = Engine(executor="jit", memory_budget=64 * 1024,
                 fault_injector=FaultInjector().inject_oom(
                     ok_bytes=SMOKE_OK_BYTES))
    got = ooc.run(e, A=RA, B=RB)
    np.testing.assert_allclose(_np(got), want, atol=1e-5, rtol=1e-5)
    st = _stream_stats(ooc)
    assert st.mode == "stream-out" and st.chunks > 1, st.as_dict()
    return [
        "# out-of-core smoke (byte-accurate injected device budget)",
        f"in-memory engine: OOM at ok_bytes={SMOKE_OK_BYTES} (expected)",
        f"Engine(memory_budget=65536): completed in {st.chunks} chunks "
        f"({st.mode}), peak ~{st.peak_device_bytes}B — matches oracle",
        "smoke guard (OOM in-memory, completes through the store): PASS",
    ]


def run(mesh=None) -> List[str]:
    contraction = bench_contraction()
    chained = bench_chained()
    out = {"contraction": contraction, "chained": chained,
           "slowdown_max": SLOWDOWN_MAX, "overlap_min": OVERLAP_MIN}
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_oocore.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)

    lines = ["# out-of-core streaming (single device, host relation store)"]
    lines.append(
        f"contraction {contraction['over_budget_factor']}× over the "
        f"{BUDGET // 2 ** 20} MiB budget: in-memory "
        f"{contraction['memory_ms']:.1f} ms → streamed "
        f"{contraction['streamed_ms']:.1f} ms "
        f"(×{contraction['slowdown']:.1f}, "
        f"{contraction['chunks_per_run']} chunks/run, "
        f"peak ~{contraction['peak_device_bytes'] / 2 ** 20:.2f} MiB)")
    lines.append(
        f"transfers: H2D {contraction['h2d_mb']:.1f} MiB / D2H "
        f"{contraction['d2h_mb']:.1f} MiB, prefetch overlap "
        f"{contraction['overlap_efficiency'] * 100:.0f}%, oversized "
        f"output written back as a host relation: "
        f"{contraction['out_is_host_relation']}")
    lines.append(
        f"chained (A@B)@C: {chained['mode']} in {chained['chunks']} "
        f"chunks, {chained['intermediate_bytes'] / 2 ** 20:.1f} MiB "
        f"intermediate never whole on device "
        f"(peak ~{chained['peak_device_bytes'] / 2 ** 20:.2f} MiB)")

    ok = (contraction["peak_device_bytes"] <= BUDGET
          and chained["peak_device_bytes"] <= BUDGET
          and contraction["slowdown"] <= SLOWDOWN_MAX
          and contraction["overlap_efficiency"] >= OVERLAP_MIN
          and contraction["out_is_host_relation"])
    lines.append(
        f"regression guard (peak ≤ budget, slowdown ≤ {SLOWDOWN_MAX:.0f}×, "
        f"overlap ≥ {OVERLAP_MIN * 100:.0f}%): {'PASS' if ok else 'FAIL'}")
    if not ok:
        raise AssertionError(f"out-of-core regression guard failed: {out}")
    return lines


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        print("\n".join(smoke()))
    else:
        print("\n".join(run()))
