"""Static-verifier overhead: strict validation on the compile path.

``Engine(validate="strict")`` runs the per-compile verifier passes
(placement, collectives, streaming, memory — see ``repro.analysis``) on
every compile-cache miss.  The pitch of compile-time verification is
that it is *free at runtime and cheap at compile time*; this benchmark
backs the second half with a number and a guard:

* **first-step wall** — the §5.3 FFNN train step through a fresh
  ``Engine(executor="jit")`` per measurement, timed from cold
  ``TraTrainer.step`` to ``block_until_ready`` (TRA lowering + JAX trace
  + XLA compile + one execution), with ``validate="off"`` vs
  ``validate="strict"``.  Guard: strict adds less than
  ``ANALYSIS_OVERHEAD_MAX`` (5 %);
* **verifier-only wall** — ``verify_plans`` on the same program in
  isolation, so the report separates "what the passes cost" from
  "what the compile costs".

Emits ``BENCH_analysis.json`` next to the repo root and raises on guard
failure — wired into ``benchmarks/run.py`` and the CI smoke step.
"""
from __future__ import annotations

import json
import os
import statistics
import time
from typing import Dict, List

# §5.3 FFNN sized so XLA compile + the O(n³) contractions dominate (same
# dims as benchmarks/robustness.py): the verifier is O(plan nodes) and
# must vanish against a real compile, not against a toy one
DIMS = (8, 16, 16, 2, 128, 64, 64, 32)   # nb db hb lb bn bd bh bl
REPS = 5
SMOKE_REPS = 2
ANALYSIS_OVERHEAD_MAX = 0.05             # strict ≤ 1.05× off


def _build(dims):
    import jax

    from repro.core import from_tensor
    from repro.core.programs import ffnn_train_step_tra

    nb, db, hb, lb, bn, bd, bh, bl = dims
    N, D, H, L = nb * bn, db * bd, hb * bh, lb * bl
    X = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    Wt = jax.random.normal(jax.random.PRNGKey(4), (D, L)) * 0.5
    Y = jax.nn.sigmoid(X @ Wt)
    W1 = jax.random.normal(jax.random.PRNGKey(2), (D, H)) * (D ** -0.5)
    W2 = jax.random.normal(jax.random.PRNGKey(3), (H, L)) * (H ** -0.5)
    step = ffnn_train_step_tra(*dims)
    data = dict(X=from_tensor(X, (bn, bd)), Y=from_tensor(Y, (bn, bl)))
    params = dict(W1=from_tensor(W1, (bd, bh)),
                  W2=from_tensor(W2, (bh, bl)))
    return step, data, params


def _first_step_ms(step, data, params, mode: str) -> float:
    """Cold compile+execute wall through a fresh engine and trainer.

    A fresh ``Engine`` per call keeps both the engine compile cache and
    the jit cache cold (the compiled callable is a new closure), so each
    measurement pays the full trace + XLA compile the verifier rides on.
    """
    import jax

    from repro.core import TraTrainer
    from repro.core.engine import Engine

    eng = Engine(executor="jit", optimize=False, validate=mode)
    trainer = TraTrainer(eng, step, params=params)
    t0 = time.perf_counter()
    trainer.step(**data)
    jax.block_until_ready(trainer.params["W1"].data)
    return (time.perf_counter() - t0) * 1e3


def bench_compile_overhead(reps: int = REPS) -> Dict:
    from repro.analysis import verify_plans

    step, data, params = _build(DIMS)
    roots = tuple(step.roots.values())

    # one throwaway compile to pay process-wide warm-up (jax backend
    # init, module imports) outside every timed measurement
    _first_step_ms(step, data, params, "off")

    rec: Dict = {"reps": reps}
    for mode in ("off", "strict"):
        walls = sorted(_first_step_ms(step, data, params, mode)
                       for _ in range(reps))
        # best-of-N: scheduler and XLA-thread noise only ever adds time
        rec[f"{mode}_compile_ms"] = round(walls[0], 2)
    rec["overhead"] = round(
        rec["strict_compile_ms"] / max(rec["off_compile_ms"], 1e-9) - 1.0,
        4)

    verify_walls = []
    n_diags = 0
    for _ in range(max(reps, 3)):
        t0 = time.perf_counter()
        diags = verify_plans(roots, executor="jit")
        verify_walls.append((time.perf_counter() - t0) * 1e3)
        n_diags = len(diags)
    rec["verifier_only_ms"] = round(statistics.median(verify_walls), 3)
    rec["verifier_diagnostics"] = n_diags
    rec["verifier_errors"] = len(diags.errors)
    return rec


def run(mesh=None, smoke: bool = False) -> List[str]:
    rec = bench_compile_overhead(SMOKE_REPS if smoke else REPS)
    out = {"dims": list(DIMS), "compile": rec,
           "analysis_overhead_max": ANALYSIS_OVERHEAD_MAX}
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_analysis.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)

    lines = ["# static verifier overhead (§5.3 FFNN train step, "
             "cold compile)"]
    lines.append(
        f"first step (lower+trace+XLA+run): validate=off "
        f"{rec['off_compile_ms']:.1f} ms → strict "
        f"{rec['strict_compile_ms']:.1f} ms "
        f"({rec['overhead'] * 100:+.2f}%)")
    lines.append(
        f"verifier alone (4 compile passes over the train-step plans): "
        f"{rec['verifier_only_ms']:.2f} ms, "
        f"{rec['verifier_diagnostics']} diagnostic(s), "
        f"{rec['verifier_errors']} error(s)")

    ok = (rec["overhead"] <= ANALYSIS_OVERHEAD_MAX
          and rec["verifier_errors"] == 0)
    lines.append(
        f"regression guard (strict compile overhead "
        f"≤{ANALYSIS_OVERHEAD_MAX * 100:.0f}%, corpus program verifies "
        f"clean): {'PASS' if ok else 'FAIL'}")
    if not ok:
        raise AssertionError(f"analysis overhead guard failed: {out}")
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer repetitions (CI smoke)")
    args = ap.parse_args()
    print("\n".join(run(smoke=args.smoke)))
