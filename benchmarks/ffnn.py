"""Paper Tables 7–9: two-layer FFNN SGD, TRA-DP vs TRA-MP.

Table 9 reproduction (5 nodes, paper accounting):

* TRA-DP — weights stored partitioned, broadcast each step, gradients
  two-phase-aggregated and shuffled once:  cost = (|W1|+|W2|)·(s+1).
* TRA-MP — W1 col-/W2 row-partitioned; the two N×H activation relations
  (a1 forward, ∇a1 backward) are broadcast:  cost = 2·N·H·s.

Both are constructed as IA fragments and priced by the exact cost model —
the numbers must match Table 9 to the digit, and the model must pick
TRA-DP for the Google-speech shapes and TRA-MP for the AmazonCat-14k
extreme-classification shapes (the paper's §5.4 headline claim).

A scaled-down *execution* of the full TRA backprop program through both
placement families validates numerical equivalence (examples/ffnn_sgd.py
covers the single-site case; tests/_distributed_checks.py the 8-device
case).
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.ffnn_paper import SPEECH_GRID, XML_GRID, FFNNConfig
from repro.core import Placement, RelType, comm_cost
from repro.core.plan import Bcast, IAInput, LocalAgg, LocalJoin, Shuf
from repro.core.kernels_registry import get_kernel

S = ("sites",)
SITES = 5

# paper Table 9 (floats moved, 5-node cluster)
TABLE9 = {
    "speech-100k": ("dp", 9.7e8, 1.0e10),
    "speech-150k": ("dp", 1.5e9, 1.5e10),
    "speech-200k": ("dp", 1.9e9, 2.0e10),
    "xml-1k": ("mp", 3.7e9, 1.0e7),
    "xml-3k": ("mp", 1.1e10, 3.0e7),
    "xml-5k": ("mp", 1.8e10, 5.0e7),
    "xml-7k": ("mp", 2.6e10, 7.0e7),
}


def _bcast_cost(floats: int, grid: int, sz: Dict[str, int]) -> int:
    """Paper cost of broadcasting a ``grid``-partitioned relation."""
    rel = IAInput("t", RelType((grid,), (floats // grid,)),
                  Placement.partitioned((0,), S))
    return comm_cost(Bcast(rel), sz, accounting="paper")


def _grad_shuffle_cost(floats: int, grid: int, sz: Dict[str, int]) -> int:
    """Two-phase aggregated gradient: the per-site partials (key dim 0 =
    batch block, partitioned) are locally summed over the *kept* weight
    grid (key dim 1), then one SHUF moves the logical w floats (paper
    prices the shuffle at the logical relation size)."""
    src = IAInput("g", RelType((grid, grid), (1, floats // grid)),
                  Placement.partitioned((0,), S))
    partial = LocalAgg(src, (1,), get_kernel("matAdd"), partial=True)
    return comm_cost(Shuf(partial, (0,), S), sz, accounting="paper")


def predicted_costs(cfg: FFNNConfig, sites: int = SITES) -> Dict[str, int]:
    sz = {"sites": sites}
    w1 = cfg.d_in * cfg.d_hidden
    w2 = cfg.d_hidden * cfg.d_out
    dp = (_bcast_cost(w1, sites, sz) + _bcast_cost(w2, sites, sz)
          + _grad_shuffle_cost(w1, sites, sz)
          + _grad_shuffle_cost(w2, sites, sz))
    act = cfg.batch * cfg.d_hidden
    mp = _bcast_cost(act, sites, sz) * 2          # a1 fwd + ∇a1 bwd
    return {"TRA-DP": dp, "TRA-MP": mp}


def run(mesh=None) -> List[str]:
    lines = ["# Table 9 — FFNN predicted costs, 5 nodes (paper "
             "accounting)"]
    all_match = True
    for cfg in list(SPEECH_GRID) + list(XML_GRID):
        costs = predicted_costs(cfg)
        want_winner, want_dp, want_mp = TABLE9[cfg.name]
        winner = "dp" if costs["TRA-DP"] < costs["TRA-MP"] else "mp"
        dp_ok = abs(costs["TRA-DP"] - want_dp) / want_dp < 0.05
        mp_ok = abs(costs["TRA-MP"] - want_mp) / want_mp < 0.05
        pick_ok = winner == want_winner
        all_match &= dp_ok and mp_ok and pick_ok
        lines.append(
            f"{cfg.name:12s} DP={costs['TRA-DP']:.2e}"
            f"{'✓' if dp_ok else '✗'} "
            f"MP={costs['TRA-MP']:.2e}{'✓' if mp_ok else '✗'} "
            f"→ {winner.upper()} "
            f"{'✓' if pick_ok else '✗ expected ' + want_winner}")
    lines.append(f"Table 9 reproduction: "
                 f"{'ALL MATCH' if all_match else 'MISMATCH'}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
