"""Assemble EXPERIMENTS.md sections from experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.report > EXPERIMENTS_tables.md
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks.roofline import DRYRUN_DIR, SHAPE_ORDER, load


def dryrun_section() -> List[str]:
    lines = ["## §Dry-run — lower + compile, every (arch × shape × mesh)",
             "",
             "| arch | shape | mesh | status | lower | compile | "
             "args/chip | temp/chip | out/chip | accum |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for mesh in ("single", "multi"):
        for r in load(mesh):
            mn = "16×16" if mesh == "single" else "2×16×16"
            if r["status"] == "skip":
                lines.append(f"| {r['arch']} | {r['shape']} | {mn} | "
                             f"skip (sub-quadratic-only shape) | | | | | | |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {r['arch']} | {r['shape']} | {mn} | "
                             f"ERROR {r.get('error', '')[:40]} | | | | | | |")
                continue
            m = r["memory"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mn} | ok "
                f"| {r['lower_s']}s | {r['compile_s']}s "
                f"| {m['argument_gib']:.2f}G | {m['temp_gib']:.2f}G "
                f"| {m['output_gib']:.2f}G | {r.get('accum_steps', 1)} |")
    return lines


def roofline_section() -> List[str]:
    lines = ["## §Roofline — three terms per cell (single-pod 16×16, "
             "TPU v5e constants)",
             "",
             "Structural (loop-corrected) metering; raw XLA "
             "cost_analysis values are in the JSON records "
             "(`xla_raw`, per-while-iteration — see "
             "src/repro/launch/metering.py for why).",
             "",
             "| arch | shape | compute | memory | collective | dominant | "
             "MODEL/HLO | roofline frac | bottleneck note |",
             "|---|---|---|---|---|---|---|---|---|"]

    def note(r: Dict) -> str:
        t = r["roofline"]
        d = t["dominant"]
        det = t.get("detail", {})
        coll = {k: v for k, v in det.items() if k.startswith("coll/")}
        top = max(coll, key=coll.get) if coll else ""
        if d == "collective":
            return (f"{top.split('/')[-1]} dominates — shrink weight/"
                    f"activation movement (see §Perf)")
        if d == "memory":
            if r["kind"] == "decode":
                return "KV-cache/weight reads per token — quantize cache"
            return "activation traffic — fuse/remat"
        return "MXU-bound — healthy; overlap the collective tail"

    for r in load("single"):
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        frac = t.get("roofline_fraction") or 0
        useful = t.get("useful_flops_ratio") or 0
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {t['compute_s']:.4f}s | {t['memory_s']:.4f}s "
            f"| {t['collective_s']:.4f}s | **{t['dominant']}** "
            f"| {useful:.2f} | {frac * 100:.1f}% | {note(r)} |")
    return lines


def main() -> None:
    print("\n".join(dryrun_section()))
    print()
    print("\n".join(roofline_section()))


if __name__ == "__main__":
    main()
