"""TRA-native train step: compile-once dispatch + fused-plan guards.

Benchmarks the §5.3 FFNN train step built by
:func:`repro.core.programs.ffnn_train_step_tra` (forward + BCE loss +
autodiff backward + AdamW update as ONE named multi-root program):

* **compile-once / dispatch-forever** — step 1 pays the compile; every
  later step must be a pure compile-cache dispatch.  Measured as the
  ratio of step-1 wall (compile + run) to the median steady-state step,
  and asserted exactly via ``Engine.cache_hits == steps − 1``;
* **fused vs unfused step** — the same program through the fusing engine
  and through the ``fuse=False`` unfused oracle: the fused
  gradient+update plan must win wall-clock and peak temp bytes (the
  backward of the train step contains the same Σ∘⋈ contractions the
  PR-1 machinery collapses);
* **convergence** — the loss history over the benchmark steps must be
  decreasing end-to-end (guards against a fast-but-wrong plan).

Emits ``BENCH_train.json`` next to the repo root and raises on guard
failure — wired into ``benchmarks/run.py`` and the slow-marker bench
test in ``tests/test_train_bench.py``.
"""
from __future__ import annotations

import json
import os
import statistics
import time
from typing import Dict, List

# §5.3 FFNN scaled so the contraction dominates Python dispatch AND
# scheduler noise on a loaded CPU (the wall-clock guard runs inside the
# full test suite) — N=512, D=H=256, L=64 in 8×4 / 4×4 / 4×2 block grids
DIMS = (8, 4, 4, 2, 64, 64, 64, 32)      # nb db hb lb bn bd bh bl
STEPS = 12
TIMING_REPS = 5                          # best-of-N wall measurements
DISPATCH_SPEEDUP_MIN = 5.0               # step-1 wall / steady-state wall


def _build(dims):
    import jax

    from repro.core import AdamW, from_tensor
    from repro.core.programs import ffnn_train_step_tra

    nb, db, hb, lb, bn, bd, bh, bl = dims
    N, D, H, L = nb * bn, db * bd, hb * bh, lb * bl
    X = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    Wt = jax.random.normal(jax.random.PRNGKey(4), (D, L)) * 0.5
    Y = jax.nn.sigmoid(X @ Wt)
    W1 = jax.random.normal(jax.random.PRNGKey(2), (D, H)) * (D ** -0.5)
    W2 = jax.random.normal(jax.random.PRNGKey(3), (H, L)) * (H ** -0.5)
    step = ffnn_train_step_tra(*dims, optimizer=AdamW(1e-2))
    data = dict(X=from_tensor(X, (bn, bd)), Y=from_tensor(Y, (bn, bl)))
    params = dict(W1=from_tensor(W1, (bd, bh)),
                  W2=from_tensor(W2, (bh, bl)))
    return step, data, params


def bench_dispatch() -> Dict:
    """Step-1 compile vs steady-state cached dispatch."""
    import jax

    from repro.core import Engine, TraTrainer

    step, data, params = _build(DIMS)
    eng = Engine(executor="jit", optimize=False)
    trainer = TraTrainer(eng, step, params=params)

    t0 = time.perf_counter()
    trainer.step(**data)
    jax.block_until_ready(trainer.params["W1"].data)
    first_ms = (time.perf_counter() - t0) * 1e3

    laters = []
    for _ in range(STEPS - 1):
        t0 = time.perf_counter()
        trainer.step(**data)
        jax.block_until_ready(trainer.params["W1"].data)
        laters.append((time.perf_counter() - t0) * 1e3)
    rec = {
        "steps": STEPS,
        "first_step_ms": round(first_ms, 2),
        "dispatch_step_ms": round(statistics.median(laters), 3),
        "cache_hits": eng.cache_hits,
        "cache_misses": eng.cache_misses,
        "loss_first": round(trainer.history[0], 4),
        "loss_last": round(trainer.history[-1], 4),
    }
    rec["compile_to_dispatch_ratio"] = round(
        rec["first_step_ms"] / max(rec["dispatch_step_ms"], 1e-9), 1)
    return rec


def bench_fused_vs_unfused() -> Dict:
    """The combined loss+grad+update plan through the fusing engine vs
    the unfused oracle — wall-clock and XLA temp bytes."""
    import jax
    import numpy as np

    from repro.core import Engine

    step, data, params = _build(DIMS)
    env = {**data, **params}
    engines = {
        "unfused": Engine(executor="jit", optimize=False, fuse=False),
        "fused": Engine(executor="jit", optimize=False),
    }
    rec: Dict = {"roots": len(step.roots)}
    outs = {}
    for tag, engine in engines.items():
        trainer_state = step.optimizer.init_state(params)
        env_t = {**env, **trainer_state}
        ce = engine.compile(step.roots)
        args = [env_t[n].data for n in ce.input_names]
        compiled = ce.jitted.lower(*args).compile()
        ma = compiled.memory_analysis()
        rec[f"{tag}_temp_bytes"] = \
            int(ma.temp_size_in_bytes) if ma is not None else -1
        out = ce.run(**env_t)
        jax.block_until_ready(out["loss"].data)
        # best-of-N: the minimum is the robust wall estimator on a
        # loaded machine (scheduler noise only ever adds time)
        best = float("inf")
        for _ in range(TIMING_REPS):
            t0 = time.perf_counter()
            out = ce.run(**env_t)
            jax.block_until_ready(out["loss"].data)
            best = min(best, time.perf_counter() - t0)
        rec[f"{tag}_ms"] = round(best * 1e3, 2)
        outs[tag] = {k: np.asarray(v.data) for k, v in out.items()}
    for k in outs["fused"]:
        np.testing.assert_allclose(outs["fused"][k], outs["unfused"][k],
                                   rtol=1e-3, atol=1e-3)
    if rec["unfused_temp_bytes"] > 0 and rec["fused_temp_bytes"] > 0:
        rec["temp_ratio"] = round(
            rec["unfused_temp_bytes"] / rec["fused_temp_bytes"], 2)
    rec["speedup"] = round(rec["unfused_ms"] / rec["fused_ms"], 2)

    # the cost-based optimizer must select FusedJoinAgg inside the
    # combined program too
    opt_eng = Engine(executor="jit", optimize=True,
                     axis_sizes={"sites": 2})
    rec["fused_nodes_in_optimized_plan"] = \
        opt_eng.compile(step.roots).describe().count("FusedJoinAgg")
    return rec


def run(mesh=None) -> List[str]:
    disp = bench_dispatch()
    fuse = bench_fused_vs_unfused()
    out = {"dims": list(DIMS), "dispatch": disp, "fused_step": fuse,
           "temp_metric": "Compiled.memory_analysis().temp_size_in_bytes"}
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_train.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)

    lines = ["# TRA train step (§5.3 FFNN + AdamW, single device)"]
    lines.append(
        f"step 1 (compile+run) {disp['first_step_ms']:8.1f} ms → "
        f"steady dispatch {disp['dispatch_step_ms']:6.2f} ms "
        f"(×{disp['compile_to_dispatch_ratio']:.0f}); "
        f"cache {disp['cache_hits']} hits / {disp['cache_misses']} miss")
    lines.append(
        f"loss {disp['loss_first']:.3f} → {disp['loss_last']:.3f} over "
        f"{disp['steps']} steps")
    lines.append(
        f"fused step: temp {fuse['unfused_temp_bytes']/1e6:.1f}→"
        f"{fuse['fused_temp_bytes']/1e6:.1f} MB "
        f"(×{fuse.get('temp_ratio', float('nan')):.1f})  wall "
        f"{fuse['unfused_ms']:.1f}→{fuse['fused_ms']:.1f} ms "
        f"(×{fuse['speedup']:.1f}); optimizer places "
        f"{fuse['fused_nodes_in_optimized_plan']} FusedJoinAgg nodes")

    ok = (disp["cache_misses"] == 1
          and disp["cache_hits"] == disp["steps"] - 1
          and disp["compile_to_dispatch_ratio"] >= DISPATCH_SPEEDUP_MIN
          and disp["loss_last"] < disp["loss_first"]
          and fuse["fused_ms"] < fuse["unfused_ms"]
          and fuse.get("temp_ratio", 0) > 1.0
          and fuse["fused_nodes_in_optimized_plan"] >= 2)
    lines.append(
        f"regression guard (pure cache dispatch from step 2, ≥"
        f"{DISPATCH_SPEEDUP_MIN:.0f}× compile/dispatch ratio, fused "
        f"grad+update plan beats unfused, loss decreasing): "
        f"{'PASS' if ok else 'FAIL'}")
    if not ok:
        raise AssertionError(f"train-step regression guard failed: {out}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
