"""Serving resilience benchmark: chaos load against the SLO guards.

Drives :class:`~repro.serve.server.TraServer` through the PR-6 fault
model under load and guards the numbers that make the resilience layer
worth having:

* **decode chaos** — the smoke recurrent LM under a Poisson open-loop
  stream while a :func:`~repro.serve.loadgen.chaos_injector` schedule
  fires periodic site failures, NaN poisonings (caught by
  ``check_numerics``), and device OOMs.  Runs on the ``reference``
  executor so node-scoped faults keep per-run semantics (the faults
  timing caveat).  Guards: goodput over admitted requests ≥
  ``GOODPUT_MIN``; every served response *bit-matches* the fault-free
  oracle (the snapshot/rewind recovery never resets neighbours); the
  retry machinery actually fired (``transient_faults``/``recovered`` >
  0); chaos p99 latency stays within ``P99_FACTOR``× the fault-free
  baseline p99 (+ ``P99_SLACK_MS`` absolute slack for backoff sleeps);
  zero hung handles after the run.
* **overload shedding** — a burst far over ``max_pending`` through the
  bucketed scorer: shed submissions must fast-fail in under
  ``SHED_MS_MAX`` ms each (no queue residence), and every admitted
  request still completes against the oracle.
* **stragglers under watchdog** — background-scheduler mode with a
  periodic straggler delay and the tick watchdog armed: the watchdog
  must stay quiet (no false trips) while every request completes.

Emits ``BENCH_resilience.json`` next to the repo root and raises on
guard failure — wired into ``benchmarks/run.py``; ``--smoke`` shrinks
the streams for the CI smoke step.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

GOODPUT_MIN = 0.99                 # completed / admitted under chaos
SHED_MS_MAX = 10.0                 # per-submission shed fast-fail bound
P99_FACTOR = 10.0                  # chaos p99 <= factor * clean p99 ...
P99_SLACK_MS = 100.0               # ... + absolute slack (backoff sleeps)
SITE_EVERY = 8                     # site failure every 8th dispatch
NAN_EVERY = 13                     # NaN poisoning every 13th dispatch
OOM_TIMES = 2                      # first two fused contractions OOM
MAX_RETRIES = 8                    # per-request budget >= worst-case hits
LM_CAPACITY = 8


def _dims(smoke: bool) -> Dict[str, int]:
    return {"chaos_requests": 12 if smoke else 48,
            "burst_requests": 24 if smoke else 64,
            "max_pending": 8,
            "straggler_requests": 4 if smoke else 12}


def _lm_server(inj=None):
    from repro.core import Engine
    from repro.launch.metering import SpanMeter
    from repro.serve import LmRequest, RecurrentLM, TraServer

    engine = Engine(executor="reference", fault_injector=inj,
                    check_numerics=True)
    lm = RecurrentLM(d_model=16, vocab_size=32, capacity=LM_CAPACITY)
    server = TraServer(engine, lm, max_retries=MAX_RETRIES)
    server.warmup()
    # pay the first-dispatch cost outside the clock so the clean-vs-chaos
    # p99 comparison sees steady-state ticks only (the warm run also
    # advances the injector's run counter by a few ticks, which is fine:
    # the schedule is periodic)
    server.serve([LmRequest(prompt=[0], max_new_tokens=1)])
    server.meter = SpanMeter()
    return server, lm


def bench_decode_chaos(n_requests: int) -> Dict:
    """Poisson decode stream, clean baseline vs chaos schedule."""
    import numpy as np

    from repro.serve import chaos_injector, lm_mix, open_loop, \
        poisson_arrivals

    def workload(lm):
        rng = np.random.default_rng(0)
        reqs = lm_mix(lm, rng, n_requests, prompt_len=(1, 6),
                      new_tokens=(1, 10))
        return reqs, poisson_arrivals(rng, n_requests, rate_per_s=100.0)

    # fault-free baseline: same engine config, same workload, no faults
    server, lm = _lm_server()
    reqs, arrivals = workload(lm)
    clean = open_loop(server, reqs, arrivals)
    assert clean.errors == 0, f"baseline had {clean.errors} errors"

    inj = chaos_injector(site_every=SITE_EVERY, nan_node="relu",
                         nan_every=NAN_EVERY, oom_times=OOM_TIMES)
    server, lm = _lm_server(inj)
    reqs, arrivals = workload(lm)
    chaos = open_loop(server, reqs, arrivals)

    mismatches = 0
    for req, res in zip(reqs, chaos.results):
        if res is None:
            continue
        toks, _ = lm.oracle_decode(req.prompt, req.max_new_tokens)
        if res["tokens"] != toks:
            mismatches += 1
    health = server.health()
    counters = health["counters"]
    return {
        "requests": n_requests,
        "fault_schedule": {"site_every": SITE_EVERY,
                           "nan_every": NAN_EVERY,
                           "oom_times": OOM_TIMES},
        "faults_fired": len(inj.log),
        "goodput": chaos.goodput,
        "errors": chaos.errors,
        "oracle_mismatches": mismatches,
        "clean_p99_ms": clean.summary["total_ms"]["p99"],
        "chaos_p99_ms": chaos.summary["total_ms"]["p99"],
        "hung_handles": sum(r is None for r in chaos.results)
        - chaos.errors - chaos.shed,
        "health_after": {"pending": health["pending"],
                         "queue_depth": health["queue_depth"]},
        "counters": counters,
    }


def bench_overload_shedding(burst: int, max_pending: int) -> Dict:
    """Burst far over max_pending: shed fast, serve the admitted."""
    import numpy as np

    from repro.core import Engine
    from repro.serve import FFNNScorer, ServerOverloaded, TraServer

    engine = Engine(executor="reference")
    scorer = FFNNScorer()
    server = TraServer(engine, scorer, max_pending=max_pending)
    server.warmup()
    rng = np.random.default_rng(1)
    payloads = [scorer.random_payload(rng) for _ in range(burst)]
    handles, shed_ms = [], []
    for p in payloads:
        t0 = time.perf_counter()
        h = server.submit(p)
        dt = (time.perf_counter() - t0) * 1e3
        handles.append(h)
        if h.done():                   # shed: already failed, time it
            shed_ms.append(dt)
    server.run_until_idle()
    served, worst = 0, 0.0
    for p, h in zip(payloads, handles):
        try:
            r = h.result(timeout=0)
        except ServerOverloaded:
            continue
        served += 1
        worst = max(worst, float(np.abs(r - scorer.oracle(p)).max()))
    return {
        "burst": burst,
        "max_pending": max_pending,
        "shed": server.counters["shed"],
        "served": served,
        "shed_ms_max": round(max(shed_ms), 3) if shed_ms else 0.0,
        "oracle_max_abs_err": worst,
        "pending_after": server._pending,
    }


def bench_stragglers_watchdog(n_requests: int) -> Dict:
    """Background scheduler + watchdog under periodic straggler delays."""
    import numpy as np

    from repro.core import Engine
    from repro.serve import RecurrentLM, TraServer, chaos_injector, \
        LmRequest

    inj = chaos_injector(straggler_every=3, straggler_delay_s=0.02)
    engine = Engine(executor="reference", fault_injector=inj)
    lm = RecurrentLM(d_model=16, vocab_size=32, capacity=4)
    server = TraServer(engine, lm)
    server.warmup()
    server.start(tick_wait_s=0.001, watchdog_timeout_s=5.0)
    rng = np.random.default_rng(2)
    handles = []
    for _ in range(n_requests):
        prompt = [int(t) for t in rng.integers(0, lm.vocab, 3)]
        handles.append(server.submit(LmRequest(prompt, 4)))
    ok = 0
    for req_h in handles:
        toks, _ = lm.oracle_decode(req_h.payload.prompt,
                                   req_h.payload.max_new_tokens)
        if req_h.result(timeout=60.0)["tokens"] == toks:
            ok += 1
    server.stop()
    return {
        "requests": n_requests,
        "oracle_ok": ok,
        "stragglers_fired": sum(1 for k, _ in inj.log
                                if k == "straggler"),
        "watchdog_trips": server.counters["watchdog_trips"],
        "pending_after": server._pending,
    }


def run(mesh=None, smoke: bool = False) -> List[str]:
    dims = _dims(smoke)
    chaos = bench_decode_chaos(dims["chaos_requests"])
    shed = bench_overload_shedding(dims["burst_requests"],
                                   dims["max_pending"])
    stragglers = bench_stragglers_watchdog(dims["straggler_requests"])
    out = {"smoke": smoke, "chaos": chaos, "shedding": shed,
           "stragglers": stragglers,
           "guards": {"goodput_min": GOODPUT_MIN,
                      "shed_ms_max": SHED_MS_MAX,
                      "p99_factor": P99_FACTOR,
                      "p99_slack_ms": P99_SLACK_MS}}
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_resilience.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)

    lines = ["# TRA serving resilience: chaos load vs SLO guards"]
    c = chaos["counters"]
    lines.append(
        f"decode chaos [reference]: {chaos['requests']} requests, "
        f"{chaos['faults_fired']} faults fired "
        f"(retries={c['retries']}, recovered={c['recovered']}), "
        f"goodput {chaos['goodput']:.3f}, "
        f"{chaos['oracle_mismatches']} oracle mismatches, p99 "
        f"{chaos['chaos_p99_ms']:.1f} ms vs clean "
        f"{chaos['clean_p99_ms']:.1f} ms")
    lines.append(
        f"overload shed: burst {shed['burst']} over "
        f"max_pending={shed['max_pending']} -> {shed['shed']} shed "
        f"(worst fast-fail {shed['shed_ms_max']:.2f} ms), "
        f"{shed['served']} served, oracle err "
        f"{shed['oracle_max_abs_err']:.2e}")
    lines.append(
        f"stragglers: {stragglers['stragglers_fired']} delays over "
        f"{stragglers['requests']} requests, "
        f"{stragglers['watchdog_trips']} watchdog trips, "
        f"{stragglers['oracle_ok']}/{stragglers['requests']} oracle-ok")

    ok_goodput = chaos["goodput"] >= GOODPUT_MIN
    ok_oracle = chaos["oracle_mismatches"] == 0
    ok_fired = chaos["faults_fired"] > 0 and c["recovered"] > 0 \
        and c["transient_faults"] > 0
    ok_tail = chaos["chaos_p99_ms"] <= \
        P99_FACTOR * chaos["clean_p99_ms"] + P99_SLACK_MS
    ok_hung = chaos["hung_handles"] == 0 \
        and chaos["health_after"]["pending"] == 0
    ok_shed = shed["shed"] > 0 and shed["shed_ms_max"] <= SHED_MS_MAX \
        and shed["served"] + shed["shed"] == shed["burst"] \
        and shed["oracle_max_abs_err"] <= 1e-5 \
        and shed["pending_after"] == 0
    ok_watch = stragglers["watchdog_trips"] == 0 \
        and stragglers["oracle_ok"] == stragglers["requests"] \
        and stragglers["pending_after"] == 0
    ok = ok_goodput and ok_oracle and ok_fired and ok_tail and ok_hung \
        and ok_shed and ok_watch
    lines.append(
        f"resilience guard (goodput ≥{GOODPUT_MIN}, oracle-exact "
        f"recovery, faults fired+recovered, chaos p99 ≤ "
        f"{P99_FACTOR:.0f}×clean+{P99_SLACK_MS:.0f}ms, shed ≤ "
        f"{SHED_MS_MAX:.0f}ms, 0 hung, 0 false watchdog trips): "
        f"{'PASS' if ok else 'FAIL'}")
    if not ok:
        raise AssertionError(f"resilience guard failed: {out}")
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    print("\n".join(run(smoke=ap.parse_args().smoke)))
