"""Robustness overheads: async checkpoint overlap + numerics-guard cost.

Measures the two knobs PR 6 adds to the hot training path and guards
that both stay cheap enough to leave on in production runs:

* **checkpoint overlap** — a TRA train loop with
  ``fit(..., ckpt_every=)`` issuing *async* checkpoints
  (``CheckpointStore.save_async`` writing on a background thread) vs the
  same loop forced to write *synchronously*.  The async loop must not be
  slower than the sync loop (the write overlaps the next steps), and the
  per-step overhead of async checkpointing vs no checkpointing at all is
  reported;
* **numerics-guard overhead** — the §5.3 FFNN train step through an
  ``Engine(check_numerics=True)`` (per-node finite flags compiled as
  extra jit outputs + a host-side check) vs the plain engine.  Guard:
  the median checked step must be within ``GUARD_OVERHEAD_MAX`` (10 %)
  of the unchecked step.

Emits ``BENCH_robust.json`` next to the repo root and raises on guard
failure — wired into ``benchmarks/run.py``.
"""
from __future__ import annotations

import json
import os
import statistics
import tempfile
import time
from typing import Dict, List

# §5.3 FFNN scaled UP from benchmarks/train.py (N=1024, D=H=1024): the
# O(n³) contraction FLOPs must dominate both Python dispatch and the
# O(n²) bandwidth-bound output finite flags the two-tier guard adds —
# the <10% claim is about workloads where compute dominates, and at toy
# sizes the flag reductions are a constant cost that swamps the step
DIMS = (8, 16, 16, 2, 128, 64, 64, 32)   # nb db hb lb bn bd bh bl
STEPS = 24
CKPT_EVERY = 4
CKPT_REPS = 3                            # best-of-N checkpoint loops
GUARD_OVERHEAD_MAX = 0.10                # checked step ≤ 1.10× unchecked


def _build(dims):
    import jax

    from repro.core import AdamW, from_tensor
    from repro.core.programs import ffnn_train_step_tra

    nb, db, hb, lb, bn, bd, bh, bl = dims
    N, D, H, L = nb * bn, db * bd, hb * bh, lb * bl
    X = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    Wt = jax.random.normal(jax.random.PRNGKey(4), (D, L)) * 0.5
    Y = jax.nn.sigmoid(X @ Wt)
    W1 = jax.random.normal(jax.random.PRNGKey(2), (D, H)) * (D ** -0.5)
    W2 = jax.random.normal(jax.random.PRNGKey(3), (H, L)) * (H ** -0.5)
    step = ffnn_train_step_tra(*dims, optimizer=AdamW(1e-2))
    data = dict(X=from_tensor(X, (bn, bd)), Y=from_tensor(Y, (bn, bl)))
    params = dict(W1=from_tensor(W1, (bd, bh)),
                  W2=from_tensor(W2, (bh, bl)))
    return step, data, params


def _timed_fit(trainer, data, *, store=None, ckpt_every=None,
               sync=False) -> float:
    """Wall-clock of STEPS train steps (after a warm-up compile step)."""
    import jax

    trainer.step(**data)                 # pay the compile outside the clock
    jax.block_until_ready(trainer.params["W1"].data)
    t0 = time.perf_counter()
    for _ in range(STEPS):
        trainer.step(**data)
        if store is not None and ckpt_every is not None \
                and trainer.step_count % ckpt_every == 0:
            trainer.save_checkpoint(store, sync=sync)
    jax.block_until_ready(trainer.params["W1"].data)
    if store is not None:
        store.wait()
    return (time.perf_counter() - t0) * 1e3


def bench_checkpoint_overlap() -> Dict:
    """Async background-thread checkpoints vs sync writes vs none."""
    from repro.checkpoint import CheckpointStore
    from repro.core import Engine, TraTrainer

    step, data, params = _build(DIMS)
    rec: Dict = {"steps": STEPS, "ckpt_every": CKPT_EVERY}
    # one engine across variants and reps: the compile cache makes every
    # trainer after the first pure dispatch, so the clock sees steps +
    # checkpoint writes only
    eng = Engine(executor="jit", optimize=False)
    for tag, use_store, sync in (("none", False, False),
                                 ("sync", True, True),
                                 ("async", True, False)):
        # best-of-N: scheduler noise only ever adds time
        wall = float("inf")
        for _ in range(CKPT_REPS):
            trainer = TraTrainer(eng, step, params=params)
            if use_store:
                with tempfile.TemporaryDirectory() as d:
                    store = CheckpointStore(d, keep=2)
                    wall = min(wall, _timed_fit(
                        trainer, data, store=store,
                        ckpt_every=CKPT_EVERY, sync=sync))
            else:
                wall = min(wall, _timed_fit(trainer, data))
        rec[f"{tag}_total_ms"] = round(wall, 2)
        rec[f"{tag}_step_ms"] = round(wall / STEPS, 3)
    rec["async_vs_sync_ratio"] = round(
        rec["async_total_ms"] / max(rec["sync_total_ms"], 1e-9), 3)
    rec["async_overhead_vs_none"] = round(
        rec["async_total_ms"] / max(rec["none_total_ms"], 1e-9) - 1.0, 3)
    return rec


def bench_numerics_guard() -> Dict:
    """check_numerics=True (per-node jit finite flags) vs plain engine."""
    import jax

    from repro.core import Engine, TraTrainer

    step, data, params = _build(DIMS)
    rec: Dict = {"steps": STEPS}
    for tag, check in (("plain", False), ("checked", True)):
        eng = Engine(executor="jit", optimize=False, check_numerics=check)
        trainer = TraTrainer(eng, step, params=params)
        trainer.step(**data)
        jax.block_until_ready(trainer.params["W1"].data)
        walls = []
        for _ in range(STEPS):
            t0 = time.perf_counter()
            trainer.step(**data)
            jax.block_until_ready(trainer.params["W1"].data)
            walls.append((time.perf_counter() - t0) * 1e3)
        rec[f"{tag}_step_ms"] = round(statistics.median(walls), 3)
        rec[f"{tag}_loss_last"] = round(trainer.history[-1], 6)
    rec["overhead"] = round(
        rec["checked_step_ms"] / max(rec["plain_step_ms"], 1e-9) - 1.0, 3)
    # fast-but-wrong guard: the checked engine must compute the same run
    assert abs(rec["checked_loss_last"] - rec["plain_loss_last"]) < 1e-6
    return rec


def run(mesh=None) -> List[str]:
    ckpt = bench_checkpoint_overlap()
    guard = bench_numerics_guard()
    out = {"dims": list(DIMS), "checkpoint": ckpt, "numerics_guard": guard,
           "guard_overhead_max": GUARD_OVERHEAD_MAX}
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_robust.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)

    lines = ["# robustness overheads (§5.3 FFNN train step, single device)"]
    lines.append(
        f"checkpoint every {ckpt['ckpt_every']} steps over "
        f"{ckpt['steps']}: none {ckpt['none_step_ms']:.2f} / sync "
        f"{ckpt['sync_step_ms']:.2f} / async {ckpt['async_step_ms']:.2f} "
        f"ms per step (async/sync ×{ckpt['async_vs_sync_ratio']:.2f}, "
        f"async overhead vs none "
        f"{ckpt['async_overhead_vs_none'] * 100:+.1f}%)")
    lines.append(
        f"numerics guard: plain {guard['plain_step_ms']:.2f} → checked "
        f"{guard['checked_step_ms']:.2f} ms per step "
        f"({guard['overhead'] * 100:+.1f}%)")

    # scheduler noise allowance on the overlap assertion: async must not
    # be meaningfully slower than sync (the write overlaps compute)
    ok = (ckpt["async_total_ms"] <= ckpt["sync_total_ms"] * 1.05
          and guard["overhead"] <= GUARD_OVERHEAD_MAX)
    lines.append(
        f"regression guard (async ckpt overlaps compute, numerics guard "
        f"≤{GUARD_OVERHEAD_MAX * 100:.0f}% step overhead): "
        f"{'PASS' if ok else 'FAIL'}")
    if not ok:
        raise AssertionError(f"robustness regression guard failed: {out}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
