"""Paper Tables 3–4: distributed matrix multiplication, BMM vs CPMM vs RMM.

Two parts:

* **Predicted costs (Table 4)** — the paper's exact cost model over the
  paper's own shapes (I=K=J=4·10⁴; K=6.4·10⁵ common-large; I=J=8·10⁴
  two-large) on a 10-site cluster, reproduced with ``accounting="paper"``.
  These must equal Table 4 to the digit.
* **Measured runtimes (Table 3 analogue)** — wall-clock of the three IA
  plans executed through the GSPMD executor on an 8-host-device mesh with
  proportionally scaled matrices (the container has no cluster; relative
  ordering per data shape is the reproduced claim).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (Placement, RelType, comm_cost, from_tensor,
                        optimize, to_tensor)
from repro.core.programs import (bmm_plan, cpmm_plan, cpmm_two_phase_plan,
                                 matmul_tra, rmm_cost)

SITES = 10


def predicted_costs() -> List[Dict]:
    """Table 4 (10 sites), paper accounting."""
    shapes = {
        # name: (I, K, J)
        "general": (4 * 10**4, 4 * 10**4, 4 * 10**4),
        "common-large-dim": (10**4, 6.4 * 10**5, 10**4),
        "two-large-dims": (8 * 10**4, 10**4, 8 * 10**4),
    }
    # paper Table 4 values (floats moved)
    expected = {
        "general": {"BMM": 1.6e10, "CPMM": 1.6e10, "RMM": 1.6e10},
        "common-large-dim": {"BMM": 6.4e10, "CPMM": 1.0e9, "RMM": 6.4e10},
        "two-large-dims": {"BMM": 8.0e9, "CPMM": 6.4e10, "RMM": 8.0e9},
    }
    out = []
    for name, (I, K, J) in shapes.items():
        I, K, J = int(I), int(K), int(J)
        # block grids: contraction split over sites where each plan wants
        fa = (SITES, SITES)
        fb = (SITES, SITES)
        ba = (I // SITES, K // SITES)
        bb = (K // SITES, J // SITES)
        sz = {"sites": SITES}
        costs = {
            "BMM": comm_cost(bmm_plan(fa, fb, ba, bb), sz,
                             accounting="paper"),
            "CPMM": comm_cost(cpmm_plan(fa, fb, ba, bb), sz,
                              accounting="paper"),
            "RMM": rmm_cost(fa, fb, ba, bb, SITES, accounting="paper"),
            "CPMM-2phase(beyond-paper)": comm_cost(
                cpmm_two_phase_plan(fa, fb, ba, bb), sz,
                accounting="paper"),
        }
        rec = {"shape": name, "I": I, "K": K, "J": J, **costs}
        for plan, want in expected[name].items():
            got = costs[plan]
            rec[f"match_{plan}"] = bool(abs(got - want) / want < 0.01)
        out.append(rec)
    return out


def measured(mesh=None, scale: int = 16) -> List[Dict]:
    """Scaled-down execution of the three plans (8 host devices)."""
    import jax
    import jax.numpy as jnp
    from repro.core import Engine

    if mesh is None:
        return []
    s = mesh.shape["sites"]
    shapes = {
        "general": (2048, 2048, 2048),
        "common-large-dim": (512, 2048 * 8, 512),
        "two-large-dims": (4096, 512, 4096),
    }
    out = []
    for name, (I, K, J) in shapes.items():
        fa, fb = (s, s), (s, s)
        ba, bb = (I // s, K // s), (K // s, J // s)
        A = jax.random.normal(jax.random.PRNGKey(0), (I, K))
        B = jax.random.normal(jax.random.PRNGKey(1), (K, J))
        RA, RB = from_tensor(A, ba), from_tensor(B, bb)
        ref = np.asarray(A @ B)
        rec = {"shape": name}
        # the hand-compiled paper plans run as-is through the GSPMD
        # engine (an IANode bypasses the optimizer)
        engine = Engine(mesh, executor="gspmd")
        for tag, plan in [("BMM", bmm_plan(fa, fb, ba, bb)),
                          ("CPMM", cpmm_plan(fa, fb, ba, bb))]:
            with mesh:
                compiled = engine.compile(plan)
                r = compiled.run(A=RA, B=RB)
                jax.block_until_ready(r.data)
                t0 = time.perf_counter()
                for _ in range(3):
                    r = compiled.run(A=RA, B=RB)
                jax.block_until_ready(r.data)
                dt = (time.perf_counter() - t0) / 3
            got = to_tensor(r)
            err = float(np.max(np.abs(np.asarray(got) - ref)))
            assert err < 1e-2 * K ** 0.5, (tag, err)
            rec[f"{tag}_ms"] = round(dt * 1e3, 2)
        out.append(rec)
    return out


def run(mesh=None) -> List[str]:
    lines = ["# Table 4 — predicted costs, 10 sites (paper accounting)"]
    for rec in predicted_costs():
        lines.append(
            f"{rec['shape']:18s} BMM={rec['BMM']:.2e}"
            f"{'✓' if rec['match_BMM'] else '✗'} "
            f"CPMM={rec['CPMM']:.2e}"
            f"{'✓' if rec['match_CPMM'] else '✗'} "
            f"RMM={rec['RMM']:.2e}"
            f"{'✓' if rec['match_RMM'] else '✗'} "
            f"| 2phase={rec['CPMM-2phase(beyond-paper)']:.2e}")
    for rec in measured(mesh):
        lines.append(f"{rec['shape']:18s} measured: "
                     + " ".join(f"{k}={v}" for k, v in rec.items()
                                if k.endswith("_ms")))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
