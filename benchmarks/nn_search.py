"""Paper Tables 5–6: Riemannian nearest-neighbour search.

Predicted costs (Table 6, 8 machines) for the paper's two data shapes:
  Large — N = 1.5·10⁶ rows, D = 6·10³ features
  Wide  — N = 6·10³ rows, D = 10⁵ features
under the paper's two IA implementations:
  Opt4Horizontal — xq, A broadcast; X row-partitioned; all local
  Opt4Vertical   — xq broadcast; diff feature-partitioned; CPMM projection

Table 6 expected: Wide  — H 2.9e8,  V 8.0e10
                  Large — H 7.2e10, V 4.8e9
plus a scaled-down measured run of both plans (correctness + ordering).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

SITES = 8


def predicted_costs() -> List[Dict]:
    """Full paper-scale shapes, priced by the real optimizer over the real
    TRA program (types only — no allocation).

    Reproduction note (EXPERIMENTS.md §NN-search): the Wide row matches
    the paper's Table 5/6 decision (Horizontal wins).  For Large the
    paper's Table 6 charges Horizontal 7.2·10¹⁰ = N·D·s — a plan that
    broadcasts the (N×D) diff relation.  Our optimizer never emits that
    plan: with A broadcast once (D²·s = 2.9·10⁸ floats) the whole pipeline
    is local, which is strictly cheaper than Vertical's N·D shuffle.
    I.e. the hand-compiled Opt4Horizontal the paper benchmarked for Large
    is not the best Horizontal plan expressible in their own algebra; the
    rewrite search finds the better one.
    """
    from repro.core.optimize import optimize
    from repro.core.plan import Placement
    from repro.core.programs import nn_search_tra

    out = []
    s = SITES
    for name, (N, D) in [("Wide", (6 * 10**3, 10**5)),
                         ("Large", (1.5 * 10**6, 6 * 10**3))]:
        N, D = int(N), int(D)
        nb, db = s, s
        rows, dcol = N // nb, D // db
        prog = nn_search_tra(nb, db, rows, dcol)
        costs: Dict[str, int] = {}
        for tag, places in [
            ("Opt4Horizontal", {"xq": Placement.replicated(),
                                "A": Placement.replicated(),
                                "X": Placement.partitioned((0,),
                                                           ("sites",))}),
            ("Opt4Vertical", {"xq": Placement.replicated(),
                              "A": Placement.partitioned((0,), ("sites",)),
                              "X": Placement.partitioned((1,),
                                                         ("sites",))}),
        ]:
            r = optimize(prog.dist, places, site_axes=("sites",),
                         axis_sizes={"sites": s},
                         try_logical_rewrites=False, accounting="paper")
            costs[tag] = r.cost
        winner = min((c, t) for t, c in costs.items())[1]
        out.append({"shape": name, "N": N, "D": D, **costs,
                    "winner": winner})
    return out


def measured(mesh=None) -> List[Dict]:
    """Scaled execution of the full TRA program through both plans."""
    import jax
    import jax.numpy as jnp
    from repro.core import Engine, from_tensor
    from repro.core import tra as tra_ops
    from repro.core.optimize import optimize
    from repro.core.plan import Placement
    from repro.core.programs import nn_search_tra

    engine = Engine(executor="reference", optimize=False)

    s = SITES if mesh is None else mesh.shape["sites"]
    out = []
    for name, (nb, db, rows, dcol) in [
            ("Wide", (s, 4 * s, 8, 64)),        # few rows, many features
            ("Large", (4 * s, s, 256, 16))]:    # many rows, few features
        N, D = nb * rows, db * dcol
        key = jax.random.PRNGKey(0)
        Xs = jax.random.normal(key, (N, D))
        xq = jax.random.normal(jax.random.PRNGKey(1), (1, D))
        Am = jnp.eye(D) + 0.05 * jax.random.normal(
            jax.random.PRNGKey(2), (D, D))
        prog = nn_search_tra(nb, db, rows, dcol)

        env = {"xq": tra_ops.rekey(from_tensor(xq, (1, dcol)),
                                   lambda k: (k[1],)),
               "X": from_tensor(Xs, (rows, dcol)),
               "A": from_tensor(Am, (dcol, dcol))}
        t0 = time.perf_counter()
        res = engine.run(prog.result, **env)
        val, idx = (float(x) for x in np.asarray(res.data).reshape(-1))
        dt = time.perf_counter() - t0
        diff = Xs - xq
        dist = jnp.einsum("nd,de,ne->n", diff, Am, diff)
        ok = int(idx) == int(jnp.argmin(dist))

        costs = {}
        for tag, places in [
            ("Opt4Horizontal", {"xq": Placement.replicated(),
                                "A": Placement.replicated(),
                                "X": Placement.partitioned((0,),
                                                           ("sites",))}),
            ("Opt4Vertical", {"xq": Placement.replicated(),
                              "A": Placement.partitioned((0,), ("sites",)),
                              "X": Placement.partitioned((1,),
                                                         ("sites",))}),
        ]:
            try:
                r = optimize(prog.dist, places, site_axes=("sites",),
                             axis_sizes={"sites": s},
                             try_logical_rewrites=False,
                             accounting="paper")
                costs[tag] = r.cost
            except ValueError:
                costs[tag] = None
        winner = min((c, t) for t, c in costs.items()
                     if c is not None)[1]
        out.append({"shape": name, "N": N, "D": D, "correct": ok,
                    "eval_ms": round(dt * 1e3, 1), **costs,
                    "cost_model_picks": winner,
                    "expected_winner": ("Opt4Horizontal" if name == "Wide"
                                        else "Opt4Vertical")})
    return out


def run(mesh=None) -> List[str]:
    lines = ["# Table 5/6 — nearest-neighbour search (8 sites, paper "
             "accounting, full paper shapes)"]
    for rec in predicted_costs():
        lines.append(
            f"{rec['shape']:6s} N={rec['N']:<8d} D={rec['D']:<7d} "
            f"H={rec['Opt4Horizontal']:.2e} "
            f"V={rec['Opt4Vertical']:.2e} → {rec['winner']}"
            + ("  (matches Table 5/6)" if rec['shape'] == 'Wide' else
               "  (beats the paper's hand-compiled H plan — see "
               "EXPERIMENTS.md §NN-search)"))
    lines.append("# scaled-down execution (correctness)")
    for rec in measured(mesh):
        lines.append(
            f"{rec['shape']:6s} N={rec['N']:<6d} D={rec['D']:<5d} "
            f"correct={'✓' if rec['correct'] else '✗'} "
            f"eval={rec['eval_ms']}ms "
            f"H={rec['Opt4Horizontal']:,} V={rec['Opt4Vertical']:,}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
