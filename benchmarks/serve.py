"""Serving benchmark: continuous batching vs per-request serial dispatch.

Exercises the end-to-end serving acceptance for this repo's TRA serving
engine (:mod:`repro.serve`) and guards the numbers that make continuous
batching worth having:

* **mixed scorer stream** — the §5.3 FFNN scorer under a Poisson
  open-loop stream (≥100 requests hitting ≥3 bucket shapes) on the
  reference and jit executors: every response must match the
  per-request dense oracle at 1e-5 and the compile cache must take
  ZERO misses after warmup (the long-lived-artifact invariant);
* **LM decode throughput** — the smoke recurrent LM decoding a fixed
  workload two ways over the SAME compiled step artifact: continuous
  batching at concurrency 8 vs strictly serial one-request-at-a-time.
  Guard: batched tokens/s ≥ ``SPEEDUP_MIN``× serial (the batched step
  amortizes one fixed-capacity dispatch over up to 8 live slots);
* **step-latency tail** — p99 of the batched scheduler tick must stay
  within ``P99_STEP_FACTOR``× the *median* solo tick: same artifact,
  same shapes, so a fat tail would mean the scheduler (packing,
  eviction, state threading) is leaking cost into the hot loop.

Emits ``BENCH_serve.json`` next to the repo root and raises on guard
failure — wired into ``benchmarks/run.py``; ``--smoke`` shrinks the
stream for the CI smoke step.
"""
from __future__ import annotations

import json
import os
import statistics
import time
from typing import Dict, List

SCORER_REQUESTS = 120
# three-phase arrival rates (requests/s): slow trickle -> solo buckets,
# medium -> small batches, burst -> full buckets; guarantees the stream
# exercises ≥3 bucket shapes regardless of host speed
SCORER_RATES = (30.0, 300.0, 3000.0)
LM_REQUESTS = 24
LM_PROMPT, LM_GEN = 4, 12
LM_CAPACITY = 8
SPEEDUP_MIN = 2.0                        # batched ≥ 2× serial tokens/s
P99_STEP_FACTOR = 5.0                    # batched p99 tick ≤ 5× solo median


def _dims(smoke: bool) -> Dict[str, int]:
    return {"scorer_requests": 30 if smoke else SCORER_REQUESTS,
            "lm_requests": 8 if smoke else LM_REQUESTS}


def bench_scorer_stream(executor: str, n_requests: int) -> Dict:
    """Poisson mixed stream through the bucketed scorer; oracle-check."""
    import numpy as np

    from repro.core import Engine
    from repro.serve import FFNNScorer, TraServer, open_loop, scorer_mix

    rng = np.random.default_rng(0)
    engine = Engine(executor=executor)
    scorer = FFNNScorer()
    server = TraServer(engine, scorer)
    server.warmup()
    payloads = scorer_mix(scorer, rng, n_requests)
    arrivals, t = [], 0.0
    seg = n_requests // len(SCORER_RATES)
    for i, rate in enumerate(SCORER_RATES):
        count = seg if i < len(SCORER_RATES) - 1 \
            else n_requests - seg * (len(SCORER_RATES) - 1)
        for gap in rng.exponential(1.0 / rate, size=count):
            t += gap
            arrivals.append(t)
    report = open_loop(server, payloads, arrivals)
    assert report.errors == 0, f"{report.errors} failed requests"
    worst = 0.0
    for p, r in zip(payloads, report.results):
        worst = max(worst, float(np.abs(r - scorer.oracle(p)).max()))
    # bucket coverage from dispatch counts: each pinned artifact is one
    # bucket program, so distinct dispatched artifacts = bucket shapes hit
    dispatched = [a for a, n in server.dispatches.items() if n > 0]
    rec = {
        "executor": executor,
        "requests": report.requests,
        "tokens_per_s": round(report.tokens_per_s, 1),
        "total_ms": report.summary["total_ms"],
        "queue_wait_ms": report.summary["queue_wait_ms"],
        "service_ms": report.summary["service_ms"],
        "bucket_shapes_hit": len(dispatched),
        "cache_misses_after_warmup": server.cache_misses_since_warmup,
        "oracle_max_abs_err": worst,
    }
    return rec


def _drive_lm(executor: str, reqs, concurrency: int) -> Dict:
    """Decode ``reqs`` at the given concurrency, timing every tick."""
    from repro.core import Engine
    from repro.launch.metering import SpanMeter
    from repro.serve import LmRequest, RecurrentLM, TraServer

    engine = Engine(executor=executor)
    lm = RecurrentLM(d_model=64, vocab_size=256, capacity=LM_CAPACITY)
    server = TraServer(engine, lm)
    server.warmup()
    # pay the first-dispatch XLA compile outside the clock, then start
    # the meter fresh so the timed run sees steady-state ticks only
    server.serve([LmRequest(prompt=[0], max_new_tokens=1)])
    server.meter = SpanMeter()
    ticks: List[float] = []
    t0 = time.perf_counter()
    pending = list(reqs)
    inflight = []
    while pending or not server.idle():
        while pending and len(inflight) < concurrency:
            inflight.append(server.submit(pending.pop(0)))
        t1 = time.perf_counter()
        server.step()
        ticks.append((time.perf_counter() - t1) * 1e3)
        inflight = [h for h in inflight if not h.done()]
    wall = time.perf_counter() - t0
    tokens = server.meter.summary()["tokens"]
    misses = server.cache_misses_since_warmup
    assert misses == 0, f"{misses} cache misses after warmup"
    return {"concurrency": concurrency,
            "tokens": tokens,
            "tokens_per_s": round(tokens / wall, 1),
            "tick_ms_median": round(statistics.median(ticks), 3),
            "tick_ms_p99": round(sorted(ticks)[int(0.99 * len(ticks))
                                               if len(ticks) > 1 else 0], 3),
            "ticks": len(ticks)}


def bench_lm_throughput(executor: str, n_requests: int) -> Dict:
    """Continuous batching vs per-request serial on one compiled step."""
    from repro.serve import LmRequest

    reqs = [LmRequest(prompt=[(7 * i + j) % 256 for j in range(LM_PROMPT)],
                      max_new_tokens=LM_GEN) for i in range(n_requests)]
    serial = _drive_lm(executor, reqs, concurrency=1)
    batched = _drive_lm(executor, reqs, concurrency=LM_CAPACITY)
    assert batched["tokens"] == serial["tokens"] == n_requests * LM_GEN
    return {
        "executor": executor,
        "requests": n_requests,
        "gen_tokens_each": LM_GEN,
        "capacity": LM_CAPACITY,
        "serial": serial,
        "batched": batched,
        "speedup": round(batched["tokens_per_s"]
                         / max(serial["tokens_per_s"], 1e-9), 2),
        "p99_tick_vs_solo_median": round(
            batched["tick_ms_p99"] / max(serial["tick_ms_median"], 1e-9), 2),
    }


def run(mesh=None, smoke: bool = False) -> List[str]:
    dims = _dims(smoke)
    streams = [bench_scorer_stream(ex, dims["scorer_requests"])
               for ex in ("reference", "jit")]
    lm = bench_lm_throughput("jit", dims["lm_requests"])
    out = {"smoke": smoke, "scorer_streams": streams, "lm": lm,
           "speedup_min": SPEEDUP_MIN, "p99_step_factor": P99_STEP_FACTOR}
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)

    lines = ["# TRA serving: continuous batching over compiled plans"]
    for s in streams:
        lines.append(
            f"scorer stream [{s['executor']}]: {s['requests']} requests "
            f"@ {s['tokens_per_s']:.0f} req/s, p50/p99 "
            f"{s['total_ms']['p50']:.1f}/{s['total_ms']['p99']:.1f} ms, "
            f"{s['bucket_shapes_hit']} bucket shapes, "
            f"{s['cache_misses_after_warmup']} cache misses after warmup, "
            f"oracle err {s['oracle_max_abs_err']:.2e}")
    lines.append(
        f"lm decode [jit]: serial {lm['serial']['tokens_per_s']:.1f} tok/s "
        f"-> batched(x{lm['capacity']}) "
        f"{lm['batched']['tokens_per_s']:.1f} tok/s "
        f"(speedup ×{lm['speedup']:.2f}); batched p99 tick "
        f"{lm['batched']['tick_ms_p99']:.1f} ms vs solo median "
        f"{lm['serial']['tick_ms_median']:.1f} ms "
        f"(×{lm['p99_tick_vs_solo_median']:.2f})")

    ok_misses = all(s["cache_misses_after_warmup"] == 0 for s in streams)
    ok_oracle = all(s["oracle_max_abs_err"] <= 1e-5 for s in streams)
    ok_buckets = all(s["bucket_shapes_hit"] >= (2 if smoke else 3)
                     for s in streams)
    ok_speed = lm["speedup"] >= SPEEDUP_MIN
    ok_tail = lm["p99_tick_vs_solo_median"] <= P99_STEP_FACTOR
    ok = ok_misses and ok_oracle and ok_buckets and ok_speed and ok_tail
    lines.append(
        f"serving guard (0 misses after warmup, oracle ≤1e-5, "
        f"≥{'2' if smoke else '3'} buckets, batched ≥"
        f"{SPEEDUP_MIN:.0f}× serial tok/s, p99 tick ≤"
        f"{P99_STEP_FACTOR:.0f}× solo median): "
        f"{'PASS' if ok else 'FAIL'}")
    if not ok:
        raise AssertionError(f"serving guard failed: {out}")
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    print("\n".join(run(smoke=ap.parse_args().smoke)))
