"""Fused Σ∘⋈ contraction vs the unfused join→agg pair, via the Engine.

Measures, for the paper's matmul shapes (§5.1, scaled as in
:mod:`benchmarks.matmul`) and the FFNN forward contraction (§5.3):

* **peak live bytes** — XLA's compiled temp allocation
  (``Compiled.memory_analysis().temp_size_in_bytes``), which for the
  unfused pair contains the broadcasted I×K×J join grid (both operands
  replicated over the cross-product keys) and for the fused node only the
  blocked-contraction relayouts;
* **wall-clock** — median-of-3 jitted execution;
* whether the optimizer *selects* ``FusedJoinAgg`` automatically for the
  ``agg(join(·, matMul), matAdd)`` pattern.

Both paths run through :class:`repro.core.Engine` on the ``jit`` executor
— the optimizing engine lowers the Expr to the fused contraction; an
``optimize=False, fuse=False`` engine stages the unfused oracle pair —
so the numbers double as a regression guard on frontend-layer overhead
(an Expr/Engine slowdown would erase the fused path's wall-clock win).

Emits ``BENCH_fusion.json`` next to the repo root and asserts the headline
regression guard: ≥5× lower peak temp bytes AND lower wall-clock for the
fused path at the CPMM common-large-dim shape.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

SHAPES = {
    # name: (I, K, J, sites)  — matching benchmarks.matmul.measured
    "general": (2048, 2048, 2048, 8),
    "common-large-dim": (512, 2048 * 8, 512, 8),
    "two-large-dims": (4096, 512, 4096, 8),
    # §5.3 FFNN forward a1 = X @ W1 at speech-100k scaled 16×
    "ffnn-fwd": (4096, 512, 1024, 8),
}

GUARD_SHAPE = "common-large-dim"
GUARD_TEMP_RATIO = 5.0


def _time_it(fn, *args, iters: int = 3) -> float:
    import jax
    r = fn(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters


def frontend_overhead() -> Dict:
    """Engine-path dispatch vs calling the same jitted artifact directly.

    Runs the small general shape many times through ``CompiledExpr.run``
    (env coercion + TensorRelation wrapping) and through the raw jitted
    callable; the per-call delta is the frontend layer's overhead and must
    stay within noise of the kernel time at real shapes (sub-ms here).
    """
    import jax

    import repro.core as tra
    from repro.core import Engine, from_tensor

    s, I, K, J = 8, 512, 512, 512
    ba, bb = (I // s, K // s), (K // s, J // s)
    A = jax.random.normal(jax.random.PRNGKey(0), (I, K))
    B = jax.random.normal(jax.random.PRNGKey(1), (K, J))
    RA, RB = from_tensor(A, ba), from_tensor(B, bb)
    ce = Engine(executor="jit").compile(
        tra.input("A", (s, s), ba) @ tra.input("B", (s, s), bb))
    args = [RA.data if n == "A" else RB.data for n in ce.input_names]
    raw = _time_it(lambda: ce.jitted(*args), iters=20)
    eng = _time_it(lambda: ce.run(A=RA, B=RB).data, iters=20)
    return {"raw_ms": round(raw * 1e3, 3), "engine_ms": round(eng * 1e3, 3),
            "overhead_ms": round((eng - raw) * 1e3, 3)}


def bench_shape(name: str, I: int, K: int, J: int, s: int) -> Dict:
    import jax
    import numpy as np

    import repro.core as tra
    from repro.core import Engine, from_tensor

    ba, bb = (I // s, K // s), (K // s, J // s)
    A = jax.random.normal(jax.random.PRNGKey(0), (I, K))
    B = jax.random.normal(jax.random.PRNGKey(1), (K, J))
    RA, RB = from_tensor(A, ba), from_tensor(B, bb)

    expr = tra.input("A", (s, s), ba) @ tra.input("B", (s, s), bb)
    engines = {
        # unfused oracle: the logical walk with fusion disabled
        "unfused": Engine(executor="jit", optimize=False, fuse=False),
        # production path: the optimizer selects the fused contraction
        "fused": Engine(executor="jit"),
    }

    rec: Dict = {"shape": name, "I": I, "K": K, "J": J, "sites": s}
    outs = {}
    for tag, engine in engines.items():
        ce = engine.compile(expr)
        args = [RA.data if n == "A" else RB.data for n in ce.input_names]
        compiled = ce.jitted.lower(*args).compile()
        ma = compiled.memory_analysis()
        temp = int(ma.temp_size_in_bytes) if ma is not None else -1
        rec[f"{tag}_temp_bytes"] = temp
        rec[f"{tag}_ms"] = round(
            _time_it(lambda: ce.run(A=RA, B=RB).data) * 1e3, 2)
        outs[tag] = np.asarray(ce.run(A=RA, B=RB).data)
    np.testing.assert_allclose(outs["fused"], outs["unfused"],
                               rtol=1e-3, atol=1e-3 * K ** 0.5)
    if rec["unfused_temp_bytes"] > 0 and rec["fused_temp_bytes"] > 0:
        rec["temp_ratio"] = round(
            rec["unfused_temp_bytes"] / rec["fused_temp_bytes"], 2)
    rec["speedup"] = round(rec["unfused_ms"] / rec["fused_ms"], 2)
    return rec


def bench_backward() -> Dict:
    """Fused vs unfused **gradient** plan — the autodiff payoff.

    Builds the §5.3 FFNN forward at the ffnn-fwd shape, derives ∂/∂W1 by
    autodiff (`Expr.grad`), and runs the same gradient expression through
    the optimizing engine (which selects the fused Σ∘⋈ contraction inside
    the backward plan) and through the unfused oracle engine.  Because the
    backward graph is plain TRA, the PR-1 fusion machinery applies to it
    with zero backward-specific code — this record guards that.
    """
    import jax
    import numpy as np

    import repro.core as tra
    from repro.core import Engine, from_tensor

    I, K, J, s = SHAPES["ffnn-fwd"]
    ba, bb = (I // s, K // s), (K // s, J // s)
    X = jax.random.normal(jax.random.PRNGKey(0), (I, K))
    W = jax.random.normal(jax.random.PRNGKey(1), (K, J)) * 0.1
    RX, RW = from_tensor(X, ba), from_tensor(W, bb)

    x = tra.input("X", (s, s), ba)
    w = tra.input("W", (s, s), bb)
    fwd = (x @ w).map("relu")
    g_w = fwd.grad("W")                 # Σ∘⋈(matTranMulL) by construction

    engines = {
        "unfused": Engine(executor="jit", optimize=False, fuse=False),
        "fused": Engine(executor="jit"),
    }
    rec: Dict = {"shape": "ffnn-bwd-dW", "I": I, "K": K, "J": J, "sites": s}
    outs = {}
    for tag, engine in engines.items():
        ce = engine.compile(g_w)
        args = [RX.data if n == "X" else RW.data for n in ce.input_names]
        compiled = ce.jitted.lower(*args).compile()
        ma = compiled.memory_analysis()
        rec[f"{tag}_temp_bytes"] = \
            int(ma.temp_size_in_bytes) if ma is not None else -1
        rec[f"{tag}_ms"] = round(
            _time_it(lambda: ce.run(X=RX, W=RW).data) * 1e3, 2)
        outs[tag] = np.asarray(ce.run(X=RX, W=RW).data)
    np.testing.assert_allclose(outs["fused"], outs["unfused"],
                               rtol=1e-3, atol=1e-3 * I ** 0.5)
    rec["fused_in_plan"] = "FusedJoinAgg" in engines["fused"] \
        .compile(g_w).describe()
    if rec["unfused_temp_bytes"] > 0 and rec["fused_temp_bytes"] > 0:
        rec["temp_ratio"] = round(
            rec["unfused_temp_bytes"] / rec["fused_temp_bytes"], 2)
    rec["speedup"] = round(rec["unfused_ms"] / rec["fused_ms"], 2)
    return rec


def optimizer_selects_fused() -> bool:
    """agg(join(·, matMul), matAdd) must compile to FusedJoinAgg."""
    import repro.core as tra
    from repro.core import Engine, Placement

    S = ("sites",)
    expr = tra.input("A", (4, 4), (8, 8)) @ tra.input("B", (4, 4), (8, 8))
    engine = Engine(input_placements={
        "A": Placement.partitioned((1,), S),
        "B": Placement.partitioned((0,), S)}, axis_sizes={"sites": 4})
    return "FusedJoinAgg" in engine.compile(expr).describe()


def run(mesh=None) -> List[str]:
    recs = [bench_shape(n, *args) for n, args in SHAPES.items()]
    bwd = bench_backward()
    sel = optimizer_selects_fused()
    overhead = frontend_overhead()
    out = {"shapes": recs, "backward": bwd,
           "optimizer_selects_fused": sel,
           "frontend_overhead": overhead,
           "temp_metric": "Compiled.memory_analysis().temp_size_in_bytes"}
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_fusion.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)

    lines = ["# fused Σ∘⋈ vs unfused join→agg (single device)"]
    for r in recs:
        lines.append(
            f"{r['shape']:18s} temp {r['unfused_temp_bytes']/1e6:8.1f}→"
            f"{r['fused_temp_bytes']/1e6:7.1f} MB "
            f"(×{r.get('temp_ratio', float('nan')):.1f})  "
            f"wall {r['unfused_ms']:7.1f}→{r['fused_ms']:6.1f} ms "
            f"(×{r['speedup']:.1f})")
    lines.append(
        f"{bwd['shape']:18s} temp {bwd['unfused_temp_bytes']/1e6:8.1f}→"
        f"{bwd['fused_temp_bytes']/1e6:7.1f} MB "
        f"(×{bwd.get('temp_ratio', float('nan')):.1f})  "
        f"wall {bwd['unfused_ms']:7.1f}→{bwd['fused_ms']:6.1f} ms "
        f"(×{bwd['speedup']:.1f})  [autodiff backward]")
    lines.append(f"optimizer selects FusedJoinAgg: {sel}")
    lines.append(f"frontend dispatch overhead: {overhead['overhead_ms']} ms"
                 f" (raw {overhead['raw_ms']} → engine "
                 f"{overhead['engine_ms']})")

    guard = next(r for r in recs if r["shape"] == GUARD_SHAPE)
    # temp ratio is deterministic → hard ≥5× bar at the guard shape;
    # wall-clock is noisy on shared CPU → fused must merely beat unfused,
    # but on EVERY shape (including the autodiff backward record), so a
    # slow optimizer-selected plan anywhere fails
    ok = (guard.get("temp_ratio", 0) >= GUARD_TEMP_RATIO
          and all(r["fused_ms"] < r["unfused_ms"] for r in recs) and sel
          and bwd["fused_in_plan"]
          and bwd["fused_ms"] < bwd["unfused_ms"]
          and bwd.get("temp_ratio", 0) > 1.0)
    lines.append(f"regression guard (≥{GUARD_TEMP_RATIO}× temp, fused "
                 f"faster on all shapes incl. autodiff backward, "
                 f"auto-selected, via Engine): "
                 f"{'PASS' if ok else 'FAIL'}")
    if not ok:
        raise AssertionError(
            f"fusion regression guard failed: {recs + [bwd]}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
