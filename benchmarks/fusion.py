"""Fused Σ∘⋈ contraction vs the unfused join→agg pair.

Measures, for the paper's matmul shapes (§5.1, scaled as in
:mod:`benchmarks.matmul`) and the FFNN forward contraction (§5.3):

* **peak live bytes** — XLA's compiled temp allocation
  (``Compiled.memory_analysis().temp_size_in_bytes``), which for the
  unfused pair contains the broadcasted I×K×J join grid (both operands
  replicated over the cross-product keys) and for the fused node only the
  blocked-contraction relayouts;
* **wall-clock** — median-of-3 jitted execution;
* whether the optimizer *selects* ``FusedJoinAgg`` automatically for the
  ``agg(join(·, matMul), matAdd)`` pattern.

Emits ``BENCH_fusion.json`` next to the repo root and asserts the headline
regression guard: ≥5× lower peak temp bytes AND lower wall-clock for the
fused path at the CPMM common-large-dim shape.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

SHAPES = {
    # name: (I, K, J, sites)  — matching benchmarks.matmul.measured
    "general": (2048, 2048, 2048, 8),
    "common-large-dim": (512, 2048 * 8, 512, 8),
    "two-large-dims": (4096, 512, 4096, 8),
    # §5.3 FFNN forward a1 = X @ W1 at speech-100k scaled 16×
    "ffnn-fwd": (4096, 512, 1024, 8),
}

GUARD_SHAPE = "common-large-dim"
GUARD_TEMP_RATIO = 5.0


def _time_it(fn, *args, iters: int = 3) -> float:
    import jax
    r = fn(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters


def bench_shape(name: str, I: int, K: int, J: int, s: int) -> Dict:
    import jax
    import numpy as np

    from repro.core import from_tensor, get_kernel
    from repro.core import tra

    mm, add = get_kernel("matMul"), get_kernel("matAdd")
    ba, bb = (I // s, K // s), (K // s, J // s)
    A = jax.random.normal(jax.random.PRNGKey(0), (I, K))
    B = jax.random.normal(jax.random.PRNGKey(1), (K, J))
    RA, RB = from_tensor(A, ba), from_tensor(B, bb)

    def unfused(a, b):
        ra = tra.TensorRelation(a, RA.rtype)
        rb = tra.TensorRelation(b, RB.rtype)
        return tra.agg(tra.join(ra, rb, (1,), (0,), mm), (0, 2), add).data

    def fused(a, b):
        ra = tra.TensorRelation(a, RA.rtype)
        rb = tra.TensorRelation(b, RB.rtype)
        return tra.fused_join_agg(ra, rb, (1,), (0,), mm, (0, 2), add).data

    rec: Dict = {"shape": name, "I": I, "K": K, "J": J, "sites": s}
    outs = {}
    for tag, f in [("unfused", unfused), ("fused", fused)]:
        jf = jax.jit(f)
        compiled = jf.lower(RA.data, RB.data).compile()
        ma = compiled.memory_analysis()
        temp = int(ma.temp_size_in_bytes) if ma is not None else -1
        rec[f"{tag}_temp_bytes"] = temp
        rec[f"{tag}_ms"] = round(_time_it(jf, RA.data, RB.data) * 1e3, 2)
        outs[tag] = np.asarray(jf(RA.data, RB.data))
    np.testing.assert_allclose(outs["fused"], outs["unfused"],
                               rtol=1e-3, atol=1e-3 * K ** 0.5)
    if rec["unfused_temp_bytes"] > 0 and rec["fused_temp_bytes"] > 0:
        rec["temp_ratio"] = round(
            rec["unfused_temp_bytes"] / rec["fused_temp_bytes"], 2)
    rec["speedup"] = round(rec["unfused_ms"] / rec["fused_ms"], 2)
    return rec


def optimizer_selects_fused() -> bool:
    """agg(join(·, matMul), matAdd) must compile to FusedJoinAgg."""
    from repro.core import (Placement, RelType, TraAgg, TraInput, TraJoin,
                            describe, get_kernel, optimize)

    S = ("sites",)
    ta = TraInput("A", RelType((4, 4), (8, 8)))
    tb = TraInput("B", RelType((4, 4), (8, 8)))
    plan = TraAgg(TraJoin(ta, tb, (1,), (0,), get_kernel("matMul")),
                  (0, 2), get_kernel("matAdd"))
    r = optimize(plan, {"A": Placement.partitioned((1,), S),
                        "B": Placement.partitioned((0,), S)},
                 S, {"sites": 4})
    return "FusedJoinAgg" in describe(r.plan)


def run(mesh=None) -> List[str]:
    recs = [bench_shape(n, *args) for n, args in SHAPES.items()]
    sel = optimizer_selects_fused()
    out = {"shapes": recs, "optimizer_selects_fused": sel,
           "temp_metric": "Compiled.memory_analysis().temp_size_in_bytes"}
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_fusion.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)

    lines = ["# fused Σ∘⋈ vs unfused join→agg (single device)"]
    for r in recs:
        lines.append(
            f"{r['shape']:18s} temp {r['unfused_temp_bytes']/1e6:8.1f}→"
            f"{r['fused_temp_bytes']/1e6:7.1f} MB "
            f"(×{r.get('temp_ratio', float('nan')):.1f})  "
            f"wall {r['unfused_ms']:7.1f}→{r['fused_ms']:6.1f} ms "
            f"(×{r['speedup']:.1f})")
    lines.append(f"optimizer selects FusedJoinAgg: {sel}")

    guard = next(r for r in recs if r["shape"] == GUARD_SHAPE)
    ok = (guard.get("temp_ratio", 0) >= GUARD_TEMP_RATIO
          and guard["fused_ms"] < guard["unfused_ms"] and sel)
    lines.append(f"regression guard (≥{GUARD_TEMP_RATIO}× temp, faster "
                 f"wall-clock, auto-selected): {'PASS' if ok else 'FAIL'}")
    if not ok:
        raise AssertionError(f"fusion regression guard failed: {guard}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
