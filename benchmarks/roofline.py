"""Roofline table assembly: reads experiments/dryrun/*.json and renders
the per-(arch × shape × mesh) three-term analysis (assignment g).

    PYTHONPATH=src python -m benchmarks.roofline [--mesh single|multi]
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str = "single") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR,
                                              f"*__{mesh}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    out.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                            if r["shape"] in SHAPE_ORDER else 99))
    return out


def _fmt_s(x: Optional[float]) -> str:
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def table(mesh: str = "single") -> List[str]:
    rows = load(mesh)
    if not rows:
        return [f"(no dry-run records for mesh={mesh}; run "
                f"python -m repro.launch.dryrun --all"
                + (" --multi-pod" if mesh == "multi" else "") + ")"]
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'collect':>9s} {'dominant':>10s} {'frac':>6s} {'useful':>7s} "
           f"{'mem/chip':>9s}")
    lines = [f"# Roofline — mesh {rows[0].get('mesh', mesh)} "
             f"(TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)", hdr]
    ok = skip = err = 0
    for r in rows:
        if r["status"] == "skip":
            skip += 1
            lines.append(f"{r['arch']:24s} {r['shape']:12s} "
                         f"{'— skipped: sub-quadratic-only shape —':>40s}")
            continue
        if r["status"] != "ok":
            err += 1
            lines.append(f"{r['arch']:24s} {r['shape']:12s} ERROR "
                         f"{r.get('error', '')[:60]}")
            continue
        ok += 1
        t = r["roofline"]
        frac = t.get("roofline_fraction")
        useful = t.get("useful_flops_ratio")
        mem = (r["memory"]["argument_gib"] + r["memory"]["temp_gib"])
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} "
            f"{_fmt_s(t['compute_s']):>9s} {_fmt_s(t['memory_s']):>9s} "
            f"{_fmt_s(t['collective_s']):>9s} {t['dominant']:>10s} "
            f"{frac * 100 if frac else 0:5.1f}% "
            f"{useful * 100 if useful else 0:6.1f}% "
            f"{mem:8.2f}G")
    lines.append(f"# {ok} ok, {skip} skipped (documented), {err} errors")
    return lines


def run(mesh=None) -> List[str]:
    lines = table("single")
    multi = table("multi")
    if len(multi) > 2:
        lines += [""] + multi
    return lines


if __name__ == "__main__":
    import sys
    which = sys.argv[sys.argv.index("--mesh") + 1] \
        if "--mesh" in sys.argv else "single"
    print("\n".join(table(which)))
