"""§Perf hillclimb driver: run named variants of the three chosen cells
and record the roofline-term deltas (hypothesis → change → measure).

    PYTHONPATH=src python -m benchmarks.perf_iters

Cells (chosen per the assignment's three criteria):
  A qwen2.5-14b × train_4k   — most representative of the paper's
                               technique (every placement is planner-
                               chosen) and most collective-bound.
  B qwen2.5-14b × decode_32k — worst roofline fraction (memory-bound).
  C deepseek-v2-lite × train_4k — MoE+MLA: EP/TP interplay.

Variants re-lower + re-compile on the production mesh and re-meter the
structural roofline; results append to experiments/perf_iters.json.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import dataclasses
import json

from repro.configs import get_config

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "perf_iters.json")


def run_variant(tag, arch, shape_name, cfg_override=None, hypothesis="",
                mesh_shape=None):
    from repro.launch import dryrun

    cfg = get_config(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)

    # monkeypatch the config the dry-run sees for this cell
    orig = dryrun.get_config
    dryrun.get_config = lambda a, smoke=False: cfg
    try:
        rec = dryrun.lower_cell(arch, shape_name, multi_pod=False,
                                mesh_shape=mesh_shape)
    finally:
        dryrun.get_config = orig
    t = rec.get("roofline", {})
    out = {
        "tag": tag,
        "cell": f"{arch}×{shape_name}",
        "hypothesis": hypothesis,
        "override": cfg_override or {},
        "compute_s": t.get("compute_s"),
        "memory_s": t.get("memory_s"),
        "collective_s": t.get("collective_s"),
        "dominant": t.get("dominant"),
        "step_s": t.get("step_s"),
        "roofline_fraction": t.get("roofline_fraction"),
        "mem_chip_gib": (rec.get("memory", {}).get("argument_gib", 0)
                         + rec.get("memory", {}).get("temp_gib", 0)),
        "status": rec.get("status"),
    }
    print(f"[{tag}] dom={out['dominant']} step={out['step_s']:.4f}s "
          f"frac={out['roofline_fraction']:.4f} "
          f"mem={out['mem_chip_gib']:.1f}G")
    return out


def main():
    results = []

    # ---- Cell B: decode, memory-bound --------------------------------
    results.append(run_variant(
        "B0-baseline", "qwen2.5-14b", "decode_32k",
        hypothesis="baseline: bf16 KV cache dominates decode bytes"))
    results.append(run_variant(
        "B1-fp8-kv", "qwen2.5-14b", "decode_32k",
        {"kv_cache_dtype": "float8_e4m3fn"},
        hypothesis="cache bytes halve → memory term ≈ halves → "
                   "roofline fraction ≈ doubles (quality cost ~4% logit "
                   "rel-err, measured in tests)"))

    # ---- Cell A: train, collective-bound ------------------------------
    results.append(run_variant(
        "A0-baseline", "qwen2.5-14b", "train_4k",
        hypothesis="baseline: planner-chosen placements, accum=16, "
                   "dots_saveable remat"))
    results.append(run_variant(
        "A1-full-remat", "qwen2.5-14b", "train_4k",
        {"remat": "full"},
        hypothesis="full remat: +27% compute term (4.0× vs 3.15× fwd) "
                   "but halves live activations → enables A2"))
    results.append(run_variant(
        "A2-mesh-64x4", "qwen2.5-14b", "train_4k", None,
        hypothesis="mesh refactor 16×16 → 64×4: the Megatron AR ring over "
                   "the model axis scales with (sm−1); at sm=4 the TP "
                   "collective shrinks 5× (62→12.4 TB) while weights "
                   "(28 GB bf16 / 4 = 7 GB/chip) still fit — step should "
                   "become compute-bound near the 6·N·D bound",
        mesh_shape=(64, 4)))
    results.append(run_variant(
        "A3-mesh-64x4-fullremat", "qwen2.5-14b", "train_4k",
        {"remat": "full"},
        hypothesis="A2 + full remat: keep the per-chip memory at 64×4 "
                   "under control (bigger bf16 weight shard)",
        mesh_shape=(64, 4)))

    # ---- Cell C: MoE train ---------------------------------------------
    results.append(run_variant(
        "C0-baseline", "deepseek-v2-lite-16b", "train_4k",
        hypothesis="baseline: grouped local dispatch, cf=1.25"))
    results.append(run_variant(
        "C1-capacity-1.0", "deepseek-v2-lite-16b", "train_4k",
        {"moe_capacity_factor": 1.0},
        hypothesis="cf 1.25→1.0: routed tokens −20% → expert flops and "
                   "EP dispatch bytes −20% (quality guarded by the "
                   "load-balance aux loss)"))
    results.append(run_variant(
        "C2-mesh-64x4", "deepseek-v2-lite-16b", "train_4k", None,
        hypothesis="mesh refactor 16×16 → 64×4: same AR-ring argument as "
                   "A2; experts 64 % 4 == 0 keeps EP available",
        mesh_shape=(64, 4)))

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
