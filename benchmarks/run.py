"""Benchmark runner: one section per paper table + the roofline report.

    PYTHONPATH=src python -m benchmarks.run [--with-measured]

``--with-measured`` additionally executes the scaled-down distributed
plans on an 8-host-device mesh (slower; spawns a subprocess so the main
process keeps its single-device view).
"""
from __future__ import annotations

import argparse
import subprocess
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--with-measured", action="store_true")
    args = ap.parse_args(argv)

    from benchmarks import (analysis, ffnn, fusion, matmul, nn_search,
                            oocore, resilience, robustness, roofline,
                            serve, train)

    sections = [
        ("§5.1 matmul (Tables 3–4)", matmul.run),
        ("§5.2 nn-search (Tables 5–6)", nn_search.run),
        ("§5.3 ffnn (Tables 7–9)", ffnn.run),
        ("fused Σ∘⋈ contraction (BENCH_fusion.json)", fusion.run),
        ("TRA train step (BENCH_train.json)", train.run),
        ("robustness overheads (BENCH_robust.json)", robustness.run),
        ("serving: continuous batching (BENCH_serve.json)", serve.run),
        ("serving resilience (BENCH_resilience.json)", resilience.run),
        ("out-of-core streaming (BENCH_oocore.json)", oocore.run),
        ("static verifier overhead (BENCH_analysis.json)", analysis.run),
        ("roofline (assignment g)", roofline.run),
    ]
    failures = 0
    for title, fn in sections:
        print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")
        try:
            for line in fn(None):
                print(line)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"SECTION FAILED: {e!r}")

    if args.with_measured:
        print(f"\n{'=' * 72}\nmeasured 8-device runs (subprocess)\n"
              f"{'=' * 72}")
        code = (
            "import os;"
            "os.environ['XLA_FLAGS']="
            "'--xla_force_host_platform_device_count=8';"
            "import jax;"
            "from benchmarks import matmul;"
            "from repro.launch.mesh import make_mesh;"
            "mesh = make_mesh((8,), ('sites',));"
            "print('\\n'.join(str(r) for r in matmul.measured(mesh)))")
        proc = subprocess.run([sys.executable, "-c", code],
                              capture_output=True, text=True, timeout=1200)
        print(proc.stdout or proc.stderr)
        failures += proc.returncode != 0
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
