"""Hypothesis property tests on system invariants beyond the TRA core."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cost import move_floats
from repro.core.plan import Placement
from repro.data import DataConfig, make_batch
from repro.optim import adamw, AdamWConfig


# ------------------------------------------------------ move-cost algebra
placements = st.sampled_from([
    Placement.replicated(),
    Placement.partitioned((0,), ("D",)),
    Placement.partitioned((1,), ("D",)),
    Placement.partitioned((0,), ("M",)),
    Placement.partitioned((1,), ("M",)),
    Placement.partitioned((0, 1), ("D", "M")),
    Placement.partitioned((1, 0), ("D", "M")),
])
axis_sizes = st.fixed_dictionaries({"D": st.sampled_from([2, 4, 8]),
                                    "M": st.sampled_from([2, 4])})
floats = st.integers(min_value=1, max_value=10**9)


@given(placements, axis_sizes, floats)
@settings(max_examples=80, deadline=None)
def test_move_to_self_is_free(p, sizes, f):
    assert move_floats(f, p, p, sizes) == 0


@given(placements, placements, axis_sizes, floats)
@settings(max_examples=120, deadline=None)
def test_move_cost_nonnegative_and_bounded(src, tgt, sizes, f):
    s = sizes["D"] * sizes["M"]
    wire = move_floats(f, src, tgt, sizes)
    assert wire >= 0
    # no transition can exceed full replication everywhere
    assert wire <= f * s


@given(placements, axis_sizes, floats)
@settings(max_examples=80, deadline=None)
def test_paper_accounting_formulas(p, sizes, f):
    s = sizes["D"] * sizes["M"]
    # BCAST = f×s, SHUF = f — the paper's §4.3 rules, verbatim
    assert move_floats(f, p, None, sizes, accounting="paper") == f * s
    tgt = Placement.partitioned((0,), ("D",))
    assert move_floats(f, p, tgt, sizes, accounting="paper") == f


@given(placements, axis_sizes, floats)
@settings(max_examples=80, deadline=None)
def test_slice_from_replicated_is_free(tgt, sizes, f):
    # a replicated source already holds every site's needs
    wire = move_floats(f, Placement.replicated(), tgt, sizes)
    if tgt.kind == "partitioned":
        assert wire == 0


@given(axis_sizes, floats)
@settings(max_examples=40, deadline=None)
def test_gather_costs_axis_minus_one(sizes, f):
    src = Placement.partitioned((0,), ("D",))
    wire = move_floats(f, src, None, sizes)
    s = sizes["D"] * sizes["M"]
    # all-gather over D replicated across M columns ≈ f×(s−1)
    assert wire == int(round(f * s * (1.0 - 1.0 / sizes["D"])))


# ------------------------------------------------------------- data rows
@given(st.integers(0, 10**6), st.integers(1, 6),
       st.sampled_from([4, 8, 16]))
@settings(max_examples=30, deadline=None)
def test_batches_deterministic_across_calls(step, seed, gb):
    cfg = DataConfig(vocab_size=97, seq_len=12, global_batch=gb, seed=seed)
    a = make_batch(cfg, step)
    b = make_batch(cfg, step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 97


@given(st.integers(0, 1000), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_grammar_rows_are_next_token_predictable(step, seed):
    cfg = DataConfig(vocab_size=53, seq_len=10, global_batch=4, seed=seed,
                     grammar_frac=1.0)
    b = make_batch(cfg, step)
    # labels are the next token of the same recurrence
    x, y = b["tokens"], b["labels"]
    assert x.shape == y.shape
    # recurrence property: y[t] == (a·x[t] + c) mod V for fixed (a, c);
    # check consistency: the map x[t] -> y[t] must be a function
    for r in range(x.shape[0]):
        seen = {}
        for t in range(x.shape[1]):
            k, v = int(x[r, t]), int(y[r, t])
            assert seen.setdefault(k, v) == v


# ----------------------------------------------------- optimizer algebra
@given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=3,
                max_size=8),
       st.floats(0.1, 5.0))
@settings(max_examples=40, deadline=None)
def test_clip_never_increases_norm(vals, max_norm):
    g = {"w": jnp.asarray(vals, jnp.float32)}
    clipped, norm = adamw.clip_by_global_norm(g, max_norm)
    cn = float(adamw.global_norm(clipped))
    assert cn <= max(max_norm, float(norm)) * (1 + 1e-5)
    if float(norm) <= max_norm:
        np.testing.assert_allclose(np.asarray(clipped["w"]),
                                   np.asarray(vals, np.float32),
                                   rtol=1e-6, atol=1e-6)


@given(st.integers(1, 4), st.floats(1e-4, 1e-2))
@settings(max_examples=10, deadline=None)
def test_adamw_step_counter_monotonic(n, lr):
    params = {"w": jnp.ones((3,))}
    state = adamw.init(params)
    for i in range(n):
        state, _, _ = adamw.apply(state, {"w": jnp.ones((3,))},
                                  AdamWConfig(lr=lr))
    assert int(state["step"]) == n
