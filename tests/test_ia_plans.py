"""IA compilation, equivalence rules, optimizer and cost-model tests."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (Bcast, IAInput, LocalAgg, LocalJoin, Placement,
                        RelType, Shuf, TraAgg, TraFilter, TraInput, TraJoin,
                        TraReKey, TraTransform, check_valid, comm_cost,
                        compile_tra, describe, from_tensor, get_kernel,
                        infer, optimize, to_tensor)
from repro.core.optimize import logical_variants
from repro.core import tra

from conftest import (shim_evaluate_ia as evaluate_ia,
                      shim_evaluate_tra as evaluate_tra)

S = ("sites",)
SZ = {"sites": 4}


def matmul_plan(fl, fr, bl, br, name_l="A", name_r="B"):
    ta = TraInput(name_l, RelType(fl, bl))
    tb = TraInput(name_r, RelType(fr, br))
    return TraAgg(TraJoin(ta, tb, (1,), (0,), get_kernel("matMul")),
                  (0, 2), get_kernel("matAdd"))


def rand_rel(key, f, b):
    x = jax.random.normal(jax.random.PRNGKey(key),
                          (f[0] * b[0], f[1] * b[1]), jnp.float32)
    return from_tensor(x, b), x


class TestCompile:
    def test_table1_default_shapes(self):
        plan = matmul_plan((4, 4), (4, 4), (8, 8), (8, 8))
        ia = compile_tra(plan, {"A": Placement.partitioned((0,), S),
                                "B": Placement.partitioned((0,), S)})
        # default join = BCAST(left); default agg = SHUF then local agg
        assert isinstance(ia, LocalAgg)
        assert isinstance(ia.child, Shuf)
        assert isinstance(ia.child.child, LocalJoin)
        assert isinstance(ia.child.child.left, Bcast)
        info = check_valid(ia)
        assert info.rtype.key_shape == (4, 4)

    def test_compiled_plan_equals_logical(self):
        plan = matmul_plan((4, 4), (4, 4), (8, 8), (8, 8))
        RA, A = rand_rel(0, (4, 4), (8, 8))
        RB, B = rand_rel(1, (4, 4), (8, 8))
        ia = compile_tra(plan, {"A": Placement.replicated(),
                                "B": Placement.replicated()})
        want = evaluate_tra(plan, {"A": RA, "B": RB})
        got = evaluate_ia(ia, {"A": RA, "B": RB})
        np.testing.assert_allclose(np.asarray(got.data),
                                   np.asarray(want.data), rtol=1e-4, atol=1e-4)


class TestCostModel:
    """Exact float-movement accounting (paper §4.3)."""

    def test_bcast_cost_is_f_times_s(self):
        rt = RelType((4, 4), (8, 8))
        inp = IAInput("A", rt, Placement.partitioned((0,), S))
        f, s = 16 * 64, 4
        # paper accounting: verbatim §4.3 BCAST = f×s
        assert comm_cost(Bcast(inp), SZ, accounting="paper") == f * s
        # wire accounting: ring all-gather = f×(s−1)
        assert comm_cost(Bcast(inp), SZ) == f * (s - 1)

    def test_bcast_of_replicated_is_free(self):
        rt = RelType((4, 4), (8, 8))
        inp = IAInput("A", rt, Placement.replicated())
        assert comm_cost(Bcast(inp), SZ) == 0

    def test_shuffle_cost_is_f(self):
        rt = RelType((4, 4), (8, 8))
        inp = IAInput("A", rt, Placement.partitioned((0,), S))
        f, s = 16 * 64, 4
        # paper accounting: SHUF = f (every tuple moves once)
        assert comm_cost(Shuf(inp, (1,), S), SZ, accounting="paper") == f
        # wire accounting: all-to-all keeps the diagonal → f×(s−1)/s
        assert comm_cost(Shuf(inp, (1,), S), SZ) == f * (s - 1) // s

    def test_noop_shuffle_is_free(self):
        rt = RelType((4, 4), (8, 8))
        inp = IAInput("A", rt, Placement.partitioned((0,), S))
        assert comm_cost(Shuf(inp, (0,), S), SZ) == 0

    def test_double_bcast_costs_double(self):
        """Paper §4.3: no automatic algorithmic optimization — a stupid
        double broadcast is costed twice (dedup happens via R2-1 rewrites,
        not in the cost model)."""
        rt = RelType((4, 4), (8, 8))
        inp = IAInput("A", rt, Placement.partitioned((0,), S))
        c1 = comm_cost(Bcast(inp), SZ)
        # NOTE: second bcast of an ALL relation is free by placement — the
        # paper's example refers to re-broadcast after placement loss; we
        # model the placement-aware exact cost.
        assert comm_cost(Bcast(Bcast(inp)), SZ) == c1

    def test_two_phase_agg_cheaper_for_large_contraction(self):
        # K blocks = 8 partials vs shuffling the whole join output
        plan = matmul_plan((2, 8), (8, 2), (4, 4), (4, 4))
        r = optimize(plan, {"A": Placement.partitioned((1,), S),
                            "B": Placement.partitioned((0,), S)},
                     S, SZ)
        # best plan must use the two-phase (partial) aggregation
        found_partial = "partial" in describe(r.plan)
        assert found_partial, describe(r.plan)


class TestOptimizer:
    def test_all_strategies_agree(self):
        plan = matmul_plan((4, 4), (4, 4), (8, 8), (8, 8))
        RA, A = rand_rel(0, (4, 4), (8, 8))
        RB, B = rand_rel(1, (4, 4), (8, 8))
        want = np.asarray(A @ B)
        for placements in [
            {"A": Placement.replicated(), "B": Placement.replicated()},
            {"A": Placement.partitioned((0,), S),
             "B": Placement.partitioned((0,), S)},
            {"A": Placement.partitioned((1,), S),
             "B": Placement.partitioned((0,), S)},
        ]:
            r = optimize(plan, placements, S, SZ)
            got = evaluate_ia(r.plan, {"A": RA, "B": RB})
            np.testing.assert_allclose(np.asarray(to_tensor(got)), want,
                                       rtol=1e-4, atol=1e-4)

    def test_optimizer_beats_default_compile(self):
        plan = matmul_plan((2, 16), (16, 2), (4, 4), (4, 4))
        placements = {"A": Placement.partitioned((1,), S),
                      "B": Placement.partitioned((0,), S)}
        default = compile_tra(plan, placements)
        r = optimize(plan, placements, S, SZ)
        assert r.cost < comm_cost(default, SZ)

    def test_rmm_enumerated_on_2d_mesh(self):
        """The §4.2.2 replication-based (3-D) matmul needs two mesh axes."""
        plan = matmul_plan((4, 4), (4, 4), (8, 8), (8, 8))
        axes = ("s0", "s1")
        sizes = {"s0": 2, "s1": 2}
        placements = {"A": Placement.partitioned((0,), ("s0",)),
                      "B": Placement.partitioned((1,), ("s1",))}
        r = optimize(plan, placements, axes, sizes)
        # with operands already on distinct axes, the best plan should join
        # them without any repartition (RMM) — communication only for the
        # final reduction
        RA, A = rand_rel(0, (4, 4), (8, 8))
        RB, B = rand_rel(1, (4, 4), (8, 8))
        got = evaluate_ia(r.plan, {"A": RA, "B": RB})
        np.testing.assert_allclose(np.asarray(to_tensor(got)),
                                   np.asarray(A @ B), rtol=1e-4, atol=1e-4)
        assert "Shuf" not in describe(r.plan).split("LocalJoin")[1], \
            describe(r.plan)

    def test_filter_pushdown_reduces_cost(self):
        """R1-6 + R2-2: pushing isEq below a join cuts the broadcast."""
        rt = RelType((4, 4), (8, 8))
        ta, tb = TraInput("A", rt), TraInput("B", rt)
        j = TraJoin(ta, tb, (0, 1), (0, 1), get_kernel("matAdd"))
        f = TraFilter(j, lambda k: k[0] == k[1], tag="isEq")
        plan = TraTransform(f, get_kernel("diag"))
        placements = {"A": Placement.partitioned((0,), S),
                      "B": Placement.partitioned((0,), S)}
        nofuse = optimize(plan, placements, S, SZ,
                          try_logical_rewrites=False)
        fused = optimize(plan, placements, S, SZ)
        assert fused.cost <= nofuse.cost
        RA, A = rand_rel(0, (4, 4), (8, 8))
        RB, B = rand_rel(1, (4, 4), (8, 8))
        want = evaluate_tra(plan, {"A": RA, "B": RB})
        got = evaluate_ia(fused.plan, {"A": RA, "B": RB})
        assert got.rtype == want.rtype
        np.testing.assert_allclose(np.asarray(got.data),
                                   np.asarray(want.data), rtol=1e-4, atol=1e-4)


class TestLogicalRewrites:
    def test_variants_preserve_semantics(self):
        rt = RelType((4, 4), (8, 8))
        ta, tb = TraInput("A", rt), TraInput("B", rt)
        j = TraJoin(ta, tb, (0, 1), (0, 1), get_kernel("matAdd"))
        f = TraFilter(j, lambda k: k[0] == k[1], tag="isEq")
        plan = TraTransform(f, get_kernel("diag"))
        RA, A = rand_rel(0, (4, 4), (8, 8))
        RB, B = rand_rel(1, (4, 4), (8, 8))
        want = evaluate_tra(plan, {"A": RA, "B": RB}).to_dict()
        variants = logical_variants(plan)
        assert len(variants) > 1
        for v in variants:
            got = evaluate_tra(v, {"A": RA, "B": RB}).to_dict()
            assert set(got) == set(want)
            for k in want:
                np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-4)

    def test_transform_agg_commute_variant(self):
        """R1-4 with a distributive kernel (diag over matAdd)."""
        rt = RelType((4, 2), (8, 8))
        ta = TraInput("A", rt)
        plan = TraTransform(TraAgg(ta, (0,), get_kernel("matAdd")),
                            get_kernel("diag"))
        variants = logical_variants(plan)
        sigs = {str(type(v).__name__) for v in variants}
        assert "TraAgg" in sigs  # the commuted form exists
        RA, _ = rand_rel(0, (4, 2), (8, 8))
        want = evaluate_tra(plan, {"A": RA}).to_dict()
        for v in variants:
            got = evaluate_tra(v, {"A": RA}).to_dict()
            for k in want:
                np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-4)
