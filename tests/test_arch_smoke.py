"""Per-architecture smoke tests on reduced configs (assignment f).

For every assigned architecture: instantiate the SMOKE config, run one
forward and one train step on CPU, assert output shapes and finiteness;
then check prefill + decode agree with the full-sequence oracle.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SMOKES, get_config, list_archs
from repro.models import (count_params, decode_step, forward, init_params,
                          loss_fn, prefill)
from repro.optim import AdamWConfig, adamw

ARCHS = list_archs()


def _batch(cfg, key, B, S, with_labels=True):
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        b = {"tokens": toks}
    else:
        b = {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                         jnp.bfloat16)}
    if with_labels:
        b["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = SMOKES[arch]
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, jax.random.PRNGKey(1), B, S)
    logits = forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    from repro.models.layers import no_shard
    from repro.optim import schedule
    from repro.runtime import make_train_step

    cfg = SMOKES[arch]
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = adamw.init(params)
    batch = _batch(cfg, jax.random.PRNGKey(1), 2, 32)

    step_fn = make_train_step(cfg, AdamWConfig(lr=1e-3),
                              lambda s: schedule.constant(s), no_shard)

    @jax.jit
    def step(state, batch):
        new_state, metrics = step_fn(state, batch)
        return new_state, metrics["loss"], metrics["grad_norm"]

    state2, loss, gnorm = step(state, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    # params actually moved
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: jnp.max(jnp.abs(a - b)),
        state["master"], state2["master"]))
    assert max(float(x) for x in delta) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_match_forward(arch):
    cfg = SMOKES[arch]
    params = init_params(cfg, jax.random.PRNGKey(2))
    B, S, CL = 2, 16, 32
    key = jax.random.PRNGKey(3)
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
        pre, step_in = {"tokens": toks[:, :S]}, {"token": toks[:, S:S + 1]}
        full = {"tokens": toks}
    else:
        em = jax.random.normal(key, (B, S + 1, cfg.d_model), jnp.bfloat16)
        pre, step_in = {"embeds": em[:, :S]}, {"embed": em[:, S:S + 1]}
        full = {"embeds": em}
    lp, cache = prefill(cfg, params, pre, CL)
    ls, cache2 = decode_step(cfg, params, cache, step_in)
    lf = forward(cfg, params, full)
    assert lp.shape == (B, 1, cfg.vocab_size)
    assert ls.shape == (B, 1, cfg.vocab_size)
    assert int(cache2["pos"]) == S + 1
    # bf16 models, different compute orders (chunked SSD, absorbed MLA):
    # compare with bf16-scale tolerance relative to the logit magnitude
    scale = float(jnp.max(jnp.abs(lf))) + 1.0
    assert float(jnp.max(jnp.abs(lp[:, 0] - lf[:, S - 1]))) < 0.02 * scale
    assert float(jnp.max(jnp.abs(ls[:, 0] - lf[:, S]))) < 0.02 * scale


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_structure(arch):
    """FULL configs: exact assigned hyperparameters, no allocation."""
    cfg = get_config(arch)
    n = count_params(cfg)
    assert n > 0
    expected_layers = {
        "mamba2-130m": 24, "qwen2.5-14b": 48, "qwen2-7b": 28,
        "gemma2-2b": 26, "minitron-4b": 32, "llama4-scout-17b-a16e": 48,
        "deepseek-v2-lite-16b": 27, "musicgen-large": 48,
        "internvl2-2b": 24, "zamba2-7b": 78,
    }
    assert cfg.n_layers == expected_layers[arch]
    # rough param-count sanity per the model card names
    expected_range = {
        "mamba2-130m": (0.10e9, 0.17e9),
        "qwen2.5-14b": (12e9, 17e9),
        "qwen2-7b": (6e9, 9e9),
        "gemma2-2b": (2e9, 3.5e9),
        "minitron-4b": (3.5e9, 5.5e9),
        "llama4-scout-17b-a16e": (80e9, 120e9),   # total incl. 16 experts
        "deepseek-v2-lite-16b": (13e9, 18e9),
        "musicgen-large": (2e9, 3.5e9),
        "internvl2-2b": (1.5e9, 3e9),
        "zamba2-7b": (6e9, 9e9),
    }
    lo, hi = expected_range[arch]
    assert lo <= n <= hi, f"{arch}: {n:,} params outside [{lo:,}, {hi:,}]"
