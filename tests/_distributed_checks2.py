"""Model-level distributed checks (8 host devices, subprocess).

1. Sharded train step == unsharded train step (bitwise-ish) for a dense
   and an MoE smoke config on a 4×2 mesh with TRA-planned specs.
2. GPipe pipeline == sequential stage application.
3. Elastic re-mesh: checkpoint written under mesh A restores under
   mesh B and training continues with identical loss.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402

from repro.configs import SMOKES  # noqa: E402
from repro.configs.base import ShapeSpec  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.models.layers import no_shard  # noqa: E402
from repro.optim import AdamWConfig, adamw, schedule  # noqa: E402
from repro.runtime import gpipe, make_train_step  # noqa: E402
from repro.sharding import (batch_pspecs, make_sharder, param_pspecs,  # noqa: E402
                            plan_arch, zero1_pspecs)


from repro.launch.mesh import make_mesh  # noqa: E402


def mesh42():
    return make_mesh((4, 2), ("data", "model"))


def check_sharded_step_matches_unsharded():
    for arch in ("qwen2.5-14b", "llama4-scout-17b-a16e", "mamba2-130m"):
        cfg = SMOKES[arch]
        mesh = mesh42()
        shape = ShapeSpec("t", 32, 8, "train")
        plan = plan_arch(cfg, shape, mesh)
        sharder = make_sharder(mesh, plan.act_axis_map)
        params = init_params(cfg, jax.random.PRNGKey(0))
        state = adamw.init(params)
        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0,
                                              cfg.vocab_size),
                 "labels": jax.random.randint(key, (8, 32), 0,
                                              cfg.vocab_size)}
        if cfg.input_mode == "embeddings":
            batch = {"embeds": jax.random.normal(
                key, (8, 32, cfg.d_model), jnp.bfloat16),
                "labels": batch["labels"]}

        base = make_train_step(cfg, AdamWConfig(lr=1e-3),
                               lambda s: schedule.constant(s), no_shard)
        _, m0 = jax.jit(base)(state, batch)

        spec_fn = zero1_pspecs
        pspecs = spec_fn(mesh, plan.param_axis_map, state["master"])
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        sh_state = {
            "step": state["step"],
            "master": jax.tree.map(jax.device_put, state["master"], psh),
            "m": jax.tree.map(jax.device_put, state["m"], psh),
            "v": jax.tree.map(jax.device_put, state["v"], psh),
        }
        bspecs = batch_pspecs(mesh, plan.act_axis_map, batch)
        sh_batch = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            batch, bspecs)
        sharded = make_train_step(cfg, AdamWConfig(lr=1e-3),
                                  lambda s: schedule.constant(s), sharder)
        with mesh:
            _, m1 = jax.jit(sharded)(sh_state, sh_batch)
        l0, l1 = float(m0["loss"]), float(m1["loss"])
        assert abs(l0 - l1) < 5e-2 * max(abs(l0), 1.0), (arch, l0, l1)
        print(f"  sharded==unsharded loss {arch}: {l0:.4f} vs {l1:.4f} OK")


def check_gpipe():
    mesh = make_mesh((8,), ("stage",))
    S, M, B, D = 8, 16, 2, 32
    ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) * 0.2
    run = gpipe(lambda p, x: jnp.tanh(x @ p["w"]), mesh, "stage")
    xs = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))
    out = run({"w": ws}, xs)
    ref = xs
    for i in range(S):
        ref = jnp.tanh(ref @ ws[i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print("  gpipe 8-stage == sequential OK")


def check_elastic_remesh():
    import tempfile

    from repro.checkpoint import CheckpointStore
    from repro.data import DataConfig
    from repro.runtime import Trainer, TrainerConfig, elastic_restore

    cfg = SMOKES["qwen2.5-14b"]
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                      global_batch=8, seed=5)
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(steps=6, ckpt_every=3, ckpt_dir=d, warmup=1,
                             adamw=AdamWConfig(lr=1e-3))
        mesh_a = mesh42()
        tr = Trainer(cfg, dcfg, tcfg, mesh=mesh_a)
        tr.train(steps=3)
        tr.save()
        tr.store.wait()
        # rescale: "lose half the cluster" → 2×2 mesh
        mesh_b = make_mesh((2, 2), ("data", "model"))
        shape = ShapeSpec("t", 16, 8, "train")
        state, extra, plan = elastic_restore(
            CheckpointStore(d), cfg, mesh_b, shape, tcfg)
        assert int(jax.device_get(state["step"])) == 3
        assert extra["data_step"] == 3
        # continue on the new mesh
        sharder = make_sharder(mesh_b, plan.act_axis_map)
        step_fn = make_train_step(cfg, tcfg.adamw,
                                  lambda s: schedule.constant(s), sharder)
        from repro.data import make_batch
        b = {k: jnp.asarray(v) for k, v in make_batch(dcfg, 3).items()}
        with mesh_b:
            state2, metrics = jax.jit(step_fn)(state, b)
        assert np.isfinite(float(metrics["loss"]))
        print(f"  elastic re-mesh 4×2 → 2×2, step 4 loss "
              f"{float(metrics['loss']):.4f} OK")


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.device_count()
    check_sharded_step_matches_unsharded()
    check_gpipe()
    check_elastic_remesh()
    print("ALL MODEL DISTRIBUTED CHECKS PASSED")
