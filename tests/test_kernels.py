"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles,
executed in interpret mode on CPU (assignment c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import _blockwise_jnp, attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.matmul.kernel import matmul_pallas
from repro.kernels.matmul.ops import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ops import (ssd_decode_step, ssd_final_state,
                                        ssd_scan)
from repro.kernels.ssd_scan.ref import ssd_ref


# ---------------------------------------------------------------- matmul
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 128),
                                   (384, 256, 640)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_pallas_interpret(m, k, n, dtype):
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (m, k), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    out = matmul_pallas(a, b, block_m=128, block_n=128, block_k=128,
                        interpret=True)
    ref = matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * k ** 0.5)


def test_matmul_op_pads_ragged():
    a = jax.random.normal(jax.random.PRNGKey(0), (100, 70))
    b = jax.random.normal(jax.random.PRNGKey(1), (70, 50))
    out = matmul(a, b, impl="pallas", block_m=64, block_n=64, block_k=64,
                 interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------- flash attention
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 64, 0.0), (True, 0, 30.0), (False, 0, 0.0)])
def test_flash_attention_interpret(hq, hkv, causal, window, softcap):
    b, s, d = 2, 256, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, hq, s, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d))
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 softcap=softcap, block_q=128,
                                 block_kv=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    b, h, s, d = 1, 4, 128, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, d), dtype)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                 block_kv=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("sq", [96, 256, 511])
def test_blockwise_jnp_matches_ref(sq):
    b, hq, hkv, d = 2, 8, 4, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, hq, sq, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, sq, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, sq, d))
    for kw in (dict(causal=True, window=0, softcap=0.0),
               dict(causal=True, window=33, softcap=0.0),
               dict(causal=True, window=0, softcap=8.0)):
        out = _blockwise_jnp(q, k, v, scale=None, block_q=128, **kw)
        ref = attention_ref(q, k, v, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_attention_op_decode_path():
    """sq=1 against a longer KV cache (ends-aligned causal)."""
    b, h, skv, d = 2, 4, 64, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, h, skv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, skv, d))
    out = attention(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- SSD scan
@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 32), (96, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunked_jnp_vs_ref(s, chunk, dtype):
    b, h, p, n = 2, 4, 16, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (b, s, h))).astype(dtype)
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)))
    Bm = jax.random.normal(jax.random.PRNGKey(3), (b, s, n), dtype)
    Cm = jax.random.normal(jax.random.PRNGKey(4), (b, s, n), dtype)
    out = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, impl="jnp")
    ref, _ = ssd_ref(x, dt, A, Bm, Cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("s,chunk", [(64, 32), (128, 64)])
def test_ssd_pallas_interpret(s, chunk):
    b, h, p, n = 1, 2, 16, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)))
    Bm = jax.random.normal(jax.random.PRNGKey(3), (b, s, n))
    Cm = jax.random.normal(jax.random.PRNGKey(4), (b, s, n))
    out = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref, _ = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_ssd_decode_matches_scan():
    """Recurrent decode steps == full scan, via the prefill state."""
    b, s, h, p, n = 2, 32, 4, 8, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, s + 4, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (b, s + 4, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)))
    Bm = jax.random.normal(jax.random.PRNGKey(3), (b, s + 4, n))
    Cm = jax.random.normal(jax.random.PRNGKey(4), (b, s + 4, n))
    full, _ = ssd_ref(x, dt, A, Bm, Cm)
    hstate = ssd_final_state(x[:, :s], dt[:, :s], A, Bm[:, :s], Cm[:, :s])
    for t in range(s, s + 4):
        y, hstate = ssd_decode_step(hstate, x[:, t], dt[:, t], A,
                                    Bm[:, t], Cm[:, t])
        np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, t]),
                                   rtol=1e-4, atol=1e-4)
