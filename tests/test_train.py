"""TRA-native training loop: optimizer updates as TRA expressions.

Covers the train-step acceptance criteria:

* full-step equivalence vs dense oracles for SGD, SGD+momentum and AdamW
  — a hand-written jnp oracle always, plus the real ``optax`` chain when
  it is installed (the two are verified against each other);
* §5.3 FFNN convergence (loss drops over 30 steps) on every executor;
* compile-cache behaviour: step 1 is the only miss, steps ≥ 2 are pure
  cached dispatch (``engine.cache_hits``);
* the fused Σ∘⋈ selection firing *inside* the combined
  loss + gradient + update plan;
* named multi-root (dict) programs on the engine;
* ``Expr.scale_by`` / scalar-relation plumbing and error paths.

The 8-device distributed train-step check lives in
``tests/_distributed_checks.py`` (slow marker).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as tra
from repro.core import (AdamW, Engine, ExprTypeError, Momentum, Placement,
                        SGD, TensorRelation, RelType, TraTrainer,
                        from_tensor, make_train_step, to_tensor)
from repro.core.programs import ffnn_train_step_tra
from repro.core.train import LOSS_ROOT, STEP_STATE

S = ("sites",)
DIMS = (4, 2, 2, 2, 4, 4, 4, 2)          # §5.3 block grid / block sizes


def _data(dims=DIMS):
    nb, db, hb, lb, bn, bd, bh, bl = dims
    N, D, H, L = nb * bn, db * bd, hb * bh, lb * bl
    X = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    Wt = jax.random.normal(jax.random.PRNGKey(4), (D, L)) * 0.5
    Y = jax.nn.sigmoid(X @ Wt)           # learnable targets
    W1 = jax.random.normal(jax.random.PRNGKey(2), (D, H)) * 0.3
    W2 = jax.random.normal(jax.random.PRNGKey(3), (H, L)) * 0.3
    return X, Y, W1, W2


def _rels(dims, X, Y, W1, W2):
    nb, db, hb, lb, bn, bd, bh, bl = dims
    data = dict(X=from_tensor(X, (bn, bd)), Y=from_tensor(Y, (bn, bl)))
    params = dict(W1=from_tensor(W1, (bd, bh)), W2=from_tensor(W2, (bh, bl)))
    return data, params


def _bce(p, Y):
    pc = jnp.clip(p, 1e-7, 1 - 1e-7)
    return jnp.sum(-(Y * jnp.log(pc) + (1 - Y) * jnp.log1p(-pc)))


def _loss_fn(X, Y):
    def loss(params):
        a2 = jax.nn.sigmoid(jax.nn.relu(X @ params["W1"]) @ params["W2"])
        return _bce(a2, Y)
    return loss


# --------------------------------------------------------------------------
# Dense oracle optimizers (optax-equivalent; verified against optax below)
# --------------------------------------------------------------------------

def _dense_sgd(lr):
    def step(t, p, g, st):
        return {k: p[k] - lr * g[k] for k in p}, st
    return step, lambda p: {}


def _dense_momentum(lr, mu):
    def step(t, p, g, st):
        m = {k: mu * st["m"][k] + g[k] for k in p}
        return {k: p[k] - lr * m[k] for k in p}, {"m": m}
    return step, lambda p: {"m": {k: jnp.zeros_like(v)
                                  for k, v in p.items()}}


def _dense_adamw(lr, b1, b2, eps, wd):
    def step(t, p, g, st):
        m = {k: b1 * st["m"][k] + (1 - b1) * g[k] for k in p}
        v = {k: b2 * st["v"][k] + (1 - b2) * g[k] ** 2 for k in p}
        out = {}
        for k in p:
            mh, vh = m[k] / (1 - b1 ** t), v[k] / (1 - b2 ** t)
            out[k] = p[k] - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p[k])
        return out, {"m": m, "v": v}
    return step, lambda p: {"m": {k: jnp.zeros_like(v)
                                  for k, v in p.items()},
                            "v": {k: jnp.zeros_like(v)
                                  for k, v in p.items()}}


OPTIMIZERS = {
    "sgd": (SGD(0.05), _dense_sgd(0.05)),
    "momentum": (Momentum(0.05, 0.9), _dense_momentum(0.05, 0.9)),
    "adamw": (AdamW(1e-2, weight_decay=0.01),
              _dense_adamw(1e-2, 0.9, 0.999, 1e-8, 0.01)),
    "adamw-plain": (AdamW(1e-2),
                    _dense_adamw(1e-2, 0.9, 0.999, 1e-8, 0.0)),
}


# ==========================================================================
# Full-step equivalence vs the dense oracles
# ==========================================================================

@pytest.mark.parametrize("name", sorted(OPTIMIZERS))
def test_train_step_matches_dense_oracle(name):
    """Per-step loss AND updated params match the dense oracle at 1e-4
    over several steps (state threading included)."""
    opt, (dense_step, dense_init) = OPTIMIZERS[name]
    X, Y, W1, W2 = _data()
    data, params = _rels(DIMS, X, Y, W1, W2)
    step = ffnn_train_step_tra(*DIMS, optimizer=opt)
    eng = Engine(executor="jit", optimize=False)
    trainer = TraTrainer(eng, step, params=params)
    p = {"W1": W1, "W2": W2}
    st = dense_init(p)
    loss = _loss_fn(X, Y)
    for t in range(1, 7):
        got_loss = trainer.step(**data)
        want_loss, g = jax.value_and_grad(loss)(p)
        p, st = dense_step(t, p, g, st)
        np.testing.assert_allclose(got_loss, float(want_loss),
                                   rtol=1e-5, atol=1e-4)
        for k in p:
            np.testing.assert_allclose(
                np.asarray(to_tensor(trainer.params[k])), np.asarray(p[k]),
                atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("name", sorted(OPTIMIZERS))
def test_train_step_matches_optax(name):
    """The same steps vs the real optax chain (when installed) — pins the
    hand-written oracles above to the reference implementation."""
    optax = pytest.importorskip("optax")
    chains = {
        "sgd": optax.sgd(0.05),
        "momentum": optax.sgd(0.05, momentum=0.9),
        "adamw": optax.adamw(1e-2, weight_decay=0.01),
        "adamw-plain": optax.adamw(1e-2, weight_decay=0.0),
    }
    opt, _ = OPTIMIZERS[name]
    tx = chains[name]
    X, Y, W1, W2 = _data()
    data, params = _rels(DIMS, X, Y, W1, W2)
    trainer = TraTrainer(Engine(executor="jit", optimize=False),
                         ffnn_train_step_tra(*DIMS, optimizer=opt),
                         params=params)
    p = {"W1": W1, "W2": W2}
    st = tx.init(p)
    loss = _loss_fn(X, Y)
    for _ in range(6):
        got_loss = trainer.step(**data)
        want_loss, g = jax.value_and_grad(loss)(p)
        upd, st = tx.update(g, st, p)
        p = optax.apply_updates(p, upd)
        np.testing.assert_allclose(got_loss, float(want_loss),
                                   rtol=1e-5, atol=1e-4)
        for k in p:
            np.testing.assert_allclose(
                np.asarray(to_tensor(trainer.params[k])), np.asarray(p[k]),
                atol=1e-4, rtol=1e-4)


# ==========================================================================
# Convergence on every executor + compile-cache behaviour
# ==========================================================================

@pytest.mark.parametrize("executor", ["reference", "jit", "gspmd",
                                      "shard_map"])
def test_ffnn_trains_on_every_executor(executor):
    """§5.3 FFNN trains end-to-end as compiled TRA plans: the loss drops
    over 30 steps and steps ≥ 2 are pure cache dispatch.  gspmd/shard_map
    run on a 1-device mesh here; the 8-device version runs in
    tests/_distributed_checks.py."""
    X, Y, W1, W2 = _data()
    data, params = _rels(DIMS, X, Y, W1, W2)
    kwargs = {}
    if executor in ("gspmd", "shard_map"):
        from repro.launch.mesh import make_mesh
        kwargs["mesh"] = make_mesh((1,), S)
        kwargs["input_placements"] = {
            "X": Placement.partitioned((0,), S),
            "Y": Placement.partitioned((0,), S),
            "W1": Placement.replicated(), "W2": Placement.replicated()}
    eng = Engine(executor=executor, **kwargs)
    trainer = TraTrainer(eng, ffnn_train_step_tra(*DIMS,
                                                  optimizer=AdamW(1e-2)),
                         params=params)
    # per-step loss/params vs the dense AdamW oracle at 1e-4
    _, (dense_step, dense_init) = OPTIMIZERS["adamw-plain"]
    p = {"W1": W1, "W2": W2}
    st = dense_init(p)
    loss = _loss_fn(X, Y)
    for t in range(1, 4):
        got_loss = trainer.step(**data)
        want_loss, g = jax.value_and_grad(loss)(p)
        p, st = dense_step(t, p, g, st)
        np.testing.assert_allclose(got_loss, float(want_loss),
                                   rtol=1e-5, atol=1e-4)
        for k in p:
            np.testing.assert_allclose(
                np.asarray(to_tensor(trainer.params[k])), np.asarray(p[k]),
                atol=1e-4, rtol=1e-4)
    # fit targets a TOTAL step count (resumable semantics, matching the
    # dense runtime trainer): 3 manual steps above + 27 more
    losses = trainer.fit(30, **data)
    assert len(losses) == 30
    assert losses[-1] < losses[0], losses
    assert losses[-1] == min(losses[-1], *losses[:5])  # actually trending
    assert eng.cache_misses == 1
    assert eng.cache_hits == 29


def test_fused_join_agg_fires_inside_train_step_plan():
    """The optimizer's Σ∘⋈ contraction selection applies to the combined
    loss + gradient + update program, not just standalone plans."""
    step = ffnn_train_step_tra(*DIMS, optimizer=AdamW(1e-2))
    eng = Engine(executor="jit", optimize=True, axis_sizes={"sites": 2})
    desc = eng.compile(step.roots).describe()
    assert desc.count("FusedJoinAgg") >= 2, desc


def test_optimized_train_step_matches_unoptimized():
    X, Y, W1, W2 = _data()
    data, params = _rels(DIMS, X, Y, W1, W2)
    histories = []
    for optimize in (False, True):
        eng = Engine(executor="jit", optimize=optimize,
                     axis_sizes={"sites": 2})
        trainer = TraTrainer(eng,
                             ffnn_train_step_tra(*DIMS,
                                                 optimizer=Momentum(0.05)),
                             params=params)
        histories.append(trainer.fit(5, **data))
    np.testing.assert_allclose(histories[0], histories[1],
                               rtol=1e-5, atol=1e-4)


# ==========================================================================
# Named multi-root programs, scalar relations, state threading
# ==========================================================================

def test_engine_dict_programs_return_named_outputs():
    a = tra.input("A", (2, 2), (4, 4))
    b = tra.input("B", (2, 2), (4, 4))
    eng = Engine(executor="jit", optimize=False)
    RA = TensorRelation(jax.random.normal(jax.random.PRNGKey(0),
                                          (2, 2, 4, 4)),
                        RelType((2, 2), (4, 4)))
    RB = TensorRelation(jax.random.normal(jax.random.PRNGKey(1),
                                          (2, 2, 4, 4)),
                        RelType((2, 2), (4, 4)))
    outs = eng.run({"sum": a + b, "prod": a * b}, A=RA, B=RB)
    assert sorted(outs) == ["prod", "sum"]
    np.testing.assert_allclose(np.asarray(outs["sum"].data),
                               np.asarray(RA.data + RB.data), atol=1e-6)
    np.testing.assert_allclose(np.asarray(outs["prod"].data),
                               np.asarray(RA.data * RB.data), atol=1e-6)
    # a tuple compile of the same roots is a distinct artifact (different
    # run() contract), but a repeated dict compile hits the cache
    eng.run({"sum": a + b, "prod": a * b}, A=RA, B=RB)
    assert eng.cache_hits == 1


def test_scale_by_applies_scalar_relation():
    m = tra.input("M", (2, 3), (4, 4))
    s = tra.scalar_input("eta")
    e = m.scale_by(s)
    RM = TensorRelation(jax.random.normal(jax.random.PRNGKey(0),
                                          (2, 3, 4, 4)),
                        RelType((2, 3), (4, 4)))
    RS = TensorRelation(jnp.full((1, 1, 1), 2.5), RelType((1,), (1, 1)))
    out = Engine(executor="jit", optimize=False).run(e, M=RM, eta=RS)
    assert out.rtype.key_shape == (2, 3)
    np.testing.assert_allclose(np.asarray(out.data),
                               np.asarray(RM.data) * 2.5, atol=1e-6)
    with pytest.raises(ExprTypeError, match="scalar relation"):
        m.scale_by(tra.input("bad", (2,), (4, 4)))


@pytest.mark.parametrize("bound", [(5,), (4, 4), (2, 3, 4)])
def test_scale_by_any_block_rank(bound):
    """scaleBy must not grow block rank: rank-1 and rank-3 relations
    scale like rank-2 ones."""
    v = tra.input("v", (3,), bound)
    e = v.scale_by(tra.scalar(2.0))
    RV = TensorRelation(
        jax.random.normal(jax.random.PRNGKey(9), (3,) + bound),
        RelType((3,), bound))
    out = Engine(executor="jit", optimize=False).run(e, v=RV)
    assert out.rtype.bound == bound
    np.testing.assert_allclose(np.asarray(out.data),
                               np.asarray(RV.data) * 2.0, atol=1e-6)


def test_adamw_state_threads_by_name():
    """The AdamW step-count relation advances 0 → n and the moment
    relations change — state-out really is rethreaded as state-in."""
    X, Y, W1, W2 = _data()
    data, params = _rels(DIMS, X, Y, W1, W2)
    trainer = TraTrainer(Engine(executor="jit", optimize=False),
                         ffnn_train_step_tra(*DIMS, optimizer=AdamW(1e-2)),
                         params=params)
    assert float(trainer.state[STEP_STATE].data[0, 0, 0]) == 0.0
    trainer.fit(3, **data)
    assert float(trainer.state[STEP_STATE].data[0, 0, 0]) == 3.0
    assert sorted(trainer.state) == sorted(
        [STEP_STATE, "W1.m", "W1.v", "W2.m", "W2.v"])
    assert float(jnp.max(jnp.abs(trainer.state["W1.m"].data))) > 0.0


def test_make_train_step_error_paths():
    m = tra.input("M", (2, 2), (4, 4))
    loss = m.map("sigmoid")
    with pytest.raises(ExprTypeError, match="do not occur"):
        make_train_step(loss, ["Q"], SGD(0.1))
    with pytest.raises(ExprTypeError, match="collides"):
        make_train_step(tra.input(LOSS_ROOT, (2, 2), (4, 4)).map("relu"),
                        [LOSS_ROOT], SGD(0.1))
    # derived (non-input) Expr in params must be diagnosable
    with pytest.raises(ExprTypeError, match="input names or input Exprs"):
        make_train_step(loss, [m.map("relu")], SGD(0.1))
    # a parameter named like an optimizer-state root must not silently
    # overwrite the state program
    w = tra.input("W", (2, 2), (4, 4))
    wm = tra.input("W.m", (2, 2), (4, 4))
    with pytest.raises(ExprTypeError, match="collide"):
        make_train_step((w + wm).map("sigmoid"), ["W", "W.m"],
                        Momentum(0.1))


def test_generic_train_step_on_custom_loss():
    """make_train_step works on arbitrary differentiable exprs, not just
    the §5.3 program: ridge-style ‖X@W − Y‖² via TRA ops."""
    x = tra.input("X", (2, 2), (8, 4))
    w = tra.input("W", (2, 2), (4, 4))
    y = tra.input("Yd", (2, 2), (8, 4))
    resid = (x @ w) - y
    loss = (resid * resid).agg((0, 1), "matAdd").map("rowSum").sum(0)
    step = make_train_step(loss, ["W"], SGD(0.01))
    Xd = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    Wd = jax.random.normal(jax.random.PRNGKey(1), (8, 8)) * 0.1
    Yd = jax.random.normal(jax.random.PRNGKey(2), (16, 8))
    trainer = TraTrainer(Engine(executor="jit"), step,
                         params={"W": from_tensor(Wd, (4, 4))})
    losses = trainer.fit(20, X=from_tensor(Xd, (8, 4)),
                         Yd=from_tensor(Yd, (8, 4)))
    assert losses[-1] < 0.5 * losses[0], losses

    def dense(W):
        return jnp.sum((Xd @ W - Yd) ** 2)

    W = Wd
    for _ in range(20):
        W = W - 0.01 * jax.grad(dense)(W)
    np.testing.assert_allclose(np.asarray(to_tensor(trainer.params["W"])),
                               np.asarray(W), atol=1e-4, rtol=1e-4)
