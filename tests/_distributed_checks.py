"""Distributed-executor checks run in a subprocess with 8 host devices.

Invoked by tests/test_distributed_exec.py (which asserts exit code 0) so
that the main pytest process keeps the default single-device view, per the
project rule that only the dry-run (and these isolated checks) fake a
device count.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import (Placement, RelType, TraAgg, TraInput, TraJoin,  # noqa: E402
                        compile_tra, from_tensor, get_kernel, jit_ia_plan,
                        optimize, to_tensor)
from repro.core.shardmap_exec import execute_shardmap  # noqa: E402
from repro.core.interp import evaluate_ia  # noqa: E402


from repro.launch.mesh import make_mesh  # noqa: E402


def mesh1d():
    return make_mesh((8,), ("sites",))


def mesh2d():
    return make_mesh((4, 2), ("s0", "s1"))


def matmul_plan(fl, fr, bl, br):
    ta = TraInput("A", RelType(fl, bl))
    tb = TraInput("B", RelType(fr, br))
    return TraAgg(TraJoin(ta, tb, (1,), (0,), get_kernel("matMul")),
                  (0, 2), get_kernel("matAdd"))


def check_shardmap_strategies():
    mesh = mesh1d()
    A = jax.random.normal(jax.random.PRNGKey(0), (32, 64), jnp.float32)
    B = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32)
    RA, RB = from_tensor(A, (4, 8)), from_tensor(B, (8, 4))
    plan = matmul_plan((8, 8), (8, 8), (4, 8), (8, 4))
    S = ("sites",)
    for name, places in [
        ("BMM", {"A": Placement.replicated(),
                 "B": Placement.partitioned((0,), S)}),
        ("CPMM", {"A": Placement.partitioned((1,), S),
                  "B": Placement.partitioned((0,), S)}),
        ("rows", {"A": Placement.partitioned((0,), S),
                  "B": Placement.partitioned((0,), S)}),
    ]:
        r = optimize(plan, places, S, {"sites": 8})
        out = execute_shardmap(r.plan, {"A": RA, "B": RB}, mesh)
        np.testing.assert_allclose(np.asarray(to_tensor(out)),
                                   np.asarray(A @ B), rtol=2e-4, atol=2e-4)
        # Table-1 default plan must agree too
        ia = compile_tra(plan, places)
        out2 = execute_shardmap(ia, {"A": RA, "B": RB}, mesh)
        np.testing.assert_allclose(np.asarray(to_tensor(out2)),
                                   np.asarray(A @ B), rtol=2e-4, atol=2e-4)
        print(f"  shard_map {name}: OK (cost {r.cost})")


def check_rmm_2d_mesh():
    mesh = mesh2d()
    A = jax.random.normal(jax.random.PRNGKey(2), (32, 64), jnp.float32)
    B = jax.random.normal(jax.random.PRNGKey(3), (64, 32), jnp.float32)
    RA, RB = from_tensor(A, (4, 8)), from_tensor(B, (8, 4))
    plan = matmul_plan((8, 8), (8, 8), (4, 8), (8, 4))
    places = {"A": Placement.partitioned((0,), ("s0",)),
              "B": Placement.partitioned((1,), ("s1",))}
    r = optimize(plan, places, ("s0", "s1"), {"s0": 4, "s1": 2})
    out = execute_shardmap(r.plan, {"A": RA, "B": RB}, mesh)
    np.testing.assert_allclose(np.asarray(to_tensor(out)),
                               np.asarray(A @ B), rtol=2e-4, atol=2e-4)
    print(f"  shard_map RMM 2-D mesh: OK (cost {r.cost})")


def check_gspmd_matches_shardmap():
    mesh = mesh1d()
    A = jax.random.normal(jax.random.PRNGKey(4), (32, 64), jnp.float32)
    B = jax.random.normal(jax.random.PRNGKey(5), (64, 32), jnp.float32)
    RA, RB = from_tensor(A, (4, 8)), from_tensor(B, (8, 4))
    plan = matmul_plan((8, 8), (8, 8), (4, 8), (8, 4))
    S = ("sites",)
    places = {"A": Placement.partitioned((1,), S),
              "B": Placement.partitioned((0,), S)}
    r = optimize(plan, places, S, {"sites": 8})
    fn, names = jit_ia_plan(r.plan, mesh)
    got = fn(RA.data, RB.data)
    want = execute_shardmap(r.plan, {"A": RA, "B": RB}, mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want.data),
                               rtol=2e-4, atol=2e-4)
    # the compiled GSPMD module must actually contain collectives
    txt = fn.lower(jax.ShapeDtypeStruct((8, 8, 4, 8), jnp.float32),
                   jax.ShapeDtypeStruct((8, 8, 8, 4), jnp.float32)) \
        .compile().as_text()
    assert any(k in txt for k in
               ("all-to-all", "all-reduce", "all-gather", "reduce-scatter",
                "collective-permute")), "no collectives in compiled HLO"
    print("  GSPMD == shard_map, collectives present: OK")


def check_two_phase_agg_is_reduce_scatter():
    """The R2-5 two-phase plan must lower to psum_scatter (reduce-scatter)
    in shard_map mode and produce correct sums."""
    mesh = mesh1d()
    # contraction-heavy shapes so the partial aggregation strictly wins
    A = jax.random.normal(jax.random.PRNGKey(6), (8, 128), jnp.float32)
    B = jax.random.normal(jax.random.PRNGKey(7), (128, 8), jnp.float32)
    RA, RB = from_tensor(A, (4, 8)), from_tensor(B, (8, 4))
    plan = matmul_plan((2, 16), (16, 2), (4, 8), (8, 4))
    S = ("sites",)
    places = {"A": Placement.partitioned((1,), S),
              "B": Placement.partitioned((0,), S)}
    from repro.core import describe
    r = optimize(plan, places, S, {"sites": 8})
    assert "partial" in describe(r.plan), describe(r.plan)
    out = execute_shardmap(r.plan, {"A": RA, "B": RB}, mesh)
    np.testing.assert_allclose(np.asarray(to_tensor(out)),
                               np.asarray(A @ B), rtol=2e-4, atol=2e-4)
    print("  two-phase aggregation (reduce-scatter) OK")


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.device_count()
    check_shardmap_strategies()
    check_rmm_2d_mesh()
    check_gspmd_matches_shardmap()
    check_two_phase_agg_is_reduce_scatter()
    print("ALL DISTRIBUTED CHECKS PASSED")
