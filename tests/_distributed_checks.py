"""Distributed-executor checks run in a subprocess with 8 host devices.

Invoked by tests/test_distributed_exec.py (which asserts exit code 0) so
that the main pytest process keeps the default single-device view, per the
project rule that only the dry-run (and these isolated checks) fake a
device count.

Everything routes through the unified :class:`repro.core.Engine` — the
same ``Expr`` runs on the shard_map and GSPMD executors and is compared
against the single-device reference engine.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.core as tra  # noqa: E402
from repro.core import (Engine, IAInput, LocalAgg, LocalJoin, Placement,  # noqa: E402
                        RelType, Shuf, from_tensor, fuse_join_agg,
                        get_kernel, to_tensor)
from repro.launch.mesh import make_mesh  # noqa: E402


def mesh1d():
    return make_mesh((8,), ("sites",))


def mesh2d():
    return make_mesh((4, 2), ("s0", "s1"))


def matmul_expr(fl, fr, bl, br):
    a = tra.input("A", fl, bl)
    b = tra.input("B", fr, br)
    return a @ b


def check_shardmap_strategies():
    mesh = mesh1d()
    A = jax.random.normal(jax.random.PRNGKey(0), (32, 64), jnp.float32)
    B = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32)
    RA, RB = from_tensor(A, (4, 8)), from_tensor(B, (8, 4))
    expr = matmul_expr((8, 8), (8, 8), (4, 8), (8, 4))
    S = ("sites",)
    for name, places in [
        ("BMM", {"A": Placement.replicated(),
                 "B": Placement.partitioned((0,), S)}),
        ("CPMM", {"A": Placement.partitioned((1,), S),
                  "B": Placement.partitioned((0,), S)}),
        ("rows", {"A": Placement.partitioned((0,), S),
                  "B": Placement.partitioned((0,), S)}),
    ]:
        eng = Engine(mesh, executor="shard_map", input_placements=places)
        compiled = eng.compile(expr)
        out = compiled.run(A=RA, B=RB)
        np.testing.assert_allclose(np.asarray(to_tensor(out)),
                                   np.asarray(A @ B), rtol=2e-4, atol=2e-4)
        # Table-1 default plan must agree too
        out2 = Engine(mesh, executor="shard_map", optimize=False,
                      input_placements=places).run(expr, A=RA, B=RB)
        np.testing.assert_allclose(np.asarray(to_tensor(out2)),
                                   np.asarray(A @ B), rtol=2e-4, atol=2e-4)
        print(f"  shard_map {name}: OK (cost {compiled.cost})")


def check_rmm_2d_mesh():
    mesh = mesh2d()
    A = jax.random.normal(jax.random.PRNGKey(2), (32, 64), jnp.float32)
    B = jax.random.normal(jax.random.PRNGKey(3), (64, 32), jnp.float32)
    RA, RB = from_tensor(A, (4, 8)), from_tensor(B, (8, 4))
    expr = matmul_expr((8, 8), (8, 8), (4, 8), (8, 4))
    places = {"A": Placement.partitioned((0,), ("s0",)),
              "B": Placement.partitioned((1,), ("s1",))}
    eng = Engine(mesh, executor="shard_map", input_placements=places)
    compiled = eng.compile(expr)
    out = compiled.run(A=RA, B=RB)
    np.testing.assert_allclose(np.asarray(to_tensor(out)),
                               np.asarray(A @ B), rtol=2e-4, atol=2e-4)
    print(f"  shard_map RMM 2-D mesh: OK (cost {compiled.cost})")


def check_gspmd_matches_shardmap():
    mesh = mesh1d()
    A = jax.random.normal(jax.random.PRNGKey(4), (32, 64), jnp.float32)
    B = jax.random.normal(jax.random.PRNGKey(5), (64, 32), jnp.float32)
    RA, RB = from_tensor(A, (4, 8)), from_tensor(B, (8, 4))
    expr = matmul_expr((8, 8), (8, 8), (4, 8), (8, 4))
    places = {"A": Placement.partitioned((1,), ("sites",)),
              "B": Placement.partitioned((0,), ("sites",))}
    gspmd = Engine(mesh, executor="gspmd", input_placements=places)
    compiled = gspmd.compile(expr)
    got = compiled.run(A=RA, B=RB)
    want = Engine(mesh, executor="shard_map",
                  input_placements=places).run(expr, A=RA, B=RB)
    np.testing.assert_allclose(np.asarray(got.data), np.asarray(want.data),
                               rtol=2e-4, atol=2e-4)
    # the compiled GSPMD module must actually contain collectives
    sds = {"A": jax.ShapeDtypeStruct((8, 8, 4, 8), jnp.float32),
           "B": jax.ShapeDtypeStruct((8, 8, 8, 4), jnp.float32)}
    txt = compiled.jitted.lower(
        *(sds[n] for n in compiled.input_names)).compile().as_text()
    assert any(k in txt for k in
               ("all-to-all", "all-reduce", "all-gather", "reduce-scatter",
                "collective-permute")), "no collectives in compiled HLO"
    # engine compile cache: same structural expression → same artifact
    assert gspmd.compile(matmul_expr((8, 8), (8, 8), (4, 8), (8, 4))) \
        is compiled and gspmd.cache_hits == 1
    print("  GSPMD == shard_map, collectives present, cache hit: OK")


def check_two_phase_agg_is_reduce_scatter():
    """The R2-5 two-phase plan must lower to psum_scatter (reduce-scatter)
    in shard_map mode and produce correct sums."""
    mesh = mesh1d()
    # contraction-heavy shapes so the partial aggregation strictly wins
    A = jax.random.normal(jax.random.PRNGKey(6), (8, 128), jnp.float32)
    B = jax.random.normal(jax.random.PRNGKey(7), (128, 8), jnp.float32)
    RA, RB = from_tensor(A, (4, 8)), from_tensor(B, (8, 4))
    expr = matmul_expr((2, 16), (16, 2), (4, 8), (8, 4))
    places = {"A": Placement.partitioned((1,), ("sites",)),
              "B": Placement.partitioned((0,), ("sites",))}
    compiled = Engine(mesh, executor="shard_map",
                      input_placements=places).compile(expr)
    assert "partial" in compiled.describe(), compiled.describe()
    out = compiled.run(A=RA, B=RB)
    np.testing.assert_allclose(np.asarray(to_tensor(out)),
                               np.asarray(A @ B), rtol=2e-4, atol=2e-4)
    print("  two-phase aggregation (reduce-scatter) OK")


def check_two_phase_other_reducers():
    """Two-phase (partial + SHUF/BCAST) plans for the non-additive
    reducers must run in shard_map mode via the psum-equivalents
    (pmax/pmin, gather+fold for products) — parametrized over kernels."""
    mesh = mesh1d()
    S = ("sites",)
    fa, fb = (8, 16), (16, 8)
    ba = bb = (4, 4)
    A = jax.random.uniform(jax.random.PRNGKey(8),
                           (fa[0] * ba[0], fa[1] * ba[1]), jnp.float32,
                           0.5, 1.5)
    B = jax.random.uniform(jax.random.PRNGKey(9),
                           (fb[0] * bb[0], fb[1] * bb[1]), jnp.float32,
                           0.5, 1.5)
    RA, RB = from_tensor(A, ba), from_tensor(B, bb)
    places = {"A": Placement.partitioned((1,), S),
              "B": Placement.partitioned((0,), S)}
    ref_eng = Engine(executor="reference", optimize=False)

    for agg_name in ("elemMax", "elemMin", "elemMul"):
        a = tra.input("A", fa, ba)
        b = tra.input("B", fb, bb)
        expr = a.join(b, on=((1,), (0,)), kernel="elemMul") \
                .agg((0, 2), agg_name)
        want = ref_eng.run(expr, A=RA, B=RB)

        # hand-built two-phase plan: co-partitioned local join, partial
        # local agg (pending duplicates over the contraction axis), SHUF
        ia = IAInput("A", RelType(fa, ba), places["A"])
        ib = IAInput("B", RelType(fb, bb), places["B"])
        j = LocalJoin(ia, ib, (1,), (0,), get_kernel("elemMul"))
        partial = LocalAgg(j, (0, 2), get_kernel(agg_name), partial=True)
        plan = Shuf(partial, (0,), S)
        sm = Engine(mesh, executor="shard_map")
        got = sm.run(plan, A=RA, B=RB)
        np.testing.assert_allclose(np.asarray(got.data),
                                   np.asarray(want.data),
                                   rtol=2e-4, atol=2e-4)

        # the fuse rewrite must now offer the two-phase fused form for
        # non-additive reducers too, and it must execute identically
        unfused = LocalAgg(Shuf(j, (0,), S), (0, 2), get_kernel(agg_name))
        fused = fuse_join_agg(unfused)
        assert "FusedJoinAgg" in tra.describe(fused), tra.describe(fused)
        assert "[partial]" in tra.describe(fused), tra.describe(fused)
        got2 = sm.run(fused, A=RA, B=RB)
        np.testing.assert_allclose(np.asarray(got2.data),
                                   np.asarray(want.data),
                                   rtol=2e-4, atol=2e-4)
        print(f"  two-phase {agg_name} via psum-equivalent OK")


def check_multi_root_and_value_and_grad():
    """PR-3: multi-root compilation on gspmd/shard_map, and
    `Engine.value_and_grad` of the §5.3 FFNN forward matching a jax.grad
    dense oracle on both distributed executors at 8 devices."""
    from repro.core.programs import ffnn_step_tra

    mesh = mesh1d()
    S = ("sites",)
    nb, db, hb, lb = 8, 2, 2, 2
    bn, bd, bh, bl = 4, 4, 4, 2
    N, D, H, L = nb * bn, db * bd, hb * bh, lb * bl
    X = jax.random.normal(jax.random.PRNGKey(10), (N, D))
    W1 = jax.random.normal(jax.random.PRNGKey(11), (D, H)) * 0.3
    W2 = jax.random.normal(jax.random.PRNGKey(12), (H, L)) * 0.3
    env = dict(X=from_tensor(X, (bn, bd)), W1=from_tensor(W1, (bd, bh)),
               W2=from_tensor(W2, (bh, bl)))
    prog = ffnn_step_tra(nb, db, hb, lb, bn, bd, bh, bl)
    places = {"X": Placement.partitioned((0,), S),
              "W1": Placement.replicated(), "W2": Placement.replicated()}

    def loss(W1, W2):
        return jnp.sum(jax.nn.sigmoid(jax.nn.relu(X @ W1) @ W2))

    want_val = np.asarray(jax.nn.sigmoid(jax.nn.relu(X @ W1) @ W2))
    wg1, wg2 = jax.grad(loss, argnums=(0, 1))(W1, W2)
    for executor in ("gspmd", "shard_map"):
        eng = Engine(mesh, executor=executor, input_placements=places)
        vg = eng.value_and_grad(prog.a2, wrt=["W1", "W2"])
        assert "FusedJoinAgg" in vg.describe()
        val, g1, g2 = vg.run(**env)
        np.testing.assert_allclose(np.asarray(to_tensor(val)), want_val,
                                   atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(to_tensor(g1)),
                                   np.asarray(wg1), atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(to_tensor(g2)),
                                   np.asarray(wg2), atol=1e-5, rtol=1e-4)
        # the compile cache returns the SAME artifact (for shard_map this
        # means the built shard_map callable is reused across runs)
        assert eng.value_and_grad(prog.a2, wrt=["W1", "W2"]) is vg
        assert eng.cache_hits == 1, (executor, eng.cache_hits)
        print(f"  value_and_grad on {executor} (8 devices): OK")


def check_train_step_8dev():
    """PR-4: the §5.3 TRA train step (forward + BCE loss + autodiff
    backward + AdamW update as ONE named multi-root program) on both
    distributed executors at 8 devices, matching a dense AdamW oracle
    per step and hitting the compile cache from step 2 on."""
    from repro.core import AdamW, TraTrainer
    from repro.core.programs import ffnn_train_step_tra

    mesh = mesh1d()
    S = ("sites",)
    dims = (8, 2, 2, 2, 4, 4, 4, 2)
    nb, db, hb, lb, bn, bd, bh, bl = dims
    N, D, H, L = nb * bn, db * bd, hb * bh, lb * bl
    X = jax.random.normal(jax.random.PRNGKey(20), (N, D))
    Y = jax.nn.sigmoid(
        X @ (jax.random.normal(jax.random.PRNGKey(21), (D, L)) * 0.5))
    W1 = jax.random.normal(jax.random.PRNGKey(22), (D, H)) * 0.3
    W2 = jax.random.normal(jax.random.PRNGKey(23), (H, L)) * 0.3
    places = {"X": Placement.partitioned((0,), S),
              "Y": Placement.partitioned((0,), S),
              "W1": Placement.replicated(), "W2": Placement.replicated()}
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.999, 1e-8, 0.01

    def loss_fn(p):
        a2 = jax.nn.sigmoid(jax.nn.relu(X @ p["W1"]) @ p["W2"])
        a2c = jnp.clip(a2, 1e-7, 1 - 1e-7)
        return jnp.sum(-(Y * jnp.log(a2c) + (1 - Y) * jnp.log1p(-a2c)))

    for executor in ("gspmd", "shard_map"):
        step = ffnn_train_step_tra(
            *dims, optimizer=AdamW(lr, b1, b2, eps, weight_decay=wd))
        eng = Engine(mesh, executor=executor, input_placements=places)
        tr = TraTrainer(eng, step, params={"W1": from_tensor(W1, (bd, bh)),
                                           "W2": from_tensor(W2, (bh, bl))})
        p = {"W1": W1, "W2": W2}
        m = {k: jnp.zeros_like(v) for k, v in p.items()}
        v = {k: jnp.zeros_like(x) for k, x in p.items()}
        for t in range(1, 6):
            loss = tr.step(X=from_tensor(X, (bn, bd)),
                           Y=from_tensor(Y, (bn, bl)))
            want_loss, g = jax.value_and_grad(loss_fn)(p)
            for k in p:
                m[k] = b1 * m[k] + (1 - b1) * g[k]
                v[k] = b2 * v[k] + (1 - b2) * g[k] ** 2
                mh, vh = m[k] / (1 - b1 ** t), v[k] / (1 - b2 ** t)
                p[k] = p[k] - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p[k])
            np.testing.assert_allclose(loss, float(want_loss),
                                       rtol=1e-5, atol=1e-4)
            for k in p:
                np.testing.assert_allclose(
                    np.asarray(to_tensor(tr.params[k])), np.asarray(p[k]),
                    atol=1e-4, rtol=1e-4)
        assert eng.cache_hits == 4, eng.cache_hits  # steps 2-5 pure dispatch
        assert tr.history[-1] < tr.history[0]
        print(f"  TRA train step on {executor} (8 devices): OK")


def check_elastic_tra_resume_8dev():
    """ISSUE-6 tentpole: TraTrainer checkpoint → injected kill →
    auto-recovery, then a FRESH trainer restores onto a DIFFERENT mesh
    shape ((8,) → (4, 2)) and finishes; the full 8-step loss trajectory
    matches the uninterrupted single-device oracle at 1e-5.  Leaves are
    stored unsharded, so the new engine's input shardings re-place them
    on first dispatch — the elastic re-mesh path."""
    import tempfile

    from repro.checkpoint import CheckpointStore
    from repro.core import AdamW, TraTrainer
    from repro.core.faults import FaultInjector
    from repro.core.programs import ffnn_train_step_tra

    dims = (8, 2, 2, 2, 4, 4, 4, 2)
    nb, db, hb, lb, bn, bd, bh, bl = dims
    N, D, H, L = nb * bn, db * bd, hb * bh, lb * bl
    X = jax.random.normal(jax.random.PRNGKey(30), (N, D))
    Y = jax.nn.sigmoid(
        X @ (jax.random.normal(jax.random.PRNGKey(31), (D, L)) * 0.5))
    W1 = jax.random.normal(jax.random.PRNGKey(32), (D, H)) * 0.3
    W2 = jax.random.normal(jax.random.PRNGKey(33), (H, L)) * 0.3
    data = dict(X=from_tensor(X, (bn, bd)), Y=from_tensor(Y, (bn, bl)))

    def params():
        return {"W1": from_tensor(W1, (bd, bh)),
                "W2": from_tensor(W2, (bh, bl))}

    def trainer(engine, **kw):
        return TraTrainer(engine, ffnn_train_step_tra(
            *dims, optimizer=AdamW(1e-2)), params=params(), **kw)

    oracle = trainer(Engine(executor="jit", optimize=False)).fit(8, **data)

    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d, keep=5)
        places1 = {"X": Placement.partitioned((0,), ("sites",)),
                   "Y": Placement.partitioned((0,), ("sites",)),
                   "W1": Placement.replicated(),
                   "W2": Placement.replicated()}
        inj = FaultInjector().inject_site_failure(step=5)
        tr = trainer(Engine(mesh1d(), executor="gspmd",
                            input_placements=places1, fault_injector=inj),
                     store=store)
        h = tr.fit(6, ckpt_every=2, **data)
        assert inj.log == [("site", "run 5")], inj.log
        assert tr.step_count == 6
        np.testing.assert_allclose(h, oracle[:6], atol=1e-5)

        # fresh trainer, DIFFERENT mesh shape: (8,) → (4, 2)
        places2 = {"X": Placement.partitioned((0,), ("s0",)),
                   "Y": Placement.partitioned((0,), ("s0",)),
                   "W1": Placement.replicated(),
                   "W2": Placement.replicated()}
        tr2 = trainer(Engine(mesh2d(), executor="gspmd", site_axes=("s0",),
                             input_placements=places2), store=store)
        h2 = tr2.fit(8, resume=True, **data)
        assert tr2.step_count == 8
        np.testing.assert_allclose(h2, oracle, atol=1e-5)
    print("  elastic TRA checkpoint/resume across mesh shapes: OK")


def check_oocore_stream_gspmd_8dev():
    """ISSUE-8: stream a store-backed host relation through the GSPMD
    executor.  The chunk programs compile on the 8-device mesh with the
    streamed key dimension partitioned across sites, each chunk's slice
    is fetched from the host relation store on demand, and the result
    matches the single-device reference at 1e-5 with the H2D traffic
    accounted in the StreamStats ledger."""
    from repro.core import TensorRelation
    from repro.launch.metering import StreamStats
    from repro.store import RelationStore
    from repro.store.stream import StreamExecutor

    mesh = mesh1d()
    ka, ba, kb, bb = (64, 4), (4, 8), (4, 2), (8, 4)
    rng = np.random.default_rng(80)
    A = np.asarray(rng.normal(size=ka + ba), np.float32)
    B = np.asarray(rng.normal(size=kb + bb), np.float32)
    RA = TensorRelation(A, RelType(ka, ba))
    RB = TensorRelation(B, RelType(kb, bb))
    expr = matmul_expr(ka, kb, ba, bb)
    want = Engine(executor="reference", optimize=False).run(
        expr, A=RA, B=RB)

    places = {"A": Placement.partitioned((0,), ("sites",)),
              "B": Placement.replicated()}
    eng = Engine(mesh, executor="gspmd", input_placements=places)
    store = RelationStore()
    hrA = store.put("A", RA)            # split along the streamed dim 0
    se = StreamExecutor(eng, store=store, budget=1 << 30)
    # chunk_keys=8 → every chunk's streamed key length divides the mesh
    splan = se.plan(expr, force=True, chunk_keys=8)
    assert splan.mode == "stream-out" and splan.dim == 0, splan
    assert splan.nchunks == 8, splan.nchunks
    stats = StreamStats(mode=splan.mode, budget_bytes=splan.budget)
    got = se.execute(splan, {"A": hrA, "B": RB}, stats)
    np.testing.assert_allclose(np.asarray(got.data), np.asarray(want.data),
                               atol=1e-5, rtol=1e-5)
    assert stats.chunks == 8 and stats.h2d_bytes >= A.nbytes, stats.as_dict()
    # the per-chunk programs really went through the GSPMD compile path
    assert eng.cache_misses >= 1 and eng.cache_info()
    print("  out-of-core stream through GSPMD (8 devices): OK")


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.device_count()
    check_shardmap_strategies()
    check_rmm_2d_mesh()
    check_gspmd_matches_shardmap()
    check_two_phase_agg_is_reduce_scatter()
    check_two_phase_other_reducers()
    check_multi_root_and_value_and_grad()
    check_train_step_8dev()
    check_elastic_tra_resume_8dev()
    check_oocore_stream_gspmd_8dev()
    print("ALL DISTRIBUTED CHECKS PASSED")
