"""Unit tests for the training substrates: optimizer, data, checkpoint."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, DataLoader, make_batch
from repro.optim import (AdamWConfig, adamw, compress, decompress,
                         init_residuals)
from repro.optim.schedule import (constant, inverse_sqrt,
                                  linear_warmup_cosine)


# ------------------------------------------------------------- optimizer
def _np_adamw_step(w, m, v, g, t, lr, b1, b2, eps, wd):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    w = w - lr * (mh / (np.sqrt(vh) + eps) + wd * w)
    return w, m, v


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=0.01, b1=0.9, b2=0.99, eps=1e-8,
                      weight_decay=0.1, grad_clip=0.0)
    params = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    state = adamw.init(params)
    w = np.asarray(params["w"], np.float64)
    m = np.zeros_like(w)
    v = np.zeros_like(w)
    for t in range(1, 6):
        g = np.full_like(w, 0.3) * t
        state, new_params, _ = adamw.apply(state, {"w": jnp.asarray(g)},
                                           cfg)
        w, m, v = _np_adamw_step(w, m, v, g, t, cfg.lr, cfg.b1, cfg.b2,
                                 cfg.eps, cfg.weight_decay)
        np.testing.assert_allclose(np.asarray(state["master"]["w"]), w,
                                   rtol=1e-5, atol=1e-6)


def test_adamw_no_decay_on_scales():
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0, grad_clip=0.0)
    params = {"ln": {"scale": jnp.ones((4,))}, "w": jnp.ones((4, 4))}
    state = adamw.init(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    state, _, _ = adamw.apply(state, zero_g, cfg)
    # scale has no decay → unchanged under zero grads; w decays
    np.testing.assert_allclose(np.asarray(state["master"]["ln"]["scale"]),
                               np.ones(4))
    assert float(jnp.max(state["master"]["w"])) < 1.0


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=1.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    state = adamw.init(params)
    for _ in range(300):
        g = {"x": 2 * (state["master"]["x"] - target)}
        state, _, _ = adamw.apply(state, g, cfg)
    np.testing.assert_allclose(np.asarray(state["master"]["x"]),
                               np.asarray(target), atol=1e-2)


def test_global_norm_clip():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - np.sqrt(90 + 160)) < 1e-4
    cn = adamw.global_norm(clipped)
    assert abs(float(cn) - 1.0) < 1e-5


def test_schedules():
    steps = jnp.arange(0, 100)
    w = linear_warmup_cosine(steps, warmup=10, total=100)
    assert float(w[0]) == 0.0
    assert abs(float(w[10]) - 1.0) < 0.01
    assert float(w[99]) < 0.2
    assert float(constant(steps)[50]) == 1.0
    inv = inverse_sqrt(steps, warmup=16)
    assert abs(float(inv[16]) - 1.0) < 0.01
    assert float(inv[64]) == pytest.approx(0.5, rel=0.01)


def test_compression_error_feedback():
    """EF: cumulative compressed sum tracks the exact sum."""
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (256,)) * 1e-3}
    res = init_residuals(g)
    total_c = jnp.zeros((256,))
    total = jnp.zeros((256,))
    for i in range(50):
        gi = {"w": g["w"] * (1 + 0.01 * i)}
        comp, res = compress(gi, res)
        total_c = total_c + decompress(comp)["w"]
        total = total + gi["w"]
    # without EF, bf16 rounding of 1e-3 values drifts ~1e-5·50; with EF the
    # running sum stays within one bf16 ulp of the true sum
    assert float(jnp.max(jnp.abs(total_c - total))) < 2e-5


# ------------------------------------------------------------------ data
def test_data_determinism_and_resume():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8, seed=3)
    a = [next(DataLoader(cfg, start_step=i)) for i in range(3)]
    loader = DataLoader(cfg)
    b = [next(loader) for _ in range(3)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    # resume from state_dict
    state = loader.state_dict()
    l2 = DataLoader(cfg)
    l2.load_state_dict(state)
    np.testing.assert_array_equal(next(loader)["tokens"],
                                  next(l2)["tokens"])


def test_data_host_slicing_consistent():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=16, seed=1)
    full = make_batch(cfg, 5)
    lo_hi = [(0, 8), (8, 16)]
    parts = [make_batch(cfg, 5, hs) for hs in lo_hi]
    # each host's rows must be internally deterministic...
    again = [make_batch(cfg, 5, hs) for hs in lo_hi]
    for p, q in zip(parts, again):
        np.testing.assert_array_equal(p["tokens"], q["tokens"])
    # ...and labels must be next-token shifted everywhere
    assert full["tokens"].shape == (16, 8)
    for p in parts:
        assert p["tokens"].shape == (8, 8)


def test_data_embeddings_mode():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=4, seed=1,
                     input_mode="embeddings", d_model=16)
    b = make_batch(cfg, 0)
    assert b["embeds"].shape == (4, 8, 16)
    assert b["labels"].shape == (4, 8)


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d, keep=2)
        for step in (1, 2, 3):
            store.save(step, jax.tree.map(lambda x: x * step, tree),
                       extra={"data_step": step * 10})
        assert store.committed_steps() == [2, 3]     # GC keeps 2
        out, extra = store.restore(tree)
        assert extra["data_step"] == 30
        np.testing.assert_allclose(np.asarray(out["a"], np.float32),
                                   np.asarray(tree["a"]) * 3)


def test_checkpoint_crash_mid_save_is_invisible():
    tree = {"a": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(1, tree)
        # simulate a crash: a stale tmp dir and an uncommitted step dir
        os.makedirs(os.path.join(d, "step_000000005.tmp"))
        os.makedirs(os.path.join(d, "step_000000007"))
        assert store.latest_step() == 1
        out, _ = store.restore(tree)
        np.testing.assert_allclose(np.asarray(out["a"]), 1.0)


def test_checkpoint_async():
    tree = {"a": jnp.full((1000,), 7.0)}
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save_async(4, tree)
        store.wait()
        out, _ = store.restore(tree)
        np.testing.assert_allclose(np.asarray(out["a"]), 7.0)


def test_checkpoint_structure_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(1, {"a": jnp.ones(2)})
        with pytest.raises(ValueError):
            store.restore({"different": jnp.ones(2)})
