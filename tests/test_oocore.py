"""Out-of-core streaming execution (the ISSUE-8 tentpole).

Property and integration coverage of ``repro.store.StreamExecutor`` and
the spill-aware ``Engine(memory_budget=...)`` mode:

* chunk-size sweeps: the force-planned streaming schedules (stream-out
  and stream-reduce) match every executor's resident result at 1e-5 for
  every ``chunk_keys``;
* masked relations refuse the streaming fast path and fall back to the
  resident executors (whose mask rules already hold);
* an over-budget fused contraction AND a chained two-matmul plan with
  operands ≥4× the budget complete through the store with the metered
  peak device live set under the budget (zero whole-intermediate
  rematerialization) and bit-compatible results;
* fault injection over store-backed runs: the byte-accurate
  ``inject_oom(ok_bytes=...)`` model OOMs the resident path and the
  ``degrade=True`` ladder recovers on its *first* rung — out-of-core
  streaming — without shrinking the fused chunk; a ``SimulatedFailure``
  killing a run mid-stream leaves the store consistent for a clean
  retry.
"""
import warnings

import numpy as np
import pytest

import repro.core as tra
from repro.core import Engine, RelType, TensorRelation, from_tensor
from repro.core.faults import FaultInjector, SimulatedFailure
from repro.core.plan import as_node
from repro.launch.metering import StreamStats
from repro.store import NotStreamable, RelationStore, StreamExecutor
from repro.store.autotune import ENV_BUDGET

S = ("sites",)


def _mesh1():
    from repro.launch.mesh import make_mesh
    return make_mesh((1,), S)


def _rel(seed, key_shape, bound, masked=False):
    rng = np.random.default_rng(seed)
    data = np.asarray(rng.normal(size=tuple(key_shape) + tuple(bound)),
                      np.float32)
    mask = None
    if masked:
        mask = np.ones(key_shape, bool)
        mask[tuple(0 for _ in key_shape)] = False
    return TensorRelation(data, RelType(tuple(key_shape), tuple(bound)),
                          mask)


def _matmul_expr(ka=(8, 2), kb=(2, 3), ba=(8, 8), bb=None):
    a = tra.input("A", key_shape=ka, bound=ba)
    b = tra.input("B", key_shape=kb, bound=bb or (ba[1], ba[0]))
    return a @ b


ORACLE = Engine(executor="reference", optimize=False, fuse=False)


def _np(res):
    return res.to_numpy() if hasattr(res, "to_numpy") \
        else np.asarray(res.data)


# ==========================================================================
# Property sweep: chunk sizes × executors, streamed == resident at 1e-5
# ==========================================================================

@pytest.mark.parametrize("executor", ["reference", "jit", "gspmd",
                                      "shard_map"])
@pytest.mark.parametrize("chunk_keys", [1, 3, 8])
def test_stream_out_matches_every_executor(executor, chunk_keys):
    e = _matmul_expr()
    RA, RB = _rel(0, (8, 2), (8, 8)), _rel(1, (2, 3), (8, 8))
    mesh = _mesh1() if executor in ("gspmd", "shard_map") else None
    resident = Engine(mesh, executor=executor).run(e, A=RA, B=RB)
    # force-planned streaming through a host engine, every chunk size
    eng = Engine(executor="jit")
    se = StreamExecutor(eng, budget=1 << 30)
    sp = se.plan(e, force=True, chunk_keys=chunk_keys)
    assert sp.mode == "stream-out" and sp.chunk_keys == chunk_keys
    stats = StreamStats()
    got = se.execute(sp, {"A": RA, "B": RB}, stats)
    np.testing.assert_allclose(_np(got), _np(resident),
                               atol=1e-5, rtol=1e-5)
    assert stats.chunks == sp.nchunks == -(-8 // chunk_keys)


@pytest.mark.parametrize("chunk_keys", [1, 2, 4, 8])
def test_stream_reduce_matches_oracle(chunk_keys):
    # out key grid is 1×1 → no stream-out axis; the contracted join dim
    # (8 key blocks) streams through the associative Σ∘⋈ fold instead
    e = _matmul_expr(ka=(1, 8), kb=(8, 1))
    RA, RB = _rel(2, (1, 8), (8, 8)), _rel(3, (8, 1), (8, 8))
    want = ORACLE.run(e, A=RA, B=RB)
    se = StreamExecutor(Engine(executor="jit"), budget=1 << 30)
    sp = se.plan(e, force=True, chunk_keys=chunk_keys)
    assert sp.mode == "stream-reduce"
    stats = StreamStats()
    got = se.execute(sp, {"A": RA, "B": RB}, stats)
    np.testing.assert_allclose(_np(got), _np(want), atol=1e-4, rtol=1e-4)
    assert stats.mode == "stream-reduce"
    assert stats.chunks == -(-8 // chunk_keys)


@pytest.mark.parametrize("executor", ["reference", "jit"])
def test_masked_inputs_fall_back_resident(executor):
    # budget small enough that the unmasked plan WOULD stream — the
    # masked runtime value must force the resident path at execute time
    e = _matmul_expr(ka=(64, 2), kb=(2, 1), ba=(32, 16), bb=(16, 16))
    RA = _rel(4, (64, 2), (32, 16), masked=True)
    RB = _rel(5, (2, 1), (16, 16))
    want = ORACLE.run(e, A=RA, B=RB)
    eng = Engine(executor=executor, memory_budget=64 * 1024)
    if executor == "jit":
        # same contract as without a budget: staged executors reject
        # masked inputs — the budget must not smuggle them through
        with pytest.raises(NotImplementedError, match="mask"):
            eng.run(e, A=RA, B=RB)
        return
    got = eng.run(e, A=RA, B=RB)
    np.testing.assert_allclose(_np(got), _np(want), atol=1e-5, rtol=1e-5)
    # the artifact streamed nothing: masked values ran the resident path
    stats = [c.stream_stats for c in eng.cache_info() if c.stream_stats]
    assert stats and stats[0].mode == "resident"


def test_masked_plan_type_refuses_force_streaming():
    a = tra.input("A", key_shape=(8, 2), bound=(4, 4))
    e = a.filter(lambda k: k[0] < 6) @ tra.input("B", key_shape=(2, 2),
                                                 bound=(4, 4))
    se = StreamExecutor(Engine(executor="reference"), budget=1)
    with pytest.raises(NotStreamable, match="continuous"):
        se.plan(e, force=True)


# ==========================================================================
# Engine(memory_budget=...): over-budget plans stream, bounded live set
# ==========================================================================

def test_over_budget_contraction_streams_under_budget():
    # A is 8·32·16·4 B = 512 KiB ≥ 4× the 64 KiB budget
    e = _matmul_expr(ka=(64, 2), kb=(2, 1), ba=(32, 16), bb=(16, 16))
    RA, RB = _rel(6, (64, 2), (32, 16)), _rel(7, (2, 1), (16, 16))
    want = ORACLE.run(e, A=RA, B=RB)
    budget = 64 * 1024
    assert RA.data.nbytes >= 4 * budget
    eng = Engine(executor="jit", memory_budget=budget)
    got = eng.run(e, A=RA, B=RB)
    np.testing.assert_allclose(_np(got), _np(want), atol=1e-5, rtol=1e-5)
    stats = [c.stream_stats for c in eng.cache_info() if c.stream_stats]
    assert len(stats) == 1 and stats[0].mode == "stream-out"
    assert stats[0].chunks > 1
    assert 0 < stats[0].peak_device_bytes <= budget
    # second run of the same expression is a pure cache hit
    hits0 = eng.cache_hits
    eng.run(e, A=RA, B=RB)
    assert eng.cache_hits > hits0
    assert stats[0].runs == 2


def test_chained_two_matmul_zero_rematerialization():
    # (A·B)·C with A = 512 KiB ≥ 4× the 64 KiB budget: the intermediate
    # A·B (256 KiB) must never materialize whole on device either
    a = tra.input("A", key_shape=(64, 2), bound=(32, 16))
    b = tra.input("B", key_shape=(2, 2), bound=(16, 8))
    c = tra.input("C", key_shape=(2, 1), bound=(8, 8))
    e = (a @ b) @ c
    RA = _rel(8, (64, 2), (32, 16))
    RB = _rel(9, (2, 2), (16, 8))
    RC = _rel(10, (2, 1), (8, 8))
    want = ORACLE.run(e, A=RA, B=RB, C=RC)
    budget = 64 * 1024
    assert RA.data.nbytes >= 4 * budget
    eng = Engine(executor="jit", memory_budget=budget)
    got = eng.run(e, A=RA, B=RB, C=RC)
    np.testing.assert_allclose(_np(got), _np(want), atol=1e-4, rtol=1e-4)
    (stats,) = [s.stream_stats for s in eng.cache_info() if s.stream_stats]
    assert stats.mode == "stream-out" and stats.chunks > 1
    assert stats.peak_device_bytes <= budget


def test_store_backed_inputs_stream_with_h2d_accounting():
    e = _matmul_expr(ka=(64, 1), kb=(1, 1), ba=(32, 16), bb=(16, 16))
    RA, RB = _rel(11, (64, 1), (32, 16)), _rel(12, (1, 1), (16, 16))
    want = ORACLE.run(e, A=RA, B=RB)
    store = RelationStore()
    eng = Engine(executor="jit", memory_budget=64 * 1024, store=store)
    got = eng.run(e, A=store.put("A", RA), B=RB)
    np.testing.assert_allclose(_np(got), _np(want), atol=1e-5, rtol=1e-5)
    (stats,) = [s.stream_stats for s in eng.cache_info() if s.stream_stats]
    # every A chunk crossed host→device exactly once
    assert stats.h2d_bytes >= RA.data.nbytes


def test_under_budget_plan_runs_resident():
    e = _matmul_expr()
    RA, RB = _rel(0, (8, 2), (8, 8)), _rel(1, (2, 3), (8, 8))
    eng = Engine(executor="jit", memory_budget=1 << 30)
    got = eng.run(e, A=RA, B=RB)
    np.testing.assert_allclose(_np(got), _np(ORACLE.run(e, A=RA, B=RB)),
                               atol=1e-5, rtol=1e-5)
    stats = [s.stream_stats for s in eng.cache_info() if s.stream_stats]
    assert stats and stats[0].mode == "resident"


# ==========================================================================
# Fault injection over store-backed runs
# ==========================================================================

@pytest.mark.faults
def test_oom_ladder_recovers_via_store_streaming_first(monkeypatch):
    # byte-accurate device model: the resident contraction (~512 KiB
    # live) OOMs; streamed key-range chunks (≤ ~64 KiB live) fit.  The
    # env override pins rung 1's autotuned budget to 64 KiB.
    ok_bytes = 96 * 1024
    monkeypatch.setenv(ENV_BUDGET, str(4 * 64 * 1024))
    # reduce dim 4 → the optimizer selects the fused Σ∘⋈ contraction,
    # whose on_contraction hook enforces the injected byte budget
    e = _matmul_expr(ka=(64, 4), kb=(4, 1), ba=(32, 16), bb=(16, 16))
    RA, RB = _rel(13, (64, 4), (32, 16)), _rel(14, (4, 1), (16, 16))
    want = ORACLE.run(e, A=RA, B=RB)
    inj = FaultInjector().inject_oom(ok_bytes=ok_bytes)
    eng = Engine(executor="jit", fault_injector=inj, degrade=True)
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        got = eng.run(e, A=RA, B=RB)
    np.testing.assert_allclose(_np(got), _np(want), atol=1e-5, rtol=1e-5)
    msgs = [str(w.message) for w in wlog]
    assert any("host relation store" in m for m in msgs)
    # rung 1 sufficed — the halving-chunk ladder never started
    assert not any("halving" in m for m in msgs)
    ooms = [d for k, d in inj.log if k == "oom"]
    assert ooms and any("unstreamed" in d for d in ooms)
    # the degraded streamed artifact is cached under its own key, so the
    # resident artifact (which would OOM again) is never shadowed
    streamed = [c for c in eng.cache_info() if c.stream_stats]
    assert streamed and streamed[0].signature[0] == "streamed"


@pytest.mark.faults
def test_oom_without_degrade_propagates_through_budget_mode():
    from repro.core.faults import DeviceOOM
    e = _matmul_expr(ka=(8, 3), kb=(3, 5))
    RA, RB = _rel(0, (8, 3), (8, 8)), _rel(1, (3, 5), (8, 8))
    inj = FaultInjector().inject_oom(ok_bytes=1)
    eng = Engine(executor="jit", fault_injector=inj, degrade=False)
    with pytest.raises(DeviceOOM):
        eng.run(e, A=RA, B=RB)


@pytest.mark.faults
def test_kill_mid_stream_then_clean_retry():
    e = _matmul_expr(ka=(64, 2), kb=(2, 1), ba=(32, 16), bb=(16, 16))
    RA, RB = _rel(15, (64, 2), (32, 16)), _rel(16, (2, 1), (16, 16))
    want = ORACLE.run(e, A=RA, B=RB)
    # the second chunk program dispatch dies — mid-stream, after chunk 0
    # already ran (and possibly appended partial output to the store)
    inj = FaultInjector().inject_site_failure(step=1, times=1)
    eng = Engine(executor="jit", memory_budget=64 * 1024,
                 fault_injector=inj)
    with pytest.raises(SimulatedFailure):
        eng.run(e, A=RA, B=RB)
    (stats,) = [s.stream_stats for s in eng.cache_info() if s.stream_stats]
    assert 0 < stats.chunks < stats.runs + 64   # died partway
    # retry: the fault budget is spent; the store-backed rerun replaces
    # any partial output and completes bit-compatibly
    got = eng.run(e, A=RA, B=RB)
    np.testing.assert_allclose(_np(got), _np(want), atol=1e-5, rtol=1e-5)
    assert stats.runs == 2


@pytest.mark.faults
def test_spilling_store_still_streams_correctly(tmp_path):
    # host tier under pressure: the store spills blocks to disk while the
    # plan streams — results unchanged, spill counters surfaced
    e = _matmul_expr(ka=(64, 1), kb=(1, 1), ba=(32, 16), bb=(16, 16))
    RA, RB = _rel(17, (64, 1), (32, 16)), _rel(18, (1, 1), (16, 16))
    want = ORACLE.run(e, A=RA, B=RB)
    blk = 8 * 32 * 16 * 4
    store = RelationStore(ram_limit_bytes=2 * blk, spill_dir=str(tmp_path),
                          block_bytes=blk)
    eng = Engine(executor="jit", memory_budget=64 * 1024, store=store)
    got = eng.run(e, A=store.put("A", RA), B=RB)
    np.testing.assert_allclose(_np(got), _np(want), atol=1e-5, rtol=1e-5)
    (stats,) = [s.stream_stats for s in eng.cache_info() if s.stream_stats]
    assert stats.spill_events > 0
