"""Einstein-notation frontend (§2.3 expressivity) vs jnp.einsum."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import Placement, from_tensor, optimize, to_tensor
from repro.core.einsum_frontend import OperandSpec, einsum_tra

from conftest import (shim_evaluate_ia as evaluate_ia,
                      shim_evaluate_tra as evaluate_tra)

CASES = [
    # (spec, shapes, tiles)
    ("ij,jk->ik", [(8, 12), (12, 16)], [(4, 4), (4, 4)]),
    ("ij,jk,kl->il", [(8, 12), (12, 16), (16, 6)],
     [(4, 4), (4, 4), (4, 3)]),
    ("ij,jk->ki", [(8, 12), (12, 16)], [(4, 4), (4, 4)]),
    ("bij,bjk->bik", [(4, 8, 12), (4, 12, 8)], [(2, 4, 4), (2, 4, 4)]),
    ("ij->i", [(8, 12)], [(4, 4)]),
    ("ij,ij->ij", [(8, 12), (8, 12)], [(4, 4), (4, 4)]),
    ("ij,j->i", [(8, 12), (12,)], [(4, 4), (4,)]),
]


@pytest.mark.parametrize("spec,shapes,tiles", CASES)
def test_einsum_matches_jnp(spec, shapes, tiles):
    lhs = spec.replace(" ", "").split("->")[0].split(",")
    tensors = [jax.random.normal(jax.random.PRNGKey(i), s)
               for i, s in enumerate(shapes)]
    operands, env = [], {}
    for i, (idx, t, tile) in enumerate(zip(lhs, tensors, tiles)):
        name = f"T{i}"
        blocks = tuple(s // b for s, b in zip(t.shape, tile))
        operands.append(OperandSpec(name, idx, blocks, tuple(tile)))
        env[name] = from_tensor(t, tile)
    plan = einsum_tra(spec, operands)
    got = to_tensor(evaluate_tra(plan, env))
    want = jnp.einsum(spec, *tensors)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_einsum_distributed_plan():
    """The frontend's plan optimizes and evaluates like any TRA plan."""
    spec = "ij,jk->ik"
    A = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
    B = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    operands = {"ij": OperandSpec("A", "ij", (4, 8), (4, 4)),
                "jk": OperandSpec("B", "jk", (8, 4), (4, 4))}
    plan = einsum_tra(spec, operands)
    r = optimize(plan, {"A": Placement.partitioned((1,), ("sites",)),
                        "B": Placement.partitioned((0,), ("sites",))},
                 ("sites",), {"sites": 4})
    env = {"A": from_tensor(A, (4, 4)), "B": from_tensor(B, (4, 4))}
    got = to_tensor(evaluate_ia(r.plan, env))
    np.testing.assert_allclose(np.asarray(got), np.asarray(A @ B),
                               rtol=1e-4, atol=1e-4)
    assert r.cost > 0
