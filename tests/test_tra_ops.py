"""Property tests: dense jnp TRA executor ≡ dict-of-numpy reference."""
import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import RelType, TensorRelation, from_tensor, get_kernel, to_tensor
from repro.core import tra
from repro.core import reference as ref


def dense_from_dict(d, key_shape, bound, fill=0.0):
    data = np.full(tuple(key_shape) + tuple(bound), fill, np.float32)
    mask = np.zeros(key_shape, bool)
    for k, a in d.items():
        data[k] = a
        mask[k] = True
    if mask.all():
        mask = None
    return TensorRelation(jnp.asarray(data),
                          RelType(tuple(key_shape), tuple(bound)), mask)


def assert_rel_equal(dense_rel, ref_rel, rtol=1e-5):
    got = dense_rel.to_dict()
    assert set(got) == set(ref_rel), (sorted(got), sorted(ref_rel))
    for k in ref_rel:
        np.testing.assert_allclose(got[k], ref_rel[k], rtol=rtol, atol=1e-5)


# --------------------------------------------------------------------------
# strategies
# --------------------------------------------------------------------------

@st.composite
def rel_strategy(draw, key_arity=None, bound=None, continuous=False):
    k = key_arity if key_arity is not None else draw(st.integers(1, 3))
    key_shape = tuple(draw(st.integers(1, 3)) for _ in range(k))
    b = bound if bound is not None else tuple(
        draw(st.integers(1, 3)) for _ in range(draw(st.integers(1, 2))))
    n = int(np.prod(key_shape))
    if continuous:
        mask_flat = [True] * n
    else:
        mask_flat = draw(st.lists(st.booleans(), min_size=n, max_size=n))
        if not any(mask_flat):
            mask_flat[0] = True
    rng = np.random.RandomState(draw(st.integers(0, 2**31 - 1)))
    d = {}
    for i, keep in enumerate(mask_flat):
        if keep:
            key = np.unravel_index(i, key_shape)
            d[tuple(int(x) for x in key)] = \
                rng.randn(*b).astype(np.float32)
    return d, key_shape, b


@settings(max_examples=40, deadline=None)
@given(rel_strategy(bound=(2, 2)), st.data())
def test_transform_matches_reference(rel, data):
    d, ks, b = rel
    kname = data.draw(st.sampled_from(["relu", "sigmoid", "diag", "rowSum"]))
    kern = get_kernel(kname)
    dense = dense_from_dict(d, ks, b)
    assert_rel_equal(tra.transform(dense, kern), ref.transform(d, kern))


@settings(max_examples=40, deadline=None)
@given(rel_strategy(bound=(2, 2)), st.data())
def test_agg_matches_reference(rel, data):
    d, ks, b = rel
    k = len(ks)
    gb_size = data.draw(st.integers(0, k))
    gb = tuple(data.draw(
        st.permutations(range(k)))[:gb_size])
    kern = get_kernel(data.draw(st.sampled_from(["matAdd", "elemMax"])))
    dense = dense_from_dict(d, ks, b)
    assert_rel_equal(tra.agg(dense, gb, kern), ref.agg(d, gb, kern))


@settings(max_examples=40, deadline=None)
@given(rel_strategy(key_arity=2, bound=(2, 3), continuous=True),
       rel_strategy(key_arity=2, bound=(3, 2), continuous=True),
       st.data())
def test_join_matmul_matches_reference(rl, rr, data):
    dl, ksl, bl = rl
    dr, ksr, br = rr
    kern = get_kernel("matMul")
    jkl, jkr = (1,), (0,)
    dense = tra.join(dense_from_dict(dl, ksl, bl),
                     dense_from_dict(dr, ksr, br), jkl, jkr, kern)
    assert_rel_equal(dense, ref.join(dl, dr, jkl, jkr, kern), rtol=1e-4)


@settings(max_examples=40, deadline=None)
@given(rel_strategy(key_arity=2, bound=(2, 2)),
       rel_strategy(key_arity=2, bound=(2, 2)), st.data())
def test_join_elementwise_matches_reference(rl, rr, data):
    dl, ksl, b = rl
    dr, ksr, _ = rr
    kern = get_kernel(data.draw(st.sampled_from(["matAdd", "elemMul"])))
    n_join = data.draw(st.integers(1, 2))
    jkl = tuple(data.draw(st.permutations(range(2)))[:n_join])
    jkr = tuple(data.draw(st.permutations(range(2)))[:n_join])
    dense = tra.join(dense_from_dict(dl, ksl, b),
                     dense_from_dict(dr, ksr, b), jkl, jkr, kern)
    want = ref.join(dl, dr, jkl, jkr, kern)
    if not want:
        return  # dense rep cannot hold the empty relation; skip
    assert_rel_equal(dense, want)


@settings(max_examples=30, deadline=None)
@given(rel_strategy(key_arity=2, bound=(2, 2)), st.data())
def test_filter_matches_reference(rel, data):
    d, ks, b = rel
    thresh = data.draw(st.integers(0, max(ks) - 1))
    pred = lambda k: (k[0] + k[1]) % 2 == 0 or k[0] <= thresh
    if not any(pred(k) for k in d):
        return
    dense = dense_from_dict(d, ks, b)
    assert_rel_equal(tra.filt(dense, pred), ref.filt(d, pred))


@settings(max_examples=30, deadline=None)
@given(rel_strategy(key_arity=2, bound=(2, 2), continuous=True))
def test_rekey_flatten_matches_reference(rel):
    d, ks, b = rel
    fn = lambda k: (k[0] * ks[1] + k[1],)
    dense = dense_from_dict(d, ks, b)
    assert_rel_equal(tra.rekey(dense, fn), ref.rekey(d, fn))


@settings(max_examples=30, deadline=None)
@given(rel_strategy(key_arity=2, bound=(2, 4), continuous=True), st.data())
def test_tile_concat_roundtrip(rel, data):
    d, ks, b = rel
    dense = dense_from_dict(d, ks, b)
    size = data.draw(st.sampled_from([1, 2]))
    tiled = tra.tile(dense, 1, size)
    assert_rel_equal(tiled, ref.tile(d, 1, size))
    back = tra.concat(tiled, len(ks), 1)   # new key dim index == old arity
    assert_rel_equal(back, d)


def test_paper_running_example():
    """The paper's §2.1 worked example: A stored as 2x2 blocks."""
    A = jnp.asarray([[1, 2, 5, 6], [3, 4, 7, 8],
                     [9, 10, 13, 14], [11, 12, 15, 16]], jnp.float32)
    RA = from_tensor(A, (2, 2))
    # vertical sum: Σ_(<1>, matAdd)
    out = tra.agg(RA, (1,), get_kernel("matAdd"))
    np.testing.assert_allclose(out.to_dict()[(0,)],
                               [[10, 12], [14, 16]])
    np.testing.assert_allclose(out.to_dict()[(1,)],
                               [[18, 20], [22, 24]])
    # total sum: Σ_(<>, matAdd)
    total = tra.agg(RA, (), get_kernel("matAdd"))
    np.testing.assert_allclose(total.to_dict()[()], [[28, 32], [36, 40]])
    # matrix multiply A @ A
    j = tra.join(RA, RA, (1,), (0,), get_kernel("matMul"))
    np.testing.assert_allclose(
        j.to_dict()[(0, 1, 0)], [[111, 122], [151, 166]])
    mm = tra.agg(j, (0, 2), get_kernel("matAdd"))
    np.testing.assert_allclose(np.asarray(to_tensor(mm)),
                               np.asarray(A @ A))


def test_paper_tile_rekey_example():
    """Paper §2.1: Tile_(1,2)(R_B) then ReKey to a 1-D key."""
    B = jnp.asarray([[1, 2, 5, 6, 9, 10, 13, 14],
                     [3, 4, 7, 8, 11, 12, 15, 16]], jnp.float32)
    RB = from_tensor(B, (2, 4))        # keys <0>,<1> after squeezing dim 0
    RB = tra.rekey(RB, lambda k: (k[1],))
    tiled = tra.tile(RB, 1, 2)
    d = tiled.to_dict()
    np.testing.assert_allclose(d[(0, 0)], [[1, 2], [3, 4]])
    np.testing.assert_allclose(d[(1, 1)], [[13, 14], [15, 16]])
    rk = tra.rekey(tiled, lambda k: (2 * k[0] + k[1],))
    d2 = rk.to_dict()
    np.testing.assert_allclose(d2[(3,)], [[13, 14], [15, 16]])
    # Concat_(1,1)(Tile_(1,2)(R_B)) recovers R_B
    back = tra.concat(tiled, 1, 1)
    np.testing.assert_allclose(np.asarray(to_tensor(back, key_dims=(1,))),
                               np.asarray(B))


def test_diag_pipeline():
    """Paper §2.1: λ_diag(ReKey_getKey0(σ_isEq(R_A))) extracts diag blocks."""
    A = jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
    RA = from_tensor(A, (2, 2))
    f = tra.filt(RA, lambda k: k[0] == k[1])
    rk = tra.rekey(f, lambda k: (k[0],))
    dg = tra.transform(rk, get_kernel("diag"))
    want = np.diag(np.asarray(A))
    got = np.concatenate([dg.to_dict()[(0,)], dg.to_dict()[(1,)]])
    np.testing.assert_allclose(got, want)
