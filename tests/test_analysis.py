"""Static plan verifier: diagnostics, every pass (positive + negative),
the cache-key fuzzer's regression classes, and the Engine integration.

Each verifier pass gets at least one test where a deliberately corrupted
plan is rejected with an error diagnostic *naming the offending node*,
plus one where the corresponding §5 program verifies clean — the
acceptance bar of the analysis subsystem.
"""
import warnings

import pytest

from repro.analysis import (ALL_PASSES, DEFAULT_COMPILE_PASSES, Diagnostic,
                            Diagnostics, PassManager, PlanVerificationError,
                            verify_plans)
from repro.core import programs as prog
from repro.core.engine import Engine, plan_sig
from repro.core.kernels_registry import get_kernel
from repro.core.plan import (Bcast, IAInput, LocalAgg, LocalJoin, Placement,
                             Shuf, TraReKey, as_node)
from repro.core.tra import RelType

# §5.1 shapes: key grids divisible by the 4-site mesh
MM = ((8, 4), (4, 8), (16, 16), (16, 16))
SITES = {"sites": 4}


# ==========================================================================
# diagnostics vocabulary
# ==========================================================================

def test_diagnostic_render_snapshot():
    d = Diagnostic("placement", "error", "the aggregation is wrong",
                   7, "7:LocalAgg[matAdd]", "use partial=True")
    assert d.render() == (
        "[placement] error at node 7:LocalAgg[matAdd]: "
        "the aggregation is wrong\n"
        "    hint: use partial=True")
    # no node, no hint: bare one-liner
    assert Diagnostic("memory", "info", "fits").render() == \
        "[memory] info: fits"


def test_diagnostics_collection_views_and_render_footer():
    ds = Diagnostics()
    ds.add("placement", "error", "bad")
    ds.add("streaming", "warning", "meh")
    ds.add("memory", "info", "ok")
    assert len(ds) == 3 and bool(ds)
    assert [d.severity for d in ds.errors] == ["error"]
    assert [d.pass_name for d in ds.by_pass("streaming")] == ["streaming"]
    out = ds.render(min_severity="warning")
    assert "bad" in out and "meh" in out and "ok" not in out
    assert out.endswith("-- 1 error(s), 1 warning(s), 1 info(s)")
    assert Diagnostics().render() == "no diagnostics"


def test_diagnostic_rejects_unknown_severity():
    with pytest.raises(ValueError, match="severity"):
        Diagnostic("placement", "fatal", "boom")


def test_plan_verification_error_is_value_error_and_carries_diags():
    ds = Diagnostics()
    ds.add("placement", "error", "bad")
    with pytest.raises(ValueError) as ei:
        ds.raise_if_errors()
    assert isinstance(ei.value, PlanVerificationError)
    assert ei.value.diagnostics is ds
    assert "1 error(s)" in str(ei.value)


def test_pass_manager_rejects_unknown_pass():
    with pytest.raises(ValueError, match="unknown verifier pass"):
        PassManager(("placement", "no-such-pass"))
    assert "cachekey" in ALL_PASSES
    assert "cachekey" not in DEFAULT_COMPILE_PASSES


# ==========================================================================
# placement / exchange soundness
# ==========================================================================

def test_placement_clean_on_valid_cpmm():
    diags = verify_plans(prog.cpmm_plan(*MM), executor="shard_map",
                        axis_sizes=SITES, passes=("placement",))
    assert not diags.errors


def test_placement_rejects_r24_violation_naming_the_node():
    # bmm_plan reduces away the broadcast-partitioned contraction dim
    # with partial=False — the R2-4 violation check_valid also rejects
    diags = verify_plans(prog.bmm_plan(*MM), executor="shard_map",
                        axis_sizes=SITES, passes=("placement",))
    assert diags.errors
    d = diags.errors[0]
    assert "LocalAgg" in d.node_label and d.node_id >= 0
    assert "reduces away partitioned key dims" in d.message
    assert "R2-4" in d.message
    assert "partial=True" in d.hint


def test_placement_downgrades_to_warning_on_host_executors():
    # the same defect on the site-ignoring jit walk computes correct
    # values — the plan is merely not distributable as written
    diags = verify_plans(prog.bmm_plan(*MM), executor="jit",
                        axis_sizes=SITES, passes=("placement",))
    assert not diags.errors
    assert any("reduces away partitioned" in d.message
               for d in diags.warnings)


def test_placement_rejects_unknown_mesh_axis():
    a = IAInput("A", RelType((4, 4), (8, 8)),
                Placement.partitioned((0,), ("ghost",)))
    diags = verify_plans(a, executor="shard_map", axis_sizes=SITES,
                        passes=("placement",))
    assert any("mesh axis 'ghost'" in d.message for d in diags.errors)


def test_placement_rejects_root_duplicates_off_shard_map():
    diags = verify_plans(prog.cpmm_fused_plan(*MM), executor="gspmd",
                        axis_sizes=SITES, passes=("placement",))
    # cpmm_fused ends in a Shuf that resolves the dups — clean
    assert not diags.errors
    # strip the Shuf: the pending partials would be returned as final
    fused = prog.cpmm_fused_plan(*MM).child
    diags = verify_plans(fused, executor="gspmd", axis_sizes=SITES,
                        passes=("placement",))
    assert any("partial duplicates" in d.message for d in diags.errors)


# ==========================================================================
# collective-consistency (race) detector
# ==========================================================================

def test_collectives_schedule_of_cpmm_two_phase():
    from repro.analysis.collectives import collective_schedule
    sched = collective_schedule(prog.cpmm_two_phase_plan(*MM), SITES)
    # R2-5 partials resolve via the divisible additive specialization
    assert [op.kind for op in sched] == ["psum_scatter"]
    assert sched[0].axis == "sites" and op_named(sched[0], "Shuf")


def op_named(op, type_name):
    return type_name in op.node_label


def test_collectives_rejects_unknown_reducer_naming_the_node():
    a = IAInput("A", RelType((4, 4), (8, 8)),
                Placement.partitioned((0,), ("x",), dup_axes=("y",),
                                      dup_kernel="noSuchKernel"))
    diags = verify_plans(Bcast(a), executor="shard_map",
                        axis_sizes={"x": 2, "y": 2},
                        passes=("collectives",))
    assert any("unknown kernel 'noSuchKernel'" in d.message
               for d in diags.errors)
    assert all(d.node_label for d in diags.errors)


def test_collectives_rejects_nonassociative_reducer():
    a = IAInput("A", RelType((4, 4), (8, 8)),
                Placement.partitioned((0,), ("x",), dup_axes=("y",),
                                      dup_kernel="matMul"))
    diags = verify_plans(Bcast(a), executor="shard_map",
                        axis_sizes={"x": 2, "y": 2},
                        passes=("collectives",))
    assert any("non-associative kernel 'matMul'" in d.message
               for d in diags.errors)


def test_collectives_rejects_ghost_axis_exchange():
    a = IAInput("A", RelType((8, 4), (4, 4)),
                Placement.partitioned((0,), ("sites",)))
    root = Shuf(a, (1,), ("ghost",))
    diags = verify_plans(root, executor="shard_map", axis_sizes=SITES,
                        passes=("collectives",))
    assert any("mesh axis 'ghost'" in d.message and "Shuf" in d.node_label
               for d in diags.errors)


def test_collectives_downgraded_on_host_executors():
    a = IAInput("A", RelType((8, 4), (4, 4)),
                Placement.partitioned((0,), ("sites",)))
    root = Shuf(a, (1,), ("ghost",))
    diags = verify_plans(root, executor="jit", axis_sizes=SITES,
                        passes=("collectives",))
    assert not diags.errors
    assert any("mesh axis 'ghost'" in d.message for d in diags.warnings)


def test_site_schedule_alignment_detects_hang_and_divergence():
    from repro.analysis.collectives import (CollectiveOp,
                                            check_site_schedules)
    ag = CollectiveOp("all_gather", "sites", None, 3, "3:Bcast")
    ar = CollectiveOp("all_reduce", "sites", "matAdd", 5, "5:Shuf")
    # aligned: clean
    assert not check_site_schedules([[ag, ar]] * 4).errors
    # one site short a collective: guaranteed hang
    diags = check_site_schedules([[ag, ar], [ag]])
    assert any("blocks forever (hang)" in d.message for d in diags.errors)
    # same length, different reducer at one position: wrong sums
    ar2 = CollectiveOp("all_reduce", "sites", "elemMax", 5, "5:Shuf")
    diags = check_site_schedules([[ag, ar], [ag, ar2]])
    assert any("diverge at position 1" in d.message for d in diags.errors)


# ==========================================================================
# stream-carrier legality
# ==========================================================================

def _over_budget_matmul():
    from repro.core.cost import plan_peak_bytes
    root = as_node(prog.matmul_tra((8, 2), (2, 2), (16, 16), (16, 16)))
    return root, int(plan_peak_bytes(root) * 0.6)


def test_streaming_legal_plan_gets_info_not_errors():
    root, budget = _over_budget_matmul()
    diags = verify_plans(root, executor="jit", memory_budget=budget,
                        passes=("streaming",))
    assert not diags.errors
    assert any("is legal" in d.message for d in diags)


def test_streaming_fits_resident_is_info():
    root, _ = _over_budget_matmul()
    diags = verify_plans(root, executor="jit", memory_budget=1 << 30,
                        passes=("streaming",))
    assert not diags.errors
    assert any("fits resident" in d.message for d in diags)


def test_streaming_rejects_rekey_naming_the_node():
    root, budget = _over_budget_matmul()
    rekeyed = TraReKey(root, lambda k: k)
    diags = verify_plans(rekeyed, executor="jit", memory_budget=budget,
                        passes=("streaming",))
    assert diags.errors
    d = diags.errors[0]
    assert "TraReKey" in d.node_label
    assert "rewrites the key space" in d.message
    assert "resident" in d.hint


def test_streaming_silent_without_budget():
    root, _ = _over_budget_matmul()
    diags = verify_plans(TraReKey(root, lambda k: k), executor="jit",
                        passes=("streaming",))
    assert not len(diags)


# ==========================================================================
# memory-model audit
# ==========================================================================

def test_memory_model_agrees_on_corpus_programs():
    from repro.analysis.memory import (audit_memory_model,
                                       independent_peak_bytes)
    from repro.core.cost import plan_peak_bytes
    step = prog.ffnn_train_step_tra(2, 2, 2, 1, 4, 4, 4, 4)
    roots = tuple(as_node(r) for r in step.roots.values())
    assert not audit_memory_model(roots).errors
    assert independent_peak_bytes(roots) == plan_peak_bytes(roots)
    mm = as_node(prog.matmul_tra(*MM))
    assert not audit_memory_model(mm).errors


def test_memory_model_divergence_is_an_error():
    from repro.analysis.memory import audit_memory_model
    root = as_node(prog.matmul_tra(*MM))
    diags = audit_memory_model(root, estimator=lambda r, fuse=True: 0)
    msgs = [d.message for d in diags.errors]
    assert any("memory model divergence" in m and "under-estimate" in m
               for m in msgs)
    huge = audit_memory_model(root, estimator=lambda r, fuse=True: 1 << 60)
    assert any("over-estimate" in d.message for d in huge.errors)


def test_memory_model_invariant_largest_relation_names_node(monkeypatch):
    # the invariants back-stop the case where BOTH liveness walks share a
    # bug: force agreement on an absurdly small peak and they must fire
    import repro.analysis.memory as mem
    root = as_node(prog.matmul_tra(*MM))
    monkeypatch.setattr(mem, "independent_peak_bytes",
                        lambda roots, fuse=True: 8)
    diags = mem.audit_memory_model(root, estimator=lambda r, fuse=True: 8)
    assert any("largest single relation" in d.message and d.node_label
               for d in diags.errors)
    assert any("sum of root outputs" in d.message for d in diags.errors)


# ==========================================================================
# cache-key injectivity fuzzing + plan_sig hardening regressions
# ==========================================================================

def test_fuzzer_clean_on_hardened_plan_sig():
    from repro.analysis.cachekey import check_sig_injectivity
    for build in (lambda: as_node(prog.matmul_tra(*MM)),
                  lambda: prog.cpmm_fused_plan(*MM),
                  lambda: prog.bmm_plan(*MM)):
        assert not check_sig_injectivity(build()).errors


def test_fuzzer_finds_out_bound_collision_under_old_kernel_sig(monkeypatch):
    """Regression: ad-hoc kernels used to sign as (name, id(apply)) —
    a kernel differing only in out_bound collided."""
    import repro.core.engine as eng_mod
    from repro.analysis.cachekey import check_sig_injectivity
    monkeypatch.setattr(eng_mod, "_kernel_sig",
                        lambda k: (k.name, id(k.apply)))
    diags = check_sig_injectivity(prog.cpmm_fused_plan(*MM))
    assert any("out_bound" in d.message and "collision" in d.message
               for d in diags.errors)
    assert all("plan_sig" in d.hint for d in diags.errors)


def test_plan_sig_observes_dup_kernel():
    """Regression: the pending dup reducer was absent from input-placement
    signatures — two-phase plans differing only in the reducer collided."""
    rt = RelType((4, 4), (8, 8))
    mk = lambda red: Bcast(IAInput(
        "A", rt, Placement.partitioned((0,), ("x",), dup_axes=("y",),
                                       dup_kernel=red)))
    assert plan_sig(mk("matAdd")) != plan_sig(mk("elemMax"))


def test_plan_sig_observes_out_bound_content():
    k = get_kernel("matMul")
    import dataclasses
    shadow = dataclasses.replace(
        k, out_bound=lambda *bounds: tuple(k.out_bound(*bounds)))
    a = IAInput("A", RelType((4, 4), (8, 8)), Placement.replicated())
    b = IAInput("B", RelType((4, 4), (8, 8)), Placement.replicated())
    j1 = LocalJoin(a, b, (1,), (0,), k)
    j2 = LocalJoin(a, b, (1,), (0,), shadow)
    assert plan_sig(j1) != plan_sig(j2)


def test_code_fingerprint_separates_bodies_not_identities():
    from repro.core.engine import _code_fp
    f1 = lambda x: x + 1
    f2 = lambda x: x + 2
    f3 = lambda x: x + 1
    assert _code_fp(f1) != _code_fp(f2)
    # same body, different object: same fingerprint (content-addressed)
    assert _code_fp(f1) == _code_fp(f3)
    assert _code_fp(f1) == _code_fp(f1)


def test_mutation_enumeration_covers_every_node():
    from repro.analysis.cachekey import plan_mutations
    root = prog.cpmm_fused_plan(*MM)
    muts = list(plan_mutations(root))
    assert len(muts) >= 6     # inputs ×2+, fused ×3+, shuf ×1
    # every mutant really is a different tree object than the original
    assert all(m is not root for _, _, m in muts)


def test_fuzz_smoke_randomized_shapes():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    from repro.analysis.cachekey import check_sig_injectivity

    @hyp.given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4))
    @hyp.settings(max_examples=10, deadline=None)
    def run(fa, fk, fb):
        root = as_node(prog.matmul_tra((fa, fk), (fk, fb), (4, 4), (4, 4)))
        assert not check_sig_injectivity(root).errors

    run()


# ==========================================================================
# Engine integration: validate="off" | "warn" | "strict"
# ==========================================================================

def test_engine_rejects_unknown_validate_mode():
    with pytest.raises(ValueError, match="unknown validate mode"):
        Engine(validate="bogus")


def test_engine_validate_default_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_VALIDATE", "strict")
    assert Engine().validate == "strict"
    monkeypatch.delenv("REPRO_VALIDATE")
    assert Engine().validate == "warn"


def test_engine_strict_rejects_corrupted_plan():
    root, budget = _over_budget_matmul()
    eng = Engine(executor="jit", memory_budget=budget, validate="strict")
    with pytest.raises(PlanVerificationError) as ei:
        eng.compile(TraReKey(root, lambda k: k))
    assert "TraReKey" in str(ei.value)
    assert ei.value.diagnostics.errors
    assert eng.last_diagnostics is ei.value.diagnostics


def test_engine_warn_compiles_anyway_with_runtime_warning():
    root, budget = _over_budget_matmul()
    eng = Engine(executor="jit", memory_budget=budget, validate="warn")
    with pytest.warns(RuntimeWarning, match="plan verification found"):
        eng.compile(TraReKey(root, lambda k: k))
    assert eng.last_diagnostics is not None
    assert eng.last_diagnostics.errors


def test_engine_off_is_silent():
    root, budget = _over_budget_matmul()
    eng = Engine(executor="jit", memory_budget=budget, validate="off")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng.compile(TraReKey(root, lambda k: k))
    assert eng.last_diagnostics is None


def test_engine_strict_accepts_clean_programs_and_records_diags():
    eng = Engine(executor="jit", validate="strict")
    step = prog.ffnn_train_step_tra(2, 2, 2, 1, 4, 4, 4, 4)
    eng.compile(step.roots)
    assert eng.last_diagnostics is not None
    assert not eng.last_diagnostics.errors


def test_verify_runs_once_per_cache_miss():
    root = as_node(prog.matmul_tra(*MM))
    eng = Engine(executor="jit", validate="strict")
    eng.compile(root)
    first = eng.last_diagnostics
    eng.compile(root)                # cache hit: no re-verification
    assert eng.last_diagnostics is first


def test_streamed_refusal_enriched_with_diagnostics():
    from repro.store.stream import NotStreamable
    root, budget = _over_budget_matmul()
    # force=True is the degradation ladder's rung-1 path — the one place
    # StreamExecutor.plan raises instead of silently planning resident
    eng = Engine(executor="jit", memory_budget=budget, validate="warn")
    with pytest.raises(NotStreamable) as ei:
        eng._compile_streamed(TraReKey(root, lambda k: k), force=True)
    assert "[streaming]" in str(ei.value)
    assert "rewrites the key space" in str(ei.value)
    # validate="off": the bare legacy refusal, no verifier text
    eng_off = Engine(executor="jit", memory_budget=budget, validate="off")
    with pytest.raises(NotStreamable) as ei:
        eng_off._compile_streamed(TraReKey(root, lambda k: k), force=True)
    assert "[streaming]" not in str(ei.value)


# ==========================================================================
# promoted legacy validation: same types, same leading text
# ==========================================================================

def test_chunk_validation_keeps_legacy_text_and_adds_diagnostic():
    with pytest.raises(ValueError, match="chunk must be >= 1, got 0") as ei:
        Engine(chunk=0)
    assert "[inputs] error" in str(ei.value)
    with pytest.raises(ValueError, match="positive int, None or \"auto\""):
        Engine(chunk="bogus")


def test_memory_budget_validation():
    with pytest.raises(ValueError,
                       match="memory_budget must be >= 1 byte") as ei:
        Engine(memory_budget=0)
    assert "[inputs] error" in str(ei.value)


def test_run_input_validation_keeps_legacy_text():
    import numpy as np
    ce = Engine(executor="reference").compile(
        prog.matmul_tra((2, 2), (2, 2), (4, 4), (4, 4)))
    A = np.ones((2, 2, 4, 4), dtype="float32")
    with pytest.raises(ValueError, match="unexpected inputs") as ei:
        ce.run(A=A, B=A, C=A)
    assert "[inputs] error" in str(ei.value)
    with pytest.raises(ValueError, match="missing inputs"):
        ce.run(A=A)


def test_masked_inputs_error_constructor():
    from repro.analysis.inputs import masked_inputs_error
    err = masked_inputs_error("jit", ["A"])
    assert isinstance(err, NotImplementedError)
    assert "requires continuous (mask-free) input relations" in str(err)
    assert "['A']" in str(err)


# ==========================================================================
# the program corpus verifies clean under every pass
# ==========================================================================

def test_corpus_clean_under_all_passes():
    from repro.analysis.lint import _corpus
    for name, build in _corpus():
        diags = verify_plans(passes=ALL_PASSES, **build())
        assert not diags.errors, (
            f"{name}: {[d.render() for d in diags.errors]}")


def test_lint_cli_exits_zero():
    from repro.analysis.lint import main
    assert main(["-q"]) == 0
