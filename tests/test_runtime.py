"""Runtime tests: fault-tolerant trainer, straggler monitor, metering."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS, SHAPES, SMOKES
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.runtime import (SimulatedFailure, StragglerMonitor, Trainer,
                           TrainerConfig, bubble_fraction)


def _trainer(ckpt_dir, steps=10, arch="qwen2.5-14b"):
    cfg = SMOKES[arch]
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                      global_batch=4, seed=7)
    tcfg = TrainerConfig(steps=steps, ckpt_every=4, ckpt_dir=ckpt_dir,
                         warmup=2, adamw=AdamWConfig(lr=1e-3))
    return Trainer(cfg, dcfg, tcfg)


# Formerly xfailed with loss flat at ~5.85 ≈ ln(256): the root cause was
# the data generator, not the model/loss — _grammar_rows drew a fresh
# uniform (a, b) per row, making p(x_{t+1} | x_t) marginally uniform over
# the vocab, i.e. unlearnable by sequence statistics at smoke scale.  The
# pipeline now samples (a, b) from a small seed-derived family
# (DataConfig.grammar_families), under which the same trainer drops the
# loss by >1 nat in 30 steps.
def test_loss_decreases_on_learnable_data():
    cfg = SMOKES["qwen2.5-14b"]
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                      global_batch=8, seed=0, grammar_frac=1.0)
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(steps=30, ckpt_every=100, ckpt_dir=d,
                             warmup=3, adamw=AdamWConfig(lr=3e-3))
        tr = Trainer(cfg, dcfg, tcfg)
        hist = tr.train()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, (first, last)


def test_restart_reproduces_uninterrupted_run():
    with tempfile.TemporaryDirectory() as d1:
        h1 = _trainer(d1).train()
    with tempfile.TemporaryDirectory() as d2:
        tr = _trainer(d2)
        fail_at = {6}

        def inj(step):
            if step in fail_at:
                fail_at.discard(step)
                raise SimulatedFailure()

        h2 = tr.train(failure_injector=inj)
    a = {h["step"]: round(h["loss"], 5) for h in h1}
    b = {h["step"]: round(h["loss"], 5) for h in h2}
    assert a == {s: b[s] for s in a}


def test_cold_restart_from_disk():
    with tempfile.TemporaryDirectory() as d:
        tr = _trainer(d, steps=8)
        tr.train(steps=4)
        tr.save()
        tr.store.wait()
        # fresh trainer object == fresh process
        tr2 = _trainer(d, steps=8)
        tr2.init_or_restore()
        assert int(jax.device_get(tr2.opt_state["step"])) == 4
        assert tr2.loader.step == 4
        h = tr2.train()
        assert h[-1]["step"] == 8


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0)
    for _ in range(5):
        mon.observe(0, 1.0)
    assert not mon.flagged
    assert mon.observe(6, 5.0)
    assert mon.flagged and mon.flagged[0][1] == 5.0


def test_bubble_fraction():
    assert bubble_fraction(8, 4) == pytest.approx(3 / 11)
    assert bubble_fraction(100, 2) < 0.01


def test_metering_sane():
    from repro.launch.metering import meter, roofline_terms
    from repro.sharding import plan_arch
    import numpy as np
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    cfg = CONFIGS["qwen2-7b"]
    shape = SHAPES["train_4k"]
    plan = plan_arch(cfg, shape, mesh)
    m = meter(cfg, shape, plan)
    # 6·N·D within 25% of the metered (model flops exclude attention
    # quadratic + remat; metered includes them)
    six_nd = 6.0 * 7.6e9 * shape.tokens
    assert 0.6 * six_nd < m.flops < 2.0 * six_nd
    terms = roofline_terms(m, 256)
    assert terms["step_s"] > 0
    assert terms["dominant"] in ("compute", "memory", "collective")


def test_metering_decode_memory_bound():
    from repro.launch.metering import meter, roofline_terms
    from repro.sharding import plan_arch
    import numpy as np
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    cfg = CONFIGS["qwen2.5-14b"]
    shape = SHAPES["decode_32k"]
    plan = plan_arch(cfg, shape, mesh)
    terms = roofline_terms(meter(cfg, shape, plan), 256)
    assert terms["dominant"] == "memory"   # decode reads cache+weights
