"""Slow-marker bench job: the compiled-vs-eager train-step guard.

Runs the full :mod:`benchmarks.train` section (which raises on guard
failure): steps ≥ 2 of the compiled §5.3 train step must be pure
compile-cache dispatch, the fused gradient+update plan must beat the
unfused oracle, and the loss must decrease.  Deselect with
``-m "not slow"`` (the tier-1 CI default); the guard runs in the bench
job and locally via ``python -m benchmarks.run``.
"""
import pytest


@pytest.mark.slow
def test_train_step_bench_guard():
    from benchmarks.train import run

    lines = run(None)                    # raises AssertionError on FAIL
    assert any("PASS" in line for line in lines)
