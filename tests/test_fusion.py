"""Fused Σ∘⋈ contraction path: semantics, plan selection, rewrite.

The unfused pair (``tra.agg(tra.join(...))``) and the dict-of-numpy
reference executor are the correctness oracles; every fused lowering —
2-D collapsed matmul, einsum contraction, chunked streaming reduction —
must agree with them, including over masked (holey) relations.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (FusedJoinAgg, Placement, RelType, TraAgg, TraInput,
                        TraJoin, compile_tra, describe, from_tensor,
                        fuse_join_agg, fused_join_agg, get_kernel, infer,
                        optimize, to_tensor)
from repro.core import reference as ref
from repro.core import tra
from repro.core.cost import cost_plan
from repro.core.programs import bmm_fused_plan, cpmm_fused_plan, cpmm_plan

from conftest import (shim_evaluate_ia as evaluate_ia,
                      shim_evaluate_tra as evaluate_tra)

S = ("sites",)
SZ = {"sites": 4}


def rand_rel(key, f, b):
    x = jax.random.normal(jax.random.PRNGKey(key),
                          (f[0] * b[0], f[1] * b[1]), jnp.float32)
    return from_tensor(x, b), x


def holey(rel, pred):
    return tra.filt(rel, pred)


def assert_rel_close(got, want, rtol=1e-4, atol=1e-4):
    assert got.rtype == want.rtype, (got.rtype, want.rtype)
    gm = None if got.mask is None else got.mask
    wm = None if want.mask is None else want.mask
    assert (gm is None) == (wm is None)
    if gm is not None:
        np.testing.assert_array_equal(gm, wm)
        sel = wm
        np.testing.assert_allclose(np.asarray(got.data)[sel],
                                   np.asarray(want.data)[sel],
                                   rtol=rtol, atol=atol)
    else:
        np.testing.assert_allclose(np.asarray(got.data),
                                   np.asarray(want.data),
                                   rtol=rtol, atol=atol)


# ------------------------------------------------------------------ oracle
KERNEL_CASES = [
    ("matMul", "matAdd"),          # 2-D collapse / einsum contraction
    ("matTranMulL", "matAdd"),     # einsum contraction (weight gradient)
    ("matTranMulR", "matAdd"),     # einsum contraction (activation grad)
    ("elemMul", "matAdd"),         # elementwise join, additive reduce
    ("elemMin", "elemMin"),        # chunked streaming reduction
    ("matAdd", "matAdd"),          # non-contraction pair → chunked
    ("elemMul", "elemMax"),        # chunked, non-additive reducer
]


@pytest.mark.parametrize("jk,ak", KERNEL_CASES)
@pytest.mark.parametrize("masked", [False, True])
def test_fused_equals_unfused(jk, ak, masked):
    jkern, akern = get_kernel(jk), get_kernel(ak)
    RA, _ = rand_rel(0, (3, 4), (4, 4))
    RB, _ = rand_rel(1, (4, 3), (4, 4))
    if masked:
        RA = holey(RA, lambda k: not (k[0] == 1 and k[1] == 2))
        RB = holey(RB, lambda k: k[0] != 3 or k[1] != 1)
    for gb in [(0,), (2,), (0, 2), (2, 0)]:
        want = tra.agg(tra.join(RA, RB, (1,), (0,), jkern), gb, akern)
        got = fused_join_agg(RA, RB, (1,), (0,), jkern, gb, akern)
        assert_rel_close(got, want)
        got_c = fused_join_agg(RA, RB, (1,), (0,), jkern, gb, akern,
                               chunk=3)
        assert_rel_close(got_c, want)


@pytest.mark.parametrize("jk,ak", [("matMul", "matAdd"),
                                   ("elemMin", "elemMin")])
def test_fused_equals_reference_oracle(jk, ak):
    """Fused path vs the tuple-at-a-time dict-of-numpy reference."""
    jkern, akern = get_kernel(jk), get_kernel(ak)
    RA, _ = rand_rel(2, (2, 3), (4, 4))
    RB, _ = rand_rel(3, (3, 2), (4, 4))
    RA = holey(RA, lambda k: k != (0, 1))
    want_d = ref.agg(ref.join(RA.to_dict(), RB.to_dict(), (1,), (0,), jkern),
                     (0, 2), akern)
    got = fused_join_agg(RA, RB, (1,), (0,), jkern, (0, 2), akern)
    got_d = got.to_dict()
    assert set(got_d) == set(want_d)
    for k in want_d:
        np.testing.assert_allclose(got_d[k], want_d[k], rtol=1e-4, atol=1e-4)


def test_fused_frontier_mismatch_windows():
    """Joined dims with unequal frontiers slice to the min window."""
    mm, add = get_kernel("matMul"), get_kernel("matAdd")
    RA, _ = rand_rel(4, (2, 5), (4, 4))
    RB, _ = rand_rel(5, (3, 2), (4, 4))
    want = tra.agg(tra.join(RA, RB, (1,), (0,), mm), (0, 2), add)
    got = fused_join_agg(RA, RB, (1,), (0,), mm, (0, 2), add)
    assert_rel_close(got, want)


def test_fused_no_reduce_dims_falls_back():
    add = get_kernel("matAdd")
    RA, _ = rand_rel(6, (3, 3), (4, 4))
    RB, _ = rand_rel(7, (3, 3), (4, 4))
    want = tra.agg(tra.join(RA, RB, (0, 1), (0, 1), add), (1, 0), add)
    got = fused_join_agg(RA, RB, (0, 1), (0, 1), add, (1, 0), add)
    assert_rel_close(got, want)


# ------------------------------------------------------- hypothesis sweep
def test_fused_property_sweep():
    """Randomized sweep (hypothesis when available, fixed seeds otherwise)."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        rng = np.random.default_rng(0)
        trials = [(int(rng.integers(1, 4)), int(rng.integers(1, 4)),
                   int(rng.integers(1, 4)), bool(rng.integers(2)))
                  for _ in range(12)]
    else:
        trials = None

    def check(i, k, j, masked):
        mm, add = get_kernel("matMul"), get_kernel("matAdd")
        RA, _ = rand_rel(10 + i, (i, k), (2, 3))
        RB, _ = rand_rel(20 + j, (k, j), (3, 2))
        if masked and i * k > 1:
            RA = holey(RA, lambda key: key != (i - 1, k - 1))
        want = tra.agg(tra.join(RA, RB, (1,), (0,), mm), (0, 2), add)
        got = fused_join_agg(RA, RB, (1,), (0,), mm, (0, 2), add)
        assert_rel_close(got, want)

    if trials is None:
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=25, deadline=None)
        @given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3),
               st.booleans())
        def prop(i, k, j, masked):
            check(i, k, j, masked)

        prop()
    else:
        for t in trials:
            check(*t)


# ------------------------------------------------------------- plan level
def matmul_tra_plan(fl, fr, bl, br):
    ta = TraInput("A", RelType(fl, bl))
    tb = TraInput("B", RelType(fr, br))
    return TraAgg(TraJoin(ta, tb, (1,), (0,), get_kernel("matMul")),
                  (0, 2), get_kernel("matAdd"))


def test_optimizer_selects_fused_for_cpmm():
    plan = matmul_tra_plan((4, 4), (4, 4), (8, 8), (8, 8))
    r = optimize(plan, {"A": Placement.partitioned((1,), S),
                        "B": Placement.partitioned((0,), S)}, S, SZ)
    assert "FusedJoinAgg" in describe(r.plan), describe(r.plan)
    RA, A = rand_rel(0, (4, 4), (8, 8))
    RB, B = rand_rel(1, (4, 4), (8, 8))
    got = evaluate_ia(r.plan, {"A": RA, "B": RB})
    np.testing.assert_allclose(np.asarray(to_tensor(got)),
                               np.asarray(A @ B), rtol=1e-4, atol=1e-4)


def test_optimizer_fused_never_costs_more():
    """Fusion is comm-neutral: best cost with fusion == seed enumeration."""
    plan = matmul_tra_plan((4, 8), (8, 4), (4, 4), (4, 4))
    for places in [
        {"A": Placement.partitioned((1,), S),
         "B": Placement.partitioned((0,), S)},
        {"A": Placement.partitioned((0,), S),
         "B": Placement.partitioned((0,), S)},
        {"A": Placement.replicated(), "B": Placement.replicated()},
    ]:
        r = optimize(plan, places, S, SZ)
        RA, A = rand_rel(2, (4, 8), (4, 4))
        RB, B = rand_rel(3, (8, 4), (4, 4))
        got = evaluate_ia(r.plan, {"A": RA, "B": RB})
        np.testing.assert_allclose(np.asarray(to_tensor(got)),
                                   np.asarray(A @ B), rtol=1e-4, atol=1e-4)


def test_fused_node_infer_matches_pair():
    fused = cpmm_fused_plan((4, 4), (4, 4), (8, 8), (8, 8))
    pair = cpmm_plan((4, 4), (4, 4), (8, 8), (8, 8))
    fi, pi = infer(fused), infer(pair)
    assert fi.rtype == pi.rtype
    assert fi.placement.kind == pi.placement.kind


def test_fused_tmp_cost_below_unfused():
    """The memory tiebreak: fused plans report less materialization."""
    fused = cpmm_fused_plan((4, 4), (4, 4), (8, 8), (8, 8))
    pair = cpmm_plan((4, 4), (4, 4), (8, 8), (8, 8))
    rf, rp = cost_plan(fused, SZ), cost_plan(pair, SZ)
    assert rf.flops == rp.flops
    assert rf.tmp_floats < rp.tmp_floats


def test_fuse_rewrite_on_default_compile():
    """fuse_join_agg collapses LocalAgg(Shuf(LocalJoin(Bcast(L), R)))."""
    plan = matmul_tra_plan((4, 4), (4, 4), (8, 8), (8, 8))
    places = {"A": Placement.partitioned((0,), S),
              "B": Placement.partitioned((0,), S)}
    ia = compile_tra(plan, places)
    fz = fuse_join_agg(ia)
    assert "FusedJoinAgg" in describe(fz), describe(fz)
    RA, A = rand_rel(4, (4, 4), (8, 8))
    RB, B = rand_rel(5, (4, 4), (8, 8))
    want = evaluate_ia(ia, {"A": RA, "B": RB})
    got = evaluate_ia(fz, {"A": RA, "B": RB})
    assert_rel_close(got, want)
    # placement-preserving: parents above the rewrite site stay valid
    assert infer(fz).placement is not None


def test_fused_bmm_and_cpmm_execute():
    RA, A = rand_rel(6, (4, 4), (8, 8))
    RB, B = rand_rel(7, (4, 4), (8, 8))
    for plan in [bmm_fused_plan((4, 4), (4, 4), (8, 8), (8, 8)),
                 cpmm_fused_plan((4, 4), (4, 4), (8, 8), (8, 8))]:
        got = evaluate_ia(plan, {"A": RA, "B": RB})
        np.testing.assert_allclose(np.asarray(to_tensor(got)),
                                   np.asarray(A @ B), rtol=1e-4, atol=1e-4)


def test_evaluate_tra_does_not_fuse_shared_join():
    """A join with two consumers is computed once and cached, not fused
    (fusing would force the sibling consumer to recompute the join)."""
    mm, add = get_kernel("matMul"), get_kernel("matAdd")
    ta = TraInput("A", RelType((3, 4), (4, 4)))
    tb = TraInput("B", RelType((4, 3), (4, 4)))
    j = TraJoin(ta, tb, (1,), (0,), mm)
    agg1 = TraAgg(j, (0, 2), add)
    agg2 = TraAgg(j, (2, 0), add)
    root = TraJoin(agg1, agg2, (0, 1), (1, 0), add)
    RA, _ = rand_rel(10, (3, 4), (4, 4))
    RB, _ = rand_rel(11, (4, 3), (4, 4))
    cache = {}
    got = evaluate_tra(root, {"A": RA, "B": RB}, cache)
    assert id(j) in cache          # the shared join was materialized once
    want = evaluate_tra(root, {"A": RA, "B": RB}, fuse=False)
    assert_rel_close(got, want)


def test_evaluate_tra_fuse_flag_is_oracle_equal():
    plan = matmul_tra_plan((3, 5), (5, 2), (4, 4), (4, 4))
    RA, _ = rand_rel(8, (3, 5), (4, 4))
    RB, _ = rand_rel(9, (5, 2), (4, 4))
    fused = evaluate_tra(plan, {"A": RA, "B": RB})
    oracle = evaluate_tra(plan, {"A": RA, "B": RB}, fuse=False)
    assert_rel_close(fused, oracle)
