"""Shared test helpers.

``shim_evaluate_tra`` / ``shim_evaluate_ia`` are the ONE place oracle
tests call the deprecated executor shims: each call asserts the shim
still emits its ``DeprecationWarning`` (via ``pytest.deprecated_call``)
while keeping the tier-1 run warning-clean.  Library code never routes
through the shims — CI escalates the warning to an error for ``repro.*``
warning sites.
"""
import pytest


def shim_evaluate_tra(*args, **kwargs):
    """Intentional oracle use of the deprecated shim (must still warn)."""
    import repro.core
    with pytest.deprecated_call():
        return repro.core.evaluate_tra(*args, **kwargs)


def shim_evaluate_ia(*args, **kwargs):
    """Intentional oracle use of the deprecated shim (must still warn)."""
    import repro.core
    with pytest.deprecated_call():
        return repro.core.evaluate_ia(*args, **kwargs)
