"""Serving subsystem tests: TraServer, servables, batching helpers.

The load-bearing guarantees:

* continuous batching is *invisible* — batched-step outputs match the
  per-request dense oracle at 1e-5 no matter how requests interleave;
* bucket padding is inert — zero tail rows never leak into real rows;
* slot lifecycle is sound — alloc/evict/reuse under randomized arrival
  and finish orders keeps free rows zero and capacity respected;
* the compile cache is cold after warmup — steady-state dispatch never
  misses, on the reference and the jit executor alike.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import Engine, ExprTypeError
from repro.core import expr as E
from repro.core.tra import (RelType, TensorRelation, pack_rows,
                            scatter_rows, unpack_rows, zero_rows)
from repro.launch.metering import RequestSpan, SpanMeter, percentiles
from repro.serve import (FFNNScorer, LmRequest, RecurrentLM, TraServer,
                         closed_loop, lm_mix, open_loop, poisson_arrivals,
                         pick_bucket, scorer_mix)

EXECUTORS = ("reference", "jit")


def small_lm(capacity=4):
    return RecurrentLM(d_model=16, vocab_size=32, capacity=capacity)


# =========================================================================
# batching helpers (core/tra.py)
# =========================================================================

class TestRowHelpers:
    def rtype(self):
        return RelType((2,), (1, 3))

    def rel(self, fill):
        return TensorRelation(jnp.full((2, 1, 3), float(fill)), self.rtype())

    def test_pack_pads_to_bucket(self):
        packed = pack_rows([self.rel(1), self.rel(2)], 4, self.rtype())
        assert packed.rtype.key_shape == (4, 2)
        np.testing.assert_allclose(np.asarray(packed.data)[2:], 0.0)
        np.testing.assert_allclose(np.asarray(packed.data)[1], 2.0)

    def test_pack_unpack_roundtrip(self):
        rels = [self.rel(i) for i in range(3)]
        packed = pack_rows(rels, 4, self.rtype())
        back = unpack_rows(packed, 3)
        assert len(back) == 3
        for orig, got in zip(rels, back):
            assert got.rtype == orig.rtype
            np.testing.assert_allclose(np.asarray(got.data),
                                       np.asarray(orig.data))

    def test_pack_rejects_overflow_and_mismatch(self):
        with pytest.raises(ValueError):
            pack_rows([self.rel(1)] * 5, 4, self.rtype())
        with pytest.raises(ValueError):
            pack_rows([TensorRelation(jnp.zeros((3, 1, 3)),
                                      RelType((3,), (1, 3)))],
                      4, self.rtype())

    def test_scatter_and_zero_rows(self):
        base = pack_rows([self.rel(1)] * 4, 4, self.rtype())
        out = scatter_rows(base, [1, 3], [self.rel(7), self.rel(9)])
        data = np.asarray(out.data)
        np.testing.assert_allclose(data[1], 7.0)
        np.testing.assert_allclose(data[3], 9.0)
        np.testing.assert_allclose(data[0], 1.0)   # untouched
        zeroed = zero_rows(out, [3])
        np.testing.assert_allclose(np.asarray(zeroed.data)[3], 0.0)
        np.testing.assert_allclose(np.asarray(zeroed.data)[1], 7.0)

    def test_scatter_rejects_bad_slots(self):
        base = pack_rows([self.rel(1)], 2, self.rtype())
        with pytest.raises(ValueError):
            scatter_rows(base, [2], [self.rel(0)])
        with pytest.raises(ValueError):
            scatter_rows(base, [0, 0], [self.rel(0), self.rel(1)])


class TestSlotUpdate:
    def test_masked_update_selects_rows(self):
        eng = Engine(executor="reference")
        state = E.input("S", (3, 1), (1, 4))
        rows = E.input("R", (3, 1), (1, 4))
        mask = E.input("M", (3, 1), (1, 1))
        prog = state.slot_update(rows, mask)
        s = jnp.arange(12, dtype=jnp.float32).reshape(3, 1, 1, 4)
        r = -jnp.ones((3, 1, 1, 4))
        m = jnp.asarray([1.0, 0.0, 1.0]).reshape(3, 1, 1, 1)
        out = eng.run(prog, S=s, R=r, M=m)
        data = np.asarray(out.data)
        np.testing.assert_allclose(data[0], -1.0)
        np.testing.assert_allclose(data[1], np.asarray(s)[1])
        np.testing.assert_allclose(data[2], -1.0)

    def test_type_errors(self):
        state = E.input("S", (3, 1), (1, 4))
        with pytest.raises(ExprTypeError):
            state.slot_update(E.input("R", (2, 1), (1, 4)),
                              E.input("M", (3, 1), (1, 1)))
        with pytest.raises(ExprTypeError):
            state.slot_update(E.input("R", (3, 1), (1, 4)),
                              E.input("M", (3, 1), (1, 4)))


# =========================================================================
# engine cache introspection (satellite b)
# =========================================================================

class TestCacheInfo:
    def test_entries_hits_and_artifact_ids(self):
        eng = Engine(executor="jit")
        sc = FFNNScorer()
        c1 = eng.compile(sc.program(2))
        c2 = eng.compile(sc.program(2))        # hit
        assert c1 is c2
        eng.compile(sc.program(4))
        info = eng.cache_info()
        assert len(info) == 2
        assert info[0].hits == 1 and info[1].hits == 0
        assert info[0].executor == "jit"
        assert info[0].artifact_id.startswith("jit:")
        assert not info[0].degraded
        assert info[0].root_names == ("scores",)

    def test_pin_survives_clear(self):
        eng = Engine(executor="reference")
        sc = FFNNScorer()
        pinned = eng.compile(sc.program(1))
        eng.pin(pinned)
        eng.compile(sc.program(2))
        assert eng.cache_clear() == 1          # unpinned entry evicted
        info = eng.cache_info()
        assert len(info) == 1 and info[0].pinned
        assert eng.cache_clear(pinned=True) == 1
        assert eng.cache_info() == ()

    def test_pin_unknown_artifact_raises(self):
        eng = Engine(executor="reference")
        other = Engine(executor="reference")
        sc = FFNNScorer()
        compiled = other.compile(sc.program(1))
        with pytest.raises(ValueError):
            eng.pin(compiled)


# =========================================================================
# batched serving vs per-request oracle (tentpole acceptance)
# =========================================================================

@pytest.mark.parametrize("executor", EXECUTORS)
class TestScorerServing:
    def test_batched_matches_oracle(self, executor):
        eng = Engine(executor=executor)
        sc = FFNNScorer()
        server = TraServer(eng, sc)
        server.warmup()
        rng = np.random.default_rng(0)
        payloads = scorer_mix(sc, rng, 11)     # 8 + 3: two buckets
        results = server.serve(payloads)
        for p, r in zip(payloads, results):
            np.testing.assert_allclose(r, sc.oracle(p), atol=1e-5)

    def test_zero_cache_misses_after_warmup(self, executor):
        eng = Engine(executor=executor)
        sc = FFNNScorer()
        server = TraServer(eng, sc)
        server.warmup()
        rng = np.random.default_rng(1)
        for n in (1, 3, 8, 2, 5, 8, 1):        # every bucket, re-visited
            server.serve(scorer_mix(sc, rng, n))
        assert server.cache_misses_since_warmup == 0
        assert all(e.pinned for e in eng.cache_info())

    def test_bucket_padding_tail_is_inert(self, executor):
        """A request's scores do not depend on how much padding rides
        along: serve the same payload alone (bucket 1) and as the head
        of a 3-wide batch (bucket 4, one zero tail row)."""
        eng = Engine(executor=executor)
        sc = FFNNScorer()
        server = TraServer(eng, sc)
        server.warmup()
        rng = np.random.default_rng(2)
        p = sc.random_payload(rng)
        solo = server.serve([p])[0]
        others = scorer_mix(sc, rng, 2)
        batched = server.serve([p] + others)[0]
        np.testing.assert_allclose(batched, solo, atol=1e-5)


@pytest.mark.parametrize("executor", EXECUTORS)
class TestLmServing:
    def test_continuous_batching_matches_oracle(self, executor):
        eng = Engine(executor=executor)
        lm = small_lm(capacity=4)
        server = TraServer(eng, lm, collect_logits=True)
        server.warmup()
        rng = np.random.default_rng(3)
        reqs = lm_mix(lm, rng, 9, prompt_len=(1, 4), new_tokens=(1, 6))
        results = server.serve(reqs)
        for req, res in zip(reqs, results):
            toks, logs = lm.oracle_decode(req.prompt, req.max_new_tokens)
            assert res["tokens"] == toks
            for got, want in zip(res["logits"], logs):
                np.testing.assert_allclose(got, want, atol=1e-5)
        assert server.cache_misses_since_warmup == 0


class TestSlotLifecycle:
    def test_randomized_arrival_and_finish_orders(self):
        """Randomized admission with heterogeneous lifetimes: capacity
        is never exceeded, freed slots are reused, free state rows stay
        zero, and every response still matches its oracle."""
        eng = Engine(executor="jit")
        lm = small_lm(capacity=3)
        server = TraServer(eng, lm)
        server.warmup()
        rng = np.random.default_rng(4)
        reqs = lm_mix(lm, rng, 10, prompt_len=(1, 3), new_tokens=(1, 5))
        handles = []
        occupied_rids = set()
        it = iter(reqs)
        pending = next(it, None)
        while pending is not None or not server.idle():
            # trickle submissions in at random ticks
            while pending is not None and rng.random() < 0.6:
                handles.append(server.submit(pending))
                pending = next(it, None)
            server.step()
            live = [s for s in server._slots if s is not None]
            assert len(live) <= lm.capacity
            occupied_rids.update(s.handle.rid for s in live)
            state = np.asarray(server._state.data)
            for i, s in enumerate(server._slots):
                if s is None:                  # freed/never-used row: zero
                    np.testing.assert_allclose(state[i], 0.0)
        assert len(handles) == 10
        assert occupied_rids == {h.rid for h in handles}
        for h in handles:
            toks, _ = lm.oracle_decode(h.payload.prompt,
                                       h.payload.max_new_tokens)
            assert h.result(timeout=0)["tokens"] == toks

    def test_slot_reuse_after_eviction(self):
        eng = Engine(executor="reference")
        lm = small_lm(capacity=1)              # forced serialization
        server = TraServer(eng, lm)
        server.warmup()
        reqs = [LmRequest(prompt=[i + 1], max_new_tokens=2)
                for i in range(3)]
        results = server.serve(reqs)
        for req, res in zip(reqs, results):
            toks, _ = lm.oracle_decode(req.prompt, req.max_new_tokens)
            assert res["tokens"] == toks


class TestServerPlumbing:
    def test_step_servable_rejects_raw_payloads(self):
        server = TraServer(Engine(executor="reference"), small_lm())
        with pytest.raises(TypeError):
            server.submit([1, 2, 3])

    def test_failed_dispatch_fails_handles_not_server(self):
        eng = Engine(executor="reference")
        sc = FFNNScorer()
        server = TraServer(eng, sc)
        server.warmup()
        bad = server.submit(np.zeros(3, np.float32))   # wrong feature dim
        server.step()
        with pytest.raises(ValueError):
            bad.result(timeout=0)
        good = sc.random_payload(np.random.default_rng(0))
        ok = server.serve([good])              # server keeps serving
        np.testing.assert_allclose(ok[0], sc.oracle(good), atol=1e-5)
        assert server.idle()

    def test_stats_report_artifacts_and_dispatches(self):
        eng = Engine(executor="jit")
        sc = FFNNScorer()
        server = TraServer(eng, sc)
        server.warmup()
        rng = np.random.default_rng(5)
        server.serve(scorer_mix(sc, rng, 3))
        stats = server.stats()
        assert stats["servable"] == "ffnn-scorer"
        assert stats["cache_misses_since_warmup"] == 0
        assert sum(a["dispatches"] for a in stats["artifacts"]) == 1
        assert stats["requests"] == 3

    def test_background_thread_serving(self):
        eng = Engine(executor="reference")
        sc = FFNNScorer()
        server = TraServer(eng, sc)
        server.warmup()
        server.start()
        try:
            rng = np.random.default_rng(6)
            payloads = scorer_mix(sc, rng, 5)
            handles = [server.submit(p) for p in payloads]
            for p, h in zip(payloads, handles):
                np.testing.assert_allclose(h.result(timeout=30.0),
                                           sc.oracle(p), atol=1e-5)
        finally:
            server.stop()


# =========================================================================
# metering (satellite f) and loadgen
# =========================================================================

class TestMetering:
    def test_percentiles_interpolation(self):
        ps = percentiles(list(range(1, 101)))
        assert ps["p50"] == pytest.approx(50.5)
        assert ps["p99"] == pytest.approx(99.01)
        assert np.isnan(percentiles([])["p50"])

    def test_span_queue_wait_vs_service(self):
        t = [0.0]
        meter = SpanMeter(clock=lambda: t[0])
        span = meter.open("request")           # submit at t=0
        t[0] = 2.0
        meter.start(span)                      # admitted at t=2
        t[0] = 5.0
        meter.complete(span, tokens=6)
        assert span.queue_wait_s == pytest.approx(2.0)
        assert span.service_s == pytest.approx(3.0)
        assert span.total_s == pytest.approx(5.0)
        s = meter.summary()
        assert s["requests"] == 1 and s["tokens"] == 6
        assert s["queue_wait_ms"]["p50"] == pytest.approx(2000.0)
        assert s["service_ms"]["p50"] == pytest.approx(3000.0)

    def test_start_idempotent(self):
        t = [0.0]
        meter = SpanMeter(clock=lambda: t[0])
        span = meter.open("request")
        t[0] = 1.0
        meter.start(span)
        t[0] = 9.0
        meter.start(span)                      # later start must not move it
        assert span.t_start == pytest.approx(1.0)


class TestLoadgen:
    def test_poisson_arrivals_monotone_and_rate(self):
        rng = np.random.default_rng(7)
        arr = poisson_arrivals(rng, 2000, rate_per_s=100.0)
        assert all(b >= a for a, b in zip(arr, arr[1:]))
        assert arr[-1] == pytest.approx(20.0, rel=0.2)

    def test_open_loop_serves_all(self):
        eng = Engine(executor="jit")
        sc = FFNNScorer()
        server = TraServer(eng, sc)
        server.warmup()
        rng = np.random.default_rng(8)
        payloads = scorer_mix(sc, rng, 16)
        rep = open_loop(server, payloads,
                        poisson_arrivals(rng, 16, rate_per_s=4000.0))
        assert rep.requests == 16 and rep.errors == 0
        assert rep.summary["requests"] == 16
        assert server.cache_misses_since_warmup == 0

    def test_closed_loop_counts_errors(self):
        eng = Engine(executor="reference")
        sc = FFNNScorer()
        server = TraServer(eng, sc)
        server.warmup()
        good = sc.random_payload(np.random.default_rng(9))
        bad = np.zeros(2, np.float32)
        rep = closed_loop(server,
                          lambda i: bad if i == 1 else good,
                          n_requests=4, concurrency=2)
        assert rep.requests == 4
        assert rep.errors >= 1
        assert server.idle()
