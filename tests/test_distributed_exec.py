"""Run the 8-device distributed executor checks in a subprocess.

The subprocess sets ``--xla_force_host_platform_device_count=8`` before
importing jax; the main pytest process keeps its single-device view.
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)


@pytest.mark.slow
def test_distributed_executors():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_distributed_checks.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL DISTRIBUTED CHECKS PASSED" in proc.stdout


@pytest.mark.slow
def test_distributed_models():
    """Sharded-vs-unsharded train step, GPipe, elastic re-mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_distributed_checks2.py")],
        capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL MODEL DISTRIBUTED CHECKS PASSED" in proc.stdout
