"""Locks the paper-reproduction results into the test suite: the
benchmark tables must match the paper to the digit, forever."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest


def test_table4_matmul_costs_exact():
    from benchmarks.matmul import predicted_costs
    for rec in predicted_costs():
        for plan in ("BMM", "CPMM", "RMM"):
            assert rec[f"match_{plan}"], (rec["shape"], plan, rec[plan])


def test_table4_two_phase_beats_paper_cpmm():
    from benchmarks.matmul import predicted_costs
    for rec in predicted_costs():
        assert rec["CPMM-2phase(beyond-paper)"] <= rec["CPMM"]


def test_table9_ffnn_costs_and_decisions_exact():
    from benchmarks.ffnn import TABLE9, predicted_costs
    from repro.configs.ffnn_paper import SPEECH_GRID, XML_GRID
    for cfg in list(SPEECH_GRID) + list(XML_GRID):
        costs = predicted_costs(cfg)
        want_winner, want_dp, want_mp = TABLE9[cfg.name]
        assert abs(costs["TRA-DP"] - want_dp) / want_dp < 0.05, cfg.name
        assert abs(costs["TRA-MP"] - want_mp) / want_mp < 0.05, cfg.name
        winner = "dp" if costs["TRA-DP"] < costs["TRA-MP"] else "mp"
        assert winner == want_winner, cfg.name


def test_nn_search_wide_picks_horizontal():
    from benchmarks.nn_search import predicted_costs
    recs = {r["shape"]: r for r in predicted_costs()}
    assert recs["Wide"]["winner"] == "Opt4Horizontal"
    # our optimizer's Horizontal-Large plan is at least as cheap as
    # Vertical (beats the paper's hand-compiled 7.2e10 plan)
    assert recs["Large"]["Opt4Horizontal"] <= \
        recs["Large"]["Opt4Vertical"]


def test_fp8_kv_cache_decode():
    from repro.configs import SMOKES
    from repro.models import decode_step, forward, init_params, prefill

    cfg = dataclasses.replace(SMOKES["qwen2.5-14b"],
                              kv_cache_dtype="float8_e4m3fn")
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S, CL = 2, 16, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                              cfg.vocab_size)
    lp, cache = prefill(cfg, params, {"tokens": toks[:, :S]}, CL)
    assert cache["blocks"]["k"].dtype == jnp.float8_e4m3fn
    ls, _ = decode_step(cfg, params, cache, {"token": toks[:, S:S + 1]})
    lf = forward(cfg, params, {"tokens": toks})
    scale = float(jnp.max(jnp.abs(lf))) + 1.0
    rel = float(jnp.max(jnp.abs(ls[:, 0] - lf[:, S]))) / scale
    assert rel < 0.10, rel          # fp8 cache: bounded quality cost
