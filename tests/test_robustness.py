"""Fault-tolerant TRA execution (the robustness tentpole).

Covers the ISSUE-6 acceptance criteria at tier-1 scale (single device;
the 8-device elastic re-mesh resume lives in
``tests/_distributed_checks.py`` behind the ``slow`` marker):

* a ``TraTrainer`` run killed mid-``fit`` by an injected
  ``SimulatedFailure`` recovers from the last committed checkpoint and —
  including when a *fresh* trainer resumes in a "new process" — matches
  the uninterrupted oracle's per-step losses at 1e-5;
* injected device OOM in the fused contraction completes via the halving
  streamed-chunk backoff ladder with correct results on every executor;
* ``check_numerics`` attributes an injected (and a data-borne) NaN to
  the exact TRA node that produced it;
* the executor compile-failure fallback ladder degrades with one
  ``RuntimeWarning`` and never shadows a later successful compile of the
  preferred executor (degraded artifacts are cached under their own key);
* ``CheckpointStore.save_async`` surfaces background-write failures on
  the next ``wait()``/``save_async()`` (regression for the silent-swallow
  bug);
* the trainer's bounded skip-step policy for non-finite losses.

Everything here is deterministic: faults are scripted on a
``FaultInjector`` and keyed on plan-signature node ids / run indices.
"""
import warnings

import jax
import numpy as np
import pytest

import repro.core as tra
from repro.core import (AdamW, Engine, TensorRelation, TraTrainer,
                        from_tensor)
from repro.core.engine import DEFAULT_OOM_LADDER_START
from repro.core.faults import (CompileFailure, DeviceOOM, FaultInjector,
                               SimulatedFailure)
from repro.core.guards import NumericsError, label_nodes
from repro.core.plan import as_node
from repro.core.programs import ffnn_train_step_tra
from repro.checkpoint import CheckpointStore

pytestmark = pytest.mark.faults

S = ("sites",)
DIMS = (4, 2, 2, 2, 4, 4, 4, 2)


def _mesh1():
    from repro.launch.mesh import make_mesh
    return make_mesh((1,), S)


def _bmm_expr():
    A = tra.input("A", key_shape=(4, 3), bound=(2, 2))
    B = tra.input("B", key_shape=(3, 5), bound=(2, 2))
    return A @ B


def _bmm_data(nan_in_a=False):
    rng = np.random.default_rng(0)
    A = rng.normal(size=(4, 3, 2, 2)).astype(np.float32)
    B = rng.normal(size=(3, 5, 2, 2)).astype(np.float32)
    if nan_in_a:
        A[1, 2, 0, 1] = np.nan
    return A, B


def _train_fixture():
    nb, db, hb, lb, bn, bd, bh, bl = DIMS
    X = jax.random.normal(jax.random.PRNGKey(0), (nb * bn, db * bd))
    Wt = jax.random.normal(jax.random.PRNGKey(4), (db * bd, lb * bl)) * 0.5
    Y = jax.nn.sigmoid(X @ Wt)
    W1 = jax.random.normal(jax.random.PRNGKey(2), (db * bd, hb * bh)) * 0.3
    W2 = jax.random.normal(jax.random.PRNGKey(3), (hb * bh, lb * bl)) * 0.3
    data = dict(X=from_tensor(X, (bn, bd)), Y=from_tensor(Y, (bn, bl)))

    def params():
        return dict(W1=from_tensor(W1, (bd, bh)),
                    W2=from_tensor(W2, (bh, bl)))

    def trainer(engine, **kw):
        return TraTrainer(engine, ffnn_train_step_tra(
            *DIMS, optimizer=AdamW(1e-2)), params=params(), **kw)

    return data, trainer


# ==========================================================================
# Checkpoint / resume
# ==========================================================================

def test_kill_midrun_resumes_and_matches_oracle(tmp_path):
    """SimulatedFailure at run 5 → auto-recovery from the last committed
    step; a FRESH trainer (new engine) then resumes to 8 total steps and
    the full trajectory matches the uninterrupted oracle at 1e-5."""
    data, trainer = _train_fixture()
    oracle = trainer(Engine(executor="jit", optimize=False)).fit(8, **data)

    store = CheckpointStore(str(tmp_path / "ckpt"), keep=5)
    inj = FaultInjector().inject_site_failure(step=5)
    tr = trainer(Engine(executor="jit", optimize=False, fault_injector=inj),
                 store=store)
    h = tr.fit(6, ckpt_every=2, **data)
    assert inj.log == [("site", "run 5")]
    assert len(h) == 6 and tr.step_count == 6
    np.testing.assert_allclose(h, oracle[:6], atol=1e-5)

    tr2 = trainer(Engine(executor="jit", optimize=False), store=store)
    h2 = tr2.fit(8, resume=True, **data)
    assert tr2.step_count == 8
    np.testing.assert_allclose(h2, oracle, atol=1e-5)


def test_resume_on_empty_store_starts_fresh(tmp_path):
    data, trainer = _train_fixture()
    store = CheckpointStore(str(tmp_path / "ckpt"))
    tr = trainer(Engine(executor="jit", optimize=False), store=store)
    h = tr.fit(3, resume=True, ckpt_every=2, **data)
    assert len(h) == 3 and tr.step_count == 3
    assert store.latest_step() is not None


def test_failure_before_first_periodic_checkpoint_recovers(tmp_path):
    """fit commits the initial state, so a kill before the first periodic
    snapshot restores to step 0 instead of crashing unrecoverably."""
    data, trainer = _train_fixture()
    oracle = trainer(Engine(executor="jit", optimize=False)).fit(3, **data)
    store = CheckpointStore(str(tmp_path / "ckpt"))
    inj = FaultInjector().inject_site_failure(step=1)
    tr = trainer(Engine(executor="jit", optimize=False, fault_injector=inj),
                 store=store)
    h = tr.fit(3, ckpt_every=10, **data)
    np.testing.assert_allclose(h, oracle, atol=1e-5)


def test_unrecoverable_without_store():
    data, trainer = _train_fixture()
    inj = FaultInjector().inject_site_failure(step=1)
    tr = trainer(Engine(executor="jit", optimize=False, fault_injector=inj))
    with pytest.raises(SimulatedFailure):
        tr.fit(4, **data)


def test_store_async_write_failure_surfaces(tmp_path, monkeypatch):
    """Regression: a failed background write must raise on the next
    wait()/save_async(), never be silently swallowed."""
    store = CheckpointStore(str(tmp_path / "ckpt"))

    def boom(step, leaves, treedef, extra):
        raise OSError("injected I/O error: disk full")

    monkeypatch.setattr(store, "_write", boom)
    store.save_async(1, {"w": np.zeros(3)})
    with pytest.raises(OSError, match="disk full"):
        store.wait()
    # the error is consumed once — the store is usable again
    monkeypatch.undo()
    store.save_async(2, {"w": np.zeros(3)})
    store.wait()
    assert store.latest_step() == 2

    # surfaced by the next save_async too (not only explicit wait)
    monkeypatch.setattr(store, "_write", boom)
    store.save_async(3, {"w": np.zeros(3)})
    with pytest.raises(OSError, match="disk full"):
        store.save_async(4, {"w": np.zeros(3)})


# ==========================================================================
# Numeric guards with plan provenance
# ==========================================================================

@pytest.mark.parametrize("executor", ["reference", "jit"])
def test_injected_nan_attributed_to_exact_node(executor):
    """check_numerics names the first TRA node that produced the NaN —
    here the fused Σ∘⋈ contraction the optimizer selected."""
    inj = FaultInjector().inject_nan(node="FusedJoinAgg", times=-1)
    eng = Engine(executor=executor, fault_injector=inj, check_numerics=True)
    A, B = _bmm_data()
    with pytest.raises(NumericsError) as ei:
        eng.run(_bmm_expr(), A=A, B=B)
    assert "FusedJoinAgg" in str(ei.value)
    assert ei.value.node_label is not None
    # the label carries the plan-signature node id prefix ("2:FusedJoinAgg…")
    nid = int(ei.value.node_label.split(":")[0])
    assert nid >= 0


@pytest.mark.parametrize("executor", ["reference", "jit"])
def test_data_borne_nan_attributed_to_input_node(executor):
    """A NaN arriving IN the data (no injector) is attributed to the input
    node — postorder checking names the producer, not a consumer."""
    eng = Engine(executor=executor, check_numerics=True)
    A, B = _bmm_data(nan_in_a=True)
    with pytest.raises(NumericsError) as ei:
        eng.run(_bmm_expr(), A=A, B=B)
    assert "Input[A]" in str(ei.value)


@pytest.mark.parametrize("executor", ["gspmd", "shard_map"])
def test_distributed_executors_check_outputs(executor):
    """The distributed executors get output-level finite checks (per-node
    probes would perturb the collective schedule under test)."""
    eng = Engine(_mesh1(), executor=executor, check_numerics=True)
    A, B = _bmm_data(nan_in_a=True)
    with pytest.raises(NumericsError, match="output"):
        eng.run(_bmm_expr(), A=A, B=B)


def test_check_numerics_off_is_silent():
    A, B = _bmm_data(nan_in_a=True)
    out = Engine(executor="jit").run(_bmm_expr(), A=A, B=B)
    assert np.isnan(np.asarray(out.data)).any()


def test_check_numerics_all_mode_attributes_in_primary_program():
    """check_numerics="all" carries the per-node flags in the primary jit
    program (no attribution re-run) and names the same exact node the
    default two-tier mode finds."""
    A, B = _bmm_data()
    labels = {}
    for mode in (True, "all"):
        inj = FaultInjector().inject_nan(node="FusedJoinAgg", times=-1)
        eng = Engine(executor="jit", fault_injector=inj,
                     check_numerics=mode)
        with pytest.raises(NumericsError) as ei:
            eng.run(_bmm_expr(), A=A, B=B)
        assert "FusedJoinAgg" in str(ei.value)
        labels[mode] = ei.value.node_label
    assert labels[True] == labels["all"]


def test_skip_step_policy_matches_oracle_and_bounds():
    """Two scoped NaN steps are skipped without advancing params/state;
    the applied trajectory equals the oracle.  An unbounded NaN stream
    exhausts the consecutive-skip budget and raises."""
    data, trainer = _train_fixture()
    oracle = trainer(Engine(executor="reference", optimize=False)) \
        .fit(4, **data)

    inj = FaultInjector() \
        .inject_nan(node="TraAgg", times=1) \
        .inject_nan(node="TraAgg", times=1)
    inj._faults[0].step = 1
    inj._faults[1].step = 2
    eng = Engine(executor="reference", optimize=False, fault_injector=inj,
                 check_numerics=True)
    tr = trainer(eng, skip_nonfinite=3)
    h = tr.fit(4, **data)
    assert len(tr.skipped) == 2
    np.testing.assert_allclose(h, oracle, atol=1e-5)

    inj2 = FaultInjector().inject_nan(node="TraAgg", times=-1)
    eng2 = Engine(executor="reference", optimize=False, fault_injector=inj2,
                  check_numerics=True)
    tr2 = trainer(eng2, skip_nonfinite=2)
    with pytest.raises(NumericsError, match="consecutive non-finite"):
        tr2.fit(4, **data)
    assert tr2.step_count == 0          # params never advanced


# ==========================================================================
# Graceful degradation: OOM chunk ladder + executor fallback
# ==========================================================================

@pytest.mark.parametrize("executor", ["reference", "jit", "gspmd",
                                      "shard_map"])
def test_oom_ladder_completes_on_all_executors(executor):
    """Injected device OOM (fits only at streaming chunk <= 2) degrades
    through the halving ladder and completes with correct results."""
    mesh = _mesh1() if executor in ("gspmd", "shard_map") else None
    A, B = _bmm_data()
    base = Engine(executor="reference").run(_bmm_expr(), A=A, B=B).data

    inj = FaultInjector().inject_oom(ok_chunk=2)
    eng = Engine(mesh, executor=executor, fault_injector=inj, degrade=True)
    with pytest.warns(RuntimeWarning, match="streamed"):
        out = eng.run(_bmm_expr(), A=A, B=B).data
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=1e-4)
    # the ladder actually walked: unstreamed attempt plus halving chunks
    ooms = [d for k, d in inj.log if k == "oom"]
    assert any("unstreamed" in d for d in ooms)
    assert any(f"chunk={DEFAULT_OOM_LADDER_START}" in d for d in ooms)


def test_oom_propagates_without_degrade():
    inj = FaultInjector().inject_oom(ok_chunk=2)
    eng = Engine(executor="jit", fault_injector=inj)
    A, B = _bmm_data()
    with pytest.raises(DeviceOOM):
        eng.run(_bmm_expr(), A=A, B=B)


def test_compile_fallback_warns_and_is_not_shadowed():
    """Satellite: a degraded artifact is cached under the fallback key, so
    the preferred executor is retried and a later successful compile is
    not shadowed by the degraded entry."""
    inj = FaultInjector().inject_compile_failure(executor="jit", times=1)
    eng = Engine(executor="jit", fault_injector=inj, degrade=True)
    A, B = _bmm_data()
    base = Engine(executor="reference").run(_bmm_expr(), A=A, B=B).data

    with pytest.warns(RuntimeWarning, match="degraded to executor"):
        c1 = eng.compile(_bmm_expr())
    assert c1.executor == "reference" and c1.degraded_from == "jit"
    np.testing.assert_allclose(np.asarray(c1.run(A=A, B=B).data),
                               np.asarray(base), atol=1e-5)

    # fault budget spent → the preferred executor compiles cleanly now
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        c2 = eng.compile(_bmm_expr())
    assert c2.executor == "jit" and c2.degraded_from is None


def test_distributed_compile_fallback_ladder():
    """gspmd → jit rung of the ladder (single-device mesh)."""
    inj = FaultInjector().inject_compile_failure(executor="gspmd", times=1)
    eng = Engine(_mesh1(), executor="gspmd", fault_injector=inj,
                 degrade=True)
    with pytest.warns(RuntimeWarning, match="degraded to executor 'jit'"):
        c = eng.compile(_bmm_expr())
    assert c.executor == "jit" and c.degraded_from == "gspmd"


def test_compile_failure_propagates_without_degrade():
    inj = FaultInjector().inject_compile_failure(executor="jit", times=1)
    eng = Engine(executor="jit", fault_injector=inj)
    with pytest.raises(CompileFailure):
        eng.compile(_bmm_expr())


def test_user_errors_never_degrade():
    """ValueError (user error) must propagate, not walk the ladder."""
    eng = Engine(executor="jit", degrade=True)
    with pytest.raises(ValueError, match="chunk must be >= 1"):
        eng.compile(_bmm_expr(), chunk=0)


# ==========================================================================
# Injector mechanics
# ==========================================================================

def test_straggler_delays_but_succeeds():
    inj = FaultInjector().inject_straggler(step=1, delay=0.01)
    eng = Engine(executor="jit", fault_injector=inj)
    A, B = _bmm_data()
    eng.run(_bmm_expr(), A=A, B=B)
    eng.run(_bmm_expr(), A=A, B=B)      # delayed, not failed
    assert inj.log == [("straggler", "run 1 +0.01s")]
    assert inj.runs == 2


def test_node_ids_match_plan_signature_postorder():
    """label_nodes numbering is the plan_sig postorder (shared subtrees
    numbered once, multi-root numbering continues across roots)."""
    A = tra.input("A", key_shape=(2, 2), bound=(3, 3))
    B = tra.input("B", key_shape=(2, 2), bound=(3, 3))
    shared = A @ B
    r1, r2 = as_node(shared + A), as_node(shared)
    labels = label_nodes((r1, r2))
    nids = sorted(nid for nid, _ in labels.values())
    assert nids == list(range(len(labels)))     # dense, deduped
    # the shared subtree keeps its first-root id in the second root
    assert labels[id(r2)][0] < len(labels)
    by_label = {lab for _, lab in labels.values()}
    assert any("TraInput[A]" in lab for lab in by_label)


def test_fault_budget_times_is_respected():
    inj = FaultInjector().inject_site_failure(step=0, times=1)
    eng = Engine(executor="jit", fault_injector=inj)
    A, B = _bmm_data()
    with pytest.raises(SimulatedFailure):
        eng.run(_bmm_expr(), A=A, B=B)
    # budget spent; same run index logic never refires
    out = eng.run(_bmm_expr(), A=A, B=B)
    assert out.data.shape == (4, 5, 2, 2)
