"""Serving resilience: admission control, deadlines, fault recovery.

TraServer under the PR-6 fault model.  The load-bearing guarantees:

* **admission** — over ``max_pending`` a submission is shed instantly
  with :class:`ServerOverloaded` (never queued), and ``max_queue_wait_s``
  bounds queue residence even when the scheduler window never reaches
  the request;
* **withdrawal** — ``cancel()`` and ``deadline_s=`` release the pending
  count *and* the decode slot (state row zeroed), whether the request is
  still queued or mid-decode, and never disturb its neighbours;
* **fault isolation** — transient faults (site failure, OOM, NaN trips)
  are retried under a per-request budget with the decode state rewound
  to the last good tick, so recovered responses are *bit-identical* to
  the fault-free oracle; permanent errors fail only their victims and
  the server keeps serving;
* **containment** — a crashed or hung scheduler fails every in-flight
  handle with a chained diagnostic instead of stranding callers, and
  :meth:`TraServer.health` reports it.

Every test asserts the server drains clean: ``pending == 0``, no
occupied slots, free state rows zero.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import Engine
from repro.core.faults import (CompileFailure, DeviceOOM, FaultInjector,
                               SimulatedFailure, is_transient)
from repro.core.guards import NumericsError
from repro.launch.metering import SpanMeter
from repro.serve import (DeadlineExceeded, FFNNScorer, LmRequest,
                         RecurrentLM, RequestCancelled, RetryBudgetExceeded,
                         ServerOverloaded, ServerStopped, TraServer)

pytestmark = pytest.mark.faults


def small_lm(capacity=2):
    return RecurrentLM(d_model=16, vocab_size=32, capacity=capacity)


def scorer_server(inj=None, **kw):
    eng = Engine(executor="reference", fault_injector=inj)
    sc = FFNNScorer()
    server = TraServer(eng, sc, **kw)
    server.warmup()
    return server, sc


def lm_server(inj=None, capacity=2, check_numerics=False, **kw):
    eng = Engine(executor="reference", fault_injector=inj,
                 check_numerics=check_numerics)
    lm = small_lm(capacity)
    server = TraServer(eng, lm, **kw)
    server.warmup()
    return server, lm


def assert_drained(server):
    """The invariant every test ends on: nothing leaked."""
    assert server._pending == 0 and server.idle()
    assert not server._waiting
    if hasattr(server, "_slots"):
        assert all(s is None for s in server._slots)
        np.testing.assert_allclose(np.asarray(server._state.data), 0.0)


# =========================================================================
# fault taxonomy (core/faults.py)
# =========================================================================

class TestTaxonomy:
    def test_is_transient_classification(self):
        assert is_transient(SimulatedFailure("site died"))
        assert is_transient(DeviceOOM("oom"))
        assert is_transient(CompileFailure("flake"))
        assert is_transient(NumericsError("nan at T[join]"))
        assert not is_transient(TypeError("bad payload"))
        assert not is_transient(ValueError("shape mismatch"))
        assert not is_transient(KeyError("missing input"))

    def test_periodic_site_fault_fires_every_nth_run(self):
        inj = FaultInjector().inject_site_failure(every=3, times=-1)
        fired = []
        for idx in range(8):
            try:
                inj.on_run()
            except SimulatedFailure:
                fired.append(idx)
        assert fired == [3, 6]            # run 0 always survives

    def test_step_scoped_fault_fires_once(self):
        inj = FaultInjector().inject_site_failure(step=1)
        inj.on_run()
        with pytest.raises(SimulatedFailure):
            inj.on_run()
        inj.on_run()                      # budget spent


# =========================================================================
# admission control & shedding
# =========================================================================

class TestAdmission:
    def test_over_max_pending_sheds_fast(self):
        server, sc = scorer_server(max_pending=2)
        rng = np.random.default_rng(0)
        kept = [server.submit(sc.random_payload(rng)) for _ in range(2)]
        t0 = time.perf_counter()
        shed = server.submit(sc.random_payload(rng))
        shed_ms = (time.perf_counter() - t0) * 1e3
        assert shed.done() and shed_ms < 10.0          # fast-fail SLO
        with pytest.raises(ServerOverloaded, match="shed"):
            shed.result(timeout=0)
        assert shed.span.outcome == "shed"
        assert server.counters["shed"] == 1
        assert server._pending == 2                    # shed never counted
        server.run_until_idle()
        for h in kept:
            np.testing.assert_allclose(h.result(timeout=0),
                                       sc.oracle(h.payload), atol=1e-5)
        assert_drained(server)

    def test_max_queue_wait_sheds_stale_requests(self):
        t = [0.0]
        meter = SpanMeter(clock=lambda: t[0])
        server, sc = scorer_server(meter=meter, max_queue_wait_s=1.0)
        rng = np.random.default_rng(1)
        stale = server.submit(sc.random_payload(rng))
        t[0] = 2.0                        # queued past the wait bound
        fresh = server.submit(sc.random_payload(rng))
        server.run_until_idle()
        with pytest.raises(ServerOverloaded, match="max_queue_wait"):
            stale.result(timeout=0)
        assert server.counters["shed"] == 1
        np.testing.assert_allclose(fresh.result(timeout=0),
                                   sc.oracle(fresh.payload), atol=1e-5)
        assert_drained(server)

    def test_serve_mixed_shed_retried_completed(self):
        inj = FaultInjector().inject_site_failure(step=0)
        server, sc = scorer_server(inj, max_pending=2)
        rng = np.random.default_rng(2)
        payloads = [sc.random_payload(rng) for _ in range(4)]
        results = server.serve(payloads, return_exceptions=True)
        assert [isinstance(r, ServerOverloaded) for r in results] == \
            [False, False, True, True]
        for p, r in zip(payloads[:2], results[:2]):
            np.testing.assert_allclose(r, sc.oracle(p), atol=1e-5)
        assert server.counters["shed"] == 2
        assert server.counters["transient_faults"] == 1
        assert server.counters["recovered"] == 2       # both retried once
        assert_drained(server)


# =========================================================================
# cancellation & deadlines (satellite: lifecycle coverage)
# =========================================================================

class TestCancellation:
    def test_cancel_while_queued_fails_immediately(self):
        server, sc = scorer_server()
        h = server.submit(sc.random_payload(np.random.default_rng(3)))
        assert h.cancel() and h.done() and h.cancelled()
        with pytest.raises(RequestCancelled, match="while queued"):
            h.result(timeout=0)
        assert h.cancel() is False        # already finished
        assert server.counters["cancelled"] == 1
        assert_drained(server)

    def test_cancel_mid_decode_frees_slot_and_zeroes_row(self):
        server, lm = lm_server(capacity=2)
        victim = server.submit(LmRequest([3, 1, 4], 8))
        neighbour = server.submit(LmRequest([2, 7], 3))
        for _ in range(2):                # both mid-decode now
            server.step()
        assert server._slots[0].handle is victim
        assert victim.cancel()
        assert not victim.done()          # eviction happens at next tick
        server.step()
        with pytest.raises(RequestCancelled, match="slot 0 freed"):
            victim.result(timeout=0)
        assert server._slots[0] is None   # slot reclaimed
        np.testing.assert_allclose(       # state row zeroed
            np.asarray(server._state.data)[0], 0.0)
        server.run_until_idle()           # neighbour rides on undisturbed
        toks, _ = lm.oracle_decode([2, 7], 3)
        assert neighbour.result(timeout=0)["tokens"] == toks
        assert server.counters["cancelled"] == 1
        assert_drained(server)

    def test_deadline_expiry_under_saturated_server(self):
        t = [0.0]
        meter = SpanMeter(clock=lambda: t[0])
        server, lm = lm_server(capacity=1, meter=meter)
        hog = server.submit(LmRequest([1, 2], 6))
        server.step()                     # hog takes the only slot
        doomed = server.submit(LmRequest([5], 2), deadline_s=1.0)
        server.step()                     # still queued: capacity 1
        assert not doomed.done()
        t[0] = 2.0                        # deadline passes while queued
        server.step()
        with pytest.raises(DeadlineExceeded, match="missed its deadline"):
            doomed.result(timeout=0)
        assert server.counters["deadline_expired"] == 1
        server.run_until_idle()
        toks, _ = lm.oracle_decode([1, 2], 6)
        assert hog.result(timeout=0)["tokens"] == toks
        assert_drained(server)

    def test_deadline_expiry_mid_decode_reclaims_slot(self):
        t = [0.0]
        meter = SpanMeter(clock=lambda: t[0])
        server, lm = lm_server(capacity=2, meter=meter)
        doomed = server.submit(LmRequest([3, 3, 3], 50), deadline_s=1.0)
        safe = server.submit(LmRequest([4, 2], 4))
        server.step()                     # both slotted, decoding
        t[0] = 5.0
        server.step()                     # sweep evicts the expired seq
        with pytest.raises(DeadlineExceeded, match="mid-decode"):
            doomed.result(timeout=0)
        assert server._slots[0] is None
        np.testing.assert_allclose(np.asarray(server._state.data)[0], 0.0)
        server.run_until_idle()
        toks, _ = lm.oracle_decode([4, 2], 4)
        assert safe.result(timeout=0)["tokens"] == toks
        assert server.counters["deadline_expired"] == 1
        assert_drained(server)


# =========================================================================
# fault-isolated retry (tentpole)
# =========================================================================

class TestRetry:
    def test_batch_transient_fault_retried_matches_oracle(self):
        inj = FaultInjector().inject_site_failure(step=0)
        server, sc = scorer_server(inj)
        rng = np.random.default_rng(4)
        payloads = [sc.random_payload(rng) for _ in range(2)]
        results = server.serve(payloads)
        for p, r in zip(payloads, results):
            np.testing.assert_allclose(r, sc.oracle(p), atol=1e-5)
        assert inj.log == [("site", "run 0")]
        assert server.counters["transient_faults"] == 1
        assert server.counters["recovered"] == 2
        assert server.health()["status"] == "degraded"  # recent fault
        assert_drained(server)

    def test_retry_budget_exhaustion_chains_fault(self):
        inj = (FaultInjector()
               .inject_site_failure(step=0)
               .inject_site_failure(every=1, times=-1))  # every run fails
        server, sc = scorer_server(inj, max_retries=2)
        h = server.submit(sc.random_payload(np.random.default_rng(5)))
        server.run_until_idle()
        with pytest.raises(RetryBudgetExceeded, match="after 2 retries"):
            h.result(timeout=0)
        assert isinstance(h._error.__cause__, SimulatedFailure)
        assert h.retries == 3             # budget + the exhausting charge
        assert server.counters["retry_exhausted"] == 1
        assert_drained(server)

    def test_batch_permanent_error_fails_without_retry(self):
        server, sc = scorer_server()
        sc.pack = lambda *a, **k: (_ for _ in ()).throw(
            TypeError("bad payload"))
        h = server.submit(sc.random_payload(np.random.default_rng(6)))
        server.run_until_idle()
        with pytest.raises(TypeError, match="bad payload"):
            h.result(timeout=0)
        assert h.retries == 0
        assert server.counters["transient_faults"] == 0
        assert_drained(server)

    def test_decode_site_fault_rewinds_one_tick_not_progress(self):
        """A site failure mid-decode restores the last committed state
        snapshot; both sequences resume and finish bit-identical to the
        fault-free oracle — the tick was retried, not the requests."""
        inj = FaultInjector().inject_site_failure(step=2)
        server, lm = lm_server(inj, capacity=2, max_retries=3)
        reqs = [LmRequest([3, 1, 4], 4), LmRequest([2, 7], 3)]
        handles = [server.submit(r) for r in reqs]
        server.run_until_idle()
        for req, h in zip(reqs, handles):
            toks, _ = lm.oracle_decode(req.prompt, req.max_new_tokens)
            assert h.result(timeout=0)["tokens"] == toks
        assert ("site", "run 2") in inj.log
        assert server.counters["transient_faults"] == 1
        assert server.counters["recovered"] == 2
        assert all(h.retries == 1 for h in handles)
        assert_drained(server)

    def test_decode_nan_fault_recovers_through_numeric_guards(self):
        """An injected NaN trips check_numerics (NumericsError names the
        poisoned node); the server classifies it transient, rewinds the
        tick, and the clean retry matches the oracle."""
        inj = FaultInjector().inject_nan(node="relu", times=1)
        server, lm = lm_server(inj, capacity=2, check_numerics=True)
        req = LmRequest([5, 9], 4)
        h = server.submit(req)
        server.run_until_idle()
        toks, _ = lm.oracle_decode(req.prompt, req.max_new_tokens)
        assert h.result(timeout=0)["tokens"] == toks
        assert server.counters["transient_faults"] >= 1
        assert server.counters["recovered"] == 1
        assert_drained(server)

    def test_decode_permanent_error_fails_victims_keeps_serving(self):
        server, lm = lm_server(capacity=2)
        orig = lm.step_inputs
        calls = {"n": 0}

        def flaky(tokens):
            calls["n"] += 1
            if calls["n"] == 1:
                raise TypeError("poisoned inputs")
            return orig(tokens)

        lm.step_inputs = flaky
        victim = server.submit(LmRequest([1], 2))
        server.run_until_idle()
        with pytest.raises(TypeError, match="poisoned inputs"):
            victim.result(timeout=0)
        assert victim.retries == 0        # permanent: no retry charged
        survivor = server.submit(LmRequest([6, 2], 3))
        server.run_until_idle()
        toks, _ = lm.oracle_decode([6, 2], 3)
        assert survivor.result(timeout=0)["tokens"] == toks
        assert_drained(server)


# =========================================================================
# crash containment & watchdog (tentpole + satellite: black-hole fix)
# =========================================================================

class TestContainment:
    def test_scheduler_crash_fails_inflight_with_diagnostic(self):
        server, sc = scorer_server()
        boom = RuntimeError("scheduler exploded")
        server.step = lambda: (_ for _ in ()).throw(boom)
        h = server.submit(sc.random_payload(np.random.default_rng(7)))
        server.start(tick_wait_s=0.001)
        with pytest.raises(RuntimeError, match="scheduler crashed") as ei:
            h.result(timeout=5.0)
        assert ei.value.__cause__ is boom
        assert server.counters["scheduler_crashes"] == 1
        assert server.health()["status"] == "stopped"
        with pytest.raises(ServerStopped):
            server.submit(sc.random_payload(np.random.default_rng(7)))
        server.stop()
        assert server._pending == 0

    def test_watchdog_trips_on_hung_scheduler(self):
        server, sc = scorer_server()
        release = threading.Event()
        server.step = lambda: release.wait(10.0) and 0  # hung dispatch
        h = server.submit(sc.random_payload(np.random.default_rng(8)))
        server.start(tick_wait_s=0.001, watchdog_timeout_s=0.15)
        with pytest.raises(RuntimeError, match="watchdog"):
            h.result(timeout=5.0)
        assert server.counters["watchdog_trips"] == 1
        assert server.health()["status"] == "stopped"
        release.set()                     # let the hung thread drain
        server.stop()
        assert server._pending == 0

    def test_watchdog_quiet_while_healthy(self):
        server, sc = scorer_server()
        rng = np.random.default_rng(9)
        server.start(tick_wait_s=0.001, watchdog_timeout_s=1.0)
        handles = [server.submit(sc.random_payload(rng)) for _ in range(5)]
        for hd in handles:
            np.testing.assert_allclose(hd.result(timeout=10.0),
                                       sc.oracle(hd.payload), atol=1e-5)
        server.stop()
        assert server.counters["watchdog_trips"] == 0
        assert server.health()["status"] == "stopped"  # explicit stop()
        assert_drained(server)
