"""TRA sharding planner tests: the paper's cost model must *derive* the
right strategies on the right shapes."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import CONFIGS, SHAPES, SMOKES
from repro.models import param_shapes
from repro.sharding import (batch_pspecs, cache_pspecs, make_sharder,
                            param_pspecs, plan_arch, price_moe, price_pair,
                            zero1_pspecs)
from repro.sharding.planner import PairDecision


def small_mesh():
    # 1 real device is fine: specs/plan logic never allocates
    dev = jax.devices()[:1]
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.array(dev).reshape(1, 1), ("data", "model"))


def test_price_pair_dp_wins_small_replicable():
    d = price_pair(1_000_000, 768, 1536, 768, 16, 16,
                   allow_replicated=True)
    assert d.strategy == "dp"
    assert d.cost == 0


def test_price_pair_sharded_when_gated():
    d = price_pair(1_000_000, 5120, 13824, 5120, 16, 16,
                   allow_replicated=False)
    assert d.strategy in ("tp", "fsdp")
    assert d.cost > 0
    assert all(c > 0 for _, c in d.candidates)


def test_price_pair_decode_prefers_weights_in_place():
    # 128 decode tokens: activation collectives are tiny; moving weights
    # (FSDP gather ≫) must lose
    d = price_pair(128, 5120, 13824, 5120, 16, 16, allow_replicated=False)
    assert d.strategy == "tp"
    assert not d.w_moved


def test_price_pair_train_vs_decode_costs_scale():
    train = price_pair(65536, 4096, 16384, 4096, 16, 16,
                       allow_replicated=False)
    dec = price_pair(128, 4096, 16384, 4096, 16, 16,
                     allow_replicated=False)
    assert dec.cost < train.cost


def test_price_moe_ep_vs_tp():
    # top-1, few experts, large d_ff → EP (dispatch cheap, TP RS large)
    tag1, ep1, tp1 = price_moe(1_048_576, 5120, 8192, 16, 1, 16, 16)
    assert tag1 == "ep" and ep1 < tp1
    # top-6 of 64 tiny experts → dispatch volume ×6, TP wins
    tag2, ep2, tp2 = price_moe(1_048_576, 2048, 1408, 64, 6, 16, 16)
    assert tag2 == "tp" and tp2 < ep2


def test_plan_arch_memory_gate():
    mesh = small_mesh()
    small = plan_arch(CONFIGS["mamba2-130m"], SHAPES["train_4k"], mesh)
    assert "fits" in small.decisions["memory-gate"]
    big = plan_arch(CONFIGS["qwen2.5-14b"], SHAPES["train_4k"], mesh)
    assert "exceeds" in big.decisions["memory-gate"]
    # big model: weight storage sharded on the model axis
    assert big.param_axis_map["ffn"] == ("model",)


def test_plan_arch_decode_forces_cache_sharding():
    mesh = small_mesh()
    plan = plan_arch(CONFIGS["qwen2.5-14b"], SHAPES["decode_32k"], mesh)
    # qwen2.5: 40 heads % 1 == 0 trivially on this mesh; check the
    # decision record exists for the decode override
    assert any("decode" in k for k in plan.decisions) or \
        plan.act_axis_map["attn"]


def test_param_pspecs_rules_and_stack_dims():
    from repro.launch.mesh import make_abstract_mesh
    mesh = make_abstract_mesh((4, 2), ("data", "model"))
    cfg = SMOKES["qwen2.5-14b"]
    shapes = param_shapes(cfg)
    amap = {"data": ("data",), "attn": ("model",), "kv": ("model",),
            "ffn": ("model",), "vocab": ("model",), "expert": None,
            "ssm": None, "seq": None}
    specs = param_pspecs(mesh, amap, shapes)
    # stacked block leaves: leading (G, gsz) dims replicated
    wq_spec = specs["blocks"]["attn"]["wq"]
    assert tuple(wq_spec) in ((None, None, None, "model"),)
    wo_spec = specs["blocks"]["attn"]["wo"]
    assert tuple(wo_spec) == (None, None, "model")
    emb = specs["embed"]["w"]
    assert tuple(emb) == ("model",)


def test_divisibility_guard_falls_back_to_replicated():
    mesh = small_mesh()
    cfg = SMOKES["qwen2-7b"]          # d_model 56, heads 4
    shapes = param_shapes(cfg)
    # claim a 10-way model axis that divides nothing
    import numpy as np
    from jax.sharding import Mesh
    amap = {"attn": ("model",), "kv": ("model",), "ffn": ("model",),
            "vocab": ("model",), "data": ("data",), "expert": None,
            "ssm": None, "seq": None}
    # sizes are 1 on the tiny mesh so everything divides; simulate via
    # the _entry guard directly
    from repro.sharding.specs import _entry
    assert _entry(mesh, {"x": ("model",)}, "x", 7) in (None, "model")


def test_zero1_adds_data_sharding():
    mesh = small_mesh()
    cfg = SMOKES["qwen2.5-14b"]
    shapes = param_shapes(cfg)
    amap = {"data": ("data",), "attn": ("model",), "kv": ("model",),
            "ffn": ("model",), "vocab": ("model",), "expert": None,
            "ssm": None, "seq": None}
    base = param_pspecs(mesh, amap, shapes)
    z = zero1_pspecs(mesh, amap, shapes)
    nb = sum(len([e for e in s if e is not None])
             for s in jax.tree.leaves(base,
                                      is_leaf=lambda x: hasattr(x, "index"))
             if hasattr(s, "__iter__"))
    nz = sum(len([e for e in s if e is not None])
             for s in jax.tree.leaves(z,
                                      is_leaf=lambda x: hasattr(x, "index"))
             if hasattr(s, "__iter__"))
    assert nz >= nb


def test_sharder_noop_without_mesh():
    sharder = make_sharder(None, {})
    x = jnp.ones((4, 4))
    assert sharder(x, "data", None) is x
