"""Executable reproductions of the paper's worked examples and §2.2
integrity-constraint (closedness) claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Placement, RelType, TraAgg, TraFilter, TraInput,
                        TraJoin, TraReKey, TraTransform, comm_cost,
                        from_tensor, get_kernel, optimize, to_tensor)
from repro.core import tra

from conftest import shim_evaluate_tra as evaluate_tra


# ------------------------------------------------------------------
# §4.2.1 worked example: diag(X + Y) — the rewrite chain R1-2, R1-6,
# R2-2, R1-7 must produce a plan that filters before joining and fuses
# diag into the join kernel, reducing both comm and compute.
# ------------------------------------------------------------------

def _diag_program(nb: int, blk: int):
    rx = TraInput("X", RelType((nb, nb), (blk, blk)))
    ry = TraInput("Y", RelType((nb, nb), (blk, blk)))
    added = TraJoin(rx, ry, (0, 1), (0, 1), get_kernel("matAdd"))
    filt = TraFilter(added, lambda k: k[0] == k[1], tag="isEq")
    rekey = TraReKey(filt, lambda k: (k[0],), tag="merge")
    return TraTransform(rekey, get_kernel("diag"))


def test_diag_example_correctness():
    nb, blk = 4, 8
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (nb * blk, nb * blk))
    Y = jax.random.normal(jax.random.PRNGKey(1), (nb * blk, nb * blk))
    prog = _diag_program(nb, blk)
    out = evaluate_tra(prog, {"X": from_tensor(X, (blk, blk)),
                              "Y": from_tensor(Y, (blk, blk))})
    got = np.asarray(out.data).reshape(-1)         # (nb, blk) diag blocks
    want = np.asarray(jnp.diagonal(X + Y))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_diag_example_rewrites_reduce_cost():
    """The optimizer must discover the paper's §4.2.1 chain: pushing the
    isEq filter below the join slashes the data the broadcast moves."""
    nb, blk = 4, 8
    prog = _diag_program(nb, blk)
    places = {"X": Placement.partitioned((0,), ("sites",)),
              "Y": Placement.partitioned((0,), ("sites",))}
    naive = optimize(prog, places, site_axes=("sites",),
                     axis_sizes={"sites": 4}, try_logical_rewrites=False,
                     accounting="paper")
    rewritten = optimize(prog, places, site_axes=("sites",),
                         axis_sizes={"sites": 4},
                         try_logical_rewrites=True, accounting="paper")
    assert rewritten.cost <= naive.cost
    assert rewritten.logical_variants_tried > 1


# ------------------------------------------------------------------
# §2.2 closedness: join/agg/transform/tile/concat preserve uniqueness
# and continuity; filter and rekey may break continuity but the system
# must TRACK it exactly (masks), never silently violate uniqueness.
# ------------------------------------------------------------------

def _rand_rel(data, key_shape, bound):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    arr = jnp.asarray(rng.standard_normal(
        key_shape + bound).astype(np.float32))
    return tra.TensorRelation(arr, RelType(key_shape, bound))


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_join_agg_closed(data):
    ks = data.draw(st.sampled_from([(2, 3), (3, 2), (4, 4)]))
    rel_l = _rand_rel(data, ks, (2, 3))
    rel_r = _rand_rel(data, (ks[1], ks[0]), (3, 2))
    out = tra.join(rel_l, rel_r, (1,), (0,), get_kernel("matMul"))
    # closed: continuous (no mask), keys unique by construction
    assert out.is_continuous()
    assert out.rtype.key_shape == (ks[0], ks[1], ks[0])
    agg = tra.agg(out, (0, 2), get_kernel("matAdd"))
    assert agg.is_continuous()


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_tile_concat_closed_and_inverse(data):
    ks = data.draw(st.sampled_from([(2,), (3,)]))
    rel = _rand_rel(data, ks, (4, 6))
    t = tra.tile(rel, 1, 2)
    assert t.is_continuous()
    assert t.rtype.key_shape == ks + (3,)
    back = tra.concat(t, len(ks), 1)
    assert back.is_continuous()
    np.testing.assert_allclose(np.asarray(back.data),
                               np.asarray(rel.data))


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_filter_breaks_continuity_but_is_tracked(data):
    rel = _rand_rel(data, (3, 3), (2, 2))
    keep_diag = tra.filt(rel, lambda k: k[0] == k[1])
    # holes exist and the mask records them exactly
    assert not keep_diag.is_continuous()
    keys = {tuple(k) for k in keep_diag.valid_keys().tolist()}
    assert keys == {(0, 0), (1, 1), (2, 2)}


def test_rekey_uniqueness_enforced():
    rel = tra.TensorRelation(jnp.zeros((2, 2, 1)), RelType((2, 2), (1,)))
    # a non-injective key function must raise (paper §2.2 uniqueness)
    try:
        tra.rekey(rel, lambda k: (0,))
    except ValueError as e:
        assert "uniqueness" in str(e) or "duplicate" in str(e)
    else:
        raise AssertionError("non-injective rekey must be rejected")


# ------------------------------------------------------------------
# §4.3 frontier inference after filter (rule 3): the frontier shrinks
# to the bounding box of surviving keys.
# ------------------------------------------------------------------

def test_filter_frontier_shrinks():
    rel = tra.TensorRelation(jnp.zeros((4, 4, 1)), RelType((4, 4), (1,)))
    out = tra.filt(rel, lambda k: k[0] < 2 and k[1] < 3)
    assert out.rtype.key_shape == (2, 3)
    assert out.is_continuous()
