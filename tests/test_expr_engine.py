"""Frontend tests: lazy Expr API + unified Engine entry point.

Covers the acceptance criteria of the API redesign:
* Expr ↔ hand-built-plan equivalence on the §5 workloads (BMM/CPMM/RMM);
* shared-subexpression DAGs evaluated once (kernel-invocation counting);
* build-time shape errors (raised at construction, with context);
* engine compile-cache hits;
* einsum routed through the same builder;
* deprecated shims still matching the Engine path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as tra
from repro.core import (Engine, ExprTypeError, Kernel, Placement, RelType,
                        TraAgg, TraInput, TraJoin, from_tensor, get_kernel,
                        optimize, to_tensor)
from repro.core.programs import (bmm_plan, cpmm_plan, ffnn_step_tra,
                                 matmul_tra, nn_search_tra)

S = ("sites",)


def _mats(i=32, k=64, j=32, bi=8, bk=8, bj=8):
    A = jax.random.normal(jax.random.PRNGKey(0), (i, k))
    B = jax.random.normal(jax.random.PRNGKey(1), (k, j))
    return A, B, from_tensor(A, (bi, bk)), from_tensor(B, (bk, bj))


# ==========================================================================
# Expr ↔ hand-built plan equivalence on the §5.1 workloads
# ==========================================================================

PLACEMENTS = {
    "BMM": {"A": Placement.replicated(),
            "B": Placement.partitioned((0,), S)},
    "CPMM": {"A": Placement.partitioned((1,), S),
             "B": Placement.partitioned((0,), S)},
    "RMM-rows": {"A": Placement.partitioned((0,), S),
                 "B": Placement.partitioned((0,), S)},
}


@pytest.mark.parametrize("strategy", sorted(PLACEMENTS))
def test_expr_matches_hand_built_plan(strategy):
    A, B, RA, RB = _mats()
    fa = fb = (4, 8)

    expr = tra.input("A", (4, 8), (8, 8)) @ tra.input("B", (8, 4), (8, 8))
    hand = TraAgg(TraJoin(TraInput("A", RelType((4, 8), (8, 8))),
                          TraInput("B", RelType((8, 4), (8, 8))),
                          (1,), (0,), get_kernel("matMul")),
                  (0, 2), get_kernel("matAdd"))
    places = PLACEMENTS[strategy]
    # the optimizer must price and pick identically for both forms
    r_expr = optimize(expr, places, S, {"sites": 4})
    r_hand = optimize(hand, places, S, {"sites": 4})
    assert r_expr.cost == r_hand.cost
    assert tra.describe(r_expr.plan) == tra.describe(r_hand.plan)

    # and execution through the engine matches the legacy walk + numpy
    eng = Engine(executor="jit", input_placements=places,
                 axis_sizes={"sites": 4})
    got = eng.run(expr, A=RA, B=RB)
    np.testing.assert_allclose(np.asarray(to_tensor(got)),
                               np.asarray(A @ B), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("plan_fn", [bmm_plan, cpmm_plan])
def test_engine_runs_hand_built_physical_plans(plan_fn):
    A, B, RA, RB = _mats()
    plan = plan_fn((4, 8), (8, 4), (8, 8), (8, 8))
    got = Engine(executor="reference").run(plan, A=RA, B=RB)
    np.testing.assert_allclose(np.asarray(to_tensor(got)),
                               np.asarray(A @ B), rtol=1e-4, atol=1e-4)


def test_rmm_two_axis_placement_equivalence():
    A, B, RA, RB = _mats()
    expr = matmul_tra((4, 8), (8, 4), (8, 8), (8, 8))
    places = {"A": Placement.partitioned((0,), ("s0",)),
              "B": Placement.partitioned((1,), ("s1",))}
    eng = Engine(executor="jit", input_placements=places,
                 site_axes=("s0", "s1"), axis_sizes={"s0": 2, "s1": 2})
    got = eng.run(expr, A=RA, B=RB)
    np.testing.assert_allclose(np.asarray(to_tensor(got)),
                               np.asarray(A @ B), rtol=1e-4, atol=1e-4)


def test_nn_search_and_ffnn_exprs_match_oracle():
    """§5.2 / §5.3 programs: engine result == deprecated-oracle result."""
    prog = nn_search_tra(4, 2, 8, 8)
    Xs = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    xq = jax.random.normal(jax.random.PRNGKey(3), (1, 16))
    Am = jnp.eye(16)
    from repro.core import tra as tra_ops
    env = {"xq": tra_ops.rekey(from_tensor(xq, (1, 8)), lambda k: (k[1],)),
           "X": from_tensor(Xs, (8, 8)), "A": from_tensor(Am, (8, 8))}
    got = Engine(executor="jit", optimize=False).run(prog.result, **env)
    from conftest import shim_evaluate_tra
    want = shim_evaluate_tra(prog.result, env, fuse=False)
    np.testing.assert_allclose(np.asarray(got.data), np.asarray(want.data),
                               rtol=1e-4, atol=1e-4)

    prog2 = ffnn_step_tra(2, 2, 2, 2, 4, 4, 4, 2)
    env2 = {"X": from_tensor(jax.random.normal(jax.random.PRNGKey(4),
                                               (8, 8)), (4, 4)),
            "Y": from_tensor(jax.random.normal(jax.random.PRNGKey(5),
                                               (8, 4)), (4, 2)),
            "W1": from_tensor(jax.random.normal(jax.random.PRNGKey(6),
                                                (8, 8)), (4, 4)),
            "W2": from_tensor(jax.random.normal(jax.random.PRNGKey(7),
                                                (8, 4)), (4, 2))}
    w1n, w2n = Engine(executor="jit", optimize=False).run(
        (prog2.w1_new, prog2.w2_new), **env2)
    cache = {}
    want1 = shim_evaluate_tra(prog2.w1_new, env2, cache)
    want2 = shim_evaluate_tra(prog2.w2_new, env2, cache)
    np.testing.assert_allclose(np.asarray(w1n.data), np.asarray(want1.data),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(w2n.data), np.asarray(want2.data),
                               rtol=1e-4, atol=1e-4)


# ==========================================================================
# Shared subexpressions: true DAGs evaluated once
# ==========================================================================

def _counting_kernel(counter):
    def apply(a, b):
        counter["calls"] += 1
        return a + b

    return Kernel(name="countAdd", arity=2, apply=apply,
                  out_bound=lambda bl, br: tuple(bl),
                  flops=lambda *bs: 0)


def test_shared_subexpression_evaluated_once():
    counter = {"calls": 0}
    a = tra.input("A", (2, 2), (4, 4))
    b = tra.input("B", (2, 2), (4, 4))
    shared = a.join(b, on=(0, 1), kernel=_counting_kernel(counter))
    expr = shared * shared            # the DAG reuses one node

    RA = from_tensor(jnp.ones((8, 8)), (4, 4))
    RB = from_tensor(jnp.ones((8, 8)) * 2, (4, 4))
    eng = Engine(executor="reference", optimize=False)
    out = eng.run(expr, A=RA, B=RB)
    assert counter["calls"] == 1, counter
    np.testing.assert_allclose(np.asarray(out.data), 9.0)

    # two structurally identical but distinct nodes evaluate twice —
    # identity, not structure, is what the DAG shares
    counter2 = {"calls": 0}
    k2 = _counting_kernel(counter2)
    s1 = a.join(b, on=(0, 1), kernel=k2)
    s2 = a.join(b, on=(0, 1), kernel=k2)
    eng.run(s1 * s2, A=RA, B=RB)
    assert counter2["calls"] == 2, counter2


def test_multi_root_shares_forward_pass():
    counter = {"calls": 0}
    a = tra.input("A", (2, 2), (4, 4))
    b = tra.input("B", (2, 2), (4, 4))
    shared = a.join(b, on=(0, 1), kernel=_counting_kernel(counter))
    r1 = shared.map("relu")
    r2 = shared.sum(0)
    out1, out2 = Engine(executor="reference", optimize=False).run(
        (r1, r2), A=from_tensor(jnp.ones((8, 8)), (4, 4)),
        B=from_tensor(jnp.ones((8, 8)), (4, 4)))
    assert counter["calls"] == 1
    assert out1.rtype.key_shape == (2, 2)
    assert out2.rtype.key_shape == (2,)


# ==========================================================================
# Build-time shape errors
# ==========================================================================

def test_join_bound_mismatch_raises_at_build():
    a = tra.input("A", (4, 4), (8, 8))
    b = tra.input("B", (4, 4), (4, 4))       # incompatible matMul bounds
    with pytest.raises(ExprTypeError, match="cannot build join"):
        a.join(b, on=((1,), (0,)), kernel="matMul")


def test_matmul_operator_checks_arity():
    a = tra.input("A", (4,), (8, 8))
    b = tra.input("B", (4, 4), (8, 8))
    with pytest.raises(ExprTypeError, match="matrix-chunked"):
        a @ b


def test_keywise_operator_checks_key_arity():
    a = tra.input("A", (4, 4), (8, 8))
    b = tra.input("B", (4,), (8, 8))
    with pytest.raises(ExprTypeError, match="key arity mismatch"):
        a + b


def test_agg_bad_group_by_raises_at_build():
    a = tra.input("A", (4, 4), (8, 8))
    with pytest.raises((ExprTypeError, IndexError)):
        a.agg((0, 5), "matAdd")


def test_einsum_operand_count_checked():
    a = tra.input("A", (4, 4), (8, 8))
    with pytest.raises(ExprTypeError, match="2 terms"):
        tra.einsum("ij,jk->ik", a)


def test_einsum_rank_checked():
    a = tra.input("A", (4,), (8,))
    b = tra.input("B", (4, 4), (8, 8))
    with pytest.raises(ExprTypeError, match="needs 2 key dims"):
        tra.einsum("ij,jk->ik", a, b)


# ==========================================================================
# Engine compile cache
# ==========================================================================

def test_compile_cache_hits_for_same_and_rebuilt_exprs():
    eng = Engine(executor="jit")
    e1 = matmul_tra((4, 4), (4, 4), (8, 8), (8, 8))
    c1 = eng.compile(e1)
    assert eng.compile(e1) is c1                      # same object
    e2 = matmul_tra((4, 4), (4, 4), (8, 8), (8, 8))   # rebuilt, same shape
    assert eng.compile(e2) is c1
    assert (eng.cache_hits, eng.cache_misses) == (2, 1)
    # a different shape misses
    eng.compile(matmul_tra((2, 2), (2, 2), (8, 8), (8, 8)))
    assert eng.cache_misses == 2


def test_compile_cache_keyed_by_placements_and_executor():
    e = matmul_tra((4, 4), (4, 4), (8, 8), (8, 8))
    eng = Engine(executor="jit", axis_sizes={"sites": 4})
    c1 = eng.compile(e)
    c2 = eng.compile(e, input_placements=PLACEMENTS["CPMM"])
    assert c1 is not c2
    assert eng.cache_misses == 2
    # run() goes through the same cache
    A, B, RA, RB = _mats()
    e3 = matmul_tra((4, 8), (8, 4), (8, 8), (8, 8))
    eng.run(e3, A=RA, B=RB)
    eng.run(e3, A=RA, B=RB)
    assert eng.cache_hits >= 1


def test_distinct_lambdas_never_share_cache_entries():
    """Two filters with the same default tag but different predicates must
    compile separately (identity is part of the signature)."""
    a = tra.input("A", (4, 4), (8, 8))
    e1 = a.filter(lambda k: k[0] < 2)
    e2 = a.filter(lambda k: k[0] >= 1)
    eng = Engine(executor="reference", optimize=False)
    RA = from_tensor(jnp.ones((32, 32)), (8, 8))
    o1 = eng.run(e1, A=RA)
    o2 = eng.run(e2, A=RA)
    assert eng.cache_misses == 2
    assert o1.rtype.key_shape != o2.rtype.key_shape


# ==========================================================================
# einsum through the Expr builder
# ==========================================================================

@pytest.mark.parametrize("spec,shapes,tiles", [
    ("ij,jk->ik", [(24, 32), (32, 16)], [(6, 8), (8, 4)]),
    ("ij,jk,kl->il", [(8, 12), (12, 8), (8, 4)], [(4, 6), (6, 4), (4, 2)]),
    ("ij,ij->ij", [(8, 12), (8, 12)], [(4, 6), (4, 6)]),
])
def test_einsum_expr_matches_jnp(spec, shapes, tiles):
    keys = jax.random.split(jax.random.PRNGKey(0), len(shapes))
    tensors = [jax.random.normal(k, s) for k, s in zip(keys, shapes)]
    rels = [from_tensor(t, tile) for t, tile in zip(tensors, tiles)]
    ops = [tra.input_like(f"T{i}", r.rtype) for i, r in enumerate(rels)]
    expr = tra.einsum(spec, *ops)
    env = {f"T{i}": r for i, r in enumerate(rels)}
    got = Engine(executor="jit", optimize=False).run(expr, **env)
    want = jnp.einsum(spec, *tensors)
    np.testing.assert_allclose(np.asarray(to_tensor(got)),
                               np.asarray(want), rtol=1e-4, atol=1e-3)


# ==========================================================================
# Inputs and ergonomics
# ==========================================================================

def test_raw_array_inputs_are_coerced():
    A, B, RA, RB = _mats()
    expr = matmul_tra((4, 8), (8, 4), (8, 8), (8, 8))
    got = Engine().run(expr, A=RA.data, B=RB.data)
    np.testing.assert_allclose(np.asarray(to_tensor(got)),
                               np.asarray(A @ B), rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError, match="dense shape"):
        Engine().run(expr, A=RA.data, B=jnp.ones((3, 3)))
    with pytest.raises(ValueError, match="missing inputs"):
        Engine().run(expr, A=RA.data)


def test_staged_executors_reject_masked_inputs():
    """jit/gspmd rebuild relations from raw arrays inside the artifact, so
    a holey input would silently lose its mask — must raise instead."""
    import numpy as onp
    expr = matmul_tra((4, 4), (4, 4), (8, 8), (8, 8))
    A, B, RA, RB = _mats(32, 32, 32)
    mask = onp.ones((4, 4), bool)
    mask[0, 0] = False
    from repro.core import TensorRelation
    holey = TensorRelation(RA.data, RA.rtype, mask)
    with pytest.raises(NotImplementedError, match="mask"):
        Engine(executor="jit").run(expr, A=holey, B=RB)
    # the eager reference walk threads masks correctly
    out = Engine(executor="reference", optimize=False).run(
        expr, A=holey, B=RB)
    assert out.mask is None        # matmul agg rejoins the full grid


def test_multi_root_optimized_cost_sums_per_root():
    prog = ffnn_step_tra(2, 2, 2, 2, 4, 4, 4, 2)
    eng = Engine(executor="jit", axis_sizes={"sites": 2})
    c_both = eng.compile((prog.w1_new, prog.w2_new))
    c_w1 = eng.compile(prog.w1_new)
    c_w2 = eng.compile(prog.w2_new)
    assert c_both.cost == c_w1.cost + c_w2.cost
    assert c_both.opt is None and c_w1.opt is not None


def test_extra_inputs_rejected_uniformly():
    A, B, RA, RB = _mats()
    expr = matmul_tra((4, 8), (8, 4), (8, 8), (8, 8))
    with pytest.raises(ValueError, match="unexpected inputs"):
        Engine().run(expr, A=RA, B=RB, C=RA)        # TensorRelation extra
    with pytest.raises(ValueError, match="unexpected inputs"):
        Engine().run(expr, A=RA.data, B=RB.data, C=RA.data)  # raw extra


def test_engine_rejects_unknown_executor_and_missing_mesh():
    with pytest.raises(ValueError, match="unknown executor"):
        Engine(executor="pmap")
    expr = matmul_tra((4, 4), (4, 4), (8, 8), (8, 8))
    with pytest.raises(ValueError, match="requires a mesh"):
        Engine(executor="shard_map").compile(expr)


def test_legacy_entry_points_accept_exprs_and_warn():
    A, B, RA, RB = _mats()
    expr = matmul_tra((4, 8), (8, 4), (8, 8), (8, 8))
    with pytest.warns(DeprecationWarning, match="deprecated"):
        out = tra.evaluate_tra(expr, {"A": RA, "B": RB})
    np.testing.assert_allclose(np.asarray(to_tensor(out)),
                               np.asarray(A @ B), rtol=1e-4, atol=1e-4)
