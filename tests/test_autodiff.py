"""Autodiff over the Expr frontend: `Expr.grad` / `Engine.value_and_grad`.

Covers the acceptance criteria of the differentiable-TRA redesign:

* parametrized gradcheck sweep — every registered differentiable kernel
  (join, transform, aggregation) and the structural ops (tile / concat /
  rekey / filter / pad), each compared against ``jax.grad`` of the dense
  reference-executor oracle, including *masked* relations;
* autodiff-derived §5.3 FFNN backward ≡ the hand-built paper backward ≡ a
  ``jax.grad`` dense oracle (atol 1e-5), at BMM/CPMM/RMM-flavoured block
  shapes, and `Engine.value_and_grad` on the reference/jit executors plus
  single-device gspmd/shard_map meshes (the 8-device case runs in
  tests/_distributed_checks.py);
* the optimizer selecting ``FusedJoinAgg`` inside an autodiff-generated
  gradient plan;
* error paths (non-differentiable kernels, unknown wrt, bad seed) and the
  configurable fused-path ``chunk``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as tra
from repro.core import (AutodiffError, Engine, Placement, RelType,
                        TensorRelation, from_tensor, to_tensor)
from repro.core.kernels_registry import make_scale_mul
from repro.core.plan import TraInput, postorder
from repro.core.programs import ffnn_step_tra, ffnn_step_tra_hand

S = ("sites",)
REF = Engine(executor="reference", optimize=False)
ORACLE = Engine(executor="reference", optimize=False, fuse=False)


def _rel(seed, ks, bound, mask=None):
    data = jax.random.normal(jax.random.PRNGKey(seed),
                             tuple(ks) + tuple(bound))
    return TensorRelation(data, RelType(tuple(ks), tuple(bound)), mask)


def gradcheck(expr, wrt, envs, atol=1e-4):
    """`expr.grad(wrt)` on the reference executor vs `jax.grad` of the
    dense unfused oracle (masked output entries excluded from the loss;
    masked input entries excluded from the comparison)."""
    dX = expr.grad(wrt)
    need = {n.name for n in postorder(dX.node) if isinstance(n, TraInput)}
    got = REF.run(dX, **{k: v for k, v in envs.items() if k in need})
    fwd_need = {n.name for n in postorder(expr.node)
                if isinstance(n, TraInput)}

    def loss(arr):
        e2 = {k: v for k, v in envs.items() if k in fwd_need}
        e2[wrt] = TensorRelation(arr, envs[wrt].rtype, envs[wrt].mask)
        out = ORACLE.run(expr, **e2)
        data = out.data
        if out.mask is not None:
            data = data * jnp.asarray(out.mask.reshape(
                out.mask.shape + (1,) * out.rtype.rank))
        return jnp.sum(data)

    want = jax.grad(loss)(envs[wrt].data)
    gd, wd = np.asarray(got.data), np.asarray(want)
    assert gd.shape == wd.shape, (gd.shape, wd.shape)
    gm = envs[wrt].mask
    if gm is not None:           # gradients at absent tuples are undefined
        sel = gm.reshape(gm.shape + (1,) * (gd.ndim - gm.ndim))
        gd, wd = gd * sel, wd * sel
    np.testing.assert_allclose(gd, wd, atol=atol, rtol=1e-4)


# ==========================================================================
# Gradcheck sweep: join kernels
# ==========================================================================

M = tra.input("M", (2, 2), (4, 4))
A34 = tra.input("A", (2, 2), (4, 3))

JOIN_CASES = {
    # name: (expr builder over fresh inputs, input types)
    "matMul-bmm": (lambda a, b: a @ b,
                   [((2, 3), (4, 5)), ((3, 2), (5, 4))]),
    "matMul-join-only": (
        lambda a, b: a.join(b, on=((1,), (0,)), kernel="matMul"),
        [((2, 3), (4, 5)), ((3, 2), (5, 4))]),
    "matTranMulL": (
        lambda a, b: a.join(b, on=((0,), (0,)),
                            kernel="matTranMulL").agg((1, 2), "matAdd"),
        [((3, 2), (4, 3)), ((3, 2), (4, 5))]),
    "matTranMulR": (
        lambda a, b: a.join(b, on=((1,), (1,)),
                            kernel="matTranMulR").agg((0, 2), "matAdd"),
        [((2, 3), (4, 5)), ((2, 3), (6, 5))]),
    "matAdd": (lambda a, b: (a + b).sum(0),
               [((2, 3), (4, 5)), ((2, 3), (4, 5))]),
    "matSub": (lambda a, b: (a - b).map("sigmoid"),
               [((2, 3), (4, 5)), ((2, 3), (4, 5))]),
    "elemMul": (lambda a, b: (a * b).agg((1,), "matAdd"),
                [((2, 3), (4, 5)), ((2, 3), (4, 5))]),
    "matVecSub": (
        lambda q, x: q.join(x, on=((0,), (1,)),
                            kernel="matVecSub").map("relu").sum(0),
        [((2,), (1, 4)), ((3, 2), (5, 4))]),
    "cross-frontier-min": (
        lambda a, b: a.join(b, on=((0,), (0,)),
                            kernel="elemMul").agg((0, 1), "matAdd"),
        [((3, 2), (4, 4)), ((2, 2), (4, 4))]),
}


@pytest.mark.parametrize("case", sorted(JOIN_CASES))
@pytest.mark.parametrize("side", [0, 1])
def test_gradcheck_join_kernels(case, side):
    build, types = JOIN_CASES[case]
    names = ["L", "R"]
    ins = [tra.input(nm, ks, b) for nm, (ks, b) in zip(names, types)]
    import zlib
    envs = {nm: _rel(i + zlib.crc32(case.encode()) % 97, *t)
            for i, (nm, t) in enumerate(zip(names, types))}
    gradcheck(build(*ins), names[side], envs)


# ==========================================================================
# Gradcheck sweep: transform kernels and structural ops
# ==========================================================================

UNARY_CASES = {
    "idOp": lambda m: m.map("idOp").sum(0),
    "relu": lambda m: m.map("relu").sum(0, 1),
    "sigmoid": lambda m: m.map("sigmoid"),
    "relu∘sigmoid": lambda m: m.map("sigmoid").map("relu").sum(1),
    "transpose": lambda m: m.map("transpose").map("sigmoid"),
    "scaleMul": lambda m: m.map(make_scale_mul(0.37)),
    "rowSum": lambda m: m.map("rowSum").sum(0),
    "diag": lambda m: m.map("diag").sum(1),
    "tile": lambda m: m.tile(1, 2).map("relu").sum(0, 1),
    "concat": lambda m: m.concat(0, 0).map("sigmoid"),
    "rekey-swap": lambda m: m.rekey(lambda kk: (kk[1], kk[0]),
                                    tag="swap").map("relu"),
    "filter-hole": lambda m: m.filter(lambda kk: kk != (1, 1),
                                      tag="hole").agg((0, 1), "matAdd"),
    "filter-shrink": lambda m: m.filter(lambda kk: kk[1] < 2,
                                        tag="shrink").sum(0, 1),
    "pad": lambda m: m.filter(lambda kk: kk[0] == 0,
                              tag="row0").pad((2, 3)).map("relu"),
    "agg-bcast-back": lambda m: m.map("sigmoid").sum(1).map("relu"),
    "permuted-gb": lambda m: (m * m.map("sigmoid")).agg((1, 0), "matAdd"),
    "fan-in": lambda m: (m.map("relu")
                         + m.map("relu").map("sigmoid")).sum(0, 1),
    "deep-chain": lambda m: (m.rekey(lambda kk: (kk[1], kk[0]), tag="swap")
                             .map("sigmoid").sum(1)),
}


@pytest.mark.parametrize("case", sorted(UNARY_CASES))
def test_gradcheck_unary_and_structural(case):
    m = tra.input("M", (2, 3), (4, 4))
    gradcheck(UNARY_CASES[case](m), "M", {"M": _rel(11, (2, 3), (4, 4))})


@pytest.mark.parametrize("case", ["elemMul", "matAdd", "relu-masked",
                                  "agg-masked"])
def test_gradcheck_masked_relations(case):
    """Inputs with holes: gradients at valid tuples must match the oracle
    (masked entries carry no gradient by construction)."""
    mask = np.ones((2, 3), bool)
    mask[0, 1] = False
    m = tra.input("M", (2, 3), (4, 4))
    o = tra.input("O", (2, 3), (4, 4))
    holey = _rel(21, (2, 3), (4, 4), mask)
    full = _rel(22, (2, 3), (4, 4))
    exprs = {
        "elemMul": (m * o).sum(0),
        "matAdd": (m + o).map("sigmoid"),
        "relu-masked": m.map("relu").map("sigmoid"),
        "agg-masked": m.agg((1,), "matAdd"),
    }
    gradcheck(exprs[case], "M", {"M": holey, "O": full})


# ==========================================================================
# Derivative-rule error paths
# ==========================================================================

def test_non_differentiable_join_kernel_raises():
    a = tra.input("A", (2,), (4, 4))
    b = tra.input("B", (2,), (4, 4))
    e = a.join(b, on=((0,), (0,)), kernel="elemMax")
    with pytest.raises(AutodiffError, match="elemMax"):
        e.grad("A")


def test_non_differentiable_aggregation_is_diagnosable():
    """A product aggregation has no VJP rule — the error must be an
    ExprTypeError naming the kernel AND the differentiable alternatives,
    not a raw internal failure."""
    from repro.core import ExprTypeError
    m = tra.input("M", (2, 2), (4, 4))
    with pytest.raises(ExprTypeError, match="elemMul") as ei:
        m.agg((0,), "elemMul").grad("M")
    assert isinstance(ei.value, AutodiffError)
    msg = str(ei.value)
    for alt in ("matAdd", "elemMax", "elemMin"):
        assert alt in msg, msg


MINMAX_AGG_CASES = {
    "max": lambda m: m.agg((1,), "elemMax").map("sigmoid"),
    "min": lambda m: m.agg((0,), "elemMin"),
    "max-all-reduced": lambda m: m.agg((0, 1), "elemMax")
                                  .agg((1,), "elemMax"),
    "max-then-sum": lambda m: (m * m).agg((0,), "elemMax").sum(0),
}


@pytest.mark.parametrize("case", sorted(MINMAX_AGG_CASES))
def test_gradcheck_minmax_aggregations(case):
    """max/min aggregation VJP via the argmax-mask construction vs
    jax.grad of the dense oracle."""
    m = tra.input("M", (2, 3), (4, 4))
    gradcheck(MINMAX_AGG_CASES[case](m), "M",
              {"M": _rel(17, (2, 3), (4, 4))})


def test_gradcheck_max_agg_with_ties_matches_jax():
    """Ties split the cotangent evenly among the maximal entries —
    jax.grad's reduce_max convention, reproduced by the tie-count
    division in the mask rule."""
    m = tra.input("M", (2, 2), (3, 3))
    base = np.arange(9, dtype=np.float32).reshape(3, 3)
    data = jnp.asarray(np.stack([base, base, base - 1.0, base],
                                axis=0).reshape(2, 2, 3, 3))
    gradcheck(m.agg((1,), "elemMax"), "M",
              {"M": TensorRelation(data, RelType((2, 2), (3, 3)))})


def test_unknown_wrt_and_bad_seed_raise():
    m = tra.input("M", (2, 2), (4, 4))
    e = m.map("relu")
    with pytest.raises(AutodiffError, match="do not occur"):
        e.grad("Q")
    with pytest.raises(AutodiffError, match="seed type"):
        e.grad("M", seed=tra.const(1.0, (2, 2), (3, 3)))


def test_grad_of_gradl_shape_donor_input_flows_zero():
    """An input consumed only through value-ignoring kernels still gets an
    exact (zero) gradient — gradL's vjp is itself gradL/zero-ish, and the
    masked-agg identity-fill zeroes the untouched contributions."""
    m = tra.input("M", (2, 2), (4, 4))
    o = tra.input("O", (2, 2), (4, 4))
    e = m.join(o, on=((0, 1), (0, 1)), kernel="matAdd").sum(0)
    dm, do = e.grad(["M", "O"])
    RM, RO = _rel(61, (2, 2), (4, 4)), _rel(62, (2, 2), (4, 4))
    np.testing.assert_allclose(np.asarray(REF.run(dm, O=RO).data), 1.0)
    np.testing.assert_allclose(np.asarray(REF.run(do, M=RM).data), 1.0)


# ==========================================================================
# Gradcheck sweep: einsum-built expressions (ROADMAP follow-up)
# ==========================================================================

EINSUM_CASES = {
    # spec: one ((key_shape, bound)) per operand
    "ij,jk->ik": [((2, 3), (4, 5)), ((3, 2), (5, 4))],
    "ij,kj->ik": [((2, 3), (4, 5)), ((2, 3), (6, 5))],
    "ij,ij->ij": [((2, 3), (4, 5)), ((2, 3), (4, 5))],
    "ij,jk->ki": [((2, 3), (4, 5)), ((3, 2), (5, 4))],      # rekey permute
    "ij->i": [((2, 3), (4, 5))],                            # trailing Σ_j
    "ij->ji": [((2, 3), (4, 5))],                           # pure permute
    "ij,jk,kl->il": [((2, 3), (4, 5)), ((3, 2), (5, 4)),
                     ((2, 2), (4, 3))],                     # binary chain
    "ij,j->i": [((2, 3), (4, 5)), ((3,), (5,))],            # matrix-vector
    "bij,bjk->bik": [((2, 2, 3), (2, 4, 5)),
                     ((2, 3, 2), (2, 5, 4))],               # batched
    "ij,ik->jk": [((3, 2), (5, 4)), ((3, 2), (5, 3))],      # AᵀB shape
}


@pytest.mark.parametrize("spec", sorted(EINSUM_CASES))
def test_gradcheck_einsum_exprs(spec):
    """`Expr.grad` through `tra.einsum`-constructed programs vs jax.grad
    of the dense oracle — every operand of every spec."""
    import zlib
    types = EINSUM_CASES[spec]
    names = ["A", "B", "C"][:len(types)]
    ins = [tra.input(nm, ks, b) for nm, (ks, b) in zip(names, types)]
    envs = {nm: _rel(i + zlib.crc32(spec.encode()) % 91, *t)
            for i, (nm, t) in enumerate(zip(names, types))}
    e = tra.einsum(spec, *ins)
    for wrt in names:
        gradcheck(e, wrt, envs)


def test_einsum_grad_composes_with_fluent_ops():
    """einsum sub-exprs differentiate inside larger fluent programs (and
    the backward of an einsum is itself an einsum-shaped TRA plan)."""
    a = tra.input("A", (2, 3), (4, 5))
    b = tra.input("B", (3, 2), (5, 4))
    e = tra.einsum("ij,jk->ik", a, b).map("sigmoid").sum(0)
    envs = {"A": _rel(71, (2, 3), (4, 5)), "B": _rel(72, (3, 2), (5, 4))}
    for wrt in ("A", "B"):
        gradcheck(e, wrt, envs)
    d = e.grad("A").describe()
    assert "einsum[" in d, d


def test_einsum_value_and_grad_on_executors():
    """einsum gradients run through Engine.value_and_grad on the staged
    executors, not just the reference walk."""
    a = tra.input("A", (2, 3), (4, 5))
    b = tra.input("B", (3, 2), (5, 4))
    e = tra.einsum("ij,jk->ik", a, b)
    RA, RB = _rel(73, (2, 3), (4, 5)), _rel(74, (3, 2), (5, 4))
    # dense oracle: block keys as capital indices — Σ over J blocks and
    # j entries is exactly the TRA join+agg semantics
    wgA, wgB = jax.grad(
        lambda A, B: jnp.sum(jnp.einsum("IJij,JKjk->IKik", A, B)),
        argnums=(0, 1))(RA.data, RB.data)
    for executor in ("jit", "reference"):
        eng = Engine(executor=executor, optimize=False)
        vg = eng.value_and_grad(e, wrt=["A", "B"])
        _, gA, gB = vg.run(A=RA, B=RB)
        np.testing.assert_allclose(np.asarray(gA.data), np.asarray(wgA),
                                   atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(gB.data), np.asarray(wgB),
                                   atol=1e-5, rtol=1e-4)


# ==========================================================================
# §5.3 FFNN: autodiff ≡ hand-built ≡ jax.grad, on all executors
# ==========================================================================

FFNN_SHAPES = {
    # block grids flavoured after the §5.1 strategies: batch-heavy (BMM),
    # contraction-heavy (CPMM), balanced (RMM)
    "bmm-batch-heavy": (4, 2, 2, 2, 4, 4, 4, 2),
    "cpmm-contraction-heavy": (2, 4, 4, 2, 4, 4, 4, 2),
    "rmm-balanced": (2, 2, 2, 2, 4, 4, 4, 2),
}


def _ffnn_env(nb, db, hb, lb, bn, bd, bh, bl):
    N, D, H, L = nb * bn, db * bd, hb * bh, lb * bl
    X = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    Y = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(1), (N, L)))
    W1 = jax.random.normal(jax.random.PRNGKey(2), (D, H)) * 0.3
    W2 = jax.random.normal(jax.random.PRNGKey(3), (H, L)) * 0.3
    env = dict(X=from_tensor(X, (bn, bd)), Y=from_tensor(Y, (bn, bl)),
               W1=from_tensor(W1, (bd, bh)), W2=from_tensor(W2, (bh, bl)))
    return (X, Y, W1, W2), env


@pytest.mark.parametrize("shape", sorted(FFNN_SHAPES))
def test_ffnn_autodiff_matches_hand_built(shape):
    dims = FFNN_SHAPES[shape]
    (X, Y, W1, W2), env = _ffnn_env(*dims)
    auto = ffnn_step_tra(*dims, eta=0.01)
    hand = ffnn_step_tra_hand(*dims, eta=0.01)
    eng = Engine(executor="jit", optimize=False)
    aw1, aw2 = eng.run((auto.w1_new, auto.w2_new), **env)
    hw1, hw2 = eng.run((hand.w1_new, hand.w2_new), **env)
    np.testing.assert_allclose(np.asarray(aw1.data), np.asarray(hw1.data),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(aw2.data), np.asarray(hw2.data),
                               atol=1e-5, rtol=1e-5)
    # and both match the dense jnp oracle for the same SGD step
    a1 = jax.nn.relu(X @ W1)
    d2 = jax.nn.sigmoid(a1 @ W2) - Y
    gw2 = a1.T @ d2
    gw1 = X.T @ ((a1 > 0) * (d2 @ W2.T))
    np.testing.assert_allclose(np.asarray(to_tensor(aw1)),
                               np.asarray(W1 - 0.01 * gw1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(to_tensor(aw2)),
                               np.asarray(W2 - 0.01 * gw2), atol=1e-5)


@pytest.mark.parametrize("executor", ["reference", "jit", "gspmd",
                                      "shard_map"])
def test_value_and_grad_ffnn_all_executors(executor):
    """`Engine.value_and_grad` of the §5.3 forward vs a jax.grad dense
    oracle (atol 1e-5).  gspmd/shard_map run on a 1-device mesh here; the
    8-device versions run in tests/_distributed_checks.py."""
    dims = (4, 2, 2, 2, 4, 4, 4, 2)
    (X, _, W1, W2), env = _ffnn_env(*dims)
    env.pop("Y")
    prog = ffnn_step_tra(*dims)
    kwargs = {}
    if executor in ("gspmd", "shard_map"):
        from repro.launch.mesh import make_mesh
        kwargs["mesh"] = make_mesh((1,), S)
        kwargs["input_placements"] = {
            "X": Placement.partitioned((0,), S),
            "W1": Placement.replicated(), "W2": Placement.replicated()}
    eng = Engine(executor=executor, **kwargs)
    vg = eng.value_and_grad(prog.a2, wrt=["W1", "W2"])
    val, g1, g2 = vg.run(**env)
    assert vg.grad_wrt == ("W1", "W2")

    def loss(W1, W2):
        return jnp.sum(jax.nn.sigmoid(jax.nn.relu(X @ W1) @ W2))

    wg1, wg2 = jax.grad(loss, argnums=(0, 1))(W1, W2)
    np.testing.assert_allclose(
        np.asarray(to_tensor(val)),
        np.asarray(jax.nn.sigmoid(jax.nn.relu(X @ W1) @ W2)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(to_tensor(g1)), np.asarray(wg1),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(to_tensor(g2)), np.asarray(wg2),
                               atol=1e-5, rtol=1e-4)


def test_optimizer_fuses_autodiff_gradient_plan():
    """The fused Σ∘⋈ selection must fire inside an autodiff-generated
    backward plan (the gradients are agg(join(·)) patterns by
    construction)."""
    prog = ffnn_step_tra(4, 2, 2, 2, 4, 4, 4, 2)
    eng = Engine(executor="jit", axis_sizes={"sites": 2})
    assert "FusedJoinAgg" in eng.compile(prog.g_w1).describe()
    assert "FusedJoinAgg" in eng.compile(prog.g_w2).describe()


def test_gradient_structure_matches_paper_hand_backward():
    """The derived ∂/∂W2 is structurally the paper's hand expression:
    Σ_(1,2)(⋈_(0,0)(a1, a2−Y, matTranMulL))."""
    prog = ffnn_step_tra(2, 2, 2, 2, 4, 4, 4, 2)
    d = prog.g_w2.describe()
    head = d.splitlines()[:2]
    assert "TraAgg(gb=[1, 2], matAdd)" in head[0]
    assert "TraJoin(L[0]=R[0], matTranMulL)" in head[1]


# ==========================================================================
# Satellites: chunk configuration, multi-root distributed compile
# ==========================================================================

def test_engine_chunk_is_configurable_and_cached_separately():
    a = tra.input("A", (2, 4), (4, 4))
    b = tra.input("B", (4, 2), (4, 4))
    # elemMax agg over a join → the chunked streaming fused path
    e = a.join(b, on=((1,), (0,)), kernel="elemMul").agg((0, 2), "elemMax")
    RA, RB = _rel(31, (2, 4), (4, 4)), _rel(32, (4, 2), (4, 4))
    want = ORACLE.run(e, A=RA, B=RB)
    eng = Engine(executor="jit", optimize=False)
    for chunk in (None, 1, 2):
        got = eng.compile(e, chunk=chunk).run(A=RA, B=RB)
        np.testing.assert_allclose(np.asarray(got.data),
                                   np.asarray(want.data),
                                   atol=1e-5, rtol=1e-5)
    assert eng.cache_misses == 3          # distinct artifacts per chunk
    with pytest.raises(ValueError, match="chunk"):
        Engine(chunk=0)


def test_multi_root_on_gspmd_and_shardmap_single_device():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), S)
    a = tra.input("A", (2, 2), (4, 4))
    b = tra.input("B", (2, 2), (4, 4))
    r1, r2 = (a @ b), (a + b).sum(0)
    RA, RB = _rel(41, (2, 2), (4, 4)), _rel(42, (2, 2), (4, 4))
    want1 = REF.run(r1, A=RA, B=RB)
    want2 = REF.run(r2, A=RA, B=RB)
    for executor in ("gspmd", "shard_map"):
        eng = Engine(mesh, executor=executor)
        got1, got2 = eng.run((r1, r2), A=RA, B=RB)
        np.testing.assert_allclose(np.asarray(got1.data),
                                   np.asarray(want1.data), atol=1e-5)
        np.testing.assert_allclose(np.asarray(got2.data),
                                   np.asarray(want2.data), atol=1e-5)


def test_const_and_pad_run_on_every_executor():
    from repro.launch.mesh import make_mesh
    ones = tra.const(1.0, (2, 2), (4, 4))
    m = tra.input("M", (2, 2), (4, 4))
    e = (m * ones).pad((3, 3)).sum(0, 1)
    RM = _rel(51, (2, 2), (4, 4))
    want = REF.run(e, M=RM)
    for eng in (Engine(executor="jit"),
                Engine(make_mesh((1,), S), executor="gspmd"),
                Engine(make_mesh((1,), S), executor="shard_map")):
        got = eng.run(e, M=RM)
        np.testing.assert_allclose(np.asarray(got.data),
                                   np.asarray(want.data), atol=1e-6)
