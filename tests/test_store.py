"""The host relation store tier (``repro.store``): blocks, spill, config.

Covers the out-of-core tentpole's storage layer in isolation:

* ``RelationStore.put``/``get``/``slice`` round-trips across block
  boundaries, and ``create``+``append`` grows the key frontier the way a
  streamed plan writes outputs back;
* the LRU disk-spill tier under ``ram_limit_bytes`` (spilled blocks fault
  back in transparently, counters feed ``StreamStats``);
* ``HostRelation`` handles are accepted by ``Engine.run`` everywhere a
  relation is — materialized resident when no budget applies;
* the ``chunk="auto"`` autotune ladder (env override → device stats →
  static default) and the engine-level ``chunk``/``memory_budget``
  validation;
* ``plan_peak_bytes``, the compile-time live-set estimator the streaming
  planner budgets against.
"""
import os

import numpy as np
import pytest

import repro.core as tra
from repro.core import Engine, RelType, TensorRelation, from_tensor
from repro.core.cost import plan_peak_bytes
from repro.core.plan import as_node
from repro.store import (DEFAULT_BLOCK_BYTES, HostRelation, RelationStore,
                         StoreError, chunk_slices, device_memory_budget,
                         stream_budget_bytes)
from repro.store.autotune import ENV_BUDGET


def _rel(seed, key_shape, bound):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=tuple(key_shape) + tuple(bound))
    return from_tensor(
        np.asarray(data, np.float32).reshape(
            tuple(k * b for k, b in zip(key_shape, bound))),
        tuple(bound))


# ==========================================================================
# Blocks: put / slice / append round-trips
# ==========================================================================

def test_put_get_slice_roundtrip_across_blocks():
    R = _rel(0, (16, 2), (8, 4))
    # tiny block target → the 16-key relation splits into many blocks
    store = RelationStore(block_bytes=3 * 2 * 8 * 4 * 4)
    hr = store.put("R", R)
    assert store.get("R") is hr and "R" in store
    assert hr.complete and hr.nkeys == 16
    assert len(hr._blocks) > 3          # actually chunked
    full = np.asarray(R.data)
    np.testing.assert_array_equal(hr.to_numpy(), full)
    for lo, hi in [(0, 1), (2, 7), (5, 16), (15, 16)]:
        np.testing.assert_array_equal(hr.slice(lo, hi), full[lo:hi])


def test_create_append_frontier_and_errors():
    rt = RelType((6, 2), (4, 4), np.float32)
    store = RelationStore()
    hr = store.create("O", rt)
    assert hr.frontier == 0 and not hr.complete
    data = np.arange(6 * 2 * 4 * 4, dtype=np.float32).reshape(6, 2, 4, 4)
    hr.append(data[:2])
    hr.append(data[2:5])
    assert hr.frontier == 5 and not hr.complete
    with pytest.raises(StoreError, match="incomplete"):
        hr.to_numpy()
    with pytest.raises(StoreError, match="exceeds"):
        hr.append(data[:2])             # 5 + 2 > 6 keys
    with pytest.raises(StoreError, match="shape"):
        hr.append(np.zeros((1, 3, 4, 4), np.float32))
    hr.append(data[5:6])
    assert hr.complete
    np.testing.assert_array_equal(hr.to_numpy(), data)
    # create() under the same name replaces the old relation
    hr2 = store.create("O", rt)
    assert store.get("O") is hr2 and hr2.frontier == 0


def test_put_raw_array_requires_rtype():
    store = RelationStore()
    with pytest.raises(StoreError, match="rtype"):
        store.put("X", np.zeros((2, 2, 4, 4), np.float32))
    rt = RelType((2, 2), (4, 4), np.float32)
    hr = store.put("X", np.zeros((2, 2, 4, 4), np.float32), rtype=rt)
    assert hr.complete
    with pytest.raises(StoreError, match="dense"):
        store.put("Y", np.zeros((3, 2, 4, 4), np.float32), rtype=rt)


# ==========================================================================
# Disk spill tier (LRU, transparent fault-in)
# ==========================================================================

def test_spill_and_faultin_roundtrip(tmp_path):
    R = _rel(1, (16, 1), (8, 8))
    blk = 2 * 1 * 8 * 8 * 4             # 2 keys per block
    store = RelationStore(ram_limit_bytes=3 * blk, spill_dir=str(tmp_path),
                          block_bytes=blk)
    hr = store.put("R", R)
    assert store.spill_events > 0       # the 16-key put exceeded 3 blocks
    assert store.ram_bytes <= 3 * blk
    spilled = [b for b in hr._blocks if b.data is None]
    assert spilled and all(b.path for b in spilled)
    # reads fault spilled blocks back in (and stay under the limit)
    np.testing.assert_array_equal(hr.to_numpy(), np.asarray(R.data))
    assert store.unspill_events > 0
    assert store.ram_bytes <= 3 * blk
    store.delete("R")
    assert store.ram_bytes == 0 and "R" not in store


def test_no_limit_never_spills():
    store = RelationStore()
    store.put("R", _rel(2, (8, 1), (8, 8)))
    assert store.spill_events == 0 and store.ram_bytes > 0


def _spilled_store(tmp_path):
    R = _rel(5, (16, 1), (8, 8))
    blk = 2 * 1 * 8 * 8 * 4
    store = RelationStore(ram_limit_bytes=3 * blk, spill_dir=str(tmp_path),
                          block_bytes=blk)
    hr = store.put("R", R)
    spilled = [b for b in hr._blocks if b.data is None]
    assert spilled
    return store, hr, spilled[0]


def test_spill_is_atomic_and_checksummed(tmp_path):
    _, hr, blk = _spilled_store(tmp_path)
    # the atomic rename leaves no temp files behind, and the block
    # record carries a content checksum for fault-in verification
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    assert blk.checksum is not None


def test_truncated_spill_file_raises_spill_corruption(tmp_path):
    from repro.store import SpillCorruption
    _, hr, blk = _spilled_store(tmp_path)
    size = os.path.getsize(blk.path)
    with open(blk.path, "r+b") as f:     # torn write: drop the tail
        f.truncate(size // 2)
    with pytest.raises(SpillCorruption):
        hr.slice(blk.start, blk.stop)


def test_bitflipped_spill_file_fails_checksum(tmp_path):
    from repro.store import SpillCorruption
    _, hr, blk = _spilled_store(tmp_path)
    with open(blk.path, "r+b") as f:     # same size, corrupted payload
        f.seek(os.path.getsize(blk.path) - 5)
        byte = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([byte[0] ^ 0xFF]))
    with pytest.raises(SpillCorruption, match="checksum"):
        hr.slice(blk.start, blk.stop)


def test_intact_spill_faults_in_after_verification(tmp_path):
    _, hr, blk = _spilled_store(tmp_path)
    out = hr.slice(blk.start, blk.stop)   # untouched file: verifies clean
    assert out.shape[0] == blk.stop - blk.start


# ==========================================================================
# HostRelation handles through Engine.run (resident materialization)
# ==========================================================================

@pytest.mark.parametrize("executor", ["reference", "jit"])
def test_host_relation_accepted_by_engine_run(executor):
    a = tra.input("A", key_shape=(4, 2), bound=(4, 4))
    b = tra.input("B", key_shape=(2, 3), bound=(4, 4))
    e = a @ b
    RA, RB = _rel(3, (4, 2), (4, 4)), _rel(4, (2, 3), (4, 4))
    want = Engine(executor="reference", optimize=False).run(e, A=RA, B=RB)
    store = RelationStore()
    eng = Engine(executor=executor)
    got = eng.run(e, A=store.put("A", RA), B=RB)
    np.testing.assert_allclose(np.asarray(got.data), np.asarray(want.data),
                               atol=1e-5, rtol=1e-5)


def test_host_relation_type_mismatch_rejected():
    a = tra.input("A", key_shape=(4, 2), bound=(4, 4))
    b = tra.input("B", key_shape=(2, 3), bound=(4, 4))
    store = RelationStore()
    wrong = store.put("A", _rel(5, (2, 3), (4, 4)))
    with pytest.raises(ValueError, match="host relation type"):
        Engine(executor="jit").run(a @ b, A=wrong,
                                   B=_rel(4, (2, 3), (4, 4)))


# ==========================================================================
# Autotune ladder + engine configuration validation
# ==========================================================================

def test_device_budget_env_override(monkeypatch):
    monkeypatch.setenv(ENV_BUDGET, str(123 * 1024 * 1024))
    assert device_memory_budget() == 123 * 1024 * 1024
    # stream budget applies the safety fraction to the device budget
    assert 0 < stream_budget_bytes() < 123 * 1024 * 1024
    monkeypatch.delenv(ENV_BUDGET)
    # explicit budgets pass through unscaled
    assert stream_budget_bytes(4096) == 4096


def test_chunk_slices_solves_budget():
    # budget 1000B, 2×100B double-buffered outputs → 800B over 50B slices
    assert chunk_slices(50, 100, 1000) == 16
    assert chunk_slices(10 ** 9, 10 ** 9, 1000) == 1   # never below 1


def test_engine_chunk_auto_matches_static_default():
    a = tra.input("A", key_shape=(2, 4), bound=(4, 4))
    b = tra.input("B", key_shape=(4, 2), bound=(4, 4))
    # elemMax agg over a join → the chunked streaming fused path, where
    # the chunk size is the knob "auto" tunes
    e = a.join(b, on=((1,), (0,)), kernel="elemMul").agg((0, 2), "elemMax")
    RA, RB = _rel(6, (2, 4), (4, 4)), _rel(7, (4, 2), (4, 4))
    want = Engine(executor="reference", optimize=False,
                  fuse=False).run(e, A=RA, B=RB)
    for chunk in ("auto", None, 2):
        got = Engine(executor="jit", chunk=chunk).run(e, A=RA, B=RB)
        np.testing.assert_allclose(np.asarray(got.data),
                                   np.asarray(want.data),
                                   atol=1e-5, rtol=1e-5)


def test_engine_config_validation():
    with pytest.raises(ValueError, match="chunk"):
        Engine(chunk="bogus")
    with pytest.raises(ValueError, match="chunk must be >= 1"):
        Engine(chunk=0)
    with pytest.raises(ValueError, match="chunk must be >= 1"):
        Engine().compile(tra.input("A", (2, 2), (2, 2)) @
                         tra.input("B", (2, 2), (2, 2)), chunk=0)
    with pytest.raises(ValueError, match="memory_budget"):
        Engine(memory_budget=0)
    # the engine-owned store is lazy and sticky
    eng = Engine()
    assert eng.store is eng.store
    mine = RelationStore()
    assert Engine(store=mine).store is mine


# ==========================================================================
# plan_peak_bytes: the live-set estimator the planner budgets against
# ==========================================================================

def test_plan_peak_bytes_scales_with_shapes_and_counts_fusion():
    def matmul(nk):
        a = tra.input("A", key_shape=(nk, 2), bound=(8, 8))
        b = tra.input("B", key_shape=(2, 2), bound=(8, 8))
        return as_node(a @ b)

    small, big = plan_peak_bytes(matmul(2)), plan_peak_bytes(matmul(64))
    assert big > small > 0
    # operands alone are a lower bound on the live set
    floats = (64 * 2 + 2 * 2) * 8 * 8
    assert big >= floats * 4
    # the fused (streamed) contraction never materializes the full join
    # product, so its peak is below the unfused walk's
    assert plan_peak_bytes(matmul(64), fuse=True) <= \
        plan_peak_bytes(matmul(64), fuse=False)
