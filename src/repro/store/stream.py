"""Plan-level streaming through the host relation store.

This generalizes the chunked ``fused_join_agg`` reduction (which streams
*grid slices of one contraction*) into an out-of-core pass over a whole
logical plan: pick one key dimension, slice every node that carries it,
and execute the plan chunk-by-chunk with per-chunk host→device copies
double-buffered against the in-flight chunk's compute.  Two schedules:

``stream-out``
    The streamed dimension survives to the *root output*.  Each chunk
    program computes an output key range; chunks either concatenate on
    device or — when the output itself is oversized — append straight
    back into the :class:`~repro.store.relation.RelationStore`, so
    multi-node plans (a two-matmul chain, the §5.3 layer stack) run with
    bounded device footprint and no whole-intermediate rematerialization.

``stream-reduce``
    The root is an associative ``TraAgg(TraJoin)`` contraction and the
    streamed dimension is *reduced away*.  Each chunk contributes a
    partial of the full output; partials fold on device with the agg
    kernel — the paper's Σ∘⋈ streaming reduction lifted to key ranges
    whose operand slices live off-device until their turn.

The **carrier analysis** (:func:`_slot_walk`) decides which nodes a
streamed dimension passes through: joins slice both sides of a joined
dimension (the frontier-min rule makes one-sided slicing silently wrong),
aggregations map output dims through ``group_by``, and any subtree the
dimension does not reach stays device-resident for the whole run.  Plans
where the same node would need slicing along two dims, or the same input
name is needed both sliced and whole, are rejected (:class:`NotStreamable`)
and fall back to resident execution.

Chunk sizing probes :func:`repro.core.cost.plan_peak_bytes` on 1- and
2-key rebuilt programs — an affine live-bytes model ``peak(c) ≈ fixed +
c·slope`` — and solves for the largest chunk whose live set (plus the
double-buffered prefetch) fits the memory budget.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost import plan_peak_bytes
from repro.core.plan import (TraAgg, TraConcat, TraConst, TraFilter,
                             TraInput, TraJoin, TraNode, TraPad, TraReKey,
                             TraTile, TraTransform, TypeInfo, as_node,
                             infer, postorder)
from repro.core.tra import TensorRelation, can_fuse
from repro.store.autotune import stream_budget_bytes
from repro.store.relation import HostRelation, RelationStore


class NotStreamable(RuntimeError):
    """The plan (or this run's inputs) cannot take the streaming path."""


@dataclasses.dataclass
class StreamPlan:
    """Compile-time streaming decision for one logical root."""

    mode: str                       # resident | stream-out | stream-reduce
    root: TraNode
    out_info: TypeInfo
    budget: Optional[int] = None
    dim: int = -1                   # streamed output / join-out key dim
    sliced: Dict[int, int] = dataclasses.field(default_factory=dict)
    input_dims: Dict[str, int] = dataclasses.field(default_factory=dict)
    chunk_keys: int = 0
    nkeys: int = 0
    out_store: bool = False
    agg_kernel: object = None       # stream-reduce fold kernel

    @property
    def nchunks(self) -> int:
        if self.mode == "resident" or self.chunk_keys < 1:
            return 1
        return -(-self.nkeys // self.chunk_keys)


def _itemsize(rtype) -> int:
    return np.dtype(rtype.dtype).itemsize


def _slot_walk(root: TraNode, start: TraNode, start_dim: int,
               types: Dict[int, TypeInfo],
               reject: Optional[list] = None) -> Optional[Dict[int, int]]:
    """Map ``{id(node): key dim}`` for every node the streamed dim carries
    through, or None when the plan rejects this dimension.

    When ``reject`` is a list, every rejection appends a ``(node,
    reason)`` pair — the provenance the static verifier's stream-carrier
    pass (:mod:`repro.analysis.streaming`) renders per candidate dim."""
    sliced: Dict[int, int] = {}
    whole: List[TraNode] = []
    ok = True

    def refuse(n, reason: str) -> None:
        nonlocal ok
        ok = False
        if reject is not None:
            reject.append((n, reason))

    def ka(n) -> int:
        return types[id(n)].rtype.key_arity

    def walk(n, d) -> None:
        if not ok:
            return
        prev = sliced.get(id(n))
        if prev is not None:
            if prev != d:
                refuse(n, f"needs slicing along two key dims "
                          f"({prev} and {d}) at once")
            return
        sliced[id(n)] = d
        if isinstance(n, (TraInput, TraConst)):
            return
        if isinstance(n, TraTransform):
            walk(n.child, d)
        elif isinstance(n, TraAgg):
            walk(n.child, n.group_by[d])
        elif isinstance(n, TraJoin):
            kl = ka(n.left)
            if d < kl:
                walk(n.left, d)
                if d in n.join_keys_l:
                    # joined dim: min-frontier rule — slice BOTH sides
                    walk(n.right, n.join_keys_r[n.join_keys_l.index(d)])
                else:
                    whole.append(n.right)
            else:
                whole.append(n.left)
                r_nonjoin = [dd for dd in range(ka(n.right))
                             if dd not in n.join_keys_r]
                walk(n.right, r_nonjoin[d - kl])
        elif isinstance(n, TraTile):
            if d < ka(n.child):
                walk(n.child, d)
            else:
                refuse(n, "the appended tile dim indexes array tiles, "
                          "not a sliceable key range")
        elif isinstance(n, TraConcat):
            walk(n.child, d if d < n.key_dim else d + 1)
        else:
            # TraReKey / TraFilter / TraPad: arbitrary key rewrites — a key
            # range of the output has no static preimage range
            refuse(n, "arbitrary key rewrite: an output key range has no "
                      "static preimage range to slice")

    walk(start, start_dim)
    if not ok:
        return None
    whole_ids = set()
    for w in whole:
        for n in postorder(w):
            whole_ids.add(id(n))
    conflicted = whole_ids & set(sliced)
    if conflicted:
        for n in postorder(root):
            if id(n) in conflicted:
                refuse(n, "subtree is needed both sliced and whole "
                          "(it feeds a join side the streamed dim does "
                          "not reach)")
                break
        return None
    name_dim: Dict[str, int] = {}
    for n in postorder(root):
        if isinstance(n, TraInput) and id(n) in sliced:
            d = sliced[id(n)]
            if name_dim.setdefault(n.name, d) != d:
                refuse(n, f"input {n.name!r} would have to stream along "
                          f"two different key dims "
                          f"({name_dim[n.name]} and {d})")
                return None
    for n in postorder(root):
        if isinstance(n, TraInput) and id(n) not in sliced \
                and n.name in name_dim:
            refuse(n, f"input {n.name!r} is needed both sliced and whole "
                      f"(it appears in a resident subtree too)")
            return None
    if not name_dim:
        refuse(root, "no input is actually sliced along this dim — "
                     "nothing would stream")
        return None
    return sliced


def _rebuild(root: TraNode, sliced: Dict[int, int], length: int) -> TraNode:
    """The chunk program: ``root`` with every sliced node's streamed key
    dim shrunk to ``length``.  Whole subtrees are reused as the SAME
    objects, so their plan signatures — and the Engine's structural
    compile cache entries — are shared across every chunk."""
    memo: Dict[int, TraNode] = {}

    def rb(n):
        if id(n) in memo:
            return memo[id(n)]
        if isinstance(n, (TraInput, TraConst)):
            if id(n) in sliced:
                d = sliced[id(n)]
                ks = list(n.rtype.key_shape)
                ks[d] = length
                out = dataclasses.replace(n, rtype=n.rtype.with_key_shape(ks))
            else:
                out = n
        else:
            if isinstance(n, TraJoin):
                kids = {"left": rb(n.left), "right": rb(n.right)}
                changed = kids["left"] is not n.left \
                    or kids["right"] is not n.right
            else:
                kids = {"child": rb(n.child)}
                changed = kids["child"] is not n.child
            out = dataclasses.replace(n, **kids) if changed else n
        memo[id(n)] = out
        return out

    return rb(root)


class StreamExecutor:
    """Schedules a logical plan through the store under a byte budget.

    Owned by an :class:`~repro.core.engine.Engine`; ``plan`` runs at
    compile time (pure shape/byte analysis), ``execute`` drives the
    double-buffered chunk loop and accounts every transfer into a
    :class:`~repro.launch.metering.StreamStats`.
    """

    def __init__(self, engine, store: Optional[RelationStore] = None,
                 budget: Optional[int] = None) -> None:
        self.engine = engine
        self.store = store if store is not None else engine.store
        self.budget = budget if budget is not None \
            else getattr(engine, "memory_budget", None)

    # ------------------------------------------------------------------
    # planning
    # ------------------------------------------------------------------
    def plan(self, root, *, force: bool = False,
             chunk_keys: Optional[int] = None) -> StreamPlan:
        root = as_node(root)
        if not isinstance(root, TraNode):
            raise NotStreamable(
                "only logical (TRA) roots stream through the store")
        types: Dict[int, TypeInfo] = {}
        out_info = infer(root, cache=types)
        budget = stream_budget_bytes(self.budget)
        total = plan_peak_bytes(root, fuse=getattr(self.engine, "fuse", True))
        if total <= budget and not force:
            return StreamPlan("resident", root, out_info, budget)
        # masks (static on types, or runtime ones minted by in-plan
        # filters/rekeys/pads) violate the continuity the chunk
        # concatenation relies on — those plans only run resident
        holey = any(types[id(n)].mask is not None
                    or isinstance(n, (TraFilter, TraPad, TraReKey))
                    for n in postorder(root))
        if holey:
            if force:
                raise NotStreamable(
                    "streaming requires continuous relations (masked "
                    "types or in-plan filter/rekey/pad run resident)")
            return StreamPlan("resident", root, out_info, budget)

        # -- stream-out: a root output key dim, largest first ------------
        out_ks = out_info.rtype.key_shape
        for d in sorted(range(len(out_ks)), key=lambda dd: -out_ks[dd]):
            nk = out_ks[d]
            if nk < 2:
                continue
            sliced = _slot_walk(root, root, d, types)
            if sliced is None:
                continue
            ck = self._chunk_keys(root, sliced, types, nk, budget, force,
                                  chunk_keys)
            if ck is None:
                continue
            out_bytes = out_info.rtype.nfloats * _itemsize(out_info.rtype)
            sp = StreamPlan("stream-out", root, out_info, budget, d, sliced,
                            self._input_dims(root, sliced), ck, nk,
                            out_store=out_bytes > budget // 2)
            return sp

        # -- stream-reduce: associative contraction over a reduced dim ---
        if isinstance(root, TraAgg) and isinstance(root.child, TraJoin) \
                and root.kernel.is_associative \
                and can_fuse(root.child.kernel, root.kernel):
            join = root.child
            j_ks = types[id(join)].rtype.key_shape
            red = [d for d in range(len(j_ks)) if d not in root.group_by]
            for d in sorted(red, key=lambda dd: -j_ks[dd]):
                nk = j_ks[d]
                if nk < 2:
                    continue
                sliced = _slot_walk(root, join, d, types)
                if sliced is None:
                    continue
                ck = self._chunk_keys(root, sliced, types, nk, budget,
                                      force, chunk_keys)
                if ck is None:
                    continue
                return StreamPlan("stream-reduce", root, out_info, budget,
                                  d, sliced,
                                  self._input_dims(root, sliced), ck, nk,
                                  agg_kernel=root.kernel)
        raise NotStreamable(
            "no streamable key dimension found (key rewrites, tiled dims, "
            "or conflicting slice requirements block every candidate)")

    @staticmethod
    def _input_dims(root, sliced) -> Dict[str, int]:
        return {n.name: sliced[id(n)] for n in postorder(root)
                if isinstance(n, TraInput) and id(n) in sliced}

    def _chunk_keys(self, root, sliced, types, nkeys, budget, force,
                    override) -> Optional[int]:
        if override is not None:
            return max(1, min(int(override), nkeys))
        fuse = getattr(self.engine, "fuse", True)
        p1 = plan_peak_bytes(_rebuild(root, sliced, 1), fuse=fuse)
        p2 = plan_peak_bytes(_rebuild(root, sliced, 2), fuse=fuse) \
            if nkeys >= 2 else p1
        slope = max(1, p2 - p1)
        fixed = max(0, p1 - slope)
        # the prefetched next chunk's input slices are live during compute
        prefetch = 0
        for n in postorder(root):
            if isinstance(n, TraInput) and id(n) in sliced:
                ti = types[id(n)]
                per = (ti.rtype.nfloats * _itemsize(ti.rtype)
                       // max(1, ti.rtype.key_shape[sliced[id(n)]]))
                prefetch += per
        ck = (budget - fixed) // max(1, slope + prefetch)
        if ck < 1:
            if not force:
                return None
            ck = 1
        if ck >= nkeys:
            if not force:
                return None     # resident part alone is over budget
            ck = max(1, nkeys // 4)
        return int(ck)

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def execute(self, splan: StreamPlan, env: Dict[str, object], stats):
        stores = {self.store}
        for v in env.values():
            if isinstance(v, HostRelation):
                stores.add(v.store)
        spill0 = sum(s.spill_events for s in stores)
        spillb0 = sum(s.spill_bytes for s in stores)
        try:
            if splan.mode == "resident" or self._must_run_resident(env):
                out = self._run_resident(splan, env, stats)
            elif splan.mode == "stream-out":
                out = self._run_stream_out(splan, env, stats)
            else:
                out = self._run_stream_reduce(splan, env, stats)
        finally:
            stats.runs += 1
            stats.spill_events += sum(s.spill_events for s in stores) - spill0
            stats.spill_bytes += sum(s.spill_bytes for s in stores) - spillb0
        return out

    @staticmethod
    def _must_run_resident(env) -> bool:
        # masked values violate continuity — only the materialized path
        # (whose executors already know the mask rules) may run them
        return any(getattr(v, "mask", None) is not None
                   for v in env.values())

    def _needed(self, root, env) -> Dict[str, object]:
        names = {n.name for n in postorder(root) if isinstance(n, TraInput)}
        return {k: v for k, v in env.items() if k in names}

    def _to_device(self, value, stats) -> object:
        import jax
        if isinstance(value, HostRelation):
            rel = value.to_relation()
            stats.h2d_bytes += rel.data.nbytes
            return rel
        data = value.data if isinstance(value, TensorRelation) else value
        if isinstance(data, np.ndarray):
            stats.h2d_bytes += data.nbytes
            dev = jax.device_put(data)
            if isinstance(value, TensorRelation):
                return TensorRelation(dev, value.rtype, value.mask)
            return dev
        return value

    def _run_resident(self, splan, env, stats):
        mat = {k: self._to_device(v, stats)
               for k, v in self._needed(splan.root, env).items()}
        stats.mode = "resident"
        return self.engine.compile(splan.root).run(**mat)

    def _load_chunk(self, splan, env, lo, hi, stats, hidden):
        import jax
        t0 = time.perf_counter()
        out: Dict[str, object] = {}
        moved = 0
        for name, d in splan.input_dims.items():
            v = env[name]
            if isinstance(v, HostRelation):
                if v.split_dim != d:
                    raise NotStreamable(
                        f"input {name!r} is blocked along key dim "
                        f"{v.split_dim} but the plan streams dim {d}")
                arr = v.slice(lo, hi)
                moved += arr.nbytes
                out[name] = jax.device_put(arr)
                continue
            data = v.data if isinstance(v, TensorRelation) else v
            idx = [slice(None)] * data.ndim
            idx[d] = slice(lo, hi)
            if isinstance(data, np.ndarray):
                arr = data[tuple(idx)]
                moved += arr.nbytes
                out[name] = jax.device_put(arr)
            else:
                out[name] = data[tuple(idx)]    # already device-resident
        dt = time.perf_counter() - t0
        stats.copy_s += dt
        if hidden:
            stats.hidden_copy_s += dt
        stats.h2d_bytes += moved
        dev_bytes = sum(a.nbytes for a in out.values())
        return out, dev_bytes

    def _spans(self, splan) -> List[Tuple[int, int]]:
        nk, ck = splan.nkeys, splan.chunk_keys
        return [(lo, min(lo + ck, nk)) for lo in range(0, nk, ck)]

    def _chunk_programs(self, splan, spans):
        progs = {}
        for lo, hi in spans:
            n = hi - lo
            if n not in progs:
                progs[n] = self.engine.compile(
                    _rebuild(splan.root, splan.sliced, n))
        return progs

    def _resident_env(self, splan, env, stats):
        need = self._needed(splan.root, env)
        res = {k: self._to_device(v, stats) for k, v in need.items()
               if k not in splan.input_dims}
        rbytes = 0
        for v in res.values():
            data = v.data if isinstance(v, TensorRelation) else v
            rbytes += getattr(data, "nbytes", 0)
        return res, rbytes

    def _run_stream_out(self, splan, env, stats):
        import jax
        import jax.numpy as jnp
        stats.mode = "stream-out"
        stats.budget_bytes = splan.budget
        spans = self._spans(splan)
        progs = self._chunk_programs(splan, spans)
        resident, resident_bytes = self._resident_env(splan, env, stats)
        out_hr = None
        if splan.out_store:
            out_hr = self.store.create(
                f"stream-out:{id(splan.root):x}", splan.out_info.rtype,
                split_dim=splan.dim)
        collected, kept_bytes = [], 0
        pending, pending_bytes = self._load_chunk(
            splan, env, *spans[0], stats, hidden=False)
        for i, (lo, hi) in enumerate(spans):
            cur, cur_bytes = pending, pending_bytes
            t0 = time.perf_counter()
            out = progs[hi - lo].run(**cur, **resident)
            if i + 1 < len(spans):
                pending, pending_bytes = self._load_chunk(
                    splan, env, *spans[i + 1], stats, hidden=True)
            else:
                pending, pending_bytes = None, 0
            jax.block_until_ready(out.data)
            stats.compute_s += time.perf_counter() - t0
            stats.chunks += 1
            peak = (resident_bytes + cur_bytes + pending_bytes
                    + out.data.nbytes + kept_bytes)
            stats.peak_device_bytes = max(stats.peak_device_bytes, peak)
            if out_hr is not None:
                host = np.asarray(out.data)             # D2H
                stats.d2h_bytes += host.nbytes
                out_hr.append(host)
            else:
                collected.append(out.data)
                kept_bytes += out.data.nbytes
        if out_hr is not None:
            return out_hr
        data = jnp.concatenate(collected, axis=splan.dim)
        stats.peak_device_bytes = max(
            stats.peak_device_bytes,
            resident_bytes + kept_bytes + data.nbytes)
        return TensorRelation(data, splan.out_info.rtype, None)

    def _run_stream_reduce(self, splan, env, stats):
        import jax
        stats.mode = "stream-reduce"
        stats.budget_bytes = splan.budget
        spans = self._spans(splan)
        progs = self._chunk_programs(splan, spans)
        resident, resident_bytes = self._resident_env(splan, env, stats)
        acc = None
        pending, pending_bytes = self._load_chunk(
            splan, env, *spans[0], stats, hidden=False)
        for i, (lo, hi) in enumerate(spans):
            cur, cur_bytes = pending, pending_bytes
            t0 = time.perf_counter()
            part = progs[hi - lo].run(**cur, **resident)
            if i + 1 < len(spans):
                pending, pending_bytes = self._load_chunk(
                    splan, env, *spans[i + 1], stats, hidden=True)
            else:
                pending, pending_bytes = None, 0
            acc = part.data if acc is None \
                else splan.agg_kernel.apply(acc, part.data)
            jax.block_until_ready(acc)
            stats.compute_s += time.perf_counter() - t0
            stats.chunks += 1
            peak = (resident_bytes + cur_bytes + pending_bytes
                    + 2 * acc.nbytes)
            stats.peak_device_bytes = max(stats.peak_device_bytes, peak)
        return TensorRelation(acc, splan.out_info.rtype, None)
