"""Device-memory calibration and fused-contraction chunk autotuning.

``Engine(chunk="auto")`` (the default since the out-of-core subsystem)
sizes the streamed contraction's chunk from a live-slice bytes model
instead of the fixed 16 MiB ``DEFAULT_CHUNK_BYTES`` guess:

    live(chunk) ≈ chunk · slice_bytes  +  2 · out_bytes

— ``chunk`` vmapped join-grid slices in flight plus the output
accumulator and the merged partial.  The budget it solves against is, in
order of preference: an explicit ``Engine(memory_budget=...)``, the
``REPRO_DEVICE_MEMORY_BUDGET`` environment override, the device's
reported ``memory_stats()['bytes_limit']`` scaled by a safety fraction
(calibrated once per device — accelerator backends report it, CPU
returns no stats), and finally ``DEFAULT_CHUNK_BYTES`` so CPU-only
environments keep the pre-autotune behavior.
"""
from __future__ import annotations

import os
from typing import Optional

ENV_BUDGET = "REPRO_DEVICE_MEMORY_BUDGET"
SAFETY_FRACTION = 0.25      # fraction of device memory the live set may use

_calibrated: dict = {}


def device_memory_budget(device=None) -> Optional[int]:
    """Total device memory in bytes, or None when the backend won't say.

    The ``REPRO_DEVICE_MEMORY_BUDGET`` env var overrides (useful to
    simulate a small device in CI); otherwise the answer is calibrated
    once per ``(platform, id)`` from ``memory_stats()``.
    """
    env = os.environ.get(ENV_BUDGET)
    if env:
        try:
            return max(1, int(float(env)))
        except ValueError:
            pass
    import jax
    device = device if device is not None else jax.devices()[0]
    key = (device.platform, device.id)
    if key not in _calibrated:
        limit = None
        try:
            stats = device.memory_stats()
            if stats:
                limit = int(stats.get("bytes_limit")
                            or stats.get("bytes_reservable_limit") or 0)
                limit = limit or None
        except Exception:       # backend without memory introspection
            limit = None
        _calibrated[key] = limit
    return _calibrated[key]


def stream_budget_bytes(budget: Optional[int] = None) -> int:
    """Resolve the live-bytes budget streaming paths plan against."""
    if budget is not None:
        return max(1, int(budget))
    dev = device_memory_budget()
    if dev:
        return max(1, int(dev * SAFETY_FRACTION))
    from repro.core.tra import DEFAULT_CHUNK_BYTES
    return DEFAULT_CHUNK_BYTES


def chunk_slices(slice_bytes: int, out_bytes: int,
                 budget: Optional[int] = None) -> int:
    """Chunk count solving the live-slice model against the budget."""
    b = stream_budget_bytes(budget)
    return max(1, (b - 2 * out_bytes) // max(1, slice_bytes))
