"""Host-RAM relation store: chunked, key-range-partitioned tensor relations.

The paper's headline claim — TRA handles "matrices or tensors that do not
easily fit into the RAM of an ASIC" — needs relations that *live off the
device*.  A :class:`HostRelation` is a handle to one tensor relation held
as an ordered list of contiguous key-range **blocks** along a single key
dimension (``split_dim``), each block a pinned host ``numpy`` buffer.  The
handle is usable anywhere ``Engine.run`` accepts a relation: the Engine
either streams it chunk-by-chunk through the plan (``repro.store.stream``)
or materializes it once on device when the plan fits.

A :class:`RelationStore` owns the blocks.  It tracks resident host bytes
and, past an optional ``ram_limit_bytes``, spills least-recently-used
blocks to a disk tier (``numpy`` ``.npy`` files under ``spill_dir``),
faulting them back in transparently on access — so the host tier itself
degrades gracefully instead of OOMing the driver process.  Spill writes
are atomic (temp file + ``os.replace``) and carry a content checksum
verified on fault-in; a torn or corrupt spill file raises
:class:`SpillCorruption` instead of returning silently wrong data.

Blocks are split at ``block_bytes`` targets (default 64 MiB) so spill and
streaming granularity stay decoupled from how the user hands the data in.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import zlib
from typing import Dict, List, Optional

import numpy as np

from repro.core.tra import RelType, TensorRelation

DEFAULT_BLOCK_BYTES = 64 * 1024 * 1024


class StoreError(RuntimeError):
    """Raised on malformed store usage (shape/range mismatches)."""


class SpillCorruption(StoreError):
    """A spilled block failed verification on fault-in.

    Raised when a disk-tier ``.npy`` file is unreadable (torn write,
    truncation) or reads back with a different content checksum than the
    block record carries — the store refuses to hand back silently wrong
    data.  Spill writes go through a temp file + ``os.replace`` so a
    crash mid-spill can at worst leave a stale-but-whole previous
    version, never a half-written one.
    """


@dataclasses.dataclass
class _Block:
    """One contiguous key-range ``[start, stop)`` along the split dim."""

    start: int
    stop: int
    data: Optional[np.ndarray]      # None while spilled to disk
    path: Optional[str] = None      # .npy file when spilled
    nbytes: int = 0
    seq: int = 0                    # LRU clock; larger = more recent
    checksum: Optional[int] = None  # crc32 of the block's raw bytes


class HostRelation:
    """A tensor relation held in host RAM as key-range blocks.

    ``rtype`` is the full (dense-layout) relation type; blocks partition
    key dimension ``split_dim``.  ``append`` grows the key frontier — a
    streamed plan writes its output back chunk-by-chunk; ``complete`` is
    True once the blocks cover ``rtype.key_shape[split_dim]``.  ``mask``
    (a host bool grid over the key space) carries non-continuous
    relations; streaming requires continuity, so masked handles only take
    the materialize-resident path.
    """

    def __init__(self, store: "RelationStore", name: str, rtype: RelType,
                 split_dim: int = 0,
                 mask: Optional[np.ndarray] = None) -> None:
        if not 0 <= split_dim < rtype.key_arity:
            raise StoreError(
                f"split_dim {split_dim} out of range for key arity "
                f"{rtype.key_arity}")
        self.store = store
        self.name = name
        self.rtype = rtype
        self.split_dim = split_dim
        self.mask = None if mask is None else np.asarray(mask, bool)
        self._blocks: List[_Block] = []

    # -- shape/bookkeeping -------------------------------------------------
    @property
    def nkeys(self) -> int:
        """Key count along the split dimension."""
        return self.rtype.key_shape[self.split_dim]

    @property
    def frontier(self) -> int:
        """Keys covered so far along the split dimension."""
        return self._blocks[-1].stop if self._blocks else 0

    @property
    def complete(self) -> bool:
        return self.frontier >= self.nkeys

    @property
    def nbytes(self) -> int:
        """Full dense size (what a device materialization would allocate)."""
        return self.rtype.nfloats * np.dtype(self.rtype.dtype).itemsize

    @property
    def stored_bytes(self) -> int:
        return sum(b.nbytes for b in self._blocks)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"HostRelation({self.name!r}, {self.rtype}, "
                f"split_dim={self.split_dim}, blocks={len(self._blocks)}, "
                f"frontier={self.frontier}/{self.nkeys})")

    # -- writes ------------------------------------------------------------
    def append(self, array) -> None:
        """Append the next key range along the split dim (host copy)."""
        arr = np.ascontiguousarray(np.asarray(array))
        want = list(self.rtype.key_shape) + list(self.rtype.bound)
        if arr.ndim != len(want):
            raise StoreError(
                f"append to {self.name!r}: rank {arr.ndim} != {len(want)}")
        n = arr.shape[self.split_dim]
        want[self.split_dim] = n
        if list(arr.shape) != want:
            raise StoreError(
                f"append to {self.name!r}: shape {arr.shape} != {tuple(want)}")
        if self.frontier + n > self.nkeys:
            raise StoreError(
                f"append to {self.name!r}: frontier {self.frontier}+{n} "
                f"exceeds {self.nkeys} keys")
        self.store._admit_range(self, arr)

    # -- reads -------------------------------------------------------------
    def slice(self, lo: int, hi: int) -> np.ndarray:
        """Dense host array for keys ``[lo, hi)`` along the split dim."""
        if not 0 <= lo < hi <= self.frontier:
            raise StoreError(
                f"slice [{lo}, {hi}) outside frontier {self.frontier} "
                f"of {self.name!r}")
        parts = []
        for b in self._blocks:
            if b.stop <= lo or b.start >= hi:
                continue
            data = self.store._loaded(b)
            s, e = max(lo, b.start) - b.start, min(hi, b.stop) - b.start
            idx = [slice(None)] * data.ndim
            idx[self.split_dim] = slice(s, e)
            parts.append(data[tuple(idx)])
        if len(parts) == 1:
            return parts[0]
        return np.concatenate(parts, axis=self.split_dim)

    def mask_slice(self, lo: int, hi: int) -> Optional[np.ndarray]:
        if self.mask is None:
            return None
        idx = [slice(None)] * self.mask.ndim
        idx[self.split_dim] = slice(lo, hi)
        return self.mask[tuple(idx)]

    def to_numpy(self) -> np.ndarray:
        if not self.complete:
            raise StoreError(
                f"{self.name!r} is incomplete ({self.frontier}/{self.nkeys} "
                f"keys) — cannot materialize")
        return self.slice(0, self.nkeys)

    def to_relation(self) -> TensorRelation:
        """Materialize the whole relation on the default device."""
        import jax
        data = jax.device_put(self.to_numpy())
        mask = None
        if self.mask is not None:
            import jax.numpy as jnp
            mask = jnp.asarray(self.mask)
        return TensorRelation(data, self.rtype, mask)


class RelationStore:
    """Owns :class:`HostRelation` blocks; host tier + optional disk spill.

    ``ram_limit_bytes=None`` (default) never spills.  With a limit, blocks
    past the budget spill LRU-first to ``.npy`` files and fault back in on
    access; ``spill_events`` / ``spill_bytes`` / ``unspill_events`` feed
    the :class:`repro.launch.metering.StreamStats` counters.
    """

    def __init__(self, ram_limit_bytes: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 block_bytes: int = DEFAULT_BLOCK_BYTES) -> None:
        self.ram_limit_bytes = ram_limit_bytes
        self.block_bytes = max(1, block_bytes)
        self._spill_dir = spill_dir
        self._rels: Dict[str, HostRelation] = {}
        self._seq = 0
        self.ram_bytes = 0
        self.spill_events = 0
        self.spill_bytes = 0
        self.unspill_events = 0
        self.unspill_bytes = 0

    # -- relation lifecycle ------------------------------------------------
    def put(self, name: str, value, *, rtype: Optional[RelType] = None,
            split_dim: int = 0) -> HostRelation:
        """Ingest a relation (TensorRelation / array / HostRelation)."""
        mask = None
        if isinstance(value, HostRelation):
            rtype = value.rtype
            mask = value.mask
            data = value.to_numpy()
        elif isinstance(value, TensorRelation):
            rtype = value.rtype
            data = np.asarray(value.data)
            if value.mask is not None:
                mask = np.asarray(value.mask)
        else:
            data = np.asarray(value)
            if rtype is None:
                raise StoreError(
                    "put of a raw array needs an explicit rtype=")
            want = tuple(rtype.key_shape) + tuple(rtype.bound)
            if data.shape != want:
                raise StoreError(
                    f"put({name!r}): array shape {data.shape} != dense "
                    f"layout {want}")
        hr = self.create(name, rtype, split_dim=split_dim, mask=mask)
        n = hr.nkeys
        per_key = max(1, hr.nbytes // max(1, n))
        step = max(1, self.block_bytes // per_key)
        for lo in range(0, n, step):
            idx = [slice(None)] * data.ndim
            idx[split_dim] = slice(lo, min(lo + step, n))
            hr.append(data[tuple(idx)])
        return hr

    def create(self, name: str, rtype: RelType, *, split_dim: int = 0,
               mask: Optional[np.ndarray] = None) -> HostRelation:
        """New (empty) relation to be filled with ``append``; replaces any
        existing relation of the same name."""
        if name in self._rels:
            self.delete(name)
        hr = HostRelation(self, name, rtype, split_dim=split_dim, mask=mask)
        self._rels[name] = hr
        return hr

    def get(self, name: str) -> HostRelation:
        return self._rels[name]

    def __contains__(self, name: str) -> bool:
        return name in self._rels

    def relations(self) -> Dict[str, HostRelation]:
        return dict(self._rels)

    def delete(self, name: str) -> None:
        hr = self._rels.pop(name, None)
        if hr is None:
            return
        for b in hr._blocks:
            if b.data is not None:
                self.ram_bytes -= b.nbytes
            if b.path is not None and os.path.exists(b.path):
                os.unlink(b.path)
        hr._blocks = []

    # -- block admission / spill tier --------------------------------------
    def _admit_range(self, hr: HostRelation, arr: np.ndarray) -> None:
        n = arr.shape[hr.split_dim]
        per_key = max(1, arr.nbytes // max(1, n))
        step = max(1, self.block_bytes // per_key)
        for lo in range(0, n, step):
            idx = [slice(None)] * arr.ndim
            idx[hr.split_dim] = slice(lo, min(lo + step, n))
            part = np.ascontiguousarray(arr[tuple(idx)])
            self._seq += 1
            blk = _Block(start=hr.frontier,
                         stop=hr.frontier + part.shape[hr.split_dim],
                         data=part, nbytes=part.nbytes, seq=self._seq)
            hr._blocks.append(blk)
            self.ram_bytes += blk.nbytes
            self._maybe_spill(keep=blk)

    def _spill_path(self, blk: _Block) -> str:
        if self._spill_dir is None:
            self._spill_dir = tempfile.mkdtemp(prefix="repro-store-")
        os.makedirs(self._spill_dir, exist_ok=True)
        return os.path.join(self._spill_dir, f"blk-{id(blk):x}-{blk.seq}.npy")

    def _maybe_spill(self, keep: Optional[_Block] = None) -> None:
        if self.ram_limit_bytes is None:
            return
        while self.ram_bytes > self.ram_limit_bytes:
            victim = None
            for hr in self._rels.values():
                for b in hr._blocks:
                    if b.data is None or b is keep:
                        continue
                    if victim is None or b.seq < victim.seq:
                        victim = b
            if victim is None:
                return                  # nothing evictable — stay resident
            path = victim.path or self._spill_path(victim)
            # atomic spill: write beside the target, fsync, then rename —
            # a crash mid-write leaves the previous whole file (or none),
            # never a torn one that would fault back in silently wrong
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                np.save(f, victim.data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            victim.checksum = zlib.crc32(victim.data.tobytes())
            victim.path = path
            victim.data = None
            self.ram_bytes -= victim.nbytes
            self.spill_events += 1
            self.spill_bytes += victim.nbytes

    def _loaded(self, blk: _Block) -> np.ndarray:
        self._seq += 1
        blk.seq = self._seq             # touch for LRU
        if blk.data is None:
            try:
                data = np.load(blk.path)
            except Exception as err:
                raise SpillCorruption(
                    f"spilled block [{blk.start}, {blk.stop}) at "
                    f"{blk.path} is unreadable (torn or truncated "
                    f"write): {err!r}") from err
            if data.nbytes != blk.nbytes:
                raise SpillCorruption(
                    f"spilled block [{blk.start}, {blk.stop}) at "
                    f"{blk.path} read back {data.nbytes} bytes, "
                    f"expected {blk.nbytes}")
            if blk.checksum is not None \
                    and zlib.crc32(data.tobytes()) != blk.checksum:
                raise SpillCorruption(
                    f"spilled block [{blk.start}, {blk.stop}) at "
                    f"{blk.path} failed its content checksum — on-disk "
                    f"bytes differ from what was spilled")
            blk.data = data
            self.ram_bytes += blk.nbytes
            self.unspill_events += 1
            self.unspill_bytes += blk.nbytes
            self._maybe_spill(keep=blk)
        return blk.data
