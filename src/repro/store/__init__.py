"""Out-of-core TRA execution: host-RAM relation store + plan streaming.

The subsystem behind ``Engine(memory_budget=...)`` and ``HostRelation``
inputs — relations larger than device RAM live here as key-range blocks
(with an optional disk spill tier) and stream chunk-by-chunk through
compiled plans with double-buffered H2D transfers.  See
``docs/out_of_core.md``.
"""
from repro.store.autotune import (chunk_slices, device_memory_budget,
                                  stream_budget_bytes)
from repro.store.relation import (DEFAULT_BLOCK_BYTES, HostRelation,
                                  RelationStore, SpillCorruption,
                                  StoreError)
from repro.store.stream import NotStreamable, StreamExecutor, StreamPlan

__all__ = [
    "DEFAULT_BLOCK_BYTES",
    "HostRelation",
    "NotStreamable",
    "RelationStore",
    "SpillCorruption",
    "StoreError",
    "StreamExecutor",
    "StreamPlan",
    "chunk_slices",
    "device_memory_budget",
    "stream_budget_bytes",
]
