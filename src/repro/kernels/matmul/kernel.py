"""Blocked matmul Pallas TPU kernel — the TRA local-join hot spot.

The paper's ``⋈ᴸ`` applies an opaque MKL/CUDA ``matMul`` kernel per joined
block pair.  On TPU the analogous hot spot is an MXU-tiled block matmul:

* grid ``(M/bm, N/bn, K/bk)`` with the contraction dim innermost so the
  f32 accumulator lives in VMEM scratch across the K sweep,
* 128-aligned block shapes so every ``jnp.dot`` maps onto full MXU passes,
* inputs stay in their storage dtype (bf16 on TPU) and accumulate in f32
  (``preferred_element_type``), written back in the output dtype.

VMEM budget per core: ``bm*bk + bk*bn`` input tiles + ``bm*bn`` f32
accumulator; the default 512×512×512 tiling costs ~2.6 MB of the ~16 MB
VMEM, leaving headroom for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(a: jax.Array, b: jax.Array, *, block_m: int = 512,
                  block_n: int = 512, block_k: int = 512,
                  out_dtype=None, interpret: bool = False) -> jax.Array:
    """``a @ b`` for 2-D operands with MXU-aligned tiling.

    Shapes must divide the block sizes (the ops.py wrapper pads).
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} x {b.shape}")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError("shapes must divide block sizes (pad in ops.py)")
    out_dtype = out_dtype or a.dtype
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, l: (i, l)),
            pl.BlockSpec((block_k, block_n), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
