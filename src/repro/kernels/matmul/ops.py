"""Public matmul op: pads to block multiples, dispatches pallas vs jnp.

``impl="auto"`` uses the Pallas kernel on TPU backends and the jnp oracle
elsewhere (CPU dry-runs and tests lower through XLA's own matmul, which is
what a CPU run would use anyway; the kernel path is validated separately in
interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.matmul.kernel import matmul_pallas
from repro.kernels.matmul.ref import matmul_ref


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("impl", "block_m", "block_n",
                                             "block_k", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, impl: str = "auto",
           block_m: int = 512, block_n: int = 512, block_k: int = 512,
           interpret: bool = False) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        return matmul_ref(a, b)
    if impl != "pallas":
        raise ValueError(impl)
    m, n = a.shape[0], b.shape[1]
    bm, bn, bk = (min(block_m, m), min(block_n, n), min(block_k, a.shape[1]))
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    out = matmul_pallas(ap, bp, block_m=bm, block_n=bn, block_k=bk,
                        interpret=interpret)
    return out[:m, :n]
