"""Pure-jnp oracle for the Mamba2 SSD (state-space duality) scan.

Sequential recurrence, per head:

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * (B_t ⊗ x_t)     h ∈ R^{N×P}
    y_t = C_t · h_t                                        y ∈ R^{P}

with x (B,S,H,P), dt (B,S,H), A (H,) negative decay rates, B/C (B,S,N)
(single state group shared across heads, as in mamba2-130m).
"""
import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
            Cm: jax.Array, h0: jax.Array | None = None):
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    dtype = x.dtype
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)

    def step(hprev, inp):
        xt, dtt, bt, ct = inp                       # (b,h,p),(b,h),(b,n),(b,n)
        decay = jnp.exp(dtt * A[None, :])           # (b,h)
        upd = jnp.einsum("bn,bhp->bhnp", bt, xt * dtt[..., None])
        hnew = hprev * decay[..., None, None] + upd
        yt = jnp.einsum("bn,bhnp->bhp", ct, hnew)
        return hnew, yt

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    hfin, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(dtype), hfin
