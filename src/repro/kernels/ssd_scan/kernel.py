"""Chunked SSD (Mamba2) Pallas TPU kernel.

The SSD duality says the recurrence

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_tᵀ ;   y_t = C_t · h_t

splits, for a chunk of length L, into matmul-shaped work the MXU likes:

    within-chunk (quadratic, L×L):  Y_intra = (M ⊙ (C Bᵀ)) (dt ⊙ X)
       with M_ij = exp(a_i - a_j)·1[i ≥ j],  a = cumsum(dt·A)
    chunk state:  S_c = Σ_j exp(a_L - a_j) dt_j B_j ⊗ x_j        (N×P)
    across chunks (linear scan):  h ← h·exp(a_L) + S_c ;
       Y_inter,i = exp(a_i) C_i · h_prev

Grid is ``(B, H, n_chunks)`` with the chunk dim innermost-sequential so the
running state ``h`` persists in a VMEM scratch tile across chunk steps —
the TPU-idiomatic replacement for the GPU version's inter-block shared
memory handoff.  All matmuls run in f32 on (L×L)/(L×N)/(N×P) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hstate, *,
                nchunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        hstate[...] = jnp.zeros_like(hstate)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (L,)
    A = a_ref[0].astype(jnp.float32)                 # scalar decay rate
    Bm = b_ref[0].astype(jnp.float32)                # (L, N)
    Cm = c_ref[0].astype(jnp.float32)                # (L, N)

    da = dt * A                                      # (L,)
    a_cs = jnp.cumsum(da)                            # (L,) inclusive
    L = x.shape[0]

    # ---- within-chunk (quadratic) term --------------------------------
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # (L,L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    # decay from step j to step i (i ≥ j): exp(a_i - a_j); mask BEFORE the
    # exp so the discarded upper triangle cannot overflow to inf
    diff = jnp.where(ii >= jj, a_cs[:, None] - a_cs[None, :], 0.0)
    m = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    xdt = x * dt[:, None]                            # (L, P)
    y = jnp.dot(scores * m, xdt, preferred_element_type=jnp.float32)

    # ---- contribution of the carried state ----------------------------
    # y_inter_i = exp(a_i) * C_i · h_prev
    y += jnp.exp(a_cs)[:, None] * jnp.dot(
        Cm, hstate[...], preferred_element_type=jnp.float32)

    # ---- update carried state ------------------------------------------
    # S_c = Σ_j exp(a_L - a_j) dt_j B_j x_jᵀ ;  h ← h exp(a_L) + S_c
    w = jnp.exp(a_cs[-1] - a_cs)[:, None] * Bm       # (L, N)
    s_c = jnp.dot(w.T, xdt, preferred_element_type=jnp.float32)  # (N, P)
    hstate[...] = hstate[...] * jnp.exp(a_cs[-1]) + s_c

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_scan_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array, *, chunk: int = 256,
                    interpret: bool = False) -> jax.Array:
    """Chunked SSD forward: x (B,S,H,P), dt (B,S,H), A (H,), B/C (B,S,N)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError("sequence must divide chunk (pad in ops.py)")
    grid = (b, h, s // chunk)
    kernel = functools.partial(_ssd_kernel, nchunks=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, cc: (bb, cc, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, hh, cc: (bb, cc, hh)),
            pl.BlockSpec((1,), lambda bb, hh, cc: (hh,)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, cc: (bb, cc, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, cc: (bb, cc, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda bb, hh, cc: (bb, cc, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
