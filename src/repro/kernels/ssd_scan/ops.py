"""Public SSD op with pallas/jnp dispatch + the O(1) decode step.

The jnp fallback uses the same *chunked* math as the kernel (matmul form),
not the sequential scan, so the dry-run lowers to MXU-shaped HLO on every
backend; ``ssd_ref`` (sequential) remains the correctness oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_ref


def _ssd_chunked_jnp(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD in plain jnp (same algorithm as the Pallas kernel).

    Scans over chunks (the kernel's sequential grid dim) so peak temp is
    one chunk's intra-chunk tile — (b, L, L, h) — instead of all chunks'
    at once; XLA fuses the per-chunk einsums the same way the Pallas
    kernel tiles them in VMEM.
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    dtype = x.dtype
    c = s // chunk
    xf = x.astype(jnp.float32).reshape(b, c, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, c, chunk, h)
    Bf = Bm.astype(jnp.float32).reshape(b, c, chunk, n)
    Cf = Cm.astype(jnp.float32).reshape(b, c, chunk, n)
    Af = A.astype(jnp.float32)
    ii = jnp.arange(chunk)[:, None]
    jj = jnp.arange(chunk)[None, :]
    tri = (ii >= jj)[None, :, :, None]                 # (1,L,L,1)

    def step(hprev, inp):
        xc, dtc, bc, cc = inp                          # (b,L,h,p) …
        da = dtc * Af[None, None, :]                   # (b,L,h)
        a_cs = jnp.cumsum(da, axis=1)                  # inclusive
        scores = jnp.einsum("bin,bjn->bij", cc, bc)    # (b,L,L)
        diff = jnp.where(tri, a_cs[:, :, None, :] - a_cs[:, None, :, :],
                         0.0)
        m = jnp.where(tri, jnp.exp(diff), 0.0)         # (b,L,L,h)
        xdt = xc * dtc[..., None]                      # (b,L,h,p)
        y = jnp.einsum("bij,bijh,bjhp->bihp", scores, m, xdt)
        # inter-chunk contribution from the carried state
        y += jnp.exp(a_cs)[..., None] * jnp.einsum(
            "bin,bhnp->bihp", cc, hprev)
        # chunk state update: S_c = Σ_j exp(a_L − a_j) dt_j B_j ⊗ x_j
        wj = jnp.exp(a_cs[:, -1:, :] - a_cs) * dtc     # (b,L,h)
        s_c = jnp.einsum("bjn,bjh,bjhp->bhnp", bc, wj, xc)
        hnew = hprev * jnp.exp(a_cs[:, -1, :])[..., None, None] + s_c
        return hnew, y

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)                 # (c,b,L,h,p)
    return jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p).astype(dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 256, impl: str = "auto",
             interpret: bool = False):
    """SSD forward over a full sequence. Returns y (B,S,H,P)."""
    s = x.shape[1]
    chunk = min(chunk, s)
    if s % chunk:
        # causal recurrence: zero right-padding never affects live positions
        pad = chunk - s % chunk
        padded = ssd_scan(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            A,
            jnp.pad(Bm, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(Cm, ((0, 0), (0, pad), (0, 0))),
            chunk=chunk, impl=impl, interpret=interpret)
        return padded[:, :s]
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "jnp":
        return _ssd_chunked_jnp(x, dt, A, Bm, Cm, chunk)
    if impl == "ref":
        return ssd_ref(x, dt, A, Bm, Cm)[0]
    if impl != "pallas":
        raise ValueError(impl)
    return ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                           interpret=interpret)


@jax.jit
def ssd_final_state(x, dt, A, Bm, Cm):
    """Final SSM state h_S = Σ_j exp(a_S − a_j)·dt_j·(B_j ⊗ x_j).

    Used by the prefill path to seed the O(1) decode recurrence after a
    full-sequence SSD forward.  Shapes as :func:`ssd_scan`; returns
    (B, H, N, P) float32.
    """
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    da = dtf * A.astype(jnp.float32)[None, None, :]
    a_cs = jnp.cumsum(da, axis=1)                      # (B,S,H) inclusive
    w = jnp.exp(a_cs[:, -1:, :] - a_cs) * dtf          # (B,S,H)
    return jnp.einsum("bsn,bsh,bshp->bhnp", Bf, w, xf)


@jax.jit
def ssd_decode_step(h, x_t, dt_t, A, B_t, C_t):
    """O(1) recurrent decode step.

    h (B,H,N,P) carried state; x_t (B,H,P); dt_t (B,H); B_t/C_t (B,N).
    Returns (y_t (B,H,P), h_new).
    """
    hf = h.astype(jnp.float32)
    decay = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32)[None])
    upd = jnp.einsum("bn,bhp->bhnp", B_t.astype(jnp.float32),
                     x_t.astype(jnp.float32) * dt_t[..., None])
    hnew = hf * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C_t.astype(jnp.float32), hnew)
    return y.astype(x_t.dtype), hnew.astype(h.dtype)
