"""Public attention op with pallas/jnp dispatch.

The Pallas kernel targets self-attention (sq == skv — training/prefill).
Decode (sq=1 against a long KV cache) stays on the jnp path: a single-row
softmax is bandwidth-bound gather+GEMV work that XLA already emits
optimally, and a bq=1 tile would waste the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _blockwise_jnp(q, k, v, *, causal, window, softcap, scale,
                   block_q: int = 512):
    """Flash-structured attention in plain jnp: map over query blocks
    with a rematerialized block body, so peak temp is one block's scores
    (B, H, bq, S) rather than the full (B, H, S, S) matrix.  This is what
    the dry-run lowers on CPU for long sequences; on TPU the Pallas
    kernel replaces it."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    dv = v.shape[-1]                     # MLA: v head dim may differ
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    nq = -(-sq // block_q)
    pad = nq * block_q - sq
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qs = qp.reshape(b, hq, nq, block_q, d).transpose(2, 0, 1, 3, 4)
    kg = k.reshape(b, hkv, 1, skv, d)
    vg = v.reshape(b, hkv, 1, skv, dv)

    @jax.checkpoint
    def block(qi, i0):
        qf = qi.astype(jnp.float32).reshape(b, hkv, group, block_q, d)
        s = jnp.einsum("bkgqd,bkzsd->bkgqs", qf,
                       kg.astype(jnp.float32)) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        rows = i0 + jnp.arange(block_q)[:, None] + (skv - sq)
        cols = jnp.arange(skv)[None, :]
        mask = jnp.ones((block_q, skv), bool)
        if causal:
            mask = mask & (rows >= cols)
        if window > 0:
            mask = mask & (rows - cols < window)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p)
        o = jnp.einsum("bkgqs,bkzsd->bkgqd", p, vg.astype(jnp.float32))
        return o.reshape(b, hq, block_q, dv).astype(q.dtype)

    outs = jax.lax.map(lambda args: block(args[0], args[1]),
                       (qs, jnp.arange(nq) * block_q))
    o = outs.transpose(1, 2, 0, 3, 4).reshape(b, hq, nq * block_q, dv)
    return o[:, :, :sq]


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "impl", "block_q", "block_kv",
    "interpret"))
def attention(q, k, v, *, causal: bool = True, window: int = 0,
              softcap: float = 0.0, scale=None, impl: str = "auto",
              block_q: int = 128, block_kv: int = 128,
              interpret: bool = False):
    if impl == "auto":
        if jax.default_backend() == "tpu" and q.shape[2] == k.shape[2] \
                and q.shape[2] >= 128:
            impl = "pallas"
        elif k.shape[2] > 1024:
            impl = "jnp_blockwise"
        else:
            impl = "jnp"
    if impl == "jnp":
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale)
    if impl == "jnp_blockwise":
        return _blockwise_jnp(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale)
    if impl != "pallas":
        raise ValueError(impl)
    if q.shape[2] != k.shape[2]:
        raise ValueError("pallas path requires sq == skv (self-attention)")
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_kv=block_kv, interpret=interpret)
