"""Pure-jnp oracle for flash attention (materializes the score matrix)."""
import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0,
                  scale: float | None = None) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    rows = jnp.arange(sq)[:, None] + (skv - sq)   # align ends for decode
    cols = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = mask & (rows >= cols)
    if window > 0:
        mask = mask & (rows - cols < window)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
