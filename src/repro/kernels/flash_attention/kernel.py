"""Flash-attention Pallas TPU kernel (online softmax, causal/windowed/GQA).

Adaptation notes (GPU flash-attention → TPU):
  * no warp-level shuffles — the online-softmax running stats (m, l) live in
    VMEM scratch tiles shaped ``(block_q, 128)`` so reductions stay in the
    lane-aligned layout the VPU wants;
  * the KV sweep is the innermost grid dim, so the accumulator tile persists
    in VMEM across it (same accumulation idiom as the matmul kernel);
  * GQA is an *index-map* property: query head ``h`` reads KV head
    ``h // group`` — no gather, no replication in HBM;
  * supports causal masking, sliding windows (gemma2 local layers) and
    logit soft-capping (gemma2) so one kernel serves every assigned arch.

Causally-skippable KV blocks are masked rather than skipped; on TPU the
grid must be static, and for the prefill shapes we target the masked
fraction is amortized by the 128-wide lanes.  (A `pl.when` early-out still
avoids the two matmuls for fully-masked blocks.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  nkv: int, block_q: int, block_kv: int, scale: float,
                  causal: bool, window: int, softcap: float):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    kv_start = ikv * block_kv
    # block-level early-out for fully-masked (future) KV blocks
    needed = True
    if causal:
        needed = kv_start <= q_start + block_q - 1

    def body():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)           # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)           # (bkv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                  # (bq, bkv)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        rows = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        cols = kv_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = mask & (rows >= cols)
        if window > 0:
            mask = mask & (rows - cols < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                          # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)      # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # (bq, bkv)
        # fully-masked rows: keep p exactly zero (m_new == NEG_INF)
        p = jnp.where(m_new > NEG_INF / 2, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    if causal:
        pl.when(needed)(body)
    else:
        body()

    @pl.when(ikv == nkv - 1)
    def _flush():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                # all-masked rows → 0
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           softcap: float = 0.0, scale: float | None = None,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = False) -> jax.Array:
    """Attention over ``q (B,Hq,S,D)``, ``k/v (B,Hkv,S,D)``; GQA by ratio."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"GQA ratio must be integral: {hq} vs {hkv}")
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    if sq % block_q or skv % block_kv:
        raise ValueError("sequence must divide block size (pad in ops.py)")
    grid = (b, hq, sq // block_q, skv // block_kv)
    kernel = functools.partial(
        _flash_kernel, nkv=grid[3], block_q=block_q, block_kv=block_kv,
        scale=scale, causal=causal, window=window, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, h, i, j: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, h, i, j, g=group: (bb, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, h, i, j, g=group: (bb, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, h, i, j: (bb, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # running denom
            pltpu.VMEM((block_q, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
