"""Compiled-artifact analysis: memory, FLOPs, and collective bytes.

The dry-run proves a (arch × shape × mesh) cell compiles; this module
extracts the roofline terms from the compiled executable:

  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

``cost_analysis`` supplies per-device FLOPs/bytes (the SPMD module is
per-partition); collective bytes are NOT in cost_analysis, so we parse the
optimized HLO text and sum result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.  All three
terms are reported as *global* quantities (per-device × chips) so the
division by chips in the roofline formulas recovers per-device seconds.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional, Tuple

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "bf16[16,128,5120]{2,1,0} all-gather(" or "(f32[8,4]{...}, ...) all-to-all("
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Sum result-shape bytes of every collective op (per-partition)."""
    total = 0
    by_kind: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result shape(s) appear before "= <op>(" — find the op first
        m = re.search(r"=\s*\(?\s*(\w[\w-]*)\(", stripped)
        kind = None
        for c in _COLLECTIVES:
            if f" {c}(" in stripped or stripped.startswith(f"{c}("):
                # confirm it's the op, not a comment
                if re.search(rf"=\s*\(?[^=]*\b{c}\(", stripped) or \
                        re.search(rf"\)\s*{c}\(", stripped):
                    kind = c
                    break
        if kind is None:
            continue
        # sum every shape on the lhs of '='
        lhs = stripped.split(f"{kind}(")[0]
        b = sum(_shape_bytes(dt, dims)
                for dt, dims in _SHAPE_RE.findall(lhs))
        total += b
        by_kind[kind] = by_kind.get(kind, 0) + b
    return total, by_kind


@dataclasses.dataclass
class Roofline:
    chips: int
    flops_global: float
    hbm_bytes_global: float
    coll_bytes_global: float
    coll_by_kind: Dict[str, int]
    peak_bytes_per_chip: int
    model_flops: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_global / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_global / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_global / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time: dominant term (perfect overlap bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if not self.model_flops or not self.flops_global:
            return None
        return self.model_flops / self.flops_global

    @property
    def roofline_fraction(self) -> Optional[float]:
        """MODEL_FLOPS / (chips × peak × step_s) — MFU at the bound."""
        if not self.model_flops or self.step_s <= 0:
            return None
        return self.model_flops / (self.chips * PEAK_FLOPS * self.step_s)

    def to_dict(self) -> Dict:
        return {
            "chips": self.chips,
            "flops_global": self.flops_global,
            "hbm_bytes_global": self.hbm_bytes_global,
            "coll_bytes_global": self.coll_bytes_global,
            "coll_by_kind": self.coll_by_kind,
            "peak_bytes_per_chip": self.peak_bytes_per_chip,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s": self.step_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, chips: int, model_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll, by_kind = collective_bytes(text)
    mem = compiled.memory_analysis()
    peak = int(getattr(mem, "temp_size_in_bytes", 0)
               + getattr(mem, "argument_size_in_bytes", 0)
               + getattr(mem, "output_size_in_bytes", 0)
               - getattr(mem, "alias_size_in_bytes", 0))
    return Roofline(
        chips=chips,
        flops_global=flops * chips,
        hbm_bytes_global=hbm * chips,
        coll_bytes_global=coll * chips,
        coll_by_kind=by_kind,
        peak_bytes_per_chip=peak,
        model_flops=model_flops,
    )
