"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

THE first two lines below must run before any other import (jax locks the
device count on first init); only the dry-run fakes 512 devices.

For each cell this script:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. runs the TRA planner on the actual config/shape/mesh,
  3. lowers the step function against ShapeDtypeStruct stand-ins with the
     planner's in/out shardings (no allocation),
  4. ``.compile()``s — sharding mismatches, unsupported collectives and
     compile-time OOMs all surface here,
  5. records memory_analysis / cost_analysis / parsed collective bytes to
     ``experiments/dryrun/<cell>.json`` for EXPERIMENTS.md §Dry-run and
     the roofline table.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs N]
  python -m repro.launch.dryrun --tra-workloads    # §5 plans via Engine
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import math
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (get_config, get_shape, input_specs, list_archs,
                           SHAPES, supports_shape)
from repro.launch.analysis import analyze
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import (cache_spec, count_params, decode_step, init_params,
                          param_shapes, prefill)
from repro.optim import AdamWConfig
from repro.runtime import make_train_step
from repro.sharding import (batch_pspecs, cache_pspecs, logits_pspec,
                            make_sharder, param_pspecs, plan_arch,
                            zero1_pspecs)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·tokens (train), 2·N_active·tokens (infer)."""
    n = count_params(cfg, active_only=True)
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch          # decode: one token each


def _f32_like(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               mesh_shape: Optional[tuple] = None) -> Dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = supports_shape(cfg, shape)
    mesh_name = ("x".join(str(x) for x in mesh_shape) if mesh_shape
                 else ("2x16x16" if multi_pod else "16x16"))
    cell = f"{arch}×{shape_name}×{mesh_name}"
    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": mesh_name,
                 "kind": shape.kind}
    if not ok:
        rec["status"] = "skip"
        rec["reason"] = reason
        return rec

    if mesh_shape is not None:
        # §Perf mesh-refactor iterations: same 256 chips, different
        # (data × model) factorization
        mesh = make_mesh(mesh_shape, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.shape.values())
    plan = plan_arch(cfg, shape, mesh)
    sharder = make_sharder(mesh, plan.act_axis_map)
    rec["plan"] = plan.describe()

    params_sds = param_shapes(cfg)
    if shape.kind == "train":
        pspecs = param_pspecs(mesh, plan.param_axis_map, params_sds)
    else:
        # serving: no optimizer state to pay for, so weights also shard
        # over the data axes (FSDP-at-inference) and are gathered one
        # scanned layer at a time
        pspecs = zero1_pspecs(mesh, plan.param_axis_map, params_sds)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    batch_sds = input_specs(cfg, shape)
    microbatched = False
    if shape.kind == "train":
        # gradient accumulation: one sequence per data shard per
        # microbatch keeps live activations (with remat) ≈ one layer of
        # one sequence — the standard memory shape at this batch size
        dsize = plan.mesh.data_size
        accum = max(1, shape.global_batch // max(dsize, 1))
        if accum > 1:
            microbatched = True
            batch_sds = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (accum, s.shape[0] // accum) + s.shape[1:], s.dtype),
                batch_sds)
            rec["accum_steps"] = accum
    bspecs = batch_pspecs(mesh, plan.act_axis_map, batch_sds,
                          microbatched=microbatched)
    bsh = jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            from repro.optim import schedule as sched
            step = make_train_step(cfg, AdamWConfig(),
                                   lambda s: sched.constant(s), sharder)
            zspecs = zero1_pspecs(mesh, plan.param_axis_map, params_sds)
            zsh = jax.tree.map(lambda s: NamedSharding(mesh, s), zspecs)
            opt_sds = {"step": jax.ShapeDtypeStruct((), jnp.int32),
                       "master": _f32_like(params_sds),
                       "m": _f32_like(params_sds),
                       "v": _f32_like(params_sds)}
            opt_sh = {"step": NamedSharding(mesh, P()),
                      "master": zsh, "m": zsh, "v": zsh}
            fn = jax.jit(step, in_shardings=(opt_sh, bsh),
                         donate_argnums=(0,))
            lowered = fn.lower(opt_sds, batch_sds)
        elif shape.kind == "prefill":
            def pf(params, batch):
                return prefill(cfg, params, batch, shape.seq_len, sharder)

            cache_sds = cache_spec(cfg, shape.global_batch, shape.seq_len)
            cspecs = cache_pspecs(mesh, plan.act_axis_map, cfg, cache_sds)
            csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs)
            lsh = NamedSharding(mesh, logits_pspec(mesh,
                                                   plan.act_axis_map))
            fn = jax.jit(pf, in_shardings=(psh, bsh),
                         out_shardings=(lsh, csh))
            lowered = fn.lower(params_sds, batch_sds)
        else:  # decode
            def dec(params, cache, batch):
                return decode_step(cfg, params, cache, batch, sharder)

            cache_sds = cache_spec(cfg, shape.global_batch, shape.seq_len)
            cspecs = cache_pspecs(mesh, plan.act_axis_map, cfg, cache_sds)
            csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs)
            lsh = NamedSharding(mesh, logits_pspec(mesh,
                                                   plan.act_axis_map))
            fn = jax.jit(dec, in_shardings=(psh, csh, bsh),
                         out_shardings=(lsh, csh), donate_argnums=(1,))
            lowered = fn.lower(params_sds, cache_sds, batch_sds)

        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_gib": getattr(mem, "argument_size_in_bytes", 0) / 2**30,
        "output_gib": getattr(mem, "output_size_in_bytes", 0) / 2**30,
        "temp_gib": getattr(mem, "temp_size_in_bytes", 0) / 2**30,
        "alias_gib": getattr(mem, "alias_size_in_bytes", 0) / 2**30,
    }
    # raw XLA numbers (per-while-iteration — see metering.py docstring)
    roof = analyze(compiled, chips, model_flops(cfg, shape))
    rec["xla_raw"] = roof.to_dict()
    # structural (loop-corrected) roofline — the table §Roofline uses this
    from repro.launch.metering import meter, roofline_terms
    mt = meter(cfg, shape, plan)
    terms = roofline_terms(mt, chips)
    mf = model_flops(cfg, shape)
    terms["model_flops"] = mf
    terms["useful_flops_ratio"] = mf / mt.flops if mt.flops else None
    terms["roofline_fraction"] = (
        mf / (chips * 197e12 * terms["step_s"])
        if terms["step_s"] > 0 else None)
    terms["flops_global"] = mt.flops
    terms["hbm_bytes_global"] = mt.hbm_bytes
    terms["coll_bytes_global"] = mt.coll_bytes
    terms["detail"] = {k: round(v, 3) for k, v in sorted(
        mt.detail.items(), key=lambda kv: -kv[1])}
    rec["roofline"] = terms
    rec["status"] = "ok"
    rec["params"] = count_params(cfg)
    rec["active_params"] = count_params(cfg, active_only=True)
    frac = terms.get("roofline_fraction")
    print(f"[dryrun] {cell}: OK "
          f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s, "
          f"dominant={terms['dominant']}, "
          f"frac={frac if frac is None else round(frac, 4)})", flush=True)
    return rec


def lower_tra_workloads(n_sites: int = 256) -> Dict:
    """Lower + compile the §5 TRA workloads through the unified Engine on
    a production-scale 1-D sites mesh — the plan-level analogue of the
    model cells: optimizer output, GSPMD lowering, collective emission and
    compile-time memory all surface here without allocating inputs.
    """
    from repro.core import Engine, Placement
    from repro.core.programs import ffnn_step_tra, matmul_tra

    mesh = make_mesh((n_sites,), ("sites",))
    S = ("sites",)
    workloads = {
        "matmul-cpmm": (
            matmul_tra((n_sites, n_sites), (n_sites, n_sites), (8, 8),
                       (8, 8)),
            {"A": Placement.partitioned((1,), S),
             "B": Placement.partitioned((0,), S)}),
        # TRA-DP at pod scale: batch blocks sharded, weights replicated
        # (the weight grids don't divide a pod-sized axis)
        "ffnn-w1-update": (
            ffnn_step_tra(n_sites, 4, 4, 4, 8, 8, 8, 8).w1_new,
            {"X": Placement.partitioned((0,), S),
             "Y": Placement.partitioned((0,), S),
             "W1": Placement.replicated(),
             "W2": Placement.replicated()}),
    }
    out: Dict = {"mesh": f"{n_sites}x1(sites)"}
    for name, (expr, places) in workloads.items():
        rec: Dict = {}
        try:
            eng = Engine(mesh, executor="gspmd", input_placements=places)
            t0 = time.time()
            compiled = eng.compile(expr)
            rec["optimize_s"] = round(time.time() - t0, 1)
            rec["cost_floats"] = compiled.cost
            rec["plan"] = compiled.describe()
            # launch gate: the per-site programs this launcher would hand
            # out must agree on their collective schedules (a divergence
            # hangs or mis-sums at run time) — strict, so a bad plan
            # fails here, before any site executes
            from repro.launch.sites import verify_site_programs
            verify_site_programs([compiled.plan] * min(n_sites, 8),
                                 {"sites": n_sites})
            rec["site_schedule_verified"] = True
            sds = [jax.ShapeDtypeStruct(
                tuple(compiled.input_rtypes[n].key_shape)
                + tuple(compiled.input_rtypes[n].bound), jnp.float32)
                for n in compiled.input_names]
            t1 = time.time()
            with mesh:
                xc = compiled.jitted.lower(*sds).compile()
            rec["compile_s"] = round(time.time() - t1, 1)
            mem = xc.memory_analysis()
            rec["temp_gib"] = getattr(mem, "temp_size_in_bytes", 0) / 2**30
            rec["status"] = "ok"
            print(f"[dryrun] tra:{name}: OK (cost {rec['cost_floats']:,}, "
                  f"compile {rec['compile_s']}s)", flush=True)
        except Exception as e:  # noqa: BLE001 — record and continue
            rec["status"] = "error"
            rec["error"] = repr(e)
            rec["traceback"] = traceback.format_exc()
            print(f"[dryrun] tra:{name}: FAIL {e!r}", flush=True)
        out[name] = rec
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tra-workloads", action="store_true")
    ap.add_argument("--tra-sites", type=int, default=256)
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    if args.tra_workloads:
        rec = lower_tra_workloads(args.tra_sites)
        with open(os.path.join(args.out, "tra_workloads.json"), "w") as f:
            json.dump(rec, f, indent=1)
        return 1 if any(isinstance(v, dict) and v.get("status") == "error"
                        for v in rec.values()) else 0

    cells = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                cells.append((a, s, args.multi_pod))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
        path = os.path.join(args.out, tag + ".json")
        try:
            rec = lower_cell(arch, shape, mp)
        except Exception as e:  # noqa: BLE001 — record and continue
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()}
            failures += 1
            print(f"[dryrun] {tag}: FAIL {e!r}", flush=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
