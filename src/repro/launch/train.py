"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --steps 50 --batch 8 --seq 128 [--mesh 4x2]

On real hardware the same entry point runs the full config on the
production mesh; in this container ``--smoke`` selects the reduced config
and a host-device mesh.  All the production machinery is exercised either
way: TRA planning, sharded params/optimizer (ZeRO-1), async atomic
checkpointing, restart, straggler monitoring.
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="DxM host mesh, e.g. 4x2 (needs fake devices)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={d * m} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax

    from repro.configs import get_config
    from repro.data import DataConfig
    from repro.optim import AdamWConfig
    from repro.runtime import Trainer, TrainerConfig

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(d, m)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch,
                      input_mode=cfg.input_mode, d_model=cfg.d_model)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir,
                         adamw=AdamWConfig(lr=args.lr))
    tr = Trainer(cfg, dcfg, tcfg, mesh=mesh)
    if args.resume:
        tr.init_or_restore()
    hist = tr.train()
    first = hist[0]["loss"] if hist else float("nan")
    last = hist[-1]["loss"] if hist else float("nan")
    print(f"[train] {args.arch}: {len(hist)} steps, "
          f"loss {first:.4f} → {last:.4f}")
    if tr.monitor.flagged:
        print(f"[train] stragglers flagged: {tr.monitor.flagged}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
