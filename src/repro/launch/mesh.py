"""Production meshes.

Functions, not module-level constants, so importing this module never
touches jax device state.  The single-pod production mesh is 16×16 = 256
chips (one TPU v5e pod); the multi-pod mesh stacks a leading "pod" axis:
2 × 16 × 16 = 512 chips.  The planner folds ("pod", "data") into one
logical data-parallel axis; "model" carries TP/EP/vocab sharding.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,)
                         * len(axes))


def make_host_mesh(data: int = 4, model: int = 2):
    """Small mesh over host devices (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
