"""Production meshes.

Functions, not module-level constants, so importing this module never
touches jax device state.  The single-pod production mesh is 16×16 = 256
chips (one TPU v5e pod); the multi-pod mesh stacks a leading "pod" axis:
2 × 16 × 16 = 512 chips.  The planner folds ("pod", "data") into one
logical data-parallel axis; "model" carries TP/EP/vocab sharding.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis types across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist on newer jax;
    older releases are implicitly Auto, so simply omit the argument there.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_abstract_mesh(shape, axes):
    """`jax.sharding.AbstractMesh` across jax versions.

    Newer jax takes ``(axis_shapes, axis_names)``; 0.4.x takes a single
    tuple of ``(name, size)`` pairs.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2):
    """Small mesh over host devices (tests / examples)."""
    return make_mesh((data, model), ("data", "model"))
