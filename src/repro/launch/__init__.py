"""Launchers: mesh construction, multi-pod dry-run, train/serve CLIs.

NOTE: do not import ``repro.launch.dryrun`` from library code — importing
it sets XLA_FLAGS to fake 512 host devices (dry-run only, by design).
"""
from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
