"""Multi-host launcher gate: verify per-site programs before execution.

The static verifier's collectives pass self-checks SPMD plans (one
program, every site runs it by construction).  A multi-host *launcher*
is the place where that assumption can actually break: it hands each
site a physical program, and nothing forces externally supplied per-site
plans — hand-edited, planner-v2 candidates, or programs deserialized
from different optimizer versions — to agree on their collective
schedules.  A disagreement is the worst failure class in the paper's
distributed story: a site with an extra collective blocks forever (hang)
and a mismatched reducer/axis silently computes wrong sums.

:func:`verify_site_programs` is the launch-time gate (the PR 9 ROADMAP
follow-up): it derives each site's ordered collective schedule with
:func:`repro.analysis.collectives.collective_schedule` — the same
lowering the shard_map executor performs — and aligns them with
:func:`repro.analysis.collectives.check_site_schedules`, raising
:class:`~repro.analysis.diagnostics.PlanVerificationError` before any
site starts executing.  ``repro.launch.dryrun --tra-workloads`` routes
its compiled plans through this gate, modelling a launcher verifying the
programs it is about to distribute.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.analysis.collectives import (check_site_schedules,
                                        collective_schedule)
from repro.analysis.diagnostics import Diagnostics

PASS = "site-programs"


def site_collective_schedules(site_roots: Sequence,
                              axis_sizes: Dict[str, int],
                              diags: Optional[Diagnostics] = None):
    """Per-site ordered collective schedules for a list of physical
    plan roots (one per site).  Lowering problems (unknown axes, bad
    reducers) are reported into ``diags``; a site whose plan cannot be
    lowered at all contributes an empty schedule plus an error."""
    from repro.core.guards import label_nodes
    if diags is None:
        diags = Diagnostics()
    schedules = []
    for site, root in enumerate(site_roots):
        try:
            labels = label_nodes((root,))
            schedules.append(collective_schedule(root, axis_sizes,
                                                 labels=labels,
                                                 diags=diags))
        except (ValueError, TypeError) as exc:
            diags.add(PASS, "error",
                      f"site {site}: collective lowering failed: {exc}",
                      node=root)
            schedules.append([])
    return schedules


def verify_site_programs(site_roots: Sequence,
                         axis_sizes: Dict[str, int], *,
                         strict: bool = True) -> Diagnostics:
    """Verify externally supplied per-site programs agree on collectives.

    ``site_roots[i]`` is the physical plan (:class:`repro.core.plan.
    IANode`, e.g. ``CompiledExpr.plan``) site *i* would execute;
    ``axis_sizes`` is the launch mesh's axis table.  Derives each site's
    collective schedule and checks the cross-site alignment invariant —
    identical ordered sequences with matching kind/axis/reducer.  With
    ``strict`` (the default: this is a pre-launch gate, not a linter)
    any error raises :class:`~repro.analysis.diagnostics.
    PlanVerificationError`; otherwise the diagnostics are returned for
    the caller to render.
    """
    diags = Diagnostics()
    schedules = site_collective_schedules(site_roots, axis_sizes,
                                          diags=diags)
    check_site_schedules(schedules, diags=diags)
    if strict:
        diags.raise_if_errors()
    return diags
