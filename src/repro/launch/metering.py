"""Structural roofline metering: exact-by-construction FLOPs / HBM bytes /
collective bytes for a (config × shape × plan) cell.

Why this exists: ``compiled.cost_analysis()`` does NOT multiply while-loop
bodies by their trip counts (verified empirically: a 10-step scanned
matmul reports 1.000000× the flops of a single matmul), and our models
scan over layers / microbatches / attention blocks — so the XLA numbers
undercount by the product of loop trip counts.  The dry-run records both:
the raw XLA numbers (labeled per-iteration) and these structural numbers,
which enumerate every matmul/attention/scan in the model analytically.
The same formulas are the napkin-math engine for the §Perf hypothesis
loop.

Conventions: FLOPs count multiply+add (2·M·N·K per matmul).  Backward =
2× forward matmul flops; the "full" remat policy recomputes the forward
(+1×); "dots_saveable" recomputes only cheap elementwise ops (+~5%).
HBM bytes: every weight is read once per microbatch per pass (fwd, bwd-
dX, bwd-dW → 3×); activations are written+read once per layer boundary;
optimizer reads+writes master/m/v.  Collective bytes follow the TRA
plan: ring-collective wire volume  ≈ payload × (axis−1)/axis per hop
direction (reduce-scatter and all-gather each move ≈ payload; all-reduce
= RS + AG = 2× payload).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.analysis import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.sharding.planner import ArchPlan, PairDecision


@dataclasses.dataclass
class Meter:
    flops: float = 0.0          # global FLOPs per step
    hbm_bytes: float = 0.0      # global HBM traffic per step
    coll_bytes: float = 0.0     # global wire bytes per step
    detail: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, key: str, *, flops: float = 0.0, hbm: float = 0.0,
            coll: float = 0.0) -> None:
        self.flops += flops
        self.hbm_bytes += hbm
        self.coll_bytes += coll
        if flops:
            self.detail[f"flops/{key}"] = \
                self.detail.get(f"flops/{key}", 0.0) + flops
        if coll:
            self.detail[f"coll/{key}"] = \
                self.detail.get(f"coll/{key}", 0.0) + coll


def _ring(payload_bytes: float, axis: int) -> float:
    """Wire bytes of one reduce-scatter or all-gather over ``axis``."""
    if axis <= 1:
        return 0.0
    return payload_bytes * (axis - 1)


BP = 2  # bf16 weight/activation bytes


def _layer_weight_bytes(cfg: ModelConfig) -> Dict[str, float]:
    d = cfg.d_model
    out = {}
    if cfg.has_attention:
        if cfg.use_mla:
            w = d * cfg.q_dim + d * (cfg.kv_lora_rank + cfg.qk_rope_dim) \
                + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim
                                                    + cfg.v_head_dim) \
                + cfg.n_heads * cfg.v_head_dim * d
        else:
            hd = cfg.head_dim
            w = d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2
        out["attn"] = w * BP
    if cfg.d_ff and cfg.family != "moe":
        out["mlp"] = 3 * d * cfg.d_ff * BP
    if cfg.n_experts:
        out["moe"] = (3 * cfg.n_experts * d * cfg.d_ff_expert
                      + 3 * cfg.n_shared_experts * d * cfg.d_ff_expert
                      + d * cfg.n_experts) * BP
    if cfg.ssm_state:
        di = cfg.d_inner
        out["ssm"] = (d * (2 * di + 2 * cfg.ssm_ngroups * cfg.ssm_state
                           + cfg.ssm_heads) + di * d) * BP
    return out


def _attn_flops(cfg: ModelConfig, t: int, kv_len: int, window: int,
                causal_square: bool = True) -> float:
    """Projections + scores + PV for t query tokens against kv_len keys."""
    d = cfg.d_model
    if cfg.use_mla:
        qd, r = cfg.q_dim, cfg.kv_lora_rank
        proj = 2 * t * d * qd + 2 * t * d * (r + cfg.qk_rope_dim) \
            + 2 * t * r * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim) \
            + 2 * t * cfg.n_heads * cfg.v_head_dim * d
        per_head = cfg.qk_nope_dim + cfg.qk_rope_dim + cfg.v_head_dim
        attn = 2 * t * kv_len * cfg.n_heads * per_head
    else:
        hd = cfg.head_dim
        proj = 2 * t * d * (cfg.n_heads * hd * 2
                            + cfg.n_kv_heads * hd * 2)
        attn = 2 * t * kv_len * cfg.n_heads * hd * 2
    if window and window < kv_len:
        attn *= window / kv_len
    elif causal_square:
        attn *= 0.5          # causal: half the square
    return proj + attn


def _mamba_flops(cfg: ModelConfig, t: int) -> float:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h, p = (cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads,
                  cfg.ssm_head_dim)
    proj = 2 * t * d * (2 * di + 2 * g * n + h) + 2 * t * di * d
    conv = 2 * t * (di + 2 * g * n) * cfg.ssm_conv_width
    L = min(cfg.ssm_chunk, t)
    # intra-chunk: scores 2L²n + masked-mix 2L²hp ; states/inter: ≈4Lnhp
    per_chunk = 2 * L * L * n + 2 * L * L * h * p + 4 * L * n * h * p
    ssd = per_chunk * max(t // L, 1)
    return proj + conv + ssd


def _mlp_flops(d: int, ff: int, t: int) -> float:
    return 3 * 2 * t * d * ff


def _moe_flops(cfg: ModelConfig, t: int) -> float:
    routed = t * cfg.top_k * cfg.moe_capacity_factor
    f = _mlp_flops(cfg.d_model, cfg.d_ff_expert, int(routed))
    f += 2 * t * cfg.d_model * cfg.n_experts          # router
    if cfg.n_shared_experts:
        f += _mlp_flops(cfg.d_model,
                        cfg.d_ff_expert * cfg.n_shared_experts, t)
    return f


def _strategy(plan: ArchPlan, comp: str) -> str:
    dec = plan.decisions.get(comp)
    if isinstance(dec, PairDecision):
        return dec.strategy
    if isinstance(dec, str) and dec.startswith("ep"):
        return "ep"
    if isinstance(dec, str) and dec.startswith("tp"):
        return "tp"
    return "dp"


def meter(cfg: ModelConfig, shape: ShapeSpec, plan: ArchPlan) -> Meter:
    m = Meter()
    sd, sm = plan.mesh.data_size, plan.mesh.model_size
    train = shape.kind == "train"
    decode = shape.kind == "decode"
    S = shape.seq_len
    t_tokens = shape.global_batch * (1 if decode else S)
    kv_len = S
    accum = max(1, shape.global_batch // max(sd, 1)) if train else 1
    # pass multiplier: fwd=1; train adds bwd (2×) and remat recompute
    if train:
        pass_mult = {"none": 3.0, "dots_saveable": 3.15,
                     "full": 4.0}[cfg.remat]
    else:
        pass_mult = 1.0

    d, V = cfg.d_model, cfg.vocab_size
    wbytes = _layer_weight_bytes(cfg)
    n_attn_layers = 0
    n_mamba_layers = 0
    n_moe_layers = 0
    n_mlp_layers = 0
    if cfg.family in ("dense", "audio", "vlm"):
        n_attn_layers = cfg.n_layers
        n_mlp_layers = cfg.n_layers
    elif cfg.family == "moe":
        n_attn_layers = cfg.n_layers
        n_moe_layers = cfg.n_layers - cfg.first_dense_layers
        n_mlp_layers = cfg.first_dense_layers
    elif cfg.family == "ssm":
        n_mamba_layers = cfg.n_layers
    elif cfg.family == "hybrid":
        n_mamba_layers = cfg.n_layers
        n_attn_layers = cfg.n_layers // cfg.mamba_per_group
        n_mlp_layers = n_attn_layers

    # ---------------- FLOPs ----------------
    csq = not decode
    if n_attn_layers:
        if cfg.local_global_period:
            loc = n_attn_layers // 2
            glob = n_attn_layers - loc
            f = loc * _attn_flops(cfg, t_tokens, kv_len, cfg.attn_window,
                                  csq) \
                + glob * _attn_flops(cfg, t_tokens, kv_len, 0, csq)
        else:
            f = n_attn_layers * _attn_flops(cfg, t_tokens, kv_len,
                                            cfg.attn_window, csq)
        m.add("attn", flops=f * pass_mult)
    if n_mlp_layers:
        ff = cfg.d_ff or 4 * d
        m.add("mlp", flops=n_mlp_layers * _mlp_flops(d, ff, t_tokens)
              * pass_mult)
    if n_moe_layers:
        m.add("moe", flops=n_moe_layers * _moe_flops(cfg, t_tokens)
              * pass_mult)
    if n_mamba_layers:
        if decode:
            di = cfg.d_inner
            per_tok = (2 * d * (2 * di + 2 * cfg.ssm_ngroups
                                * cfg.ssm_state + cfg.ssm_heads)
                       + 2 * di * d
                       + 4 * cfg.ssm_state * cfg.ssm_heads
                       * cfg.ssm_head_dim)
            f = n_mamba_layers * per_tok * shape.global_batch
        else:
            f = n_mamba_layers * _mamba_flops(cfg, S) * shape.global_batch
        m.add("ssm", flops=f * pass_mult)
    m.add("head", flops=2 * t_tokens * d * V * pass_mult)
    if train:
        from repro.models.model import count_params
        m.add("optimizer", flops=12.0 * count_params(cfg))

    # ---------------- HBM bytes ----------------
    layer_w = 0.0
    if n_attn_layers:
        layer_w += n_attn_layers * wbytes.get("attn", 0)
    if n_mlp_layers:
        layer_w += n_mlp_layers * wbytes.get("mlp", 0)
    if n_moe_layers:
        layer_w += n_moe_layers * wbytes.get("moe", 0)
    if n_mamba_layers:
        layer_w += n_mamba_layers * wbytes.get("ssm", 0)
    head_w = d * V * BP * (1 if cfg.tie_embeddings else 2)
    total_w = layer_w + head_w
    w_reads = (3 if train else 1) * accum
    m.add("weights", hbm=total_w * w_reads)
    n_layers_total = (n_attn_layers + n_mamba_layers + n_moe_layers
                      + n_mlp_layers)
    act_bytes = t_tokens * d * BP * n_layers_total * (4 if train else 2)
    m.add("activations", hbm=act_bytes)
    if decode:
        # the KV cache / SSM state is read every step — decode's wall
        cache_bp = 1 if "float8" in (cfg.kv_cache_dtype or "") else BP
        cache = 0.0
        if n_attn_layers and not cfg.use_mla:
            cache = (n_attn_layers * 2 * cfg.n_kv_heads * cfg.head_dim
                     * kv_len * shape.global_batch * cache_bp)
        elif cfg.use_mla:
            cache = (n_attn_layers * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                     * kv_len * shape.global_batch * cache_bp)
        if n_mamba_layers:
            cache += (n_mamba_layers * cfg.ssm_heads * cfg.ssm_state
                      * cfg.ssm_head_dim * 4 * shape.global_batch)
        m.add("kv-cache", hbm=cache)
    if train:
        from repro.models.model import count_params
        m.add("optimizer", hbm=count_params(cfg) * 4 * 6)  # rd+wr m/v/w f32

    # ---------------- collective bytes ----------------
    if train and sd > 1:
        # gradient sync over the data axes (RS) + ZeRO-1 param AG
        m.add("grad-sync", coll=2 * _ring(total_w, sd))
    comp_of = {"attn": ("attn", wbytes.get("attn", 0) * n_attn_layers),
               "mlp": ("mlp", wbytes.get("mlp", 0) * n_mlp_layers),
               "ssm": ("ssm", wbytes.get("ssm", 0) * n_mamba_layers),
               "moe": ("moe", wbytes.get("moe", 0) * n_moe_layers)}
    for comp, (key, wb) in comp_of.items():
        if not wb:
            continue
        strat = _strategy(plan, comp)
        nl = {"attn": n_attn_layers, "mlp": n_mlp_layers,
              "ssm": n_mamba_layers, "moe": n_moe_layers}[key]
        if strat == "fsdp" and sm > 1:
            # weights gathered over the model axis per pass (fwd+bwd)
            passes = (2 if train else 1) * accum
            m.add(f"{key}-fsdp-gather", coll=_ring(wb, sm) * passes)
        elif strat == "tp" and sm > 1:
            # Megatron: RS+AG of the activations per layer per pass
            passes = 2 if train else 1
            payload = t_tokens * d * BP
            m.add(f"{key}-tp-rs-ag",
                  coll=2 * _ring(payload, sm) * nl * passes)
        elif strat == "ep" and sm > 1:
            routed = t_tokens * cfg.top_k * cfg.moe_capacity_factor
            payload = routed * d * BP
            passes = 2 if train else 1
            m.add("moe-ep-a2a", coll=2 * payload * passes * nl)
    if plan.act_axis_map.get("vocab") and sm > 1:
        # vocab-sharded logits: logsumexp partial + dlogits path ≈ t×d
        m.add("vocab", coll=_ring(t_tokens * d * BP,
                                  sm) * (2 if train else 1))
    return m


def roofline_terms(meter_: Meter, chips: int) -> Dict[str, float]:
    c = meter_.flops / (chips * PEAK_FLOPS)
    h = meter_.hbm_bytes / (chips * HBM_BW)
    k = meter_.coll_bytes / (chips * ICI_BW)
    dom = max((c, "compute"), (h, "memory"), (k, "collective"))[1]
    return {"compute_s": c, "memory_s": h, "collective_s": k,
            "dominant": dom, "step_s": max(c, h, k)}


# ==========================================================================
# Request-span metering (repro.serve): admission → completion wall clock
# ==========================================================================
#
# The structural meters above price a (config × shape × plan) cell; a
# serving benchmark needs the *other* kind of meter — measured per-request
# spans, split into queue wait (submit → first scheduled step) and service
# (first step → completion), so BENCH_serve.json can report latency
# percentiles instead of one whole-process wall clock that hides queueing.

@dataclasses.dataclass
class RequestSpan:
    """One request's lifecycle timestamps (``time.perf_counter`` seconds).

    ``t_submit`` is stamped at queue admission, ``t_start`` when the
    scheduler first packs the request into a batch (or allocates its
    decode slot), ``t_complete`` when the result is handed back.
    ``tokens`` counts produced output units (generated tokens for decode
    servables, scored rows for stateless ones); ``artifacts`` records the
    compile-cache ``artifact_id`` of every program dispatch that served
    this request.  ``outcome`` is the request's fate — ``"ok"`` or one of
    the resilience outcomes (``shed`` / ``cancelled`` / ``deadline`` /
    ``failed``); shed spans complete without ever starting, so their
    ``t_start`` stays ``None``.
    """

    rid: int
    kind: str = "request"
    t_submit: float = 0.0
    t_start: Optional[float] = None
    t_complete: Optional[float] = None
    tokens: int = 0
    artifacts: list = dataclasses.field(default_factory=list)
    outcome: str = "ok"

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.t_start is None:
            return None
        return self.t_start - self.t_submit

    @property
    def service_s(self) -> Optional[float]:
        if self.t_start is None or self.t_complete is None:
            return None
        return self.t_complete - self.t_start

    @property
    def total_s(self) -> Optional[float]:
        if self.t_complete is None:
            return None
        return self.t_complete - self.t_submit


def percentiles(values: Sequence[float],
                qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
    """``{"p50": ..., "p95": ..., "p99": ...}`` by linear interpolation."""
    out: Dict[str, float] = {}
    xs = sorted(float(v) for v in values)
    for q in qs:
        name = f"p{int(q) if float(q).is_integer() else q}"
        if not xs:
            out[name] = float("nan")
            continue
        pos = (len(xs) - 1) * (q / 100.0)
        lo, hi = int(math.floor(pos)), int(math.ceil(pos))
        out[name] = xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)
    return out


@dataclasses.dataclass
class StreamStats:
    """Per-plan out-of-core streaming counters (``repro.store``).

    One instance lives on each streamed compile-cache artifact
    (``Engine.cache_info()`` surfaces it) and accumulates across ``run``
    calls of that artifact.  ``copy_s`` is total wall spent issuing
    host→device chunk transfers; ``hidden_copy_s`` is the portion issued
    while the previous chunk's compute was already dispatched — the
    double-buffered prefetches — so ``overlap_efficiency`` → (n−1)/n for
    an n-chunk stream when transfers are uniform.  ``peak_device_bytes``
    is the analytic live set (resident operands + current chunk +
    prefetched chunk + output side), the quantity the memory-budget
    planner bounds.  Spill counters are deltas of the backing
    :class:`repro.store.RelationStore`'s disk tier over this plan's runs.
    """

    mode: str = "resident"          # resident | stream-out | stream-reduce
    budget_bytes: Optional[int] = None
    runs: int = 0
    chunks: int = 0
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    copy_s: float = 0.0
    hidden_copy_s: float = 0.0
    compute_s: float = 0.0
    spill_events: int = 0
    spill_bytes: int = 0
    peak_device_bytes: int = 0

    @property
    def overlap_efficiency(self) -> float:
        """Fraction of transfer wall hidden behind in-flight compute."""
        if self.copy_s <= 0.0:
            return 1.0
        return min(1.0, self.hidden_copy_s / self.copy_s)

    def as_dict(self) -> Dict[str, object]:
        return {
            "mode": self.mode,
            "budget_bytes": self.budget_bytes,
            "runs": self.runs,
            "chunks": self.chunks,
            "h2d_bytes": self.h2d_bytes,
            "d2h_bytes": self.d2h_bytes,
            "copy_s": round(self.copy_s, 6),
            "hidden_copy_s": round(self.hidden_copy_s, 6),
            "compute_s": round(self.compute_s, 6),
            "overlap_efficiency": round(self.overlap_efficiency, 4),
            "spill_events": self.spill_events,
            "spill_bytes": self.spill_bytes,
            "peak_device_bytes": self.peak_device_bytes,
        }


class SpanMeter:
    """Collects :class:`RequestSpan`\\ s and summarizes them.

    The serving layer owns exactly one meter per server; spans are opened
    at ``submit`` time and closed by the scheduler, so queue wait and
    compute are metered per request instead of folded into one
    whole-process wall clock.
    """

    def __init__(self, clock=None) -> None:
        import time
        self._clock = clock or time.perf_counter
        self.spans: list = []
        self._next_rid = 0

    def now(self) -> float:
        return self._clock()

    def open(self, kind: str = "request") -> RequestSpan:
        span = RequestSpan(rid=self._next_rid, kind=kind,
                           t_submit=self.now())
        self._next_rid += 1
        self.spans.append(span)
        return span

    def start(self, span: RequestSpan) -> None:
        if span.t_start is None:
            span.t_start = self.now()

    def complete(self, span: RequestSpan, tokens: int = 0) -> None:
        span.t_complete = self.now()
        span.tokens += tokens

    # -- reporting --------------------------------------------------------
    def completed(self) -> list:
        return [s for s in self.spans if s.t_complete is not None]

    def summary(self) -> Dict[str, object]:
        """Percentile latencies (ms) + aggregate throughput (tokens/s).

        Latency percentiles cover the spans that were actually
        *scheduled* (``t_start`` set) — shed requests fail before ever
        starting, so folding them in would deflate queue-wait and
        service numbers; they are tallied in ``outcomes`` instead.
        """
        done = self.completed()
        if not done:
            return {"requests": 0}
        served = [s for s in done if s.t_start is not None]
        outcomes: Dict[str, int] = {}
        for s in done:
            outcomes[s.outcome] = outcomes.get(s.outcome, 0) + 1
        t0 = min(s.t_submit for s in done)
        t1 = max(s.t_complete for s in done)
        window = max(t1 - t0, 1e-9)
        tokens = sum(s.tokens for s in done)
        ms = 1e3
        return {
            "requests": len(done),
            "tokens": tokens,
            "window_s": round(window, 6),
            "tokens_per_s": round(tokens / window, 3),
            "outcomes": outcomes,
            "total_ms": {k: round(v * ms, 3) for k, v in percentiles(
                [s.total_s for s in served]).items()},
            "queue_wait_ms": {k: round(v * ms, 3) for k, v in percentiles(
                [s.queue_wait_s for s in served]).items()},
            "service_ms": {k: round(v * ms, 3) for k, v in percentiles(
                [s.service_s for s in served]).items()},
        }
