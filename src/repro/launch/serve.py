"""Serving launcher: TraServer with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --servable lm \
        --arch gemma2-2b --smoke --requests 40 --mode poisson --rate 50

Serves either the §5.3 FFNN scorer (``--servable scorer``) or the smoke
step-decode LM (``--servable lm``) through
:class:`~repro.serve.server.TraServer`: requests from the load generator
are continuously batched into long-lived compiled relational plans
(zero compile-cache misses after warmup), and the run prints tokens/s
with p50/p95/p99 of total / queue-wait / service latency.

``--dense-oracle`` keeps the previous launcher behaviour — the dense
transformer prefill + KV-cache decode loop over ``repro.models`` — as a
comparison path (with ``--mesh`` it shards cache and weights per the TRA
plan; decode forces KV sharding — see planner).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _dense_oracle(args) -> int:
    """Dense transformer prefill + decode loop (pre-TraServer launcher)."""
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={d * m} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import decode_step, init_params, prefill

    cfg = get_config(args.arch, smoke=args.smoke)
    cache_len = args.prompt_len + args.gen
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)

    sharder = None
    if args.mesh:
        from repro.configs.base import ShapeSpec
        from repro.launch.mesh import make_host_mesh
        from repro.sharding import make_sharder, plan_arch
        mesh = make_host_mesh(d, m)
        shape = ShapeSpec("serve", cache_len, args.batch, "decode")
        plan = plan_arch(cfg, shape, mesh)
        sharder = make_sharder(mesh, plan.act_axis_map)
    else:
        from repro.models.layers import no_shard
        sharder = no_shard

    B, S = args.batch, args.prompt_len
    if cfg.input_mode == "tokens":
        prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": prompts}
    else:
        batch = {"embeds": jax.random.normal(
            key, (B, S, cfg.d_model), jnp.bfloat16)}

    t0 = time.perf_counter()
    pf = jax.jit(lambda p, b: prefill(cfg, p, b, cache_len, sharder))
    logits, cache = pf(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    step = jax.jit(lambda p, c, b: decode_step(cfg, p, c, b, sharder),
                   donate_argnums=(1,))
    out_tokens = []
    tok = logits.argmax(-1).astype(jnp.int32)
    t1 = time.perf_counter()
    for _ in range(args.gen):
        if cfg.input_mode == "tokens":
            step_in = {"token": tok}
        else:
            emb = jax.random.normal(key, (B, 1, cfg.d_model), jnp.bfloat16)
            step_in = {"embed": emb}
        logits, cache = step(params, cache, step_in)
        tok = logits.argmax(-1).astype(jnp.int32)
        out_tokens.append(jax.device_get(tok)[:, 0])
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t1

    toks_s = B * args.gen / t_decode
    print(f"[serve] {args.arch}: prefill({B}x{S}) {t_prefill * 1e3:.1f} ms, "
          f"decode {args.gen} steps @ {toks_s:.1f} tok/s")
    print(f"[serve] sample continuation (seq 0): "
          f"{[int(t[0]) for t in out_tokens]}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--servable", choices=("lm", "scorer"), default="lm")
    ap.add_argument("--arch", default="gemma2-2b",
                    help="model config sizing the LM servable / dense path")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--executor", default="jit",
                    help="TRA engine executor (reference | jit | ...)")
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--capacity", type=int, default=8,
                    help="decode slots (lm servable)")
    ap.add_argument("--mode", choices=("poisson", "closed"),
                    default="poisson")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="poisson arrival rate, requests/s")
    ap.add_argument("--concurrency", type=int, default=8,
                    help="closed-loop outstanding requests")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-pending", type=int, default=None,
                    help="admission bound: shed submissions over this "
                         "many pending requests (ServerOverloaded)")
    ap.add_argument("--max-queue-wait", type=float, default=None,
                    help="shed requests queued longer than this (s)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline (s), scheduler-enforced")
    ap.add_argument("--retries", type=int, default=3,
                    help="per-request transient-fault retry budget")
    ap.add_argument("--json", action="store_true",
                    help="emit the full report as JSON")
    ap.add_argument("--dense-oracle", action="store_true",
                    help="run the dense transformer prefill/decode loop "
                         "instead of TraServer (comparison path)")
    ap.add_argument("--batch", type=int, default=4,
                    help="dense-oracle batch size")
    ap.add_argument("--mesh", default=None,
                    help="dense-oracle mesh, e.g. 2x2")
    args = ap.parse_args(argv)

    if args.dense_oracle:
        return _dense_oracle(args)

    import numpy as np

    from repro.core import Engine
    from repro.serve import (FFNNScorer, RecurrentLM, TraServer,
                             closed_loop, lm_mix, open_loop,
                             poisson_arrivals, scorer_mix)

    rng = np.random.default_rng(args.seed)
    engine = Engine(executor=args.executor)
    if args.servable == "scorer":
        servable = FFNNScorer(seed=args.seed)
        payloads = scorer_mix(servable, rng, args.requests)
    else:
        from repro.configs import get_config
        cfg = get_config(args.arch, smoke=args.smoke)
        servable = RecurrentLM.from_config(cfg, capacity=args.capacity,
                                           seed=args.seed)
        payloads = lm_mix(servable, rng, args.requests,
                          prompt_len=(1, max(1, args.prompt_len)),
                          new_tokens=(1, max(1, args.gen)))

    server = TraServer(engine, servable,
                       max_pending=args.max_pending,
                       max_queue_wait_s=args.max_queue_wait,
                       max_retries=args.retries)
    server.warmup()
    if args.mode == "poisson":
        arrivals = poisson_arrivals(rng, args.requests, args.rate)
        report = open_loop(server, payloads, arrivals,
                           deadline_s=args.deadline)
    else:
        report = closed_loop(server, lambda i: payloads[i],
                             n_requests=args.requests,
                             concurrency=args.concurrency)

    stats = server.stats()
    out = {**report.to_json(),
           "cache_misses_since_warmup": stats["cache_misses_since_warmup"],
           "artifacts": stats["artifacts"],
           "health": stats["health"]}
    if args.json:
        print(json.dumps(out, indent=2))
    else:
        t = out["total_ms"]
        print(f"[serve] {servable.name} on {engine.executor}: "
              f"{report.requests} requests ({report.errors} errors, "
              f"{report.shed} shed), {out['tokens_per_s']:.1f} tok/s")
        print(f"[serve] latency ms p50/p95/p99 = "
              f"{t['p50']:.1f}/{t['p95']:.1f}/{t['p99']:.1f}; "
              f"queue-wait p50 = {out['queue_wait_ms']['p50']:.1f} ms")
        print(f"[serve] artifacts: {len(out['artifacts'])} pinned, "
              f"{out['cache_misses_since_warmup']} cache misses "
              f"after warmup")
        hc = out["health"]["counters"]
        print(f"[serve] health {out['health']['status']}: "
              f"retries={hc['retries']} recovered={hc['recovered']} "
              f"shed={hc['shed']} deadline={hc['deadline_expired']}")
    return 1 if report.errors else 0


if __name__ == "__main__":
    sys.exit(main())
