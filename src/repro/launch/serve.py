"""Serving launcher: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Runs prefill over a batch of prompts, then step-decodes with greedy
sampling against the fixed-capacity cache.  With ``--mesh`` the cache and
weights are sharded per the TRA plan (decode forces KV sharding — see
planner).
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args(argv)

    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={d * m} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import decode_step, init_params, prefill

    cfg = get_config(args.arch, smoke=args.smoke)
    cache_len = args.prompt_len + args.gen
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)

    sharder = None
    if args.mesh:
        from repro.configs.base import ShapeSpec
        from repro.launch.mesh import make_host_mesh
        from repro.sharding import make_sharder, plan_arch
        mesh = make_host_mesh(d, m)
        shape = ShapeSpec("serve", cache_len, args.batch, "decode")
        plan = plan_arch(cfg, shape, mesh)
        sharder = make_sharder(mesh, plan.act_axis_map)
    else:
        from repro.models.layers import no_shard
        sharder = no_shard

    B, S = args.batch, args.prompt_len
    if cfg.input_mode == "tokens":
        prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": prompts}
    else:
        batch = {"embeds": jax.random.normal(
            key, (B, S, cfg.d_model), jnp.bfloat16)}

    t0 = time.perf_counter()
    pf = jax.jit(lambda p, b: prefill(cfg, p, b, cache_len, sharder))
    logits, cache = pf(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    step = jax.jit(lambda p, c, b: decode_step(cfg, p, c, b, sharder),
                   donate_argnums=(1,))
    out_tokens = []
    tok = logits.argmax(-1).astype(jnp.int32)
    t1 = time.perf_counter()
    for _ in range(args.gen):
        if cfg.input_mode == "tokens":
            step_in = {"token": tok}
        else:
            emb = jax.random.normal(key, (B, 1, cfg.d_model), jnp.bfloat16)
            step_in = {"embed": emb}
        logits, cache = step(params, cache, step_in)
        tok = logits.argmax(-1).astype(jnp.int32)
        out_tokens.append(jax.device_get(tok)[:, 0])
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t1

    toks_s = B * args.gen / t_decode
    print(f"[serve] {args.arch}: prefill({B}x{S}) {t_prefill * 1e3:.1f} ms, "
          f"decode {args.gen} steps @ {toks_s:.1f} tok/s")
    print(f"[serve] sample continuation (seq 0): "
          f"{[int(t[0]) for t in out_tokens]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
