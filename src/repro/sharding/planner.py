"""TRA-cost-driven sharding planner — the paper's technique as the
framework's distribution engine.

Every heavy matmul (pair) in a model — QKV/out projections, MLP up/down,
MoE experts, Mamba2 in/out projections, embedding, LM head — is expressed
as a TRA join+aggregate chain over chunked operands:

    H[b,h] = Σ_k X[b,k]·W1[k,h] ;  Y[b,o] = Σ_h H[b,h]·W2[h,o]
    ≙  Σ_{(⟨0,2⟩,+)}(⋈_{(⟨1⟩,⟨0⟩,×)}(Σ_{(⟨0,2⟩,+)}(⋈_{(⟨1⟩,⟨0⟩,×)}(R_X,R_W1)), R_W2))

For each candidate *weight placement* pair (the paper's ALL / PART_D
predicates: replicated, column-partitioned, row-partitioned over the model
mesh axis) the paper's optimizer (repro.core.optimize — equivalence rules
R1/R2 + the BMM/CPMM/RMM domain rules, priced by the exact §4.3
float-movement cost model) finds the cheapest IA realization with the
output back in batch-partitioned form.  The planner thereby *derives*:

* data parallelism      — (ALL, ALL): weights replicated, zero steady-state
                          forward comm (the paper's TRA-DP);
* Megatron tensor parallelism — (col, row): the first local join needs no
                          movement and leaves H feature-partitioned; the
                          second is a co-partitioned CPMM join whose
                          aggregation is the two-phase R2-5 rule — i.e. a
                          reduce-scatter.  This is the paper's TRA-MP,
                          recovered from first principles;
* everything in between — (col, ALL), (ALL, row), … are priced too and the
                          full candidate log is kept for EXPERIMENTS.md.

Backward-pass communication mirrors the forward structure (dX retraces the
chain with transposed weights; dW joins are co-partitioned on the batch
dim), so the steady-state per-step cost we compare is ``3 × fwd`` plus the
gradient synchronization over the data axis — which is placement-invariant
(``w`` global floats either way) and therefore dropped from the
comparison.  Results are memoized per (shape, mesh) signature.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core import (Placement, RelType, TraAgg, TraInput, TraJoin,
                        get_kernel, optimize)

# --------------------------------------------------------------------------
# Mesh description (hashable, planner-level)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlannerMesh:
    """Logical 2-D planning mesh: all data-parallel axes folded into one."""

    data_axes: Tuple[str, ...]          # e.g. ("pod", "data")
    model_axis: str
    data_size: int
    model_size: int

    @staticmethod
    def from_mesh(mesh) -> "PlannerMesh":
        names = list(mesh.axis_names)
        model = names[-1]
        data = tuple(n for n in names if n != model)
        dsize = math.prod(mesh.shape[a] for a in data) if data else 1
        return PlannerMesh(data, model, dsize, mesh.shape[model])


@dataclasses.dataclass(frozen=True)
class PairDecision:
    strategy: str                       # "dp" | "tp" | "fsdp" | mixed tag
    w1: str                             # "all" | "col" | "row"
    w2: str
    w_moved: bool                       # winner gathers weights per step
    cost: int                           # fwd floats moved (wire metric)
    candidates: Tuple[Tuple[str, int], ...]


_PLACE = {
    "all": lambda m: Placement.replicated(),
    "col": lambda m: Placement.partitioned((1,), (m,)),
    "row": lambda m: Placement.partitioned((0,), (m,)),
}


def _weights_moved(plan, names=("W1", "W2")) -> bool:
    """True if any weight input feeds a BCAST/SHUF in the winning plan
    (FSDP-style per-step gather rather than in-place use)."""
    from repro.core.plan import Bcast as _B, IAInput as _I, Shuf as _S
    from repro.core.plan import children, postorder as _post
    moved = False
    for n in _post(plan):
        if isinstance(n, (_B, _S)):
            for c in children(n):
                if isinstance(c, _I) and c.name in names \
                        and c.placement.kind == "partitioned":
                    moved = True
    return moved


@functools.lru_cache(maxsize=None)
def price_pair(tokens: int, d_in: int, d_hidden: int, d_out: int,
               data_size: int, model_size: int,
               allow_replicated: bool = True) -> PairDecision:
    """Price every weight-placement pair through the TRA optimizer.

    ``allow_replicated=False`` excludes replicated weight *storage* — the
    memory gate.  The paper's comm-only cost model famously ran out of GPU
    memory in its own §5.4 ("our simple Python-based TRA implementation
    lacked a proper memory management system"); at 1000-node scale the
    framework instead refuses to replicate weights that do not fit the
    budget, which is exactly the paper's TRA-DP choice of *storing* weights
    partitioned and broadcasting them per step (≙ FSDP on TPU).
    """
    d_ax, m_ax = "D", "M"
    sd, sm = max(data_size, 1), max(model_size, 1)
    axis_sizes = {d_ax: sd, m_ax: sm}

    tb = max(tokens // sd, 1)
    kb, hb, ob = (max(d_in // sm, 1), max(d_hidden // sm, 1),
                  max(d_out // sm, 1))
    x = TraInput("X", RelType((sd, sm), (tb, kb)))
    w1 = TraInput("W1", RelType((sm, sm), (kb, hb)))
    w2 = TraInput("W2", RelType((sm, sm), (hb, ob)))
    h = TraAgg(TraJoin(x, w1, (1,), (0,), get_kernel("matMul")),
               (0, 2), get_kernel("matAdd"))
    y = TraAgg(TraJoin(h, w2, (1,), (0,), get_kernel("matMul")),
               (0, 2), get_kernel("matAdd"))

    target = Placement.partitioned((0,), (d_ax,))
    tags = list(_PLACE) if allow_replicated else ["col", "row"]
    results = []
    plans = {}
    for t1 in tags:
        for t2 in tags:
            try:
                res = optimize(
                    y,
                    {"X": Placement.partitioned((0,), (d_ax,)),
                     "W1": _PLACE[t1](m_ax), "W2": _PLACE[t2](m_ax)},
                    site_axes=(d_ax, m_ax), axis_sizes=axis_sizes,
                    target=target, try_logical_rewrites=False)
            except ValueError:
                continue
            results.append(((t1, t2), res.cost))
            plans[(t1, t2)] = res.plan
    if not results:
        raise ValueError("no valid placement for matmul pair")
    # prefer cheaper; on ties prefer more-sharded weights (memory)
    shardedness = {"all": 0, "col": 1, "row": 1}

    def rank(item):
        (t1, t2), cost = item
        return (cost, -(shardedness[t1] + shardedness[t2]))

    results.sort(key=rank)
    (t1, t2), cost = results[0]
    moved = _weights_moved(plans[(t1, t2)])
    if (t1, t2) == ("all", "all"):
        strategy = "dp"
    elif moved:
        strategy = "fsdp"
    else:
        strategy = "tp"
    return PairDecision(strategy, t1, t2, moved, cost,
                        tuple((f"{a}+{b}", c) for (a, b), c in results))


@functools.lru_cache(maxsize=None)
def price_moe(tokens: int, d_model: int, d_ff: int, n_experts: int,
              top_k: int, data_size: int, model_size: int,
              capacity_factor: float = 1.25) -> Tuple[str, int, int]:
    """Expert-parallel vs tensor-parallel experts, paper cost units.

    * EP — experts are ``PART_expert`` over the model axis; the token
      dispatch into the (E, C, d) buffer and the return combine are each a
      ``SHUF`` (all-to-all) of the full dispatch relation:
      ``cost = 2 × T·K·cf·d`` floats.
    * TP — every expert's FFN is Megatron-split over the model axis; the
      dispatch stays local but each of the T·K routed tokens pays the
      two-phase aggregation (reduce-scatter) on the way out of the pair,
      priced by :func:`price_pair` with T·K tokens.
    """
    routed = int(tokens * top_k * capacity_factor)
    ep_cost = 2 * routed * d_model
    tp = price_pair(max(tokens * top_k, 1), d_model, d_ff, d_model,
                    data_size, model_size)
    # force a sharded strategy for TP pricing (dp handled by EP comparison)
    tp_cost = dict(tp.candidates).get("col+row", tp.cost)
    if ep_cost <= tp_cost:
        return "ep", ep_cost, tp_cost
    return "tp", ep_cost, tp_cost


# --------------------------------------------------------------------------
# Whole-architecture plan
# --------------------------------------------------------------------------


# Replicated-storage budget per chip (weights in bf16).  TPU v5e has 16 GB
# HBM; at scale, weights+grads+optimizer+activations must share it, so only
# genuinely small models may replicate (the paper's §5.4 OOM lesson).
REPLICATED_BUDGET_BYTES = 2 << 30


@dataclasses.dataclass
class ArchPlan:
    """Logical-axis → physical-mesh-axis mappings + the decision log.

    ``param_axis_map`` drives weight *storage* specs; ``act_axis_map``
    drives activation constraints.  They differ under FSDP: weights stored
    sharded (gathered per step by XLA) while activations keep no feature
    sharding.
    """

    param_axis_map: Dict[str, Optional[Tuple[str, ...]]]
    act_axis_map: Dict[str, Optional[Tuple[str, ...]]]
    decisions: Dict[str, object]
    mesh: PlannerMesh

    def describe(self) -> str:
        lines = [f"mesh: data={self.mesh.data_axes}×{self.mesh.data_size} "
                 f"model={self.mesh.model_axis}×{self.mesh.model_size}"]
        for comp, dec in sorted(self.decisions.items()):
            if isinstance(dec, PairDecision):
                lines.append(
                    f"  {comp:8s} → {dec.strategy:8s} (W1={dec.w1}, "
                    f"W2={dec.w2}) cost={dec.cost:,}  "
                    f"candidates={list(dec.candidates)[:4]}")
            else:
                lines.append(f"  {comp:8s} → {dec}")
        pa = {k: v for k, v in self.param_axis_map.items() if v}
        aa = {k: v for k, v in self.act_axis_map.items() if v}
        lines.append(f"  param axes: {pa}")
        lines.append(f"  act axes:   {aa}")
        return "\n".join(lines)


def plan_arch(cfg: ModelConfig, shape: ShapeSpec, mesh) -> ArchPlan:
    """Run the paper's cost model over every component of ``cfg``."""
    from repro.models.model import count_params

    pm = PlannerMesh.from_mesh(mesh)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    if shape.kind == "train":
        # training runs gradient accumulation at one sequence per data
        # shard per microbatch; weight movement (FSDP gathers) recurs per
        # microbatch while activation collectives scale with tokens, so
        # the honest comparison prices ONE microbatch.
        accum = max(1, shape.global_batch // max(pm.data_size, 1))
        tokens = max(tokens // accum, 1)
    sd, sm = pm.data_size, pm.model_size
    m = pm.model_axis
    decisions: Dict[str, object] = {}

    replicated_bytes = 2 * count_params(cfg)
    allow_rep = replicated_bytes <= REPLICATED_BUDGET_BYTES
    decisions["memory-gate"] = (
        f"replicated weights = {replicated_bytes / 2**30:.2f} GiB "
        f"({'fits' if allow_rep else 'exceeds'} "
        f"{REPLICATED_BUDGET_BYTES / 2**30:.0f} GiB budget) → "
        f"{'replication allowed' if allow_rep else 'sharded storage only'}")

    data_ok = shape.global_batch % max(sd, 1) == 0
    base: Dict[str, Optional[Tuple[str, ...]]] = {
        "data": pm.data_axes if data_ok else None,
        "attn": None, "kv": None, "ffn": None, "expert": None,
        "ssm": None, "vocab": None, "seq": None,
    }
    pmap = dict(base)
    amap = dict(base)
    if not data_ok and shape.kind == "decode":
        # batch below the data size (long-context decode): context-shard
        # the KV caches' sequence dim over the data axes instead
        amap["seq"] = pm.data_axes

    def decide(component: str, logical: str, d_hidden: int,
               act_divisor: int) -> None:
        """Weight *storage* shards whenever the flat weight dim divides
        the model axis; feature-dim *activation* sharding additionally
        needs ``act_divisor`` (e.g. the head count) to divide."""
        dec = price_pair(tokens, cfg.d_model, d_hidden, cfg.d_model,
                         sd, sm, allow_replicated=allow_rep)
        decisions[component] = dec
        if dec.strategy in ("tp", "fsdp") and d_hidden % sm == 0:
            pmap[logical] = (m,)
        if dec.strategy == "tp" and act_divisor % sm == 0:
            amap[logical] = (m,)

    if cfg.has_attention:
        decide("attn", "attn", cfg.n_heads * max(cfg.head_dim, 1),
               cfg.n_heads)
        if pmap["attn"] and cfg.n_kv_heads:
            if (cfg.n_kv_heads * cfg.head_dim) % sm == 0:
                pmap["kv"] = (m,)
            if amap["attn"] and cfg.n_kv_heads % sm == 0:
                amap["kv"] = (m,)
        if shape.kind in ("decode", "prefill"):
            # Inference is KV-cache-bound: the cache must shard over the
            # model axis regardless of the weight-comm decision — over kv
            # heads when divisible, else over the cache sequence dim
            # (context parallelism).  Matmul comm is second-order here.
            if not cfg.use_mla and cfg.n_kv_heads % sm == 0 \
                    and cfg.n_heads % sm == 0:
                pmap["attn"] = amap["attn"] = (m,)
                pmap["kv"] = amap["kv"] = (m,)
                decisions["attn-serve"] = "TP (KV-head-sharded cache)"
            else:
                amap["seq"] = (m,)
                decisions["attn-serve"] = ("context-sharded cache "
                                           "(kv heads % model != 0 or MLA)")

    if cfg.d_ff:
        decide("mlp", "ffn", cfg.d_ff, cfg.d_ff)

    if cfg.n_experts:
        tag, ep_cost, tp_cost = price_moe(
            tokens, cfg.d_model, cfg.d_ff_expert, cfg.n_experts,
            cfg.top_k, sd, sm, cfg.moe_capacity_factor)
        decisions["moe"] = (f"{tag} (ep={ep_cost:,} vs tp={tp_cost:,})")
        if tag == "ep" and cfg.n_experts % sm == 0:
            pmap["expert"] = (m,)
            amap["expert"] = (m,)
        elif cfg.d_ff_expert % sm == 0:
            pmap["ffn"] = (m,)
            amap["ffn"] = (m,)

    if cfg.ssm_state:
        decide("ssm", "ssm", cfg.d_inner, cfg.d_inner)

    # LM head / embedding: vocab-sharding keeps the logits partitioned
    # (softmax normalizer is a tiny all-reduce) at zero extra fwd cost and
    # shards the largest single tensor — preferred whenever divisible,
    # mandatory when replication is memory-gated.
    if cfg.vocab_size % sm == 0:
        pmap["vocab"] = (m,)
        amap["vocab"] = (m,)
        decisions["vocab"] = "col (vocab-sharded embed/head + logits)"
    else:
        decisions["vocab"] = "replicated (vocab % model axis != 0)"

    return ArchPlan(pmap, amap, decisions, pm)
