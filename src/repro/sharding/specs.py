"""PartitionSpec assignment: ArchPlan → param/batch/cache specs + sharder.

The planner decides *strategies* (ArchPlan.axis_map maps logical axis names
used inside the model — "data", "attn", "kv", "ffn", "expert", "ssm",
"vocab", "seq" — to physical mesh axes).  This module turns those into:

* a PartitionSpec pytree for the parameters (path-rule based),
* PartitionSpecs for step inputs (token batches) and decode caches,
* a ``shard`` closure for activation constraints inside the model,
* optional ZeRO-style optimizer-state sharding over the data axes.

Every rule guards divisibility: a dim that does not divide its axis size
falls back to replication for that dim (GSPMD could pad, the explicit
shard_map tests cannot).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.sharding.planner import ArchPlan

AxisMap = Dict[str, Optional[Tuple[str, ...]]]


def _axis_size(mesh: Mesh, axes: Optional[Tuple[str, ...]]) -> int:
    if not axes:
        return 1
    return math.prod(mesh.shape[a] for a in axes)


def _entry(mesh: Mesh, axis_map: AxisMap, logical: Optional[str],
           dim_size: int):
    """Physical spec entry for one dim, with a divisibility guard."""
    if logical is None:
        return None
    phys = axis_map.get(logical)
    if not phys:
        return None
    size = _axis_size(mesh, phys)
    if size <= 1 or dim_size % size:
        return None
    return phys if len(phys) > 1 else phys[0]


def _dedupe_axes(entries):
    """A mesh axis may shard at most one dim: first claim wins."""
    used = set()
    out = []
    for e in entries:
        axes = (e,) if isinstance(e, str) else tuple(e or ())
        if e is not None and any(a in used for a in axes):
            out.append(None)
        else:
            used.update(axes)
            out.append(e)
    return out


def make_sharder(mesh: Optional[Mesh], axis_map: AxisMap):
    """Activation-constraint closure passed into the model as ``shard``."""
    if mesh is None:
        from repro.models.layers import no_shard
        return no_shard

    def shard(x: jax.Array, *logical):
        entries = [None] * x.ndim
        for d, name in enumerate(logical[:x.ndim]):
            entries[d] = _entry(mesh, axis_map, name, x.shape[d])
        entries = _dedupe_axes(entries)
        while entries and entries[-1] is None:
            entries.pop()
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*entries)))

    # expose the data-parallel group count (MoE local dispatch keys on it)
    shard.data_size = _axis_size(mesh, axis_map.get("data"))
    return shard


# --------------------------------------------------------------------------
# Parameter specs (path rules)
# --------------------------------------------------------------------------

# (matcher keys, per-trailing-dim logical axes) — matched against the last
# path components of each leaf; None entries replicate that dim.
_RULES = [
    (("embed", "w"), ("vocab", None)),
    (("lm_head", "w"), (None, "vocab")),
    # GQA
    (("attn", "wq"), (None, "attn")),
    (("attn", "bq"), ("attn",)),
    (("attn", "wk"), (None, "kv")),
    (("attn", "bk"), ("kv",)),
    (("attn", "wv"), (None, "kv")),
    (("attn", "bv"), ("kv",)),
    (("attn", "wo"), ("attn", None)),
    # MLA
    (("attn", "wdkv"), (None, None)),
    (("attn", "wuk"), (None, "attn")),
    (("attn", "wuv"), (None, "attn")),
    # MLP
    (("mlp", "wi"), (None, "ffn")),
    (("mlp", "wg"), (None, "ffn")),
    (("mlp", "wo"), ("ffn", None)),
    # MoE (EP shards the expert dim; TP-experts shard the ff dim)
    (("moe", "router"), (None, None)),
    (("moe", "wi"), ("expert", None, "ffn")),
    (("moe", "wg"), ("expert", None, "ffn")),
    (("moe", "wo"), ("expert", "ffn", None)),
    (("shared", "wi"), (None, "ffn")),
    (("shared", "wg"), (None, "ffn")),
    (("shared", "wo"), ("ffn", None)),
    # Mamba2
    (("mix", "w_z"), (None, "ssm")),
    (("mix", "w_x"), (None, "ssm")),
    (("mix", "w_bc"), (None, None)),
    (("mix", "w_dt"), (None, "ssm")),
    (("mix", "conv_wx"), (None, "ssm")),
    (("mix", "conv_bx"), ("ssm",)),
    (("mix", "w_out"), ("ssm", None)),
]


def _path_keys(path) -> Tuple[str, ...]:
    out = []
    for e in path:
        k = getattr(e, "key", None)
        if k is None:
            k = getattr(e, "idx", None)
        out.append(str(k))
    return tuple(out)


def _leaf_spec(mesh: Mesh, axis_map: AxisMap, path, leaf) -> P:
    keys = _path_keys(path)
    for matcher, logical in _RULES:
        if len(keys) >= len(matcher) and \
                tuple(keys[-len(matcher):]) == tuple(matcher):
            base = logical
            break
    else:
        base = (None,) * leaf.ndim
    # leading stack dims (scan groups / in-group layers) replicate
    lead = leaf.ndim - len(base)
    entries = [None] * lead + [
        _entry(mesh, axis_map, name, leaf.shape[lead + i])
        for i, name in enumerate(base)]
    entries = _dedupe_axes(entries)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_pspecs(mesh: Mesh, axis_map: AxisMap, params_tree) -> object:
    """PartitionSpec tree matching ``params_tree`` (arrays or SDS)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(mesh, axis_map, path, leaf),
        params_tree)


def param_shardings(mesh: Mesh, axis_map: AxisMap, params_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(mesh, axis_map, params_tree))


def zero1_pspecs(mesh: Mesh, axis_map: AxisMap, params_tree) -> object:
    """Optimizer-state specs: param specs + data-axis sharding on the
    largest still-unsharded dim (ZeRO-1).  Beyond-paper optimization —
    recorded in EXPERIMENTS.md §Perf."""
    data_axes = axis_map.get("data")
    base = param_pspecs(mesh, axis_map, params_tree)

    def extend(path, leaf, spec: P):
        if not data_axes or leaf.ndim == 0:
            return spec
        dsize = _axis_size(mesh, data_axes)
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        # choose the largest unsharded dim divisible by the data size
        cands = [(leaf.shape[i], i) for i, e in enumerate(entries)
                 if e is None and leaf.shape[i] % dsize == 0
                 and leaf.shape[i] >= dsize]
        if not cands:
            return spec
        _, dim = max(cands)
        entries[dim] = data_axes if len(data_axes) > 1 else data_axes[0]
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf, spec: extend(path, leaf, spec),
        params_tree, base)


# --------------------------------------------------------------------------
# Step-input / cache specs
# --------------------------------------------------------------------------

def batch_pspecs(mesh: Mesh, axis_map: AxisMap, batch_tree,
                 microbatched: bool = False) -> object:
    """Token/label/embedding inputs: batch dim over the data axes.

    ``microbatched`` — leaves carry a leading gradient-accumulation dim
    (unsharded); the batch dim is dim 1.
    """
    bdim = 1 if microbatched else 0

    def spec(leaf) -> P:
        entries = [None] * leaf.ndim
        entries[bdim] = _entry(mesh, axis_map, "data", leaf.shape[bdim])
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    return jax.tree.map(spec, batch_tree)


def cache_pspecs(mesh: Mesh, axis_map: AxisMap, cfg: ModelConfig,
                 cache_tree) -> object:
    """Decode-cache specs.

    Attention KV caches: (…, B, S, kv_heads|lora, hd) — batch over data
    when divisible, else sequence over data ("seq" context parallelism);
    kv heads over the model axis.  SSM states: (…, B, heads, N, P) — batch
    over data, heads over the ssm axis.
    """
    def spec(path, leaf) -> P:
        keys = _path_keys(path)
        name = keys[-1]
        entries = [None] * leaf.ndim
        if name == "pos":
            return P()
        # find the batch dim: first dim after any leading stack dims.
        # leaves are stacked (G, [gsz,] B, ...): detect by name/rank.
        if name in ("k", "v"):                  # (..., B, S, KV, hd)
            b, s, kv = leaf.ndim - 4, leaf.ndim - 3, leaf.ndim - 2
            entries[b] = _entry(mesh, axis_map, "data", leaf.shape[b])
            entries[s] = _entry(mesh, axis_map, "seq", leaf.shape[s])
            entries[kv] = _entry(mesh, axis_map, "kv", leaf.shape[kv])
        elif name in ("c_kv", "k_rope"):        # (..., B, S, r)
            b, s = leaf.ndim - 3, leaf.ndim - 2
            entries[b] = _entry(mesh, axis_map, "data", leaf.shape[b])
            entries[s] = _entry(mesh, axis_map, "seq", leaf.shape[s])
        elif name == "ssm":                     # (..., B, H, N, P)
            b, h = leaf.ndim - 4, leaf.ndim - 3
            entries[b] = _entry(mesh, axis_map, "data", leaf.shape[b])
            entries[h] = _entry(mesh, axis_map, "ssm", leaf.shape[h])
        elif name in ("conv_x", "conv_bc"):     # (..., B, W-1, C)
            b, c = leaf.ndim - 3, leaf.ndim - 1
            entries[b] = _entry(mesh, axis_map, "data", leaf.shape[b])
            if name == "conv_x":
                entries[c] = _entry(mesh, axis_map, "ssm", leaf.shape[c])
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def logits_pspec(mesh: Mesh, axis_map: AxisMap) -> P:
    d = axis_map.get("data")
    v = axis_map.get("vocab")
    return P(d if not d or len(d) > 1 else d[0], None,
             v if not v or len(v) > 1 else v[0])
