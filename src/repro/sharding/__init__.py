"""TRA-cost-driven sharding: planner (strategy) + specs (PartitionSpecs)."""
from repro.sharding.planner import (ArchPlan, PairDecision, PlannerMesh,
                                    plan_arch, price_moe, price_pair)
from repro.sharding.specs import (batch_pspecs, cache_pspecs, logits_pspec,
                                  make_sharder, param_pspecs,
                                  param_shardings, zero1_pspecs)

__all__ = ["ArchPlan", "PairDecision", "PlannerMesh", "plan_arch",
           "price_moe", "price_pair", "batch_pspecs", "cache_pspecs",
           "logits_pspec", "make_sharder", "param_pspecs",
           "param_shardings", "zero1_pspecs"]
