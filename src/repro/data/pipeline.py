"""Deterministic, shardable, resumable synthetic token pipeline.

Design goals (mirroring a production loader):

* **Deterministic** — batch ``i`` is a pure function of (seed, i); any host
  can reproduce any batch.
* **Shardable** — each data-parallel host slices its own rows of the
  global batch (``host_slice``); no host ever materializes the full batch.
* **Resumable** — the loader state is a single integer (next step); a
  restart from a checkpoint at step ``k`` continues with batch ``k`` —
  byte-identical to a run that never failed (tested).

The synthetic distribution is a mixture of Zipfian unigrams and a
deterministic affine-recurrence "grammar" so the loss actually decreases
(the model can learn the recurrence), which the end-to-end example uses.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1
    grammar_frac: float = 0.5      # fraction of rows from the recurrence
    grammar_families: int = 4      # distinct (a, b) recurrences in the mix
    input_mode: str = "tokens"     # tokens | embeddings
    d_model: int = 0               # for embeddings mode


def _zipf_rows(rng: np.random.Generator, n: int, cfg: DataConfig
               ) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    p = ranks ** (-cfg.zipf_alpha)
    p /= p.sum()
    return rng.choice(cfg.vocab_size, size=(n, cfg.seq_len + 1),
                      p=p).astype(np.int32)


def _grammar_rows(rng: np.random.Generator, n: int, cfg: DataConfig
                  ) -> np.ndarray:
    """x_{t+1} = (a·x_t + b) mod V, (a, b) from a small per-dataset family.

    The family is a pure function of ``cfg.seed`` (NOT the per-batch rng),
    so every batch on every host draws from the same ``grammar_families``
    recurrences.  This is what makes the stream learnable by sequence
    statistics: p(x_{t+1} | x_t) concentrates on ≤ ``grammar_families``
    values.  (Drawing a fresh uniform ``b`` per row — the earlier behaviour
    — makes that conditional *exactly* uniform over V, so only in-context
    regression of (a, b) could beat chance and short smoke runs sat flat
    at ln V.)
    """
    v = cfg.vocab_size
    fam_rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, 0xFA311]))
    fams = np.stack([fam_rng.integers(2, 8, size=cfg.grammar_families),
                     fam_rng.integers(0, v, size=cfg.grammar_families)],
                    axis=1)
    pick = rng.integers(0, cfg.grammar_families, size=n)
    a, b = fams[pick, 0:1], fams[pick, 1:2]
    x = np.empty((n, cfg.seq_len + 1), np.int64)
    x[:, 0] = rng.integers(0, v, size=n)
    for t in range(cfg.seq_len):
        x[:, t + 1] = (a[:, 0] * x[:, t] + b[:, 0]) % v
    return x.astype(np.int32)


def make_batch(cfg: DataConfig, step: int,
               host_slice: Optional[Tuple[int, int]] = None
               ) -> Dict[str, np.ndarray]:
    """Batch ``step`` (or this host's row range of it)."""
    lo, hi = host_slice or (0, cfg.global_batch)
    rows = hi - lo
    # per-(step, row-range) independent stream: fold into the seed so a
    # host only generates its own rows yet stays globally consistent
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, lo, hi]))
    n_gram = int(rows * cfg.grammar_frac)
    parts = []
    if rows - n_gram:
        parts.append(_zipf_rows(rng, rows - n_gram, cfg))
    if n_gram:
        parts.append(_grammar_rows(rng, n_gram, cfg))
    seq = np.concatenate(parts, axis=0)
    batch: Dict[str, np.ndarray] = {"labels": seq[:, 1:]}
    if cfg.input_mode == "tokens":
        batch["tokens"] = seq[:, :-1]
    else:
        # frontend stub: deterministic embedding of the token ids
        emb_rng = np.random.default_rng(cfg.seed + 7)
        table = emb_rng.standard_normal(
            (cfg.vocab_size, cfg.d_model)).astype(np.float32) * 0.02
        batch["embeds"] = table[seq[:, :-1]]
    return batch


class DataLoader:
    """Stateful iterator wrapper: state == next step index."""

    def __init__(self, cfg: DataConfig,
                 host_slice: Optional[Tuple[int, int]] = None,
                 start_step: int = 0):
        self.cfg = cfg
        self.host_slice = host_slice
        self.step = start_step

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = make_batch(self.cfg, self.step, self.host_slice)
        self.step += 1
        return b

    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        self.step = int(state["step"])
