from repro.data.pipeline import DataConfig, DataLoader, make_batch

__all__ = ["DataConfig", "DataLoader", "make_batch"]
