"""Cache-key injectivity fuzzing.

:func:`repro.core.engine.plan_sig` is the Engine's compile-cache key: two
plans with equal signatures share one compiled artifact, so a signature
that fails to separate *semantically different* plans silently serves
wrong results from the cache.  This pass perturbs a plan one attribute
at a time — kernel parameters, value dtypes, key shapes, join-key
pairings, group-bys, placements (including the pending ``dup_kernel`` of
a two-phase aggregation), partial flags, tile/concat/pad geometry — and
asserts the signature separates every mutant from the original.  A
surviving collision is reported with the mutated node's provenance and
the exact attribute the signature drops.

The mutation enumeration is deterministic (no RNG): it is cheap enough
to run from tests and ``python -m repro.analysis.lint``, and the same
enumeration seeds the hypothesis-driven randomized variant in
``tests/test_analysis.py``.  Collisions this fuzzer found historically
(pending ``dup_kernel`` missing from input-placement signatures; ad-hoc
kernels distinguished only by ``id(apply)``, which a recycled id can
alias) are fixed in ``engine.plan_sig`` with regression tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostics
from repro.core import plan as P
from repro.core.kernels_registry import Kernel
from repro.core.tra import RelType

PASS = "cachekey"


def _replace_node(root, target, replacement):
    """``root`` with ``target`` (by identity) swapped for ``replacement``;
    ancestors are rebuilt, untouched subtrees are shared."""
    memo = {}

    def rb(n):
        if id(n) in memo:
            return memo[id(n)]
        if n is target:
            out = replacement
        elif isinstance(n, (P.TraJoin, P.LocalJoin, P.FusedJoinAgg)):
            left, right = rb(n.left), rb(n.right)
            out = n if left is n.left and right is n.right \
                else dataclasses.replace(n, left=left, right=right)
        elif isinstance(n, (P.TraInput, P.IAInput, P.TraConst, P.IAConst)):
            out = n
        else:
            child = rb(n.child)
            out = n if child is n.child \
                else dataclasses.replace(n, child=child)
        memo[id(n)] = out
        return out

    return rb(root)


def _flip_dtype(rtype: RelType) -> RelType:
    new = "float64" if str(rtype.dtype) in ("float32", "<class 'float'>") \
        else "float32"
    return RelType(rtype.key_shape, rtype.bound, new)


def _bump_key_shape(rtype: RelType) -> Optional[RelType]:
    if not rtype.key_shape:
        return None
    ks = (rtype.key_shape[0] + 1,) + rtype.key_shape[1:]
    return RelType(ks, rtype.bound, rtype.dtype)


def _shadow_kernel(k: Kernel) -> Kernel:
    """Same name, same ``apply`` identity, different ``out_bound`` — the
    ad-hoc-kernel collision class: only the out_bound content differs."""
    return dataclasses.replace(
        k, out_bound=lambda *bounds: tuple(k.out_bound(*bounds)))


def _mutate_placement(p: P.Placement) -> List[Tuple[str, P.Placement]]:
    out: List[Tuple[str, P.Placement]] = []
    if p.kind == "partitioned" and p.dims:
        out.append(("drop the partitioning (replicated instead of "
                    f"PART{list(p.dims)})", P.Placement.replicated()))
        if not p.dup_axes:
            out.append((f"mark pending duplicates along {p.axes[0]!r}",
                        P.Placement.partitioned(
                            p.dims, p.axes, dup_axes=(p.axes[0],),
                            dup_kernel="matAdd")))
    if p.dup_axes:
        other = "elemMax" if p.dup_kernel != "elemMax" else "matAdd"
        out.append((f"change the pending dup reducer "
                    f"{p.dup_kernel or 'matAdd'!r} -> {other!r}",
                    dataclasses.replace(p, dup_kernel=other)))
    return out


def node_mutations(n) -> Iterator[Tuple[str, object]]:
    """Yield ``(what changed, mutated node)`` for one plan node."""
    if isinstance(n, (P.TraInput, P.IAInput)):
        yield ("flip the input value dtype",
               dataclasses.replace(n, rtype=_flip_dtype(n.rtype)))
        bumped = _bump_key_shape(n.rtype)
        if bumped is not None:
            yield ("grow the input key frontier",
                   dataclasses.replace(n, rtype=bumped))
        if isinstance(n, P.IAInput):
            for what, pl in _mutate_placement(n.placement):
                yield (what, dataclasses.replace(n, placement=pl))
    elif isinstance(n, (P.TraConst, P.IAConst)):
        yield ("change the constant fill value",
               dataclasses.replace(n, fill=n.fill + 1.0))
        if isinstance(n, P.IAConst):
            for what, pl in _mutate_placement(n.placement):
                yield (what, dataclasses.replace(n, placement=pl))
    elif isinstance(n, (P.TraJoin, P.LocalJoin)):
        if len(n.join_keys_r) > 1:
            yield ("re-pair the join keys (reverse the right key order)",
                   dataclasses.replace(
                       n, join_keys_r=tuple(reversed(n.join_keys_r))))
        yield ("swap the join kernel's out_bound under the same name "
               "and apply",
               dataclasses.replace(n, kernel=_shadow_kernel(n.kernel)))
    elif isinstance(n, P.FusedJoinAgg):
        if len(n.join_keys_r) > 1:
            yield ("re-pair the fused join keys",
                   dataclasses.replace(
                       n, join_keys_r=tuple(reversed(n.join_keys_r))))
        if len(n.group_by) > 1:
            yield ("permute the fused group_by",
                   dataclasses.replace(
                       n, group_by=tuple(reversed(n.group_by))))
        yield ("flip the fused partial flag",
               dataclasses.replace(n, partial=not n.partial))
        yield ("swap the fused agg kernel's out_bound under the same "
               "name and apply",
               dataclasses.replace(n,
                                   agg_kernel=_shadow_kernel(n.agg_kernel)))
    elif isinstance(n, (P.TraAgg, P.LocalAgg)):
        if len(n.group_by) > 1:
            yield ("permute the group_by",
                   dataclasses.replace(n,
                                       group_by=tuple(reversed(n.group_by))))
        if isinstance(n, P.LocalAgg):
            yield ("flip the partial flag",
                   dataclasses.replace(n, partial=not n.partial))
        yield ("swap the agg kernel's out_bound under the same name "
               "and apply",
               dataclasses.replace(n, kernel=_shadow_kernel(n.kernel)))
    elif isinstance(n, P.TraTransform):
        yield ("swap the map kernel's out_bound under the same name "
               "and apply",
               dataclasses.replace(n, kernel=_shadow_kernel(n.kernel)))
    elif isinstance(n, (P.TraFilter, P.LocalFilter)):
        yield ("swap the filter predicate under the same tag",
               dataclasses.replace(n, bool_func=lambda k: True))
    elif isinstance(n, P.TraReKey):
        yield ("swap the key function under the same tag",
               dataclasses.replace(n, key_func=lambda k: k))
    elif isinstance(n, (P.TraTile, P.LocalTile)):
        yield ("double the tile size",
               dataclasses.replace(n, tile_size=n.tile_size * 2))
    elif isinstance(n, (P.TraConcat, P.LocalConcat)):
        yield ("move the concat array_dim",
               dataclasses.replace(n, array_dim=n.array_dim + 1))
    elif isinstance(n, (P.TraPad, P.LocalPad)):
        yield ("grow the pad target key_shape",
               dataclasses.replace(
                   n, key_shape=tuple(k + 1 for k in n.key_shape)))
    elif isinstance(n, P.Shuf):
        yield ("retarget the shuffle axes",
               dataclasses.replace(
                   n, axes=tuple(f"{a}'" for a in n.axes)))
    # Bcast carries no attributes beyond its child


def plan_mutations(root) -> Iterator[Tuple[str, object, object]]:
    """All single-attribute mutants of ``root``:
    ``(description, mutated_node, mutant_root)``."""
    root = P.as_node(root)
    for n in P.postorder(root):
        for what, repl in node_mutations(n):
            yield (f"{what} at {type(n).__name__}",
                   n, _replace_node(root, n, repl))


def check_sig_injectivity(roots, sig_fn: Optional[Callable] = None,
                          labels=None,
                          diags: Optional[Diagnostics] = None
                          ) -> Diagnostics:
    """Assert ``sig_fn`` separates every single-attribute mutant.

    ``sig_fn`` defaults to the engine's :func:`plan_sig`.  Each surviving
    collision is an error diagnostic naming the mutation and the node it
    perturbs — i.e. the attribute the signature fails to observe.
    """
    if sig_fn is None:
        from repro.core.engine import plan_sig
        sig_fn = plan_sig
    if diags is None:
        diags = Diagnostics()
    if not isinstance(roots, (tuple, list)):
        roots = (roots,)
    if labels is None:
        from repro.core.guards import label_nodes
        labels = label_nodes(roots)
    for root in roots:
        base = sig_fn(root)
        for what, node, mutant in plan_mutations(root):
            if sig_fn(mutant) == base:
                diags.add(
                    PASS, "error",
                    f"plan_sig collision: \"{what}\" leaves the "
                    f"signature unchanged — two structurally different "
                    f"plans would share one compile-cache artifact",
                    node=node, labels=labels,
                    hint="include the mutated attribute in that node "
                         "type's signature tuple in "
                         "repro.core.engine.plan_sig")
    return diags


def check_cache_keys(ctx) -> None:
    """Pass body (lint/tests only — not part of the per-compile set)."""
    check_sig_injectivity(ctx.roots, labels=ctx.labels, diags=ctx.diags)
