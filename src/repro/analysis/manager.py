"""Pass manager for the static plan verifier.

The verifier is a short, deterministic pipeline over one compiled
program's roots: every pass is a plain function ``(VerifyContext) ->
None`` that appends to ``ctx.diags``.  The manager owns pass ordering,
the shared type-inference cache, and the node-provenance table — all
passes address nodes by the :func:`repro.core.guards.label_nodes` ids so
diagnostics, fault-injection selectors and numerics attribution agree on
what "node 7" means.

:func:`verify_plans` is the one-call entry the
:class:`~repro.core.engine.Engine` uses on every compile (post
optimization, pre executor construction); ``python -m
repro.analysis.lint`` drives the same manager over the program corpus.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.diagnostics import Diagnostics
from repro.core.guards import label_nodes
from repro.core.plan import TypeInfo, as_node, infer

# passes cheap enough (pure shape/placement walks) to run on every
# Engine compile; "cachekey" mutates and re-signs whole plans, so it
# runs from the lint CLI / tests instead
DEFAULT_COMPILE_PASSES = ("placement", "collectives", "streaming", "memory")
ALL_PASSES = DEFAULT_COMPILE_PASSES + ("cachekey",)


@dataclasses.dataclass
class VerifyContext:
    """Shared state threaded through every verifier pass.

    ``roots`` are the plans as handed to the executor (physical ``IANode``
    trees post-optimization, or logical ``TraNode`` trees on the
    unoptimized host walks); ``logical_roots`` are the pre-lowering
    logical roots when the caller still has them (the streaming pass
    analyses those — carrier legality is a logical-plan property).
    """

    roots: Tuple
    executor: str = "jit"
    axis_sizes: Dict[str, int] = dataclasses.field(default_factory=dict)
    memory_budget: Optional[int] = None
    fuse: bool = True
    logical_roots: Optional[Tuple] = None
    diags: Diagnostics = dataclasses.field(default_factory=Diagnostics)
    # id(node) -> (nid, label): plan_sig-postorder provenance over roots
    labels: Dict[int, Tuple[int, str]] = dataclasses.field(
        default_factory=dict)
    # id(node) -> TypeInfo, shared across passes (infer is cache-keyed)
    types: Dict[int, TypeInfo] = dataclasses.field(default_factory=dict)

    def type_of(self, node) -> TypeInfo:
        if id(node) not in self.types:
            infer(node, cache=self.types)
        return self.types[id(node)]


def _registry() -> Dict[str, Callable[[VerifyContext], None]]:
    from repro.analysis.cachekey import check_cache_keys
    from repro.analysis.collectives import check_collectives
    from repro.analysis.memory import check_memory_model
    from repro.analysis.placement import check_placements
    from repro.analysis.streaming import check_streaming
    return {
        "placement": check_placements,
        "collectives": check_collectives,
        "streaming": check_streaming,
        "memory": check_memory_model,
        "cachekey": check_cache_keys,
    }


class PassManager:
    """Run an ordered list of verifier passes over one program."""

    def __init__(self, passes: Sequence[str] = DEFAULT_COMPILE_PASSES):
        registry = _registry()
        unknown = [p for p in passes if p not in registry]
        if unknown:
            raise ValueError(
                f"unknown verifier pass(es) {unknown}; "
                f"available: {sorted(registry)}")
        self.passes: List[Tuple[str, Callable]] = [
            (p, registry[p]) for p in passes]

    def run(self, ctx: VerifyContext) -> Diagnostics:
        if not ctx.labels:
            ctx.labels = label_nodes(ctx.roots)
        for name, fn in self.passes:
            try:
                fn(ctx)
            except Exception as exc:  # a crashing pass is itself a finding
                ctx.diags.add(
                    name, "error",
                    f"verifier pass crashed: {type(exc).__name__}: {exc}",
                    hint="this is a verifier bug — report it; the plan "
                         "itself may still be valid")
        return ctx.diags


def verify_plans(roots, *, executor: str = "jit",
                 axis_sizes: Optional[Dict[str, int]] = None,
                 memory_budget: Optional[int] = None,
                 fuse: bool = True,
                 logical_roots=None,
                 passes: Sequence[str] = DEFAULT_COMPILE_PASSES
                 ) -> Diagnostics:
    """Verify a program's plans; returns the collected diagnostics.

    This is the hook :meth:`repro.core.engine.Engine.compile` calls once
    per cache miss (``validate="warn"``/``"strict"``): ``roots`` are the
    executor-bound plans, ``executor``/``axis_sizes``/``memory_budget``/
    ``fuse`` mirror the engine configuration so pass applicability (e.g.
    shard_map divisibility, streaming legality) matches what will
    actually execute.
    """
    if not isinstance(roots, (tuple, list)):
        roots = (roots,)
    roots = tuple(as_node(r) for r in roots)
    if logical_roots is not None:
        if not isinstance(logical_roots, (tuple, list)):
            logical_roots = (logical_roots,)
        logical_roots = tuple(as_node(r) for r in logical_roots)
    ctx = VerifyContext(
        roots=roots, executor=executor,
        axis_sizes=dict(axis_sizes or {}), memory_budget=memory_budget,
        fuse=fuse, logical_roots=logical_roots)
    return PassManager(passes).run(ctx)
