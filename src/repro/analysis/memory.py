"""Memory-model audit.

``cost.plan_peak_bytes`` is load-bearing: ``Engine(memory_budget=...)``
trusts it to decide whether a plan runs resident or is routed through
the host relation store, and the out-of-core planner's chunk sizing is
an affine fit over it.  An estimator bug does not fail loudly — it
surfaces as an OOM (under-estimate) or as pointless streaming
(over-estimate).

This pass recomputes the peak with an *independent* formulation —
interval liveness over the evaluation order (each value is alive from
its producing step to its last consuming step; roots to the end), swept
as a birth/death event walk — rather than the estimator's incremental
reference-count walk.  Both encode the same execution model (postorder
evaluation, dense allocation, fused contractions never materialize the
join grid, streamed contractions hold output + one merged partial), so
the two peaks must agree exactly; any divergence means one of the two
walks no longer models what the executors do.

Two model-level invariants are checked as well: the peak can never be
below the largest single relation, nor below the sum of the root
outputs (roots are never released).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.analysis.diagnostics import Diagnostics
from repro.core.cost import _itemsize, plan_peak_bytes
from repro.core.plan import (FusedJoinAgg, TraAgg, TraJoin, TypeInfo,
                             as_node, children, infer, postorder)

PASS = "memory"


def independent_peak_bytes(roots, *, fuse: bool = True) -> int:
    """Peak live bytes via interval liveness (event sweep).

    Independent cross-check of :func:`repro.core.cost.plan_peak_bytes`:
    same execution model, different algorithm.  Value *v* is live over
    the closed step interval ``[birth(v), death(v)]`` where ``birth`` is
    its evaluation step and ``death`` the step of its last consumer
    (roots die at the final step); the peak is the max over steps of the
    live-byte sum, plus — at a streamed contraction's own step — one
    extra output-sized transient for the in-flight merged partial.
    """
    from repro.core.tra import can_fuse
    if not isinstance(roots, (tuple, list)):
        roots = (roots,)
    roots = tuple(as_node(r) for r in roots)
    cache: Dict[int, TypeInfo] = {}
    for r in roots:
        infer(r, cache=cache)
    order, seen = [], set()
    for r in roots:
        for n in postorder(r):
            if id(n) not in seen:
                seen.add(id(n))
                order.append(n)

    consumers: Dict[int, int] = {}
    for n in order:
        for c in children(n):
            consumers[id(c)] = consumers.get(id(c), 0) + 1

    fused = set()
    for n in order:
        if isinstance(n, FusedJoinAgg):
            continue
        if (fuse and isinstance(n, TraAgg) and isinstance(n.child, TraJoin)
                and consumers.get(id(n.child), 0) == 1
                and can_fuse(n.child.kernel, n.kernel)):
            fused.add(id(n.child))

    def eff_children(n):
        out = []
        for c in children(n):
            if id(c) in fused:
                out.extend(children(c))
            else:
                out.append(c)
        return out

    steps = [n for n in order if id(n) not in fused]
    step_of = {id(n): i for i, n in enumerate(steps)}
    last = len(steps) - 1
    death: Dict[int, int] = {id(n): step_of[id(n)] for n in steps}
    for n in steps:
        for c in eff_children(n):
            death[id(c)] = max(death[id(c)], step_of[id(n)])
    for r in roots:
        death[id(r)] = last

    if not steps:
        return 0
    delta: List[int] = [0] * (len(steps) + 1)
    transient: List[int] = [0] * len(steps)
    for n in steps:
        b = cache[id(n)].rtype.nfloats * _itemsize(cache[id(n)].rtype)
        i = step_of[id(n)]
        delta[i] += b
        delta[death[id(n)] + 1] -= b
        if isinstance(n, FusedJoinAgg) or (
                isinstance(n, TraAgg) and id(n.child) in fused):
            transient[i] = b
    peak = cur = 0
    for i in range(len(steps)):
        cur += delta[i]
        peak = max(peak, cur + transient[i])
    return peak


def audit_memory_model(roots, *, fuse: bool = True,
                       estimator: Optional[Callable] = None,
                       labels=None,
                       diags: Optional[Diagnostics] = None
                       ) -> Diagnostics:
    """Cross-check ``estimator`` (default ``plan_peak_bytes``) against
    the independent liveness analysis and the model invariants."""
    from repro.core.guards import label_nodes
    if not isinstance(roots, (tuple, list)):
        roots = (roots,)
    roots = tuple(as_node(r) for r in roots)
    if estimator is None:
        estimator = plan_peak_bytes
    if diags is None:
        diags = Diagnostics()
    if labels is None:
        labels = label_nodes(roots)
    try:
        est = estimator(roots, fuse=fuse)
    except (ValueError, TypeError) as exc:
        diags.add(PASS, "error",
                  f"peak-bytes estimator failed: {exc}",
                  node=roots[0], labels=labels)
        return diags
    ind = independent_peak_bytes(roots, fuse=fuse)
    if est != ind:
        diags.add(
            PASS, "error",
            f"memory model divergence: plan_peak_bytes reports "
            f"{est:,} B but independent interval liveness reports "
            f"{ind:,} B — the budget/streaming decisions built on the "
            f"estimator are untrustworthy "
            f"({'under' if est < ind else 'over'}-estimate)",
            node=roots[0], labels=labels,
            hint="one of the two walks no longer models postorder "
                 "evaluation with last-consumer release; diff "
                 "cost.plan_peak_bytes against "
                 "analysis.memory.independent_peak_bytes")
        return diags

    from repro.core.tra import can_fuse
    cache: Dict[int, TypeInfo] = {}
    for r in roots:
        infer(r, cache=cache)
    # fused-away join grids are never materialized — they don't bound the
    # peak (the same fusion rule both liveness walks apply)
    consumers: Dict[int, int] = {}
    seen = set()
    order = []
    for r in roots:
        for n in postorder(r):
            if id(n) in seen:
                continue
            seen.add(id(n))
            order.append(n)
            for c in children(n):
                consumers[id(c)] = consumers.get(id(c), 0) + 1
    fused = set()
    for n in order:
        if (fuse and not isinstance(n, FusedJoinAgg)
                and isinstance(n, TraAgg) and isinstance(n.child, TraJoin)
                and consumers.get(id(n.child), 0) == 1
                and can_fuse(n.child.kernel, n.kernel)):
            fused.add(id(n.child))
    biggest, biggest_node = 0, roots[0]
    for n in order:
        if id(n) in fused:
            continue
        b = cache[id(n)].rtype.nfloats * _itemsize(cache[id(n)].rtype)
        if b > biggest:
            biggest, biggest_node = b, n
    if est < biggest:
        diags.add(
            PASS, "error",
            f"estimated peak ({est:,} B) is below the largest single "
            f"relation in the plan ({biggest:,} B) — that relation alone "
            f"must be resident at its evaluation step",
            node=biggest_node, labels=labels,
            hint="the estimator is dropping a live value")
    roots_bytes = sum(
        cache[id(r)].rtype.nfloats * _itemsize(cache[id(r)].rtype)
        for r in {id(r): r for r in roots}.values())
    if est < roots_bytes:
        diags.add(
            PASS, "error",
            f"estimated peak ({est:,} B) is below the sum of root "
            f"outputs ({roots_bytes:,} B), which are all live at the "
            f"final step (outputs never release)",
            node=roots[0], labels=labels,
            hint="the estimator is releasing a root output")
    return diags


def check_memory_model(ctx) -> None:
    """Pass body: audit the estimator over the plans being compiled."""
    audit_memory_model(ctx.roots, fuse=ctx.fuse, labels=ctx.labels,
                       diags=ctx.diags)
