"""Promoted engine input/configuration validation.

The Engine historically carried a handful of ad-hoc inline checks —
``chunk``/``memory_budget`` range validation at construction,
unexpected/missing-input rejection and the staged executors' masked-input
rejection at dispatch.  Those checks now speak the verifier's diagnostic
vocabulary: each failure is a :class:`~repro.analysis.diagnostics.Diagnostic`
(pass ``"inputs"``, severity ``error``, a fix-it hint) rendered into the
raised exception.

Backward compatibility is deliberate: every constructor here raises the
*same exception type* with the *same leading message text* as the inline
check it replaces (``ValueError("chunk must be >= 1, ...")``,
``ValueError("unexpected inputs: ...")``,
``NotImplementedError("... mask-free ...")``), so existing callers — and
the test suite — matching on type or substring keep working; the uniform
diagnostic rendering is appended after the legacy first line.
"""
from __future__ import annotations

from typing import Sequence, Type

from repro.analysis.diagnostics import Diagnostic

PASS = "inputs"


def _raiseable(exc_type: Type[Exception], message: str, *, hint: str = "",
               where: str = "Engine") -> Exception:
    d = Diagnostic(PASS, "error", message, node_label=where, hint=hint)
    return exc_type(f"{message}\n{d.render()}")


def check_chunk(chunk) -> None:
    """``chunk`` is ``None``, ``"auto"`` or a positive int."""
    if chunk is None:
        return
    if isinstance(chunk, str):
        if chunk != "auto":
            raise _raiseable(
                ValueError,
                f"chunk must be a positive int, None or \"auto\"; "
                f"got {chunk!r}",
                hint="\"auto\" autotunes from the device memory budget",
                where="Engine(chunk=...)")
        return
    if chunk < 1:
        raise _raiseable(
            ValueError, f"chunk must be >= 1, got {chunk}",
            hint="the chunk counts grid slices per streamed reduction "
                 "step; use \"auto\" to autotune it",
            where="Engine(chunk=...)")


def check_memory_budget(budget) -> None:
    """``memory_budget`` is ``None`` or a positive byte count."""
    if budget is not None and budget < 1:
        raise _raiseable(
            ValueError,
            f"memory_budget must be >= 1 byte, got {budget}",
            hint="pass the device live-bytes budget in bytes, or None "
                 "to disable the out-of-core tier",
            where="Engine(memory_budget=...)")


def unexpected_inputs_error(unknown: Sequence[str],
                            expected: Sequence[str]) -> ValueError:
    return _raiseable(
        ValueError,
        f"unexpected inputs: {list(unknown)}; "
        f"expected {sorted(expected)}",
        hint="run() takes exactly the plan's declared TraInput/IAInput "
             "names",
        where="CompiledExpr.run")


def missing_inputs_error(missing: Sequence[str],
                         expected: Sequence[str]) -> ValueError:
    return _raiseable(
        ValueError,
        f"missing inputs: {list(missing)}; "
        f"expected {sorted(expected)}",
        hint="every declared input must be bound by name",
        where="CompiledExpr.run")


def masked_inputs_error(executor: str,
                        holey: Sequence[str]) -> NotImplementedError:
    return _raiseable(
        NotImplementedError,
        f"executor {executor!r} requires continuous (mask-free) input "
        f"relations; inputs {list(holey)} carry masks — run on "
        f"executor=\"reference\", or express the filter inside the plan",
        hint="staged executors rebuild relations from raw arrays, so an "
             "input-side static mask would be silently dropped",
        where="CompiledExpr.run")
