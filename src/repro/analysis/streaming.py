"""Stream-carrier legality pass.

Statically re-runs the out-of-core planner's carrier analysis
(:func:`repro.store.stream._slot_walk`) over a logical root and explains
— with node provenance — why each candidate streamed dimension is
accepted or refused: masked types, in-plan filter/rekey/pad refusals,
the frontier-min rule forcing both join sides to slice, tiled dims,
sliced-and-whole conflicts.

The pass only fires for engines with an out-of-core configuration
(``memory_budget`` set) on a single logical root — exactly the
population :meth:`Engine._streaming_applicable` routes through the
store.  A plan that *fits the budget resident* is fine (info only); an
over-budget plan with no streamable dimension is the error case the pass
exists for: today that surfaces either as a silent resident fallback
that then OOMs, or as a bare ``NotStreamable`` deep in execution — the
diagnostic instead names the first refusing node per candidate dim at
compile time.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostics
from repro.core.cost import plan_peak_bytes
from repro.core.plan import (TraAgg, TraFilter, TraInput, TraJoin, TraNode,
                             TraPad, TraReKey, TypeInfo, infer, postorder)

PASS = "streaming"


def _chunk_feasible(root, sliced, types, nkeys: int, budget: int,
                    fuse: bool) -> Tuple[bool, str]:
    """Mirror ``StreamExecutor._chunk_keys``: does any chunk size fit?"""
    from repro.store.stream import _itemsize, _rebuild
    p1 = plan_peak_bytes(_rebuild(root, sliced, 1), fuse=fuse)
    p2 = plan_peak_bytes(_rebuild(root, sliced, 2), fuse=fuse) \
        if nkeys >= 2 else p1
    slope = max(1, p2 - p1)
    fixed = max(0, p1 - slope)
    prefetch = 0
    for n in postorder(root):
        if isinstance(n, TraInput) and id(n) in sliced:
            ti = types[id(n)]
            prefetch += (ti.rtype.nfloats * _itemsize(ti.rtype)
                         // max(1, ti.rtype.key_shape[sliced[id(n)]]))
    ck = (budget - fixed) // max(1, slope + prefetch)
    if ck < 1:
        return False, (f"even a 1-key chunk exceeds the budget "
                       f"(fixed resident set ~{fixed:,} B + per-key "
                       f"~{slope + prefetch:,} B > {budget:,} B)")
    if ck >= nkeys:
        return False, (f"the non-streamed resident part alone "
                       f"(~{fixed:,} B) is what exceeds the budget — "
                       f"slicing this dim does not help")
    return True, ""


def explain_unstreamable(root: TraNode, *, budget: Optional[int],
                         fuse: bool = True, labels: Optional[Dict] = None,
                         diags: Optional[Diagnostics] = None
                         ) -> Diagnostics:
    """Diagnostics for a plan's streamability under ``budget``.

    Mirrors :meth:`repro.store.stream.StreamExecutor.plan` decision for
    decision, but records *why* instead of just failing: one diagnostic
    per blocking construct (masked types, key rewrites), and one per
    refused candidate dimension carrying the refusing node's provenance.
    No error diagnostics means the plan either fits resident or streams.
    """
    from repro.core.guards import label_nodes
    from repro.core.tra import can_fuse
    from repro.store.autotune import stream_budget_bytes
    from repro.store.stream import _slot_walk
    if labels is None:
        labels = label_nodes((root,))
    if diags is None:
        diags = Diagnostics()
    types: Dict[int, TypeInfo] = {}
    out_info = infer(root, cache=types)
    eff_budget = stream_budget_bytes(budget)
    total = plan_peak_bytes(root, fuse=fuse)
    if total <= eff_budget:
        diags.add(PASS, "info",
                  f"plan fits resident: estimated peak "
                  f"{total:,} B <= budget {eff_budget:,} B",
                  node=root, labels=labels)
        return diags

    # hard blockers: masks / key rewrites anywhere in the plan
    blocked = False
    for n in postorder(root):
        if isinstance(n, (TraFilter, TraPad, TraReKey)):
            blocked = True
            diags.add(
                PASS, "error",
                f"over-budget plan (peak {total:,} B > budget "
                f"{eff_budget:,} B) cannot stream: "
                f"{type(n).__name__} rewrites the key space, so chunk "
                f"concatenation loses continuity",
                node=n, labels=labels,
                hint="run resident (raise memory_budget), or move the "
                     "filter/rekey outside the streamed region")
        elif types[id(n)].mask is not None:
            blocked = True
            diags.add(
                PASS, "error",
                f"over-budget plan cannot stream: node carries a static "
                f"mask ({types[id(n)].valid_tuples} of "
                f"{types[id(n)].rtype.ntuples} keys valid) — chunked "
                f"execution requires continuous relations",
                node=n, labels=labels,
                hint="densify with pad() before the streamed region, or "
                     "run resident")
    if blocked:
        return diags

    # candidate dims, largest-first — the same order plan() tries
    refusals: List[Tuple[int, str, object, str]] = []
    out_ks = out_info.rtype.key_shape
    for d in sorted(range(len(out_ks)), key=lambda dd: -out_ks[dd]):
        if out_ks[d] < 2:
            continue
        rej: list = []
        sliced = _slot_walk(root, root, d, types, reject=rej)
        if sliced is not None:
            ok, why = _chunk_feasible(root, sliced, types, out_ks[d],
                                      eff_budget, fuse)
            if ok:
                diags.add(PASS, "info",
                          f"stream-out over output key dim {d} "
                          f"({out_ks[d]} keys) is legal",
                          node=root, labels=labels)
                return diags
            refusals.append((d, "stream-out", root, why))
            continue
        node, why = rej[0] if rej else (root, "refused")
        refusals.append((d, "stream-out", node, why))
    if isinstance(root, TraAgg) and isinstance(root.child, TraJoin) \
            and root.kernel.is_associative \
            and can_fuse(root.child.kernel, root.kernel):
        j_ks = types[id(root.child)].rtype.key_shape
        red = [d for d in range(len(j_ks)) if d not in root.group_by]
        for d in sorted(red, key=lambda dd: -j_ks[dd]):
            if j_ks[d] < 2:
                continue
            rej = []
            sliced = _slot_walk(root, root.child, d, types, reject=rej)
            if sliced is not None:
                ok, why = _chunk_feasible(root, sliced, types, j_ks[d],
                                          eff_budget, fuse)
                if ok:
                    diags.add(PASS, "info",
                              f"stream-reduce over reduced join dim {d} "
                              f"({j_ks[d]} keys) is legal",
                              node=root, labels=labels)
                    return diags
                refusals.append((d, "stream-reduce", root, why))
                continue
            node, why = rej[0] if rej else (root, "refused")
            refusals.append((d, "stream-reduce", node, why))

    if not refusals:
        diags.add(PASS, "error",
                  f"over-budget plan (peak {total:,} B > budget "
                  f"{eff_budget:,} B) has no key dim with >= 2 keys to "
                  f"stream over",
                  node=root, labels=labels,
                  hint="raise memory_budget or reshape the program "
                       "around a larger key dim")
        return diags
    for d, mode, node, why in refusals:
        diags.add(
            PASS, "error",
            f"over-budget plan (peak {total:,} B > budget "
            f"{eff_budget:,} B): candidate {mode} dim {d} refused — "
            f"{why}",
            node=node, labels=labels,
            hint="every candidate dim is blocked; restructure the plan "
                 "or raise memory_budget (resident fallback may OOM)")
    return diags


def check_streaming(ctx) -> None:
    """Pass body: out-of-core legality for budgeted single-root plans."""
    if ctx.memory_budget is None:
        return
    roots = ctx.logical_roots if ctx.logical_roots is not None \
        else ctx.roots
    if len(roots) != 1 or not isinstance(roots[0], TraNode):
        return                      # multi-root / physical plans run resident
    # provenance over the logical root (ctx.labels covers ctx.roots,
    # which may be the lowered physical plans)
    labels = ctx.labels if id(roots[0]) in ctx.labels else None
    explain_unstreamable(roots[0], budget=ctx.memory_budget,
                         fuse=ctx.fuse, labels=labels, diags=ctx.diags)
