"""Collective-consistency (race) detector.

Symbolically lowers a physical plan's exchanges exactly the way
:mod:`repro.core.shardmap_exec` does — ``Bcast`` → ``all_gather``,
dim-changing ``Shuf`` → ``all_to_all``, pending R2-5 duplicates →
``psum_scatter`` (divisible additive case) or an all-reduce via
``_cross_site_reduce`` — and checks the resulting **ordered collective
schedule** statically:

* every collective's mesh axis must exist in the engine's axis table
  (a nonexistent axis hangs or crashes at trace time today);
* every cross-site reduction's kernel must be *registered and
  associative* and must match the placement's pending ``dup_kernel`` —
  a non-associative reducer silently computes order-dependent (wrong)
  sums on a ring;
* the additive reduce-scatter specialization only fires when the local
  window divides the axis — the pass re-derives that branch so the
  schedule it validates is the one that will actually trace.

Because the lowering is SPMD — one program, data-independent lowering
decisions — every site executes this one schedule by construction;
:func:`check_site_schedules` is the alignment half of the pass for
callers that *do* hold per-site programs (multi-host launchers, planner
v2 candidates): it verifies all sites execute an identical ordered
sequence with matching axes and reducers, the property whose violation
surfaces as a hang (mismatched collective count) or a wrong sum
(mismatched reducer/axis) at run time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.diagnostics import Diagnostics
from repro.core.plan import (Bcast, IANode, Placement, Shuf, TypeInfo,
                             infer, postorder)

PASS = "collectives"

# reducers with a native fused collective (psum / pmax / pmin); every
# other associative kernel lowers to all_gather + local fold
_NATIVE_REDUCERS = (None, "matAdd", "elemMax", "elemMin")


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective in the lowered schedule of a physical plan."""

    kind: str                       # all_gather | all_to_all |
    #                                 psum_scatter | all_reduce
    axis: str
    reducer: Optional[str] = None   # cross-site reduction kernel name
    node_id: int = -1
    node_label: str = ""

    def describe(self) -> str:
        red = f", reducer={self.reducer}" if self.reducer else ""
        return f"{self.kind}(axis={self.axis!r}{red})"

    def matches(self, other: "CollectiveOp") -> bool:
        return (self.kind, self.axis, self.reducer) == \
            (other.kind, other.axis, other.reducer)


def _local_key_shape(ti: TypeInfo, axis_sizes: Dict[str, int]
                     ) -> Tuple[int, ...]:
    """Per-site key window under ``ti.placement`` (shard_map local view)."""
    ks = list(ti.rtype.key_shape)
    p = ti.placement
    if p is not None and p.kind == "partitioned":
        for d, ax in zip(p.dims, p.axes):
            size = axis_sizes.get(ax, 1)
            if size and ks[d] % size == 0:
                ks[d] //= size
    return tuple(ks)


def _reducer_ok(kernel_name: Optional[str], node, labels,
                diags: Diagnostics) -> None:
    if kernel_name in _NATIVE_REDUCERS:
        return
    from repro.core.kernels_registry import get_kernel
    try:
        kern = get_kernel(kernel_name)
    except KeyError:
        diags.add(PASS, "error",
                  f"cross-site reduction names unknown kernel "
                  f"{kernel_name!r}",
                  node=node, labels=labels,
                  hint="register the kernel, or use one of "
                       "matAdd/elemMax/elemMin")
        return
    if not kern.is_associative:
        diags.add(PASS, "error",
                  f"cross-site reduction over non-associative kernel "
                  f"{kernel_name!r} — per-site fold order differs, so "
                  f"sites would disagree on the reduced value (wrong "
                  f"sums, not an error at run time)",
                  node=node, labels=labels,
                  hint="two-phase aggregation requires an associative "
                       "reducer; keep the aggregation single-phase "
                       "(replicated operand) for this kernel")


def _dup_resolution_ops(src: Placement, tgt: Optional[Placement],
                        local_ks: Tuple[int, ...],
                        axis_sizes: Dict[str, int], node, labels,
                        diags: Diagnostics) -> List[CollectiveOp]:
    """Mirror ``shardmap_exec._resolve_dups``'s collective choices."""
    nid, label = labels.get(id(node), (-1, type(node).__name__))
    ops: List[CollectiveOp] = []
    remaining = list(src.dup_axes)
    _reducer_ok(src.dup_kernel, node, labels, diags)
    if tgt is not None and tgt.kind == "partitioned":
        for d, ax in zip(tgt.dims, tgt.axes):
            if ax not in remaining:
                continue
            size = axis_sizes.get(ax, 0)
            if size and local_ks[d] % size == 0 \
                    and src.dup_kernel in (None, "matAdd"):
                ops.append(CollectiveOp("psum_scatter", ax,
                                        src.dup_kernel or "matAdd",
                                        nid, label))
            else:
                ops.append(CollectiveOp("all_reduce", ax,
                                        src.dup_kernel or "matAdd",
                                        nid, label))
            remaining.remove(ax)
    for ax in remaining:
        ops.append(CollectiveOp("all_reduce", ax,
                                src.dup_kernel or "matAdd", nid, label))
    return ops


def collective_schedule(root: IANode, axis_sizes: Dict[str, int],
                        labels: Optional[Dict] = None,
                        diags: Optional[Diagnostics] = None
                        ) -> List[CollectiveOp]:
    """The ordered collective sequence the shard_map lowering emits.

    Walks the plan in evaluation (postorder) order — the same order the
    lowering's memoized recursion visits exchanges — and records each
    communication op with its axis, reducer, and provenance.  Structural
    problems (unknown axes, bad reducers) are reported into ``diags``
    when given.
    """
    from repro.core.guards import label_nodes
    if labels is None:
        labels = label_nodes((root,))
    if diags is None:
        diags = Diagnostics()
    cache: Dict[int, TypeInfo] = {}
    infer(root, cache=cache)
    sched: List[CollectiveOp] = []
    for n in postorder(root):
        if not isinstance(n, (Bcast, Shuf)):
            continue
        nid, label = labels.get(id(n), (-1, type(n).__name__))
        src = cache[id(n.child)].placement
        tgt = cache[id(n)].placement
        if src is None:
            diags.add(PASS, "error",
                      "exchange over an operand whose placement could "
                      "not be derived — the collective's source sharding "
                      "is undefined",
                      node=n, labels=labels,
                      hint="fix the operand subtree (see the placement "
                           "pass diagnostics)")
            continue
        src_eff = src
        if src.dup_axes:
            local_ks = _local_key_shape(cache[id(n.child)], axis_sizes)
            sched.extend(_dup_resolution_ops(
                src, tgt, local_ks, axis_sizes, n, diags=diags,
                labels=labels))
            scattered = []
            if tgt is not None and tgt.kind == "partitioned":
                # only divisible dup axes scatter into place; the rest
                # all-reduce and stay replicated along their axis
                scattered = [(d, ax) for d, ax in zip(tgt.dims, tgt.axes)
                             if ax in src.dup_axes
                             and axis_sizes.get(ax, 0)
                             and local_ks[d] % axis_sizes[ax] == 0]
            src_eff = Placement.partitioned(
                tuple(src.dims) + tuple(d for d, _ in scattered),
                tuple(src.axes) + tuple(ax for _, ax in scattered))
        # the _move phase: per mesh axis, slice / all_gather / all_to_all
        src_map = {ax: d for d, ax in zip(src_eff.dims, src_eff.axes)}
        tgt_map = {} if tgt is None or tgt.kind == "replicated" \
            else {ax: d for d, ax in zip(tgt.dims, tgt.axes)}
        for ax in sorted(set(src_map) | set(tgt_map)):
            if ax not in axis_sizes:
                diags.add(PASS, "error",
                          f"collective over mesh axis {ax!r} which does "
                          f"not exist in the mesh "
                          f"(axes: {sorted(axis_sizes)}) — this hangs or "
                          f"fails at trace time",
                          node=n, labels=labels,
                          hint="use the engine's mesh axis names")
                continue
            od, nd = src_map.get(ax), tgt_map.get(ax)
            if od == nd:
                continue
            if od is None:
                continue            # replicated → sharded: local slice
            if nd is None:
                sched.append(CollectiveOp("all_gather", ax, None,
                                          nid, label))
            else:
                sched.append(CollectiveOp("all_to_all", ax, None,
                                          nid, label))
    # trailing output duplicates resolve at the root (shard_map emits an
    # all-reduce per remaining dup axis before returning)
    rp = cache[id(root)].placement
    if rp is not None and rp.dup_axes:
        rid, rlabel = labels.get(id(root), (-1, type(root).__name__))
        _reducer_ok(rp.dup_kernel, root, labels, diags)
        for ax in rp.dup_axes:
            if ax not in axis_sizes:
                diags.add(PASS, "error",
                          f"output duplicate resolution over mesh axis "
                          f"{ax!r} which does not exist in the mesh",
                          node=root, labels=labels)
                continue
            sched.append(CollectiveOp("all_reduce", ax,
                                      rp.dup_kernel or "matAdd",
                                      rid, rlabel))
    return sched


def check_site_schedules(schedules: Sequence[Sequence[CollectiveOp]],
                         diags: Optional[Diagnostics] = None
                         ) -> Diagnostics:
    """Verify every site executes one identical ordered collective
    sequence.

    ``schedules[i]`` is site *i*'s sequence.  Any divergence — a site
    with more/fewer collectives (a guaranteed hang: the extra collective
    blocks forever), or the same position lowering to different
    kind/axis/reducer (wrong data movement or wrong sums) — becomes an
    error naming the first divergent position and both ops.
    """
    if diags is None:
        diags = Diagnostics()
    if not schedules:
        return diags
    ref = list(schedules[0])
    for site, sched in enumerate(schedules[1:], start=1):
        sched = list(sched)
        if len(sched) != len(ref):
            k = min(len(sched), len(ref))
            extra = ref[k] if len(ref) > k else sched[k]
            diags.add(
                PASS, "error",
                f"site {site} executes {len(sched)} collectives where "
                f"site 0 executes {len(ref)} — the unmatched "
                f"{extra.describe()} at position {k} "
                f"(node {extra.node_label}) blocks forever (hang)",
                hint="every site must run the same program; re-derive "
                     "per-site plans from one logical root")
            continue
        for k, (a, b) in enumerate(zip(ref, sched)):
            if not a.matches(b):
                diags.add(
                    PASS, "error",
                    f"collective schedules diverge at position {k}: "
                    f"site 0 runs {a.describe()} "
                    f"(node {a.node_label}) but site {site} runs "
                    f"{b.describe()} (node {b.node_label}) — mismatched "
                    f"collectives hang or silently corrupt the "
                    f"reduction",
                    hint="align the exchange placement and reducer "
                         "across sites")
                break
    return diags


def check_collectives(ctx) -> None:
    """Pass body: schedule well-formedness + cross-site alignment.

    On the site-ignoring host executors (``reference``/``jit``) no
    collective ever actually runs, so findings are downgraded to
    warnings — the plan would misbehave *if distributed*; on
    ``gspmd``/``shard_map`` they are errors.
    """
    n_sites = 1
    for s in ctx.axis_sizes.values():
        n_sites *= max(1, s)
    distributed = ctx.executor in ("gspmd", "shard_map")
    for root in ctx.roots:
        if not isinstance(root, IANode):
            continue
        diags = ctx.diags if distributed else Diagnostics()
        try:
            sched = collective_schedule(root, ctx.axis_sizes,
                                        labels=ctx.labels, diags=diags)
        except (ValueError, TypeError) as exc:
            diags.add(PASS, "error",
                      f"collective lowering failed: {exc}",
                      node=root, labels=ctx.labels)
            sched = []
        # SPMD: the lowering is site-uniform by construction, so the
        # per-site alignment check is over n_sites copies of the one
        # derived schedule — it guards the invariant the executors rely
        # on, and the same checker validates externally-supplied
        # per-site programs (see check_site_schedules)
        if n_sites > 1 and sched:
            check_site_schedules([sched] * min(n_sites, 16), diags=diags)
        if not distributed:
            ctx.diags.extend(Diagnostics(
                dataclasses.replace(d, severity="warning")
                if d.severity == "error" else d for d in diags))
