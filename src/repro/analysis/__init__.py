"""Static plan verifier & lint framework.

A pass-manager-driven verifier over the TRA logical (``TraNode``) and
physical (``IANode``) IRs, running post-optimization / pre-compile:

* ``placement``   — re-derives placements bottom-up and names the
  missing exchange / duplicate-resolution obligation per violation;
* ``collectives`` — derives the ordered collective schedule the
  shard_map lowering will emit and checks axes, reducers, and cross-site
  alignment (hang / wrong-sum races);
* ``streaming``   — re-checks the out-of-core carrier analysis so
  ``Engine(memory_budget=...)`` rejects unstreamable plans at compile
  time with provenance-bearing refusal reasons;
* ``memory``      — cross-checks ``cost.plan_peak_bytes`` against an
  independent interval-liveness analysis;
* ``cachekey``    — mutation-fuzzes ``plan_sig`` injectivity (lint /
  tests only).

``Engine(validate="off"|"warn"|"strict")`` wires the compile-time set
into every compile; ``python -m repro.analysis.lint`` runs everything
over the program corpus.  All diagnostics address nodes by the same
``nid:Label`` provenance as fault injection and numerics attribution.
"""
from repro.analysis.diagnostics import (Diagnostic, Diagnostics,
                                        PlanVerificationError, SEVERITIES)
from repro.analysis.manager import (ALL_PASSES, DEFAULT_COMPILE_PASSES,
                                    PassManager, VerifyContext, verify_plans)

__all__ = [
    "ALL_PASSES",
    "DEFAULT_COMPILE_PASSES",
    "Diagnostic",
    "Diagnostics",
    "PassManager",
    "PlanVerificationError",
    "SEVERITIES",
    "VerifyContext",
    "verify_plans",
]
