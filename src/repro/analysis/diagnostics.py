"""Diagnostics for the static plan verifier.

Every verifier pass reports through the same small vocabulary: a
:class:`Diagnostic` names the pass that produced it, a severity, the
offending plan node by **provenance** — the ``nid:Label`` addressing of
:func:`repro.core.guards.label_nodes`, i.e. the node's postorder index in
:func:`repro.core.engine.plan_sig` (the same ids the fault injector's
node selectors and ``NumericsError`` attribution use) — a one-line
message, and a fix-it hint.

:class:`Diagnostics` is the ordered collection a
:class:`~repro.analysis.manager.PassManager` run returns;
:class:`PlanVerificationError` (a ``ValueError``, so callers matching the
pre-verifier error class keep working) is what ``Engine(validate="strict")``
raises when any error-severity diagnostic survives.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, Optional, Tuple

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding of one verifier pass, anchored to a plan node."""

    pass_name: str                  # "placement" | "collectives" | ...
    severity: str                   # "error" | "warning" | "info"
    message: str
    node_id: int = -1               # plan_sig postorder id (-1: whole plan)
    node_label: str = ""            # e.g. "7:FusedJoinAgg[matMul→matAdd]"
    hint: str = ""                  # fix-it suggestion

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, "
                f"got {self.severity!r}")

    def render(self) -> str:
        where = f" at node {self.node_label}" if self.node_label else ""
        out = f"[{self.pass_name}] {self.severity}{where}: {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out

    def __str__(self) -> str:
        return self.render()


class Diagnostics:
    """Ordered collection of :class:`Diagnostic` with severity views."""

    def __init__(self, items: Iterable[Diagnostic] = ()) -> None:
        self._items: List[Diagnostic] = list(items)

    # -- construction ------------------------------------------------------
    def add(self, pass_name: str, severity: str, message: str, *,
            node=None, labels=None, hint: str = "") -> Diagnostic:
        """Append a diagnostic, resolving ``node`` provenance via
        ``labels`` (the :func:`repro.core.guards.label_nodes` table)."""
        nid, label = -1, ""
        if node is not None:
            if labels is not None and id(node) in labels:
                nid, label = labels[id(node)]
            else:
                label = type(node).__name__
        d = Diagnostic(pass_name, severity, message, nid, label, hint)
        self._items.append(d)
        return d

    def extend(self, other: "Diagnostics") -> None:
        self._items.extend(other)

    # -- views -------------------------------------------------------------
    def __iter__(self) -> Iterator[Diagnostic]:
        return iter(self._items)

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def __getitem__(self, i):
        return self._items[i]

    @property
    def errors(self) -> Tuple[Diagnostic, ...]:
        return tuple(d for d in self._items if d.severity == "error")

    @property
    def warnings(self) -> Tuple[Diagnostic, ...]:
        return tuple(d for d in self._items if d.severity == "warning")

    def by_pass(self, pass_name: str) -> Tuple[Diagnostic, ...]:
        return tuple(d for d in self._items if d.pass_name == pass_name)

    def render(self, min_severity: str = "info") -> str:
        keep = SEVERITIES[:SEVERITIES.index(min_severity) + 1]
        lines = [d.render() for d in self._items if d.severity in keep]
        if not lines:
            return "no diagnostics"
        counts = ", ".join(
            f"{len([d for d in self._items if d.severity == s])} {s}(s)"
            for s in SEVERITIES
            if any(d.severity == s for d in self._items))
        return "\n".join(lines + [f"-- {counts}"])

    def raise_if_errors(self) -> "Diagnostics":
        if self.errors:
            raise PlanVerificationError(self)
        return self


class PlanVerificationError(ValueError):
    """Static verification rejected the plan (``validate="strict"``).

    Subclasses ``ValueError`` so pre-verifier callers catching the engine's
    historical invalid-plan error class continue to work; carries the full
    :class:`Diagnostics` as ``.diagnostics``.
    """

    def __init__(self, diagnostics: Diagnostics,
                 prefix: Optional[str] = None) -> None:
        self.diagnostics = diagnostics
        head = prefix or (
            f"plan verification failed with "
            f"{len(diagnostics.errors)} error(s)")
        super().__init__(f"{head}\n{diagnostics.render()}")
