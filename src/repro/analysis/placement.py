"""Placement / exchange soundness pass.

Re-derives every physical node's placement bottom-up (the same
:func:`repro.core.plan.infer` rules the runtime checker uses) and turns
each violated precondition into a *diagnosis*: instead of
``check_valid``'s blanket "placement preconditions unsatisfied", the pass
names the offending node, states which operand placements are
incompatible, and says which exchange (``Shuf``/``Bcast``) — or which
duplicate-resolution obligation the shard_map ``_resolve_dups`` path
assumes — is missing.

Checks, per physical root:

* local joins / fused contractions whose operand placements cannot
  combine (mismatched shardings on one mesh axis, or an operand still
  carrying R2-5 partial duplicates);
* full aggregations that reduce away partitioned dims (rule R2-4 —
  needs the two-phase ``partial=True`` + exchange form);
* concats across a partitioned key dim and frontier-growing pads of
  partitioned children;
* roots whose placement still carries ``dup_axes``: on every executor
  but shard_map (which auto-resolves trailing duplicates at the output)
  the partial values would be returned as if final;
* mesh-axis references that don't exist in the engine's axis table, and
  — on shard_map, whose lowering hard-requires it — frontier dims not
  divisible by their mesh axis.

Logical (``TraNode``) roots carry no placements and are skipped.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core.plan import (FusedJoinAgg, IANode, LocalAgg, LocalConcat,
                             LocalJoin, LocalPad, Placement, TypeInfo,
                             _join_types, _local_join_placement, postorder)

PASS = "placement"


def _sharding_table(p: Placement) -> dict:
    if p.is_replicated:
        return {}
    return {ax: d for d, ax in zip(p.dims, p.axes)}


def _join_failure(node, lt: TypeInfo, rt: TypeInfo
                  ) -> Tuple[str, str]:
    """(message, hint) for a join whose placement derivation failed."""
    lp, rp = lt.placement, rt.placement
    if lp is None or rp is None:
        side = "left" if lp is None else "right"
        return (f"the {side} operand's placement could not be derived "
                f"(its own subtree is invalid)",
                "fix the operand subtree first — its diagnostic precedes "
                "this one in postorder")
    for side, p in (("left", lp), ("right", rp)):
        if p.has_duplicates:
            return (f"the {side} operand still carries partial duplicates "
                    f"along mesh axes {list(p.dup_axes)} (pending "
                    f"{p.dup_kernel or 'matAdd'} reduction from a "
                    f"two-phase aggregation); joining partial values is "
                    f"not TRA-equivalent",
                    "resolve the duplicates first: a Shuf lowers to "
                    "reduce-scatter, a Bcast to all-reduce "
                    "(shard_map _resolve_dups)")
    jl, jr = node.join_keys_l, node.join_keys_r
    l_tab, r_tab = _sharding_table(lp), _sharding_table(rp)
    for ax in sorted(set(l_tab) & set(r_tab)):
        dl, dr = l_tab[ax], r_tab[ax]
        pair = dl in jl and jr[jl.index(dl)] == dr
        if not pair:
            return (f"both operands are sharded along mesh axis {ax!r} "
                    f"on non-corresponding key dims (left dim {dl}, "
                    f"right dim {dr}) — the local join would combine "
                    f"unrelated key windows",
                    f"insert a Shuf to re-shard one side so axis {ax!r} "
                    f"lands on a corresponding join-key pair, or Bcast "
                    f"one side")
    return ("two mesh axes shard the same output key dim — the combined "
            "placement is not expressible",
            "re-shard one operand (Shuf) onto a distinct output dim")


def _agg_failure(node, ct: TypeInfo) -> Tuple[str, str]:
    p = ct.placement
    if p is None:
        return ("the operand's placement could not be derived "
                "(its own subtree is invalid)",
                "fix the operand subtree first")
    if p.has_duplicates:
        return (f"aggregating an operand that still carries partial "
                f"duplicates along mesh axes {list(p.dup_axes)}",
                "resolve the pending duplicates with a Shuf "
                "(reduce-scatter) or Bcast (all-reduce) before "
                "aggregating again")
    group_by = tuple(node.group_by)
    partial = getattr(node, "partial", False)
    if partial:
        return ("partial=True but no partitioned dim is reduced away — "
                "nothing is partial about this aggregation",
                "use partial=False (plain local aggregation)")
    reduced = sorted(set(p.dims) - set(group_by))
    return (f"the aggregation reduces away partitioned key dims "
            f"{reduced} (sharded over "
            f"{[ax for d, ax in zip(p.dims, p.axes) if d in reduced]}) — "
            f"each site would return its local partial as if it were the "
            f"full reduction (rule R2-4)",
            "use the two-phase form: partial=True here, then a "
            "Shuf/Bcast to resolve the pending duplicates (R2-5)")


def check_placements(ctx) -> None:
    """Placement-soundness pass body (see module docstring).

    Severity is executor-aware: on the placement-sensitive executors
    (``gspmd``/``shard_map``) a violation executes wrongly or not at all
    — an *error*; on the site-ignoring host executors
    (``reference``/``jit``, which evaluate the dense relations and treat
    placements as annotations) the same plan computes correct values, so
    the violation is reported as a *warning* (the plan is not
    distributable as written — exactly the status of the paper's cost-
    model-only BMM plan variants).
    """
    diags = ctx.diags
    sev = "error" if ctx.executor in ("gspmd", "shard_map") else "warning"
    for root in ctx.roots:
        if not isinstance(root, IANode):
            continue
        try:
            info = ctx.type_of(root)
        except (ValueError, TypeError) as exc:
            diags.add(PASS, "error",
                      f"type inference over the physical plan failed: "
                      f"{exc}", node=root, labels=ctx.labels)
            continue
        for n in postorder(root):
            ti = ctx.types[id(n)]
            if ti.placement is None:
                if isinstance(n, (LocalJoin, FusedJoinAgg)):
                    lt = ctx.types[id(n.left)]
                    rt = ctx.types[id(n.right)]
                    jp = _local_join_placement(n, lt, rt)
                    if isinstance(n, FusedJoinAgg) and jp is not None:
                        # the join half is fine — the fused agg half is
                        # what failed (e.g. R2-4)
                        jt = _join_types(lt, rt, n.join_keys_l,
                                         n.join_keys_r, n.join_kernel)
                        jt.placement = jp
                        msg, hint = _agg_failure(n, jt)
                        diags.add(PASS, sev,
                                  f"fused contraction's aggregation is "
                                  f"not TRA-equivalent: {msg}",
                                  node=n, labels=ctx.labels, hint=hint)
                        continue
                    msg, hint = _join_failure(n, lt, rt)
                    diags.add(PASS, sev,
                              f"local join is not TRA-equivalent: {msg}",
                              node=n, labels=ctx.labels, hint=hint)
                elif isinstance(n, LocalAgg):
                    msg, hint = _agg_failure(n, ctx.types[id(n.child)])
                    diags.add(PASS, sev,
                              f"local aggregation is not TRA-equivalent: "
                              f"{msg}",
                              node=n, labels=ctx.labels, hint=hint)
                elif isinstance(n, LocalConcat):
                    diags.add(
                        PASS, sev,
                        f"concat along key dim {n.key_dim} which is "
                        f"partitioned (or the operand subtree is "
                        f"invalid) — concatenating across sites is not "
                        f"a local op",
                        node=n, labels=ctx.labels,
                        hint="Bcast (or Shuf off the concat dim) before "
                             "the concat")
                elif isinstance(n, LocalPad):
                    diags.add(
                        PASS, sev,
                        "pad grows the key frontier of a partitioned "
                        "relation — per-site key windows would shift",
                        node=n, labels=ctx.labels,
                        hint="Bcast the child first (frontier growth "
                             "needs a replicated operand); zero-filling "
                             "holes alone is local")
            p = ti.placement
            if p is None:
                continue
            for ax in tuple(p.axes) + tuple(p.dup_axes):
                if ax not in ctx.axis_sizes:
                    diags.add(
                        PASS, sev,
                        f"placement references mesh axis {ax!r} which "
                        f"is not in the engine's mesh "
                        f"(axes: {sorted(ctx.axis_sizes)})",
                        node=n, labels=ctx.labels,
                        hint="build the plan against the engine's "
                             "site_axes / mesh axis names")
            if p.kind == "partitioned" and ctx.executor == "shard_map":
                for d, ax in zip(p.dims, p.axes):
                    size = ctx.axis_sizes.get(ax)
                    if size and ti.rtype.key_shape[d] % size:
                        diags.add(
                            PASS, sev,
                            f"frontier dim {d} ({ti.rtype.key_shape[d]}) "
                            f"not divisible by axis {ax!r} ({size}); the "
                            f"shard_map lowering has no uneven-shard "
                            f"support",
                            node=n, labels=ctx.labels,
                            hint="pad the relation to a multiple of the "
                                 "axis size, or run on gspmd")
        rp = _root_placement(info)
        if rp is not None and rp.has_duplicates \
                and ctx.executor != "shard_map":
            diags.add(
                PASS, sev,
                f"the plan result still holds partial duplicates along "
                f"mesh axes {list(rp.dup_axes)} (pending "
                f"{rp.dup_kernel or 'matAdd'}); executor "
                f"{ctx.executor!r} would return per-site partials as if "
                f"final",
                node=root, labels=ctx.labels,
                hint="finish the two-phase aggregation with a Shuf "
                     "(reduce-scatter) or Bcast (all-reduce), or run on "
                     "shard_map which resolves trailing duplicates at "
                     "the output")


def _root_placement(info: TypeInfo) -> Optional[Placement]:
    return info.placement
