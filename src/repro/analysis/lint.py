"""Plan-lint CLI: the full verifier over the program corpus.

``python -m repro.analysis.lint`` runs **all** verifier passes — the
per-compile set plus the cache-key injectivity fuzzer — over the repo's
evaluation programs: the §5.1 matmul plans (logical and all five
hand-compiled physical variants), the §5.2 NN-search program, the §5.3
FFNN step (autodiff and hand-backward) and train step, the serving
scorer's request program, and an out-of-core (budgeted, streamed)
contraction.  It then compiles the §5.3 train step through an
``Engine(validate="strict")`` to prove the integrated compile-time hook
accepts the corpus.

Exit status 0 means zero error-severity diagnostics — the invariant CI
enforces; any error prints with provenance and fails the run.
"""
from __future__ import annotations

import sys
from typing import Callable, Dict, List, Tuple

from repro.analysis.diagnostics import Diagnostics
from repro.analysis.manager import ALL_PASSES, verify_plans

# §5.1 shapes: key grids divisible by the 4-site mesh the physical
# plans are linted against
_MM = ((8, 4), (4, 8), (16, 16), (16, 16))
_SITES = {"sites": 4}


def _corpus() -> List[Tuple[str, Callable[[], Dict]]]:
    """``(name, builder)`` pairs; builders return verify_plans kwargs."""
    from repro.core import programs as prog
    from repro.core.cost import plan_peak_bytes
    from repro.core.plan import as_node

    def mm_logical():
        return {"roots": prog.matmul_tra(*_MM)}

    def physical(builder, executor="shard_map"):
        # the BMM variants are cost-model / host-executor artifacts: the
        # repo's own check_valid rejects them for distributed execution
        # (the contraction dim stays partitioned through the full
        # aggregation), and tests run them on the site-ignoring
        # reference/jit walks — linted as such, where the placement
        # findings are warnings, not errors
        def build():
            return {"roots": builder(*_MM), "executor": executor,
                    "axis_sizes": dict(_SITES)}
        return build

    def nn_search():
        p = prog.nn_search_tra(4, 2, rows=8, dcol=8)
        return {"roots": (p.dist, p.result)}

    def ffnn(step_fn):
        def build():
            p = step_fn(2, 2, 2, 1, 4, 4, 4, 4)
            return {"roots": (p.w1_new, p.w2_new, p.a2)}
        return build

    def train_step():
        step = prog.ffnn_train_step_tra(2, 2, 2, 1, 4, 4, 4, 4)
        return {"roots": tuple(step.roots.values())}

    def serve_scorer():
        from repro.serve.servable import FFNNScorer
        sv = FFNNScorer()
        return {"roots": tuple(sv.program(sv.buckets[0]).values())}

    def streamed_mm():
        root = as_node(prog.matmul_tra((8, 2), (2, 2), (16, 16), (16, 16)))
        budget = int(plan_peak_bytes(root) * 0.6)
        return {"roots": root, "memory_budget": budget}

    return [
        ("sec5.1/matmul-logical", mm_logical),
        ("sec5.1/bmm", physical(prog.bmm_plan, executor="jit")),
        ("sec5.1/cpmm", physical(prog.cpmm_plan)),
        ("sec5.1/cpmm-two-phase", physical(prog.cpmm_two_phase_plan)),
        ("sec5.1/bmm-fused", physical(prog.bmm_fused_plan,
                                      executor="jit")),
        ("sec5.1/cpmm-fused", physical(prog.cpmm_fused_plan)),
        ("sec5.2/nn-search", nn_search),
        ("sec5.3/ffnn-step-autodiff", ffnn(prog.ffnn_step_tra)),
        ("sec5.3/ffnn-step-hand", ffnn(prog.ffnn_step_tra_hand)),
        ("sec5.3/ffnn-train-step", train_step),
        ("serve/ffnn-scorer", serve_scorer),
        ("oocore/streamed-matmul", streamed_mm),
    ]


def lint_corpus(verbose: bool = True) -> Diagnostics:
    """Run every pass over every corpus program; return all diagnostics."""
    all_diags = Diagnostics()
    for name, build in _corpus():
        kwargs = build()
        diags = verify_plans(passes=ALL_PASSES, **kwargs)
        n_err = len(diags.errors)
        if verbose:
            status = f"{n_err} error(s)" if n_err else "clean"
            print(f"  {name:<32} {status}")
            for d in diags:
                if d.severity != "info" or n_err:
                    print(f"    {d.render()}")
        all_diags.extend(diags)
    return all_diags


def lint_engine_integration(verbose: bool = True) -> int:
    """Compile the §5.3 train step under ``validate="strict"``."""
    from repro.analysis.diagnostics import PlanVerificationError
    from repro.core import programs as prog
    from repro.core.engine import Engine
    step = prog.ffnn_train_step_tra(2, 2, 2, 1, 4, 4, 4, 4)
    eng = Engine(executor="jit", validate="strict")
    try:
        eng.compile(step.roots)
    except PlanVerificationError as err:
        if verbose:
            print("  engine/strict-train-step compile REJECTED:")
            print(f"    {err}")
        return 1
    if verbose:
        diags = eng.last_diagnostics
        n = 0 if diags is None else len(diags)
        print(f"  engine/strict-train-step compile accepted "
              f"({n} diagnostic(s))")
    return 0


def main(argv=None) -> int:
    quiet = bool(argv) and "-q" in argv
    if not quiet:
        print("repro.analysis.lint: static verification of the program "
              "corpus")
    diags = lint_corpus(verbose=not quiet)
    rc = lint_engine_integration(verbose=not quiet)
    n_err = len(diags.errors)
    print(f"lint: {len(diags)} diagnostic(s), {n_err} error(s) over "
          f"{len(_corpus())} programs"
          + ("" if rc == 0 else "; strict engine compile FAILED"))
    if n_err:
        for d in diags.errors:
            print(d.render())
    return 1 if (n_err or rc) else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
