"""Gradient compression with error feedback.

``bf16_ef``: gradients are rounded to bf16 before the (XLA-emitted)
cross-replica reduction; the rounding error is carried in a per-leaf f32
residual and added back the next step.  Halves the gradient all-reduce
bytes — the dominant collective of data-parallel training — at ≈0 quality
cost (the error-feedback guarantee).  This is one of the distributed-
optimization extensions recorded in EXPERIMENTS.md §Perf.

Under ``jit`` the compression is expressed as a cast *before* the pmean /
psum-equivalent sharding constraint, so XLA's collective runs on bf16
buffers; the residual state keeps the method exact in expectation.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, residuals) -> Tuple[object, object]:
    """Returns (bf16 grads to feed the reduction, new residuals)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        gc = gf.astype(jnp.bfloat16)
        return gc, gf - gc.astype(jnp.float32)

    pairs = jax.tree.map(one, grads, residuals)
    comp = jax.tree.map(lambda pr: pr[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda pr: pr[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, res


def decompress(grads):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)
