"""AdamW from scratch (decoupled weight decay), pytree-native.

Mixed precision: model params may be bf16; the optimizer keeps float32
master copies plus float32 first/second moments.  Update math runs in f32
and casts back to the param dtype.

State layout (a pytree mirroring params at every leaf):
    {"step": i32 scalar, "master": f32 params, "m": f32, "v": f32}

ZeRO-1: :func:`repro.sharding.zero1_pspecs` shards the master/m/v leaves
over the data axes on top of the parameter sharding — the update is then
computed shard-locally and the fresh params are all-gathered by XLA where
the forward needs them.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0           # global-norm clip; 0 disables
    # gradient compression (see compression.py); "none" | "bf16_ef"
    compression: str = "none"


def init(params) -> Dict:
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), \
        norm


def _decayable(path) -> bool:
    """No weight decay on norms/scales/biases/1-d leaves."""
    last = str(getattr(path[-1], "key", ""))
    return last not in ("scale", "bq", "bk", "bv", "a_log", "dt_bias",
                        "d_skip", "conv_bx", "conv_bbc")


def apply(state: Dict, grads, cfg: AdamWConfig,
          lr_scale: jax.Array | float = 1.0) -> Tuple[Dict, object, Dict]:
    """One AdamW step.  Returns (new_state, new_params, metrics)."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)

    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                         state["v"], grads)

    def upd(path, master, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and _decayable(path):
            delta = delta + cfg.weight_decay * master
        return master - lr * delta

    new_master = jax.tree_util.tree_map_with_path(
        upd, state["master"], new_m, new_v)
    new_state = {"step": step, "master": new_master, "m": new_m,
                 "v": new_v}
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
    return new_state, new_master, metrics


def params_from_state(state: Dict, like) -> object:
    """Cast master params back to the model's compute dtypes."""
    return jax.tree.map(lambda mp, p: mp.astype(p.dtype),
                        state["master"], like)
