"""Optimizer substrate: AdamW, schedules, clipping, compression."""
from repro.optim.adamw import (AdamWConfig, apply, clip_by_global_norm,
                               global_norm, init, params_from_state)
from repro.optim.compression import compress, decompress, init_residuals
from repro.optim.schedule import constant, inverse_sqrt, \
    linear_warmup_cosine

__all__ = ["AdamWConfig", "apply", "clip_by_global_norm", "global_norm",
           "init", "params_from_state", "compress", "decompress",
           "init_residuals", "constant", "inverse_sqrt",
           "linear_warmup_cosine"]
