"""LR schedules as pure ``step -> scale`` functions (scale multiplies the
optimizer's base lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(step):
    return jnp.ones_like(step, jnp.float32)


def linear_warmup_cosine(step, *, warmup: int, total: int,
                         min_ratio: float = 0.1):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    t = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(s < warmup, warm, cos)


def inverse_sqrt(step, *, warmup: int):
    s = jnp.maximum(step.astype(jnp.float32), 1.0)
    return jnp.minimum(s / jnp.maximum(warmup, 1),
                       jnp.sqrt(warmup / s))
