"""gemma2-2b — dense GQA with local+global alternating attention + softcaps.

[arXiv:2408.00118; hf]  26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000.  head_dim=256 (8·256 = 2048 ≠ d_model — gemma2 projects).
Even layers use a 4096-token sliding window; odd layers are global.
Attention logits capped at 50, final logits at 30; post-block RMSNorms.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2_304,
    vocab_size=256_000,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9_216,
    attn_window=4_096,
    local_global_period=2,
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_block_norm=True,
    tie_embeddings=True,
    scale_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma2-smoke", n_layers=2, d_model=64, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, attn_window=8)
