"""deepseek-v2-lite-16b — MLA + fine-grained MoE (shared + routed top-6).

[arXiv:2405.04434; hf]  27L d_model=2048 16H d_ff_expert=1408
vocab=102400, MLA kv_lora=512 (qk_nope=128, qk_rope=64, v_head=128),
2 shared + 64 routed experts top-6; layer 0 stays dense (d_ff=10944).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2_048,
    vocab_size=102_400,
    n_heads=16,
    n_kv_heads=16,              # MLA: every head gets its own up-projection
    head_dim=128,
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    d_ff=10_944,                # dense layer-0 hidden
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1_408,
    first_dense_layers=1,
)

SMOKE = dataclasses.replace(
    CONFIG, name="deepseek-smoke", moe_capacity_factor=8.0, n_layers=2, d_model=64, vocab_size=256,
    n_heads=4, n_kv_heads=4, head_dim=16, kv_lora_rank=32, qk_nope_dim=16,
    qk_rope_dim=8, v_head_dim=16, d_ff=128, n_experts=8, top_k=2,
    d_ff_expert=32, first_dense_layers=1)
