"""minitron-4b — dense GQA transformer (pruned nemotron).

[arXiv:2407.14679; hf]  32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000.  head_dim = 3072/24 = 128.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3_072,
    vocab_size=256_000,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9_216,
)

SMOKE = dataclasses.replace(
    CONFIG, name="minitron-smoke", n_layers=2, d_model=48, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=12, d_ff=96)
