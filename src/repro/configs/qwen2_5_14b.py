"""qwen2.5-14b — dense GQA transformer with QKV bias.

[hf:Qwen/Qwen2.5-0.5B (family); hf]  48L d_model=5120 40H (GQA kv=8)
d_ff=13824 vocab=152064.  head_dim = 5120/40 = 128.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5_120,
    vocab_size=152_064,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13_824,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2.5-smoke", n_layers=2, d_model=64, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128)
