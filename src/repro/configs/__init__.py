"""Architecture registry: ``get_config("qwen2-7b")`` / ``--arch`` flags."""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs.base import (ModelConfig, ShapeSpec, SHAPES, input_specs,
                                supports_shape)

from repro.configs import (deepseek_v2_lite, gemma2_2b, internvl2_2b,
                           llama4_scout_17b, mamba2_130m, minitron_4b,
                           musicgen_large, qwen2_5_14b, qwen2_7b, zamba2_7b)

_MODULES = {
    "mamba2-130m": mamba2_130m,
    "qwen2.5-14b": qwen2_5_14b,
    "qwen2-7b": qwen2_7b,
    "gemma2-2b": gemma2_2b,
    "minitron-4b": minitron_4b,
    "llama4-scout-17b-a16e": llama4_scout_17b,
    "deepseek-v2-lite-16b": deepseek_v2_lite,
    "musicgen-large": musicgen_large,
    "internvl2-2b": internvl2_2b,
    "zamba2-7b": zamba2_7b,
}

CONFIGS: Dict[str, ModelConfig] = {k: m.CONFIG for k, m in _MODULES.items()}
SMOKES: Dict[str, ModelConfig] = {k: m.SMOKE for k, m in _MODULES.items()}


def list_archs() -> List[str]:
    return sorted(CONFIGS)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    table = SMOKES if smoke else CONFIGS
    try:
        return table[arch]
    except KeyError as exc:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}") \
            from exc


def get_shape(name: str) -> ShapeSpec:
    try:
        return SHAPES[name]
    except KeyError as exc:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}") \
            from exc


def all_cells() -> List[Tuple[str, str]]:
    """Every assigned (arch, shape) pair — 40 cells."""
    return [(a, s) for a in list_archs() for s in SHAPES]


__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "CONFIGS", "SMOKES",
           "input_specs", "supports_shape", "list_archs", "get_config",
           "get_shape", "all_cells"]
