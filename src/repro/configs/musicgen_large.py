"""musicgen-large — decoder-only LM over EnCodec tokens (backbone only).

[arXiv:2306.05284; hf]  48L d_model=2048 32H (kv=32, MHA) d_ff=8192
vocab=2048.  The EnCodec frontend is a STUB per assignment:
``input_specs()`` provides precomputed frame embeddings (B, S, d_model);
the backbone is the standard decoder stack with a 2048-way codec head.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2_048,
    vocab_size=2_048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8_192,
    input_mode="embeddings",
)

SMOKE = dataclasses.replace(
    CONFIG, name="musicgen-smoke", n_layers=2, d_model=64, vocab_size=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128)
