"""llama4-scout-17b-a16e — MoE transformer, 16 experts top-1.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]  48L d_model=5120 40H
(GQA kv=8) d_ff=8192 (per expert) vocab=202048, MoE 16e top-1 + 1 shared
expert.  head_dim = 128.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5_120,
    vocab_size=202_048,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8_192,                 # dense-fallback hidden (unused: all MoE)
    n_experts=16,
    n_shared_experts=1,
    top_k=1,
    d_ff_expert=8_192,
    rope_theta=500_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama4-smoke", moe_capacity_factor=8.0, n_layers=2, d_model=64, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, n_experts=4,
    d_ff_expert=128)
