"""internvl2-2b — InternViT + InternLM2 VLM (backbone only).

[arXiv:2404.16821; hf]  24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553.  The InternViT patch frontend is a STUB per assignment:
``input_specs()`` provides precomputed patch/text embeddings
(B, S, d_model); the InternLM2 decoder backbone runs as usual.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2_048,
    vocab_size=92_553,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8_192,
    input_mode="embeddings",
)

SMOKE = dataclasses.replace(
    CONFIG, name="internvl2-smoke", n_layers=2, d_model=64, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128)
