"""zamba2-7b — hybrid Mamba2 stack + shared attention blocks.

[arXiv:2411.15242; unverified]  81L d_model=3584 32H (kv=32) d_ff=14336
vocab=32000, ssm_state=64.  Structure here: 13 groups of 6 Mamba2 layers,
each group followed by one application of a *shared* attention+MLP block
(two alternating shared weight sets, as in the paper) — 78 Mamba layers +
13 shared-block applications ≈ the 81-block stack (the exact interleave
offsets differ from the HF release; see DESIGN.md §Arch-applicability).
d_inner = 7168, ssm head_dim 64 → 112 SSD heads.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=78,                 # mamba layers (13 groups × 6)
    d_model=3_584,
    vocab_size=32_000,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14_336,                 # shared block MLP hidden
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_conv_width=4,
    ssm_chunk=128,
    mamba_per_group=6,
    n_shared_blocks=2,
)

SMOKE = dataclasses.replace(
    CONFIG, name="zamba2-smoke", n_layers=4, d_model=64, vocab_size=128,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, ssm_state=16,
    ssm_head_dim=32, ssm_chunk=16, mamba_per_group=2, n_shared_blocks=2)
