"""qwen2-7b — dense GQA transformer with QKV bias.

[arXiv:2407.10671; hf]  28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064.  head_dim = 3584/28 = 128.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3_584,
    vocab_size=152_064,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2-smoke", n_layers=2, d_model=56, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=14, d_ff=112)
