"""The paper's own §5.3 two-layer FFNN benchmark configs.

Google-speech: D=1600 features, L=10 labels, H ∈ {100k, 150k, 200k},
minibatch N=10^4.  AmazonCat-14k extreme classification: D=597540,
L=14588, H ∈ {1k,3k,5k,7k}, minibatch N=10^3.  These are not decoder LMs;
they drive the TRA-DP vs TRA-MP plan comparison in benchmarks/ffnn.py.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class FFNNConfig:
    name: str
    d_in: int
    d_hidden: int
    d_out: int
    batch: int
    lr: float = 0.01


def speech(hidden: int) -> FFNNConfig:
    return FFNNConfig(f"speech-{hidden // 1000}k", 1_600, hidden, 10, 10_000)


def amazoncat(hidden: int) -> FFNNConfig:
    return FFNNConfig(f"xml-{hidden // 1000}k", 597_540, hidden, 14_588,
                      1_000)


SPEECH_GRID: Tuple[FFNNConfig, ...] = tuple(
    speech(h) for h in (100_000, 150_000, 200_000))
XML_GRID: Tuple[FFNNConfig, ...] = tuple(
    amazoncat(h) for h in (1_000, 3_000, 5_000, 7_000))

SMOKE = FFNNConfig("ffnn-smoke", 32, 64, 8, 16)
