"""mamba2-130m — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  24L d_model=768 d_ff=0 vocab=50280,
ssm_state=128.  d_inner = 2·768 = 1536, head_dim 64 → 24 SSD heads.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    vocab_size=50_280,
    d_ff=0,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_conv_width=4,
    ssm_chunk=128,
    tie_embeddings=True,
    rope_theta=0.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-smoke", n_layers=2, d_model=64, vocab_size=128,
    ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
