"""Architecture + shape configuration system.

Every assigned architecture is a :class:`ModelConfig`; every assigned input
shape is a :class:`ShapeSpec`.  A (config, shape) pair defines one dry-run
cell.  ``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins for
every model input (weak-type-correct, shardable, no device allocation) —
the pattern the multi-pod dry-run lowers against.

Families:
  dense   — GQA transformer (qwen2.5/qwen2/gemma2/minitron)
  moe     — mixture-of-experts transformer (llama4-scout, deepseek-v2-lite)
  ssm     — attention-free Mamba2/SSD stack (mamba2-130m)
  hybrid  — Mamba2 + shared attention blocks (zamba2-7b)
  audio   — decoder-only LM over EnCodec frames (musicgen-large; frontend
            is a stub: inputs are precomputed frame embeddings)
  vlm     — ViT+LM (internvl2-2b; frontend is a stub: inputs are
            precomputed patch/text embeddings)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab_size: int
    # -- attention ---------------------------------------------------------
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_window: int = 0           # sliding-window size (0 = full)
    local_global_period: int = 0   # gemma2: every even layer is windowed
    attn_softcap: float = 0.0      # gemma2 attention-logit soft cap
    logit_softcap: float = 0.0     # gemma2 final-logit soft cap
    post_block_norm: bool = False  # gemma2 post-attn/post-mlp RMSNorms
    # -- MLA (deepseek) ----------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # -- MLP / MoE ---------------------------------------------------------
    d_ff: int = 0                  # dense MLP hidden size
    n_experts: int = 0             # routed experts (0 = dense MLP)
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0    # deepseek: layer 0 stays dense
    moe_capacity_factor: float = 1.25
    # -- SSM (mamba2) ------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    # -- hybrid (zamba2) ---------------------------------------------------
    mamba_per_group: int = 0       # mamba layers between shared-attn blocks
    n_shared_blocks: int = 0       # alternating shared attention weight sets
    # -- io / numerics -----------------------------------------------------
    input_mode: str = "tokens"     # tokens | embeddings
    tie_embeddings: bool = False
    scale_embeddings: bool = False # gemma2: x *= sqrt(d_model)
    rms_eps: float = 1e-5
    dtype: str = "bfloat16"
    kv_cache_dtype: str = ""       # "" = model dtype; "float8_e4m3fn" halves
                                   # decode cache traffic (§Perf, beyond-paper)
    # -- remat policy (perf knob, see EXPERIMENTS.md §Perf) -----------------
    remat: str = "dots_saveable"   # none | full | dots_saveable

    # -- derived -----------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_dim(self) -> int:
        if self.use_mla:
            return self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.n_heads * self.head_dim

    @property
    def has_attention(self) -> bool:
        return self.family not in ("ssm",)

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch decode at 500k context without a quadratic prefill
        or an unbounded per-layer KV cache?  SSM and hybrid families only."""
        return self.family in ("ssm", "hybrid")

    @property
    def n_param_layers(self) -> int:
        """Number of distinct weight-bearing blocks (scan length)."""
        return self.n_layers

    def param_count(self) -> int:
        """Exact parameter count (used for MODEL_FLOPS = 6·N·D)."""
        from repro.models.model import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def supports_shape(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether ``cfg`` runs ``shape``; (False, reason) records the skip."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("pure full-attention arch: quadratic attention and an "
                       "O(S) KV cache at 524k tokens are skipped per "
                       "assignment (see DESIGN.md §Arch-applicability)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                per_pod_batch: Optional[int] = None) -> Dict[str, object]:
    """ShapeDtypeStruct stand-ins for every step input (no allocation).

    For ``train``:   {"tokens"/"embeds", "labels"}
    For ``prefill``: {"tokens"/"embeds"}
    For ``decode``:  {"token"/"embed"} — the KV cache is built separately by
                     :func:`repro.models.model.cache_specs` because its
                     structure is architecture-dependent.
    """
    import jax
    B = per_pod_batch or shape.global_batch
    S = shape.seq_len
    specs: Dict[str, object] = {}
    tok_dtype = jnp.int32
    if shape.kind == "train":
        if cfg.input_mode == "tokens":
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), tok_dtype)
        else:
            specs["embeds"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.bfloat16)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), tok_dtype)
    elif shape.kind == "prefill":
        if cfg.input_mode == "tokens":
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), tok_dtype)
        else:
            specs["embeds"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.bfloat16)
    elif shape.kind == "decode":
        if cfg.input_mode == "tokens":
            specs["token"] = jax.ShapeDtypeStruct((B, 1), tok_dtype)
        else:
            specs["embed"] = jax.ShapeDtypeStruct(
                (B, 1, cfg.d_model), jnp.bfloat16)
    else:
        raise ValueError(shape.kind)
    return specs
