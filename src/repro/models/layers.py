"""Model layers: norms, RoPE, GQA/MLA attention, SwiGLU, MoE, Mamba2.

Conventions
-----------
* Params are nested dicts of jax.Arrays; init functions take a PRNG key and
  return the dict.  Weight dtype is ``cfg.dtype`` except norm scales and SSM
  decay parameters, which stay float32.
* Every layer takes a ``shard`` callable ``(x, *axes) -> x`` that applies a
  ``with_sharding_constraint`` when running under a mesh and is a no-op
  otherwise.  The *axes* follow the TRA planner's decisions (see
  ``repro/sharding/planner.py``) — this is how the paper's cost-model-chosen
  placements reach XLA.
* Attention layers expose both the full-sequence path (training/prefill,
  flash-attention kernel on TPU) and an O(1)-per-token decode path against a
  fixed-capacity KV cache.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention.ops import attention
from repro.kernels.ssd_scan.ops import ssd_decode_step, ssd_scan

Params = Dict[str, object]
Shard = Callable[..., jax.Array]


def no_shard(x: jax.Array, *axes) -> jax.Array:
    return x


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * std).astype(dtype)


# ==========================================================================
# RMSNorm
# ==========================================================================

def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# ==========================================================================
# RoPE
# ==========================================================================

def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x (..., S, D) with positions (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    # broadcast ang across any head dims between batch and S
    while ang.ndim < x.ndim:
        ang = ang[..., None, :, :] if ang.ndim >= 2 else ang
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ==========================================================================
# GQA attention
# ==========================================================================

def gqa_init(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dt),
        "wk": dense_init(ks[1], d, KV * hd, dt),
        "wv": dense_init(ks[2], d, KV * hd, dt),
        "wo": dense_init(ks[3], H * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KV * hd,), dt)
        p["bv"] = jnp.zeros((KV * hd,), dt)
    return p


def _qkv(p: Params, cfg: ModelConfig, x: jax.Array, shard: Shard):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = shard(q.reshape(B, S, H, hd), "data", None, "attn", None)
    k = shard(k.reshape(B, S, KV, hd), "data", None, "kv", None)
    v = shard(v.reshape(B, S, KV, hd), "data", None, "kv", None)
    return q, k, v


def gqa_attention(p: Params, cfg: ModelConfig, x: jax.Array, *,
                  window: int = 0, shard: Shard = no_shard,
                  positions: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence causal attention (training / prefill)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, shard)
    pos = positions if positions is not None else jnp.arange(S)
    q = apply_rope(q.swapaxes(1, 2), pos, cfg.rope_theta)    # (B,H,S,hd)
    k = apply_rope(k.swapaxes(1, 2), pos, cfg.rope_theta)    # (B,KV,S,hd)
    v = v.swapaxes(1, 2)
    o = attention(q, k, v, causal=True, window=window,
                  softcap=cfg.attn_softcap)
    o = o.swapaxes(1, 2).reshape(B, S, cfg.n_heads * cfg.head_dim)
    return shard(o @ p["wo"], "data", None, None)


def gqa_prefill(p: Params, cfg: ModelConfig, x: jax.Array, *,
                window: int = 0, cache_len: int, shard: Shard = no_shard
                ) -> Tuple[jax.Array, Params]:
    """Prefill: returns output and a right-padded KV cache."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, shard)
    pos = jnp.arange(S)
    qr = apply_rope(q.swapaxes(1, 2), pos, cfg.rope_theta)
    kr = apply_rope(k.swapaxes(1, 2), pos, cfg.rope_theta)
    vr = v.swapaxes(1, 2)
    o = attention(qr, kr, vr, causal=True, window=window,
                  softcap=cfg.attn_softcap)
    o = o.swapaxes(1, 2).reshape(B, S, cfg.n_heads * cfg.head_dim)
    pad = cache_len - S
    cdt = jnp.dtype(cfg.kv_cache_dtype or cfg.dtype)
    cache = {
        "k": shard(jnp.pad(kr.swapaxes(1, 2),
                           ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cdt),
                   "data", "seq", "kv", None),
        "v": shard(jnp.pad(vr.swapaxes(1, 2),
                           ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cdt),
                   "data", "seq", "kv", None),
    }
    return shard(o @ p["wo"], "data", None, None), cache


def gqa_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Params,
               pos: jax.Array, *, window: int = 0,
               shard: Shard = no_shard) -> Tuple[jax.Array, Params]:
    """One-token decode against a fixed-capacity cache.

    ``x`` (B, 1, d); ``cache["k"/"v"]`` (B, Smax, KV, hd); ``pos`` scalar —
    the index this token writes at (number of tokens already cached).
    """
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _qkv(p, cfg, x, shard)
    posv = jnp.full((1,), pos, jnp.int32)
    q = apply_rope(q.swapaxes(1, 2), posv, cfg.rope_theta)   # (B,H,1,hd)
    k = apply_rope(k.swapaxes(1, 2), posv, cfg.rope_theta)   # (B,KV,1,hd)
    # one-hot masked write (not dynamic_update_slice): elementwise select
    # keeps a sequence-sharded cache sharded under GSPMD — no gather
    smax = cache["k"].shape[1]
    hot = (jnp.arange(smax) == pos)[None, :, None, None]
    knew = jnp.where(hot, k.swapaxes(1, 2).astype(cache["k"].dtype),
                     cache["k"])
    vnew = jnp.where(hot, v.astype(cache["v"].dtype), cache["v"])
    group = H // KV
    kk = knew.swapaxes(1, 2).astype(jnp.float32)             # (B,KV,Smax,hd)
    vv = vnew.swapaxes(1, 2).astype(jnp.float32)
    qf = q.reshape(B, KV, group, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qf, kk) * (hd ** -0.5)
    if cfg.attn_softcap > 0.0:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    cols = jnp.arange(smax)
    valid = cols <= pos
    if window > 0:
        valid &= cols > pos - window
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", pr, vv).reshape(B, 1, H * hd)
    o = o.astype(x.dtype)
    return shard(o @ p["wo"], "data", None, None), \
        {"k": knew, "v": vnew}


# ==========================================================================
# MLA attention (deepseek-v2)
# ==========================================================================

def mla_init(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    d, H = cfg.d_model, cfg.n_heads
    r, nope, rope, vh = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                         cfg.v_head_dim)
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], d, H * (nope + rope), dt),
        "wdkv": dense_init(ks[1], d, r + rope, dt),     # down-proj + k_rope
        "wuk": dense_init(ks[2], r, H * nope, dt),      # up-proj for K
        "wuv": dense_init(ks[3], r, H * vh, dt),        # up-proj for V
        "wo": dense_init(ks[4], H * vh, d, dt),
        "norm_kv": rmsnorm_init(r),
    }


def _mla_qc(p: Params, cfg: ModelConfig, x: jax.Array, positions,
            shard: Shard):
    """Shared query/compressed-KV computation for prefill and decode."""
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.kv_lora_rank
    q = (x @ p["wq"]).reshape(B, S, H, nope + rope)
    q = shard(q, "data", None, "attn", None)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions,
                        cfg.rope_theta).swapaxes(1, 2)
    dkv = x @ p["wdkv"]                                  # (B,S,r+rope)
    c_kv = rmsnorm(p["norm_kv"], dkv[..., :r], cfg.rms_eps)
    k_rope = apply_rope(dkv[..., None, r:].swapaxes(1, 2), positions,
                        cfg.rope_theta).swapaxes(1, 2)   # (B,S,1,rope)
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(p: Params, cfg: ModelConfig, x: jax.Array, *,
                  shard: Shard = no_shard) -> jax.Array:
    """Training/prefill MLA: decompress K/V and run flash attention."""
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope, vh, r = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                         cfg.kv_lora_rank)
    pos = jnp.arange(S)
    q_nope, q_rope, c_kv, k_rope = _mla_qc(p, cfg, x, pos, shard)
    k_nope = (c_kv @ p["wuk"]).reshape(B, S, H, nope)
    v = (c_kv @ p["wuv"]).reshape(B, S, H, vh)
    v = shard(v, "data", None, "attn", None)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope))], axis=-1)
    scale = (nope + rope) ** -0.5
    o = attention(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
                  causal=True, scale=scale)
    o = o.swapaxes(1, 2).reshape(B, S, H * vh)
    return shard(o @ p["wo"], "data", None, None)


def mla_prefill(p: Params, cfg: ModelConfig, x: jax.Array, *,
                cache_len: int, shard: Shard = no_shard
                ) -> Tuple[jax.Array, Params]:
    B, S, _ = x.shape
    out = mla_attention(p, cfg, x, shard=shard)
    pos = jnp.arange(S)
    _, _, c_kv, k_rope = _mla_qc(p, cfg, x, pos, shard)
    pad = cache_len - S
    cdt = jnp.dtype(cfg.kv_cache_dtype or cfg.dtype)
    cache = {
        "c_kv": shard(jnp.pad(c_kv, ((0, 0), (0, pad),
                                     (0, 0))).astype(cdt),
                      "data", "seq", None),
        "k_rope": shard(jnp.pad(k_rope[:, :, 0, :],
                                ((0, 0), (0, pad), (0, 0))).astype(cdt),
                        "data", "seq", None),
    }
    return out, cache


def mla_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Params,
               pos: jax.Array, *, shard: Shard = no_shard
               ) -> Tuple[jax.Array, Params]:
    """Absorbed MLA decode: attention runs in the compressed space.

    The W_uk projection is absorbed into the query and W_uv into the
    output, so per-step work is O(r) per cached token rather than
    O(H·(nope+vh)) — the memory-bound decode reads only the (r + rope)
    compressed cache.  This is MLA's raison d'être and our decode shapes
    exercise it directly.
    """
    B = x.shape[0]
    H = cfg.n_heads
    nope, rope, vh, r = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                         cfg.kv_lora_rank)
    posv = jnp.full((1,), pos, jnp.int32)
    q_nope, q_rope, c_new, k_rope_new = _mla_qc(p, cfg, x, posv, shard)
    smax = cache["c_kv"].shape[1]
    hot = (jnp.arange(smax) == pos)[None, :, None]
    c_kv = jnp.where(hot, c_new.astype(cache["c_kv"].dtype),
                     cache["c_kv"])
    k_rope = jnp.where(hot, k_rope_new[:, :, 0, :].astype(
        cache["k_rope"].dtype), cache["k_rope"])
    # absorb W_uk into q:  (B,1,H,nope) @ (r,H,nope) -> (B,1,H,r)
    wuk = p["wuk"].reshape(r, H, nope)
    q_abs = jnp.einsum("bthn,rhn->bthr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    s = (jnp.einsum("bthr,bsr->bhts", q_abs, c_kv.astype(jnp.float32))
         + jnp.einsum("bthe,bse->bhts", q_rope.astype(jnp.float32),
                      k_rope.astype(jnp.float32)))
    s = s * ((nope + rope) ** -0.5)
    valid = jnp.arange(smax) <= pos
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhts,bsr->bthr", pr, c_kv.astype(jnp.float32))
    wuv = p["wuv"].reshape(r, H, vh)
    o = jnp.einsum("bthr,rhv->bthv", o_c, wuv.astype(jnp.float32))
    o = o.reshape(B, 1, H * vh).astype(x.dtype)
    return shard(o @ p["wo"], "data", None, None), \
        {"c_kv": c_kv, "k_rope": k_rope}


# ==========================================================================
# SwiGLU MLP
# ==========================================================================

def mlp_init(key, d: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], d, d_ff, dtype),       # up
        "wg": dense_init(ks[1], d, d_ff, dtype),       # gate
        "wo": dense_init(ks[2], d_ff, d, dtype),       # down
    }


def mlp(p: Params, x: jax.Array, shard: Shard = no_shard) -> jax.Array:
    h = shard(jax.nn.silu(x @ p["wg"]) * (x @ p["wi"]),
              "data", None, "ffn")
    return shard(h @ p["wo"], "data", None, None)


# ==========================================================================
# Mixture of Experts
# ==========================================================================

def moe_init(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "wi": (jax.random.normal(ks[1], (E, d, ff), jnp.float32)
               * std).astype(dt),
        "wg": (jax.random.normal(ks[2], (E, d, ff), jnp.float32)
               * std).astype(dt),
        "wo": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
               / math.sqrt(ff)).astype(dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d,
                               cfg.d_ff_expert * cfg.n_shared_experts, dt)
    return p


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def moe(p: Params, cfg: ModelConfig, x: jax.Array,
        shard: Shard = no_shard) -> jax.Array:
    """Grouped local dispatch → batched expert SwiGLU → weighted combine.

    Tokens are dispatched *within per-data-shard groups* (the standard
    TPU SPMD MoE layout): each group sorts only its own tokens into an
    (E, C_g, d) buffer, so every dispatch op is elementwise in the group
    dim and shards trivially under GSPMD — the only cross-device traffic
    is the intended expert all-to-all when experts are ``PART_expert``
    over the model axis (the TRA ``SHUF`` on the expert key dim).
    Capacity is ``top_k·T_g/E × capacity_factor`` per group; overflow
    tokens fall back to the shared-expert / residual path.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    G = getattr(shard, "data_size", 1)
    if G <= 1 or T % G:
        G = 1
    Tg = T // G
    xt = shard(x.reshape(G, Tg, d), "data", None, None)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"])                          # (G, Tg, E)
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), K)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    fe = idx.reshape(G, Tg * K)                               # expert ids
    ft = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg), K)[None], (G, Tg * K))     # token rows
    fg = gates.reshape(G, Tg * K)

    cap = _round_up(max(int(Tg * K / E * cfg.moe_capacity_factor), 1), 8)
    order = jnp.argsort(fe, axis=1)
    se = jnp.take_along_axis(fe, order, axis=1)
    st = jnp.take_along_axis(ft, order, axis=1)
    sg = jnp.take_along_axis(fg, order, axis=1)
    first = jax.vmap(lambda a: jnp.searchsorted(a, a, side="left"))(se)
    pos_in_e = jnp.arange(Tg * K)[None] - first
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, E * cap)      # overflow

    xg = jnp.take_along_axis(xt, st[..., None], axis=1)       # (G,TgK,d)

    def scatter(buf, sl, rows):
        return buf.at[sl].set(rows, mode="drop")

    buf = jnp.zeros((G, E * cap + 1, d), x.dtype)
    buf = jax.vmap(scatter)(buf, slot, xg)[:, :-1]
    buf = shard(buf.reshape(G, E, cap, d), "data", "expert", None, None)

    h = jnp.einsum("gecd,edf->gecf", buf, p["wg"])
    u = jnp.einsum("gecd,edf->gecf", buf, p["wi"])
    h = shard(jax.nn.silu(h) * u, "data", "expert", None, "ffn")
    eo = jnp.einsum("gecf,efd->gecd", h, p["wo"])             # (G,E,cap,d)
    eo = shard(eo, "data", "expert", None, None)
    eo = eo.reshape(G, E * cap, d)

    back = jnp.take_along_axis(
        eo, jnp.where(keep, se * cap + pos_in_e, 0)[..., None], axis=1)
    contrib = back * (sg * keep)[..., None].astype(x.dtype)

    def combine(o, rows, c):
        return o.at[rows].add(c)

    out = jax.vmap(combine)(jnp.zeros((G, Tg, d), x.dtype), st, contrib)
    out = shard(out, "data", None, None)
    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xt.reshape(G * Tg, d), shard=no_shard
                        ).reshape(G, Tg, d)
    return shard(out.reshape(B, S, d), "data", None, None)


def moe_aux_loss(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Load-balancing auxiliary loss (switch-style)."""
    T = x.shape[0] * x.shape[1]
    logits = (x.reshape(T, -1).astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * imp)


# ==========================================================================
# Mamba2 block
# ==========================================================================

def mamba2_init(key, cfg: ModelConfig) -> Params:
    """Projections are stored *split* (z / x / BC / dt) rather than as one
    fused in-projection: each part then has a clean tensor-parallel axis
    (z, x, dt shard over SSD heads; the small B/C projections replicate),
    which the TRA planner can assign independently."""
    dt = _dtype(cfg)
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 8)
    return {
        "w_z": dense_init(ks[0], d, di, dt),
        "w_x": dense_init(ks[1], d, di, dt),
        "w_bc": dense_init(ks[2], d, 2 * g * n, dt),
        "w_dt": dense_init(ks[3], d, h, dt),
        "conv_wx": (jax.random.normal(ks[4], (cfg.ssm_conv_width, di),
                                      jnp.float32)
                    / math.sqrt(cfg.ssm_conv_width)).astype(dt),
        "conv_bx": jnp.zeros((di,), dt),
        "conv_wbc": (jax.random.normal(ks[5], (cfg.ssm_conv_width, 2 * g * n),
                                       jnp.float32)
                     / math.sqrt(cfg.ssm_conv_width)).astype(dt),
        "conv_bbc": jnp.zeros((2 * g * n,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": rmsnorm_init(di),
        "w_out": dense_init(ks[6], di, d, dt),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with taps (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(W):
        out = out + pad[:, i:i + xbc.shape[1], :].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(xbc.dtype)


def _mamba_proj(p: Params, cfg: ModelConfig, x: jax.Array, shard: Shard):
    z = shard(x @ p["w_z"], "data", None, "ssm")
    xr = shard(x @ p["w_x"], "data", None, "ssm")
    bc = x @ p["w_bc"]
    dtr = x @ p["w_dt"]
    return z, xr, bc, dtr


def mamba2_forward(p: Params, cfg: ModelConfig, x: jax.Array,
                   shard: Shard = no_shard) -> jax.Array:
    B, S, _ = x.shape
    di, g, n, h, hp = (cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                       cfg.ssm_heads, cfg.ssm_head_dim)
    z, xr, bc, dtr = _mamba_proj(p, cfg, x, shard)
    xc = jax.nn.silu(_causal_conv(xr, p["conv_wx"], p["conv_bx"]))
    bcc = jax.nn.silu(_causal_conv(bc, p["conv_wbc"], p["conv_bbc"]))
    xs = xc.reshape(B, S, h, hp)
    Bm, Cm = bcc[..., :g * n], bcc[..., g * n:]
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    y = ssd_scan(xs, dt, A, Bm, Cm, chunk=min(cfg.ssm_chunk, S))
    y = y + xs * p["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.rms_eps)
    return shard(y @ p["w_out"], "data", None, None)


def mamba2_prefill(p: Params, cfg: ModelConfig, x: jax.Array,
                   shard: Shard = no_shard) -> Tuple[jax.Array, Params]:
    """Full-sequence forward that also returns the decode cache."""
    from repro.kernels.ssd_scan.ops import ssd_final_state
    B, S, _ = x.shape
    di, g, n, h, hp = (cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                       cfg.ssm_heads, cfg.ssm_head_dim)
    z, xr, bc, dtr = _mamba_proj(p, cfg, x, shard)
    xc = jax.nn.silu(_causal_conv(xr, p["conv_wx"], p["conv_bx"]))
    bcc = jax.nn.silu(_causal_conv(bc, p["conv_wbc"], p["conv_bbc"]))
    xs = xc.reshape(B, S, h, hp)
    Bm, Cm = bcc[..., :g * n], bcc[..., g * n:]
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    y = ssd_scan(xs, dt, A, Bm, Cm, chunk=min(cfg.ssm_chunk, S))
    hfin = ssd_final_state(xs, dt, A, Bm, Cm)
    y = y + xs * p["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.rms_eps)
    W = cfg.ssm_conv_width
    cache = {"conv_x": shard(xr[:, S - (W - 1):, :], "data", None, "ssm"),
             "conv_bc": bc[:, S - (W - 1):, :],
             "ssm": shard(hfin, "data", "ssm", None, None)}
    return shard(y @ p["w_out"], "data", None, None), cache


def mamba2_init_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    return {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv_width - 1, cfg.d_inner),
                            dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv_width - 1,
                              2 * cfg.ssm_ngroups * cfg.ssm_state), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                          cfg.ssm_head_dim), jnp.float32),
    }


def mamba2_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Params,
                  shard: Shard = no_shard) -> Tuple[jax.Array, Params]:
    """O(1) decode step: x (B, 1, d)."""
    B = x.shape[0]
    di, g, n, h, hp = (cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                       cfg.ssm_heads, cfg.ssm_head_dim)
    z, xr, bc, dtr = _mamba_proj(p, cfg, x, shard)
    hist_x = jnp.concatenate([cache["conv_x"], xr], axis=1)   # (B, W, di)
    hist_bc = jnp.concatenate([cache["conv_bc"], bc], axis=1)
    conv_x = jnp.einsum("bwc,wc->bc", hist_x.astype(jnp.float32),
                        p["conv_wx"].astype(jnp.float32)) \
        + p["conv_bx"].astype(jnp.float32)
    conv_bc = jnp.einsum("bwc,wc->bc", hist_bc.astype(jnp.float32),
                         p["conv_wbc"].astype(jnp.float32)) \
        + p["conv_bbc"].astype(jnp.float32)
    xt = jax.nn.silu(conv_x).astype(x.dtype).reshape(B, h, hp)
    bcc = jax.nn.silu(conv_bc).astype(x.dtype)
    Bt, Ct = bcc[:, :g * n], bcc[:, g * n:]
    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    y, hnew = ssd_decode_step(cache["ssm"], xt, dt, A, Bt, Ct)
    y = y + xt * p["d_skip"][None, :, None].astype(xt.dtype)
    y = y.reshape(B, 1, di) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.rms_eps)
    new_cache = {"conv_x": hist_x[:, 1:, :], "conv_bc": hist_bc[:, 1:, :],
                 "ssm": hnew}
    return shard(y @ p["w_out"], "data", None, None), new_cache
