"""Unified decoder LM covering all assigned architecture families.

One model definition, configured by :class:`repro.configs.base.ModelConfig`:

* **dense / audio / vlm** — scan over attention+SwiGLU blocks.  gemma2's
  local/global alternation is handled by scanning over *groups* of
  ``local_global_period`` layers so window sizes stay static.
* **moe** — optional leading dense blocks (deepseek layer 0), then a scan
  over MoE blocks.
* **ssm** — scan over Mamba2 blocks.
* **hybrid** (zamba2) — scan over groups of ``mamba_per_group`` Mamba2
  layers, each group followed by one application of a *shared* attention
  block (alternating among ``n_shared_blocks`` weight sets).

Three entry points per architecture, all pure functions of (params, inputs):

* :func:`forward`      — training / scoring (full sequence → logits)
* :func:`prefill`      — full sequence → (last-position logits, KV cache)
* :func:`decode_step`  — one token + cache → (logits, cache)

Layers are scanned (``jax.lax.scan``) so the lowered HLO is O(1) in depth —
essential for the 512-device multi-pod dry-run — with a configurable remat
policy applied to the scan body.
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.layers import Params, Shard, no_shard

# ==========================================================================
# Block = (attention | mamba) + (mlp | moe), pre-norm residual
# ==========================================================================


def _attn_block_init(key, cfg: ModelConfig, *, use_moe: bool,
                     d_ff: Optional[int] = None) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {"ln1": L.rmsnorm_init(cfg.d_model),
                 "ln2": L.rmsnorm_init(cfg.d_model)}
    p["attn"] = L.mla_init(k1, cfg) if cfg.use_mla else L.gqa_init(k1, cfg)
    if use_moe:
        p["moe"] = L.moe_init(k2, cfg)
    else:
        p["mlp"] = L.mlp_init(k2, cfg.d_model, d_ff or cfg.d_ff,
                              jnp.dtype(cfg.dtype))
    if cfg.post_block_norm:
        p["post_ln1"] = L.rmsnorm_init(cfg.d_model)
        p["post_ln2"] = L.rmsnorm_init(cfg.d_model)
    return p


def _attn_block(p: Params, cfg: ModelConfig, x, *, window: int,
                shard: Shard, mode: str, cache=None, pos=None):
    """mode ∈ {train, prefill, decode}; returns (x, new_cache_or_None)."""
    h = L.rmsnorm(p["ln1"], x, cfg.rms_eps)
    new_cache = None
    if cfg.use_mla:
        if mode == "train":
            a = L.mla_attention(p["attn"], cfg, h, shard=shard)
        elif mode == "prefill":
            a, new_cache = L.mla_prefill(p["attn"], cfg, h,
                                         cache_len=cache, shard=shard)
        else:
            a, new_cache = L.mla_decode(p["attn"], cfg, h, cache, pos,
                                        shard=shard)
    else:
        if mode == "train":
            a = L.gqa_attention(p["attn"], cfg, h, window=window,
                                shard=shard)
        elif mode == "prefill":
            a, new_cache = L.gqa_prefill(p["attn"], cfg, h, window=window,
                                         cache_len=cache, shard=shard)
        else:
            a, new_cache = L.gqa_decode(p["attn"], cfg, h, cache, pos,
                                        window=window, shard=shard)
    if cfg.post_block_norm:
        a = L.rmsnorm(p["post_ln1"], a, cfg.rms_eps)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.rms_eps)
    m = L.moe(p["moe"], cfg, h, shard) if "moe" in p \
        else L.mlp(p["mlp"], h, shard)
    if cfg.post_block_norm:
        m = L.rmsnorm(p["post_ln2"], m, cfg.rms_eps)
    return x + m, new_cache


def _mamba_block_init(key, cfg: ModelConfig) -> Params:
    return {"ln": L.rmsnorm_init(cfg.d_model),
            "mix": L.mamba2_init(key, cfg)}


def _mamba_block(p: Params, cfg: ModelConfig, x, *, shard: Shard,
                 mode: str, cache=None):
    h = L.rmsnorm(p["ln"], x, cfg.rms_eps)
    if mode == "train":
        return x + L.mamba2_forward(p["mix"], cfg, h, shard), None
    if mode == "prefill":
        y, c = L.mamba2_prefill(p["mix"], cfg, h, shard)
        return x + y, c
    y, c = L.mamba2_decode(p["mix"], cfg, h, cache, shard)
    return x + y, c


# ==========================================================================
# Group structure (what one scan step covers)
# ==========================================================================

def group_size(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.mamba_per_group
    if cfg.local_global_period:
        return cfg.local_global_period
    return 1


def n_scan_groups(cfg: ModelConfig) -> int:
    n = cfg.n_layers - cfg.first_dense_layers
    g = group_size(cfg)
    if n % g:
        raise ValueError(f"{cfg.name}: {n} layers not divisible by "
                         f"group size {g}")
    return n // g


def _window_for(cfg: ModelConfig, idx_in_group: int) -> int:
    """Static sliding-window size for sub-layer ``idx_in_group``."""
    if cfg.local_global_period and idx_in_group % 2 == 0:
        return cfg.attn_window
    return cfg.attn_window if not cfg.local_global_period else 0


def _group_init(key, cfg: ModelConfig) -> Params:
    """Init one scan group (stacked over the in-group sub-layers)."""
    g = group_size(cfg)
    keys = jax.random.split(key, g)
    if cfg.family in ("dense", "audio", "vlm"):
        blocks = [_attn_block_init(k, cfg, use_moe=False) for k in keys]
    elif cfg.family == "moe":
        blocks = [_attn_block_init(k, cfg, use_moe=cfg.n_experts > 0)
                  for k in keys]
    elif cfg.family in ("ssm", "hybrid"):
        blocks = [_mamba_block_init(k, cfg) for k in keys]
    else:
        raise ValueError(cfg.family)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


# ==========================================================================
# init / count
# ==========================================================================

def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    p: Params = {}
    if cfg.input_mode == "tokens":
        p["embed"] = {"w": (jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), jnp.float32)
            * (cfg.d_model ** -0.5)).astype(dt)}
    # leading dense blocks (deepseek layer 0)
    if cfg.first_dense_layers:
        dks = jax.random.split(keys[1], cfg.first_dense_layers)
        p["dense0"] = [_attn_block_init(k, cfg, use_moe=False) for k in dks]
    # scanned groups
    G = n_scan_groups(cfg)
    gks = jax.random.split(keys[2], G)
    groups = [_group_init(k, cfg) for k in gks]
    p["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    # hybrid shared attention blocks
    if cfg.family == "hybrid":
        sks = jax.random.split(keys[3], cfg.n_shared_blocks)
        shared = [_attn_block_init(k, cfg, use_moe=False) for k in sks]
        p["shared"] = jax.tree.map(lambda *xs: jnp.stack(xs), *shared)
    p["final_norm"] = L.rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings and cfg.input_mode == "tokens":
        p["lm_head"] = {"w": L.dense_init(keys[4], cfg.d_model,
                                          cfg.vocab_size, dt)}
    elif cfg.input_mode == "embeddings":
        p["lm_head"] = {"w": L.dense_init(keys[4], cfg.d_model,
                                          cfg.vocab_size, dt)}
    return p


def param_shapes(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct tree of the params — no allocation (dry-run)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = param_shapes(cfg)
    total = 0
    routed = 0

    def visit(path, leaf):
        nonlocal total, routed
        n = math.prod(leaf.shape)
        total += n
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        if "moe" in keys and any(k in ("wi", "wg", "wo") for k in keys):
            routed += n

    jax.tree_util.tree_map_with_path(visit, shapes)
    if active_only and cfg.n_experts:
        total -= routed
        total += routed * cfg.top_k // cfg.n_experts
    return total


# ==========================================================================
# forward / loss
# ==========================================================================

def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots_saveable":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(cfg.remat)


def embed_in(cfg: ModelConfig, params: Params, batch: Dict,
             shard: Shard) -> jax.Array:
    if cfg.input_mode == "tokens":
        tok = batch.get("tokens", batch.get("token"))
        x = params["embed"]["w"][tok]
    else:
        x = batch.get("embeds", batch.get("embed"))
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return shard(x.astype(jnp.dtype(cfg.dtype)), "data", None, None)


def unembed(cfg: ModelConfig, params: Params, x: jax.Array,
            shard: Shard) -> jax.Array:
    x = L.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        w = params["embed"]["w"].T
    else:
        w = params["lm_head"]["w"]
    logits = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if cfg.logit_softcap > 0.0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return shard(logits, "data", None, "vocab")


def _select_shared(params: Params, gi, n_shared: int) -> Params:
    return jax.tree.map(lambda l: l[gi % n_shared], params["shared"])


def forward(cfg: ModelConfig, params: Params, batch: Dict,
            shard: Shard = no_shard) -> jax.Array:
    """Full-sequence forward → logits (B, S, vocab) in f32."""
    x = embed_in(cfg, params, batch, shard)
    gsz = group_size(cfg)

    for blk in params.get("dense0", []):
        x, _ = _attn_block(blk, cfg, x, window=0, shard=shard, mode="train")

    def body(x, xs):
        gp, gi = xs
        for i in range(gsz):
            sub = jax.tree.map(lambda l, i=i: l[i], gp)
            if cfg.family in ("ssm", "hybrid"):
                x, _ = _mamba_block(sub, cfg, x, shard=shard, mode="train")
            else:
                x, _ = _attn_block(sub, cfg, x, window=_window_for(cfg, i),
                                   shard=shard, mode="train")
        if cfg.family == "hybrid":
            sp = _select_shared(params, gi, cfg.n_shared_blocks)
            x, _ = _attn_block(sp, cfg, x, window=0, shard=shard,
                               mode="train")
        return x, None

    G = n_scan_groups(cfg)
    x, _ = jax.lax.scan(_remat(cfg, body), x,
                        (params["blocks"], jnp.arange(G)))
    return unembed(cfg, params, x, shard)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict,
            shard: Shard = no_shard) -> Tuple[jax.Array, Dict]:
    logits = forward(cfg, params, batch, shard)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    nll = (logz - gold).mean()
    # z-loss keeps the softmax normalizer bounded (bf16 stability)
    zloss = 1e-4 * jnp.square(logz).mean()
    metrics = {"nll": nll, "zloss": zloss,
               "accuracy": (logits.argmax(-1) == labels).mean()}
    return nll + zloss, metrics


# ==========================================================================
# prefill / decode (serving)
# ==========================================================================

def cache_spec(cfg: ModelConfig, batch: int, cache_len: int):
    """ShapeDtypeStruct tree of the decode cache (dry-run stand-in)."""
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, cache_len))


def _empty_attn_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      dt) -> Params:
    if cfg.use_mla:
        return {
            "c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dt),
            "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dt),
        }
    return {
        "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.head_dim), dt),
    }


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> Params:
    """Fixed-capacity decode cache, all-zero, position 0."""
    dt = jnp.dtype(cfg.kv_cache_dtype or cfg.dtype)
    gsz = group_size(cfg)
    G = n_scan_groups(cfg)

    def one_group():
        if cfg.family in ("ssm", "hybrid"):
            sub = [L.mamba2_init_cache(cfg, batch, dt) for _ in range(gsz)]
        else:
            sub = [_empty_attn_cache(cfg, batch, cache_len, dt)
                   for _ in range(gsz)]
        g = jax.tree.map(lambda *xs: jnp.stack(xs), *sub)
        if cfg.family == "hybrid":
            g = {"mamba": g,
                 "attn": _empty_attn_cache(cfg, batch, cache_len, dt)}
        return g

    groups = [one_group() for _ in range(G)]
    cache: Params = {
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *groups),
        "pos": jnp.zeros((), jnp.int32),
    }
    if cfg.first_dense_layers:
        cache["dense0"] = [
            _empty_attn_cache(cfg, batch, cache_len, dt)
            for _ in range(cfg.first_dense_layers)]
    return cache


def prefill(cfg: ModelConfig, params: Params, batch: Dict, cache_len: int,
            shard: Shard = no_shard) -> Tuple[jax.Array, Params]:
    """Full-sequence prefill → (last-position logits, primed cache)."""
    x = embed_in(cfg, params, batch, shard)
    S = x.shape[1]
    gsz = group_size(cfg)
    cache: Params = {"pos": jnp.asarray(S, jnp.int32)}

    d0 = []
    for blk in params.get("dense0", []):
        x, c = _attn_block(blk, cfg, x, window=0, shard=shard,
                           mode="prefill", cache=cache_len)
        d0.append(c)
    if d0:
        cache["dense0"] = d0

    def body(x, xs):
        gp, gi = xs
        subcaches = []
        for i in range(gsz):
            sub = jax.tree.map(lambda l, i=i: l[i], gp)
            if cfg.family in ("ssm", "hybrid"):
                x, c = _mamba_block(sub, cfg, x, shard=shard, mode="prefill")
            else:
                x, c = _attn_block(sub, cfg, x, window=_window_for(cfg, i),
                                   shard=shard, mode="prefill",
                                   cache=cache_len)
            subcaches.append(c)
        g = jax.tree.map(lambda *cs: jnp.stack(cs), *subcaches)
        if cfg.family == "hybrid":
            sp = _select_shared(params, gi, cfg.n_shared_blocks)
            x, ac = _attn_block(sp, cfg, x, window=0, shard=shard,
                                mode="prefill", cache=cache_len)
            g = {"mamba": g, "attn": ac}
        return x, g

    G = n_scan_groups(cfg)
    x, gcaches = jax.lax.scan(_remat(cfg, body), x,
                              (params["blocks"], jnp.arange(G)))
    cache["blocks"] = gcaches
    logits = unembed(cfg, params, x[:, -1:, :], shard)
    return logits, cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                batch: Dict, shard: Shard = no_shard
                ) -> Tuple[jax.Array, Params]:
    """One decode step: batch holds "token" (B,1) or "embed" (B,1,d)."""
    x = embed_in(cfg, params, batch, shard)
    pos = cache["pos"]
    gsz = group_size(cfg)
    new_cache: Params = {"pos": pos + 1}

    if "dense0" in cache:
        nd0 = []
        for blk, c in zip(params["dense0"], cache["dense0"]):
            x, nc = _attn_block(blk, cfg, x, window=0, shard=shard,
                                mode="decode", cache=c, pos=pos)
            nd0.append(nc)
        new_cache["dense0"] = nd0

    def body(x, xs):
        gp, gc, gi = xs
        subcaches = []
        for i in range(gsz):
            sub = jax.tree.map(lambda l, i=i: l[i], gp)
            if cfg.family in ("ssm", "hybrid"):
                mc = gc["mamba"] if cfg.family == "hybrid" else gc
                subc = jax.tree.map(lambda l, i=i: l[i], mc)
                x, c = _mamba_block(sub, cfg, x, shard=shard, mode="decode",
                                    cache=subc)
            else:
                subc = jax.tree.map(lambda l, i=i: l[i], gc)
                x, c = _attn_block(sub, cfg, x, window=_window_for(cfg, i),
                                   shard=shard, mode="decode", cache=subc,
                                   pos=pos)
            subcaches.append(c)
        g = jax.tree.map(lambda *cs: jnp.stack(cs), *subcaches)
        if cfg.family == "hybrid":
            sp = _select_shared(params, gi, cfg.n_shared_blocks)
            x, ac = _attn_block(sp, cfg, x, window=0, shard=shard,
                                mode="decode", cache=gc["attn"], pos=pos)
            g = {"mamba": g, "attn": ac}
        return x, g

    G = n_scan_groups(cfg)
    x, gcaches = jax.lax.scan(body, x, (params["blocks"], cache["blocks"],
                                        jnp.arange(G)))
    new_cache["blocks"] = gcaches
    logits = unembed(cfg, params, x, shard)
    return logits, new_cache
