"""Composable model definitions for all assigned architectures."""
from repro.models.model import (cache_spec, count_params, decode_step,
                                forward, init_cache, init_params, loss_fn,
                                param_shapes, prefill)

__all__ = ["cache_spec", "count_params", "decode_step", "forward",
           "init_cache", "init_params", "loss_fn", "param_shapes",
           "prefill"]
