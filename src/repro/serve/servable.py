"""Servables: model programs a :class:`~repro.serve.server.TraServer` holds.

A *servable* is the serving-side analogue of a train-step builder
(:mod:`repro.core.train`): it owns the model weights as relations and
emits the lazy :class:`~repro.core.expr.Expr` programs the server compiles
once per shape and dispatches forever.  Two shapes of servable exist:

* :class:`BatchServable` — stateless request/response scoring.  One
  program per *bucket size*: the batched input relation gains a new
  leading **batch key dim** (``tra.pack_rows``), padded to the bucket so
  the engine's structural compile cache serves every request count from a
  small artifact set.  The §5.3 FFNN scorer (:class:`FFNNScorer`) is the
  paper-native instance.
* :class:`StepServable` — stateful step decode.  ONE program over a
  **fixed-capacity slot-keyed state relation**: the leading key dim
  indexes decode slots, admission/eviction are functional row writes
  (``tra.scatter_rows`` / ``tra.zero_rows``), and the compiled step is
  re-dispatched every engine tick with state threaded
  state-out → state-in by name, exactly like
  :class:`~repro.core.train.TraTrainer`.  :class:`RecurrentLM` is the
  smoke LM — an Elman-style recurrence sized from any model config.

Every servable also carries a **dense per-request oracle** (plain jnp,
no Engine) — the correctness reference the continuous-batching tests and
benchmarks compare against at 1e-5.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import expr as E
from repro.core.expr import Expr
from repro.core.tra import RelType, TensorRelation, to_tensor

DEFAULT_BUCKETS = (1, 2, 4, 8)


def pick_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket that fits ``n`` requests (buckets sorted asc)."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"{n} requests exceed the largest bucket "
                     f"{max(buckets)}")


class Servable:
    """Base: a named model whose programs the server compiles and pins."""

    name: str = "servable"

    def weights(self) -> Dict[str, TensorRelation]:
        """Weight input relations fed to every dispatch (long-lived)."""
        raise NotImplementedError

    def programs(self) -> List[Dict[str, Expr]]:
        """Every program to compile at warmup (one per served shape)."""
        raise NotImplementedError


class BatchServable(Servable):
    """Stateless scoring over bucket-padded batched relations."""

    buckets: Tuple[int, ...] = DEFAULT_BUCKETS

    def program(self, bucket: int) -> Dict[str, Expr]:
        raise NotImplementedError

    def pack(self, payloads: Sequence, bucket: int
             ) -> Dict[str, TensorRelation]:
        raise NotImplementedError

    def unpack(self, outs: Dict[str, TensorRelation], n: int) -> List:
        raise NotImplementedError

    def oracle(self, payload) -> np.ndarray:
        raise NotImplementedError

    def programs(self) -> List[Dict[str, Expr]]:
        return [self.program(b) for b in self.buckets]


class StepServable(Servable):
    """Fixed-capacity slot-keyed step decode (continuous batching)."""

    capacity: int = 8

    def step_program(self) -> Dict[str, Expr]:
        """Named roots; must include ``"state"`` (threaded) and
        ``"logits"`` (per-slot outputs)."""
        raise NotImplementedError

    def init_state(self) -> TensorRelation:
        raise NotImplementedError

    def step_inputs(self, tokens: Sequence[Optional[int]]
                    ) -> Dict[str, TensorRelation]:
        """Non-state inputs for one tick; ``tokens[slot]`` is the token
        the slot consumes this tick (``None`` = free slot)."""
        raise NotImplementedError

    def next_token(self, logits_row: np.ndarray) -> int:
        raise NotImplementedError

    def oracle_decode(self, prompt: Sequence[int], max_new_tokens: int
                      ) -> Tuple[List[int], List[np.ndarray]]:
        raise NotImplementedError

    # -- fault recovery ----------------------------------------------------
    def snapshot_state(self, state: TensorRelation) -> TensorRelation:
        """Cheap host copy of the slot-keyed state — the recovery point
        the server commits after every good tick.  Pulling the buffer to
        host ``numpy`` decouples the snapshot from device lifetime (a
        faulted dispatch cannot corrupt or free it)."""
        return TensorRelation(np.array(state.data, copy=True),
                              state.rtype, state.mask)

    def restore_state(self, snapshot: TensorRelation) -> TensorRelation:
        """Re-materialize a :meth:`snapshot_state` copy on device."""
        import jax.numpy as jnp
        return TensorRelation(jnp.asarray(snapshot.data),
                              snapshot.rtype, snapshot.mask)

    def programs(self) -> List[Dict[str, Expr]]:
        return [self.step_program()]


# ==========================================================================
# §5.3 FFNN scorer — the paper's evaluation network behind a request path
# ==========================================================================

class FFNNScorer(BatchServable):
    """The §5.3 two-layer FFNN as a stateless scoring servable.

    ``scores = σ(relu(X @ W1) @ W2)`` over block-chunked relations — the
    same forward program :func:`repro.core.programs.ffnn_step_tra` trains,
    now *served*: requests are feature vectors packed into an ``X``
    relation keyed ``(bucket, db)`` with ``(1, bd)`` row blocks.  The
    batch key dim is never contracted (the contraction runs over the
    feature blocks), so every request's scores are computed independently
    of its batch neighbours — zero-padding tail rows is inert, which is
    what makes bucket padding correct.

    One program per bucket size; the weight relations are shared across
    buckets, so ``d_in = db·bd`` features in, ``d_out = lb·bl`` scores
    out, for any admitted batch.
    """

    name = "ffnn-scorer"

    def __init__(self, db: int = 2, hb: int = 2, lb: int = 1,
                 bd: int = 8, bh: int = 8, bl: int = 4,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 seed: int = 0):
        self.db, self.hb, self.lb = db, hb, lb
        self.bd, self.bh, self.bl = bd, bh, bl
        self.buckets = tuple(sorted(buckets))
        self.d_in = db * bd
        self.d_out = lb * bl
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        h = hb * bh
        w1 = jax.random.normal(k1, (db, hb, bd, bh)) * (self.d_in ** -0.5)
        w2 = jax.random.normal(k2, (hb, lb, bh, bl)) * (h ** -0.5)
        self._weights = {
            "scorer.W1": TensorRelation(w1, RelType((db, hb), (bd, bh))),
            "scorer.W2": TensorRelation(w2, RelType((hb, lb), (bh, bl))),
        }
        self._row_rtype = RelType((db,), (1, bd))
        self._programs: Dict[int, Dict[str, Expr]] = {}

    def weights(self) -> Dict[str, TensorRelation]:
        return self._weights

    def program(self, bucket: int) -> Dict[str, Expr]:
        """The bucket's scoring program (built once, cached — reusing the
        identical ``Expr`` objects keeps the engine's structural cache
        key stable across dispatches)."""
        if bucket not in self._programs:
            if bucket not in self.buckets:
                raise ValueError(
                    f"bucket {bucket} not in {self.buckets}")
            x = E.input("X", (bucket, self.db), (1, self.bd))
            w1 = E.input("scorer.W1", (self.db, self.hb),
                         (self.bd, self.bh))
            w2 = E.input("scorer.W2", (self.hb, self.lb),
                         (self.bh, self.bl))
            a2 = ((x @ w1).map("relu") @ w2).map("sigmoid")
            self._programs[bucket] = {"scores": a2}
        return self._programs[bucket]

    # -- request packing ---------------------------------------------------
    def pack(self, payloads: Sequence, bucket: int
             ) -> Dict[str, TensorRelation]:
        from repro.core.tra import pack_rows
        rows = []
        for p in payloads:
            arr = jnp.asarray(p, jnp.float32)
            if arr.shape != (self.d_in,):
                raise ValueError(
                    f"scorer request must be a ({self.d_in},) feature "
                    f"vector, got {arr.shape}")
            rows.append(arr.reshape(self.db, 1, self.bd))
        return {"X": pack_rows(rows, bucket, self._row_rtype)}

    def unpack(self, outs: Dict[str, TensorRelation], n: int) -> List:
        from repro.core.tra import unpack_rows
        return [np.asarray(r.data).reshape(self.d_out)
                for r in unpack_rows(outs["scores"], n)]

    def random_payload(self, rng: np.random.Generator) -> np.ndarray:
        return rng.standard_normal(self.d_in).astype(np.float32)

    # -- dense oracle ------------------------------------------------------
    def oracle(self, payload) -> np.ndarray:
        """Per-request dense forward (plain jnp, no Engine, no batching)."""
        w1 = to_tensor(self._weights["scorer.W1"])
        w2 = to_tensor(self._weights["scorer.W2"])
        x = jnp.asarray(payload, jnp.float32)
        out = jax.nn.sigmoid(jax.nn.relu(x @ w1) @ w2)
        return np.asarray(out)


# ==========================================================================
# Smoke LM — an Elman recurrence sized from a model config
# ==========================================================================

@dataclasses.dataclass
class LmRequest:
    """A decode request: prompt token ids + generation budget."""

    prompt: List[int]
    max_new_tokens: int

    def __post_init__(self):
        if not self.prompt:
            raise ValueError("LmRequest needs a non-empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


class RecurrentLM(StepServable):
    """Elman-style recurrent LM as ONE fixed-capacity TRA step program.

    Per slot: ``h' = relu(h @ Wh + emb(tok) @ Wx)``,
    ``logits = h' @ Wo`` — greedy sampling happens host-side (like any
    real serving loop), the recurrent state lives in the slot-keyed
    relation ``lm.state`` (key ``(capacity, 1)``, bound ``(1, d)``).  The
    step program updates state through :meth:`~repro.core.expr.Expr.
    slot_update` with the ``lm.active`` mask relation, so free /
    mid-eviction slots hold their rows bit-exactly while neighbours
    decode — the invariant behind continuous batching correctness.

    Sized from any :class:`~repro.configs.base.ModelConfig` via
    :meth:`from_config` (``d_model``/``vocab_size`` of the smoke config);
    the weights are seeded Gaussians with sub-unit spectral scale so long
    decodes stay bounded.  Not the dense transformer zoo — the point is a
    *TRA-native* stateful decode path; ``launch/serve.py --dense-oracle``
    keeps the dense-model loop for comparison.
    """

    name = "recurrent-lm"

    def __init__(self, d_model: int = 64, vocab_size: int = 256,
                 capacity: int = 8, seed: int = 0):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.d = int(d_model)
        self.vocab = int(vocab_size)
        self.capacity = int(capacity)
        d, v = self.d, self.vocab
        kh, kx, ko, ke = jax.random.split(jax.random.PRNGKey(seed), 4)
        # sub-unit recurrent gain: relu(h·Wh + e·Wx) stays bounded over
        # arbitrarily long decodes
        wh = jax.random.normal(kh, (d, d)) * (0.5 * d ** -0.5)
        wx = jax.random.normal(kx, (d, d)) * (d ** -0.5)
        wo = jax.random.normal(ko, (d, v)) * (d ** -0.5)
        self._weights = {
            "lm.Wh": TensorRelation(wh[None, None],
                                    RelType((1, 1), (d, d))),
            "lm.Wx": TensorRelation(wx[None, None],
                                    RelType((1, 1), (d, d))),
            "lm.Wo": TensorRelation(wo[None, None],
                                    RelType((1, 1), (d, v))),
        }
        # host-side table: per-tick gathers index it in numpy, so the
        # traced device shapes never depend on how many slots are live
        # (one XLA program per step, not one per live-slot count)
        self.embedding = np.asarray(
            jax.random.normal(ke, (v, d)) * (d ** -0.5), np.float32)
        self._program: Optional[Dict[str, Expr]] = None
        self._state_rtype = RelType((self.capacity, 1), (1, d))

    @classmethod
    def from_config(cls, cfg, capacity: int = 8,
                    seed: int = 0) -> "RecurrentLM":
        """Size the LM from a model config (use the smoke variant)."""
        return cls(d_model=cfg.d_model, vocab_size=cfg.vocab_size,
                   capacity=capacity, seed=seed)

    def weights(self) -> Dict[str, TensorRelation]:
        return self._weights

    def step_program(self) -> Dict[str, Expr]:
        if self._program is None:
            c, d, v = self.capacity, self.d, self.vocab
            s = E.input("lm.state", (c, 1), (1, d))
            emb = E.input("lm.emb", (c, 1), (1, d))
            active = E.input("lm.active", (c, 1), (1, 1))
            wh = E.input("lm.Wh", (1, 1), (d, d))
            wx = E.input("lm.Wx", (1, 1), (d, d))
            wo = E.input("lm.Wo", (1, 1), (d, v))
            h = ((s @ wh) + (emb @ wx)).map("relu")
            self._program = {"state": s.slot_update(h, active),
                             "logits": h @ wo}
        return self._program

    def init_state(self) -> TensorRelation:
        c, d = self.capacity, self.d
        return TensorRelation(jnp.zeros((c, 1, 1, d), jnp.float32),
                              self._state_rtype)

    def step_inputs(self, tokens: Sequence[Optional[int]]
                    ) -> Dict[str, TensorRelation]:
        c, d = self.capacity, self.d
        if len(tokens) != c:
            raise ValueError(f"need {c} per-slot tokens, got {len(tokens)}")
        emb = np.zeros((c, 1, 1, d), np.float32)
        mask = np.zeros((c, 1, 1, 1), np.float32)
        for i, t in enumerate(tokens):
            if t is not None:
                emb[i, 0, 0] = self.embedding[int(t)]
                mask[i] = 1.0
        return {"lm.emb": TensorRelation(jnp.asarray(emb),
                                         RelType((c, 1), (1, d))),
                "lm.active": TensorRelation(jnp.asarray(mask),
                                            RelType((c, 1), (1, 1)))}

    def next_token(self, logits_row: np.ndarray) -> int:
        return int(np.argmax(logits_row))

    # -- dense oracle ------------------------------------------------------
    def oracle_step(self, h: jnp.ndarray, token: int
                    ) -> Tuple[jnp.ndarray, np.ndarray]:
        """One dense recurrence step: ``(h', logits)`` for one sequence."""
        wh = self._weights["lm.Wh"].data[0, 0]
        wx = self._weights["lm.Wx"].data[0, 0]
        wo = self._weights["lm.Wo"].data[0, 0]
        h2 = jax.nn.relu(h @ wh + self.embedding[token][None, :] @ wx)
        return h2, np.asarray((h2 @ wo)[0])

    def oracle_decode(self, prompt: Sequence[int], max_new_tokens: int
                      ) -> Tuple[List[int], List[np.ndarray]]:
        """Greedy per-request dense decode: ``(tokens, per-token logits)``.

        The logits list has one entry per *generated* token — the
        reference the continuously batched server must match at 1e-5
        regardless of which slots its neighbours occupied.
        """
        h = jnp.zeros((1, self.d), jnp.float32)
        for t in prompt[:-1]:
            h, _ = self.oracle_step(h, int(t))
        tok = int(prompt[-1])
        out_tokens: List[int] = []
        out_logits: List[np.ndarray] = []
        for _ in range(max_new_tokens):
            h, logits = self.oracle_step(h, tok)
            tok = self.next_token(logits)
            out_tokens.append(tok)
            out_logits.append(logits)
        return out_tokens, out_logits
