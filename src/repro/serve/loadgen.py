"""Load generation for :class:`~repro.serve.server.TraServer`.

Two canonical drive modes, both running the scheduler *in-thread* so a
run is deterministic modulo the clock:

* :func:`open_loop` — requests arrive on a pre-drawn schedule
  (:func:`poisson_arrivals` for a Poisson process at a target rate);
  whatever is due gets submitted before each tick.  Latency here is the
  honest serving number: queue wait under burst + service time.
* :func:`closed_loop` — a fixed number of outstanding requests; each
  completion immediately resubmits.  This saturates the server at a
  given concurrency, which is the right mode for peak-throughput
  measurements (the continuous-batching speedup guard).

Both return a :class:`LoadReport` built from the server's
:class:`~repro.launch.metering.SpanMeter` summary — tokens/s plus
p50/p95/p99 of total, queue-wait, and service spans — and the payload
mix helpers (:func:`scorer_mix`, :func:`lm_mix`) draw the heterogeneous
request shapes (feature vectors / varied prompt+generation lengths) the
bucket and slot schedulers are exercised against.

**Chaos mode.**  :func:`chaos_injector` scripts the PR-6
:class:`~repro.core.faults.FaultInjector` with *periodic* faults (every
N-th dispatch: site failure, NaN poisoning, device OOM) so a load run
doubles as a resilience drill: drive :func:`open_loop` with the
injector threaded through the engine and the server's retry/snapshot
machinery must hold the goodput SLO (``benchmarks/resilience.py``).
Reports split ``errors`` (failed after admission) from ``shed``
(admission-control fast-fails) so goodput is measured over admitted
requests only.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.faults import FaultInjector
from repro.serve.servable import BatchServable, LmRequest, StepServable
from repro.serve.server import RequestHandle, ServerOverloaded, TraServer


def poisson_arrivals(rng: np.random.Generator, n: int,
                     rate_per_s: float) -> List[float]:
    """Cumulative arrival offsets (seconds) of a Poisson process."""
    if rate_per_s <= 0:
        raise ValueError("rate_per_s must be > 0")
    gaps = rng.exponential(1.0 / rate_per_s, size=n)
    return list(np.cumsum(gaps))


def scorer_mix(sv: BatchServable, rng: np.random.Generator,
               n: int) -> List[np.ndarray]:
    """Random feature-vector payloads for a batch servable."""
    return [sv.random_payload(rng) for _ in range(n)]


def lm_mix(sv: StepServable, rng: np.random.Generator, n: int,
           prompt_len: tuple = (1, 8),
           new_tokens: tuple = (1, 12)) -> List[LmRequest]:
    """Mixed prompt/generation lengths — the continuous-batching diet."""
    vocab = getattr(sv, "vocab", 2)
    reqs = []
    for _ in range(n):
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        reqs.append(LmRequest(
            prompt=[int(t) for t in rng.integers(0, vocab, plen)],
            max_new_tokens=int(rng.integers(new_tokens[0],
                                            new_tokens[1] + 1))))
    return reqs


def chaos_injector(*, site_every: Optional[int] = None,
                   nan_node: Optional[str] = None,
                   nan_every: Optional[int] = None,
                   oom_times: int = 0, oom_ok_chunk: int = 1,
                   straggler_every: Optional[int] = None,
                   straggler_delay_s: float = 0.05) -> FaultInjector:
    """Script a periodic fault schedule for a chaos load run.

    * ``site_every`` — a :class:`~repro.core.faults.SimulatedFailure`
      kills every N-th dispatch (run-scoped, fires on every executor).
    * ``nan_node`` + ``nan_every`` — NaN-poison the named plan node on
      every N-th dispatch; per-run semantics need the eager
      ``reference`` executor (see the faults timing caveat) and
      ``Engine(check_numerics=True)`` to turn silent corruption into a
      retryable :class:`~repro.core.guards.NumericsError`.
    * ``oom_times`` — the first N fused contractions OOM unless streamed
      at ``oom_ok_chunk``.
    * ``straggler_every`` — delay every N-th dispatch by
      ``straggler_delay_s`` (watchdog drills).

    All periodic faults are unlimited (``times=-1``): the schedule runs
    as long as the load does.
    """
    inj = FaultInjector()
    if site_every is not None:
        inj.inject_site_failure(every=site_every, times=-1)
    if nan_node is not None:
        inj.inject_nan(node=nan_node, every=nan_every, times=-1)
    if oom_times > 0:
        inj.inject_oom(ok_chunk=oom_ok_chunk, times=oom_times)
    if straggler_every is not None:
        inj.inject_straggler(every=straggler_every,
                             delay=straggler_delay_s, times=-1)
    return inj


@dataclasses.dataclass
class LoadReport:
    """One load run: meter summary + outcome counts + wall time.

    ``results`` holds the per-request responses in submission order
    (``None`` where the request failed or was shed) so callers can
    cross-check served outputs against an oracle.  ``errors`` counts
    admitted requests that failed; ``shed`` counts admission-control
    fast-fails (:class:`~repro.serve.server.ServerOverloaded`) — kept
    apart because the goodput SLO is defined over admitted requests.
    """

    mode: str
    requests: int
    errors: int
    wall_s: float
    summary: Dict[str, Any]
    results: List[Any] = dataclasses.field(default_factory=list)
    shed: int = 0

    @property
    def admitted(self) -> int:
        return self.requests - self.shed

    @property
    def goodput(self) -> float:
        """Fraction of *admitted* requests that completed with a result."""
        if self.admitted <= 0:
            return 1.0
        return (self.admitted - self.errors) / self.admitted

    @property
    def tokens_per_s(self) -> float:
        return float(self.summary.get("tokens_per_s", 0.0))

    def to_json(self) -> Dict[str, Any]:
        return {"mode": self.mode, "requests": self.requests,
                "errors": self.errors, "shed": self.shed,
                "goodput": round(self.goodput, 6),
                "wall_s": round(self.wall_s, 4),
                **self.summary}


def _collect(handles: List[Optional[RequestHandle]]) -> tuple:
    errors, shed, results = 0, 0, []
    for h in handles:
        try:
            results.append(h.result(timeout=0) if h is not None else None)
        except ServerOverloaded:
            shed += 1
            results.append(None)
        except Exception:  # noqa: BLE001 — tallied, surfaced via report
            errors += 1
            results.append(None)
    return errors, shed, results


def open_loop(server: TraServer, payloads: List[Any],
              arrivals: List[float],
              clock: Optional[Callable[[], float]] = None,
              deadline_s: Optional[float] = None) -> LoadReport:
    """Drive a timed arrival schedule; tick whenever work is pending."""
    if len(payloads) != len(arrivals):
        raise ValueError("payloads and arrivals must align")
    order = np.argsort(arrivals, kind="stable")
    clock = clock or time.perf_counter
    t0 = clock()
    handles: List[Optional[RequestHandle]] = [None] * len(payloads)
    nxt = 0
    while nxt < len(payloads) or not server.idle():
        now = clock() - t0
        while nxt < len(payloads) and arrivals[order[nxt]] <= now:
            handles[order[nxt]] = server.submit(payloads[order[nxt]],
                                                deadline_s=deadline_s)
            nxt += 1
        if server.step() == 0 and nxt < len(payloads):
            # idle gap before the next arrival: sleep it off
            time.sleep(min(1e-3, max(0.0,
                                     arrivals[order[nxt]] - (clock() - t0))))
    wall = clock() - t0
    errors, shed, results = _collect(handles)
    return LoadReport("open_loop", len(payloads), errors, wall,
                      server.meter.summary(), results, shed=shed)


def closed_loop(server: TraServer, make_payload: Callable[[int], Any],
                n_requests: int, concurrency: int,
                clock: Optional[Callable[[], float]] = None) -> LoadReport:
    """Keep ``concurrency`` requests in flight until ``n_requests`` done."""
    if concurrency < 1:
        raise ValueError("concurrency must be >= 1")
    clock = clock or time.perf_counter
    t0 = clock()
    handles: List[RequestHandle] = []
    submitted = 0
    inflight: List[RequestHandle] = []
    while len(handles) - sum(h.done() for h in handles) > 0 \
            or submitted < n_requests:
        while submitted < n_requests and len(inflight) < concurrency:
            h = server.submit(make_payload(submitted))
            handles.append(h)
            inflight.append(h)
            submitted += 1
        server.step()
        inflight = [h for h in inflight if not h.done()]
    wall = clock() - t0
    errors, shed, results = _collect(handles)
    return LoadReport("closed_loop", len(handles), errors, wall,
                      server.meter.summary(), results, shed=shed)
