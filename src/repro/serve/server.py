"""TraServer — continuous batching over long-lived compiled TRA plans.

The server owns an :class:`~repro.core.engine.Engine` plus one
*servable* (:mod:`repro.serve.servable`) and turns the engine's
structural compile cache into a serving artifact store:

* at :meth:`warmup` every program the servable declares is compiled once
  and **pinned** (`Engine.pin`), so the steady state dispatches against a
  fixed artifact set — the acceptance invariant is *zero cache misses
  after warmup* no matter how request shapes interleave;
* requests enter through a thread-safe queue (:meth:`submit` returns a
  :class:`RequestHandle` the caller blocks on) and the scheduler
  (:meth:`step`) packs whatever is waiting into batched tensor relations:

  - **batch servables** (stateless scoring): drain up to the largest
    bucket, pad to the smallest fitting bucket with zero rows
    (:func:`~repro.core.tra.pack_rows`), dispatch, unpack the first *k*
    rows — the batch key dim is never contracted so padding is inert;
  - **step servables** (LM decode): token-level continuous batching over
    a fixed-capacity slot-keyed state relation.  Each tick admits
    pending requests into free slots (functional row writes), feeds
    every active slot one token (its next prompt token while prefilling,
    its last sampled token while decoding), dispatches ONE compiled step
    for all slots, rethreads ``state`` out→in by name exactly like
    :class:`~repro.core.train.TraTrainer`, and evicts finished
    sequences — zeroing their state rows — before the next tick, so a
    new request can occupy the slot immediately.

Resilience model (the PR-6 fault taxonomy, applied to serving):

* **Admission control** — ``max_pending`` bounds the number of admitted,
  unfinished requests; over-limit submissions are *shed*: their handle
  fails immediately with :class:`ServerOverloaded` (fast-fail, no queue
  residence).  ``max_queue_wait_s`` sheds requests that wait too long
  for a slot/bucket, so queue residence is bounded even under overload.
* **Cancellation & deadlines** — :meth:`RequestHandle.cancel` withdraws
  a request (immediately while queued; at the next tick mid-decode, with
  its slot freed and state row zeroed), and ``submit(deadline_s=...)``
  arms a deadline the scheduler enforces: an expired request fails with
  :class:`DeadlineExceeded` and releases its pending count and decode
  slot instead of leaking.
* **Fault-isolated retry** — dispatch failures are classified with
  :func:`repro.core.faults.is_transient`.  Transient faults (site
  failures, device OOM, compile flakes, numeric-guard trips) are retried
  with capped exponential backoff under a per-request ``max_retries``
  budget; only requests exhausting their budget fail, with the fault
  chained as ``__cause__``.  On the decode path the state relation is
  snapshotted (cheap host copy) after every good tick and restored on
  retry, so one injected fault rewinds the *tick*, not every live
  sequence's progress.  Permanent errors (bad payloads, type errors)
  fail the affected requests without retry, zeroing only *their* rows.
* **Crash containment & watchdog** — an exception escaping the
  background scheduler loop fails every pending/in-flight handle with a
  diagnostic (fault chained) and marks the server stopped instead of
  dying silently on the daemon thread; ``start(watchdog_timeout_s=...)``
  additionally arms a tick watchdog that detects a hung or dead
  scheduler thread and fails stranded requests.  :meth:`health` reports
  live/degraded/stopped plus queue depth, oldest-request age, and the
  shed/retry/recovery counters (also surfaced through :meth:`stats`).

Per-request admission→completion spans are metered through
:class:`~repro.launch.metering.SpanMeter`, splitting queue wait from
service time, tagging each request with the artifact ids that served it
and its outcome (ok / shed / cancelled / deadline / failed).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.core.engine import CompiledExpr, Engine
from repro.core.faults import is_transient
from repro.core.tra import TensorRelation, zero_rows
from repro.launch.metering import RequestSpan, SpanMeter
from repro.serve.servable import (BatchServable, LmRequest, Servable,
                                  StepServable, pick_bucket)


class ServerOverloaded(RuntimeError):
    """Request shed by admission control (queue full / waited too long)."""


class ServerStopped(RuntimeError):
    """The server is stopped (scheduler crashed or watchdog tripped)."""


class RequestCancelled(RuntimeError):
    """The request was withdrawn via :meth:`RequestHandle.cancel`."""


class DeadlineExceeded(TimeoutError):
    """The request's deadline passed before it completed; its pending
    count and any decode slot were released."""


class RetryBudgetExceeded(RuntimeError):
    """Transient-fault retries exhausted; the last fault is ``__cause__``."""


class RequestHandle:
    """Caller-side future for one submitted request."""

    def __init__(self, rid: int, payload: Any, span: RequestSpan,
                 server: Optional["TraServer"] = None,
                 deadline: Optional[float] = None):
        self.rid = rid
        self.payload = payload
        self.span = span
        self.deadline = deadline          # absolute meter-clock seconds
        self.retries = 0                  # transient faults charged so far
        self._server = server
        self._event = threading.Event()
        self._result: Any = None
        self._error: Optional[BaseException] = None
        self._cancelled = False
        self._counted = False             # holds one pending-count unit
        self._final_lock = threading.Lock()

    def done(self) -> bool:
        return self._event.is_set()

    def cancelled(self) -> bool:
        return isinstance(self._error, RequestCancelled)

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until served; raises the server-side error if it failed.

        A timeout here only stops *waiting* — to actually withdraw the
        request (freeing its pending count and decode slot) call
        :meth:`cancel`, or submit with ``deadline_s=`` so the scheduler
        enforces the bound server-side.
        """
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not done")
        if self._error is not None:
            raise self._error
        return self._result

    def cancel(self) -> bool:
        """Withdraw the request; returns False if it already finished.

        Still-queued requests fail immediately with
        :class:`RequestCancelled`; a request mid-decode is evicted at
        the next scheduler tick (slot freed, state row zeroed).
        """
        if self.done():
            return False
        self._cancelled = True
        if self._server is not None:
            self._server._on_cancel(self)
        return True

    def _complete(self, result: Any) -> None:
        self._result = result
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._event.set()


class _Seq:
    """One in-flight decode sequence occupying a slot."""

    def __init__(self, handle: RequestHandle, req: LmRequest):
        self.handle = handle
        self.req = req
        self.pos = 0                      # prompt tokens consumed
        self.generated: List[int] = []
        self.logits: List[np.ndarray] = []

    def next_input_token(self) -> int:
        if self.pos < len(self.req.prompt):
            return int(self.req.prompt[self.pos])     # prefill
        return self.generated[-1]                     # decode

    @property
    def finished(self) -> bool:
        return len(self.generated) >= self.req.max_new_tokens


_COUNTERS = ("shed", "cancelled", "deadline_expired", "retries",
             "transient_faults", "recovered", "retry_exhausted",
             "watchdog_trips", "scheduler_crashes")


class TraServer:
    """Serve one servable over one engine with continuous batching."""

    def __init__(self, engine: Engine, servable: Servable, *,
                 collect_logits: bool = False,
                 meter: Optional[SpanMeter] = None,
                 max_pending: Optional[int] = None,
                 max_queue_wait_s: Optional[float] = None,
                 max_retries: int = 3,
                 retry_backoff_s: float = 0.001,
                 retry_backoff_max_s: float = 0.05,
                 degraded_window_s: float = 5.0):
        if max_pending is not None and max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        self.engine = engine
        self.servable = servable
        self.collect_logits = collect_logits
        self.meter = meter if meter is not None else SpanMeter()
        self.max_pending = max_pending
        self.max_queue_wait_s = max_queue_wait_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.retry_backoff_max_s = retry_backoff_max_s
        self.degraded_window_s = degraded_window_s
        self._waiting: Deque[RequestHandle] = deque()
        self._queue_lock = threading.Lock()
        self._pending = 0                 # admitted, not yet finalized
        self._pending_lock = threading.Lock()
        self._step_lock = threading.RLock()
        self._next_rid = 0
        self.artifacts: Dict[str, CompiledExpr] = {}
        self.dispatches: Dict[str, int] = {}
        self.warmup_misses: Optional[int] = None
        self.counters: Dict[str, int] = {k: 0 for k in _COUNTERS}
        self._thread: Optional[threading.Thread] = None
        self._watchdog: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._stopped = False             # explicit stop() happened
        self._crashed: Optional[BaseException] = None
        self._last_tick: Optional[float] = None
        self._last_fault: Optional[float] = None
        self._decode_attempt = 0          # consecutive failed decode ticks
        if isinstance(servable, StepServable):
            self._state: TensorRelation = servable.init_state()
            self._slots: List[Optional[_Seq]] = [None] * servable.capacity
            self._state_snapshot = servable.snapshot_state(self._state)
        elif not isinstance(servable, BatchServable):
            raise TypeError(f"unsupported servable {type(servable).__name__}")

    # -- admission ---------------------------------------------------------
    def submit(self, payload: Any,
               deadline_s: Optional[float] = None) -> RequestHandle:
        """Enqueue one request; returns a handle to block on.

        ``deadline_s`` (relative seconds) arms scheduler-enforced expiry.
        Over ``max_pending``, the returned handle is already failed with
        :class:`ServerOverloaded` (fast-fail shedding) — it never enters
        the queue.  Raises :class:`ServerStopped` if the scheduler
        crashed or the watchdog tripped.
        """
        if self._crashed is not None:
            raise ServerStopped(
                f"server stopped: {self._crashed!r}") from self._crashed
        if isinstance(self.servable, StepServable) and \
                not isinstance(payload, LmRequest):
            raise TypeError("step servables take LmRequest payloads")
        span = self.meter.open("request")
        deadline = None if deadline_s is None else span.t_submit + deadline_s
        with self._pending_lock:
            rid = self._next_rid
            self._next_rid += 1
            admitted = self.max_pending is None \
                or self._pending < self.max_pending
            if admitted:
                self._pending += 1
        handle = RequestHandle(rid, payload, span, server=self,
                               deadline=deadline)
        handle._counted = admitted
        if not admitted:
            self._finalize(handle, error=ServerOverloaded(
                f"request {rid} shed: {self.max_pending} requests "
                f"already pending"), outcome="shed")
            self.counters["shed"] += 1
            return handle
        with self._queue_lock:
            self._waiting.append(handle)
        return handle

    def _on_cancel(self, handle: RequestHandle) -> None:
        """Called from :meth:`RequestHandle.cancel`.  Queued (never
        scheduled) requests finalize immediately; scheduled ones are
        evicted by the scheduler at the next tick."""
        if handle.span.t_start is not None:
            return
        if self._finalize(handle, error=RequestCancelled(
                f"request {handle.rid} cancelled while queued"),
                outcome="cancelled"):
            self.counters["cancelled"] += 1
        with self._queue_lock:
            try:
                self._waiting.remove(handle)
            except ValueError:
                pass

    # -- artifact lifecycle ------------------------------------------------
    def warmup(self) -> Dict[str, CompiledExpr]:
        """Compile and pin every program the servable declares.

        After this returns, steady-state dispatch must be hit-only:
        :attr:`cache_misses_since_warmup` staying 0 is the serving
        acceptance invariant.
        """
        for prog in self.servable.programs():
            compiled = self.engine.compile(prog)
            self.engine.pin(compiled)
            self.artifacts[compiled.artifact_id] = compiled
        self.warmup_misses = self.engine.cache_misses
        return dict(self.artifacts)

    @property
    def cache_misses_since_warmup(self) -> int:
        if self.warmup_misses is None:
            return self.engine.cache_misses
        return self.engine.cache_misses - self.warmup_misses

    # -- scheduling --------------------------------------------------------
    def idle(self) -> bool:
        with self._pending_lock:
            return self._pending == 0

    def step(self) -> int:
        """One scheduler tick; returns how many requests made progress."""
        with self._step_lock:
            now = self.meter.now()
            swept = self._sweep_queue(now)
            if isinstance(self.servable, BatchServable):
                progressed = self._step_batch(now)
            else:
                progressed = self._step_decode(now)
            self._last_tick = self.meter.now()
            return swept + progressed

    def run_until_idle(self, max_steps: int = 100_000) -> int:
        """Drive ticks until every submitted request completed."""
        steps = 0
        while not self.idle():
            if steps >= max_steps:
                raise RuntimeError(f"not idle after {max_steps} steps")
            self.step()
            steps += 1
        return steps

    def serve(self, payloads: Sequence[Any],
              return_exceptions: bool = False) -> List[Any]:
        """Submit a batch of payloads, drive to idle, return results.

        With ``return_exceptions`` a failed/shed request yields its
        exception object instead of raising — the mixed-outcome mode.
        """
        handles = [self.submit(p) for p in payloads]
        self.run_until_idle()
        out: List[Any] = []
        for h in handles:
            try:
                out.append(h.result(timeout=0))
            except Exception as err:  # noqa: BLE001 — caller asked for it
                if not return_exceptions:
                    raise
                out.append(err)
        return out

    # -- background loop ---------------------------------------------------
    def start(self, tick_wait_s: float = 0.001,
              watchdog_timeout_s: Optional[float] = None) -> None:
        """Run the scheduler on a background thread (loadgen mode).

        An exception escaping :meth:`step` no longer dies silently on
        the daemon thread: it fails every pending/in-flight handle (the
        crash chained as ``__cause__``) and marks the server stopped.
        ``watchdog_timeout_s`` arms a watchdog thread that does the same
        when the scheduler goes quiet (hung dispatch / dead thread) for
        longer than the timeout while requests are pending — size it
        well above the worst-case tick (dispatch + full retry backoff).
        """
        if self._thread is not None:
            raise RuntimeError("server already started")
        if self._crashed is not None:
            raise ServerStopped(
                f"server stopped: {self._crashed!r}") from self._crashed
        self._stop.clear()
        self._stopped = False

        def loop() -> None:
            while not self._stop.is_set():
                try:
                    progressed = self.step()
                except Exception as err:  # noqa: BLE001 — crash containment
                    self._on_scheduler_crash(err)
                    return
                if progressed == 0:
                    self._stop.wait(tick_wait_s)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="tra-server")
        self._thread.start()
        if watchdog_timeout_s is not None:
            self._start_watchdog(watchdog_timeout_s, self._thread)

    def _start_watchdog(self, timeout_s: float,
                        scheduler: threading.Thread) -> None:
        started_at = self.meter.now()

        def watch() -> None:
            interval = max(min(timeout_s / 4.0, 0.05), 1e-3)
            while not self._stop.wait(interval):
                if self.idle():
                    continue
                last = self._last_tick
                ref = last if last is not None else started_at
                dead = not scheduler.is_alive()
                hung = self.meter.now() - ref > timeout_s
                if not (dead or hung):
                    continue
                why = ("scheduler thread died" if dead else
                       f"no scheduler tick in {timeout_s}s")
                self.counters["watchdog_trips"] += 1
                self._crashed = RuntimeError(f"watchdog tripped: {why}")
                self._fail_all_inflight(lambda h: RuntimeError(
                    f"request {h.rid} stranded: {why} (watchdog)"))
                self._stop.set()
                return

        self._watchdog = threading.Thread(target=watch, daemon=True,
                                          name="tra-server-watchdog")
        self._watchdog.start()

    def _on_scheduler_crash(self, err: BaseException) -> None:
        """Satellite of the watchdog: contain a crash escaping step()."""
        self._crashed = err
        self.counters["scheduler_crashes"] += 1

        def make_err(h: RequestHandle) -> BaseException:
            diag: BaseException = RuntimeError(
                f"request {h.rid} abandoned: server scheduler crashed "
                f"({err!r})")
            diag.__cause__ = err
            return diag

        self._fail_all_inflight(make_err)
        self._stop.set()

    def _fail_all_inflight(
            self, make_err: Callable[[RequestHandle], BaseException]) -> int:
        """Fail every queued and slotted request (crash/watchdog path)."""
        failed = 0
        while True:
            with self._queue_lock:
                if not self._waiting:
                    break
                handle = self._waiting.popleft()
            if self._finalize(handle, error=make_err(handle),
                              outcome="failed"):
                failed += 1
        if isinstance(self.servable, StepServable):
            for i, seq in enumerate(self._slots):
                if seq is None:
                    continue
                if self._finalize(seq.handle, error=make_err(seq.handle),
                                  outcome="failed"):
                    failed += 1
                self._slots[i] = None
            self._state = self.servable.init_state()
            self._commit_state()
        return failed

    def stop(self, join_timeout_s: Optional[float] = 5.0) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(join_timeout_s)
        if self._watchdog is not None:
            self._watchdog.join(join_timeout_s)
            self._watchdog = None
        self._thread = None
        self._stopped = True

    # -- internals ---------------------------------------------------------
    def _finalize(self, handle: RequestHandle, *, result: Any = None,
                  error: Optional[BaseException] = None,
                  outcome: str = "ok", tokens: int = 0) -> bool:
        """First-wins completion: exactly one caller sets the result /
        error, completes the span, and releases the pending count — the
        scheduler, a deadline sweep, cancel(), and the watchdog can race
        on the same handle without double-counting."""
        with handle._final_lock:
            if handle.done():
                return False
            if error is not None:
                handle._fail(error)
            else:
                handle._complete(result)
        handle.span.outcome = outcome
        self.meter.complete(handle.span, tokens=tokens)
        if handle._counted:
            with self._pending_lock:
                self._pending -= 1
        return True

    def _finish(self, handle: RequestHandle, result: Any,
                tokens: int) -> None:
        if self._finalize(handle, result=result, tokens=tokens) \
                and handle.retries > 0:
            self.counters["recovered"] += 1

    def _fail(self, handle: RequestHandle, err: BaseException,
              outcome: str = "failed") -> bool:
        return self._finalize(handle, error=err, outcome=outcome)

    def _expire(self, handle: RequestHandle, now: float) -> bool:
        """Apply cancel/deadline/queue-wait policy to a queued handle;
        True if it was finalized (caller must skip it)."""
        if handle.done():
            return True
        if handle._cancelled:
            if self._fail(handle, RequestCancelled(
                    f"request {handle.rid} cancelled while queued"),
                    outcome="cancelled"):
                self.counters["cancelled"] += 1
            return True
        if handle.deadline is not None and now > handle.deadline:
            if self._fail(handle, DeadlineExceeded(
                    f"request {handle.rid} missed its deadline after "
                    f"{now - handle.span.t_submit:.3f}s in queue"),
                    outcome="deadline"):
                self.counters["deadline_expired"] += 1
            return True
        if self.max_queue_wait_s is not None \
                and now - handle.span.t_submit > self.max_queue_wait_s:
            if self._fail(handle, ServerOverloaded(
                    f"request {handle.rid} shed: queued longer than "
                    f"max_queue_wait_s={self.max_queue_wait_s}"),
                    outcome="shed"):
                self.counters["shed"] += 1
            return True
        return False

    def _sweep_queue(self, now: float) -> int:
        """Finalize expired/cancelled queued requests even when the
        schedulable window never reaches them (saturated server)."""
        with self._queue_lock:
            snapshot = list(self._waiting)
        finalized = 0
        for handle in snapshot:
            if not handle.done() and self._expire(handle, now):
                finalized += 1
        with self._queue_lock:
            done = [h for h in self._waiting if h.done()]
            for h in done:                # prune finalized entries
                self._waiting.remove(h)
        return finalized

    def _pop_next(self, now: float) -> Optional[RequestHandle]:
        """Next schedulable request, skipping finalized/expired ones."""
        while True:
            with self._queue_lock:
                if not self._waiting:
                    return None
                handle = self._waiting.popleft()
            if self._expire(handle, now):
                continue
            return handle

    def _backoff(self, attempt: int) -> None:
        delay = min(self.retry_backoff_max_s,
                    self.retry_backoff_s * (2.0 ** attempt))
        if delay > 0:
            time.sleep(delay)

    def _charge_retry(self, handle: RequestHandle,
                      fault: BaseException) -> bool:
        """Charge one transient fault to the handle's retry budget;
        False (and the handle failed, fault chained) if exhausted."""
        handle.retries += 1
        self.counters["retries"] += 1
        if handle.retries <= self.max_retries:
            return True
        err = RetryBudgetExceeded(
            f"request {handle.rid} failed after {self.max_retries} "
            f"retries; last fault: {fault!r}")
        err.__cause__ = fault
        if self._fail(handle, err):
            self.counters["retry_exhausted"] += 1
        return False

    def _record_dispatch(self, compiled: CompiledExpr,
                         spans: Sequence[RequestSpan]) -> None:
        aid = compiled.artifact_id or "unkeyed"
        self.dispatches[aid] = self.dispatches.get(aid, 0) + 1
        for sp in spans:
            if not sp.artifacts or sp.artifacts[-1] != aid:
                sp.artifacts.append(aid)

    def _step_batch(self, now: float) -> int:
        sv: BatchServable = self.servable  # type: ignore[assignment]
        batch: List[RequestHandle] = []
        while len(batch) < max(sv.buckets):
            handle = self._pop_next(now)
            if handle is None:
                break
            self.meter.start(handle.span)
            batch.append(handle)
        if not batch:
            return 0
        progressed = len(batch)
        attempt = 0
        while batch:
            bucket = pick_bucket(len(batch), sv.buckets)
            try:
                compiled = self.engine.compile(sv.program(bucket))
                self._record_dispatch(compiled, [h.span for h in batch])
                outs = compiled.run(**sv.pack([h.payload for h in batch],
                                              bucket), **sv.weights())
                results = sv.unpack(outs, len(batch))
            except Exception as err:  # noqa: BLE001 — classify and retry
                if not is_transient(err):
                    for h in batch:      # permanent: fail, keep serving
                        self._fail(h, err)
                    return progressed
                self.counters["transient_faults"] += 1
                self._last_fault = self.meter.now()
                batch = [h for h in batch if self._charge_retry(h, err)]
                self._backoff(attempt)
                attempt += 1
                continue
            for h, res in zip(batch, results):
                self._finish(h, res, tokens=1)
            break
        return progressed

    def _commit_state(self) -> None:
        """Host-copy recovery point: the state every retry rewinds to."""
        sv: StepServable = self.servable  # type: ignore[assignment]
        self._state_snapshot = sv.snapshot_state(self._state)

    def _reclaim_slots(self, now: float) -> int:
        """Evict cancelled / deadline-expired sequences: free the slot,
        zero the state row, fail the handle."""
        reclaimed: List[int] = []
        for i, seq in enumerate(self._slots):
            if seq is None:
                continue
            handle = seq.handle
            if handle._cancelled and not handle.done():
                if self._fail(handle, RequestCancelled(
                        f"request {handle.rid} cancelled mid-decode "
                        f"(slot {i} freed)"), outcome="cancelled"):
                    self.counters["cancelled"] += 1
            elif handle.deadline is not None and now > handle.deadline \
                    and not handle.done():
                if self._fail(handle, DeadlineExceeded(
                        f"request {handle.rid} missed its deadline "
                        f"mid-decode (slot {i} freed)"),
                        outcome="deadline"):
                    self.counters["deadline_expired"] += 1
            if handle.done():
                self._slots[i] = None
                reclaimed.append(i)
        if reclaimed:
            self._state = zero_rows(self._state, reclaimed)
            self._commit_state()
        return len(reclaimed)

    def _on_decode_failure(self, live, err: BaseException) -> None:
        """Fault-isolated decode recovery: restore the last good state
        snapshot, so surviving sequences resume from the previous tick
        instead of a full-state reset."""
        sv: StepServable = self.servable  # type: ignore[assignment]
        self._state = sv.restore_state(self._state_snapshot)
        if not is_transient(err):
            dead = []
            for i, seq in live:          # permanent: fail only the victims
                self._fail(seq.handle, err)
                self._slots[i] = None
                dead.append(i)
            self._state = zero_rows(self._state, dead)
            self._commit_state()
            return
        self.counters["transient_faults"] += 1
        self._last_fault = self.meter.now()
        dead = []
        for i, seq in live:
            if not self._charge_retry(seq.handle, err):
                self._slots[i] = None
                dead.append(i)
        if dead:
            self._state = zero_rows(self._state, dead)
        self._commit_state()
        self._backoff(self._decode_attempt)
        self._decode_attempt += 1

    def _step_decode(self, now: float) -> int:
        sv: StepServable = self.servable  # type: ignore[assignment]
        # 0. reclaim slots of cancelled / expired sequences
        reclaimed = self._reclaim_slots(now)
        # 1. admit pending requests into the lowest free slots
        for i in range(sv.capacity):
            if self._slots[i] is not None:
                continue
            handle = self._pop_next(now)
            if handle is None:
                break
            self.meter.start(handle.span)
            self._slots[i] = _Seq(handle, handle.payload)
        live = [(i, s) for i, s in enumerate(self._slots) if s is not None]
        if not live:
            return reclaimed
        # 2. one token per active slot: prompt token while prefilling,
        #    last sampled token while decoding
        tokens: List[Optional[int]] = [None] * sv.capacity
        for i, seq in live:
            tokens[i] = seq.next_input_token()
        # 3. ONE batched step for every slot; state threads out -> in
        try:
            compiled = self.engine.compile(sv.step_program())
            self._record_dispatch(compiled, [s.handle.span for _, s in live])
            outs = compiled.run(**sv.step_inputs(tokens), **sv.weights(),
                                **{"lm.state": self._state})
        except Exception as err:  # noqa: BLE001 — classify and retry
            self._on_decode_failure(live, err)
            return reclaimed + len(live)
        self._state = outs["state"]
        self._decode_attempt = 0
        logits = np.asarray(outs["logits"].data)
        # 4. advance sequences; sample once prefill is done
        evicted: List[int] = []
        for i, seq in live:
            seq.pos += 1
            if seq.pos >= len(seq.req.prompt):
                row = logits[i].reshape(-1)
                seq.generated.append(sv.next_token(row))
                if self.collect_logits:
                    seq.logits.append(row.copy())
            if seq.finished:
                result = {"tokens": list(seq.generated)}
                if self.collect_logits:
                    result["logits"] = list(seq.logits)
                self._finish(seq.handle, result,
                             tokens=len(seq.generated))
                self._slots[i] = None
                evicted.append(i)
        # 5. zero evicted state rows so reused slots start clean, then
        #    commit the post-tick state as the new recovery point
        if evicted:
            self._state = zero_rows(self._state, evicted)
        self._commit_state()
        return reclaimed + len(live)

    # -- reporting ---------------------------------------------------------
    def health(self) -> Dict[str, Any]:
        """Liveness snapshot: status, depths, ages, resilience counters."""
        now = self.meter.now()
        with self._queue_lock:
            queued = [h for h in self._waiting if not h.done()]
        submits = [h.span.t_submit for h in queued]
        if isinstance(self.servable, StepServable):
            submits += [s.handle.span.t_submit for s in self._slots
                        if s is not None and not s.handle.done()]
        with self._pending_lock:
            pending = self._pending
        if self._crashed is not None or self._stopped:
            status = "stopped"
        elif self._last_fault is not None \
                and now - self._last_fault < self.degraded_window_s:
            status = "degraded"
        else:
            status = "live"
        return {
            "status": status,
            "queue_depth": len(queued),
            "pending": pending,
            "oldest_request_age_s":
                round(now - min(submits), 6) if submits else None,
            "last_tick_age_s":
                round(now - self._last_tick, 6)
                if self._last_tick is not None else None,
            "counters": dict(self.counters),
        }

    def stats(self) -> Dict[str, Any]:
        """Serving report: artifacts, dispatch counts, health, spans."""
        cache = [{
            "artifact_id": e.artifact_id,
            "executor": e.executor,
            "hits": e.hits,
            "pinned": e.pinned,
            "degraded": e.degraded,
            "dispatches": self.dispatches.get(e.artifact_id, 0),
        } for e in self.engine.cache_info()]
        return {
            "servable": self.servable.name,
            "executor": self.engine.executor,
            "cache_misses_since_warmup": self.cache_misses_since_warmup,
            "artifacts": cache,
            "health": self.health(),
            **self.meter.summary(),
        }
