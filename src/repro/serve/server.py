"""TraServer — continuous batching over long-lived compiled TRA plans.

The server owns an :class:`~repro.core.engine.Engine` plus one
*servable* (:mod:`repro.serve.servable`) and turns the engine's
structural compile cache into a serving artifact store:

* at :meth:`warmup` every program the servable declares is compiled once
  and **pinned** (`Engine.pin`), so the steady state dispatches against a
  fixed artifact set — the acceptance invariant is *zero cache misses
  after warmup* no matter how request shapes interleave;
* requests enter through a thread-safe queue (:meth:`submit` returns a
  :class:`RequestHandle` the caller blocks on) and the scheduler
  (:meth:`step`) packs whatever is waiting into batched tensor relations:

  - **batch servables** (stateless scoring): drain up to the largest
    bucket, pad to the smallest fitting bucket with zero rows
    (:func:`~repro.core.tra.pack_rows`), dispatch, unpack the first *k*
    rows — the batch key dim is never contracted so padding is inert;
  - **step servables** (LM decode): token-level continuous batching over
    a fixed-capacity slot-keyed state relation.  Each tick admits
    pending requests into free slots (functional row writes), feeds
    every active slot one token (its next prompt token while prefilling,
    its last sampled token while decoding), dispatches ONE compiled step
    for all slots, rethreads ``state`` out→in by name exactly like
    :class:`~repro.core.train.TraTrainer`, and evicts finished
    sequences — zeroing their state rows — before the next tick, so a
    new request can occupy the slot immediately.

Per-request admission→completion spans are metered through
:class:`~repro.launch.metering.SpanMeter`, splitting queue wait from
service time and tagging each request with the artifact ids that served
it.  Failures during a dispatch fail the *affected* handles (their
``result()`` raises) and leave the server serving — pair with
``Engine(degrade=True)`` to ride out compile/OOM faults mid-stream.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.engine import CompiledExpr, Engine
from repro.core.tra import TensorRelation, zero_rows
from repro.launch.metering import RequestSpan, SpanMeter
from repro.serve.servable import (BatchServable, LmRequest, Servable,
                                  StepServable, pick_bucket)


class RequestHandle:
    """Caller-side future for one submitted request."""

    def __init__(self, rid: int, payload: Any, span: RequestSpan):
        self.rid = rid
        self.payload = payload
        self.span = span
        self._event = threading.Event()
        self._result: Any = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until served; raises the server-side error if it failed."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not done")
        if self._error is not None:
            raise self._error
        return self._result

    def _complete(self, result: Any) -> None:
        self._result = result
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._event.set()


class _Seq:
    """One in-flight decode sequence occupying a slot."""

    def __init__(self, handle: RequestHandle, req: LmRequest):
        self.handle = handle
        self.req = req
        self.pos = 0                      # prompt tokens consumed
        self.generated: List[int] = []
        self.logits: List[np.ndarray] = []

    def next_input_token(self) -> int:
        if self.pos < len(self.req.prompt):
            return int(self.req.prompt[self.pos])     # prefill
        return self.generated[-1]                     # decode

    @property
    def finished(self) -> bool:
        return len(self.generated) >= self.req.max_new_tokens


class TraServer:
    """Serve one servable over one engine with continuous batching."""

    def __init__(self, engine: Engine, servable: Servable, *,
                 collect_logits: bool = False,
                 meter: Optional[SpanMeter] = None):
        self.engine = engine
        self.servable = servable
        self.collect_logits = collect_logits
        self.meter = meter if meter is not None else SpanMeter()
        self._queue: "queue.Queue[RequestHandle]" = queue.Queue()
        self._pending = 0                 # submitted, not yet completed
        self._pending_lock = threading.Lock()
        self._step_lock = threading.RLock()
        self._next_rid = 0
        self.artifacts: Dict[str, CompiledExpr] = {}
        self.dispatches: Dict[str, int] = {}
        self.warmup_misses: Optional[int] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        if isinstance(servable, StepServable):
            self._state: TensorRelation = servable.init_state()
            self._slots: List[Optional[_Seq]] = [None] * servable.capacity
        elif not isinstance(servable, BatchServable):
            raise TypeError(f"unsupported servable {type(servable).__name__}")

    # -- admission ---------------------------------------------------------
    def submit(self, payload: Any) -> RequestHandle:
        """Enqueue one request; returns a handle to block on."""
        if isinstance(self.servable, StepServable) and \
                not isinstance(payload, LmRequest):
            raise TypeError("step servables take LmRequest payloads")
        with self._pending_lock:
            rid = self._next_rid
            self._next_rid += 1
            self._pending += 1
        handle = RequestHandle(rid, payload, self.meter.open("request"))
        self._queue.put(handle)
        return handle

    # -- artifact lifecycle ------------------------------------------------
    def warmup(self) -> Dict[str, CompiledExpr]:
        """Compile and pin every program the servable declares.

        After this returns, steady-state dispatch must be hit-only:
        :attr:`cache_misses_since_warmup` staying 0 is the serving
        acceptance invariant.
        """
        for prog in self.servable.programs():
            compiled = self.engine.compile(prog)
            self.engine.pin(compiled)
            self.artifacts[compiled.artifact_id] = compiled
        self.warmup_misses = self.engine.cache_misses
        return dict(self.artifacts)

    @property
    def cache_misses_since_warmup(self) -> int:
        if self.warmup_misses is None:
            return self.engine.cache_misses
        return self.engine.cache_misses - self.warmup_misses

    # -- scheduling --------------------------------------------------------
    def idle(self) -> bool:
        with self._pending_lock:
            return self._pending == 0

    def step(self) -> int:
        """One scheduler tick; returns how many requests made progress."""
        with self._step_lock:
            if isinstance(self.servable, BatchServable):
                return self._step_batch()
            return self._step_decode()

    def run_until_idle(self, max_steps: int = 100_000) -> int:
        """Drive ticks until every submitted request completed."""
        steps = 0
        while not self.idle():
            if steps >= max_steps:
                raise RuntimeError(f"not idle after {max_steps} steps")
            self.step()
            steps += 1
        return steps

    def serve(self, payloads: Sequence[Any]) -> List[Any]:
        """Submit a batch of payloads, drive to idle, return results."""
        handles = [self.submit(p) for p in payloads]
        self.run_until_idle()
        return [h.result(timeout=0) for h in handles]

    # -- background loop ---------------------------------------------------
    def start(self, tick_wait_s: float = 0.001) -> None:
        """Run the scheduler on a background thread (loadgen mode)."""
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()

        def loop() -> None:
            while not self._stop.is_set():
                if self.step() == 0:
                    self._stop.wait(tick_wait_s)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="tra-server")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    # -- internals ---------------------------------------------------------
    def _finish(self, handle: RequestHandle, result: Any,
                tokens: int) -> None:
        handle._complete(result)
        self.meter.complete(handle.span, tokens=tokens)
        with self._pending_lock:
            self._pending -= 1

    def _fail(self, handle: RequestHandle, err: BaseException) -> None:
        handle._fail(err)
        self.meter.complete(handle.span, tokens=0)
        with self._pending_lock:
            self._pending -= 1

    def _record_dispatch(self, compiled: CompiledExpr,
                         spans: Sequence[RequestSpan]) -> None:
        aid = compiled.artifact_id or "unkeyed"
        self.dispatches[aid] = self.dispatches.get(aid, 0) + 1
        for sp in spans:
            if not sp.artifacts or sp.artifacts[-1] != aid:
                sp.artifacts.append(aid)

    def _step_batch(self) -> int:
        sv: BatchServable = self.servable  # type: ignore[assignment]
        batch: List[RequestHandle] = []
        while len(batch) < max(sv.buckets):
            try:
                batch.append(self._queue.get_nowait())
            except queue.Empty:
                break
        if not batch:
            return 0
        for h in batch:
            self.meter.start(h.span)
        bucket = pick_bucket(len(batch), sv.buckets)
        try:
            compiled = self.engine.compile(sv.program(bucket))
            self._record_dispatch(compiled, [h.span for h in batch])
            outs = compiled.run(**sv.pack([h.payload for h in batch],
                                          bucket), **sv.weights())
            results = sv.unpack(outs, len(batch))
        except Exception as err:  # fail the batch, keep serving
            for h in batch:
                self._fail(h, err)
            return len(batch)
        for h, res in zip(batch, results):
            self._finish(h, res, tokens=1)
        return len(batch)

    def _step_decode(self) -> int:
        sv: StepServable = self.servable  # type: ignore[assignment]
        # 1. admit pending requests into the lowest free slots
        for i in range(sv.capacity):
            if self._slots[i] is not None:
                continue
            try:
                handle = self._queue.get_nowait()
            except queue.Empty:
                break
            self.meter.start(handle.span)
            self._slots[i] = _Seq(handle, handle.payload)
        live = [(i, s) for i, s in enumerate(self._slots) if s is not None]
        if not live:
            return 0
        # 2. one token per active slot: prompt token while prefilling,
        #    last sampled token while decoding
        tokens: List[Optional[int]] = [None] * sv.capacity
        for i, seq in live:
            tokens[i] = seq.next_input_token()
        # 3. ONE batched step for every slot; state threads out -> in
        try:
            compiled = self.engine.compile(sv.step_program())
            self._record_dispatch(compiled, [s.handle.span for _, s in live])
            outs = compiled.run(**sv.step_inputs(tokens), **sv.weights(),
                                **{"lm.state": self._state})
        except Exception as err:  # fail every in-flight seq, free slots
            for i, seq in live:
                self._fail(seq.handle, err)
                self._slots[i] = None
            self._state = sv.init_state()
            return len(live)
        self._state = outs["state"]
        logits = np.asarray(outs["logits"].data)
        # 4. advance sequences; sample once prefill is done
        evicted: List[int] = []
        for i, seq in live:
            seq.pos += 1
            if seq.pos >= len(seq.req.prompt):
                row = logits[i].reshape(-1)
                seq.generated.append(sv.next_token(row))
                if self.collect_logits:
                    seq.logits.append(row.copy())
            if seq.finished:
                result = {"tokens": list(seq.generated)}
                if self.collect_logits:
                    result["logits"] = list(seq.logits)
                self._finish(seq.handle, result,
                             tokens=len(seq.generated))
                self._slots[i] = None
                evicted.append(i)
        # 5. zero evicted state rows so reused slots start clean
        if evicted:
            self._state = zero_rows(self._state, evicted)
        return len(live)

    # -- reporting ---------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Serving report: artifacts, dispatch counts, span summary."""
        cache = [{
            "artifact_id": e.artifact_id,
            "executor": e.executor,
            "hits": e.hits,
            "pinned": e.pinned,
            "degraded": e.degraded,
            "dispatches": self.dispatches.get(e.artifact_id, 0),
        } for e in self.engine.cache_info()]
        return {
            "servable": self.servable.name,
            "executor": self.engine.executor,
            "cache_misses_since_warmup": self.cache_misses_since_warmup,
            "artifacts": cache,
            **self.meter.summary(),
        }
