"""TRA serving engine — continuous batching over compiled relational plans.

Entry points:

* :class:`~repro.serve.server.TraServer` — the server: admission queue,
  continuous-batching scheduler, pinned compile-cache artifacts, plus
  the resilience layer (load shedding, cancellation/deadlines,
  transient-fault retry with decode-state snapshots, crash containment,
  tick watchdog, :meth:`~repro.serve.server.TraServer.health`).
* :class:`~repro.serve.servable.FFNNScorer` /
  :class:`~repro.serve.servable.RecurrentLM` — the paper-native §5.3
  scorer and the smoke step-decode LM it serves.
* :mod:`repro.serve.loadgen` — Poisson / closed-loop drivers emitting
  p50/p95/p99 latency and tokens/s, and :func:`chaos_injector` for
  fault-schedule chaos runs.

See ``docs/serving.md`` for the architecture and resilience model.
"""
from repro.serve.loadgen import (LoadReport, chaos_injector, closed_loop,
                                 lm_mix, open_loop, poisson_arrivals,
                                 scorer_mix)
from repro.serve.servable import (BatchServable, FFNNScorer, LmRequest,
                                  RecurrentLM, Servable, StepServable,
                                  pick_bucket)
from repro.serve.server import (DeadlineExceeded, RequestCancelled,
                                RequestHandle, RetryBudgetExceeded,
                                ServerOverloaded, ServerStopped, TraServer)

__all__ = [
    "LoadReport", "chaos_injector", "closed_loop", "lm_mix", "open_loop",
    "poisson_arrivals", "scorer_mix",
    "BatchServable", "FFNNScorer", "LmRequest", "RecurrentLM",
    "Servable", "StepServable", "pick_bucket",
    "DeadlineExceeded", "RequestCancelled", "RequestHandle",
    "RetryBudgetExceeded", "ServerOverloaded", "ServerStopped",
    "TraServer",
]
