"""Einstein-notation frontend for the TRA (paper §2.3).

The paper proves TRA ⊇ Einstein notation by construction: every index of a
tensor becomes a key dim (the tensor is chunked so blocks carry the same
index structure), a binary term becomes a join on the shared indices, and
contracted indices are aggregated out with ``matAdd``.  This module is that
construction, executable.

:func:`build_einsum` is the construction itself, over arbitrary logical
child nodes — it is what :func:`repro.core.expr.einsum` (the ``Expr``
frontend) calls, so Einstein-notation expressions flow through the same
builder and optimizer entry path as the fluent API:

    C = tra.einsum("ij,jk->ik", A, B)          # A, B are Exprs

:func:`einsum_tra` is the original spec-dict form kept for compatibility;
it wraps each :class:`OperandSpec` in a fresh ``TraInput`` and delegates.
Chained/multi-operand expressions reduce left-to-right (each step is one
join+aggregate), matching the grammar's binary production rule.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax.numpy as jnp

from repro.core.kernels_registry import JoinVjp, Kernel, get_kernel
from repro.core.plan import (TraAgg, TraInput, TraJoin, TraNode, TraReKey,
                             TraTransform)
from repro.core.tra import RelType


@dataclasses.dataclass(frozen=True)
class OperandSpec:
    """A tensor operand: per-index block counts and block sizes."""

    name: str
    indices: str                 # e.g. "ij"
    blocks: Tuple[int, ...]      # key frontier per index
    block_sizes: Tuple[int, ...] # array bound per index

    @property
    def rtype(self) -> RelType:
        return RelType(self.blocks, self.block_sizes, jnp.float32)


def _pairwise_einsum_kernel(idx_l: str, idx_r: str, idx_out: str,
                            bl: Sequence[int], br: Sequence[int],
                            derivative: bool = False) -> Kernel:
    """Blockwise kernel for one binary contraction (the join's projOp).

    Unless building a ``derivative`` kernel, the kernel carries its own
    VJP pair — the classic einsum index swap: for ``out = Σ l,r → o`` the
    operand cotangents are ``dL = Σ o,r → l`` and ``dR = Σ o,l → r``
    (every ``idx_l`` letter appears in ``idx_out ∪ idx_r`` because the
    §2.3 construction only contracts *shared* indices, so the swapped
    specs are always well-formed).  The VJP kernels are parameterized
    :class:`Kernel` objects carried directly on the :class:`JoinVjp`, and
    :mod:`repro.core.autodiff` emits the surrounding join+aggregation —
    the backward of an einsum expression is itself an einsum-shaped TRA
    plan."""
    spec = f"...{idx_l},...{idx_r}->...{idx_out}"
    size = dict(zip(idx_l, bl))
    size.update(zip(idx_r, br))
    out_bound = tuple(size[i] for i in idx_out)
    flops = 2
    for i in set(idx_l) | set(idx_r):
        flops *= size[i]

    vjp = None
    if not derivative:
        bo = [size[i] for i in idx_out]
        vjp = (
            JoinVjp(_pairwise_einsum_kernel(idx_out, idx_r, idx_l,
                                            bo, br, derivative=True)),
            JoinVjp(_pairwise_einsum_kernel(idx_out, idx_l, idx_r,
                                            bo, bl, derivative=True)),
        )

    return Kernel(
        name=f"einsum[{idx_l},{idx_r}->{idx_out}]",
        arity=2,
        apply=lambda a, b: jnp.einsum(spec, a, b),
        out_bound=lambda _bl, _br: out_bound,
        flops=lambda _bl, _br: flops,
        vjp=vjp,
    )


def _expand_kernel(src_idx: str, dst_idx: str,
                   dst_sizes: Sequence[int]) -> Kernel:
    """Broadcast blocks from ``src_idx`` order back to ``dst_idx`` shape —
    the VJP image of a within-block trailing contraction (``dst → src``).
    Missing indices regrow by broadcasting the cotangent."""
    dst_sizes = tuple(dst_sizes)
    src_in_dst = [i for i in dst_idx if i in src_idx]
    perm = [src_idx.index(i) for i in src_in_dst]
    missing = [ax for ax, i in enumerate(dst_idx) if i not in src_idx]

    def _apply(a: jnp.ndarray) -> jnp.ndarray:
        lead = a.ndim - len(src_idx)
        a = jnp.transpose(a, list(range(lead)) + [lead + p for p in perm])
        for ax in missing:
            a = jnp.expand_dims(a, lead + ax)
        return jnp.broadcast_to(a, a.shape[:lead] + dst_sizes)

    return Kernel(
        name=f"einsumExpand[{src_idx}->{dst_idx}]", arity=1,
        apply=_apply,
        out_bound=lambda b: dst_sizes,
        flops=lambda b: 0,
    )


def _block_permute_kernel(src_idx: str, dst_idx: str) -> Kernel:
    """Pure within-block axis permutation ``src_idx → dst_idx`` (its own
    VJP is the inverse permutation)."""
    inv = tuple(src_idx.index(i) for i in dst_idx)
    return Kernel(
        name=f"einsum[{src_idx}->{dst_idx}]", arity=1,
        apply=lambda a, s=f"...{src_idx}->...{dst_idx}": jnp.einsum(s, a),
        out_bound=lambda b, p=inv: tuple(b[i] for i in p),
        flops=lambda b: 0,
        vjp=lambda x, y, g, si=src_idx, di=dst_idx:
            g.map(_block_permute_kernel(di, si)),
    )


def parse_spec(spec: str) -> Tuple[List[str], str]:
    lhs, rhs = spec.replace(" ", "").split("->")
    return lhs.split(","), rhs


def build_einsum(terms: Sequence[str], out_idx: str,
                 nodes: Sequence[TraNode],
                 sizes_list: Sequence[Sequence[int]]) -> TraNode:
    """The §2.3 construction over existing logical children.

    ``nodes[i]`` is the logical plan for lhs term ``terms[i]``;
    ``sizes_list[i]`` its bound (one entry per index letter) — key
    frontiers are carried by the nodes themselves.  Returns the plan
    computing the einsum with output keys in rhs order.
    """
    if len(nodes) < 1:
        raise ValueError("need at least one operand")
    cur: TraNode = nodes[0]
    cur_idx = terms[0]
    cur_sizes = dict(zip(terms[0], sizes_list[0]))

    for k in range(1, len(nodes)):
        rhs_remaining = set("".join(terms[k + 1:])) | set(out_idx)
        nxt = nodes[k]
        shared = [i for i in cur_idx if i in terms[k]]
        jkl = tuple(cur_idx.index(i) for i in shared)
        jkr = tuple(terms[k].index(i) for i in shared)
        # post-join key order: cur indices ++ (next indices minus joined)
        post_idx = cur_idx + "".join(i for i in terms[k] if i not in shared)
        contract = [i for i in shared if i not in rhs_remaining]
        # the block kernel contracts WITHIN blocks; the agg below contracts
        # ACROSS blocks.  kernel output = all non-contracted indices.
        kept_idx = "".join(i for i in post_idx if i not in contract)
        kern = _pairwise_einsum_kernel(
            cur_idx, terms[k], kept_idx,
            [cur_sizes[i] for i in cur_idx], list(sizes_list[k]))
        joined = TraJoin(cur, nxt, jkl, jkr, kern)
        if contract:
            gb = tuple(post_idx.index(i) for i in kept_idx)
            cur = TraAgg(joined, gb, get_kernel("matAdd"))
            cur_idx = kept_idx
        else:
            cur = joined
            cur_idx = post_idx
        cur_sizes.update(zip(terms[k], sizes_list[k]))

    if cur_idx != out_idx:
        if sorted(cur_idx) != sorted(out_idx):
            # trailing contraction of indices absent from the output:
            # contract within blocks (transform) then across blocks (agg)
            keep = "".join(i for i in cur_idx if i in out_idx)
            cur_bound = tuple(cur_sizes[i] for i in cur_idx)
            inner = Kernel(
                name=f"einsum[{cur_idx}->{keep}]", arity=1,
                apply=lambda a, s=f"...{cur_idx}->...{keep}":
                    jnp.einsum(s, a),
                out_bound=lambda b, ci=cur_idx, kp=keep:
                    tuple(b[ci.index(i)] for i in kp),
                flops=lambda b: int(jnp.prod(jnp.asarray(b))),
                # d(within-block sum)/dX broadcasts the cotangent back
                # over the summed-out block axes
                vjp=lambda x, y, g, kp=keep, ci=cur_idx, cb=cur_bound:
                    g.map(_expand_kernel(kp, ci, cb)),
            )
            cur = TraTransform(cur, inner)
            gb = tuple(cur_idx.index(i) for i in keep)
            cur = TraAgg(cur, gb, get_kernel("matAdd"))
            cur_idx = keep
        if cur_idx != out_idx:
            # permute both the block grid (rekey) and the block interiors
            # (transform) to the rhs order
            inv = tuple(cur_idx.index(i) for i in out_idx)
            cur = TraTransform(cur, _block_permute_kernel(cur_idx, out_idx))
            cur = TraReKey(cur, lambda key, p=inv: tuple(key[i] for i in p),
                           tag=f"permute{inv}")
    return cur


def einsum_tra(spec: str, operands) -> TraNode:
    """Build the logical TRA plan for an einsum over chunked tensors.

    ``operands`` is either a list of :class:`OperandSpec` (one per lhs term,
    in order) or a dict keyed by index string (only when terms are unique).
    Returns a plan whose inputs are named by the operand names and whose
    output keys follow the rhs index order.
    """
    terms, out_idx = parse_spec(spec)
    if len(terms) < 1:
        raise ValueError("need at least one operand")
    if isinstance(operands, dict):
        if len(set(terms)) != len(terms):
            raise ValueError("duplicate index terms: pass operands as a list")
        specs = [operands[t] for t in terms]
    else:
        specs = list(operands)
    if len(specs) != len(terms):
        raise ValueError("operand count mismatch")
    return build_einsum(
        terms, out_idx,
        [TraInput(s.name, s.rtype) for s in specs],
        [s.block_sizes for s in specs])
