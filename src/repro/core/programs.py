"""The paper's evaluation workloads as TRA programs (§5.1–§5.3).

Shared by examples/ and benchmarks/: each builder returns lazy
:class:`~repro.core.expr.Expr` programs — built through the fluent
frontend, runnable on any executor via
:class:`~repro.core.engine.Engine` — plus the paper's hand-compiled IA
plan variants so the cost model's choices (Tables 4, 6, 9) can be
reproduced and the plans executed.  (Legacy callers that pass these
results to ``optimize``/``evaluate_*`` still work: every entry point
unwraps ``Expr`` handles.)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core import expr as E
from repro.core.expr import Expr
from repro.core.kernels_registry import (get_kernel, make_scale_mul,
                                         make_to_val_idx)
from repro.core.plan import (Bcast, FusedJoinAgg, IAInput, IANode, LocalAgg,
                             LocalJoin, Placement, Shuf)
from repro.core.tra import RelType

S = ("sites",)


# ==========================================================================
# §5.1 — distributed matrix multiplication (BMM / CPMM / RMM)
# ==========================================================================

def matmul_tra(fa: Tuple[int, int], fb: Tuple[int, int],
               ba: Tuple[int, int], bb: Tuple[int, int]) -> Expr:
    """C = A @ B over chunked relations — the §2.1 running example."""
    return E.input("A", fa, ba) @ E.input("B", fb, bb)


def bmm_plan(fa, fb, ba, bbnd) -> IANode:
    """Broadcast-based MM: A broadcast, B row-partitioned (paper §4.2.2)."""
    a = IAInput("A", RelType(fa, ba), Placement.partitioned((0,), S))
    b = IAInput("B", RelType(fb, bbnd), Placement.partitioned((0,), S))
    j = LocalJoin(Bcast(a), b, (1,), (0,), get_kernel("matMul"))
    return LocalAgg(j, (0, 2), get_kernel("matAdd"))


def cpmm_plan(fa, fb, ba, bbnd) -> IANode:
    """Cross-product MM: A col-partitioned, B row-partitioned; the join is
    co-partitioned on the contraction key; Table-1 shuffle then aggregate."""
    a = IAInput("A", RelType(fa, ba), Placement.partitioned((1,), S))
    b = IAInput("B", RelType(fb, bbnd), Placement.partitioned((0,), S))
    j = LocalJoin(a, b, (1,), (0,), get_kernel("matMul"))
    return LocalAgg(Shuf(j, (0,), S), (0, 2), get_kernel("matAdd"))


def cpmm_two_phase_plan(fa, fb, ba, bbnd) -> IANode:
    """Beyond-paper variant: R2-5 partial aggregation before the shuffle
    (reduce-scatter) — strictly less traffic than cpmm_plan when the
    contraction grid exceeds the site count."""
    a = IAInput("A", RelType(fa, ba), Placement.partitioned((1,), S))
    b = IAInput("B", RelType(fb, bbnd), Placement.partitioned((0,), S))
    j = LocalJoin(a, b, (1,), (0,), get_kernel("matMul"))
    partial = LocalAgg(j, (0, 2), get_kernel("matAdd"), partial=True)
    return Shuf(partial, (0,), S)


def bmm_fused_plan(fa, fb, ba, bbnd) -> IANode:
    """BMM with the Σ∘⋈ pair collapsed into one FusedJoinAgg contraction —
    identical comm cost to :func:`bmm_plan`, no materialized join grid."""
    a = IAInput("A", RelType(fa, ba), Placement.partitioned((0,), S))
    b = IAInput("B", RelType(fb, bbnd), Placement.partitioned((0,), S))
    return FusedJoinAgg(Bcast(a), b, (1,), (0,), get_kernel("matMul"),
                        (0, 2), get_kernel("matAdd"))


def cpmm_fused_plan(fa, fb, ba, bbnd) -> IANode:
    """CPMM as the fused two-phase contraction: each site contracts its
    key window in one blocked matmul (partial FusedJoinAgg), then a single
    SHUF reduce-scatters the pending partials — the plan the paper's
    Σ∘⋈-as-contraction claim describes."""
    a = IAInput("A", RelType(fa, ba), Placement.partitioned((1,), S))
    b = IAInput("B", RelType(fb, bbnd), Placement.partitioned((0,), S))
    fused = FusedJoinAgg(a, b, (1,), (0,), get_kernel("matMul"),
                         (0, 2), get_kernel("matAdd"), partial=True)
    return Shuf(fused, (0,), S)


def rmm_cost(fa, fb, ba, bbnd, sites: int, accounting: str = "paper") -> int:
    """Analytic RMM cost per paper §4.2.2.

    The paper's construction sets ``xDups = Front(R_B)[1]`` (B's column
    grid) and ``yDups = Front(R_A)[0]`` (A's row grid) with both operands
    initially partitioned by dimension 0.  With A stored row-partitioned
    in a (s, 1) grid, ``xDups = 1`` — A is not duplicated and its shuffle
    is a no-op under the optimized initial layout — while B is duplicated
    ``yDups = s`` times and shuffled once:

        cost_paper = f_B × s

    which reproduces Table 4's RMM column exactly on all three shapes.
    ``accounting="wire"`` instead prices the balanced 3-D (p1·p2·p3 = s)
    grid: f_A·(p3−1) + f_B·(p1−1) wire floats.
    """
    fa_floats = int(fa[0] * fa[1] * ba[0] * ba[1])
    fb_floats = int(fb[0] * fb[1] * bbnd[0] * bbnd[1])
    if accounting == "paper":
        return fb_floats * sites
    # balanced 3-D grid for the wire variant
    best = (sites, 1, 1)
    best_score = None
    for p1 in range(1, sites + 1):
        if sites % p1:
            continue
        rest = sites // p1
        for p2 in range(1, rest + 1):
            if rest % p2:
                continue
            p3 = rest // p2
            score = max(p1, p2, p3) / min(p1, p2, p3)
            if best_score is None or score < best_score:
                best_score = score
                best = (p1, p2, p3)
    p1, p2, p3 = best
    return fa_floats * (p3 - 1) + fb_floats * (p1 - 1)


# ==========================================================================
# §5.2 — nearest neighbour search in a Riemannian metric space
# ==========================================================================

@dataclasses.dataclass
class NNSearchProgram:
    dist: Expr               # (nblocks,)-keyed distance blocks
    result: Expr             # single (val, idx) pair after concat+argmin


def nn_search_tra(n_blocks: int, d_blocks: int, rows: int, dcol: int
                  ) -> NNSearchProgram:
    """d_A(x_i, x_q) = (x_i − x_q) A (x_i − x_q)ᵀ for every row i.

    Relations: R_xq keyed (d,) bound (1, dcol); R_X keyed (n, d) bound
    (rows, dcol); R_A keyed (d, d) bound (dcol, dcol).

    ``dist`` is shared between the returned roots — with the Expr DAG it
    is evaluated once even when both are computed in one engine run.
    """
    rxq = E.input("xq", (d_blocks,), (1, dcol))
    rx = E.input("X", (n_blocks, d_blocks), (rows, dcol))
    ra = E.input("A", (d_blocks, d_blocks), (dcol, dcol))

    # R_diff[n, d] = X − xq  (join on the feature-block key); keys arrive
    # (d, n) — reorder to (n, d)
    diff = rxq.join(rx, on=((0,), (1,)), kernel="matVecSub") \
              .rekey(lambda k: (k[1], k[0]), tag="swap")

    # R_proj[n, d'] = Σ_d diff · A
    proj = diff @ ra

    # R_dist[n] = rowSum(proj ⊙ diff); agg grouped (0,1) keeps both key
    # dims and rowSum drops the col dim of the block — re-aggregate over
    # d to a (n,)-keyed relation
    dist = (proj * diff).agg((0, 1), "matAdd").map("rowSum").sum(0)

    # global argmin: concatenate the blocks and take (val, idx) once —
    # indices are then global by construction
    result = dist.concat(0, 0).map(make_to_val_idx(rows * n_blocks))
    return NNSearchProgram(dist, result)


# ==========================================================================
# §5.3 — two-layer FFNN SGD step
# ==========================================================================

@dataclasses.dataclass
class FFNNProgram:
    """One SGD step: inputs X, Y, W1, W2 → outputs W1', W2'."""

    w1_new: Expr
    w2_new: Expr
    a2: Expr
    g_w1: Optional[Expr] = None          # raw weight gradients
    g_w2: Optional[Expr] = None


def _ffnn_forward(nb, db, hb, lb, bn, bd, bh, bl):
    rx = E.input("X", (nb, db), (bn, bd))
    ry = E.input("Y", (nb, lb), (bn, bl))
    rw1 = E.input("W1", (db, hb), (bd, bh))
    rw2 = E.input("W2", (hb, lb), (bh, bl))
    a1 = (rx @ rw1).map("relu")
    z2 = a1 @ rw2
    a2 = z2.map("sigmoid")
    return rx, ry, rw1, rw2, a1, z2, a2


def ffnn_step_tra(nb: int, db: int, hb: int, lb: int,
                  bn: int, bd: int, bh: int, bl: int,
                  eta: float = 0.01) -> FFNNProgram:
    """Paper §5.3, with the backward pass **derived by autodiff** from the
    forward plan (Tang et al., arXiv 2306.00088) instead of hand-written.

    The forward pass is the paper's: ``a2 = σ(relu(X@W1)@W2)``.  The
    paper's hand backward uses the classic sigmoid-cross-entropy shortcut
    ``∂L/∂z2 = a2 − Y``; we reproduce it exactly by differentiating the
    *pre-activation* ``z2`` with the seed cotangent ``a2 − Y`` — the
    gradient expressions for W1 and W2 are then emitted by
    :func:`repro.core.autodiff.grad`, not written out.  The hand-built
    version survives as :func:`ffnn_step_tra_hand`, the correctness
    oracle the autodiff output is tested against.
    """
    rx, ry, rw1, rw2, a1, z2, a2 = _ffnn_forward(
        nb, db, hb, lb, bn, bd, bh, bl)
    d_a2 = a2 - ry                       # ∂(Σ BCE(σ(z2), Y))/∂z2
    g_w1, g_w2 = z2.grad(["W1", "W2"], seed=d_a2)

    scale = make_scale_mul(eta)
    w2_new = rw2 - g_w2.map(scale)
    w1_new = rw1 - g_w1.map(scale)
    return FFNNProgram(w1_new, w2_new, a2, g_w1, g_w2)


def ffnn_step_tra_hand(nb: int, db: int, hb: int, lb: int,
                       bn: int, bd: int, bh: int, bl: int,
                       eta: float = 0.01) -> FFNNProgram:
    """Paper §5.3 verbatim (with relu/sigmoid activations) — the
    hand-written backward pass, kept as the autodiff correctness oracle.

    Key grids: X (nb, db), Y (nb, lb), W1 (db, hb), W2 (hb, lb); block
    bounds (bn, bd) etc.  The three roots share ``a1``/``a2``/``d_a2`` as
    DAG nodes, so one engine run over ``(w1_new, w2_new, a2)`` evaluates
    the forward pass once.
    """
    rx, ry, rw1, rw2, a1, z2, a2 = _ffnn_forward(
        nb, db, hb, lb, bn, bd, bh, bl)

    # backward.  NOTE an erratum in the paper's §5.3 expressions: the
    # weight-gradient aggregations are written Σ_(⟨0,2⟩,·) like the matmul
    # template, but their joins contract on key position 0 (the batch
    # block), so TRA-correct group-by keys are ⟨1,2⟩ — otherwise the
    # output would stay keyed by batch block.  (Verified against a direct
    # jnp implementation of the same SGD step; see tests.)
    d_a2 = a2 - ry
    g_w2 = a1.join(d_a2, on=((0,), (0,)),
                   kernel="matTranMulL").agg((1, 2), "matAdd")
    d_a1_1 = d_a2.join(rw2, on=((1,), (1,)),
                       kernel="matTranMulR").agg((0, 2), "matAdd")
    d_a1 = a1.map("reluGrad") * d_a1_1
    g_w1 = rx.join(d_a1, on=((0,), (0,)),
                   kernel="matTranMulL").agg((1, 2), "matAdd")

    # update
    scale = make_scale_mul(eta)
    w2_new = rw2 - g_w2.map(scale)
    w1_new = rw1 - g_w1.map(scale)
    return FFNNProgram(w1_new, w2_new, a2, g_w1, g_w2)


def ffnn_train_step_tra(nb: int, db: int, hb: int, lb: int,
                        bn: int, bd: int, bh: int, bl: int,
                        optimizer=None):
    """§5.3 FFNN as ONE compiled TRA train step: forward + BCE loss +
    autodiff-derived backward + optimizer update, a single named
    multi-root program (see :mod:`repro.core.train`).

    The loss root is the blockwise binary-cross-entropy partial sums
    (``bceSum`` join of ``a2`` with ``Y``, keyed by the (batch, label)
    block grid); its array total is the scalar Σ-BCE loss whose gradient
    w.r.t. the pre-activation ``z2`` is exactly the paper's seed
    ``a2 − Y`` — so the backward sub-DAG is the same autodiff derivation
    :func:`ffnn_step_tra` tests against the paper's hand expressions,
    now composed with the optimizer's update expressions instead of the
    fixed ``scaleMul`` SGD write-out.

    ``optimizer`` is any :class:`repro.core.train.TraOptimizer`
    (default: plain :class:`~repro.core.train.SGD` at the paper's
    η = 0.01).  Returns a :class:`repro.core.train.TrainStep` whose
    ``roots`` compile once and re-dispatch every step on any executor.
    """
    from repro.core.train import SGD, make_train_step
    if optimizer is None:
        optimizer = SGD(lr=0.01)
    rx, ry, rw1, rw2, a1, z2, a2 = _ffnn_forward(
        nb, db, hb, lb, bn, bd, bh, bl)
    loss = a2.join(ry, on=((0, 1), (0, 1)), kernel="bceSum")
    d_a2 = a2 - ry                       # ∂(Σ BCE(σ(z2), Y))/∂z2
    return make_train_step(loss, ["W1", "W2"], optimizer,
                           grad_of=z2, seed=d_a2)


def ffnn_dp_placements(nb, db, hb, lb) -> Dict[str, Placement]:
    """TRA-DP: batch-partitioned data, weights broadcast each step
    (stored partitioned on dim 0, as the paper describes)."""
    return {"X": Placement.partitioned((0,), S),
            "Y": Placement.partitioned((0,), S),
            "W1": Placement.partitioned((0,), S),
            "W2": Placement.partitioned((0,), S)}


def ffnn_mp_placements(nb, db, hb, lb) -> Dict[str, Placement]:
    """TRA-MP: intra-operator model parallelism — W1 col-, W2 row-
    partitioned; batches partitioned on the feature dim."""
    return {"X": Placement.partitioned((1,), S),
            "Y": Placement.partitioned((1,), S),
            "W1": Placement.partitioned((1,), S),
            "W2": Placement.partitioned((0,), S)}
