"""Lazy expression frontend for the TRA — the user-facing API.

The paper's point is that the TRA is *declarative*: a computation written
once against the logical algebra can be re-optimized and retargeted across
back-ends.  :class:`Expr` makes that the ergonomic default.  An ``Expr`` is
a thin immutable handle over a logical :class:`~repro.core.plan.TraNode`
with

* **method chaining / operator overloading** — ``A.join(B, on=...).agg(...)``,
  ``A @ B`` for the §5.1 matmul pattern, ``A + B`` / ``A - B`` / ``A * B``
  for keywise elementwise joins;
* **eager type inference** — every constructor runs the exact static
  type/frontier/mask inference at *build* time, so shape mistakes raise
  where the expression is written, not where it is run;
* **true DAG sharing** — reusing one ``Expr`` in several places reuses the
  same underlying node, and every executor caches by node identity, so a
  shared subexpression is evaluated exactly once per run.

Expressions carry no data and no executor: pair them with
:class:`repro.core.engine.Engine`, whose ``run``/``compile`` are the only
two evaluation entry points.

    >>> import repro.core as tra
    >>> A = tra.input("A", key_shape=(4, 4), bound=(16, 24))
    >>> B = tra.input("B", key_shape=(4, 4), bound=(24, 12))
    >>> C = A @ B                       # Σ_(⟨0,2⟩,+) ∘ ⋈_(⟨1⟩,⟨0⟩,matMul)
    >>> tra.Engine().run(C, A=RA, B=RB)

``einsum`` builds through the same constructors, so every frontend —
fluent, operator, Einstein notation — lands on one optimizer entry path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence, Tuple, Union

import jax.numpy as jnp

from repro.core.kernels_registry import Kernel, get_kernel
from repro.core.plan import (TraAgg, TraConcat, TraConst, TraFilter,
                             TraInput, TraJoin, TraNode, TraPad, TraReKey,
                             TraTile, TraTransform, TypeInfo, infer)
from repro.core.tra import RelType

KernelLike = Union[Kernel, str]


def _kern(k: KernelLike) -> Kernel:
    return get_kernel(k) if isinstance(k, str) else k


class ExprTypeError(TypeError):
    """Build-time type/shape error in an Expr constructor."""


def _describe_rtype(info: TypeInfo) -> str:
    return f"f={info.rtype.key_shape} b={info.rtype.bound}"


@dataclasses.dataclass(frozen=True)
class Expr:
    """Immutable lazy handle over a logical TRA plan node.

    ``node`` is the wrapped :class:`TraNode`; ``info`` its eagerly inferred
    :class:`TypeInfo` (exact key frontier, bound, static mask).  Building
    an invalid expression raises :class:`ExprTypeError` immediately.
    """

    node: TraNode
    info: TypeInfo

    # -- introspection -----------------------------------------------------
    @property
    def rtype(self) -> RelType:
        return self.info.rtype

    @property
    def key_shape(self) -> Tuple[int, ...]:
        return self.info.rtype.key_shape

    @property
    def bound(self) -> Tuple[int, ...]:
        return self.info.rtype.bound

    @property
    def key_arity(self) -> int:
        return self.info.rtype.key_arity

    def describe(self) -> str:
        from repro.core.plan import describe
        return describe(self.node)

    def __repr__(self) -> str:
        return (f"Expr<{type(self.node).__name__} "
                f"{_describe_rtype(self.info)}>")

    # -- algebra -----------------------------------------------------------
    def join(self, other: "Expr",
             on: Union[Sequence[int], Tuple[Sequence[int], Sequence[int]]],
             kernel: KernelLike) -> "Expr":
        """⋈_(on, kernel)(self, other).

        ``on`` is either one key-dim list shared by both sides or a
        ``(left_dims, right_dims)`` pair.
        """
        other = _as_expr(other)
        if (len(on) == 2 and on and not isinstance(on[0], int)):
            jkl, jkr = tuple(on[0]), tuple(on[1])
        else:
            jkl = jkr = tuple(on)          # type: ignore[arg-type]
        return _build(TraJoin(self.node, other.node, jkl, jkr, _kern(kernel)),
                      "join", self, other)

    def agg(self, group_by: Sequence[int],
            kernel: KernelLike = "matAdd") -> "Expr":
        """Σ_(group_by, kernel)(self)."""
        return _build(TraAgg(self.node, tuple(group_by), _kern(kernel)),
                      "agg", self)

    def sum(self, *group_by: int) -> "Expr":
        """Shorthand for ``agg(group_by, "matAdd")``."""
        return self.agg(group_by, "matAdd")

    def rekey(self, key_func: Callable, tag: str = "keyFunc") -> "Expr":
        return _build(TraReKey(self.node, key_func, tag), "rekey", self)

    def filter(self, bool_func: Callable, tag: str = "boolFunc") -> "Expr":
        return _build(TraFilter(self.node, bool_func, tag), "filter", self)

    def map(self, kernel: KernelLike) -> "Expr":
        """λ_(kernel)(self) — apply a unary kernel to every array."""
        return _build(TraTransform(self.node, _kern(kernel)), "map", self)

    transform = map

    def tile(self, tile_dim: int, tile_size: int) -> "Expr":
        return _build(TraTile(self.node, tile_dim, tile_size), "tile", self)

    def concat(self, key_dim: int, array_dim: int) -> "Expr":
        return _build(TraConcat(self.node, key_dim, array_dim),
                      "concat", self)

    def pad(self, key_shape: Sequence[int]) -> "Expr":
        """Pad_(keyShape)(self) — densify to the full key grid (the dual
        of σ: holes become zero tuples, the frontier grows)."""
        return _build(TraPad(self.node, tuple(key_shape)), "pad", self)

    def scale_by(self, scalar: "Expr") -> "Expr":
        """Multiply every array by a *scalar relation* (key ``(1,)``,
        bound ``(1, 1)``).

        The scalar joins in on no key dims (a broadcast join with the
        ``scaleBy`` kernel; jnp broadcasting over the trailing block dims
        does the arithmetic) and the appended singleton key dim is
        aggregated away.  This is how per-step scalars — Adam bias
        corrections, learning-rate schedules — thread through a compiled
        train-step program as *data* instead of kernel constants, so one
        compiled artifact serves every step (see :mod:`repro.core.train`).
        """
        scalar = _as_expr(scalar)
        if scalar.key_shape != (1,) or scalar.bound != (1, 1):
            raise ExprTypeError(
                f"scale_by needs a scalar relation (key (1,), bound "
                f"(1, 1) — tra.scalar / tra.scalar_input), got "
                f"{_describe_rtype(scalar.info)}")
        k = self.key_arity
        j = self.join(scalar, on=((), ()), kernel="scaleBy")
        return j.agg(tuple(range(k)), "matAdd")

    def slot_update(self, rows: "Expr", mask: "Expr") -> "Expr":
        """Masked in-plan slot update: ``mask·rows + (1−mask)·self``.

        The carrier of continuous-batching decode state
        (:mod:`repro.serve`): ``self`` is a fixed-capacity slot-keyed
        state relation, ``rows`` the freshly computed per-slot values
        (keyed identically), and ``mask`` an activity relation over the
        same key grid with ``(1, 1)`` blocks — ``1.0`` rows take the new
        value, ``0.0`` rows keep the old state unchanged, so inactive /
        mid-eviction slots never drift inside a compiled step program.
        Built from keywise ``scaleBy`` joins and a ``matAdd`` — no new
        plan node, so every executor, the optimizer, and autodiff see
        plain algebra.
        """
        rows = _as_expr(rows)
        mask = _as_expr(mask)
        if rows.key_shape != self.key_shape:
            raise ExprTypeError(
                f"slot_update: rows key grid {rows.key_shape} != state "
                f"key grid {self.key_shape}")
        if mask.key_shape != self.key_shape or mask.bound != (1, 1):
            raise ExprTypeError(
                f"slot_update: mask must be keyed {self.key_shape} with "
                f"(1, 1) blocks, got {_describe_rtype(mask.info)}")
        on = tuple(range(self.key_arity))
        inv = const(1.0, mask.key_shape, mask.bound, mask.rtype.dtype) - mask
        take = rows.join(mask, on=on, kernel="scaleBy")
        keep = self.join(inv, on=on, kernel="scaleBy")
        return take + keep

    # -- differentiation ---------------------------------------------------
    def grad(self, wrt, seed: "Expr" = None):
        """Cotangent expression(s) of ``self`` w.r.t. input(s) ``wrt``.

        The backward graph is derived from this expression's plan by
        :mod:`repro.core.autodiff` and is itself an ``Expr`` DAG — run it
        on any executor, optimizer fusion included.  ``wrt`` is an input
        name / input ``Expr`` (returns one ``Expr``) or a sequence of
        them (returns a tuple); ``seed`` overrides the default ones
        cotangent (∂Σ(out)/∂out).

            >>> z = (x @ w).map("relu")
            >>> dw = z.grad("W")                  # d Σ(relu(x@w)) / dW
        """
        from repro.core.autodiff import grad as _grad
        single = isinstance(wrt, (str, Expr))
        outs = _grad(self, [wrt] if single else list(wrt), seed=seed)
        return outs[0] if single else outs

    # -- operator sugar ----------------------------------------------------
    def _keywise(self, other: "Expr", kernel: str) -> "Expr":
        other = _as_expr(other)
        k = self.key_arity
        if other.key_arity != k:
            raise ExprTypeError(
                f"{kernel}: key arity mismatch — left has {k} key dims "
                f"({_describe_rtype(self.info)}), right has "
                f"{other.key_arity} ({_describe_rtype(other.info)})")
        return self.join(other, on=tuple(range(k)), kernel=kernel)

    def __add__(self, other: "Expr") -> "Expr":
        return self._keywise(other, "matAdd")

    def __sub__(self, other: "Expr") -> "Expr":
        return self._keywise(other, "matSub")

    def __mul__(self, other: "Expr") -> "Expr":
        return self._keywise(other, "elemMul")

    def __matmul__(self, other: "Expr") -> "Expr":
        """Blocked matrix product — the paper's §2.1 running example.

        ``Σ_(⟨0,2⟩, matAdd)(⋈_(⟨1⟩,⟨0⟩, matMul)(self, other))`` over
        matrix-chunked relations (key arity 2, rank-2 bounds).
        """
        other = _as_expr(other)
        for side, e in (("left", self), ("right", other)):
            if e.key_arity != 2 or e.info.rtype.rank != 2:
                raise ExprTypeError(
                    f"@: {side} operand must be a matrix-chunked relation "
                    f"(2 key dims, rank-2 bound), got "
                    f"{_describe_rtype(e.info)}")
        return self.join(other, on=((1,), (0,)),
                         kernel="matMul").agg((0, 2), "matAdd")


def _as_expr(obj) -> Expr:
    if isinstance(obj, Expr):
        return obj
    if isinstance(obj, TraNode):
        return wrap(obj)
    raise ExprTypeError(f"expected an Expr, got {type(obj).__name__}")


def _build(node: TraNode, op: str, *operands: Expr) -> Expr:
    """Construct an Expr, running inference now so errors are build-time."""
    try:
        info = infer(node)
    except (ValueError, TypeError, KeyError, IndexError) as exc:
        ops = "; ".join(f"{type(o.node).__name__}[{_describe_rtype(o.info)}]"
                        for o in operands)
        raise ExprTypeError(
            f"cannot build {op} over {ops}: {exc}") from exc
    return Expr(node, info)


# ==========================================================================
# Constructors
# ==========================================================================

def input(name: str, key_shape: Sequence[int], bound: Sequence[int],
          dtype=jnp.float32) -> Expr:  # noqa: A001 — mirrors tf.placeholder
    """A named logical input of type ``R^(f=key_shape, b=bound)``."""
    rt = RelType(tuple(key_shape), tuple(bound), dtype)
    return wrap(TraInput(name, rt))


def input_like(name: str, rtype: RelType) -> Expr:
    """A named logical input matching an existing :class:`RelType`."""
    return wrap(TraInput(name, rtype))


def const(fill: float, key_shape: Sequence[int], bound: Sequence[int],
          dtype=jnp.float32) -> Expr:
    """A literal constant relation (every key maps to a ``fill`` array).

    Materialized locally by every executor — zero communication cost."""
    return wrap(TraConst(RelType(tuple(key_shape), tuple(bound), dtype),
                         float(fill)))


def scalar(fill: float, dtype=jnp.float32) -> Expr:
    """A literal *scalar relation* — key ``(1,)``, bound ``(1, 1)``.

    The carrier type for per-step scalars (step counts, schedules) in
    :mod:`repro.core.train`; apply one with :meth:`Expr.scale_by`."""
    return const(fill, (1,), (1, 1), dtype)


def scalar_input(name: str, dtype=jnp.float32) -> Expr:
    """A named scalar-relation input (key ``(1,)``, bound ``(1, 1)``)."""
    return input(name, (1,), (1, 1), dtype)


def ones_like(e: Expr) -> Expr:
    """A ones constant typed like ``e`` — the default autodiff seed."""
    e = _as_expr(e)
    return wrap(TraConst(e.info.rtype, 1.0))


def wrap(node: TraNode) -> Expr:
    """Wrap an existing logical plan node (type-checks it eagerly)."""
    return _build(node, type(node).__name__)


def einsum(spec: str, *operands: Expr) -> Expr:
    """Einstein-notation frontend (paper §2.3) over ``Expr`` operands.

    Builds the paper's binary-production construction — one join +
    aggregation per contraction step — through the same ``Expr``
    constructors as the fluent API, so einsum expressions flow through the
    identical optimizer entry path.

        >>> C = tra.einsum("ij,jk->ik", A, B)

    Each operand's key arity and rank must both equal its index-term
    length (one key dim + one array dim per index).
    """
    from repro.core.einsum_frontend import build_einsum
    terms, out_idx = _parse_einsum_terms(spec, operands)
    exprs = [_as_expr(o) for o in operands]
    for t, e in zip(terms, exprs):
        if e.key_arity != len(t) or e.info.rtype.rank != len(t):
            raise ExprTypeError(
                f"einsum term '{t}' needs {len(t)} key dims and rank "
                f"{len(t)}, got {_describe_rtype(e.info)}")
    node = build_einsum(
        terms, out_idx,
        [e.node for e in exprs],
        [e.bound for e in exprs])
    return wrap(node)


def _parse_einsum_terms(spec: str, operands) -> Tuple[list, str]:
    from repro.core.einsum_frontend import parse_spec
    terms, out_idx = parse_spec(spec)
    if len(terms) != len(operands):
        raise ExprTypeError(
            f"einsum '{spec}' has {len(terms)} terms but "
            f"{len(operands)} operands were given")
    return terms, out_idx
