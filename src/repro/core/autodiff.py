"""Reverse-mode differentiation of TRA expressions (the Tang et al.
direction: arXiv 2306.00088, "Auto-Differentiation of Relational
Computations for Very Large Scale Machine Learning").

The paper's §5.3 writes the FFNN backward pass *by hand* as TRA
expressions.  This module derives it instead: given a lazy
:class:`~repro.core.expr.Expr` forward DAG, :func:`grad` emits the
cotangent of every requested input **as another Expr DAG** — plain joins,
aggregations, maps, pads — so the backward plan flows through the same
cost-based optimizer (including the fused Σ∘⋈ contraction selection) and
runs on every executor, exactly like a forward plan.

Three ingredients:

* **kernel-level derivative rules** — every differentiable
  :class:`~repro.core.kernels_registry.Kernel` carries a ``vjp``:
  binary (join) kernels name the registered kernel computing each
  operand's cotangent (``matMul → (matTranMulR, matTranMulL)``, the
  paper's §5.3 kernel triple); unary (map) kernels provide an
  Expr-builder (``relu → reluGrad(z)·g``);

* **a direct Σ∘⋈ backward rule** — the cotangent of a contraction
  ``Σ_(gb)(⋈(L, R))`` is emitted as one join + one aggregation per
  operand (``dL = Σ(⋈(G, R, vjp_l))``), *not* as a broadcast-back
  followed by a join over the materialized grid, so backward plans
  contain the same ``agg(join(·))`` shape the optimizer fuses;

* **fan-in accumulation** — a node consumed by several operations sums
  its cotangent contributions with keywise ``matAdd`` joins; the
  :class:`~repro.core.plan.TraPad` densify op aligns contributions onto
  one common key grid (zero at filtered-out / out-of-window keys).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.expr import Expr, ExprTypeError, wrap
from repro.core.kernels_registry import JoinVjp, Kernel
from repro.core.plan import (TraAgg, TraConcat, TraConst, TraFilter,
                             TraInput, TraJoin, TraNode, TraPad, TraReKey,
                             TraTile, TraTransform, TypeInfo, children,
                             infer, postorder)
from repro.core.tra import RelType

WrtLike = Union[str, Expr]


class AutodiffError(ExprTypeError, ValueError):
    """A forward expression (or one of its kernels) has no derivative
    rule, or its cotangent cannot be expressed in the algebra.

    Subclasses :class:`~repro.core.expr.ExprTypeError` (these are
    build-time type errors of the backward expression) and ``ValueError``
    (compatibility with pre-PR-4 callers)."""


# aggregation kernels with a derivative rule: matAdd flows the cotangent
# straight through (broadcast-back / direct Σ∘⋈); elemMax / elemMin route
# it through the argmax-mask construction below
DIFFERENTIABLE_AGGS = ("matAdd", "elemMax", "elemMin")


# ==========================================================================
# Contraction backward: the operand cotangent of Σ_(gb, matAdd)∘⋈(L, R)
# ==========================================================================

def _contraction_vjp(G: Expr, side: str, left: Expr, right: Expr,
                     jkl: Tuple[int, ...], jkr: Tuple[int, ...],
                     gb: Tuple[int, ...],
                     spec: JoinVjp) -> Optional[Expr]:
    """Cotangent of the ``side`` operand of ``Σ_(gb)∘⋈_(jkl,jkr)(L, R)``.

    ``G`` is keyed by the ``gb``-selected subspace of the join's output
    key space ``k_out`` (= left keys ++ right non-join keys).  Emits one
    backward join (between ``G`` and the *other* operand, applying the
    ``spec`` kernel) followed by one matAdd aggregation restoring the
    target operand's key space — the structure the optimizer's fused
    Σ∘⋈ selection recognizes.  Returns ``None`` when a reduced key axis
    of the target cannot be recovered from the backward join's key space
    (caller falls back to the broadcast-back construction).
    """
    kl, kr = left.key_arity, right.key_arity
    r_nonjoin = [d for d in range(kr) if d not in jkr]
    axis_of_right = {}
    for i, d in enumerate(jkr):
        axis_of_right[d] = jkl[i]
    for i, d in enumerate(r_nonjoin):
        axis_of_right[d] = kl + i
    pos_in_gb = {a: i for i, a in enumerate(gb)}

    if side == "left":
        target, other = left, right
        target_axes = list(range(kl))
        other_axes = [axis_of_right[d] for d in range(kr)]
    else:
        target, other = right, left
        target_axes = [axis_of_right[d] for d in range(kr)]
        other_axes = list(range(kl))

    # feasibility: every target key axis must be recoverable — either kept
    # by the aggregation (in gb → on the G side) or joined with an
    # other-operand key dim (→ on the other side of the backward join)
    other_axis_set = set(other_axes)
    for a in target_axes:
        if a not in pos_in_gb and a not in other_axis_set:
            return None

    # backward join: pair every G dim whose k_out axis an other-operand
    # dim covers with that dim
    on_g, on_o = [], []
    for od, a in enumerate(other_axes):
        if a in pos_in_gb:
            on_g.append(pos_in_gb[a])
            on_o.append(od)

    if spec.cot_first:
        joined = G.join(other, on=(tuple(on_g), tuple(on_o)),
                        kernel=spec.kernel)
        # output keys: all G dims (leading), then unjoined other dims
        tail = [od for od in range(len(other_axes)) if od not in on_o]
        pos_of_g = {g: g for g in range(len(gb))}
        pos_of_other = {od: len(gb) + i for i, od in enumerate(tail)}
        for g, od in zip(on_g, on_o):
            pos_of_other[od] = g
        n_out = len(gb) + len(tail)
    else:
        joined = other.join(G, on=(tuple(on_o), tuple(on_g)),
                            kernel=spec.kernel)
        # output keys: all other dims (leading), then unjoined G dims
        unjoined = [g for g in range(len(gb)) if g not in on_g]
        pos_of_other = {od: od for od in range(len(other_axes))}
        pos_of_g = {g: len(other_axes) + i for i, g in enumerate(unjoined)}
        for g, od in zip(on_g, on_o):
            pos_of_g[g] = od
        n_out = len(other_axes) + len(unjoined)

    group_by = []
    for a in target_axes:
        if a in pos_in_gb:
            group_by.append(pos_of_g[pos_in_gb[a]])
        else:
            group_by.append(pos_of_other[other_axes.index(a)])

    if group_by != list(range(n_out)):
        out = joined.agg(tuple(group_by), "matAdd")
    else:
        out = joined
    if out.key_shape != target.key_shape:
        # joined frontiers were min-sliced in the forward pass: the
        # out-of-window target entries never contributed → zero cotangent
        out = wrap(TraPad(out.node, target.key_shape))
    return out


# ==========================================================================
# The reverse-mode transform
# ==========================================================================

def _accumulate(contribs: List[Expr], key_shape: Tuple[int, ...]) -> Expr:
    """Sum cotangent contributions onto the primal's key grid."""
    fixed = []
    for c in contribs:
        if c.key_shape != tuple(key_shape) or c.info.mask is not None:
            c = wrap(TraPad(c.node, tuple(key_shape)))
        fixed.append(c)
    total = fixed[0]
    for c in fixed[1:]:
        total = total + c
    return total


def _agg_broadcast_back(node: TraAgg, child_info: TypeInfo,
                        G: Expr) -> Expr:
    """Generic Σ_(gb, matAdd) backward: replicate ``G`` over the reduced
    key dims.  A zero-cost :class:`TraConst` donates the pre-aggregation
    key space; ``gradR`` projects the cotangent through the join."""
    donor = wrap(TraConst(
        RelType(child_info.rtype.key_shape, (1,), child_info.rtype.dtype),
        0.0))
    gb = tuple(node.group_by)
    return donor.join(G, on=(gb, tuple(range(len(gb)))), kernel="gradR")


def _agg_minmax_vjp(node: TraAgg, child_info: TypeInfo, G: Expr) -> Expr:
    """Backward of a max/min aggregation via the argmax-mask construction.

    The cotangent of the reduced child is ``G`` routed to the extremal
    entries only: ``mask = (child == broadcast(out))`` selects them, and
    dividing by the broadcast tie count splits the cotangent evenly among
    ties — exactly ``jax.grad``'s convention for ``reduce_max``.  Every
    step is a plain TRA op (keywise joins + one matAdd aggregation), so
    the backward plan optimizes and executes like any other."""
    child = wrap(node.child)
    out = wrap(node)                     # shared forward DAG node
    k = child_info.rtype.key_arity
    cokey = (tuple(range(k)), tuple(range(k)))
    bo = _agg_broadcast_back(node, child_info, out)
    bg = _agg_broadcast_back(node, child_info, G)
    mask = child.join(bo, on=cokey, kernel="eqMask")
    ties = mask.agg(tuple(node.group_by), "matAdd")
    bt = _agg_broadcast_back(node, child_info, ties)
    return mask.join(bg, on=cokey, kernel="elemMul") \
               .join(bt, on=cokey, kernel="elemDiv")


def _join_vjp_specs(kernel: Kernel) -> Tuple[Optional[JoinVjp],
                                             Optional[JoinVjp]]:
    v = kernel.vjp
    if v is None:
        return (None, None)
    if not (isinstance(v, tuple) and len(v) == 2):
        raise AutodiffError(
            f"binary kernel {kernel.name} carries a malformed vjp rule")
    return v


def grad(expr: Expr, wrt: Sequence[WrtLike],
         seed: Optional[Expr] = None) -> Tuple[Expr, ...]:
    """Cotangent expressions of ``expr`` w.r.t. the named inputs.

    ``seed`` is the root cotangent (an Expr of the same relation type);
    ``None`` seeds with ones — the gradient of ``Σ`` over every entry of
    every output array.  Returns one Expr per ``wrt`` entry, each typed
    exactly like its input (inputs the output does not depend on get a
    zero constant).
    """
    if not isinstance(expr, Expr):
        expr = wrap(expr)
    root = expr.node
    order = postorder(root)
    infos: Dict[int, TypeInfo] = {}
    cache: Dict[int, TypeInfo] = {}
    for n in order:
        infos[id(n)] = infer(n, cache=cache)

    names = []
    for w in wrt:
        if isinstance(w, Expr):
            if not isinstance(w.node, TraInput):
                raise AutodiffError(
                    "wrt entries must be input names or input Exprs")
            names.append(w.node.name)
        else:
            names.append(w)
    have = {n.name for n in order if isinstance(n, TraInput)}
    unknown = [nm for nm in names if nm not in have]
    if unknown:
        raise AutodiffError(
            f"wrt inputs {unknown} do not occur in the expression "
            f"(inputs: {sorted(have)})")

    # active = nodes whose subtree contains a wrt input
    active: set = set()
    for n in order:                       # children precede parents
        if isinstance(n, TraInput) and n.name in names:
            active.add(id(n))
        elif any(id(c) in active for c in children(n)):
            active.add(id(n))
    if id(root) not in active:
        # output independent of every wrt input → all-zero gradients
        return tuple(
            wrap(TraConst(_input_rtype(order, nm), 0.0)) for nm in names)

    if seed is None:
        seed = wrap(TraConst(infos[id(root)].rtype, 1.0))
    if (seed.key_shape != infos[id(root)].rtype.key_shape
            or seed.bound != infos[id(root)].rtype.bound):
        raise AutodiffError(
            f"seed type f={seed.key_shape} b={seed.bound} does not match "
            f"the root's f={infos[id(root)].rtype.key_shape} "
            f"b={infos[id(root)].rtype.bound}")

    consumers: Dict[int, int] = {}
    for n in order:
        for c in children(n):
            consumers[id(c)] = consumers.get(id(c), 0) + 1

    cots: Dict[int, List[Expr]] = {id(root): [seed]}
    grads: Dict[str, List[Expr]] = {nm: [] for nm in names}

    def contribute(node: TraNode, c: Expr) -> None:
        cots.setdefault(id(node), []).append(c)

    for n in reversed(order):             # parents precede children
        contribs = cots.get(id(n))
        if not contribs or id(n) not in active:
            continue
        G = _accumulate(contribs, infos[id(n)].rtype.key_shape)
        _backward(n, G, infos, active, consumers, contribute, grads, names,
                  cots)

    outs = []
    for nm in names:
        rtype = _input_rtype(order, nm)
        if grads[nm]:
            outs.append(_accumulate(grads[nm], rtype.key_shape))
        else:
            outs.append(wrap(TraConst(rtype, 0.0)))
    return tuple(outs)


def _input_rtype(order, name: str) -> RelType:
    for n in order:
        if isinstance(n, TraInput) and n.name == name:
            return n.rtype
    raise KeyError(name)


def _backward(n: TraNode, G: Expr, infos, active, consumers, contribute,
              grads, names, cots) -> None:
    """Propagate the accumulated cotangent ``G`` of ``n`` one step."""
    if isinstance(n, TraInput):
        if n.name in names:
            grads[n.name].append(G)
        return
    if isinstance(n, TraConst):
        return

    if isinstance(n, TraAgg):
        if n.kernel.name in ("elemMax", "elemMin"):
            contribute(n.child,
                       _agg_minmax_vjp(n, infos[id(n.child)], G))
            return
        if n.kernel.name != "matAdd":
            hint = ("product aggregations are not differentiable here — "
                    "rewrite as Σ of logs where the data permits"
                    if n.kernel.name == "elemMul" else
                    "use a differentiable aggregation or stop the "
                    "gradient before it")
            raise AutodiffError(
                f"aggregation kernel {n.kernel.name!r} has no derivative "
                f"rule; differentiable aggregations are "
                f"{', '.join(DIFFERENTIABLE_AGGS)} ({hint})")
        c = n.child
        gb = tuple(n.group_by)
        if isinstance(c, TraJoin) and consumers.get(id(c), 0) == 1 \
                and id(c) not in cots:
            # direct Σ∘⋈ backward: cotangents flow straight into the join
            # operands as agg(join(·)) patterns — fusable by the optimizer
            lspec, rspec = _join_vjp_specs(c.kernel)
            ok = True
            sides = []
            for side, spec, op in (("left", lspec, c.left),
                                   ("right", rspec, c.right)):
                if id(op) not in active:
                    continue
                if spec is None:
                    ok = False
                    break
                lx, rx = wrap(c.left), wrap(c.right)
                cot = _contraction_vjp(G, side, lx, rx, c.join_keys_l,
                                       c.join_keys_r, gb, spec)
                if cot is None:
                    ok = False
                    break
                sides.append((op, cot))
            if ok:
                for op, cot in sides:
                    contribute(op, cot)
                return
        # fall back: broadcast the cotangent over the reduced dims, then
        # let the child's own rule consume it
        contribute(c, _agg_broadcast_back(n, infos[id(c)], G))
        return

    if isinstance(n, TraJoin):
        lspec, rspec = _join_vjp_specs(n.kernel)
        k_out = infos[id(n)].rtype.key_arity
        gb = tuple(range(k_out))
        lx, rx = wrap(n.left), wrap(n.right)
        for side, spec, op in (("left", lspec, n.left),
                               ("right", rspec, n.right)):
            if id(op) not in active:
                continue
            if spec is None:
                from repro.core.kernels_registry import (get_kernel,
                                                         registered_kernels)
                alts = [nm for nm in registered_kernels()
                        if (kk := get_kernel(nm)).arity == 2
                        and isinstance(kk.vjp, tuple)
                        and all(v is not None for v in kk.vjp)]
                raise AutodiffError(
                    f"join kernel {n.kernel.name!r} has no derivative "
                    f"rule for its {side} operand; differentiable join "
                    f"kernels include {', '.join(alts)}")
            cot = _contraction_vjp(G, side, lx, rx, n.join_keys_l,
                                   n.join_keys_r, gb, spec)
            assert cot is not None      # full gb is always feasible
            contribute(op, cot)
        return

    if isinstance(n, TraTransform):
        if n.kernel.vjp is None:
            raise AutodiffError(
                f"transform kernel {n.kernel.name} has no derivative rule")
        child = wrap(n.child)
        out = wrap(n)
        contribute(n.child, n.kernel.vjp(child, out, G))
        return

    if isinstance(n, TraTile):
        k = infos[id(n.child)].rtype.key_arity
        contribute(n.child, G.concat(k, n.tile_dim))
        return

    if isinstance(n, TraConcat):
        cinfo = infos[id(n.child)]
        t = G.tile(n.array_dim, cinfo.rtype.bound[n.array_dim])
        kd = n.key_dim
        if kd != cinfo.rtype.key_arity - 1:
            # the regrown key dim is appended last; permute it home
            t = t.rekey(
                lambda kk, _kd=kd: kk[:_kd] + (kk[-1],) + kk[_kd:-1],
                tag=f"untile→{kd}")
        contribute(n.child, t)
        return

    if isinstance(n, TraReKey):
        cinfo = infos[id(n.child)]
        inv = {}
        for kk in _valid_keys(cinfo):
            inv[tuple(n.key_func(kk))] = kk
        g = G
        if infos[id(n)].mask is not None:
            # the image has holes: keep only cotangent keys the forward
            # relation actually produced before inverting
            g = g.filter(lambda kk, _inv=inv: kk in _inv,
                         tag=f"{n.tag}⁻¹dom")
        contribute(n.child,
                   g.rekey(lambda kk, _inv=inv: _inv[kk],
                           tag=f"{n.tag}⁻¹"))
        return

    if isinstance(n, TraFilter):
        cinfo = infos[id(n.child)]
        kept = G.filter(n.bool_func, tag=f"{n.tag}∂")
        contribute(n.child,
                   wrap(TraPad(kept.node, cinfo.rtype.key_shape)))
        return

    if isinstance(n, TraPad):
        cinfo = infos[id(n.child)]
        f = cinfo.rtype.key_shape
        if f != infos[id(n)].rtype.key_shape:
            G = G.filter(lambda kk, _f=f: all(x < b for x, b in
                                              zip(kk, _f)),
                         tag="pad∂")
        contribute(n.child, G)
        return

    raise AutodiffError(f"no derivative rule for {type(n).__name__}")


def _valid_keys(info: TypeInfo):
    import numpy as np
    ks = info.rtype.key_shape
    grid = np.indices(ks).reshape(len(ks), -1).T if ks else \
        np.zeros((1, 0), np.int64)
    if info.mask is not None:
        grid = grid[info.mask.reshape(-1)]
    return [tuple(int(x) for x in kk) for kk in grid]
