"""Unified evaluation entry point for the TRA: the :class:`Engine`.

One object owns everything between a logical expression and a result:

* the **optimizer invocation** (cost-based placement DP + logical rewrites,
  including the fused Σ∘⋈ contraction selection) with the engine's mesh
  topology and accounting mode;
* the **executor** choice — one declarative expression runs unchanged on
  any of the four back-ends:

  - ``"reference"`` — the eager sites-ignoring walk (logical plans run the
    dense eager ops; physical plans the semantics-check IA walk);
  - ``"jit"``       — the same walk staged into a single ``jax.jit``;
  - ``"gspmd"``     — one ``jit`` whose placement constraints make XLA emit
    the plan's collective schedule (requires ``mesh``);
  - ``"shard_map"`` — paper-faithful explicit collectives (requires
    ``mesh``);
  - ``"auto"``      — ``"gspmd"`` when a mesh is given, else ``"jit"``;

* a **keyed compile cache** — structurally identical expressions (same
  shapes, kernels, placements, executor) reuse the compiled artifact; and
* the **kernel registry view** (``engine.kernel(name)``).

The only two entry points are ``engine.run(expr, **inputs)`` and
``engine.compile(expr)``; everything else in :mod:`repro.core.interp` /
:mod:`repro.core.shardmap_exec` is a deprecated shim over the same
internals.

``run``/``compile`` accept an :class:`~repro.core.expr.Expr`, a raw
logical ``TraNode``, an already-built physical ``IANode`` (executed as-is,
bypassing the optimizer — how hand-compiled paper plans are priced and
run), a *tuple* of logical roots (multi-output programs such as the
§5.3 FFNN step; with ``optimize=False`` shared subexpressions are
evaluated once across all roots, while optimizer lowering rebuilds each
root's physical tree independently), or a *dict* of named roots — then
``run`` returns ``{name: relation}``, which is how
:class:`repro.core.train.TraTrainer` rethreads optimizer state between
steps.  Input values may be :class:`TensorRelation`\\ s or raw arrays of
the declared dense shape ``key_shape ++ bound``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import warnings
import weakref
from typing import Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import kernels_registry as kr
from repro.core.compile import compile_tra
from repro.core.interp import _evaluate_ia, _evaluate_tra, _jit_ia_plans
from repro.core.optimize import OptimizeResult, optimize as _optimize
from repro.core.plan import (IAInput, IANode, Placement, TraInput, TraNode,
                             TypeInfo, as_node, describe, infer, postorder)
from repro.core.tra import TensorRelation

EXECUTORS = ("auto", "reference", "jit", "gspmd", "shard_map")

# graceful-degradation ladders (Engine(degrade=True)): on a *compile*
# failure of the preferred executor, fall back left-to-right; on a device
# OOM in the fused contraction at *run* time, retry streamed with a
# halving chunk starting here
_EXECUTOR_FALLBACKS = {
    "shard_map": ("jit", "reference"),
    "gspmd": ("jit", "reference"),
    "jit": ("reference",),
}
DEFAULT_OOM_LADDER_START = 64


def _validate_chunk(chunk) -> None:
    """``chunk`` is ``None``, ``"auto"`` or a positive int.

    The check itself lives with the other promoted input validation in
    :mod:`repro.analysis.inputs`; it raises the same ``ValueError`` (same
    leading text) as it always did, now carrying a rendered diagnostic.
    """
    from repro.analysis.inputs import check_chunk
    check_chunk(chunk)


VALIDATE_MODES = ("off", "warn", "strict")


# ==========================================================================
# Structural plan signatures (compile-cache keys)
# ==========================================================================

# id(fn)-only signatures have a fuzzer-found collision class: a kernel
# rebuilt after its predecessor was garbage-collected can reuse the exact
# id, and two kernels sharing one `apply` but differing in `out_bound`
# are distinct semantics under one id.  The content fingerprint below
# closes both; ids stay in the signature so live distinct objects never
# need a fingerprint comparison to separate.  Memoized per function
# *object* (weak keys — a GC'd function drops its entry, so a recycled id
# can never alias a stale fingerprint).
_code_fp_memo: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _code_fp(fn) -> str:
    """Content fingerprint of a callable (bytecode + consts + closure)."""
    try:
        return _code_fp_memo[fn]
    except (KeyError, TypeError):
        pass

    def feed(h, code):
        h.update(code.co_code)
        h.update(repr(code.co_names).encode())
        for c in code.co_consts:
            if hasattr(c, "co_code"):
                feed(h, c)              # nested lambdas/defs: hash content,
            else:                       # not their repr (which embeds ids)
                h.update(repr(c).encode())

    code = getattr(fn, "__code__", None)
    if code is None:
        # builtins / partials / callables: class + best-effort repr
        fp = f"{type(fn).__name__}:{getattr(fn, '__name__', repr(fn))}"
    else:
        h = hashlib.sha1()
        feed(h, code)
        for cell in getattr(fn, "__closure__", None) or ():
            try:
                h.update(repr(cell.cell_contents).encode())
            except Exception:
                h.update(b"?")
        fp = h.hexdigest()[:12]
    try:
        _code_fp_memo[fn] = fp
    except TypeError:
        pass                            # non-weakref-able callable
    return fp


def _kernel_sig(k) -> Tuple:
    # registered kernels are singletons and factory kernels embed their
    # parameters in the name (scaleMul(eta), einsum[...]); the id covers
    # ad-hoc kernels with colliding names, the content fingerprints cover
    # id reuse and shared-apply kernels (see _code_fp)
    return (k.name, id(k.apply), _code_fp(k.apply), _code_fp(k.out_bound))


def _func_sig(tag: str, fn) -> Tuple:
    # user key/bool functions are opaque — the tag plus identity keys them,
    # so structurally rebuilt expressions sharing the function object hit
    # the cache while different lambdas under a default tag never collide;
    # the fingerprint closes the id-reuse-after-GC collision
    return (tag, id(fn), _code_fp(fn))


def plan_sig(node) -> Tuple:
    """Structural signature of a logical or physical plan (cache key)."""
    node = as_node(node)
    memo: Dict[int, int] = {}
    parts = []

    def rec(n) -> int:
        if id(n) in memo:               # shared subexpression → back-ref
            return memo[id(n)]
        from repro.core import plan as P
        if isinstance(n, (P.TraInput, P.IAInput)):
            sig = ("in", n.name, n.rtype.key_shape, n.rtype.bound,
                   str(n.rtype.dtype))
            if isinstance(n, P.IAInput):
                # dup_kernel is semantics (which reduction the pending
                # R2-5 partials still owe) — a fuzzer-found collision
                # when it was absent
                sig += (n.placement.kind, n.placement.dims,
                        n.placement.axes, n.placement.dup_axes,
                        n.placement.dup_kernel)
        elif isinstance(n, (P.TraConst, P.IAConst)):
            sig = ("const", n.rtype.key_shape, n.rtype.bound,
                   str(n.rtype.dtype), n.fill)
            if isinstance(n, P.IAConst):
                sig += (n.placement.kind, n.placement.dims,
                        n.placement.axes, n.placement.dup_axes,
                        n.placement.dup_kernel)
        elif isinstance(n, (P.TraPad, P.LocalPad)):
            sig = ("pad", rec(n.child), n.key_shape)
        elif isinstance(n, (P.TraJoin, P.LocalJoin)):
            sig = ("join", rec(n.left), rec(n.right), n.join_keys_l,
                   n.join_keys_r, _kernel_sig(n.kernel))
        elif isinstance(n, P.FusedJoinAgg):
            sig = ("fja", rec(n.left), rec(n.right), n.join_keys_l,
                   n.join_keys_r, _kernel_sig(n.join_kernel), n.group_by,
                   _kernel_sig(n.agg_kernel), n.partial)
        elif isinstance(n, (P.TraAgg, P.LocalAgg)):
            sig = ("agg", rec(n.child), n.group_by, _kernel_sig(n.kernel),
                   getattr(n, "partial", False))
        elif isinstance(n, P.TraTransform):
            sig = ("map", rec(n.child), _kernel_sig(n.kernel))
        elif isinstance(n, P.LocalMap):
            sig = ("lmap", rec(n.child), _kernel_sig(n.kernel),
                   None if n.key_func is None
                   else _func_sig(n.tag, n.key_func))
        elif isinstance(n, (P.TraFilter, P.LocalFilter)):
            sig = ("filter", rec(n.child), _func_sig(n.tag, n.bool_func))
        elif isinstance(n, P.TraReKey):
            sig = ("rekey", rec(n.child), _func_sig(n.tag, n.key_func))
        elif isinstance(n, (P.TraTile, P.LocalTile)):
            sig = ("tile", rec(n.child), n.tile_dim, n.tile_size)
        elif isinstance(n, (P.TraConcat, P.LocalConcat)):
            sig = ("concat", rec(n.child), n.key_dim, n.array_dim)
        elif isinstance(n, P.Bcast):
            sig = ("bcast", rec(n.child))
        elif isinstance(n, P.Shuf):
            sig = ("shuf", rec(n.child), n.part_dims, n.axes)
        else:
            raise TypeError(type(n))
        memo[id(n)] = len(parts)
        parts.append(sig)
        return memo[id(n)]

    rec(node)
    return tuple(parts)


def _placements_sig(placements: Optional[Dict[str, Placement]]) -> Tuple:
    if not placements:
        return ()
    return tuple(sorted(
        (name, p.kind, p.dims, p.axes, p.dup_axes, p.dup_kernel or "")
        for name, p in placements.items()))


# ==========================================================================
# Compiled artifacts
# ==========================================================================

@dataclasses.dataclass
class CompiledExpr:
    """A compiled expression: physical plan (when one exists) + callable.

    ``__call__``/``run`` accept the program inputs by name and return
    :class:`TensorRelation` results (a tuple for multi-root programs).
    """

    executor: str
    roots: Tuple                        # plan nodes (logical or physical)
    input_rtypes: Dict[str, object]
    out_infos: Tuple[TypeInfo, ...]
    _call: Callable                     # env dict -> tuple of TensorRelation
    opts: Tuple[OptimizeResult, ...] = ()   # one per optimizer-lowered root
    multi: bool = False                 # caller passed a tuple of roots
    # jit/gspmd: the underlying jitted callable and its input-name order,
    # for .lower()/.compile() dry-runs, memory analysis and HLO inspection
    jitted: Optional[Callable] = None
    input_names: Optional[Tuple[str, ...]] = None
    # set by Engine.value_and_grad: names of the wrt inputs whose gradients
    # follow the value in the run() tuple
    grad_wrt: Optional[Tuple[str, ...]] = None
    # set for dict-compiled programs: run() returns {name: relation}
    root_names: Optional[Tuple[str, ...]] = None
    # the engine's FaultInjector (run-scoped faults hook every dispatch)
    faults: Optional[object] = None
    # set when Engine(degrade=True) fell back from a failed preferred
    # executor — names that executor so callers can see the degradation
    degraded_from: Optional[str] = None
    # stable process-local id ("<executor>:<sig digest>") assigned by the
    # engine at compile time; serving layers report which artifact served
    # a request by this id (see Engine.cache_info)
    artifact_id: Optional[str] = None
    # out-of-core streamed artifacts (Engine(memory_budget=...)): inputs
    # may be host-resident HostRelations, and validation defers to the
    # per-chunk inner compiles
    streamed: bool = False
    stream_stats: Optional[object] = None   # metering.StreamStats

    @property
    def plan(self):
        """The (first) root plan node this artifact executes."""
        return self.roots[0]

    @property
    def opt(self) -> Optional[OptimizeResult]:
        """The optimizer result (single optimized root only)."""
        return self.opts[0] if len(self.opts) == 1 else None

    @property
    def cost(self) -> Optional[int]:
        """Comm cost of the optimizer's plan(s) — summed over roots."""
        return sum(o.cost for o in self.opts) if self.opts else None

    def describe(self) -> str:
        return "\n".join(describe(r) for r in self.roots)

    def run(self, **inputs) -> Union[TensorRelation, Tuple]:
        if self.faults is not None:
            self.faults.on_run()
        # failure paths raise through repro.analysis.inputs (uniform
        # diagnostics, legacy exception types/text); imports stay off the
        # happy path
        unknown = [n for n in inputs if n not in self.input_rtypes]
        if unknown:
            from repro.analysis.inputs import unexpected_inputs_error
            raise unexpected_inputs_error(unknown, self.input_rtypes)
        env = {name: _coerce(name, val, self.input_rtypes[name],
                             keep_host=self.streamed)
               for name, val in inputs.items()}
        missing = [n for n in self.input_rtypes if n not in env]
        if missing:
            from repro.analysis.inputs import missing_inputs_error
            raise missing_inputs_error(missing, self.input_rtypes)
        if self.executor != "reference" and not self.streamed:
            # staged executors rebuild relations from raw arrays inside
            # the compiled artifact, so an input-side static mask would be
            # silently dropped — only the eager reference walk threads
            # per-value masks through (plan-level masks from in-plan
            # filters are unaffected; they live in the inferred types)
            holey = [n for n, r in env.items() if r.mask is not None]
            if holey:
                from repro.analysis.inputs import masked_inputs_error
                raise masked_inputs_error(self.executor, holey)
        outs = self._call(env)
        if self.root_names is not None:
            return dict(zip(self.root_names, outs))
        return outs if self.multi else outs[0]

    __call__ = run


@dataclasses.dataclass
class _CacheSlot:
    """Internal compile-cache slot: artifact + per-entry accounting."""

    compiled: CompiledExpr
    hits: int = 0
    pinned: bool = False


@dataclasses.dataclass(frozen=True)
class CacheEntry:
    """One compile-cache entry as reported by :meth:`Engine.cache_info`.

    ``signature`` is the full structural cache key (plan signatures,
    executor, optimizer configuration, …); ``artifact_id`` is its short
    digest — the id a serving layer logs per request.  ``degraded`` marks
    artifacts cached by the ``Engine(degrade=True)`` executor-fallback
    ladder under the fallback executor's key.
    """

    artifact_id: str
    executor: str
    hits: int
    pinned: bool
    degraded: bool
    root_names: Optional[Tuple[str, ...]]
    signature: Tuple
    compiled: CompiledExpr
    # per-artifact out-of-core streaming counters
    # (repro.launch.metering.StreamStats) for artifacts compiled through
    # the host relation store; None for resident artifacts
    stream_stats: Optional[object] = None


def _coerce(name: str, value, rtype, keep_host: bool = False):
    if isinstance(value, TensorRelation):
        return value
    if rtype is None:
        raise ValueError(f"unexpected input {name!r}")
    # host-resident handles from the relation store (duck-typed so the
    # core layer does not import repro.store): streamed artifacts keep
    # them host-side and slice per chunk; resident artifacts materialize
    if hasattr(value, "to_relation") and hasattr(value, "split_dim"):
        if value.rtype != rtype:
            raise ValueError(
                f"input {name!r}: host relation type {value.rtype} != "
                f"declared {rtype}")
        return value if keep_host else value.to_relation()
    expect = tuple(rtype.key_shape) + tuple(rtype.bound)
    if tuple(value.shape) != expect:
        raise ValueError(
            f"input {name!r}: dense shape {tuple(value.shape)} != "
            f"key_shape ++ bound {expect}")
    return TensorRelation(value, rtype)


def _input_nodes(roots) -> Dict[str, object]:
    """name -> rtype over all roots; duplicate names must agree."""
    rtypes: Dict[str, object] = {}
    for root in roots:
        for n in postorder(root):
            if isinstance(n, (TraInput, IAInput)):
                prev = rtypes.get(n.name)
                if prev is not None and prev != n.rtype:
                    raise ValueError(
                        f"input {n.name!r} declared with conflicting types "
                        f"{prev} vs {n.rtype}")
                rtypes[n.name] = n.rtype
    return rtypes


# ==========================================================================
# Engine
# ==========================================================================

class Engine:
    """Unified entry point: optimizer + executor + compile cache.

    Parameters
    ----------
    mesh:
        Optional :class:`jax.sharding.Mesh`.  Provides the site axes and
        sizes for the optimizer and is required by the distributed
        executors.
    executor:
        One of ``"auto" | "reference" | "jit" | "gspmd" | "shard_map"``.
    optimize:
        ``True`` (default) runs the cost-based optimizer on logical roots
        (fused Σ∘⋈ selection included).  ``False`` compiles the Table-1
        default plan for distributed executors and walks the logical tree
        directly on ``reference``/``jit``.
    fuse:
        Only meaningful with ``optimize=False`` on logical walks: forward
        the ``fuse`` flag of the eager evaluator (``False`` = the unfused
        correctness oracle).
    input_placements / site_axes / axis_sizes / accounting /
    try_logical_rewrites:
        Optimizer configuration, defaulted from ``mesh`` when given
        (1-site ``("sites",)`` otherwise).
    chunk:
        Grid slices materialized per step of the chunked fused-Σ∘⋈
        streaming reduction (the non-contraction kernel pairs).
        ``"auto"`` (default) autotunes a per-shape value from the device
        memory budget (``memory_budget`` when given, else the device's
        calibrated ``memory_stats`` limit, else the static
        :data:`repro.core.tra.DEFAULT_CHUNK_BYTES` — see
        :mod:`repro.store.autotune`); ``None`` keeps the static
        bytes-based default; an int pins it.  ``compile(..., chunk=...)``
        overrides per expression.
    memory_budget:
        Optional device live-bytes budget enabling the spill-aware
        out-of-core mode: at compile time the engine estimates each
        plan's peak live bytes (:func:`repro.core.cost.plan_peak_bytes`)
        and routes over-budget single-root logical plans through the
        host relation store (:mod:`repro.store`) — operands stream in
        key-range chunks with double-buffered H2D copies instead of
        materializing resident.  Plans under budget run exactly as
        without it.
    store:
        Optional :class:`repro.store.RelationStore` backing
        ``HostRelation`` inputs/outputs (one is created lazily when
        needed).  ``engine.store.put(name, rel)`` turns any relation
        into a host-resident handle accepted by ``run``.
    fault_injector:
        Optional :class:`repro.core.faults.FaultInjector` threaded into
        every executor — simulated site failures, device OOM, stragglers
        and NaN poisoning fire at deterministic plan-addressable points
        (see :mod:`repro.core.faults` for the executor-timing caveat).
    check_numerics:
        ``True`` adds finite checks; a NaN/Inf raises
        :class:`repro.core.guards.NumericsError` naming the first
        producing plan node on ``reference``/``jit`` and the failing
        output on the distributed executors.  On ``jit`` the guard is
        two-tier (cheap enough to leave on): the steady-state program
        flags outputs only, and a trip triggers one deterministic
        re-run through a lazily compiled every-node-flagged variant for
        exact attribution.  ``"all"`` puts per-node flags in the
        primary jit program instead (full flag traffic every dispatch;
        no re-execution on failure).
    degrade:
        ``True`` enables graceful degradation: a device OOM in the fused
        contraction retries through a halving streamed-``chunk`` backoff
        ladder, and a failed executor compile falls back ``shard_map/gspmd
        → jit → reference`` with one :class:`RuntimeWarning`.  Off by
        default — without it every failure propagates unchanged.
    validate:
        Static plan verification mode (:mod:`repro.analysis`): on every
        compile-cache miss the post-optimization plans run the verifier
        passes (placement/exchange soundness, collective consistency,
        out-of-core streamability, memory-model audit).  ``"warn"``
        (default) emits one :class:`RuntimeWarning` carrying the rendered
        error diagnostics; ``"strict"`` raises
        :class:`repro.analysis.PlanVerificationError` (a ``ValueError``)
        instead of handing the plan to the executor; ``"off"`` skips
        verification.  Defaults from the ``REPRO_VALIDATE`` environment
        variable when unset (CI lints the program corpus under
        ``strict``).  The last run's findings — errors or not — are kept
        on ``engine.last_diagnostics``.
    """

    def __init__(self, mesh=None, executor: str = "auto",
                 optimize: bool = True, *,
                 input_placements: Optional[Dict[str, Placement]] = None,
                 site_axes: Optional[Sequence[str]] = None,
                 axis_sizes: Optional[Dict[str, int]] = None,
                 accounting: str = "wire",
                 try_logical_rewrites: bool = True,
                 fuse: bool = True,
                 chunk: Union[int, str, None] = "auto",
                 memory_budget: Optional[int] = None,
                 store=None,
                 fault_injector=None,
                 check_numerics=False,
                 degrade: bool = False,
                 validate: Optional[str] = None):
        from repro.analysis.inputs import check_memory_budget
        if executor not in EXECUTORS:
            raise ValueError(
                f"unknown executor {executor!r}; choose from {EXECUTORS}")
        _validate_chunk(chunk)
        check_memory_budget(memory_budget)
        if validate is None:
            validate = os.environ.get("REPRO_VALIDATE", "warn")
        if validate not in VALIDATE_MODES:
            raise ValueError(
                f"unknown validate mode {validate!r}; "
                f"choose from {VALIDATE_MODES}")
        self.validate = validate
        # Diagnostics of the most recent verified compile (any severity)
        self.last_diagnostics = None
        self.mesh = mesh
        self.fault_injector = fault_injector
        self.check_numerics = check_numerics
        self.degrade = degrade
        self.executor = executor
        self.optimize = optimize
        self.fuse = fuse
        # grid slices per streamed fused-reduction step; "auto" autotunes
        # from the device budget, None derives the static bytes-based
        # default from tra.DEFAULT_CHUNK_BYTES
        self.chunk = chunk
        # out-of-core mode: device live-bytes budget + host relation store
        self.memory_budget = memory_budget
        self._store_obj = store
        self.accounting = accounting
        self.try_logical_rewrites = try_logical_rewrites
        self.input_placements = dict(input_placements or {})
        if site_axes is None:
            site_axes = tuple(mesh.axis_names) if mesh is not None \
                else ("sites",)
        self.site_axes = tuple(site_axes)
        if axis_sizes is None:
            axis_sizes = ({a: int(mesh.shape[a]) for a in self.site_axes}
                          if mesh is not None
                          else {a: 1 for a in self.site_axes})
        self.axis_sizes = dict(axis_sizes)
        self._cache: Dict[Tuple, _CacheSlot] = {}
        self.cache_hits = 0
        self.cache_misses = 0

    # -- host relation store (out-of-core tier) ---------------------------
    @property
    def store(self):
        """The engine's :class:`repro.store.RelationStore` (lazy)."""
        if self._store_obj is None:
            from repro.store import RelationStore
            self._store_obj = RelationStore()
        return self._store_obj

    # -- compile-cache introspection --------------------------------------
    def cache_info(self) -> Tuple[CacheEntry, ...]:
        """Per-entry view of the compile cache, in insertion order.

        Each entry carries the structural ``signature`` (the full cache
        key), the resolved ``executor``, the per-entry ``hits`` count
        (``sum(e.hits for e in cache_info()) == engine.cache_hits``), the
        ``pinned`` flag, and the ``degraded`` marker for artifacts the
        degradation ladder cached under a fallback executor.  This is how
        :class:`repro.serve.TraServer` reports which artifact served a
        request and how tests assert steady-state serving is 100% cache
        hits.
        """
        out = []
        for key, slot in self._cache.items():
            out.append(CacheEntry(
                artifact_id=slot.compiled.artifact_id or "?",
                executor=slot.compiled.executor,
                hits=slot.hits,
                pinned=slot.pinned,
                degraded=key[-1] == "degraded",
                root_names=slot.compiled.root_names,
                signature=key,
                compiled=slot.compiled,
                stream_stats=getattr(slot.compiled, "stream_stats", None)))
        return tuple(out)

    def pin(self, compiled: CompiledExpr) -> CompiledExpr:
        """Pin a compiled artifact: ``cache_clear()`` keeps it by default.

        Long-lived serving artifacts are pinned so periodic cache hygiene
        (or an explicit ``cache_clear()``) never evicts the programs the
        request path dispatches to.
        """
        for slot in self._cache.values():
            if slot.compiled is compiled:
                slot.pinned = True
                return compiled
        raise ValueError(
            f"artifact {compiled.artifact_id!r} is not in this engine's "
            f"compile cache (compiled by another engine?)")

    def cache_clear(self, *, pinned: bool = False) -> int:
        """Drop cache entries; ``pinned=True`` also drops pinned ones.

        Returns the number of entries evicted.  Hit/miss counters are
        cumulative and unaffected.
        """
        if pinned:
            n = len(self._cache)
            self._cache.clear()
            return n
        keep = {k: s for k, s in self._cache.items() if s.pinned}
        n = len(self._cache) - len(keep)
        self._cache = keep
        return n

    # -- kernel registry view ---------------------------------------------
    @staticmethod
    def kernel(name: str) -> kr.Kernel:
        return kr.get_kernel(name)

    @staticmethod
    def kernels() -> Sequence[str]:
        return kr.registered_kernels()

    # -- entry points ------------------------------------------------------
    def run(self, expr, **inputs) -> Union[TensorRelation, Tuple]:
        """Compile (with caching) and execute in one call.

        With ``memory_budget`` set (or ``HostRelation`` inputs) a
        single-root logical expression is first considered for the
        out-of-core path: when its estimated peak live bytes exceed the
        budget it executes through the host relation store, streaming
        key-range chunks with double-buffered transfers
        (:class:`repro.store.StreamExecutor`); under-budget plans run
        resident exactly as without the budget.

        With ``degrade=True`` a device OOM raised out of the fused
        contraction (injected :class:`~repro.core.faults.DeviceOOM` or a
        real ``RESOURCE_EXHAUSTED``) walks a two-rung recovery ladder:
        first the whole expression is retried *streamed through the host
        relation store* (out-of-core key-range chunking, which bounds
        peak operand bytes); if that cannot apply or still OOMs, the
        fused Σ∘⋈ is forced onto the chunked ``fori_loop`` fallback with
        a halving chunk ladder, trading arithmetic intensity for bounded
        peak memory until a rung fits.
        """
        from repro.core.guards import is_oom_error
        try:
            return self._dispatch(expr, inputs)
        except Exception as err:
            if not (self.degrade and is_oom_error(err)):
                raise
        # rung 1: out-of-core streaming through the relation store —
        # bounds peak device bytes without shrinking the fused chunk
        from repro.store.stream import NotStreamable
        try:
            warnings.warn(
                "device OOM in fused contraction; retrying streamed "
                "through the host relation store (out-of-core key-range "
                "chunks) before the last-resort chunked fallback",
                RuntimeWarning, stacklevel=2)
            return self._compile_streamed(expr, force=True).run(**inputs)
        except NotStreamable:
            pass
        except Exception as err:
            if not is_oom_error(err):
                raise
        # rung 2: force the fused Σ∘⋈ onto its chunked streaming fallback
        # with a halving chunk ladder
        start = self.chunk if isinstance(self.chunk, int) \
            else DEFAULT_OOM_LADDER_START
        warnings.warn(
            f"device OOM persists; degrading to the streamed chunked "
            f"fallback (halving chunk ladder from {start}) — consider a "
            f"smaller Engine(chunk=...), Engine(memory_budget=...), or "
            f"more device memory",
            RuntimeWarning, stacklevel=2)
        c = start
        while True:
            try:
                return self.compile(expr, chunk=c, _stream=True) \
                           .run(**inputs)
            except Exception as err:
                if not (is_oom_error(err) and c > 1):
                    raise
                c = max(1, c // 2)

    def _dispatch(self, expr, inputs):
        """Route a ``run`` through the out-of-core path when applicable."""
        if self._streaming_applicable(expr, inputs):
            from repro.store.stream import NotStreamable
            try:
                return self._compile_streamed(expr).run(**inputs)
            except NotStreamable:
                pass
        return self.compile(expr).run(**inputs)

    def _streaming_applicable(self, expr, inputs) -> bool:
        """Cheap pre-check: is the out-of-core path worth consulting?

        True when the engine has a memory budget or any input is a
        host-resident store handle.  Only single-root logical plans on
        the host executors stream; everything else runs resident.
        """
        if isinstance(expr, (dict, tuple, list)):
            return False
        if self._resolve_executor() not in ("reference", "jit"):
            return False
        has_host = any(hasattr(v, "to_relation") and
                       hasattr(v, "split_dim") for v in inputs.values())
        if not (has_host or self.memory_budget is not None):
            return False
        try:
            return isinstance(as_node(expr), TraNode)
        except TypeError:
            return False

    def _compile_streamed(self, expr, force: bool = False) -> CompiledExpr:
        """Compile ``expr`` as an out-of-core streamed artifact.

        Plans the expression through :class:`repro.store.StreamExecutor`
        (raising :class:`repro.store.NotStreamable` when the plan has no
        streamable axis, or — unless ``force`` — when it fits the budget
        resident) and caches a :class:`CompiledExpr` whose call runs the
        chunked double-buffered schedule.  ``force`` (the degradation
        ladder's rung-1 knob) streams even plans the estimator judges
        resident.
        """
        from repro.launch.metering import StreamStats
        from repro.store.stream import NotStreamable, StreamExecutor
        if isinstance(expr, (dict, tuple, list)):
            raise NotStreamable("multi-root programs run resident")
        root = as_node(expr)
        if not isinstance(root, TraNode):
            raise NotStreamable("physical IA plans run resident")
        if self._resolve_executor() not in ("reference", "jit"):
            raise NotStreamable(
                "out-of-core streaming chunks compile on the host "
                "executors (reference/jit) only")
        key = ("streamed", plan_sig(root), self._resolve_executor(),
               self.optimize, self.fuse, self.memory_budget, bool(force))
        hit = self._cache.get(key)
        if hit is not None:
            self.cache_hits += 1
            hit.hits += 1
            return hit.compiled
        se = StreamExecutor(self)
        try:
            splan = se.plan(root, force=force)   # may raise NotStreamable
        except NotStreamable as err:
            if self.validate == "off":
                raise
            # enrich the refusal with the static verifier's per-candidate
            # provenance diagnostics; the exception TYPE is preserved so
            # _dispatch's resident fallback (and callers catching
            # NotStreamable) behave exactly as before
            from repro.analysis.streaming import explain_unstreamable
            diags = explain_unstreamable(root, budget=self.memory_budget,
                                         fuse=self.fuse)
            self.last_diagnostics = diags
            if diags.errors:
                raise NotStreamable(
                    f"{err}\n{diags.render(min_severity='warning')}"
                ) from err
            raise
        self.cache_misses += 1
        stats = StreamStats(mode=splan.mode, budget_bytes=splan.budget)
        out_info = splan.out_info
        compiled = CompiledExpr(
            executor=f"{self._resolve_executor()}+stream",
            roots=(root,),
            input_rtypes=_input_nodes((root,)),
            out_infos=(out_info,),
            _call=lambda env: (se.execute(splan, env, stats),),
            streamed=True,
            stream_stats=stats)
        compiled.artifact_id = (
            f"{compiled.executor}:"
            f"{hashlib.sha1(repr(key).encode()).hexdigest()[:10]}")
        self._cache[key] = _CacheSlot(compiled)
        return compiled

    def compile(self, expr,
                input_placements: Optional[Dict[str, Placement]] = None,
                target: Optional[Placement] = None,
                chunk: Optional[int] = None,
                _grad_wrt: Optional[Tuple[str, ...]] = None,
                _stream: bool = False) -> CompiledExpr:
        """Compile an expression for this engine's executor.

        ``input_placements`` (falling back to the engine-level default)
        seed the optimizer; ``target`` constrains the result placement;
        ``chunk`` overrides the engine-level fused-path chunk size.
        ``_stream`` (the OOM ladder's knob) forces the fused Σ∘⋈ onto the
        chunked streaming fallback even for contraction kernel pairs.
        """
        _validate_chunk(chunk)
        root_names = None
        if isinstance(expr, dict):
            # named multi-root program (train-step state threading):
            # run() returns {name: relation} so callers rethread
            # state-out → state-in by name
            root_names = tuple(expr)
            expr = tuple(expr.values())
        multi = isinstance(expr, (tuple, list))
        roots = tuple(as_node(e) for e in (expr if multi else (expr,)))
        placements = dict(self.input_placements)
        placements.update(input_placements or {})
        executor = self._resolve_executor()
        chunk = self.chunk if chunk is None else chunk

        # _grad_wrt is part of the key so a value_and_grad artifact (which
        # carries gradient semantics in .grad_wrt) never aliases a plain
        # compile() of the structurally identical roots; the robustness
        # fields (_stream / check_numerics / injector identity) are keyed
        # because they are baked into the compiled callable
        inj = self.fault_injector
        key = (tuple(plan_sig(r) for r in roots), executor, self.optimize,
               self.fuse, self.accounting, self.try_logical_rewrites,
               _placements_sig(placements),
               _placements_sig({"·": target} if target else None),
               multi, chunk, _grad_wrt, root_names,
               _stream, self.check_numerics,
               None if inj is None else id(inj))
        hit = self._cache.get(key)
        if hit is not None:
            self.cache_hits += 1
            hit.hits += 1
            return hit.compiled
        self.cache_misses += 1
        degraded_from = None
        try:
            compiled = self._compile(roots, placements, target, executor,
                                     multi, chunk, stream=_stream)
        except Exception as err:
            compiled, executor, err2 = self._compile_degraded(
                err, roots, placements, target, executor, multi, chunk,
                _stream)
            if compiled is None:
                raise err2
            degraded_from = self._resolve_executor()
            # the degraded artifact is cached under the *fallback*
            # executor's key (plus a marker): the preferred key stays
            # vacant, so the next compile() retries the preferred executor
            # and a later successful compile is never shadowed
            key = key[:1] + (executor,) + key[2:] + ("degraded",)
        compiled.grad_wrt = _grad_wrt
        compiled.root_names = root_names
        compiled.faults = inj
        compiled.degraded_from = degraded_from
        compiled.artifact_id = (
            f"{compiled.executor}:"
            f"{hashlib.sha1(repr(key).encode()).hexdigest()[:10]}")
        self._cache[key] = _CacheSlot(compiled)
        return compiled

    def _compile_degraded(self, err, roots, placements, target, executor,
                          multi, chunk, stream):
        """Walk the executor fallback ladder after a failed compile.

        Only *compile-class* failures degrade (injected
        :class:`~repro.core.faults.CompileFailure`, ``NotImplementedError``
        from an executor's unsupported subset, XLA runtime errors) — user
        errors such as shape/divisibility ``ValueError`` propagate
        unchanged.  Returns ``(compiled, executor, err)``; ``compiled`` is
        ``None`` when no rung succeeded (re-raise ``err``).
        """
        from repro.core.faults import CompileFailure
        def compile_class(e):
            return isinstance(e, (CompileFailure, NotImplementedError)) \
                or type(e).__name__ == "XlaRuntimeError"
        ladder = _EXECUTOR_FALLBACKS.get(executor, ())
        if not self.degrade or not ladder or not compile_class(err):
            return None, executor, err
        for fb in ladder:
            try:
                compiled = self._compile(roots, placements, target, fb,
                                         multi, chunk, stream=stream)
            except Exception as err2:
                if not compile_class(err2):
                    return None, executor, err2
                err = err2
                continue
            warnings.warn(
                f"executor {executor!r} failed to compile ({err}); "
                f"degraded to executor {fb!r} for this expression — fix "
                f"the {executor!r} failure to restore the preferred "
                f"executor (it is retried on the next compile)",
                RuntimeWarning, stacklevel=3)
            return compiled, fb, err
        return None, executor, err

    def value_and_grad(self, expr, wrt, seed=None,
                       input_placements: Optional[Dict[str,
                                                       Placement]] = None,
                       chunk: Optional[int] = None) -> CompiledExpr:
        """Compile ``(expr, *d expr/d wrt)`` as one multi-output program.

        The gradient expressions are *derived* from the forward plan by
        :mod:`repro.core.autodiff` (Tang et al. direction) and flow through
        the same optimizer/executor stack as any expression — the fused
        Σ∘⋈ selection applies to backward plans too.  ``wrt`` is a list of
        input names (or input ``Expr`` handles); ``seed`` is the output
        cotangent (default: ones — the gradient of the sum of every output
        entry).  The returned artifact's ``run`` yields
        ``(value, grad_0, grad_1, ...)`` in ``wrt`` order.
        """
        from repro.core.autodiff import grad as _grad
        from repro.core.expr import Expr, wrap
        if not isinstance(expr, Expr):
            expr = wrap(as_node(expr))
        wrt_list = list(wrt) if isinstance(wrt, (tuple, list)) else [wrt]
        grads = _grad(expr, wrt=wrt_list, seed=seed)
        names = tuple(w if isinstance(w, str) else w.node.name
                      for w in wrt_list)
        return self.compile((expr,) + tuple(grads),
                            input_placements=input_placements,
                            chunk=chunk, _grad_wrt=names)

    # -- internals ---------------------------------------------------------
    def _resolve_executor(self) -> str:
        if self.executor != "auto":
            return self.executor
        return "gspmd" if self.mesh is not None else "jit"

    def _physical_roots(self, roots, placements, target):
        """Lower logical roots to physical plans; pass IANodes through.

        Each logical root is optimized *independently* — physical lowering
        rebuilds nodes, so cross-root DAG sharing only survives on the
        unoptimized logical walk (``optimize=False``).  On the staged
        executors (jit/gspmd/shard_map) the duplicated lowering costs
        compile time only — XLA CSE merges the structurally identical
        subgraphs — and buys the fused Σ∘⋈ selection inside every root
        (the train-step programs rely on this); on the eager
        ``reference`` walk the duplicated roots re-execute per run, so
        shared-forward multi-root programs there should compile with
        ``optimize=False``.  ``CompiledExpr.cost`` sums the per-root
        plan costs.
        """
        phys, opts = [], []
        for r in roots:
            if isinstance(r, IANode):
                phys.append(r)
            elif self.optimize:
                opt = _optimize(
                    r, placements, site_axes=self.site_axes,
                    axis_sizes=self.axis_sizes, target=target,
                    try_logical_rewrites=self.try_logical_rewrites,
                    accounting=self.accounting)
                opts.append(opt)
                phys.append(opt.plan)
            else:
                phys.append(compile_tra(r, placements, self.site_axes))
        return tuple(phys), tuple(opts)

    def _make_ctx(self, plans, executor, stream):
        """Build the ExecContext threaded through the executor walks.

        ``None`` when no robustness feature is active — the walks then run
        exactly the pre-robustness code path.  Per-node finite checks run
        eagerly on ``reference``; ``jit`` collects per-node flags in the
        primary program only under ``check_numerics="all"`` (the default
        ``True`` mode is two-tier — output flags steady-state, per-node
        attribution on a lazily compiled re-run); the distributed
        executors get output-level checks (per-node probes would perturb
        the collective schedule under test).
        """
        from repro.core.guards import ExecContext, label_nodes
        if executor == "reference":
            per_node = self.check_numerics
        elif executor == "jit":
            # default jit mode flags outputs only (two-tier: the
            # per-node attribution variant is compiled lazily on a trip)
            per_node = "all" if self.check_numerics == "all" else False
        else:
            per_node = False
        if self.fault_injector is None and not per_node and not stream:
            return None
        return ExecContext(faults=self.fault_injector, check=per_node,
                           stream=stream, labels=label_nodes(plans))

    @staticmethod
    def _checked_call(call):
        """Wrap a distributed executor's call with output finite checks."""
        from repro.core.guards import check_output_rel
        def wrapped(env):
            outs = call(env)
            for i, r in enumerate(outs):
                check_output_rel(r, f"output[{i}]")
            return outs
        return wrapped

    def _verify_compile(self, plans, executor, logical_roots) -> None:
        """Run the static verifier over the executor-bound plans.

        Called once per compile-cache miss (cache hits re-dispatch
        already-verified artifacts).  ``"warn"`` surfaces error
        diagnostics as one RuntimeWarning; ``"strict"`` raises
        :class:`~repro.analysis.PlanVerificationError` before any
        executor construction.  All findings (any severity) are kept on
        ``self.last_diagnostics``.
        """
        if self.validate == "off":
            return
        from repro.analysis.diagnostics import PlanVerificationError
        from repro.analysis.manager import verify_plans
        diags = verify_plans(
            plans, executor=executor, axis_sizes=self.axis_sizes,
            memory_budget=self.memory_budget, fuse=self.fuse,
            logical_roots=logical_roots)
        self.last_diagnostics = diags
        if not diags.errors:
            return
        if self.validate == "strict":
            raise PlanVerificationError(diags)
        warnings.warn(
            f"plan verification found {len(diags.errors)} error(s) "
            f"(Engine(validate=\"warn\") — compiling anyway):\n"
            f"{diags.render(min_severity='warning')}",
            RuntimeWarning, stacklevel=4)

    def _compile(self, roots, placements, target, executor, multi,
                 chunk, stream=False) -> CompiledExpr:
        if self.fault_injector is not None:
            self.fault_injector.on_compile(executor)
        if executor in ("gspmd", "shard_map"):
            if self.mesh is None:
                raise ValueError(f"executor {executor!r} requires a mesh")
            phys, opts = self._physical_roots(roots, placements, target)
            self._verify_compile(phys, executor, roots)
            ctx = self._make_ctx(phys, executor, stream)
            out_infos = tuple(infer(p) for p in phys)
            jfn = names = None
            if executor == "gspmd":
                call, jfn, names = self._gspmd_call(phys, out_infos, chunk,
                                                    ctx)
            else:
                # the shard_map callable is built ONCE here; repeat runs of
                # a cached artifact are pure dispatch (no rebuild)
                call = self._shardmap_call(phys, chunk, ctx)
            if self.check_numerics:
                call = self._checked_call(call)
            return CompiledExpr(executor, phys, _input_nodes(phys),
                                out_infos, call, opts, multi,
                                jitted=jfn, input_names=names)

        # reference / jit: logical roots run the eager TRA walk (optimized
        # ones run the physical walk); shared subexpressions are evaluated
        # once via the id-keyed cache shared across roots.
        if self.optimize or any(isinstance(r, IANode) for r in roots):
            plans, opts = self._physical_roots(roots, placements, target)
        else:
            plans, opts = roots, ()
        self._verify_compile(plans, executor, roots)
        ctx = self._make_ctx(plans, executor, stream)
        out_infos = tuple(infer(p) for p in plans)
        rtypes = _input_nodes(plans)

        def eval_all(env, ectx):
            cache: dict = {}
            outs = []
            for p in plans:
                if isinstance(p, IANode):
                    outs.append(_evaluate_ia(p, env, _cache=cache,
                                             chunk=chunk,
                                             budget=self.memory_budget,
                                             ctx=ectx))
                else:
                    outs.append(_evaluate_tra(p, env, cache,
                                              fuse=self.fuse, chunk=chunk,
                                              budget=self.memory_budget,
                                              ctx=ectx))
            return tuple(outs)

        if executor == "reference":
            return CompiledExpr("reference", plans, rtypes, out_infos,
                                lambda env: eval_all(env, ctx), opts,
                                multi)

        names = sorted(rtypes)
        check = self.check_numerics
        # Two-tier jit numerics guard.  Finite flags become extra
        # (scalar) jit outputs, led by a single combined all-finite
        # scalar: the happy path costs one host sync per dispatch.  In
        # the default ``check_numerics=True`` mode the steady-state
        # program flags *outputs only* (cheap — no per-node reduce
        # traffic, no fusion breakage); when the combined flag trips,
        # ``attribute`` lazily compiles an every-node-flagged variant of
        # the same program and re-runs the same inputs once (the program
        # is deterministic, injected faults included) so the error still
        # names the first producing node in plan postorder.
        # ``check_numerics="all"`` puts per-node flags in the primary
        # program instead.  Flag labels are recorded at trace time
        # (re-recorded on retrace), one list per variant.

        def make_fn(ectx):
            labels: list = []

            def fn(*arrays):
                if ectx is not None:
                    ectx.flags.clear()   # stale flags from aborted traces
                env = {n: TensorRelation(a, rtypes[n])
                       for n, a in zip(names, arrays)}
                outs = eval_all(env, ectx)
                datas = tuple(r.data for r in outs)
                if ectx is not None and ectx.check:
                    pairs = ectx.take_flags()
                elif check:
                    from repro.core.guards import finite_flag
                    pairs = [(f"output[{i}]", finite_flag(r.data, r.mask))
                             for i, r in enumerate(outs)]
                    pairs = [(la, fl) for la, fl in pairs if fl is not None]
                else:
                    pairs = []
                labels[:] = [la for la, _ in pairs]
                if not pairs:
                    return datas
                flags = tuple(fl for _, fl in pairs)
                combined = flags[0]
                for fl in flags[1:]:
                    combined = jnp.logical_and(combined, fl)
                return datas + (combined,) + flags

            return fn, labels

        fn, flag_labels = make_fn(ctx)
        jfn = jax.jit(fn)
        nout = len(out_infos)
        attrib: dict = {}

        def attribute(args):
            """Re-run with every node flagged; raise naming the first."""
            from repro.core.guards import ExecContext, NumericsError, \
                label_nodes
            if "jfn" not in attrib:
                ctx2 = ExecContext(faults=self.fault_injector, check="all",
                                   stream=stream, labels=label_nodes(plans))
                fn2, labels2 = make_fn(ctx2)
                attrib["jfn"], attrib["labels"] = jax.jit(fn2), labels2
            res = attrib["jfn"](*args)
            flags = res[nout:]
            if flags and not bool(flags[0]):
                for lab, fl in zip(attrib["labels"], flags[1:]):
                    if not bool(fl):
                        raise NumericsError(
                            f"non-finite values first produced by node "
                            f"{lab} (jit finite-flags; plan postorder "
                            f"attribution)", node_label=lab)

        def call(env):
            args = tuple(env[n].data for n in names)
            res = jfn(*args)
            datas, flags = res[:nout], res[nout:]
            if flags and not bool(flags[0]):
                from repro.core.guards import NumericsError
                if check != "all":
                    attribute(args)   # raises when it reproduces
                for lab, fl in zip(flag_labels, flags[1:]):
                    if not bool(fl):
                        raise NumericsError(
                            f"non-finite values first produced by node "
                            f"{lab} (jit finite-flags; plan postorder "
                            f"attribution)", node_label=lab)
                raise NumericsError(
                    "non-finite values in jit outputs (attribution "
                    "re-run did not reproduce the failure)")
            return tuple(TensorRelation(d, oi.rtype, oi.mask)
                         for d, oi in zip(datas, out_infos))

        return CompiledExpr("jit", plans, rtypes, out_infos, call, opts,
                            multi, jitted=jfn, input_names=tuple(names))

    def _gspmd_call(self, plans, out_infos, chunk, ctx=None):
        jfn, names = _jit_ia_plans(plans, self.mesh, chunk=chunk,
                                   budget=self.memory_budget, ctx=ctx)

        def call(env):
            datas = jfn(*(env[n].data for n in names))
            return tuple(TensorRelation(d, oi.rtype, oi.mask)
                         for d, oi in zip(datas, out_infos))

        return call, jfn, tuple(names)

    def _shardmap_call(self, plans, chunk, ctx=None):
        from repro.core.shardmap_exec import _build_shardmap
        call, _, _ = _build_shardmap(plans, self.mesh, chunk=chunk,
                                     budget=self.memory_budget, ctx=ctx)
        return call
