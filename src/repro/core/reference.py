"""Tuple-at-a-time reference executor for the TRA (oracle for tests).

Relations are plain ``{key tuple: np.ndarray}`` dicts — the literal reading
of the paper's definition.  Deliberately simple and slow; the hypothesis
property tests assert that the dense jnp executor in :mod:`repro.core.tra`
agrees with this one on every operation.
"""
from __future__ import annotations

from typing import Callable, Dict, Sequence, Tuple

import numpy as np

from repro.core.kernels_registry import Kernel

RefRel = Dict[Tuple[int, ...], np.ndarray]


def _np(kernel: Kernel, *xs):
    return np.asarray(kernel.apply(*[np.asarray(x) for x in xs]))


def join(left: RefRel, right: RefRel, jkl: Sequence[int], jkr: Sequence[int],
         kernel: Kernel) -> RefRel:
    out: RefRel = {}
    jkr_set = set(jkr)
    for lk, la in left.items():
        for rk, ra in right.items():
            if all(lk[dl] == rk[dr] for dl, dr in zip(jkl, jkr)):
                ok = tuple(lk) + tuple(v for d, v in enumerate(rk)
                                       if d not in jkr_set)
                if ok in out:
                    raise ValueError("join produced duplicate key")
                out[ok] = _np(kernel, la, ra)
    return out


def agg(rel: RefRel, group_by: Sequence[int], kernel: Kernel) -> RefRel:
    groups: Dict[Tuple[int, ...], list] = {}
    for k, a in rel.items():
        gk = tuple(k[d] for d in group_by)
        groups.setdefault(gk, []).append((k, a))
    out: RefRel = {}
    for gk, members in groups.items():
        # deterministic fold order (row-major key order)
        members.sort(key=lambda ka: ka[0])
        acc = members[0][1]
        for _, a in members[1:]:
            acc = _np(kernel, acc, a)
        out[gk] = acc
    return out


def rekey(rel: RefRel, key_func: Callable) -> RefRel:
    out: RefRel = {}
    for k, a in rel.items():
        nk = tuple(key_func(k))
        if nk in out:
            raise ValueError("rekey produced duplicate keys")
        out[nk] = a
    return out


def filt(rel: RefRel, bool_func: Callable) -> RefRel:
    return {k: a for k, a in rel.items() if bool_func(k)}


def transform(rel: RefRel, kernel: Kernel) -> RefRel:
    return {k: _np(kernel, a) for k, a in rel.items()}


def tile(rel: RefRel, tile_dim: int, tile_size: int) -> RefRel:
    out: RefRel = {}
    for k, a in rel.items():
        n = a.shape[tile_dim] // tile_size
        pieces = np.split(a, n, axis=tile_dim)
        for i, p in enumerate(pieces):
            out[tuple(k) + (i,)] = p
    return out


def concat(rel: RefRel, key_dim: int, array_dim: int) -> RefRel:
    groups: Dict[Tuple[int, ...], list] = {}
    for k, a in rel.items():
        gk = tuple(v for d, v in enumerate(k) if d != key_dim)
        groups.setdefault(gk, []).append((k[key_dim], a))
    out: RefRel = {}
    for gk, members in groups.items():
        members.sort(key=lambda ia: ia[0])
        out[gk] = np.concatenate([a for _, a in members], axis=array_dim)
    return out
