"""Tensor relations and eager TRA operations (paper §2).

Representation
--------------
The paper's integrity constraints (key *uniqueness* + *continuity*) make a
tensor relation of type ``R^(k, r, b)`` with frontier ``f`` isomorphic to a
dense array of shape ``f ++ b`` — keys become the leading ``k`` axes.  That
is exactly the representation used here, so the whole algebra stays inside
jnp and can be jit/pjit-ed.

Relations that pass through ``σ`` (filter) or a non-bijective ``ReKey`` can
violate continuity ("holes").  Keys are *static* metadata (frontiers are
known at trace time), so holes are represented by a static numpy boolean
``mask`` over the key grid — no dynamic shapes are ever needed, matching the
paper's observation that cardinalities are exact, never estimated.

Two executors share this module:
  * the dense jnp ops below (production path, jit-able),
  * :mod:`repro.core.reference` — a dict-of-numpy tuple-at-a-time oracle used
    by the hypothesis property tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_registry import Kernel

KeyFunc = Callable[[Tuple[int, ...]], Tuple[int, ...]]
BoolFunc = Callable[[Tuple[int, ...]], bool]


@dataclasses.dataclass(frozen=True)
class RelType:
    """Static type of a tensor relation: key frontier + array bound."""

    key_shape: Tuple[int, ...]   # frontier f  (exact, by continuity)
    bound: Tuple[int, ...]       # array bound b
    dtype: object = jnp.float32

    @property
    def key_arity(self) -> int:
        return len(self.key_shape)

    @property
    def rank(self) -> int:
        return len(self.bound)

    @property
    def ntuples(self) -> int:
        return math.prod(self.key_shape) if self.key_shape else 1

    @property
    def nfloats(self) -> int:
        """Total scalar payload — the paper's exact ``n × ∏ b_i``."""
        return self.ntuples * (math.prod(self.bound) if self.bound else 1)

    def with_key_shape(self, ks: Sequence[int]) -> "RelType":
        return dataclasses.replace(self, key_shape=tuple(ks))

    def with_bound(self, b: Sequence[int]) -> "RelType":
        return dataclasses.replace(self, bound=tuple(b))


@dataclasses.dataclass
class TensorRelation:
    """A dense-backed tensor relation value."""

    data: jax.Array              # shape = key_shape + bound
    rtype: RelType
    mask: Optional[np.ndarray] = None   # static validity grid or None (=all)

    def __post_init__(self) -> None:
        expect = tuple(self.rtype.key_shape) + tuple(self.rtype.bound)
        if tuple(self.data.shape) != expect:
            raise ValueError(
                f"data shape {self.data.shape} != type shape {expect}")
        if self.mask is not None and self.mask.shape != self.rtype.key_shape:
            raise ValueError("mask shape mismatch")

    # -- conveniences -----------------------------------------------------
    @property
    def key_shape(self) -> Tuple[int, ...]:
        return self.rtype.key_shape

    @property
    def bound(self) -> Tuple[int, ...]:
        return self.rtype.bound

    def is_continuous(self) -> bool:
        return self.mask is None or bool(np.all(self.mask))

    def valid_keys(self) -> np.ndarray:
        """(n, k) int array of valid keys, row-major order."""
        if not self.key_shape:
            return np.zeros((1, 0), np.int64)
        grid = np.indices(self.key_shape).reshape(len(self.key_shape), -1).T
        if self.mask is None:
            return grid
        return grid[self.mask.reshape(-1)]

    def to_dict(self) -> dict:
        """Materialize as {key tuple: np.ndarray} (reference format)."""
        out = {}
        data = np.asarray(self.data)
        for key in self.valid_keys():
            out[tuple(int(x) for x in key)] = data[tuple(key)]
        return out


def _full_mask_and(a: Optional[np.ndarray], b: Optional[np.ndarray],
                   shape: Tuple[int, ...]) -> Optional[np.ndarray]:
    if a is None and b is None:
        return None
    aa = np.broadcast_to(a if a is not None else True, shape)
    bb = np.broadcast_to(b if b is not None else True, shape)
    return np.logical_and(aa, bb)


# ==========================================================================
# Constructors
# ==========================================================================

def from_tensor(tensor: jax.Array, tile: Sequence[int]) -> TensorRelation:
    """Chunk a dense tensor into a tensor relation with block-index keys.

    ``tile[d]`` is the block size along tensor dim ``d`` (must divide the
    dim).  Keys are block coordinates; arrays are the blocks.
    """
    tile = tuple(tile)
    if len(tile) != tensor.ndim:
        raise ValueError("tile rank mismatch")
    key_shape = []
    for d, t in enumerate(tile):
        if tensor.shape[d] % t:
            raise ValueError(f"dim {d} ({tensor.shape[d]}) not divisible by {t}")
        key_shape.append(tensor.shape[d] // t)
    # reshape (k0, t0, k1, t1, ...) then move key axes to the front
    interleaved = []
    for k, t in zip(key_shape, tile):
        interleaved += [k, t]
    x = tensor.reshape(interleaved)
    perm = list(range(0, 2 * len(tile), 2)) + list(range(1, 2 * len(tile), 2))
    x = jnp.transpose(x, perm)
    rt = RelType(tuple(key_shape), tile, tensor.dtype)
    return TensorRelation(x, rt)


def to_tensor(rel: TensorRelation,
              key_dims: Optional[Sequence[int]] = None) -> jax.Array:
    """Reassemble a continuous relation into a dense tensor.

    ``key_dims[i]`` names the array dim that key dim ``i`` blocks along
    (default: the identity, requiring key arity == rank).
    """
    if not rel.is_continuous():
        raise ValueError("cannot reassemble a relation with holes")
    k, r = rel.rtype.key_arity, rel.rtype.rank
    if key_dims is None:
        if k != r:
            raise ValueError(f"key arity {k} != rank {r}; pass key_dims")
        key_dims = tuple(range(k))
    key_dims = tuple(key_dims)
    if len(key_dims) != k or len(set(key_dims)) != k:
        raise ValueError("key_dims must name each key dim once")
    # interleave: for each array dim, optionally prefix its key dim
    perm = []
    shape = []
    for d in range(r):
        if d in key_dims:
            perm.append(key_dims.index(d))
            shape.append(rel.key_shape[key_dims.index(d)] * rel.bound[d])
        else:
            shape.append(rel.bound[d])
        perm.append(k + d)
    x = jnp.transpose(rel.data, perm)
    return x.reshape(shape)


# ==========================================================================
# TRA operations (eager, dense)
# ==========================================================================

@dataclasses.dataclass
class _JoinGeometry:
    """Key alignment shared by ``join`` and ``fused_join_agg``.

    ``ldata`` is the frontier-sliced left payload (shape ``f_out_l ++
    left.bound``); ``rdata_t`` is the right payload with its key axes moved
    into output-axis order (shape = covered-axis sizes ++ right.bound).
    ``r_shape`` is the singleton-expanded right key shape over the full
    output key grid.  Nothing here is broadcast yet — the grid is only
    materialized by ``join``, never by the fused path.
    """

    kl: int
    kr: int
    k_out: int
    f_out_l: Tuple[int, ...]
    out_key_shape: Tuple[int, ...]
    covered: Tuple[int, ...]          # output key axes the right side covers
    r_shape: Tuple[int, ...]
    ldata: jax.Array
    rdata_t: jax.Array
    lmask: Optional[np.ndarray]
    rmask_t: Optional[np.ndarray]


def _join_align(left: TensorRelation, right: TensorRelation,
                jkl: Tuple[int, ...], jkr: Tuple[int, ...]) -> _JoinGeometry:
    if len(jkl) != len(jkr):
        raise ValueError("join key lists must have equal length")
    kl = left.rtype.key_arity
    kr = right.rtype.key_arity
    r_nonjoin = [d for d in range(kr) if d not in jkr]

    # equi-join on a dense grid: valid range of a joined dim is the min of
    # the two frontiers (paper §4.3 rule 1)
    f_out_l = list(left.key_shape)
    for i, dl in enumerate(jkl):
        f_out_l[dl] = min(left.key_shape[dl], right.key_shape[jkr[i]])
    ldata = left.data[tuple(slice(0, f) for f in f_out_l)]
    lmask = None if left.mask is None else \
        left.mask[tuple(slice(0, f) for f in f_out_l)]

    r_slices = [slice(None)] * kr
    for i, dr in enumerate(jkr):
        r_slices[dr] = slice(0, f_out_l[jkl[i]])
    rdata = right.data[tuple(r_slices)]
    rmask = None if right.mask is None else right.mask[tuple(r_slices)]

    out_key_shape = tuple(f_out_l) + tuple(rdata.shape[d] for d in r_nonjoin)
    k_out = len(out_key_shape)

    # Align RIGHT onto the output key axes:
    #   joined right dim jkr[i]   -> output axis jkl[i]
    #   non-joined right dim d    -> output axis kl + (index in r_nonjoin)
    out_axis_of_rdim = {}
    for i, dr in enumerate(jkr):
        out_axis_of_rdim[dr] = jkl[i]
    for i, dr in enumerate(r_nonjoin):
        out_axis_of_rdim[dr] = kl + i
    order = sorted(range(kr), key=lambda d: out_axis_of_rdim[d])
    rdata_t = jnp.moveaxis(rdata, list(range(kr)),
                           [order.index(d) for d in range(kr)])
    rmask_t = None if rmask is None else np.moveaxis(
        rmask, list(range(kr)), [order.index(d) for d in range(kr)])
    # singleton axes for output key axes not covered by the right
    covered = tuple(sorted(out_axis_of_rdim.values()))
    r_shape = []
    ci = 0
    for ax in range(k_out):
        if ci < len(covered) and covered[ci] == ax:
            r_shape.append(rdata_t.shape[ci])
            ci += 1
        else:
            r_shape.append(1)
    return _JoinGeometry(kl, kr, k_out, tuple(f_out_l), out_key_shape,
                         covered, tuple(r_shape), ldata, rdata_t,
                         lmask, rmask_t)


def join(left: TensorRelation, right: TensorRelation,
         join_keys_l: Sequence[int], join_keys_r: Sequence[int],
         kernel: Kernel) -> TensorRelation:
    """⋈_(joinKeysL, joinKeysR, projOp)(L, R).

    Output keys: all left keys (original order) then right keys with the
    joined dims dropped — the paper's natural-join convention.
    """
    jkl, jkr = tuple(join_keys_l), tuple(join_keys_r)
    g = _join_align(left, right, jkl, jkr)
    rdata_b = g.rdata_t.reshape(g.r_shape + tuple(right.bound))
    rmask_b = None if g.rmask_t is None else g.rmask_t.reshape(g.r_shape)

    # left occupies the first kl output axes
    ldata_b = g.ldata.reshape(g.f_out_l + (1,) * (g.k_out - g.kl)
                              + tuple(left.bound))

    lb = jnp.broadcast_to(ldata_b, g.out_key_shape + tuple(left.bound))
    rb = jnp.broadcast_to(rdata_b, g.out_key_shape + tuple(right.bound))
    out = kernel.apply(lb, rb)

    out_bound = kernel.out_bound(left.bound, right.bound)
    rt = RelType(g.out_key_shape, tuple(out_bound), out.dtype)
    lmask_b = None if g.lmask is None else g.lmask.reshape(
        g.f_out_l + (1,) * (g.k_out - g.kl))
    mask = _full_mask_and(lmask_b, rmask_b, g.out_key_shape)
    return TensorRelation(out, rt, mask)


def _tree_fold(blocks: jax.Array, kernel: Kernel) -> jax.Array:
    """Fold axis 0 of ``blocks`` with an associative binary kernel."""
    n = blocks.shape[0]
    while n > 1:
        half = n // 2
        a = blocks[:half]
        b = blocks[half:2 * half]
        merged = kernel.apply(a, b)
        if n % 2:
            merged = jnp.concatenate([merged, blocks[2 * half:n]], axis=0)
        blocks = merged
        n = blocks.shape[0]
    return blocks[0]


def agg(rel: TensorRelation, group_by: Sequence[int],
        kernel: Kernel) -> TensorRelation:
    """Σ_(groupByKeys, aggOp)(R)."""
    if not kernel.is_associative:
        raise ValueError(f"agg kernel {kernel.name} must be associative")
    gb = tuple(group_by)
    k = rel.rtype.key_arity
    reduce_dims = tuple(d for d in range(k) if d not in gb)
    # reorder keys: group-by dims (in requested order) first
    perm = list(gb) + list(reduce_dims)
    data = jnp.moveaxis(rel.data, perm, list(range(k)))
    out_key_shape = tuple(rel.key_shape[d] for d in gb)

    mask = rel.mask
    if mask is not None:
        mask_t = np.moveaxis(mask, perm, list(range(k)))
        if kernel.identity is None:
            raise ValueError(
                f"agg over holes needs identity for {kernel.name}")
        fill = jnp.asarray(kernel.identity, dtype=data.dtype)
        mb = mask_t.reshape(mask_t.shape + (1,) * rel.rtype.rank)
        data = jnp.where(jnp.asarray(mb), data, fill)
        out_mask = np.any(mask_t, axis=tuple(range(len(gb), k))) \
            if reduce_dims else mask_t
        if np.all(out_mask):
            out_mask = None
    else:
        out_mask = None

    axes = tuple(range(len(gb), k))
    if not axes:
        out = data
    elif kernel.reduce is not None:
        out = kernel.reduce(data, axes)
    else:
        flat = data.reshape(out_key_shape + (-1,) + tuple(rel.bound))
        flat = jnp.moveaxis(flat, len(gb), 0)
        out = _tree_fold(flat, kernel)
    rt = RelType(out_key_shape, rel.bound, out.dtype)
    return TensorRelation(out, rt, out_mask)


# ==========================================================================
# Fused join→agg (Σ∘⋈ as a blocked contraction — never materializes the
# broadcasted cross-product grid the unfused pair would build)
# ==========================================================================

# Join kernels whose Σ∘⋈ with matAdd is a pure tensor contraction.  The
# value maps (left-bound, right-bound, out-bound) dims to contraction
# letters; ``None`` marks an elementwise kernel (all bound dims shared).
_CONTRACTION_JOINS = {
    "matMul": ("mk", "kn", "mn"),
    "matTranMulL": ("km", "kn", "mn"),
    "matTranMulR": ("mk", "nk", "mn"),
    "elemMul": None,
}


def can_fuse(join_kernel: Kernel, agg_kernel: Kernel) -> bool:
    """True when ``agg(join(·, join_kernel), agg_kernel)`` has a fused
    lowering (a contraction or a streamed associative reduction)."""
    return (join_kernel.arity == 2 and agg_kernel.arity == 2
            and agg_kernel.is_associative)


def _joint_mask_grid(g: _JoinGeometry) -> Optional[np.ndarray]:
    """Joined validity grid over the full output key space (bools only —
    key-grid sized, so cheap even when the payload grid is not)."""
    if g.lmask is None and g.rmask_t is None:
        return None
    lm = (g.lmask if g.lmask is not None
          else np.ones(g.f_out_l, bool)).reshape(
        g.f_out_l + (1,) * (g.k_out - g.kl))
    rm = (g.rmask_t.reshape(g.r_shape) if g.rmask_t is not None
          else np.ones((1,) * g.k_out, bool))
    return np.broadcast_to(lm, g.out_key_shape) \
        & np.broadcast_to(rm, g.out_key_shape)


def _fused_out_mask(g: _JoinGeometry, gb: Tuple[int, ...],
                    reduce_dims: Tuple[int, ...]) -> Optional[np.ndarray]:
    """Static output mask of agg∘join."""
    jm = _joint_mask_grid(g)
    if jm is None:
        return None
    om = np.any(jm, axis=reduce_dims) if reduce_dims else jm
    remaining = [d for d in range(g.k_out) if d not in reduce_dims]
    om = om.transpose([remaining.index(d) for d in gb])
    return None if np.all(om) else om


def _zero_fill(data: jax.Array, mask: Optional[np.ndarray],
               bound_rank: int) -> jax.Array:
    if mask is None:
        return data
    m = jnp.asarray(mask.reshape(mask.shape + (1,) * bound_rank))
    return jnp.where(m, data, jnp.zeros((), data.dtype))


def _fused_matmul_2d(g: _JoinGeometry, left: TensorRelation,
                     right: TensorRelation, jkl: Tuple[int, ...],
                     gb: Tuple[int, ...]) -> jax.Array:
    """Collapse Σ∘⋈_(matMul→matAdd) into ONE blocked 2-D matmul.

    Valid when every joined key dim is reduced and every reduced dim is
    joined: the whole expression is exactly ``(I·m, K·c) @ (K·c, J·n)`` —
    the paper's claim that the TRA plan *is* the hand-tuned contraction.
    Dispatches through :func:`repro.kernels.matmul.ops.matmul`, which
    selects the Pallas MXU kernel on TPU (``impl="auto"``) and the XLA
    matmul elsewhere.
    """
    from repro.kernels.matmul.ops import matmul as matmul_op

    kl = g.kl
    kept_l = [ax for ax in range(kl) if ax not in jkl]
    kept_r = [ax for ax in range(kl, g.k_out)]
    m, c = left.bound
    _, n = right.bound
    # left: (f_out_l ++ (m, c)) → (kept_l..., m, joined..., c) → 2-D
    lperm = kept_l + [kl] + list(jkl) + [kl + 1]
    L2 = jnp.transpose(g.ldata, lperm).reshape(
        math.prod(g.f_out_l[ax] for ax in kept_l) * m,
        math.prod(g.f_out_l[ax] for ax in jkl) * c)
    # right: covered-axis order → (joined in jkl order..., c, kept_r..., n)
    pos = {ax: i for i, ax in enumerate(g.covered)}
    nb = len(g.covered)
    rperm = [pos[ax] for ax in jkl] + [nb] \
        + [pos[ax] for ax in kept_r] + [nb + 1]
    R2 = jnp.transpose(g.rdata_t, rperm).reshape(
        math.prod(g.f_out_l[ax] for ax in jkl) * c,
        math.prod(g.out_key_shape[ax] for ax in kept_r) * n)
    out2 = matmul_op(L2, R2, impl="auto")
    # back to blocks: (kept_l..., m, kept_r..., n) → gb order ++ (m, n)
    out = out2.reshape(tuple(g.f_out_l[ax] for ax in kept_l) + (m,)
                       + tuple(g.out_key_shape[ax] for ax in kept_r) + (n,))
    axis_of = {ax: i for i, ax in enumerate(kept_l)}
    for j, ax in enumerate(kept_r):
        axis_of[ax] = len(kept_l) + 1 + j
    perm = [axis_of[d] for d in gb] + [len(kept_l),
                                       len(kept_l) + 1 + len(kept_r)]
    return jnp.transpose(out, perm)


def _fused_einsum(g: _JoinGeometry, left: TensorRelation,
                  right: TensorRelation, join_kernel: Kernel,
                  gb: Tuple[int, ...]) -> jax.Array:
    """Lower Σ∘⋈ to one ``jnp.einsum`` contraction (→ lax.dot_general)."""
    import string
    letters = string.ascii_lowercase + string.ascii_uppercase
    key_l = letters[:g.k_out]
    spec = _CONTRACTION_JOINS[join_kernel.name]
    if spec is None:                       # elementwise join kernel
        r = len(left.bound)
        bl = br = bo = letters[g.k_out:g.k_out + r]
    else:
        fresh = {ch: letters[g.k_out + i]
                 for i, ch in enumerate(sorted(set("".join(spec))))}
        bl, br, bo = ("".join(fresh[ch] for ch in part) for part in spec)
    l_sub = "".join(key_l[ax] for ax in range(g.kl)) + bl
    r_sub = "".join(key_l[ax] for ax in g.covered) + br
    o_sub = "".join(key_l[d] for d in gb) + bo
    ldata = _zero_fill(g.ldata, g.lmask, len(left.bound))
    rdata = _zero_fill(g.rdata_t, g.rmask_t, len(right.bound))
    return jnp.einsum(f"{l_sub},{r_sub}->{o_sub}", ldata, rdata)


def _fused_chunked(g: _JoinGeometry, left: TensorRelation,
                   right: TensorRelation, join_kernel: Kernel,
                   gb: Tuple[int, ...], reduce_dims: Tuple[int, ...],
                   agg_kernel: Kernel, chunk: int) -> jax.Array:
    """Stream the reduction over the contracted key dims.

    A ``fori_loop`` walks the flattened reduce-key grid ``chunk`` cells per
    step; each step materializes only ``chunk`` grid *slices* (one slice =
    the group-by grid × one reduce coordinate) and folds them into the
    accumulator with the associative agg kernel.  Peak live payload is
    O(output + chunk·slice) instead of the unfused O(full grid).
    """
    k_out, kl = g.k_out, g.kl
    out_bound = tuple(join_kernel.out_bound(left.bound, right.bound))
    ldata_b = g.ldata.reshape(g.f_out_l + (1,) * (k_out - kl)
                              + tuple(left.bound))
    rdata_b = g.rdata_t.reshape(g.r_shape + tuple(right.bound))
    jm = _joint_mask_grid(g)
    jm_dev = None if jm is None else jnp.asarray(jm)
    red_sizes = tuple(g.out_key_shape[d] for d in reduce_dims)
    nred = math.prod(red_sizes)

    def take(x, coords):
        for d, cidx in zip(reduce_dims, coords):
            cidx = jnp.minimum(cidx, x.shape[d] - 1)   # clamp size-1 axes
            x = jax.lax.dynamic_slice_in_dim(x, cidx, 1, axis=d)
        return x

    def cell_val(i):
        coords, rem = [], i
        for sz in reversed(red_sizes):
            coords.append(rem % sz)
            rem = rem // sz
        coords = coords[::-1]
        val = join_kernel.apply(take(ldata_b, coords), take(rdata_b, coords))
        if jm_dev is not None:
            msk = take(jm_dev, coords)
            fill = jnp.asarray(agg_kernel.identity, val.dtype)
            val = jnp.where(
                msk.reshape(msk.shape + (1,) * len(out_bound)), val, fill)
        return val

    csize = max(1, min(int(chunk), nred))
    while nred % csize:
        csize -= 1

    def step_val(s):
        base = s * csize
        if csize == 1:
            return cell_val(base)
        vals = jax.vmap(lambda j: cell_val(base + j))(jnp.arange(csize))
        return _tree_fold(vals, agg_kernel)

    acc = step_val(0)
    acc = jax.lax.fori_loop(
        1, nred // csize, lambda s, a: agg_kernel.apply(a, step_val(s)), acc)

    res = jnp.squeeze(acc, axis=reduce_dims)
    remaining = [d for d in range(k_out) if d not in reduce_dims]
    perm = [remaining.index(d) for d in gb] \
        + [len(gb) + i for i in range(len(out_bound))]
    return jnp.transpose(res, perm)


# Default streaming-chunk budget for the fused fallback path: each
# fori_loop step materializes ``chunk`` grid slices, so the bytes-based
# default keeps peak live payload near this budget regardless of shape.
DEFAULT_CHUNK_BYTES = 16 * 1024 * 1024


def fused_join_agg(left: TensorRelation, right: TensorRelation,
                   join_keys_l: Sequence[int], join_keys_r: Sequence[int],
                   join_kernel: Kernel, group_by: Sequence[int],
                   agg_kernel: Kernel, *,
                   chunk=None,
                   budget: Optional[int] = None,
                   ctx=None, node=None) -> TensorRelation:
    """Σ_(groupBy, aggOp) ∘ ⋈_(jkl, jkr, projOp) without the grid.

    Semantically identical to ``agg(join(left, right, ...), group_by, ...)``
    (``group_by`` indexes the join's output key space) but lowered as:

    * one blocked 2-D matmul (Pallas on TPU) when (matMul, matAdd) collapses
      cleanly — the paper's BMM/CPMM/RMM inner contraction;
    * one ``jnp.einsum``/dot_general for any contraction-shaped pair
      (matMul / matTranMulL / matTranMulR / elemMul with matAdd);
    * a chunked ``lax.fori_loop`` streaming reduction for every other
      associative kernel pair.  ``chunk`` is the number of grid slices
      each loop step materializes; ``None`` derives it from
      :data:`DEFAULT_CHUNK_BYTES`, and ``"auto"`` (the Engine default)
      autotunes it from the device memory ``budget`` via the live-slice
      bytes model in :mod:`repro.store.autotune` (configurable per
      :class:`~repro.core.engine.Engine` via its ``chunk`` /
      ``memory_budget`` parameters).

    ``ctx`` (an :class:`~repro.core.guards.ExecContext`) hooks the fault
    injector's device-OOM model before each contraction lowers and, when
    ``ctx.stream`` is set (the engine's OOM degradation ladder), forces
    even contraction-shaped pairs onto the chunked streaming fallback so
    peak live memory is bounded by ``chunk`` slices.

    Falls back to the unfused pair when nothing is actually reduced or when
    holes cannot be identity-filled — the unfused path remains the
    correctness oracle in all cases.
    """
    jkl, jkr = tuple(join_keys_l), tuple(join_keys_r)
    gb = tuple(group_by)
    if not agg_kernel.is_associative:
        raise ValueError(f"agg kernel {agg_kernel.name} must be associative")
    g = _join_align(left, right, jkl, jkr)
    reduce_dims = tuple(d for d in range(g.k_out) if d not in gb)
    if not reduce_dims or not can_fuse(join_kernel, agg_kernel):
        return agg(join(left, right, jkl, jkr, join_kernel), gb, agg_kernel)

    out_bound = tuple(join_kernel.out_bound(left.bound, right.bound))
    out_key_shape = tuple(g.out_key_shape[d] for d in gb)
    out_mask = _fused_out_mask(g, gb, reduce_dims)

    # live-bytes estimates for the injected-OOM device model (ok_bytes):
    # inputs + output for the one-shot contraction; inputs + chunk slices
    # + accumulator/partial pair for the streamed fallback
    itemsize = jnp.dtype(left.data.dtype).itemsize
    out_floats = (math.prod(out_key_shape) if out_key_shape else 1) \
        * (math.prod(out_bound) if out_bound else 1)
    in_bytes = (g.ldata.size + g.rdata_t.size) * itemsize
    out_bytes = out_floats * itemsize

    streaming = ctx is not None and ctx.stream
    if (not streaming and agg_kernel.name == "matAdd"
            and join_kernel.name in _CONTRACTION_JOINS):
        if ctx is not None:
            ctx.on_contraction(stream=False, chunk=None, node=node,
                               bytes_live=in_bytes + out_bytes)
        if (join_kernel.name == "matMul" and g.lmask is None
                and g.rmask_t is None and set(reduce_dims) == set(jkl)):
            data = _fused_matmul_2d(g, left, right, jkl, gb)
        else:
            data = _fused_einsum(g, left, right, join_kernel, gb)
        return TensorRelation(
            data, RelType(out_key_shape, out_bound, data.dtype), out_mask)

    has_mask = g.lmask is not None or g.rmask_t is not None
    if has_mask and agg_kernel.identity is None:
        # cannot identity-fill holes — mirror tra.agg's requirement
        return agg(join(left, right, jkl, jkr, join_kernel), gb, agg_kernel)
    if chunk is None or chunk == "auto":
        slice_bytes = max(1, out_floats * itemsize)
        if chunk == "auto":
            from repro.store.autotune import chunk_slices
            chunk = chunk_slices(slice_bytes, out_bytes, budget)
        else:
            chunk = max(1, DEFAULT_CHUNK_BYTES // slice_bytes)
    if ctx is not None:
        ctx.on_contraction(
            stream=True, chunk=chunk, node=node,
            bytes_live=in_bytes + chunk * out_bytes + 2 * out_bytes)
    data = _fused_chunked(g, left, right, join_kernel, gb, reduce_dims,
                          agg_kernel, chunk)
    return TensorRelation(
        data, RelType(out_key_shape, out_bound, data.dtype), out_mask)


def rekey(rel: TensorRelation, key_func: KeyFunc,
          out_arity: Optional[int] = None) -> TensorRelation:
    """ReKey_(keyFunc)(R) — keys are static, so this is a static scatter."""
    keys = rel.valid_keys()
    new_keys = np.asarray([key_func(tuple(int(x) for x in k)) for k in keys],
                          dtype=np.int64)
    if new_keys.ndim == 1:
        new_keys = new_keys[:, None]
    if out_arity is not None and new_keys.shape[1] != out_arity:
        raise ValueError("key_func arity mismatch")
    if len(new_keys) == 0:
        raise ValueError("rekey of an empty relation")
    uniq = {tuple(k) for k in new_keys.tolist()}
    if len(uniq) != len(new_keys):
        raise ValueError("rekey produced duplicate keys (uniqueness violated)")
    f_out = tuple(int(m) + 1 for m in new_keys.max(axis=0))
    flat_src = np.ravel_multi_index(keys.T, rel.key_shape) if rel.key_shape \
        else np.zeros(1, np.int64)
    src = rel.data.reshape((-1,) + tuple(rel.bound))[flat_src]
    out = jnp.zeros(f_out + tuple(rel.bound), rel.data.dtype)
    out = out.at[tuple(new_keys.T)].set(src)
    mask = np.zeros(f_out, bool)
    mask[tuple(new_keys.T)] = True
    if np.all(mask):
        mask = None
    rt = RelType(f_out, rel.bound, rel.data.dtype)
    return TensorRelation(out, rt, mask)


def filt(rel: TensorRelation, bool_func: BoolFunc) -> TensorRelation:
    """σ_(boolFunc)(R) — static key predicate ⇒ static mask update."""
    grid = np.indices(rel.key_shape).reshape(rel.rtype.key_arity, -1).T
    keep = np.asarray([bool(bool_func(tuple(int(x) for x in k)))
                       for k in grid]).reshape(rel.key_shape)
    mask = keep if rel.mask is None else np.logical_and(rel.mask, keep)
    if not mask.any():
        raise ValueError("filter removed every tuple")
    # frontier shrink (paper §4.3 rule 3): slice to the bounding box
    idx = np.argwhere(mask)
    f_out = tuple(int(m) + 1 for m in idx.max(axis=0))
    sl = tuple(slice(0, f) for f in f_out)
    data = rel.data[sl]
    mask = mask[sl]
    if np.all(mask):
        mask = None
    rt = RelType(f_out, rel.bound, rel.data.dtype)
    return TensorRelation(data, rt, mask)


def pad(rel: TensorRelation, key_shape: Sequence[int]) -> TensorRelation:
    """Pad_(keyShape)(R) — densify: zero-fill holes, grow the frontier.

    The dual of σ, introduced for the autodiff layer: converts "tuple
    absent" into "tuple present with value 0" so cotangents over filtered
    key spaces can be accumulated on one common grid.
    """
    ks = tuple(key_shape)
    if len(ks) != rel.rtype.key_arity or \
            any(k < f for k, f in zip(ks, rel.key_shape)):
        raise ValueError(
            f"pad key_shape {ks} must cover frontier {rel.key_shape}")
    data = rel.data
    if rel.mask is not None:
        m = jnp.asarray(
            rel.mask.reshape(rel.mask.shape + (1,) * rel.rtype.rank))
        data = jnp.where(m, data, jnp.zeros((), data.dtype))
    if ks != rel.key_shape:
        widths = [(0, k - f) for k, f in zip(ks, rel.key_shape)] \
            + [(0, 0)] * rel.rtype.rank
        data = jnp.pad(data, widths)
    return TensorRelation(data, RelType(ks, rel.bound, data.dtype))


def transform(rel: TensorRelation, kernel: Kernel) -> TensorRelation:
    """λ_(transformFunc)(R)."""
    out = kernel.apply(rel.data)
    out_bound = tuple(kernel.out_bound(rel.bound))
    rt = RelType(rel.key_shape, out_bound, out.dtype)
    return TensorRelation(out, rt, rel.mask)


def tile(rel: TensorRelation, tile_dim: int, tile_size: int) -> TensorRelation:
    """Tile_(tileDim, tileSize)(R) — split an array dim, append a key dim."""
    b = rel.bound
    if b[tile_dim] % tile_size:
        raise ValueError("tile size must divide the bound")
    ntiles = b[tile_dim] // tile_size
    k = rel.rtype.key_arity
    ax = k + tile_dim
    shape = (rel.key_shape + b[:tile_dim] + (ntiles, tile_size)
             + b[tile_dim + 1:])
    x = rel.data.reshape(shape)
    x = jnp.moveaxis(x, ax, k)          # new key dim appended after keys
    new_bound = b[:tile_dim] + (tile_size,) + b[tile_dim + 1:]
    rt = RelType(rel.key_shape + (ntiles,), new_bound, rel.data.dtype)
    mask = None
    if rel.mask is not None:
        mask = np.repeat(rel.mask[..., None], ntiles, axis=-1)
    return TensorRelation(x, rt, mask)


def concat(rel: TensorRelation, key_dim: int, array_dim: int) -> TensorRelation:
    """Concat_(keyDim, arrayDim)(R) — inverse of tile."""
    if rel.mask is not None:
        mt = np.moveaxis(rel.mask, key_dim, -1)
        if not (np.all(mt == mt[..., :1])):
            raise ValueError("concat groups must be complete")
    k = rel.rtype.key_arity
    x = jnp.moveaxis(rel.data, key_dim, k - 1 + array_dim)
    # now the concat key dim sits immediately before the target array axis
    new_key_shape = tuple(s for d, s in enumerate(rel.key_shape)
                          if d != key_dim)
    nb = list(rel.bound)
    nb[array_dim] = rel.key_shape[key_dim] * rel.bound[array_dim]
    x = x.reshape(new_key_shape + tuple(nb))
    mask = None
    if rel.mask is not None:
        mask = np.take(rel.mask, 0, axis=key_dim)
        if np.all(mask):
            mask = None
    rt = RelType(new_key_shape, tuple(nb), rel.data.dtype)
    return TensorRelation(x, rt, mask)


# ==========================================================================
# Serving helpers (repro.serve): batch-key packing + fixed-capacity slots
# ==========================================================================
#
# A serving layer batches concurrent requests into ONE relation by adding a
# new leading key dim (the batch key), padded to a bucket size so the
# engine's structural compile cache stays hot across request counts.  The
# decode state lives in a fixed-capacity relation whose leading key dim
# indexes *slots*; admission/eviction are functional row writes.  All three
# helpers require continuous (mask-free) relations — serving padding is
# zero *rows*, not key holes, so the batched programs run on every staged
# executor (which reject masked inputs).

def _batched_rtype(rtype: RelType, bucket: int) -> RelType:
    return RelType((bucket,) + tuple(rtype.key_shape), tuple(rtype.bound),
                   rtype.dtype)


def pack_rows(rows: Sequence, bucket: int, rtype: RelType
              ) -> TensorRelation:
    """Pack per-request values into one bucket-padded batched relation.

    ``rows`` are :class:`TensorRelation`\\ s of type ``rtype`` (or raw
    dense arrays of shape ``key_shape ++ bound``), one per request.  The
    result gains a NEW leading key dim of size ``bucket`` — row ``i`` is
    request ``i``'s value; rows ``len(rows)..bucket-1`` are zero padding.
    Programs that never contract the batch key dim compute each row
    independently, so the padding rows are inert (see
    ``tests/test_serve.py`` for the masked-tail oracle).
    """
    if not 0 < len(rows) <= bucket:
        raise ValueError(
            f"pack_rows: {len(rows)} rows do not fit bucket {bucket}")
    dense = tuple(rtype.key_shape) + tuple(rtype.bound)
    datas = []
    for i, r in enumerate(rows):
        if isinstance(r, TensorRelation):
            if r.rtype.key_shape != rtype.key_shape \
                    or r.rtype.bound != rtype.bound:
                raise ValueError(
                    f"pack_rows: row {i} has type "
                    f"f={r.rtype.key_shape} b={r.rtype.bound}, expected "
                    f"f={rtype.key_shape} b={rtype.bound}")
            if r.mask is not None:
                raise ValueError(
                    f"pack_rows: row {i} carries a mask; serving "
                    f"relations must be continuous")
            datas.append(r.data)
        else:
            arr = jnp.asarray(r, rtype.dtype)
            if tuple(arr.shape) != dense:
                raise ValueError(
                    f"pack_rows: row {i} has dense shape "
                    f"{tuple(arr.shape)}, expected {dense}")
            datas.append(arr)
    stacked = jnp.stack(datas, axis=0)
    if len(rows) < bucket:
        padding = jnp.zeros((bucket - len(rows),) + dense, rtype.dtype)
        stacked = jnp.concatenate([stacked, padding], axis=0)
    return TensorRelation(stacked, _batched_rtype(rtype, bucket))


def unpack_rows(rel: TensorRelation, n: Optional[int] = None) -> list:
    """Split a batched relation back into per-request relations.

    Inverse of :func:`pack_rows` over the leading (batch) key dim:
    returns the first ``n`` rows (default: all) as relations typed
    without the batch key dim.
    """
    if rel.mask is not None:
        raise ValueError("unpack_rows: batched relations are continuous")
    if not rel.rtype.key_shape:
        raise ValueError("unpack_rows: relation has no batch key dim")
    bucket = rel.rtype.key_shape[0]
    n = bucket if n is None else n
    if not 0 <= n <= bucket:
        raise ValueError(f"unpack_rows: n={n} outside bucket {bucket}")
    row_rt = RelType(tuple(rel.rtype.key_shape[1:]),
                     tuple(rel.rtype.bound), rel.rtype.dtype)
    return [TensorRelation(rel.data[i], row_rt) for i in range(n)]


def scatter_rows(rel: TensorRelation, slots: Sequence[int],
                 rows: Sequence) -> TensorRelation:
    """Functionally write per-slot values into a fixed-capacity relation.

    ``rel`` is slot-keyed (leading key dim = capacity); ``rows[i]`` (a
    relation or dense array typed like one slot) replaces slot
    ``slots[i]``.  This is the serving layer's slot allocate/evict
    primitive: admission writes freshly initialized state rows, eviction
    zeroes freed ones — both out-of-place, so a compiled step program's
    inputs are never mutated under it.
    """
    if len(slots) != len(rows):
        raise ValueError(
            f"scatter_rows: {len(slots)} slots vs {len(rows)} rows")
    if rel.mask is not None:
        raise ValueError("scatter_rows: slot relations are continuous")
    if not rel.rtype.key_shape:
        raise ValueError("scatter_rows: relation has no slot key dim")
    if not slots:
        return rel
    capacity = rel.rtype.key_shape[0]
    dense = tuple(rel.rtype.key_shape[1:]) + tuple(rel.rtype.bound)
    datas = []
    for i, r in enumerate(rows):
        arr = r.data if isinstance(r, TensorRelation) else \
            jnp.asarray(r, rel.rtype.dtype)
        if tuple(arr.shape) != dense:
            raise ValueError(
                f"scatter_rows: row {i} has dense shape "
                f"{tuple(arr.shape)}, expected {dense}")
        datas.append(arr)
    idx = []
    for s in slots:
        if not 0 <= s < capacity:
            raise ValueError(
                f"scatter_rows: slot {s} outside capacity {capacity}")
        idx.append(int(s))
    if len(set(idx)) != len(idx):
        raise ValueError(f"scatter_rows: duplicate slots {idx}")
    data = rel.data.at[jnp.asarray(idx)].set(jnp.stack(datas, axis=0))
    return TensorRelation(data, rel.rtype)


def zero_rows(rel: TensorRelation, slots: Sequence[int]) -> TensorRelation:
    """Zero the given slots of a fixed-capacity relation (slot free).

    Implemented as a full-capacity mask multiply rather than a gather /
    scatter: the traced shapes depend only on the relation's type, never
    on ``len(slots)``, so a serving loop freeing a different number of
    slots each tick reuses ONE compiled XLA computation instead of
    paying a recompile per distinct eviction count.
    """
    if rel.mask is not None:
        raise ValueError("zero_rows: slot relations are continuous")
    if not rel.rtype.key_shape:
        raise ValueError("zero_rows: relation has no slot key dim")
    if not slots:
        return rel
    capacity = rel.rtype.key_shape[0]
    keep = np.ones((capacity,) + (1,) * (rel.data.ndim - 1),
                   dtype=np.asarray(rel.data).dtype)
    for s in slots:
        if not 0 <= s < capacity:
            raise ValueError(f"zero_rows: slot {s} out of range "
                             f"[0, {capacity})")
        keep[s] = 0.0
    return TensorRelation(rel.data * jnp.asarray(keep), rel.rtype)
