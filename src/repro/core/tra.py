"""Tensor relations and eager TRA operations (paper §2).

Representation
--------------
The paper's integrity constraints (key *uniqueness* + *continuity*) make a
tensor relation of type ``R^(k, r, b)`` with frontier ``f`` isomorphic to a
dense array of shape ``f ++ b`` — keys become the leading ``k`` axes.  That
is exactly the representation used here, so the whole algebra stays inside
jnp and can be jit/pjit-ed.

Relations that pass through ``σ`` (filter) or a non-bijective ``ReKey`` can
violate continuity ("holes").  Keys are *static* metadata (frontiers are
known at trace time), so holes are represented by a static numpy boolean
``mask`` over the key grid — no dynamic shapes are ever needed, matching the
paper's observation that cardinalities are exact, never estimated.

Two executors share this module:
  * the dense jnp ops below (production path, jit-able),
  * :mod:`repro.core.reference` — a dict-of-numpy tuple-at-a-time oracle used
    by the hypothesis property tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_registry import Kernel

KeyFunc = Callable[[Tuple[int, ...]], Tuple[int, ...]]
BoolFunc = Callable[[Tuple[int, ...]], bool]


@dataclasses.dataclass(frozen=True)
class RelType:
    """Static type of a tensor relation: key frontier + array bound."""

    key_shape: Tuple[int, ...]   # frontier f  (exact, by continuity)
    bound: Tuple[int, ...]       # array bound b
    dtype: object = jnp.float32

    @property
    def key_arity(self) -> int:
        return len(self.key_shape)

    @property
    def rank(self) -> int:
        return len(self.bound)

    @property
    def ntuples(self) -> int:
        return math.prod(self.key_shape) if self.key_shape else 1

    @property
    def nfloats(self) -> int:
        """Total scalar payload — the paper's exact ``n × ∏ b_i``."""
        return self.ntuples * (math.prod(self.bound) if self.bound else 1)

    def with_key_shape(self, ks: Sequence[int]) -> "RelType":
        return dataclasses.replace(self, key_shape=tuple(ks))

    def with_bound(self, b: Sequence[int]) -> "RelType":
        return dataclasses.replace(self, bound=tuple(b))


@dataclasses.dataclass
class TensorRelation:
    """A dense-backed tensor relation value."""

    data: jax.Array              # shape = key_shape + bound
    rtype: RelType
    mask: Optional[np.ndarray] = None   # static validity grid or None (=all)

    def __post_init__(self) -> None:
        expect = tuple(self.rtype.key_shape) + tuple(self.rtype.bound)
        if tuple(self.data.shape) != expect:
            raise ValueError(
                f"data shape {self.data.shape} != type shape {expect}")
        if self.mask is not None and self.mask.shape != self.rtype.key_shape:
            raise ValueError("mask shape mismatch")

    # -- conveniences -----------------------------------------------------
    @property
    def key_shape(self) -> Tuple[int, ...]:
        return self.rtype.key_shape

    @property
    def bound(self) -> Tuple[int, ...]:
        return self.rtype.bound

    def is_continuous(self) -> bool:
        return self.mask is None or bool(np.all(self.mask))

    def valid_keys(self) -> np.ndarray:
        """(n, k) int array of valid keys, row-major order."""
        if not self.key_shape:
            return np.zeros((1, 0), np.int64)
        grid = np.indices(self.key_shape).reshape(len(self.key_shape), -1).T
        if self.mask is None:
            return grid
        return grid[self.mask.reshape(-1)]

    def to_dict(self) -> dict:
        """Materialize as {key tuple: np.ndarray} (reference format)."""
        out = {}
        data = np.asarray(self.data)
        for key in self.valid_keys():
            out[tuple(int(x) for x in key)] = data[tuple(key)]
        return out


def _full_mask_and(a: Optional[np.ndarray], b: Optional[np.ndarray],
                   shape: Tuple[int, ...]) -> Optional[np.ndarray]:
    if a is None and b is None:
        return None
    aa = np.broadcast_to(a if a is not None else True, shape)
    bb = np.broadcast_to(b if b is not None else True, shape)
    return np.logical_and(aa, bb)


# ==========================================================================
# Constructors
# ==========================================================================

def from_tensor(tensor: jax.Array, tile: Sequence[int]) -> TensorRelation:
    """Chunk a dense tensor into a tensor relation with block-index keys.

    ``tile[d]`` is the block size along tensor dim ``d`` (must divide the
    dim).  Keys are block coordinates; arrays are the blocks.
    """
    tile = tuple(tile)
    if len(tile) != tensor.ndim:
        raise ValueError("tile rank mismatch")
    key_shape = []
    for d, t in enumerate(tile):
        if tensor.shape[d] % t:
            raise ValueError(f"dim {d} ({tensor.shape[d]}) not divisible by {t}")
        key_shape.append(tensor.shape[d] // t)
    # reshape (k0, t0, k1, t1, ...) then move key axes to the front
    interleaved = []
    for k, t in zip(key_shape, tile):
        interleaved += [k, t]
    x = tensor.reshape(interleaved)
    perm = list(range(0, 2 * len(tile), 2)) + list(range(1, 2 * len(tile), 2))
    x = jnp.transpose(x, perm)
    rt = RelType(tuple(key_shape), tile, tensor.dtype)
    return TensorRelation(x, rt)


def to_tensor(rel: TensorRelation,
              key_dims: Optional[Sequence[int]] = None) -> jax.Array:
    """Reassemble a continuous relation into a dense tensor.

    ``key_dims[i]`` names the array dim that key dim ``i`` blocks along
    (default: the identity, requiring key arity == rank).
    """
    if not rel.is_continuous():
        raise ValueError("cannot reassemble a relation with holes")
    k, r = rel.rtype.key_arity, rel.rtype.rank
    if key_dims is None:
        if k != r:
            raise ValueError(f"key arity {k} != rank {r}; pass key_dims")
        key_dims = tuple(range(k))
    key_dims = tuple(key_dims)
    if len(key_dims) != k or len(set(key_dims)) != k:
        raise ValueError("key_dims must name each key dim once")
    # interleave: for each array dim, optionally prefix its key dim
    perm = []
    shape = []
    for d in range(r):
        if d in key_dims:
            perm.append(key_dims.index(d))
            shape.append(rel.key_shape[key_dims.index(d)] * rel.bound[d])
        else:
            shape.append(rel.bound[d])
        perm.append(k + d)
    x = jnp.transpose(rel.data, perm)
    return x.reshape(shape)


# ==========================================================================
# TRA operations (eager, dense)
# ==========================================================================

def join(left: TensorRelation, right: TensorRelation,
         join_keys_l: Sequence[int], join_keys_r: Sequence[int],
         kernel: Kernel) -> TensorRelation:
    """⋈_(joinKeysL, joinKeysR, projOp)(L, R).

    Output keys: all left keys (original order) then right keys with the
    joined dims dropped — the paper's natural-join convention.
    """
    jkl, jkr = tuple(join_keys_l), tuple(join_keys_r)
    if len(jkl) != len(jkr):
        raise ValueError("join key lists must have equal length")
    kl = left.rtype.key_arity
    kr = right.rtype.key_arity
    r_nonjoin = [d for d in range(kr) if d not in jkr]

    # equi-join on a dense grid: valid range of a joined dim is the min of
    # the two frontiers (paper §4.3 rule 1)
    f_out_l = list(left.key_shape)
    for i, dl in enumerate(jkl):
        f_out_l[dl] = min(left.key_shape[dl], right.key_shape[jkr[i]])
    ldata = left.data[tuple(slice(0, f) for f in f_out_l)]
    lmask = None if left.mask is None else \
        left.mask[tuple(slice(0, f) for f in f_out_l)]

    r_slices = [slice(None)] * kr
    for i, dr in enumerate(jkr):
        r_slices[dr] = slice(0, f_out_l[jkl[i]])
    rdata = right.data[tuple(r_slices)]
    rmask = None if right.mask is None else right.mask[tuple(r_slices)]

    out_key_shape = tuple(f_out_l) + tuple(rdata.shape[d] for d in r_nonjoin)
    k_out = len(out_key_shape)

    # Align RIGHT onto the output key axes:
    #   joined right dim jkr[i]   -> output axis jkl[i]
    #   non-joined right dim d    -> output axis kl + (index in r_nonjoin)
    out_axis_of_rdim = {}
    for i, dr in enumerate(jkr):
        out_axis_of_rdim[dr] = jkl[i]
    for i, dr in enumerate(r_nonjoin):
        out_axis_of_rdim[dr] = kl + i
    order = sorted(range(kr), key=lambda d: out_axis_of_rdim[d])
    rdata_t = jnp.moveaxis(rdata, list(range(kr)),
                           [order.index(d) for d in range(kr)])
    rmask_t = None if rmask is None else np.moveaxis(
        rmask, list(range(kr)), [order.index(d) for d in range(kr)])
    # insert singleton axes for output key axes not covered by the right
    covered = sorted(out_axis_of_rdim.values())
    r_shape = []
    ci = 0
    for ax in range(k_out):
        if ci < len(covered) and covered[ci] == ax:
            r_shape.append(rdata_t.shape[ci])
            ci += 1
        else:
            r_shape.append(1)
    rdata_b = rdata_t.reshape(tuple(r_shape) + tuple(right.bound))
    rmask_b = None if rmask_t is None else rmask_t.reshape(tuple(r_shape))

    # left occupies the first kl output axes
    ldata_b = ldata.reshape(tuple(f_out_l) + (1,) * (k_out - kl)
                            + tuple(left.bound))

    lb = jnp.broadcast_to(ldata_b, out_key_shape + tuple(left.bound))
    rb = jnp.broadcast_to(rdata_b, out_key_shape + tuple(right.bound))
    out = kernel.apply(lb, rb)

    out_bound = kernel.out_bound(left.bound, right.bound)
    rt = RelType(out_key_shape, tuple(out_bound), out.dtype)
    lmask_b = None if lmask is None else lmask.reshape(
        tuple(f_out_l) + (1,) * (k_out - kl))
    mask = _full_mask_and(lmask_b, rmask_b, out_key_shape)
    return TensorRelation(out, rt, mask)


def _tree_fold(blocks: jax.Array, kernel: Kernel) -> jax.Array:
    """Fold axis 0 of ``blocks`` with an associative binary kernel."""
    n = blocks.shape[0]
    while n > 1:
        half = n // 2
        a = blocks[:half]
        b = blocks[half:2 * half]
        merged = kernel.apply(a, b)
        if n % 2:
            merged = jnp.concatenate([merged, blocks[2 * half:n]], axis=0)
        blocks = merged
        n = blocks.shape[0]
    return blocks[0]


def agg(rel: TensorRelation, group_by: Sequence[int],
        kernel: Kernel) -> TensorRelation:
    """Σ_(groupByKeys, aggOp)(R)."""
    if not kernel.is_associative:
        raise ValueError(f"agg kernel {kernel.name} must be associative")
    gb = tuple(group_by)
    k = rel.rtype.key_arity
    reduce_dims = tuple(d for d in range(k) if d not in gb)
    # reorder keys: group-by dims (in requested order) first
    perm = list(gb) + list(reduce_dims)
    data = jnp.moveaxis(rel.data, perm, list(range(k)))
    out_key_shape = tuple(rel.key_shape[d] for d in gb)

    mask = rel.mask
    if mask is not None:
        mask_t = np.moveaxis(mask, perm, list(range(k)))
        if kernel.identity is None:
            raise ValueError(
                f"agg over holes needs identity for {kernel.name}")
        fill = jnp.asarray(kernel.identity, dtype=data.dtype)
        mb = mask_t.reshape(mask_t.shape + (1,) * rel.rtype.rank)
        data = jnp.where(jnp.asarray(mb), data, fill)
        out_mask = np.any(mask_t, axis=tuple(range(len(gb), k))) \
            if reduce_dims else mask_t
        if np.all(out_mask):
            out_mask = None
    else:
        out_mask = None

    axes = tuple(range(len(gb), k))
    if not axes:
        out = data
    elif kernel.reduce is not None:
        out = kernel.reduce(data, axes)
    else:
        flat = data.reshape(out_key_shape + (-1,) + tuple(rel.bound))
        flat = jnp.moveaxis(flat, len(gb), 0)
        out = _tree_fold(flat, kernel)
    rt = RelType(out_key_shape, rel.bound, out.dtype)
    return TensorRelation(out, rt, out_mask)


def rekey(rel: TensorRelation, key_func: KeyFunc,
          out_arity: Optional[int] = None) -> TensorRelation:
    """ReKey_(keyFunc)(R) — keys are static, so this is a static scatter."""
    keys = rel.valid_keys()
    new_keys = np.asarray([key_func(tuple(int(x) for x in k)) for k in keys],
                          dtype=np.int64)
    if new_keys.ndim == 1:
        new_keys = new_keys[:, None]
    if out_arity is not None and new_keys.shape[1] != out_arity:
        raise ValueError("key_func arity mismatch")
    if len(new_keys) == 0:
        raise ValueError("rekey of an empty relation")
    uniq = {tuple(k) for k in new_keys.tolist()}
    if len(uniq) != len(new_keys):
        raise ValueError("rekey produced duplicate keys (uniqueness violated)")
    f_out = tuple(int(m) + 1 for m in new_keys.max(axis=0))
    flat_src = np.ravel_multi_index(keys.T, rel.key_shape) if rel.key_shape \
        else np.zeros(1, np.int64)
    src = rel.data.reshape((-1,) + tuple(rel.bound))[flat_src]
    out = jnp.zeros(f_out + tuple(rel.bound), rel.data.dtype)
    out = out.at[tuple(new_keys.T)].set(src)
    mask = np.zeros(f_out, bool)
    mask[tuple(new_keys.T)] = True
    if np.all(mask):
        mask = None
    rt = RelType(f_out, rel.bound, rel.data.dtype)
    return TensorRelation(out, rt, mask)


def filt(rel: TensorRelation, bool_func: BoolFunc) -> TensorRelation:
    """σ_(boolFunc)(R) — static key predicate ⇒ static mask update."""
    grid = np.indices(rel.key_shape).reshape(rel.rtype.key_arity, -1).T
    keep = np.asarray([bool(bool_func(tuple(int(x) for x in k)))
                       for k in grid]).reshape(rel.key_shape)
    mask = keep if rel.mask is None else np.logical_and(rel.mask, keep)
    if not mask.any():
        raise ValueError("filter removed every tuple")
    # frontier shrink (paper §4.3 rule 3): slice to the bounding box
    idx = np.argwhere(mask)
    f_out = tuple(int(m) + 1 for m in idx.max(axis=0))
    sl = tuple(slice(0, f) for f in f_out)
    data = rel.data[sl]
    mask = mask[sl]
    if np.all(mask):
        mask = None
    rt = RelType(f_out, rel.bound, rel.data.dtype)
    return TensorRelation(data, rt, mask)


def transform(rel: TensorRelation, kernel: Kernel) -> TensorRelation:
    """λ_(transformFunc)(R)."""
    out = kernel.apply(rel.data)
    out_bound = tuple(kernel.out_bound(rel.bound))
    rt = RelType(rel.key_shape, out_bound, out.dtype)
    return TensorRelation(out, rt, rel.mask)


def tile(rel: TensorRelation, tile_dim: int, tile_size: int) -> TensorRelation:
    """Tile_(tileDim, tileSize)(R) — split an array dim, append a key dim."""
    b = rel.bound
    if b[tile_dim] % tile_size:
        raise ValueError("tile size must divide the bound")
    ntiles = b[tile_dim] // tile_size
    k = rel.rtype.key_arity
    ax = k + tile_dim
    shape = (rel.key_shape + b[:tile_dim] + (ntiles, tile_size)
             + b[tile_dim + 1:])
    x = rel.data.reshape(shape)
    x = jnp.moveaxis(x, ax, k)          # new key dim appended after keys
    new_bound = b[:tile_dim] + (tile_size,) + b[tile_dim + 1:]
    rt = RelType(rel.key_shape + (ntiles,), new_bound, rel.data.dtype)
    mask = None
    if rel.mask is not None:
        mask = np.repeat(rel.mask[..., None], ntiles, axis=-1)
    return TensorRelation(x, rt, mask)


def concat(rel: TensorRelation, key_dim: int, array_dim: int) -> TensorRelation:
    """Concat_(keyDim, arrayDim)(R) — inverse of tile."""
    if rel.mask is not None:
        mt = np.moveaxis(rel.mask, key_dim, -1)
        if not (np.all(mt == mt[..., :1])):
            raise ValueError("concat groups must be complete")
    k = rel.rtype.key_arity
    x = jnp.moveaxis(rel.data, key_dim, k - 1 + array_dim)
    # now the concat key dim sits immediately before the target array axis
    new_key_shape = tuple(s for d, s in enumerate(rel.key_shape)
                          if d != key_dim)
    nb = list(rel.bound)
    nb[array_dim] = rel.key_shape[key_dim] * rel.bound[array_dim]
    x = x.reshape(new_key_shape + tuple(nb))
    mask = None
    if rel.mask is not None:
        mask = np.take(rel.mask, 0, axis=key_dim)
        if np.all(mask):
            mask = None
    rt = RelType(new_key_shape, tuple(nb), rel.data.dtype)
    return TensorRelation(x, rt, mask)
