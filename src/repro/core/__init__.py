"""TRA/IA core — the paper's contribution as a composable JAX module."""
from repro.core.kernels_registry import (Kernel, compose, get_kernel,
                                         register, registered_kernels)
from repro.core.tra import (RelType, TensorRelation, can_fuse, from_tensor,
                            fused_join_agg, to_tensor)
from repro.core.plan import (Bcast, FusedJoinAgg, IAInput, LocalAgg,
                             LocalConcat, LocalFilter, LocalJoin, LocalMap,
                             LocalTile, Placement, Shuf, TraAgg, TraConcat,
                             TraFilter, TraInput, TraJoin, TraReKey, TraTile,
                             TraTransform, check_valid, describe, infer)
from repro.core.compile import compile_tra
from repro.core.cost import (CostReport, HardwareModel, TPU_V5E, comm_cost,
                             cost_plan)
from repro.core.optimize import OptimizeResult, fuse_join_agg, optimize
from repro.core.interp import evaluate_ia, evaluate_tra, jit_ia_plan

__all__ = [
    "Kernel", "compose", "get_kernel", "register", "registered_kernels",
    "RelType", "TensorRelation", "can_fuse", "from_tensor",
    "fused_join_agg", "to_tensor",
    "Bcast", "FusedJoinAgg", "IAInput", "LocalAgg", "LocalConcat",
    "LocalFilter", "LocalJoin", "LocalMap", "LocalTile", "Placement", "Shuf",
    "TraAgg", "TraConcat", "TraFilter", "TraInput", "TraJoin", "TraReKey",
    "TraTile", "TraTransform", "check_valid", "describe", "infer",
    "compile_tra", "CostReport", "HardwareModel", "TPU_V5E", "comm_cost",
    "cost_plan", "OptimizeResult", "fuse_join_agg", "optimize",
    "evaluate_ia", "evaluate_tra", "jit_ia_plan",
]
