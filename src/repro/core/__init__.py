"""TRA/IA core — the paper's contribution as a composable JAX module.

The supported user-facing API is the lazy frontend plus the engine:

    import repro.core as tra
    A = tra.input("A", key_shape=(4, 4), bound=(16, 24))
    B = tra.input("B", key_shape=(4, 4), bound=(24, 12))
    engine = tra.Engine()                  # or Engine(mesh, executor=...)
    C = engine.run(A @ B, A=RA, B=RB)

``evaluate_tra`` / ``evaluate_ia`` / ``jit_ia_plan`` (and
``shardmap_exec.execute_shardmap``) remain as deprecated shims.
"""
from repro.core.kernels_registry import (JoinVjp, Kernel, compose,
                                         get_kernel, register,
                                         registered_kernels)
from repro.core.tra import (RelType, TensorRelation, can_fuse, from_tensor,
                            fused_join_agg, pack_rows, scatter_rows,
                            to_tensor, unpack_rows, zero_rows)
from repro.core.plan import (Bcast, FusedJoinAgg, IAConst, IAInput, LocalAgg,
                             LocalConcat, LocalFilter, LocalJoin, LocalMap,
                             LocalPad, LocalTile, Placement, Shuf, TraAgg,
                             TraConcat, TraConst, TraFilter, TraInput,
                             TraJoin, TraPad, TraReKey, TraTile,
                             TraTransform, as_node, check_valid, describe,
                             infer)
from repro.core.compile import compile_tra
from repro.core.cost import (CostReport, HardwareModel, TPU_V5E, comm_cost,
                             cost_plan)
from repro.core.optimize import OptimizeResult, fuse_join_agg, optimize
from repro.core.expr import (Expr, ExprTypeError, const, einsum,  # noqa: A004
                             input, input_like, ones_like, scalar,
                             scalar_input, wrap)
from repro.core.autodiff import AutodiffError, grad
from repro.core.engine import CacheEntry, CompiledExpr, Engine
from repro.core.faults import (CompileFailure, DeviceOOM, FaultError,
                               FaultInjector, SimulatedFailure)
from repro.core.guards import NumericsError
from repro.core.train import (AdamW, Momentum, SGD, TrainStep, TraOptimizer,
                              TraTrainer, make_train_step)
from repro.core.interp import evaluate_ia, evaluate_tra, jit_ia_plan

__all__ = [
    "JoinVjp", "Kernel", "compose", "get_kernel", "register",
    "registered_kernels",
    "RelType", "TensorRelation", "can_fuse", "from_tensor",
    "fused_join_agg", "pack_rows", "scatter_rows", "to_tensor",
    "unpack_rows", "zero_rows",
    "Bcast", "FusedJoinAgg", "IAConst", "IAInput", "LocalAgg", "LocalConcat",
    "LocalFilter", "LocalJoin", "LocalMap", "LocalPad", "LocalTile",
    "Placement", "Shuf",
    "TraAgg", "TraConcat", "TraConst", "TraFilter", "TraInput", "TraJoin",
    "TraPad", "TraReKey", "TraTile", "TraTransform", "as_node",
    "check_valid", "describe", "infer",
    "compile_tra", "CostReport", "HardwareModel", "TPU_V5E", "comm_cost",
    "cost_plan", "OptimizeResult", "fuse_join_agg", "optimize",
    "Expr", "ExprTypeError", "const", "einsum", "input", "input_like",
    "ones_like", "scalar", "scalar_input", "wrap",
    "AutodiffError", "grad",
    "CacheEntry", "CompiledExpr", "Engine",
    "CompileFailure", "DeviceOOM", "FaultError", "FaultInjector",
    "SimulatedFailure", "NumericsError",
    "AdamW", "Momentum", "SGD", "TrainStep", "TraOptimizer", "TraTrainer",
    "make_train_step",
    "evaluate_ia", "evaluate_tra", "jit_ia_plan",
]
