"""TRA-native training: optimizer update rules as TRA expressions.

PR 3 made the backward pass a TRA plan (:mod:`repro.core.autodiff`); this
module makes the *whole train step* one.  An optimizer here is not a
pytree transformation — it is a builder of TRA ``Expr`` programs over
three families of relations:

* **parameter relations**  — the model weights, block-chunked exactly as
  the forward pass consumes them;
* **gradient relations**   — the autodiff-derived cotangent expressions
  (still lazy: they are sub-DAGs of the same program, never materialized
  between "backward" and "update");
* **optimizer-state relations** — momentum / moment buffers typed like
  their parameter, plus one shared *scalar step-count relation* (key
  ``(1,)``, bound ``(1, 1)``) whose per-step values (Adam bias
  corrections) flow through :meth:`~repro.core.expr.Expr.scale_by`
  broadcast joins as **data**, not kernel constants.

That last point is what makes the training loop a *compile-once* loop:
the step program's structural signature is step-independent, so
:class:`~repro.core.engine.Engine`'s compile cache turns every step after
the first into pure dispatch (``engine.cache_hits`` counts them), and the
optimizer's fused Σ∘⋈ selection fires inside the combined
loss + gradient + update plan like in any other expression.

    step = make_train_step(loss, params=["W1", "W2"], optimizer=AdamW(1e-3))
    trainer = TraTrainer(Engine(), step, params={"W1": RW1, "W2": RW2})
    for _ in range(30):
        trainer.step(X=RX, Y=RY)       # one multi-root cached program

The update rules are deliberately kernel-fused: SGD is a single ``axpy``
join per parameter; the momentum / Adam moment updates are single fused
joins (``mu·m + g``, ``b2·v + (1−b2)·g²``) rather than scale-map + add
chains — see the update-rule kernel section of
:mod:`repro.core.kernels_registry`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp

from repro.core import expr as E
from repro.core.expr import Expr, ExprTypeError
from repro.core.kernels_registry import (make_adam_dir, make_axpy,
                                         make_bias_corr, make_ema,
                                         make_ema_sq, make_momentum,
                                         make_scale_mul)
from repro.core.plan import TraInput, postorder
from repro.core.tra import RelType, TensorRelation

STEP_STATE = "opt.step"                  # shared scalar step-count input
LOSS_ROOT = "loss"                       # reserved root name


def _cokey(a: Expr, b: Expr, kernel) -> Expr:
    """Keywise join of two identically-keyed relations."""
    return a.join(b, on=tuple(range(a.key_arity)), kernel=kernel)


def _zeros_rel(rtype: RelType) -> TensorRelation:
    shape = tuple(rtype.key_shape) + tuple(rtype.bound)
    return TensorRelation(jnp.zeros(shape, rtype.dtype), rtype)


def _scalar_rel(value: float) -> TensorRelation:
    return TensorRelation(jnp.full((1, 1, 1), value, jnp.float32),
                          RelType((1,), (1, 1), jnp.float32))


# ==========================================================================
# Optimizers
# ==========================================================================

class TraOptimizer:
    """Base class: an optimizer whose update rule is a TRA Expr program.

    ``state_inputs`` declares the optimizer-state input relations for a
    parameter set; ``init_state`` produces their step-0 values;
    ``update`` emits the new-parameter and new-state expressions from the
    parameter / gradient / state input expressions.  All three key state
    by name, so :class:`TraTrainer` (or any caller) can thread
    state-out → state-in across steps of one compiled program.
    """

    def state_inputs(self, params: Dict[str, Expr]) -> Dict[str, Expr]:
        return {}

    def init_state(self, params: Dict[str, TensorRelation]
                   ) -> Dict[str, TensorRelation]:
        return {}

    def update(self, params: Dict[str, Expr], grads: Dict[str, Expr],
               state: Dict[str, Expr]
               ) -> Tuple[Dict[str, Expr], Dict[str, Expr]]:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class SGD(TraOptimizer):
    """Stateless SGD: one fused ``axpy(−lr)`` join per parameter."""

    lr: float = 0.01

    def update(self, params, grads, state):
        axpy = make_axpy(-self.lr)
        new_params = {nm: _cokey(p, grads[nm], axpy)
                      for nm, p in params.items()}
        return new_params, {}


@dataclasses.dataclass(frozen=True)
class Momentum(TraOptimizer):
    """Heavy-ball SGD (optax ``trace``): ``m' = mu·m + g``,
    ``p' = p − lr·m'``.  One buffer relation per parameter."""

    lr: float = 0.01
    mu: float = 0.9

    def state_inputs(self, params):
        return {f"{nm}.m": E.input_like(f"{nm}.m", p.rtype)
                for nm, p in params.items()}

    def init_state(self, params):
        return {f"{nm}.m": _zeros_rel(p.rtype)
                for nm, p in params.items()}

    def update(self, params, grads, state):
        mom = make_momentum(self.mu)
        axpy = make_axpy(-self.lr)
        new_params, new_state = {}, {}
        for nm, p in params.items():
            m_new = _cokey(state[f"{nm}.m"], grads[nm], mom)
            new_state[f"{nm}.m"] = m_new
            new_params[nm] = _cokey(p, m_new, axpy)
        return new_params, new_state


@dataclasses.dataclass(frozen=True)
class AdamW(TraOptimizer):
    """AdamW with decoupled weight decay, matching ``optax.adamw``:

        m' = b1·m + (1−b1)·g               (fused ``ema`` join)
        v' = b2·v + (1−b2)·g²              (fused ``emaSq`` join)
        m̂ = m'/(1−b1ᵗ),  v̂ = v'/(1−b2ᵗ)   (``scale_by`` the step relation)
        p' = p − lr·( m̂/(√v̂+eps) + wd·p )

    The step count lives in the shared scalar relation ``opt.step``; the
    bias corrections are computed *from it inside the plan*
    (``biasCorr`` kernels + ``scale_by`` broadcast joins), so the same
    compiled program serves every step — no per-step constants, no
    recompiles.
    """

    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0

    def state_inputs(self, params):
        state = {STEP_STATE: E.scalar_input(STEP_STATE)}
        for nm, p in params.items():
            state[f"{nm}.m"] = E.input_like(f"{nm}.m", p.rtype)
            state[f"{nm}.v"] = E.input_like(f"{nm}.v", p.rtype)
        return state

    def init_state(self, params):
        state = {STEP_STATE: _scalar_rel(0.0)}
        for nm, p in params.items():
            state[f"{nm}.m"] = _zeros_rel(p.rtype)
            state[f"{nm}.v"] = _zeros_rel(p.rtype)
        return state

    def update(self, params, grads, state):
        t_new = state[STEP_STATE].map("stepIncr")
        c1 = t_new.map(make_bias_corr(self.b1))
        c2 = t_new.map(make_bias_corr(self.b2))
        ema = make_ema(self.b1)
        ema_sq = make_ema_sq(self.b2)
        adam_dir = make_adam_dir(self.eps)
        axpy = make_axpy(-self.lr)
        new_params, new_state = {}, {STEP_STATE: t_new}
        for nm, p in params.items():
            g = grads[nm]
            m_new = _cokey(state[f"{nm}.m"], g, ema)
            v_new = _cokey(state[f"{nm}.v"], g, ema_sq)
            new_state[f"{nm}.m"] = m_new
            new_state[f"{nm}.v"] = v_new
            direction = _cokey(m_new.scale_by(c1), v_new.scale_by(c2),
                               adam_dir)
            if self.weight_decay:
                direction = direction + p.map(
                    make_scale_mul(self.weight_decay))
            new_params[nm] = _cokey(p, direction, axpy)
        return new_params, new_state


# ==========================================================================
# Train-step programs
# ==========================================================================

@dataclasses.dataclass
class TrainStep:
    """One optimizer step as a named multi-root TRA program.

    ``roots`` maps output names to expressions: :data:`LOSS_ROOT` (the
    loss relation — its array total is the scalar loss), each parameter
    name to its updated value, and each optimizer-state name to its new
    value.  Compile with ``engine.compile(step.roots)`` (or just call
    ``engine.run(step.roots, ...)`` per step — structurally identical
    dicts hit the compile cache) and rethread ``state_names`` /
    ``param_names`` outputs into the next step's inputs by name —
    :class:`TraTrainer` does exactly that.
    """

    roots: Dict[str, Expr]
    param_names: Tuple[str, ...]
    state_names: Tuple[str, ...]
    optimizer: TraOptimizer

    @property
    def loss(self) -> Expr:
        return self.roots[LOSS_ROOT]


def _input_exprs(root: Expr, names: Sequence[str],
                 what: str) -> Dict[str, Expr]:
    found: Dict[str, Expr] = {}
    for n in postorder(root.node):
        if isinstance(n, TraInput) and n.name in names:
            found[n.name] = E.wrap(n)
    missing = [nm for nm in names if nm not in found]
    if missing:
        present = sorted(n.name for n in postorder(root.node)
                         if isinstance(n, TraInput))
        raise ExprTypeError(
            f"parameters {missing} do not occur in {what} "
            f"(inputs present: {present})")
    return found


def make_train_step(loss: Expr, params: Sequence[Union[str, Expr]],
                    optimizer: TraOptimizer, *,
                    grad_of: Optional[Expr] = None,
                    seed: Optional[Expr] = None) -> TrainStep:
    """Compose loss + autodiff backward + optimizer update into ONE
    multi-root TRA program.

    ``loss`` is the loss expression (any key grid; its array total is the
    scalar loss).  ``params`` are input names (or input ``Expr`` handles)
    to differentiate and update.  ``grad_of``/``seed`` optionally
    differentiate a *different* node with a custom cotangent — the §5.3
    program seeds ``a2 − Y`` on the pre-activation ``z2`` (the
    sigmoid-BCE shortcut) instead of differentiating the clipped-log loss
    kernel itself.

    The returned program's gradient sub-DAGs contain the usual
    ``agg(join(·))`` patterns, so the engine's fused Σ∘⋈ selection fires
    inside the train step exactly as it does in a forward or backward
    plan.
    """
    from repro.core.autodiff import grad as _grad
    names = []
    for p in params:
        if isinstance(p, str):
            names.append(p)
        elif isinstance(p, Expr) and isinstance(p.node, TraInput):
            names.append(p.node.name)
        else:
            raise ExprTypeError(
                f"params entries must be input names or input Exprs, "
                f"got {type(p.node).__name__ if isinstance(p, Expr) else type(p).__name__}")
    if LOSS_ROOT in names:
        raise ExprTypeError(
            f"parameter name {LOSS_ROOT!r} collides with the loss root")
    target = grad_of if grad_of is not None else loss
    grad_list = _grad(target, wrt=names, seed=seed)
    grads = dict(zip(names, grad_list))
    param_exprs = _input_exprs(
        target, names,
        "the loss expression" if grad_of is None
        else "the grad_of expression (gradients differentiate it, "
             "not the loss)")
    state_in = optimizer.state_inputs(param_exprs)
    new_params, new_state = optimizer.update(param_exprs, grads, state_in)
    if set(new_state) != set(state_in):
        raise ExprTypeError(
            f"optimizer state mismatch: inputs {sorted(state_in)} vs "
            f"outputs {sorted(new_state)}")
    clash = (set(names) & set(new_state)) | ({LOSS_ROOT} & set(new_state))
    if clash:
        # e.g. a parameter literally named "W.m" next to Momentum's
        # "W.m" buffer — roots.update would silently drop one program
        raise ExprTypeError(
            f"root names collide between parameters and optimizer state: "
            f"{sorted(clash)}")
    # an existing model/data input named like a derived state relation
    # ("W.m", "opt.step") would collide in the program's shared input
    # namespace — fail here with the real reason, not downstream
    model_inputs = {n.name for r in (loss, target) for n in
                    postorder(r.node) if isinstance(n, TraInput)}
    shadowed = model_inputs & set(state_in)
    if shadowed:
        raise ExprTypeError(
            f"inputs of the loss/grad_of expression collide with "
            f"optimizer-state names: {sorted(shadowed)} — rename the "
            f"inputs or the optimizer's state naming")
    roots: Dict[str, Expr] = {LOSS_ROOT: loss}
    roots.update(new_params)
    roots.update(new_state)
    return TrainStep(roots, tuple(names), tuple(new_state), optimizer)


# ==========================================================================
# The training loop
# ==========================================================================

class TraTrainer:
    """Compile-once training loop over a :class:`TrainStep` program.

    Every ``step`` issues ONE ``engine.run`` of the same named multi-root
    program — step 1 compiles (a cache miss), every later step is pure
    cached dispatch (``engine.cache_hits`` grows by 1 per step).  The
    loop owns the state threading: updated parameter and optimizer-state
    relations come back by name and become the next step's inputs.

    Works on every executor the engine supports; on the distributed
    executors pass the engine a mesh (and input placements) exactly as
    for any other program.

    **Fault tolerance.**  With a :class:`repro.checkpoint.CheckpointStore`
    (``store=`` here or per ``fit`` call), ``fit(..., ckpt_every=N)``
    snapshots params + optimizer state (including the scalar ``opt.step``
    relation) every N applied steps through the store's atomic async
    writer, and recovers from a
    :class:`~repro.core.faults.SimulatedFailure` raised mid-``fit`` by
    restoring the last committed step and continuing.  ``fit(steps)``
    counts *total* applied steps (``self.step_count``), so
    ``fit(steps=K, resume=True)`` on a freshly constructed trainer — a
    new process, possibly a new engine on a **different mesh shape** —
    restores and finishes the remaining ``K − restored`` steps: leaves
    are stored unsharded and the engine's input shardings re-place them
    on first dispatch, which is the elastic re-mesh path.  The replay is
    reproducible from the restore point because the entire optimizer
    state is relation-valued and snapshot by root name.

    **Numerics policy.**  ``skip_nonfinite=N`` skips a step whose loss is
    non-finite (or that raised
    :class:`~repro.core.guards.NumericsError` under the engine's
    ``check_numerics``): params/state/step-count do not advance, the
    event is recorded in ``self.skipped``, and more than ``N``
    *consecutive* skips re-raise — a bounded budget, not a silent
    spin.  ``0`` (default) disables the policy.
    """

    def __init__(self, engine, step: TrainStep,
                 params: Dict[str, TensorRelation], *,
                 store=None, skip_nonfinite: int = 0):
        missing = [nm for nm in step.param_names if nm not in params]
        if missing:
            raise ValueError(f"missing initial parameters: {missing}")
        self.engine = engine
        self.program = step
        self.params = {nm: params[nm] for nm in step.param_names}
        self.state = step.optimizer.init_state(self.params)
        self.history: List[float] = []
        self.store = store
        self.skip_nonfinite = skip_nonfinite
        self.step_count = 0
        self.skipped: List[Tuple[int, float]] = []
        self._consec_skips = 0

    def step(self, **data) -> float:
        """Run one train step; returns the scalar loss (total over the
        loss relation's arrays) and advances params/state in place."""
        from repro.core.guards import NumericsError
        try:
            outs = self.engine.run(self.program.roots, **self.params,
                                   **self.state, **data)
            loss = float(jnp.sum(outs[LOSS_ROOT].data))
            bad = math.isnan(loss) or math.isinf(loss)
        except NumericsError:
            if self.skip_nonfinite <= 0:
                raise
            outs, loss, bad = None, float("nan"), True
        if bad and self.skip_nonfinite > 0:
            self._consec_skips += 1
            self.skipped.append((self.step_count, loss))
            if self._consec_skips > self.skip_nonfinite:
                raise NumericsError(
                    f"{self._consec_skips} consecutive non-finite train "
                    f"steps at step {self.step_count} (budget "
                    f"skip_nonfinite={self.skip_nonfinite}); params/state "
                    f"remain at the last finite step")
            return loss                     # params/state do NOT advance
        self._consec_skips = 0
        self.params = {nm: outs[nm] for nm in self.program.param_names}
        self.state = {nm: outs[nm] for nm in self.program.state_names}
        self.history.append(loss)
        self.step_count += 1
        return loss

    # -- checkpointing -----------------------------------------------------
    def _snapshot(self) -> Dict[str, Dict[str, object]]:
        return {"params": {nm: r.data for nm, r in self.params.items()},
                "state": {nm: r.data for nm, r in self.state.items()}}

    def save_checkpoint(self, store=None, *, sync: bool = False) -> None:
        """Snapshot params + optimizer state at ``self.step_count``.

        Async by default (the atomic COMMIT protocol makes a crash
        mid-write unreadable rather than corrupt); ``sync=True`` blocks.
        """
        store = store if store is not None else self.store
        if store is None:
            raise ValueError("no CheckpointStore configured")
        extra = {"step_count": self.step_count,
                 "history": list(self.history)}
        if sync:
            store.save(self.step_count, self._snapshot(), extra)
        else:
            store.save_async(self.step_count, self._snapshot(), extra)

    def restore_checkpoint(self, store=None,
                           step: Optional[int] = None) -> int:
        """Restore params/state by root name from the last committed step.

        Leaves come back as unsharded host arrays and are rebuilt into
        relations with the *program's* declared rtypes — the current
        engine re-places them (different mesh included) on its next
        dispatch.  Returns the restored step count.
        """
        store = store if store is not None else self.store
        if store is None:
            raise ValueError("no CheckpointStore configured")
        tree, extra = store.restore(self._snapshot(), step)
        self.params = {nm: TensorRelation(jnp.asarray(tree["params"][nm]),
                                          self.params[nm].rtype)
                       for nm in self.params}
        self.state = {nm: TensorRelation(jnp.asarray(tree["state"][nm]),
                                         self.state[nm].rtype)
                      for nm in self.state}
        self.step_count = int(extra["step_count"])
        self.history = [float(x) for x in extra.get("history", [])]
        self._consec_skips = 0
        return self.step_count

    def fit(self, steps: int, *, store=None,
            ckpt_every: Optional[int] = None, resume: bool = False,
            max_recoveries: int = 3, **data) -> List[float]:
        """Train until ``step_count`` reaches ``steps`` on fixed data.

        ``ckpt_every`` snapshots every N applied steps (async, atomic);
        ``resume=True`` first restores the last committed checkpoint (a
        store with no committed step starts fresh); an in-flight
        :class:`~repro.core.faults.SimulatedFailure` triggers restore +
        continue, at most ``max_recoveries`` times.  Returns the loss
        history (restored prefix included).
        """
        from repro.core.faults import SimulatedFailure
        store = store if store is not None else self.store
        if (resume or ckpt_every) and store is None:
            raise ValueError("fit(ckpt_every=/resume=) needs a store")
        if resume:
            try:
                self.restore_checkpoint(store)
            except FileNotFoundError:
                pass                        # nothing committed: fresh start
        if store is not None and ckpt_every and store.latest_step() is None:
            # commit the initial state so a failure before the first
            # periodic snapshot still has a restore point
            self.save_checkpoint(store, sync=True)
        recoveries = 0
        while self.step_count < steps:
            try:
                self.step(**data)
            except SimulatedFailure:
                if store is None or recoveries >= max_recoveries:
                    raise
                recoveries += 1
                store.wait()                # surface a failed async write
                self.restore_checkpoint(store)
                continue
            if store is not None and ckpt_every \
                    and self.step_count % ckpt_every == 0:
                self.save_checkpoint(store)
        if store is not None:
            store.wait()
        return self.history
