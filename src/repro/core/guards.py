"""Numeric guards with plan provenance + the executor run context.

Two related pieces:

* :class:`NumericsError` / the finite-checking machinery behind
  ``Engine(check_numerics=True)``.  The *first* checked node (in plan
  postorder, so producers are checked before consumers) whose output
  contains a NaN/Inf is named in the error — ``NumericsError:
  non-finite values first produced by node 7:TraTransform[log] ...`` —
  which turns "the loss is NaN" into "this kernel diverged".  On the
  ``reference`` executor every (non-structural, see
  :func:`node_needs_check`) node's output gets an eager mask-aware
  finite check.  On ``jit`` the guard is **two-tier** so it stays cheap
  enough to leave on in production: the steady-state program carries
  only *output-level* finite flags (one extra bool sync per dispatch;
  any non-finite intermediate either propagates to an output or is an
  output), and when a flag trips the engine lazily compiles an
  every-node-flagged variant of the same program and re-runs the same
  inputs once — deterministic, so the failure reproduces — to attribute
  the exact first producing node.  ``check_numerics="all"`` puts the
  per-node flags in the primary program instead (every dispatch pays
  the full flag traffic; useful when re-execution is undesirable).  On
  the distributed executors (``gspmd``/``shard_map``) the check wraps
  the executor *outputs* (per root), since per-node probes would
  perturb the collective schedule being tested.

* :class:`ExecContext` — the small per-compile context the
  :class:`~repro.core.engine.Engine` threads through all four executors.
  It carries the fault injector (:mod:`repro.core.faults`), the
  ``check_numerics`` flag machinery, the node-id/label table
  (:func:`label_nodes`, numbering identical to
  :func:`repro.core.engine.plan_sig`), and the ``stream`` flag of the
  OOM degradation ladder (force the fused Σ∘⋈ onto the chunked streaming
  fallback).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


class NumericsError(RuntimeError):
    """A NaN/Inf was produced, attributed to a plan node when possible."""

    def __init__(self, msg: str, node_label: Optional[str] = None):
        super().__init__(msg)
        self.node_label = node_label


def _node_desc(n) -> str:
    """Human-readable node label body (kernel / name detail)."""
    from repro.core import plan as P
    t = type(n).__name__
    if isinstance(n, (P.TraInput, P.IAInput)):
        return f"{t}[{n.name}]"
    if isinstance(n, (P.TraJoin, P.LocalJoin)):
        return f"{t}[{n.kernel.name}]"
    if isinstance(n, P.FusedJoinAgg):
        return f"{t}[{n.join_kernel.name}→{n.agg_kernel.name}]"
    if isinstance(n, (P.TraAgg, P.LocalAgg)):
        return f"{t}[{n.kernel.name}]"
    if isinstance(n, (P.TraTransform, P.LocalMap)):
        return f"{t}[{n.kernel.name}]"
    return t


def label_nodes(roots) -> Dict[int, Tuple[int, str]]:
    """``id(node) -> (nid, label)`` over all roots, postorder, deduped.

    ``nid`` is the node's plan-signature id: the postorder index
    :func:`repro.core.engine.plan_sig` assigns (shared subexpressions
    numbered once; multi-root programs continue numbering across roots in
    root order, matching the tuple-of-signatures cache key).
    """
    from repro.core.plan import as_node, postorder
    out: Dict[int, Tuple[int, str]] = {}
    nid = 0
    for root in roots:
        for n in postorder(as_node(root)):
            if id(n) in out:
                continue
            out[id(n)] = (nid, f"{nid}:{_node_desc(n)}")
            nid += 1
    return out


def finite_flag(data: jax.Array, mask=None) -> Optional[jax.Array]:
    """Scalar bool: every (valid) entry finite.  None for exact dtypes."""
    import numpy as np
    if not jnp.issubdtype(data.dtype, jnp.inexact):
        return None
    if mask is not None and np.asarray(mask).all():
        mask = None                     # static all-ones mask: skip select
    if mask is not None:
        m = jnp.asarray(mask.reshape(mask.shape + (1,) * (data.ndim
                                                          - mask.ndim)))
        data = jnp.where(m, data, jnp.zeros((), data.dtype))
    return jnp.all(jnp.isfinite(data))


def node_needs_check(node, level=True) -> bool:
    """False for structural nodes that cannot *produce* a non-finite
    value from finite inputs (rekey/tile/pad/concat/filter and the IA
    data movements): skipping their flags keeps attribution on the first
    arithmetic producer while trimming guard traffic.  ``level="all"``
    checks every node.
    """
    from repro.core import plan as P
    if level == "all":
        return True
    return not isinstance(node, (P.TraReKey, P.TraTile, P.TraPad,
                                 P.TraConcat, P.TraFilter, P.LocalTile,
                                 P.LocalPad, P.LocalConcat, P.LocalFilter,
                                 P.Bcast, P.Shuf))


def _is_traced(x) -> bool:
    return isinstance(x, jax.core.Tracer)


@dataclasses.dataclass
class ExecContext:
    """Per-compile execution context threaded through the executor walks.

    ``on_node`` is called by the interpreters after each plan node's
    value is computed; it applies node-scoped injected faults and the
    per-node finite check.  ``flags`` accumulates ``(label, traced
    flag)`` pairs during a staged (jit) trace — the engine returns them
    as extra program outputs and raises host-side on the first failure.
    """

    faults: Optional[object] = None          # FaultInjector
    check: object = False                    # False | True (pruned) | "all"
    stream: bool = False                     # force chunked fused streaming
    labels: Dict[int, Tuple[int, str]] = dataclasses.field(
        default_factory=dict)
    flags: List[Tuple[str, jax.Array]] = dataclasses.field(
        default_factory=list)

    @property
    def active(self) -> bool:
        return self.faults is not None or self.check or self.stream

    def ids_of(self, node) -> Tuple[int, str]:
        return self.labels.get(id(node), (-1, type(node).__name__))

    def on_node(self, node, rel):
        """Fault + numerics hook over a freshly computed TensorRelation."""
        nid, label = self.ids_of(node)
        data = rel.data
        if self.faults is not None:
            poisoned = self.faults.on_node(nid, label, data)
            if poisoned is not data:
                from repro.core.tra import TensorRelation
                rel = TensorRelation(poisoned, rel.rtype, rel.mask)
                data = poisoned
        if self.check and node_needs_check(node, self.check):
            flag = finite_flag(data, rel.mask)
            if flag is not None:
                if _is_traced(flag) or _is_traced(data):
                    self.flags.append((label, flag))
                elif not bool(flag):
                    raise NumericsError(
                        f"non-finite values first produced by node {label} "
                        f"(eager finite-check; plan postorder attribution)",
                        node_label=label)
        return rel

    def on_array(self, node, data):
        """Array-valued variant (shard_map local walk): faults only —
        per-node finite checks would add per-shard probes; the engine
        checks distributed-executor outputs instead."""
        if self.faults is None:
            return data
        nid, label = self.ids_of(node)
        return self.faults.on_node(nid, label, data)

    def on_contraction(self, *, stream: bool, chunk: Optional[int],
                       node=None, bytes_live: Optional[int] = None) -> None:
        if self.faults is None:
            return
        nid, label = (-1, "") if node is None else self.ids_of(node)
        self.faults.on_contraction(stream=stream, chunk=chunk, nid=nid,
                                   label=label, bytes_live=bytes_live)

    def take_flags(self) -> List[Tuple[str, jax.Array]]:
        flags, self.flags = list(self.flags), []
        return flags


def check_output_rel(rel, label: str) -> None:
    """Output-level finite check (distributed executors): eager raise."""
    flag = finite_flag(rel.data, rel.mask)
    if flag is not None and not bool(flag):
        raise NumericsError(
            f"non-finite values in executor output {label} (per-node "
            f"attribution is available on the reference/jit executors)",
            node_label=label)


def is_oom_error(exc: BaseException) -> bool:
    """True for injected DeviceOOM and real XLA RESOURCE_EXHAUSTED."""
    from repro.core.faults import DeviceOOM
    if isinstance(exc, DeviceOOM):
        return True
    return ("RESOURCE_EXHAUSTED" in str(exc)
            or "Out of memory" in str(exc)
            or type(exc).__name__ == "XlaRuntimeError"
            and "memory" in str(exc).lower())
