"""Plan IR for the TRA (logical) and IA (physical) algebras.

Logical nodes mirror paper §2; physical nodes mirror paper §3.  Physical
plans additionally carry a :class:`Placement` per node — the paper's
``ALL()`` / ``PART_D()`` predicates — which the validity checker uses to
guarantee that a physical plan is equivalent to its logical source, and the
cost model uses to price ``BCAST``/``SHUF`` exactly.

``LocalTile``/``LocalConcat`` are the Table-1 images of ``Tile``/``Concat``
(a multi-map ``λᴸ`` and a ``Σᴸ∘SHUF`` respectively); because our dense
representation makes them pure reshapes we keep them as first-class nodes
rather than encoding the multi-map arity machinery.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.kernels_registry import Kernel
from repro.core.tra import RelType


# ==========================================================================
# Placements (paper §3: ALL / PART_D)
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class Placement:
    """Tuple-to-site mapping summary.

    ``kind == "replicated"``  — ALL(R): every tuple on every site.
    ``kind == "partitioned"`` — PART_dims(R): key dims ``dims`` are sharded
    over the named mesh ``axes`` (equal length, zipped).

    ``dup_axes`` — mesh axes along which *duplicate keys with partial
    values* exist.  This is the paper's transient state inside a two-phase
    aggregation (R2-5): after the partial ``Σᴸ`` each site holds a partial
    array under the same key.  A subsequent ``SHUF`` lowers to
    ``reduce-scatter`` over these axes and a ``BCAST`` lowers to
    ``all-reduce`` — the TPU-idiomatic realizations.
    """

    kind: str
    dims: Tuple[int, ...] = ()
    axes: Tuple[str, ...] = ()
    dup_axes: Tuple[str, ...] = ()
    dup_kernel: Optional[str] = None   # agg kernel pending over dup_axes

    def __post_init__(self):
        if self.kind not in ("replicated", "partitioned"):
            raise ValueError(self.kind)
        if len(self.dims) != len(self.axes):
            raise ValueError("dims/axes length mismatch")

    @staticmethod
    def replicated() -> "Placement":
        return Placement("replicated")

    @staticmethod
    def partitioned(dims: Sequence[int], axes: Sequence[str],
                    dup_axes: Sequence[str] = (),
                    dup_kernel: Optional[str] = None) -> "Placement":
        return Placement("partitioned", tuple(dims), tuple(axes),
                         tuple(dup_axes), dup_kernel)

    @property
    def is_replicated(self) -> bool:
        return self.kind == "replicated" and not self.dup_axes

    @property
    def has_duplicates(self) -> bool:
        return bool(self.dup_axes)

    def axis_of_dim(self, d: int) -> Optional[str]:
        for dim, ax in zip(self.dims, self.axes):
            if dim == d:
                return ax
        return None

    def describe(self) -> str:
        if self.kind == "replicated" and not self.dup_axes:
            return "ALL"
        inner = ",".join(f"{d}→{a}" for d, a in zip(self.dims, self.axes))
        s = f"PART({inner})" if self.dims else "SINGLE"
        if self.dup_axes:
            s += f"+dup{list(self.dup_axes)}"
        return s


# ==========================================================================
# Logical (TRA) nodes
# ==========================================================================

class TraNode:
    pass


@dataclasses.dataclass(frozen=True, eq=False)
class TraInput(TraNode):
    name: str
    rtype: RelType


@dataclasses.dataclass(frozen=True, eq=False)
class TraConst(TraNode):
    """A literal constant relation: every key of the full grid maps to an
    array filled with ``fill``.

    Introduced for the autodiff layer (Tang et al. direction): the seed
    cotangent of ``Σ`` over all output entries is a ones-relation, and the
    broadcast-back rule of an aggregation needs a zero-cost *shape donor*
    keyed by the pre-aggregation key space.  Constants are materialized
    locally by every executor, so they carry no communication cost and may
    be placed anywhere by the optimizer.
    """

    rtype: RelType
    fill: float


@dataclasses.dataclass(frozen=True, eq=False)
class TraJoin(TraNode):
    left: TraNode
    right: TraNode
    join_keys_l: Tuple[int, ...]
    join_keys_r: Tuple[int, ...]
    kernel: Kernel


@dataclasses.dataclass(frozen=True, eq=False)
class TraAgg(TraNode):
    child: TraNode
    group_by: Tuple[int, ...]
    kernel: Kernel


@dataclasses.dataclass(frozen=True, eq=False)
class TraReKey(TraNode):
    child: TraNode
    key_func: Callable
    tag: str = "keyFunc"


@dataclasses.dataclass(frozen=True, eq=False)
class TraFilter(TraNode):
    child: TraNode
    bool_func: Callable
    tag: str = "boolFunc"


@dataclasses.dataclass(frozen=True, eq=False)
class TraTransform(TraNode):
    child: TraNode
    kernel: Kernel


@dataclasses.dataclass(frozen=True, eq=False)
class TraTile(TraNode):
    child: TraNode
    tile_dim: int
    tile_size: int


@dataclasses.dataclass(frozen=True, eq=False)
class TraConcat(TraNode):
    child: TraNode
    key_dim: int
    array_dim: int


@dataclasses.dataclass(frozen=True, eq=False)
class TraPad(TraNode):
    """Densify: extend a relation with zero tuples to the full grid of
    ``key_shape`` (holes zero-filled, frontier grown, mask dropped).

    The dual of ``σ`` — not in the paper's §2 algebra, but required by its
    differentiation (Tang et al.): the cotangent of a filtered relation is
    *zero* (not absent) at the filtered-out keys, and cotangent fan-in
    accumulation must add relations over one common key grid.  ``Pad`` is
    the op that converts "absent" into "present with value 0".
    """

    child: TraNode
    key_shape: Tuple[int, ...]


# ==========================================================================
# Physical (IA) nodes
# ==========================================================================

class IANode:
    pass


@dataclasses.dataclass(frozen=True, eq=False)
class IAInput(IANode):
    name: str
    rtype: RelType
    placement: Placement


@dataclasses.dataclass(frozen=True, eq=False)
class IAConst(IANode):
    """Physical constant — materialized locally at any placement for free
    (a constant's shards are computable everywhere)."""

    rtype: RelType
    fill: float
    placement: Placement


@dataclasses.dataclass(frozen=True, eq=False)
class Bcast(IANode):
    child: IANode


@dataclasses.dataclass(frozen=True, eq=False)
class Shuf(IANode):
    child: IANode
    part_dims: Tuple[int, ...]
    axes: Tuple[str, ...]


@dataclasses.dataclass(frozen=True, eq=False)
class LocalJoin(IANode):
    left: IANode
    right: IANode
    join_keys_l: Tuple[int, ...]
    join_keys_r: Tuple[int, ...]
    kernel: Kernel


@dataclasses.dataclass(frozen=True, eq=False)
class LocalAgg(IANode):
    child: IANode
    group_by: Tuple[int, ...]
    kernel: Kernel
    # True for the *partial* phase of a two-phase (R2-5) aggregation: the
    # local combine that runs before the shuffle and is NOT yet the final
    # TRA-equivalent value.
    partial: bool = False


@dataclasses.dataclass(frozen=True, eq=False)
class FusedJoinAgg(IANode):
    """Σᴸ∘⋈ᴸ as one physical node — the paper's Σ∘⋈ contraction pattern.

    Semantically ``LocalAgg(LocalJoin(left, right, ...), group_by, ...)``
    (``group_by`` indexes the join's output key space) but lowered without
    materializing the broadcasted join grid: a single blocked contraction
    for (matMul, matAdd)-shaped kernel pairs, a streamed reduction
    otherwise.  ``partial=True`` is the R2-5 partial phase, exactly as on
    :class:`LocalAgg`.
    """

    left: IANode
    right: IANode
    join_keys_l: Tuple[int, ...]
    join_keys_r: Tuple[int, ...]
    join_kernel: Kernel
    group_by: Tuple[int, ...]
    agg_kernel: Kernel
    partial: bool = False


@dataclasses.dataclass(frozen=True, eq=False)
class LocalFilter(IANode):
    child: IANode
    bool_func: Callable
    tag: str = "boolFunc"


@dataclasses.dataclass(frozen=True, eq=False)
class LocalMap(IANode):
    child: IANode
    key_func: Optional[Callable]    # None == idOp on keys
    kernel: Kernel                  # idOp for pure re-keys
    tag: str = "keyFunc"


@dataclasses.dataclass(frozen=True, eq=False)
class LocalTile(IANode):
    child: IANode
    tile_dim: int
    tile_size: int


@dataclasses.dataclass(frozen=True, eq=False)
class LocalConcat(IANode):
    child: IANode
    key_dim: int
    array_dim: int


@dataclasses.dataclass(frozen=True, eq=False)
class LocalPad(IANode):
    """Physical Pad.  Zero-filling holes is always local; *growing* the
    frontier of a partitioned dim would shift the per-site key windows, so
    frontier growth requires a replicated child (the checker enforces it
    via placement inference)."""

    child: IANode
    key_shape: Tuple[int, ...]


def as_node(obj):
    """Unwrap an :class:`repro.core.expr.Expr`-like handle to its plan node.

    Duck-typed (``obj.node``) so this module never imports the frontend;
    plain plan nodes pass through untouched.  Every legacy entry point
    (``evaluate_*``, ``optimize``, ``compile_tra``, ``infer``, ``describe``)
    unwraps through this, so code written against the old API composes with
    ``Expr``-returning builders.
    """
    if isinstance(obj, (TraNode, IANode)):
        return obj
    node = getattr(obj, "node", None)
    if isinstance(node, (TraNode, IANode)):
        return node
    return obj


def children(node) -> Tuple:
    if isinstance(node, (TraJoin, LocalJoin, FusedJoinAgg)):
        return (node.left, node.right)
    if isinstance(node, (TraInput, IAInput, TraConst, IAConst)):
        return ()
    return (node.child,)


def postorder(node) -> list:
    seen: Dict[int, None] = {}
    out = []

    def rec(n):
        if id(n) in seen:
            return
        seen[id(n)] = None
        for c in children(n):
            rec(c)
        out.append(n)

    rec(node)
    return out


def describe(node, indent: int = 0) -> str:
    node = as_node(node)
    pad = "  " * indent
    label = type(node).__name__
    extra = ""
    if isinstance(node, (TraInput, IAInput)):
        extra = f"[{node.name}: f={node.rtype.key_shape} b={node.rtype.bound}]"
        if isinstance(node, IAInput):
            extra += f" @{node.placement.describe()}"
    elif isinstance(node, (TraConst, IAConst)):
        extra = (f"[{node.fill}: f={node.rtype.key_shape} "
                 f"b={node.rtype.bound}]")
        if isinstance(node, IAConst):
            extra += f" @{node.placement.describe()}"
    elif isinstance(node, (TraPad, LocalPad)):
        extra = f"(key_shape={list(node.key_shape)})"
    elif isinstance(node, (TraJoin, LocalJoin)):
        extra = f"(L{list(node.join_keys_l)}=R{list(node.join_keys_r)}, " \
                f"{node.kernel.name})"
    elif isinstance(node, (TraAgg, LocalAgg)):
        extra = f"(gb={list(node.group_by)}, {node.kernel.name})"
        if isinstance(node, LocalAgg) and node.partial:
            extra += "[partial]"
    elif isinstance(node, FusedJoinAgg):
        extra = (f"(LocalJoin(L{list(node.join_keys_l)}"
                 f"=R{list(node.join_keys_r)}, {node.join_kernel.name}) → "
                 f"gb={list(node.group_by)}, {node.agg_kernel.name})")
        if node.partial:
            extra += "[partial]"
    elif isinstance(node, Shuf):
        extra = f"(dims={list(node.part_dims)}→{list(node.axes)})"
    elif isinstance(node, (TraTransform,)):
        extra = f"({node.kernel.name})"
    elif isinstance(node, LocalMap):
        kf = "id" if node.key_func is None else node.tag
        extra = f"(key={kf}, array={node.kernel.name})"
    elif isinstance(node, (TraTile, LocalTile)):
        extra = f"(dim={node.tile_dim}, size={node.tile_size})"
    elif isinstance(node, (TraConcat, LocalConcat)):
        extra = f"(key_dim={node.key_dim}, array_dim={node.array_dim})"
    lines = [f"{pad}{label}{extra}"]
    for c in children(node):
        lines.append(describe(c, indent + 1))
    return "\n".join(lines)


# ==========================================================================
# Static type / frontier / mask / placement inference
# ==========================================================================

@dataclasses.dataclass
class TypeInfo:
    rtype: RelType
    mask: Optional[np.ndarray]          # static validity grid (None == full)
    placement: Optional[Placement]      # None for logical nodes

    @property
    def valid_tuples(self) -> int:
        if self.mask is None:
            return self.rtype.ntuples
        return int(self.mask.sum())

    @property
    def valid_floats(self) -> int:
        import math
        return self.valid_tuples * (math.prod(self.rtype.bound)
                                    if self.rtype.bound else 1)


def _join_types(lt: TypeInfo, rt: TypeInfo, jkl, jkr, kernel) -> TypeInfo:
    f_out_l = list(lt.rtype.key_shape)
    for dl, dr in zip(jkl, jkr):
        f_out_l[dl] = min(lt.rtype.key_shape[dl], rt.rtype.key_shape[dr])
    r_nonjoin = [d for d in range(rt.rtype.key_arity) if d not in jkr]
    key_shape = tuple(f_out_l) + tuple(rt.rtype.key_shape[d]
                                       for d in r_nonjoin)
    bound = tuple(kernel.out_bound(lt.rtype.bound, rt.rtype.bound))
    mask = None
    if lt.mask is not None or rt.mask is not None:
        kl = lt.rtype.key_arity
        lm = (lt.mask if lt.mask is not None
              else np.ones(lt.rtype.key_shape, bool))
        lm = lm[tuple(slice(0, f) for f in f_out_l)]
        rm = (rt.mask if rt.mask is not None
              else np.ones(rt.rtype.key_shape, bool))
        rsl = [slice(None)] * rt.rtype.key_arity
        for dl, dr in zip(jkl, jkr):
            rsl[dr] = slice(0, f_out_l[dl])
        rm = rm[tuple(rsl)]
        out_axis = {dr: jkl[i] for i, dr in enumerate(jkr)}
        for i, dr in enumerate(r_nonjoin):
            out_axis[dr] = kl + i
        order = sorted(range(rt.rtype.key_arity), key=lambda d: out_axis[d])
        rm = np.moveaxis(rm, list(range(rt.rtype.key_arity)),
                         [order.index(d) for d in range(rt.rtype.key_arity)])
        covered = sorted(out_axis.values())
        shape = []
        ci = 0
        for ax in range(len(key_shape)):
            if ci < len(covered) and covered[ci] == ax:
                shape.append(rm.shape[ci])
                ci += 1
            else:
                shape.append(1)
        rm = rm.reshape(shape)
        lm = lm.reshape(tuple(f_out_l) + (1,) * (len(key_shape) - kl))
        mask = np.broadcast_to(lm, key_shape) & np.broadcast_to(rm, key_shape)
        if np.all(mask):
            mask = None
    return TypeInfo(RelType(key_shape, bound, lt.rtype.dtype), mask, None)


def _agg_types(ct: TypeInfo, group_by: Tuple[int, ...]) -> TypeInfo:
    ks = tuple(ct.rtype.key_shape[d] for d in group_by)
    mask = None
    if ct.mask is not None:
        k = ct.rtype.key_arity
        perm = list(group_by) + [d for d in range(k) if d not in group_by]
        mt = np.moveaxis(ct.mask, perm, list(range(k)))
        red = tuple(range(len(group_by), k))
        mask = np.any(mt, axis=red) if red else mt
        if np.all(mask):
            mask = None
    return TypeInfo(RelType(ks, ct.rtype.bound, ct.rtype.dtype), mask, None)


def infer(node, env: Optional[Dict[str, TypeInfo]] = None,
          cache: Optional[Dict[int, TypeInfo]] = None) -> TypeInfo:
    """Exact static inference of (type, mask, placement) for any plan node."""
    node = as_node(node)
    env = env or {}
    cache = cache if cache is not None else {}
    if id(node) in cache:
        return cache[id(node)]

    def rec(n):
        return infer(n, env, cache)

    t: TypeInfo
    if isinstance(node, (TraInput, IAInput)):
        placement = node.placement if isinstance(node, IAInput) else None
        t = TypeInfo(node.rtype, None, placement)
    elif isinstance(node, (TraConst, IAConst)):
        placement = node.placement if isinstance(node, IAConst) else None
        t = TypeInfo(node.rtype, None, placement)
    elif isinstance(node, (TraPad, LocalPad)):
        ct = rec(node.child)
        ks = tuple(node.key_shape)
        if len(ks) != ct.rtype.key_arity or \
                any(k < f for k, f in zip(ks, ct.rtype.key_shape)):
            raise ValueError(
                f"pad key_shape {ks} must cover child frontier "
                f"{ct.rtype.key_shape}")
        t = TypeInfo(RelType(ks, ct.rtype.bound, ct.rtype.dtype), None, None)
        if isinstance(node, LocalPad):
            p = ct.placement
            if p is not None and (p.is_replicated
                                  or ks == ct.rtype.key_shape):
                # mask zero-fill is local; frontier growth needs ALL(R)
                t.placement = p
    elif isinstance(node, (TraJoin, LocalJoin)):
        lt, rt = rec(node.left), rec(node.right)
        t = _join_types(lt, rt, node.join_keys_l, node.join_keys_r,
                        node.kernel)
        if isinstance(node, LocalJoin):
            t.placement = _local_join_placement(node, lt, rt)
    elif isinstance(node, (TraAgg, LocalAgg)):
        ct = rec(node.child)
        t = _agg_types(ct, tuple(node.group_by))
        if isinstance(node, LocalAgg):
            t.placement = _agg_placement(ct, node.group_by, node.kernel,
                                         node.partial)
    elif isinstance(node, FusedJoinAgg):
        lt, rt = rec(node.left), rec(node.right)
        jt = _join_types(lt, rt, node.join_keys_l, node.join_keys_r,
                         node.join_kernel)
        jt.placement = _local_join_placement(node, lt, rt)
        t = _agg_types(jt, tuple(node.group_by))
        if jt.placement is not None:
            t.placement = _agg_placement(jt, node.group_by, node.agg_kernel,
                                         node.partial)
    elif isinstance(node, Bcast):
        ct = rec(node.child)
        t = TypeInfo(ct.rtype, ct.mask, Placement.replicated())
    elif isinstance(node, Shuf):
        ct = rec(node.child)
        t = TypeInfo(ct.rtype, ct.mask,
                     Placement.partitioned(node.part_dims, node.axes))
    elif isinstance(node, (TraFilter, LocalFilter)):
        ct = rec(node.child)
        grid = np.indices(ct.rtype.key_shape).reshape(
            ct.rtype.key_arity, -1).T
        keep = np.asarray([bool(node.bool_func(tuple(int(x) for x in kk)))
                           for kk in grid]).reshape(ct.rtype.key_shape)
        mask = keep if ct.mask is None else (ct.mask & keep)
        idx = np.argwhere(mask)
        if len(idx) == 0:
            raise ValueError("filter removes all tuples")
        f_out = tuple(int(m) + 1 for m in idx.max(axis=0))
        mask = mask[tuple(slice(0, f) for f in f_out)]
        t = TypeInfo(RelType(f_out, ct.rtype.bound, ct.rtype.dtype),
                     None if np.all(mask) else mask,
                     ct.placement if isinstance(node, LocalFilter) else None)
    elif isinstance(node, TraReKey):
        ct = rec(node.child)
        t = _rekey_info(ct, node.key_func)
    elif isinstance(node, LocalMap):
        ct = rec(node.child)
        if node.key_func is None:
            bound = tuple(node.kernel.out_bound(ct.rtype.bound))
            t = TypeInfo(RelType(ct.rtype.key_shape, bound, ct.rtype.dtype),
                         ct.mask, ct.placement)
        else:
            t = _rekey_info(ct, node.key_func)
            bound = tuple(node.kernel.out_bound(ct.rtype.bound))
            t.rtype = t.rtype.with_bound(bound)
            # a key rewrite generally destroys the partitioning property —
            # EXCEPT when it is a pure coordinate permutation, in which
            # case the partitioned dims just relabel (beyond-paper
            # optimizer extension; lets e.g. a row-partitioned relation
            # stay local through a key-transpose).
            t.placement = (ct.placement if ct.placement is not None
                           and ct.placement.is_replicated else None)
            if t.placement is None and ct.placement is not None \
                    and ct.placement.kind == "partitioned" \
                    and not ct.placement.has_duplicates \
                    and ct.mask is None:
                perm = _detect_key_permutation(node.key_func,
                                               ct.rtype.key_shape)
                if perm is not None:
                    plist = list(perm)
                    dims = tuple(plist.index(d)
                                 for d in ct.placement.dims)
                    t.placement = Placement.partitioned(
                        dims, ct.placement.axes)
    elif isinstance(node, TraTransform):
        ct = rec(node.child)
        bound = tuple(node.kernel.out_bound(ct.rtype.bound))
        t = TypeInfo(RelType(ct.rtype.key_shape, bound, ct.rtype.dtype),
                     ct.mask, None)
    elif isinstance(node, (TraTile, LocalTile)):
        ct = rec(node.child)
        b = ct.rtype.bound
        ntiles = b[node.tile_dim] // node.tile_size
        nb = b[:node.tile_dim] + (node.tile_size,) + b[node.tile_dim + 1:]
        mask = None
        if ct.mask is not None:
            mask = np.repeat(ct.mask[..., None], ntiles, axis=-1)
        t = TypeInfo(RelType(ct.rtype.key_shape + (ntiles,), nb,
                             ct.rtype.dtype), mask,
                     ct.placement if isinstance(node, LocalTile) else None)
    elif isinstance(node, (TraConcat, LocalConcat)):
        ct = rec(node.child)
        ks = tuple(s for d, s in enumerate(ct.rtype.key_shape)
                   if d != node.key_dim)
        nb = list(ct.rtype.bound)
        nb[node.array_dim] = (ct.rtype.key_shape[node.key_dim]
                              * ct.rtype.bound[node.array_dim])
        mask = None
        if ct.mask is not None:
            mask = np.take(ct.mask, 0, axis=node.key_dim)
            if np.all(mask):
                mask = None
        t = TypeInfo(RelType(ks, tuple(nb), ct.rtype.dtype), mask, None)
        if isinstance(node, LocalConcat):
            t.placement = _local_concat_placement(node, ct)
    else:
        raise TypeError(f"unknown node {type(node)}")

    # attach input env overrides
    if isinstance(node, (TraInput, IAInput)) and node.name in env:
        t = env[node.name]
    cache[id(node)] = t
    return t


def _detect_key_permutation(key_func, key_shape) -> Optional[Tuple[int, ...]]:
    """Return perm with key_func(k)[j] == k[perm[j]] ∀k, else None."""
    import itertools
    k = len(key_shape)
    if k == 0 or k > 4:
        return None
    grid = np.indices(key_shape).reshape(k, -1).T
    if len(grid) > 128:
        grid = grid[:: len(grid) // 128]
    for perm in itertools.permutations(range(k)):
        ok = True
        for kk in grid:
            kt = tuple(int(x) for x in kk)
            out = tuple(key_func(kt))
            if out != tuple(kt[p] for p in perm):
                ok = False
                break
        if ok:
            return perm
    return None


def _rekey_info(ct: TypeInfo, key_func) -> TypeInfo:
    grid = np.indices(ct.rtype.key_shape).reshape(ct.rtype.key_arity, -1).T
    if ct.mask is not None:
        grid = grid[ct.mask.reshape(-1)]
    new_keys = np.asarray([tuple(key_func(tuple(int(x) for x in kk)))
                           for kk in grid], dtype=np.int64)
    if new_keys.ndim == 1:
        new_keys = new_keys[:, None]
    uniq = {tuple(k) for k in new_keys.tolist()}
    if len(uniq) != len(new_keys):
        raise ValueError("rekey violates key uniqueness")
    f_out = tuple(int(m) + 1 for m in new_keys.max(axis=0))
    mask = np.zeros(f_out, bool)
    mask[tuple(new_keys.T)] = True
    if np.all(mask):
        mask = None
    return TypeInfo(RelType(f_out, ct.rtype.bound, ct.rtype.dtype),
                    mask, None)


# --- placement rules (validity of local ops, paper §3) --------------------

def _local_join_placement(node, lt: TypeInfo,
                          rt: TypeInfo) -> Optional[Placement]:
    """Per-mesh-axis validity of a local join.

    For each mesh axis, a side is either *sharded by it* (on one of its key
    dims) or *replicated along it*.  The local join is TRA-equivalent iff for
    every axis one of the following holds:
      * neither side is sharded by it,
      * exactly one side is sharded by it (the other holds full copies), or
      * both sides are sharded by it on *corresponding join dims*
        (co-partitioned).
    This single rule subsumes the paper's broadcast (BMM), cross-product
    (CPMM) and replication/3-D (RMM) matrix-multiply placements.
    """
    lp, rp = lt.placement, rt.placement
    if lp is None or rp is None:
        return None
    if lp.has_duplicates or rp.has_duplicates:
        return None  # joining partial values is not TRA-equivalent
    if lp.is_replicated and rp.is_replicated:
        return Placement.replicated()

    kl = lt.rtype.key_arity
    r_nonjoin = [d for d in range(rt.rtype.key_arity)
                 if d not in node.join_keys_r]

    def out_dim_of_left(d):
        return d

    def out_dim_of_right(d):
        if d in node.join_keys_r:
            return node.join_keys_l[node.join_keys_r.index(d)]
        return kl + r_nonjoin.index(d)

    l_by_axis = {ax: d for d, ax in zip(lp.dims, lp.axes)} \
        if not lp.is_replicated else {}
    r_by_axis = {ax: d for d, ax in zip(rp.dims, rp.axes)} \
        if not rp.is_replicated else {}

    dims_out, axes_out = [], []
    for ax in sorted(set(l_by_axis) | set(r_by_axis)):
        dl, dr = l_by_axis.get(ax), r_by_axis.get(ax)
        if dl is not None and dr is not None:
            # must be a corresponding join pair
            if dl in node.join_keys_l and \
                    node.join_keys_r[node.join_keys_l.index(dl)] == dr:
                dims_out.append(out_dim_of_left(dl))
                axes_out.append(ax)
            else:
                return None  # mismatched sharding on the same axis
        elif dl is not None:
            dims_out.append(out_dim_of_left(dl))
            axes_out.append(ax)
        else:
            dims_out.append(out_dim_of_right(dr))
            axes_out.append(ax)
    if len(set(dims_out)) != len(dims_out):
        return None  # two axes landed on one output dim — unsupported
    return Placement.partitioned(dims_out, axes_out)


def _agg_placement(ct: TypeInfo, group_by: Tuple[int, ...], kernel: Kernel,
                   partial: bool) -> Optional[Placement]:
    """Shared by :class:`LocalAgg` and the agg half of :class:`FusedJoinAgg`
    (``ct`` is then the virtual join result)."""
    p = ct.placement
    if p is None:
        return None
    if p.has_duplicates:
        return None  # must SHUF (reduce-scatter) / BCAST (all-reduce) first
    if p.is_replicated:
        return Placement.replicated()
    if partial:
        # Partial phase of R2-5: surviving group dims keep their axes; axes
        # on reduced dims become pending-duplicate axes.
        dims, axes, dup = [], [], []
        for d, ax in zip(p.dims, p.axes):
            if d in group_by:
                dims.append(group_by.index(d))
                axes.append(ax)
            else:
                dup.append(ax)
        if not dup:
            return None  # nothing partial about it — use partial=False
        return Placement.partitioned(dims, axes, dup_axes=dup,
                                     dup_kernel=kernel.name)
    # full equivalence requires part dims ⊆ groupByKeys (rule R2-4)
    if not set(p.dims) <= set(group_by):
        return None
    dims = [group_by.index(d) for d in p.dims]
    return Placement.partitioned(dims, p.axes)


def _local_concat_placement(node: LocalConcat,
                            ct: TypeInfo) -> Optional[Placement]:
    p = ct.placement
    if p is None:
        return None
    if p.is_replicated:
        return Placement.replicated()
    if node.key_dim in p.dims:
        return None  # would concatenate across sites — invalid locally
    dims = [d - (1 if d > node.key_dim else 0) for d in p.dims]
    return Placement.partitioned(dims, p.axes)


def check_valid(root: IANode) -> TypeInfo:
    """Infer types over a physical plan, raising if any local op's placement
    preconditions are violated (i.e. the plan is not TRA-equivalent)."""
    root = as_node(root)
    cache: Dict[int, TypeInfo] = {}
    info = infer(root, cache=cache)
    for n in postorder(root):
        ti = cache[id(n)]
        if isinstance(n, (LocalJoin, LocalAgg, LocalConcat, FusedJoinAgg,
                          LocalPad)) \
                and ti.placement is None:
            raise ValueError(
                f"invalid physical plan at {type(n).__name__}: "
                f"placement preconditions unsatisfied\n{describe(n)}")
    if info.placement is not None and info.placement.has_duplicates:
        raise ValueError("plan result still holds partial duplicates; "
                         "finish the two-phase aggregation with SHUF/BCAST")
    return info
